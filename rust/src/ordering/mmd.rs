//! Multiple minimum degree (Liu 1985), §2.3 of the paper: multiple
//! elimination on a *maximal independent set* of pivots within an additive
//! relaxation of the minimum degree, built on the same quotient-graph core
//! as [`super::amd_seq`].
//!
//! Kept as a sequential baseline/ablation: the paper's key observation is
//! that MMD-style maximal independent sets maximize neighborhood *overlap*,
//! which is good sequentially but poisonous for parallelism — ParAMD
//! replaces them with distance-2 independent sets (§3.2).

use crate::graph::csr::SymGraph;
use crate::ordering::amd_seq::{AmdCore, AmdSeq, NodeState};
use crate::ordering::{Ordering, OrderingResult};
use crate::util::timer::Timer;

/// MMD configuration.
#[derive(Clone, Copy, Debug)]
pub struct Mmd {
    /// Additive degree relaxation `delta`: pivots with degree ≤ mindeg +
    /// delta are candidates (Liu's multiple elimination threshold).
    pub delta: i32,
}

impl Default for Mmd {
    fn default() -> Self {
        Self { delta: 0 }
    }
}

impl Ordering for Mmd {
    fn name(&self) -> &'static str {
        "mmd"
    }

    fn order(&self, g: &SymGraph) -> OrderingResult {
        let t = Timer::new();
        let mut core = AmdCore::new(g, AmdSeq::default());
        let mut set_sizes: Vec<u32> = Vec::new();
        loop {
            // Gather an independent set of minimum-degree pivots
            // (independent in the *elimination graph*: no two pivots
            // adjacent, i.e. not connected via A or a shared element).
            let set = core.collect_independent_min_degree_set(self.delta);
            if set.is_empty() {
                break;
            }
            set_sizes.push(set.len() as u32);
            for &p in &set {
                // A pivot may have been merged/mass-eliminated by an
                // earlier elimination in this round only if independence
                // were violated; guard anyway.
                if core.node_state(p as usize) == NodeState::Var {
                    core.remove_from_degree_list(p as usize);
                    core.eliminate(p as usize);
                }
            }
            if core.eliminated() >= g.n {
                break;
            }
        }
        let secs = t.secs();
        let (perm, mut stats) = core.finish();
        stats.set_sizes = set_sizes;
        stats.rounds = stats.set_sizes.len() as u64;
        let mut r = OrderingResult::new(perm);
        r.stats = stats;
        r.phases.add("core", secs);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{mesh2d, random_graph};
    use crate::ordering::test_support::check_ordering_contract;
    use crate::symbolic::fill_in;

    #[test]
    fn valid_on_random_graphs() {
        for seed in 0..6 {
            let g = random_graph(200, 6, seed);
            let r = Mmd::default().order(&g);
            check_ordering_contract(&g, &r);
        }
    }

    #[test]
    fn multiple_elimination_reduces_rounds() {
        let g = mesh2d(20, 20);
        let r = Mmd::default().order(&g);
        check_ordering_contract(&g, &r);
        // Rounds must be far fewer than pivots (many pivots per round).
        assert!(r.stats.rounds < r.stats.pivots, "{:?}", r.stats);
        assert!(!r.stats.set_sizes.is_empty());
    }

    #[test]
    fn relaxation_gives_larger_sets() {
        let g = mesh2d(24, 24);
        let tight = Mmd { delta: 0 }.order(&g);
        let loose = Mmd { delta: 2 }.order(&g);
        let avg = |r: &OrderingResult| {
            r.stats.set_sizes.iter().map(|&s| s as f64).sum::<f64>()
                / r.stats.set_sizes.len() as f64
        };
        assert!(avg(&loose) >= avg(&tight) * 0.9);
    }

    #[test]
    fn quality_comparable_to_amd() {
        let g = mesh2d(18, 18);
        let f_mmd = fill_in(&g, &Mmd::default().order(&g).perm) as f64;
        let f_amd = fill_in(&g, &crate::ordering::amd_seq::AmdSeq::default().order(&g).perm) as f64;
        assert!(f_mmd < f_amd * 2.0 + 100.0, "mmd={f_mmd} amd={f_amd}");
    }
}
