//! Persistent ParAMD worker pool.
//!
//! `ParAmd::order()` used to spawn `t` fresh OS threads per call; on a
//! service handling repeated requests, thread spawn/join dominated
//! request latency. An [`OrderingRuntime`] spawns its workers **once**
//! and parks them on a condvar between jobs:
//!
//! - `run(job)` publishes a borrowed `Fn(usize)` to all workers, wakes
//!   them, and blocks until every worker has finished — so the borrow
//!   can't outlive the call even though workers hold a lifetime-erased
//!   pointer while running;
//! - inside a job, workers synchronize on the runtime's **reusable**
//!   [`Barrier`] (every worker passes each round barrier the same number
//!   of times, so the barrier is reusable across jobs too);
//! - concurrent `run` callers serialize on a submission lock — requests
//!   queue, which is exactly what a shared service pool wants.
//!
//! A worker that panics mid-job is counted and the panic re-raised from
//! `run` once the job drains. (A panic *between* the algorithm's round
//! barriers can still strand peers at the barrier — the same failure
//! mode the old scoped-spawn driver had — which is why the driver
//! converts stalls into a poison flag instead of panicking.)

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Barrier, Condvar, Mutex};
use std::thread::JoinHandle;

/// A lifetime-erased borrow of a `run` job. Only alive between job
/// publication and the last worker's completion, both inside `run`.
#[derive(Clone, Copy)]
struct Job(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared calls are fine) and `run` keeps
// the underlying borrow alive until every worker is done with it.
unsafe impl Send for Job {}

struct PoolState {
    /// Job generation; bumped once per `run`.
    epoch: u64,
    job: Option<Job>,
    /// Workers still running the current job.
    remaining: usize,
    /// Workers whose job closure panicked.
    panicked: usize,
    shutdown: bool,
}

struct PoolShared {
    threads: usize,
    /// Round barrier reused by every job (and across jobs).
    barrier: Barrier,
    state: Mutex<PoolState>,
    go: Condvar,
    done: Condvar,
}

/// A persistent, reusable pool of ParAMD worker threads. Construct once,
/// run many orderings; drop to join the workers.
pub struct OrderingRuntime {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
    /// Serializes concurrent `run` callers (requests queue here).
    submit: Mutex<()>,
}

impl OrderingRuntime {
    /// Spawn a pool of `threads` parked workers (at least one).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            threads,
            barrier: Barrier::new(threads),
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                remaining: 0,
                panicked: 0,
                shutdown: false,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|tid| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("paramd-{tid}"))
                    .spawn(move || worker_loop(tid, &sh))
                    .expect("spawn paramd worker")
            })
            .collect();
        Self {
            shared,
            workers,
            submit: Mutex::new(()),
        }
    }

    /// Pool size; the effective ParAMD thread count for jobs run here.
    pub fn threads(&self) -> usize {
        self.shared.threads
    }

    /// The pool's reusable round barrier (sized to [`Self::threads`]).
    pub fn barrier(&self) -> &Barrier {
        &self.shared.barrier
    }

    /// Run `job(tid)` on every worker and wait for all of them. Callers
    /// from multiple threads serialize; the pool runs one job at a time.
    ///
    /// If any worker's job panicked, the panic is re-raised here — after
    /// the submission guard is released, so the pool stays usable for the
    /// next request (the workers themselves survived via `catch_unwind`).
    pub fn run(&self, job: &(dyn Fn(usize) + Sync)) {
        let panicked = {
            // Tolerate poison: an earlier caller panicking in this region
            // must not brick the shared pool.
            let _exclusive = self
                .submit
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            // SAFETY: we erase the borrow's lifetime to park it in the
            // shared state, but do not leave this block until
            // `remaining == 0`, i.e. until no worker can touch it anymore.
            let erased = Job(unsafe {
                std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(job)
            });
            {
                let mut st = self.shared.state.lock().unwrap();
                st.job = Some(erased);
                st.epoch += 1;
                st.remaining = self.shared.threads;
                st.panicked = 0;
            }
            self.shared.go.notify_all();
            let mut st = self.shared.state.lock().unwrap();
            while st.remaining > 0 {
                st = self.shared.done.wait(st).unwrap();
            }
            st.job = None;
            st.panicked
        };
        assert!(
            panicked == 0,
            "{panicked} ParAMD worker(s) panicked during an ordering job"
        );
    }
}

impl Drop for OrderingRuntime {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.go.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(tid: usize, sh: &PoolShared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch > seen {
                    if let Some(job) = st.job {
                        seen = st.epoch;
                        break job;
                    }
                }
                st = sh.go.wait(st).unwrap();
            }
        };
        // SAFETY: `run` keeps the job borrow alive until we report done.
        let f: &(dyn Fn(usize) + Sync) = unsafe { &*job.0 };
        let ok = catch_unwind(AssertUnwindSafe(|| f(tid))).is_ok();
        let mut st = sh.state.lock().unwrap();
        if !ok {
            st.panicked += 1;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            sh.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering::Relaxed};

    #[test]
    fn runs_jobs_on_all_workers_and_reuses_them() {
        let rt = OrderingRuntime::new(4);
        assert_eq!(rt.threads(), 4);
        let hits = AtomicUsize::new(0);
        for _ in 0..5 {
            rt.run(&|_tid| {
                hits.fetch_add(1, Relaxed);
            });
        }
        assert_eq!(hits.load(Relaxed), 20);
    }

    #[test]
    fn tids_cover_the_pool() {
        let rt = OrderingRuntime::new(3);
        let seen: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        rt.run(&|tid| {
            seen[tid].fetch_add(1, Relaxed);
        });
        for s in &seen {
            assert_eq!(s.load(Relaxed), 1);
        }
    }

    #[test]
    fn barrier_is_usable_inside_jobs_across_jobs() {
        let rt = OrderingRuntime::new(4);
        let counter = AtomicUsize::new(0);
        for round in 1..=3usize {
            rt.run(&|_tid| {
                counter.fetch_add(1, Relaxed);
                rt.barrier().wait();
                // After the barrier every worker must see all increments.
                assert_eq!(counter.load(Relaxed), 4 * round);
                rt.barrier().wait();
            });
        }
    }

    #[test]
    fn drop_joins_workers() {
        let rt = OrderingRuntime::new(2);
        rt.run(&|_| {});
        drop(rt); // must not hang
    }

    #[test]
    fn concurrent_submitters_serialize() {
        let rt = OrderingRuntime::new(2);
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let rt = &rt;
                let total = &total;
                s.spawn(move || {
                    rt.run(&|_tid| {
                        total.fetch_add(1, Relaxed);
                    });
                });
            }
        });
        assert_eq!(total.load(Relaxed), 8);
    }
}
