//! **Crash-consistent on-disk tier** under the in-memory
//! [`ResultCache`](super::ResultCache): a restarted service warms
//! straight from disk instead of re-paying every ordering it ever
//! computed.
//!
//! # Layout
//!
//! A persist directory holds at most three files:
//!
//! - `log.bin` — the append-only record log. Every insert into the
//!   in-memory tier is encoded into a checksummed, length-prefixed
//!   frame ([`record`]) and appended by a background flusher thread.
//! - `snapshot.bin` — a periodic compaction of snapshot + log into one
//!   deduplicated, TTL/version-filtered file, published by atomic
//!   rename so it is always either the old or the new snapshot, never
//!   a half-written one.
//! - `snapshot.tmp` — the in-progress compaction target; ignored (and
//!   overwritten) by recovery.
//!
//! # Recovery
//!
//! [`PersistTier::open`] replays **snapshot → log** (last write wins),
//! then filters by store version tag and TTL. A torn tail — the frame
//! a killed process was half way through appending — fails its length
//! or checksum check, is counted into `recovery_rejects`, and the log
//! is truncated back to the last complete frame so the garbage is
//! never replayed and never followed. A record that checksums but does
//! not decode is likewise quarantined and counted. Corruption is a
//! typed [`PersistError`], never a panic; the first few quarantined
//! errors are kept for inspection ([`PersistTier::recovery_errors`]).
//! Recovered entries are loaded into the in-memory tier, whose
//! exact-verify-on-hit then re-checks each one against its stored CSR
//! on first use — a disk-corrupted-but-checksum-colliding entry still
//! cannot corrupt a result.
//!
//! # Write path
//!
//! Inserts are **write-behind**: the submitting thread encodes the
//! frame (no locks held) and pushes it onto a bounded dirty queue;
//! when the queue is over its byte cap the push blocks — backpressure,
//! not unbounded memory. One flusher thread drains batches, appends,
//! and group-commits with a single fsync per batch. A panicking flush
//! (see the `persist-append` / `persist-fsync` failpoints) is caught
//! and repaired by truncating back to the last fsynced offset: the
//! service degrades to losing at most the in-flight batch, never to a
//! wedged cache.
//!
//! # Failpoints
//!
//! Four sites drive the crash suite: [`failpoint::PERSIST_APPEND`]
//! (between a frame's header and payload — a panic or kill here is a
//! torn tail), [`failpoint::PERSIST_FSYNC`] (before the group commit —
//! `sleep` holds the window open for kill -9 tests),
//! [`failpoint::PERSIST_SNAPSHOT`] (between writing `snapshot.tmp` and
//! the rename), and [`failpoint::PERSIST_RECOVER`] (before replay; a
//! contained panic degrades to an empty warm start on an untouched
//! dir, so the next open replays everything).

pub mod record;

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use super::{CacheKey, CachedOrdering};
use crate::graph::csr::SymGraph;
use crate::util::{failpoint, lock_unpoisoned};
use record::{FrameRead, Record};

/// Byte cap of the dirty queue; pushes block (backpressure) above it.
const QUEUE_CAP_BYTES: usize = 8 << 20;

/// How many quarantined-record errors are kept for inspection.
const MAX_KEPT_ERRORS: usize = 16;

/// A typed persistence failure. Corruption found during recovery is
/// quarantined and counted (`recovery_rejects`), not returned — only
/// environmental failures (unusable directory, failed writes) surface
/// from [`PersistTier::open`] and the flusher.
#[derive(Debug)]
pub enum PersistError {
    /// An OS-level I/O failure, tagged with the operation and path.
    Io {
        /// What the tier was doing (e.g. `"append"`, `"create dir"`).
        op: &'static str,
        /// The file or directory involved.
        path: PathBuf,
        /// The underlying OS error.
        source: std::io::Error,
    },
    /// A record that failed its frame or payload validation.
    Corrupt {
        /// The file the record was read from.
        path: PathBuf,
        /// Byte offset of the offending frame.
        offset: u64,
        /// What check failed.
        reason: String,
    },
}

impl fmt::Display for PersistError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PersistError::Io { op, path, source } => {
                write!(f, "persist {op} failed at {}: {source}", path.display())
            }
            PersistError::Corrupt {
                path,
                offset,
                reason,
            } => write!(
                f,
                "corrupt persist record in {} at byte {offset}: {reason}",
                path.display()
            ),
        }
    }
}

impl std::error::Error for PersistError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PersistError::Io { source, .. } => Some(source),
            PersistError::Corrupt { .. } => None,
        }
    }
}

fn io_err(op: &'static str, path: &Path, source: std::io::Error) -> PersistError {
    PersistError::Io {
        op,
        path: path.to_path_buf(),
        source,
    }
}

/// Knobs of the on-disk tier.
#[derive(Clone, Copy, Debug)]
pub struct PersistConfig {
    /// On-disk byte budget; compaction drops oldest-created records
    /// beyond it (`serve --persist-max-mb`).
    pub max_bytes: u64,
    /// Seconds a record stays replayable; `0` = no expiry
    /// (`serve --cache-ttl-secs`).
    pub ttl_secs: u64,
    /// Store **version tag**: recovery drops every record written
    /// under a different tag, so callers that reuse graph ids with
    /// changed structure invalidate the whole tier by bumping it.
    pub version: u64,
}

impl Default for PersistConfig {
    fn default() -> Self {
        Self {
            max_bytes: 256 << 20,
            ttl_secs: 0,
            version: 0,
        }
    }
}

/// Counter snapshot of a [`PersistTier`], surfaced through
/// `ShardMetrics::report()` and `telemetry::export`.
#[derive(Clone, Debug, Default)]
pub struct PersistMetrics {
    /// Entries replayed into the in-memory tier at the last open.
    pub warm_start_entries: u64,
    /// Payload bytes of those replayed entries.
    pub recovered_bytes: u64,
    /// Corrupt/torn records quarantined (recovery and compaction).
    pub recovery_rejects: u64,
    /// Recovery passes aborted by a contained panic (empty warm start).
    pub recovery_aborts: u64,
    /// Records dropped at recovery/compaction for a version-tag mismatch.
    pub version_drops: u64,
    /// Records dropped at recovery/compaction for TTL expiry.
    pub ttl_drops: u64,
    /// Frames appended and fsynced to the log since open.
    pub appended_records: u64,
    /// Bytes appended and fsynced to the log since open.
    pub appended_bytes: u64,
    /// Frames currently waiting in the dirty queue.
    pub flush_lag: u64,
    /// Flusher batches lost to a contained panic (log repaired back to
    /// the last fsynced offset).
    pub flush_panics: u64,
    /// Flusher batches lost to an I/O error.
    pub io_errors: u64,
    /// Compacted snapshots published.
    pub snapshots: u64,
    /// Wall seconds spent compacting.
    pub snapshot_secs: f64,
    /// Records dropped by the on-disk byte budget at last compaction.
    pub snapshot_dropped: u64,
    /// Durable log length after the last flush.
    pub log_bytes: u64,
    /// Length of the last published snapshot.
    pub snapshot_bytes: u64,
}

impl PersistMetrics {
    /// Render a compact report section (one line).
    pub fn report(&self) -> String {
        format!(
            "persist: warm_start={} recovered_bytes={} rejects={} appends={} \
             flush_lag={} flush_panics={} snapshots={} snapshot~={:.3}s \
             log_bytes={} snapshot_bytes={}\n",
            self.warm_start_entries,
            self.recovered_bytes,
            self.recovery_rejects,
            self.appended_records,
            self.flush_lag,
            self.flush_panics,
            self.snapshots,
            self.snapshot_secs,
            self.log_bytes,
            self.snapshot_bytes
        )
    }
}

#[derive(Default)]
struct Counters {
    warm_start_entries: AtomicU64,
    recovered_bytes: AtomicU64,
    recovery_rejects: AtomicU64,
    recovery_aborts: AtomicU64,
    version_drops: AtomicU64,
    ttl_drops: AtomicU64,
    appended_records: AtomicU64,
    appended_bytes: AtomicU64,
    flush_panics: AtomicU64,
    io_errors: AtomicU64,
    snapshots: AtomicU64,
    snapshot_nanos: AtomicU64,
    snapshot_dropped: AtomicU64,
    log_bytes: AtomicU64,
    snapshot_bytes: AtomicU64,
}

#[derive(Default)]
struct FlushQueue {
    frames: VecDeque<Vec<u8>>,
    queued_bytes: usize,
    enqueued: u64,
    flushed: u64,
    shutdown: bool,
}

struct LogIo {
    file: File,
    /// Length through the last successful fsync; repairs truncate back
    /// to it so torn bytes are never followed by live appends.
    good_len: u64,
    path: PathBuf,
}

impl LogIo {
    fn open(path: PathBuf, initial_len: u64) -> Result<Self, PersistError> {
        let mut file = OpenOptions::new()
            .append(true)
            .create(true)
            .open(&path)
            .map_err(|e| io_err("open log", &path, e))?;
        let good_len = if initial_len < record::FILE_HEADER_BYTES as u64 {
            file.set_len(0).map_err(|e| io_err("reset log", &path, e))?;
            file.write_all(&record::file_header())
                .map_err(|e| io_err("write log header", &path, e))?;
            file.sync_data().map_err(|e| io_err("sync log", &path, e))?;
            record::FILE_HEADER_BYTES as u64
        } else {
            initial_len
        };
        Ok(Self {
            file,
            good_len,
            path,
        })
    }
}

struct Inner {
    dir: PathBuf,
    log_path: PathBuf,
    snap_path: PathBuf,
    cfg: PersistConfig,
    queue: Mutex<FlushQueue>,
    /// Signaled when the queue gains work or shuts down.
    work: Condvar,
    /// Signaled when the flusher makes progress (drain/ack) — wakes
    /// backpressure waiters and [`PersistTier::flush`].
    done: Condvar,
    counters: Counters,
    io: Mutex<LogIo>,
    recovery_errors: Mutex<Vec<PersistError>>,
}

/// The on-disk tier handle. Construct with [`PersistTier::open`],
/// attach to a cache with
/// [`ResultCache::attach_persist`](super::ResultCache::attach_persist);
/// the coordinator shares one cache (and therefore one tier) across
/// shard-engine rebuilds. Dropping the handle drains the dirty queue,
/// flushes, and joins the flusher thread.
pub struct PersistTier {
    inner: Arc<Inner>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

fn unix_now() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map_or(0, |d| d.as_secs())
}

/// Version/TTL admission shared by recovery and compaction.
fn admit(cfg: &PersistConfig, counters: &Counters, rec: Record, now: u64) -> Option<Record> {
    if rec.version != cfg.version {
        counters.version_drops.fetch_add(1, Relaxed);
        return None;
    }
    if cfg.ttl_secs > 0 && now.saturating_sub(rec.created_at) > cfg.ttl_secs {
        counters.ttl_drops.fetch_add(1, Relaxed);
        return None;
    }
    Some(rec)
}

fn keep_error(errors: &Mutex<Vec<PersistError>>, e: PersistError) {
    let mut errs = lock_unpoisoned(errors.lock());
    if errs.len() < MAX_KEPT_ERRORS {
        errs.push(e);
    }
}

struct Replayed {
    /// Offset of the first unreadable byte (`None` = clean to EOF).
    torn_at: Option<u64>,
}

/// Replay one persist file into `map` (last write wins), counting
/// quarantined records. Returns where the file turned unreadable, if
/// anywhere, so the caller can truncate a torn log.
fn replay_file(
    path: &Path,
    cfg: &PersistConfig,
    counters: &Counters,
    errors: &Mutex<Vec<PersistError>>,
    map: &mut HashMap<CacheKey, (Record, usize)>,
    now: u64,
) -> Replayed {
    let buf = match fs::read(path) {
        Ok(b) => b,
        Err(_) => return Replayed { torn_at: None }, // absent: nothing to replay
    };
    if buf.is_empty() {
        return Replayed { torn_at: None };
    }
    if !record::check_file_header(&buf) {
        counters.recovery_rejects.fetch_add(1, Relaxed);
        keep_error(
            errors,
            PersistError::Corrupt {
                path: path.to_path_buf(),
                offset: 0,
                reason: "bad or incompatible file header".into(),
            },
        );
        return Replayed { torn_at: Some(0) };
    }
    let mut off = record::FILE_HEADER_BYTES;
    loop {
        match record::read_frame(&buf, off) {
            FrameRead::Eof => return Replayed { torn_at: None },
            FrameRead::Torn(reason) => {
                counters.recovery_rejects.fetch_add(1, Relaxed);
                keep_error(
                    errors,
                    PersistError::Corrupt {
                        path: path.to_path_buf(),
                        offset: off as u64,
                        reason,
                    },
                );
                return Replayed {
                    torn_at: Some(off as u64),
                };
            }
            FrameRead::Frame { payload, next } => {
                match record::decode_payload(payload) {
                    Ok(rec) => {
                        if let Some(rec) = admit(cfg, counters, rec, now) {
                            map.insert(rec.key, (rec, payload.len()));
                        }
                    }
                    Err(reason) => {
                        // Framing is intact (the length prefix
                        // checksummed), so quarantine just this record
                        // and keep walking.
                        counters.recovery_rejects.fetch_add(1, Relaxed);
                        keep_error(
                            errors,
                            PersistError::Corrupt {
                                path: path.to_path_buf(),
                                offset: off as u64,
                                reason,
                            },
                        );
                    }
                }
                off = next;
            }
        }
    }
}

/// Snapshot→log replay; truncates a torn log tail so it is never
/// followed. Panics (the `persist-recover` failpoint) are contained by
/// the caller.
fn recover(
    log_path: &Path,
    snap_path: &Path,
    cfg: &PersistConfig,
    counters: &Counters,
    errors: &Mutex<Vec<PersistError>>,
) -> Vec<Record> {
    failpoint::hit(failpoint::PERSIST_RECOVER);
    let now = unix_now();
    let mut map: HashMap<CacheKey, (Record, usize)> = HashMap::new();
    // Snapshots are published by atomic rename; a torn one is real
    // corruption — quarantine and use what decoded.
    replay_file(snap_path, cfg, counters, errors, &mut map, now);
    let replayed = replay_file(log_path, cfg, counters, errors, &mut map, now);
    if let Some(at) = replayed.torn_at {
        if let Ok(f) = OpenOptions::new().write(true).open(log_path) {
            let _ = f.set_len(at);
            let _ = f.sync_data();
        }
    }
    let mut bytes = 0u64;
    let recs: Vec<Record> = map
        .into_values()
        .map(|(rec, len)| {
            bytes += len as u64;
            rec
        })
        .collect();
    counters.warm_start_entries.store(recs.len() as u64, Relaxed);
    counters.recovered_bytes.store(bytes, Relaxed);
    recs
}

impl PersistTier {
    /// Open (or create) the tier at `dir`: run recovery, repair any
    /// torn log tail, start the flusher, and return the handle plus
    /// every recovered record for the caller to load into the
    /// in-memory tier. Only environmental failures error; corruption
    /// is quarantined and counted, and a panic during recovery (the
    /// `persist-recover` failpoint) degrades to an empty warm start on
    /// an untouched directory.
    #[allow(clippy::type_complexity)]
    pub fn open(dir: &Path, cfg: PersistConfig) -> Result<(Arc<Self>, Vec<Record>), PersistError> {
        fs::create_dir_all(dir).map_err(|e| io_err("create dir", dir, e))?;
        let log_path = dir.join("log.bin");
        let snap_path = dir.join("snapshot.bin");
        let counters = Counters::default();
        let errors = Mutex::new(Vec::new());
        let recovered = match catch_unwind(AssertUnwindSafe(|| {
            recover(&log_path, &snap_path, &cfg, &counters, &errors)
        })) {
            Ok(recs) => recs,
            Err(_) => {
                counters.recovery_aborts.fetch_add(1, Relaxed);
                Vec::new()
            }
        };
        let log_len = fs::metadata(&log_path).map_or(0, |m| m.len());
        let io = LogIo::open(log_path.clone(), log_len)?;
        counters.log_bytes.store(io.good_len, Relaxed);
        counters
            .snapshot_bytes
            .store(fs::metadata(&snap_path).map_or(0, |m| m.len()), Relaxed);
        let inner = Arc::new(Inner {
            dir: dir.to_path_buf(),
            log_path,
            snap_path,
            cfg,
            queue: Mutex::new(FlushQueue::default()),
            work: Condvar::new(),
            done: Condvar::new(),
            counters,
            io: Mutex::new(io),
            recovery_errors: errors,
        });
        let worker = {
            let inner = Arc::clone(&inner);
            thread::Builder::new()
                .name("paramd-persist".into())
                .spawn(move || worker_loop(&inner))
                .map_err(|e| io_err("spawn flusher", dir, e))?
        };
        Ok((
            Arc::new(Self {
                inner,
                worker: Mutex::new(Some(worker)),
            }),
            recovered,
        ))
    }

    /// The persist directory.
    pub fn dir(&self) -> &Path {
        &self.inner.dir
    }

    /// The tier's configuration.
    pub fn config(&self) -> PersistConfig {
        self.inner.cfg
    }

    /// Encode one cache entry as a durable frame (no locks held; the
    /// hot insert path calls this before moving the entry into the
    /// in-memory tier) stamped with the tier's version tag and the
    /// current time.
    pub fn encode_frame(
        &self,
        key: &CacheKey,
        graph: &SymGraph,
        weights: Option<&[i32]>,
        value: &CachedOrdering,
    ) -> Vec<u8> {
        record::encode(key, self.inner.cfg.version, unix_now(), graph, weights, value)
    }

    /// Queue an encoded frame for the flusher. Blocks while the dirty
    /// queue is over its byte cap — bounded backpressure, not
    /// unbounded memory.
    pub fn enqueue_frame(&self, frame: Vec<u8>) {
        let inner = &self.inner;
        let mut q = lock_unpoisoned(inner.queue.lock());
        while q.queued_bytes >= QUEUE_CAP_BYTES && !q.shutdown {
            q = lock_unpoisoned(inner.done.wait(q));
        }
        if q.shutdown {
            return;
        }
        q.queued_bytes += frame.len();
        q.frames.push_back(frame);
        q.enqueued += 1;
        inner.work.notify_one();
    }

    /// Block until everything queued so far has been offered to disk
    /// (fsynced, or counted lost to a contained flusher failure).
    pub fn flush(&self) {
        let inner = &self.inner;
        let mut q = lock_unpoisoned(inner.queue.lock());
        let target = q.enqueued;
        while q.flushed < target && !q.shutdown {
            q = lock_unpoisoned(inner.done.wait(q));
        }
    }

    /// Flush, then compact snapshot + log into a fresh snapshot now
    /// (tests and operational tooling; the flusher also compacts
    /// automatically once the log outgrows its threshold).
    pub fn compact_now(&self) -> Result<(), PersistError> {
        self.flush();
        let mut io = lock_unpoisoned(self.inner.io.lock());
        self.inner.compact(&mut io)
    }

    /// Snapshot every counter.
    pub fn metrics(&self) -> PersistMetrics {
        let flush_lag = lock_unpoisoned(self.inner.queue.lock()).frames.len() as u64;
        let c = &self.inner.counters;
        PersistMetrics {
            warm_start_entries: c.warm_start_entries.load(Relaxed),
            recovered_bytes: c.recovered_bytes.load(Relaxed),
            recovery_rejects: c.recovery_rejects.load(Relaxed),
            recovery_aborts: c.recovery_aborts.load(Relaxed),
            version_drops: c.version_drops.load(Relaxed),
            ttl_drops: c.ttl_drops.load(Relaxed),
            appended_records: c.appended_records.load(Relaxed),
            appended_bytes: c.appended_bytes.load(Relaxed),
            flush_lag,
            flush_panics: c.flush_panics.load(Relaxed),
            io_errors: c.io_errors.load(Relaxed),
            snapshots: c.snapshots.load(Relaxed),
            snapshot_secs: c.snapshot_nanos.load(Relaxed) as f64 / 1e9,
            snapshot_dropped: c.snapshot_dropped.load(Relaxed),
            log_bytes: c.log_bytes.load(Relaxed),
            snapshot_bytes: c.snapshot_bytes.load(Relaxed),
        }
    }

    /// The first few corruption errors quarantined during recovery /
    /// compaction (rendered; bounded).
    pub fn recovery_errors(&self) -> Vec<String> {
        lock_unpoisoned(self.inner.recovery_errors.lock())
            .iter()
            .map(ToString::to_string)
            .collect()
    }
}

impl Drop for PersistTier {
    fn drop(&mut self) {
        {
            let mut q = lock_unpoisoned(self.inner.queue.lock());
            q.shutdown = true;
            self.inner.work.notify_all();
            self.inner.done.notify_all();
        }
        if let Some(h) = lock_unpoisoned(self.worker.lock()).take() {
            let _ = h.join();
        }
    }
}

impl Inner {
    /// Block for the next batch; `None` = shut down with an empty
    /// queue (a shutdown with queued frames drains them first).
    fn next_batch(&self) -> Option<Vec<Vec<u8>>> {
        let mut q = lock_unpoisoned(self.queue.lock());
        loop {
            if !q.frames.is_empty() {
                let batch: Vec<Vec<u8>> = q.frames.drain(..).collect();
                q.queued_bytes = 0;
                self.done.notify_all(); // free backpressure waiters
                return Some(batch);
            }
            if q.shutdown {
                return None;
            }
            q = lock_unpoisoned(self.work.wait(q));
        }
    }

    fn ack(&self, n: u64) {
        let mut q = lock_unpoisoned(self.queue.lock());
        q.flushed += n;
        self.done.notify_all();
    }

    /// Append a batch and group-commit it with one fsync. The
    /// `persist-append` failpoint sits between a frame's header and
    /// payload — a panic or kill there leaves exactly the torn tail
    /// recovery must truncate.
    fn flush_batch(&self, io: &mut LogIo, batch: &[Vec<u8>]) -> Result<(), PersistError> {
        let mut appended = 0u64;
        for f in batch {
            io.file
                .write_all(&f[..record::FRAME_HEADER_BYTES])
                .map_err(|e| io_err("append", &io.path, e))?;
            failpoint::hit(failpoint::PERSIST_APPEND);
            io.file
                .write_all(&f[record::FRAME_HEADER_BYTES..])
                .map_err(|e| io_err("append", &io.path, e))?;
            appended += f.len() as u64;
        }
        failpoint::hit(failpoint::PERSIST_FSYNC);
        io.file
            .sync_data()
            .map_err(|e| io_err("fsync", &io.path, e))?;
        io.good_len += appended;
        self.counters
            .appended_records
            .fetch_add(batch.len() as u64, Relaxed);
        self.counters.appended_bytes.fetch_add(appended, Relaxed);
        self.counters.log_bytes.store(io.good_len, Relaxed);
        Ok(())
    }

    /// Truncate back to the last fsynced offset after a failed or
    /// panicked flush, so torn bytes are never followed by live
    /// appends (the handle is in append mode — later writes go to the
    /// repaired EOF).
    fn repair(&self, io: &mut LogIo) {
        let _ = io.file.set_len(io.good_len);
        let _ = io.file.sync_data();
        self.counters.log_bytes.store(io.good_len, Relaxed);
    }

    fn compact_threshold(&self) -> u64 {
        (self.cfg.max_bytes / 2).max(64 * 1024)
    }

    /// Merge snapshot + log into a fresh deduplicated snapshot
    /// (published by atomic rename), then truncate the log. Oldest
    /// records are dropped first if the result would exceed the
    /// on-disk budget.
    fn compact(&self, io: &mut LogIo) -> Result<(), PersistError> {
        let t0 = Instant::now();
        let now = unix_now();
        let mut map: HashMap<CacheKey, (Record, usize)> = HashMap::new();
        for path in [&self.snap_path, &self.log_path] {
            replay_file(
                path,
                &self.cfg,
                &self.counters,
                &self.recovery_errors,
                &mut map,
                now,
            );
        }
        let mut recs: Vec<Record> = map.into_values().map(|(rec, _)| rec).collect();
        recs.sort_by_key(|r| std::cmp::Reverse(r.created_at));
        let mut frames: Vec<Vec<u8>> = Vec::new();
        let mut total = record::FILE_HEADER_BYTES as u64;
        let mut dropped = 0u64;
        for r in &recs {
            let f = record::encode(
                &r.key,
                r.version,
                r.created_at,
                &r.graph,
                r.weights.as_deref(),
                &r.value,
            );
            if total + f.len() as u64 > self.cfg.max_bytes {
                dropped += 1;
                continue;
            }
            total += f.len() as u64;
            frames.push(f);
        }
        let tmp = self.dir.join("snapshot.tmp");
        {
            let mut f = File::create(&tmp).map_err(|e| io_err("create snapshot", &tmp, e))?;
            f.write_all(&record::file_header())
                .map_err(|e| io_err("write snapshot", &tmp, e))?;
            for fr in &frames {
                f.write_all(fr).map_err(|e| io_err("write snapshot", &tmp, e))?;
            }
            f.sync_all().map_err(|e| io_err("sync snapshot", &tmp, e))?;
        }
        failpoint::hit(failpoint::PERSIST_SNAPSHOT);
        fs::rename(&tmp, &self.snap_path)
            .map_err(|e| io_err("publish snapshot", &self.snap_path, e))?;
        if let Ok(d) = File::open(&self.dir) {
            let _ = d.sync_all(); // best-effort rename durability
        }
        io.file
            .set_len(record::FILE_HEADER_BYTES as u64)
            .map_err(|e| io_err("truncate log", &io.path, e))?;
        let _ = io.file.sync_data();
        io.good_len = record::FILE_HEADER_BYTES as u64;
        self.counters.snapshots.fetch_add(1, Relaxed);
        self.counters
            .snapshot_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Relaxed);
        self.counters.snapshot_dropped.store(dropped, Relaxed);
        self.counters.snapshot_bytes.store(total, Relaxed);
        self.counters.log_bytes.store(io.good_len, Relaxed);
        Ok(())
    }
}

fn worker_loop(inner: &Arc<Inner>) {
    while let Some(batch) = inner.next_batch() {
        let n = batch.len() as u64;
        {
            let mut io = lock_unpoisoned(inner.io.lock());
            match catch_unwind(AssertUnwindSafe(|| inner.flush_batch(&mut io, &batch))) {
                Ok(Ok(())) => {}
                Ok(Err(_)) => {
                    inner.counters.io_errors.fetch_add(1, Relaxed);
                    inner.repair(&mut io);
                }
                Err(_) => {
                    // A panicked flush (e.g. the persist-append
                    // failpoint) loses at most this batch; the log is
                    // repaired and the flusher keeps serving.
                    inner.counters.flush_panics.fetch_add(1, Relaxed);
                    inner.repair(&mut io);
                }
            }
            if io.good_len > inner.compact_threshold() {
                match catch_unwind(AssertUnwindSafe(|| inner.compact(&mut io))) {
                    Ok(Ok(())) => {}
                    Ok(Err(_)) => {
                        inner.counters.io_errors.fetch_add(1, Relaxed);
                    }
                    Err(_) => {
                        inner.counters.flush_panics.fetch_add(1, Relaxed);
                    }
                }
            }
        }
        inner.ack(n);
    }
}
