//! Stitch per-component ordering results into one global ordering.
//!
//! Components must arrive in **ascending-size order** (component-id
//! order, the same deterministic order [`crate::graph::components`]
//! assigns) — smallest components are eliminated first, matching the
//! tie-break sequential AMD applies to disconnected inputs, and keeping
//! the stitched permutation independent of which shard ran what.
//!
//! Round logs merge *concurrently*, not sequentially: round `r` of the
//! stitched log aggregates the pivots every component eliminated in its
//! own round `r`, because the shards really do run those rounds at the
//! same wall-clock time. Consequently `rounds` is the longest
//! component's count and `modeled_time` the slowest component's, while
//! pivot/GC/work counters sum.

/// One component's ordering result plus its vertex map.
#[derive(Clone, Debug)]
pub struct ComponentResult {
    /// Local→original vertex map from the extraction.
    pub old_of_new: Vec<i32>,
    /// Local permutation over the component's compact ids.
    pub perm: Vec<i32>,
    pub rounds: u64,
    pub gc_count: u64,
    /// Stop-the-world GC seconds of this component's run.
    pub gc_secs: f64,
    pub modeled_time: f64,
    /// Per-round distance-2 set sizes of this component's run.
    pub set_sizes: Vec<u32>,
}

/// The merged ordering of a decomposed request.
#[derive(Clone, Debug, Default)]
pub struct StitchedOrdering {
    /// Global permutation over the original vertex ids.
    pub perm: Vec<i32>,
    /// Longest per-component round count (rounds overlap across shards).
    pub rounds: u64,
    /// Total garbage collections across components.
    pub gc_count: u64,
    /// Total stop-the-world GC seconds across components (GC stalls only
    /// one shard's pool, but the seconds still sum as spent work).
    pub gc_secs: f64,
    /// Slowest component's modeled parallel time.
    pub modeled_time: f64,
    /// Merged per-round pivot counts (element-wise sum over components).
    pub set_sizes: Vec<u32>,
}

/// Merge `comps` (in component-id order) into one ordering of `n`
/// original vertices. Panics if the components don't cover `n` exactly.
pub fn stitch(n: usize, comps: &[ComponentResult]) -> StitchedOrdering {
    let mut out = StitchedOrdering {
        perm: Vec::with_capacity(n),
        ..Default::default()
    };
    for c in comps {
        debug_assert_eq!(c.perm.len(), c.old_of_new.len());
        for &p in &c.perm {
            out.perm.push(c.old_of_new[p as usize]);
        }
        out.rounds = out.rounds.max(c.rounds);
        out.gc_count += c.gc_count;
        out.gc_secs += c.gc_secs;
        out.modeled_time = out.modeled_time.max(c.modeled_time);
        for (r, &s) in c.set_sizes.iter().enumerate() {
            if out.set_sizes.len() <= r {
                out.set_sizes.push(0);
            }
            out.set_sizes[r] += s;
        }
    }
    assert_eq!(out.perm.len(), n, "stitched components must cover the graph");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::perm::is_valid_perm;

    fn comp(old: Vec<i32>, perm: Vec<i32>, rounds: u64, sets: Vec<u32>) -> ComponentResult {
        ComponentResult {
            old_of_new: old,
            perm,
            rounds,
            gc_count: 1,
            gc_secs: 0.125,
            modeled_time: rounds as f64,
            set_sizes: sets,
        }
    }

    #[test]
    fn stitch_translates_and_concatenates() {
        // Component 0 = {2, 5} eliminated 5-then-2; component 1 = {0, 1, 3}
        // eliminated 1, 3, 0.
        let s = stitch(
            5,
            &[
                comp(vec![2, 5], vec![1, 0], 2, vec![1, 1]),
                comp(vec![0, 1, 3], vec![1, 2, 0], 3, vec![1, 1, 1]),
            ],
        );
        assert_eq!(s.perm, vec![5, 2, 1, 3, 0]);
        assert_eq!(s.rounds, 3, "rounds overlap, take the max");
        assert_eq!(s.gc_count, 2);
        assert!((s.gc_secs - 0.25).abs() < 1e-12, "GC seconds sum");
        assert_eq!(s.set_sizes, vec![2, 2, 1], "round-wise sum");
        assert!((s.modeled_time - 3.0).abs() < 1e-12);
    }

    #[test]
    fn stitched_perm_of_a_real_split_is_valid() {
        use crate::graph::components::{connected_components, split_components};
        use crate::graph::csr::SymGraph;
        use crate::matgen::mesh2d;

        // Two meshes side by side in one vertex space.
        let a = mesh2d(4, 4);
        let mut edges = Vec::new();
        for v in 0..a.n {
            for &u in a.neighbors(v) {
                if (u as usize) > v {
                    edges.push((v, u as usize));
                    edges.push((v + a.n, u as usize + a.n));
                }
            }
        }
        let g = SymGraph::from_edges(2 * a.n, &edges);
        let comps = connected_components(&g);
        assert_eq!(comps.count, 2);
        let parts = split_components(&g, &comps);
        // Identity local perms: the stitch is just the vertex maps.
        let results: Vec<ComponentResult> = parts
            .iter()
            .map(|p| {
                comp(
                    p.old_of_new.clone(),
                    (0..p.graph.n as i32).collect(),
                    1,
                    vec![p.graph.n as u32],
                )
            })
            .collect();
        let s = stitch(g.n, &results);
        assert!(is_valid_perm(&s.perm));
        assert_eq!(s.set_sizes, vec![g.n as u32]);
    }

    #[test]
    #[should_panic(expected = "cover the graph")]
    fn stitch_rejects_missing_vertices() {
        stitch(3, &[comp(vec![0], vec![0], 1, vec![1])]);
    }
}
