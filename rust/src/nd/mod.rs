//! Multilevel nested dissection — the comparator ordering of the paper's
//! §4.6 (cuDSS ND, a METIS variant). Same algorithmic family as METIS:
//!
//! 1. coarsen by heavy-edge matching until the graph is small;
//! 2. bisect the coarsest graph by BFS region growing from a
//!    pseudo-peripheral vertex;
//! 3. uncoarsen, refining the edge cut with Fiduccia–Mattheyses passes at
//!    every level;
//! 4. turn the edge separator into a vertex separator (greedy cover);
//! 5. recurse on the two parts; order leaves with AMD; emit
//!    `[left, right, separator]`.

pub mod bisect;
pub mod coarsen;
pub mod separator;

use crate::graph::csr::SymGraph;
use crate::ordering::{amd_seq::AmdSeq, Ordering, OrderingResult};
use crate::util::timer::Timer;

/// Nested dissection configuration.
#[derive(Clone, Copy, Debug)]
pub struct NestedDissection {
    /// Stop recursion below this many vertices; order the leaf with AMD.
    pub leaf_size: usize,
    /// Coarsening stops at this size.
    pub coarsen_to: usize,
    /// FM refinement passes per level.
    pub fm_passes: usize,
    /// RNG seed (matching + tie-breaking).
    pub seed: u64,
}

impl Default for NestedDissection {
    fn default() -> Self {
        Self {
            leaf_size: 64,
            coarsen_to: 200,
            fm_passes: 4,
            seed: 0x5eed,
        }
    }
}

impl Ordering for NestedDissection {
    fn name(&self) -> &'static str {
        "nd"
    }

    fn order(&self, g: &SymGraph) -> OrderingResult {
        let t = Timer::new();
        let mut perm = Vec::with_capacity(g.n);
        let all: Vec<i32> = (0..g.n as i32).collect();
        self.dissect(g, &all, &mut perm);
        debug_assert_eq!(perm.len(), g.n);
        let mut r = OrderingResult::new(perm);
        r.phases.add("core", t.secs());
        r
    }
}

impl NestedDissection {
    /// Recursively order the subgraph induced by `verts` (original ids),
    /// appending to `out` in elimination order.
    fn dissect(&self, g: &SymGraph, verts: &[i32], out: &mut Vec<i32>) {
        if verts.len() <= self.leaf_size {
            self.order_leaf(g, verts, out);
            return;
        }
        let (sub, ids) = induced_subgraph(g, verts);
        let parts = bisect::multilevel_bisect(&sub, self);
        let (left, right, sep) = separator::vertex_separator(&sub, &parts);
        // Degenerate split (refinement collapse): fall back to AMD on the
        // whole piece to guarantee progress.
        if left.is_empty() || right.is_empty() {
            self.order_leaf(g, verts, out);
            return;
        }
        let to_orig = |v: &i32| ids[*v as usize];
        let lverts: Vec<i32> = left.iter().map(to_orig).collect();
        let rverts: Vec<i32> = right.iter().map(to_orig).collect();
        self.dissect(g, &lverts, out);
        self.dissect(g, &rverts, out);
        out.extend(sep.iter().map(to_orig));
    }

    fn order_leaf(&self, g: &SymGraph, verts: &[i32], out: &mut Vec<i32>) {
        if verts.len() <= 2 {
            out.extend_from_slice(verts);
            return;
        }
        let (sub, ids) = induced_subgraph(g, verts);
        let r = AmdSeq::default().order(&sub);
        out.extend(r.perm.iter().map(|&v| ids[v as usize]));
    }
}

/// Induced subgraph of `verts`; returns the subgraph plus the local→orig
/// id map.
pub fn induced_subgraph(g: &SymGraph, verts: &[i32]) -> (SymGraph, Vec<i32>) {
    let mut local = vec![-1i32; g.n];
    for (k, &v) in verts.iter().enumerate() {
        local[v as usize] = k as i32;
    }
    let mut rowptr = vec![0usize; verts.len() + 1];
    let mut colind = Vec::new();
    for (k, &v) in verts.iter().enumerate() {
        for &u in g.neighbors(v as usize) {
            if local[u as usize] != -1 {
                colind.push(local[u as usize]);
            }
        }
        rowptr[k + 1] = colind.len();
    }
    // Rows inherit sortedness only if `verts` is sorted; sort each row.
    for k in 0..verts.len() {
        colind[rowptr[k]..rowptr[k + 1]].sort_unstable();
    }
    (
        SymGraph {
            n: verts.len(),
            rowptr,
            colind,
        },
        verts.to_vec(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{mesh2d, mesh3d, random_graph};
    use crate::ordering::test_support::check_ordering_contract;
    use crate::symbolic::fill_in;

    #[test]
    fn valid_on_meshes() {
        let g = mesh2d(20, 20);
        let r = NestedDissection::default().order(&g);
        check_ordering_contract(&g, &r);
    }

    #[test]
    fn valid_on_random_graphs() {
        for seed in 0..4 {
            let g = random_graph(300, 6, seed);
            let r = NestedDissection::default().order(&g);
            check_ordering_contract(&g, &r);
        }
    }

    #[test]
    fn valid_on_disconnected_graphs() {
        // Two disjoint meshes.
        let a = mesh2d(10, 10);
        let mut edges = vec![];
        for v in 0..a.n {
            for &u in a.neighbors(v) {
                if (u as usize) > v {
                    edges.push((v, u as usize));
                    edges.push((v + a.n, u as usize + a.n));
                }
            }
        }
        let g = SymGraph::from_edges(2 * a.n, &edges);
        let r = NestedDissection::default().order(&g);
        check_ordering_contract(&g, &r);
    }

    #[test]
    fn beats_natural_ordering_on_3d_mesh() {
        let g = mesh3d(8, 8, 8);
        let r = NestedDissection::default().order(&g);
        check_ordering_contract(&g, &r);
        let natural: Vec<i32> = (0..g.n as i32).collect();
        assert!(fill_in(&g, &r.perm) < fill_in(&g, &natural));
    }

    #[test]
    fn fill_competitive_with_amd_on_meshes() {
        // The paper's Table 4.4: ND produces *fewer* fill-ins than AMD on
        // large 3D meshes; at mini scale we accept parity within 2×.
        let g = mesh3d(9, 9, 9);
        let f_nd = fill_in(&g, &NestedDissection::default().order(&g).perm) as f64;
        let f_amd = fill_in(&g, &AmdSeq::default().order(&g).perm) as f64;
        assert!(f_nd < 2.0 * f_amd, "nd={f_nd} amd={f_amd}");
    }

    #[test]
    fn induced_subgraph_correct() {
        let g = mesh2d(3, 3);
        let verts = vec![0i32, 1, 3, 4];
        let (sub, ids) = induced_subgraph(&g, &verts);
        sub.validate().unwrap();
        assert_eq!(ids, verts);
        // 0-1, 0-3, 1-4, 3-4 survive.
        assert_eq!(sub.nedges(), 4);
    }

    #[test]
    fn tiny_graphs() {
        for n in 0..5 {
            let g = SymGraph::from_edges(n, &[]);
            let r = NestedDissection::default().order(&g);
            check_ordering_contract(&g, &r);
        }
    }
}
