//! Connected-component decomposition of a [`SymGraph`].
//!
//! AMD-family orderings never let elimination in one component influence
//! another (there are no quotient-graph paths across components), so a
//! disconnected graph is embarrassingly parallel *across* components —
//! the cheapest source of the cross-step independence the paper's §4
//! "limited parallelism within elimination steps" wall calls for. The
//! shard engine ([`crate::ordering::shard`]) uses this module to split a
//! request into per-component subproblems and later stitch the
//! per-component permutations back together.
//!
//! Two operations:
//! - [`connected_components`] — union-find (path-halving, union by size)
//!   labeling. Component ids are assigned in **ascending size order**
//!   (ties: smallest original vertex first), the deterministic order the
//!   stitcher emits components in.
//! - [`split_components`] — extract each component as its own compact
//!   [`SymGraph`] plus the `old_of_new` vertex map needed to translate a
//!   local permutation back to original vertex ids. Local ids are
//!   assigned in increasing original-vertex order, so extraction
//!   preserves the sorted-neighbor invariant without re-sorting.

use crate::graph::csr::SymGraph;

/// A component labeling of a graph.
#[derive(Clone, Debug)]
pub struct Components {
    /// Number of connected components.
    pub count: usize,
    /// `label[v]` = component id of vertex `v`, in `0..count`. Ids are
    /// ordered by ascending component size, ties by smallest vertex.
    pub label: Vec<i32>,
    /// `sizes[c]` = vertex count of component `c` (ascending).
    pub sizes: Vec<usize>,
}

impl Components {
    /// Whether the graph is connected (or empty).
    pub fn is_connected(&self) -> bool {
        self.count <= 1
    }
}

/// Label the connected components of `g` with a union-find pass.
pub fn connected_components(g: &SymGraph) -> Components {
    let n = g.n;
    let mut parent: Vec<u32> = (0..n as u32).collect();
    let mut size = vec![1u32; n];

    fn find(parent: &mut [u32], mut x: u32) -> u32 {
        while parent[x as usize] != x {
            let grand = parent[parent[x as usize] as usize];
            parent[x as usize] = grand;
            x = grand;
        }
        x
    }

    for v in 0..n {
        for &u in g.neighbors(v) {
            let a = find(&mut parent, v as u32);
            let b = find(&mut parent, u as u32);
            if a != b {
                let (big, small) = if size[a as usize] >= size[b as usize] {
                    (a, b)
                } else {
                    (b, a)
                };
                parent[small as usize] = big;
                size[big as usize] += size[small as usize];
            }
        }
    }

    // Dense temporary ids in first-seen (= smallest-vertex) order.
    let mut root_id = vec![-1i32; n];
    let mut found: Vec<(usize, usize)> = Vec::new(); // (size, first vertex)
    let mut label = vec![0i32; n];
    for v in 0..n {
        let r = find(&mut parent, v as u32) as usize;
        if root_id[r] < 0 {
            root_id[r] = found.len() as i32;
            found.push((size[r] as usize, v));
        }
        label[v] = root_id[r];
    }

    // Final ids: ascending by (size, first vertex) — deterministic.
    let mut order: Vec<usize> = (0..found.len()).collect();
    order.sort_by_key(|&i| (found[i].0, found[i].1));
    let mut remap = vec![0i32; found.len()];
    for (new_id, &tmp) in order.iter().enumerate() {
        remap[tmp] = new_id as i32;
    }
    for l in label.iter_mut() {
        *l = remap[*l as usize];
    }
    let sizes: Vec<usize> = order.iter().map(|&i| found[i].0).collect();
    Components {
        count: found.len(),
        label,
        sizes,
    }
}

/// One extracted component: a compact subgraph plus the map back to the
/// original vertex ids.
#[derive(Clone, Debug)]
pub struct Component {
    pub graph: SymGraph,
    /// `old_of_new[k]` = original vertex of local vertex `k`. Strictly
    /// increasing (local ids follow original vertex order).
    pub old_of_new: Vec<i32>,
}

/// Extract every component of `g` as its own graph, in component-id
/// (ascending-size) order.
pub fn split_components(g: &SymGraph, comps: &Components) -> Vec<Component> {
    let n = g.n;
    let mut new_of_old = vec![0i32; n];
    let mut out: Vec<Component> = comps
        .sizes
        .iter()
        .map(|&s| Component {
            graph: SymGraph {
                n: s,
                rowptr: Vec::with_capacity(s + 1),
                colind: Vec::new(),
            },
            old_of_new: Vec::with_capacity(s),
        })
        .collect();
    for v in 0..n {
        let c = comps.label[v] as usize;
        new_of_old[v] = out[c].old_of_new.len() as i32;
        out[c].old_of_new.push(v as i32);
    }
    for comp in out.iter_mut() {
        let sub = &mut comp.graph;
        sub.rowptr.push(0);
        for &ov in &comp.old_of_new {
            for &u in g.neighbors(ov as usize) {
                sub.colind.push(new_of_old[u as usize]);
            }
            sub.rowptr.push(sub.colind.len());
        }
        debug_assert_eq!(sub.rowptr.len(), sub.n + 1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn connected_graph_is_one_component() {
        let g = crate::matgen::mesh2d(5, 4);
        let c = connected_components(&g);
        assert!(c.is_connected());
        assert_eq!(c.count, 1);
        assert_eq!(c.sizes, vec![20]);
        assert!(c.label.iter().all(|&l| l == 0));
    }

    #[test]
    fn labels_and_sizes_ascend() {
        // Components: {0,1,2} (path), {3,4} (edge), {5} (isolated),
        // {6,7,8,9} (cycle) — sizes 1, 2, 3, 4 after the ascending sort.
        let g = SymGraph::from_edges(
            10,
            &[(0, 1), (1, 2), (3, 4), (6, 7), (7, 8), (8, 9), (9, 6)],
        );
        let c = connected_components(&g);
        assert_eq!(c.count, 4);
        assert_eq!(c.sizes, vec![1, 2, 3, 4]);
        assert_eq!(c.label[5], 0, "singleton is the smallest component");
        assert_eq!(c.label[3], c.label[4]);
        assert_eq!(c.label[0], c.label[2]);
        assert_eq!(c.label[6], 3, "cycle is the largest component");
    }

    #[test]
    fn equal_sizes_tie_break_by_smallest_vertex() {
        let g = SymGraph::from_edges(4, &[(2, 3), (0, 1)]);
        let c = connected_components(&g);
        assert_eq!(c.count, 2);
        assert_eq!(c.label[0], 0, "component containing vertex 0 first");
        assert_eq!(c.label[2], 1);
    }

    #[test]
    fn split_yields_valid_subgraphs_covering_every_vertex() {
        let g = SymGraph::from_edges(
            9,
            &[(0, 4), (4, 8), (1, 3), (3, 5), (5, 1), (2, 7)],
        );
        let c = connected_components(&g);
        let parts = split_components(&g, &c);
        assert_eq!(parts.len(), c.count);
        let mut seen = vec![false; 9];
        let mut edges = 0;
        for (i, p) in parts.iter().enumerate() {
            p.graph.validate().unwrap();
            assert_eq!(p.graph.n, c.sizes[i]);
            assert_eq!(p.old_of_new.len(), c.sizes[i]);
            for w in p.old_of_new.windows(2) {
                assert!(w[0] < w[1], "old_of_new must be increasing");
            }
            for &ov in &p.old_of_new {
                assert!(!seen[ov as usize], "vertex assigned twice");
                seen[ov as usize] = true;
            }
            // Edges survive the relabeling.
            for lv in 0..p.graph.n {
                let ov = p.old_of_new[lv] as usize;
                for &lu in p.graph.neighbors(lv) {
                    let ou = p.old_of_new[lu as usize];
                    assert!(g.neighbors(ov).binary_search(&ou).is_ok());
                }
            }
            edges += p.graph.nedges();
        }
        assert!(seen.iter().all(|&s| s), "every vertex lands somewhere");
        assert_eq!(edges, g.nedges(), "no edge lost or invented");
    }

    #[test]
    fn empty_graph_has_no_components() {
        let g = SymGraph::from_edges(0, &[]);
        let c = connected_components(&g);
        assert_eq!(c.count, 0);
        assert!(c.is_connected());
        assert!(split_components(&g, &c).is_empty());
    }

    #[test]
    fn isolated_vertices_each_form_a_component() {
        let g = SymGraph::from_edges(5, &[]);
        let c = connected_components(&g);
        assert_eq!(c.count, 5);
        assert_eq!(c.sizes, vec![1; 5]);
        let parts = split_components(&g, &c);
        for p in &parts {
            assert_eq!(p.graph.n, 1);
            assert_eq!(p.graph.nnz(), 0);
        }
    }
}
