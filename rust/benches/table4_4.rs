//! Table 4.4: #fill-ins of SuiteSparse-style AMD vs ParAMD vs ND on the
//! SPD subset (mean over shared random permutations).

#[path = "bench_common/mod.rs"]
mod bench_common;

use paramd::bench_util::{fmt_sci, Table};
use paramd::matgen;
use paramd::nd::NestedDissection;
use paramd::ordering::{amd_seq::AmdSeq, paramd::ParAmd, Ordering};
use paramd::symbolic::fill_in;
use paramd::util::stats;

fn main() {
    let t = bench_common::threads();
    bench_common::banner("Table 4.4 — #fill-ins by ordering method", "paper §4.6 Table 4.4");
    let mut table = Table::new(&["Matrix", "AMD", "ParAMD", "ND", "ND/AMD"]);
    for e in matgen::suite() {
        if !e.symmetric {
            continue;
        }
        let g0 = (e.gen)(bench_common::scale());
        let perms = bench_common::random_permutations(&g0, 3);
        let mut f_amd = vec![];
        let mut f_par = vec![];
        let mut f_nd = vec![];
        for g in &perms {
            f_amd.push(fill_in(g, &AmdSeq::default().order(g).perm) as f64);
            f_par.push(fill_in(g, &ParAmd::new(t).order(g).perm) as f64);
            f_nd.push(fill_in(g, &NestedDissection::default().order(g).perm) as f64);
        }
        table.row(vec![
            e.name.into(),
            fmt_sci(stats::mean(&f_amd)),
            fmt_sci(stats::mean(&f_par)),
            fmt_sci(stats::mean(&f_nd)),
            format!("{:.2}x", stats::mean(&f_nd) / stats::mean(&f_amd)),
        ]);
    }
    table.print();
    println!(
        "\npaper: ND reaches 0.64–0.93x of AMD's fill at 24k–5.3M rows; at mini\n\
         scale separators are relatively larger, so ND/AMD near or above 1.0 is\n\
         expected — the ParAMD ≈ 1.0–1.2x AMD column is the reproduced claim."
    );
}
