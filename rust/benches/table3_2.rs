//! Table 3.2: average distance-2 independent-set sizes for relaxation
//! factors mult ∈ {1.0, 1.1, 1.2} — the case for degree relaxation.
//!
//! Deviation from the paper: we report the sets of our single-iteration
//! Luby selection (§3.4 argues maximality is unnecessary); the paper's
//! table measured fully maximal sets, so its absolute sizes are larger.
//! The phenomenon the table demonstrates — relaxation grows the sets by
//! an order of magnitude — is reproduced.

#[path = "bench_common/mod.rs"]
mod bench_common;

use paramd::bench_util::Table;
use paramd::matgen;
use paramd::ordering::paramd::ParAmd;

fn main() {
    bench_common::banner("Table 3.2 — D2 set sizes vs mult", "paper §3.2 Table 3.2");
    let mut table = Table::new(&["Matrix", "mult = 1.0", "mult = 1.1", "mult = 1.2"]);
    for name in ["mini_nd24k", "mini_flan", "mini_nlpkkt"] {
        let e = matgen::suite_entry(name).unwrap();
        let g = (e.gen)(bench_common::scale());
        let mut cells = vec![name.to_string()];
        for mult in [1.0, 1.1, 1.2] {
            let (r, _) = ParAmd::new(1)
                .with_mult(mult)
                .with_lim_total(usize::MAX / 2) // no candidate cap for this measurement
                .order_detailed(&g);
            let s = &r.stats.set_sizes;
            let avg = s.iter().map(|&x| x as f64).sum::<f64>() / s.len() as f64;
            cells.push(format!("{avg:.1}"));
        }
        table.row(cells);
    }
    table.print();
    println!(
        "\npaper (full scale, maximal sets): nd24k 2.2/9.0/10.9, \
         Flan 42.0/448.5/678.1, nlpkkt240 57.5/4084.5/6695.8"
    );
}
