//! Persistent ParAMD worker pool with an internal job queue.
//!
//! `ParAmd::order()` used to spawn `t` fresh OS threads per call; on a
//! service handling repeated requests, thread spawn/join dominated
//! request latency. An [`OrderingRuntime`] spawns its workers **once**
//! and parks them on a condvar between jobs:
//!
//! - [`OrderingRuntime::run_weighted`] enqueues a borrowed `Fn(usize)`
//!   onto the pool's **internal job queue** and blocks until that job
//!   (not the whole queue) completes — so the borrow can't outlive the
//!   call even though workers hold a lifetime-erased pointer while
//!   running. Concurrent submitters therefore never contend on a
//!   submission mutex: each enqueues, the pool runs one job at a time,
//!   and each submitter wakes when *its* job's status flips to done.
//! - The queue is FIFO by default; [`QueuePolicy::SmallestFirst`] pops
//!   the lightest queued job instead (weight = vertex count for ordering
//!   jobs), letting a service drain cheap requests ahead of a monster
//!   graph that arrived first.
//! - Inside a job, workers synchronize on the runtime's **reusable**
//!   [`Barrier`] (every worker passes each round barrier the same number
//!   of times, so the barrier is reusable across jobs too).
//!
//! A worker that panics mid-job is counted and the panic re-raised from
//! the submitting `run*` call once the job drains. (A panic *between*
//! the algorithm's round barriers can still strand peers at the barrier
//! — the same failure mode the old scoped-spawn driver had — which is
//! why the driver converts stalls into a poison flag instead of
//! panicking.)

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Barrier, Condvar, Mutex};
use std::thread::JoinHandle;

/// A lifetime-erased borrow of a `run` job. Only alive between job
/// publication and the last worker's completion, both inside `run`.
#[derive(Clone, Copy)]
struct Job(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared calls are fine) and `run` keeps
// the underlying borrow alive until every worker is done with it.
unsafe impl Send for Job {}

/// How the pool picks the next queued job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueuePolicy {
    /// Strict submission order.
    Fifo,
    /// Lightest queued job first (ties broken by submission order), so a
    /// burst of small requests is not stuck behind one huge graph.
    SmallestFirst,
}

/// Completion flag of one queued job, shared between its submitter and
/// the last worker to finish it.
#[derive(Default)]
struct JobStatus {
    state: Mutex<JobState>,
    cv: Condvar,
}

#[derive(Default)]
struct JobState {
    done: bool,
    panicked: usize,
}

struct QueuedJob {
    job: Job,
    /// Scheduling weight (vertex count for ordering jobs; 0 = unknown).
    weight: usize,
    /// Submission order, the FIFO key and the SmallestFirst tie-break.
    seq: u64,
    status: Arc<JobStatus>,
}

struct PoolState {
    /// Job generation; bumped once per started job.
    epoch: u64,
    /// The active job, if any (present from start until the last worker
    /// finishes it).
    job: Option<Job>,
    active_status: Option<Arc<JobStatus>>,
    /// Workers still running the active job.
    remaining: usize,
    /// Workers whose active-job closure panicked.
    panicked: usize,
    /// Jobs waiting for the pool.
    queue: VecDeque<QueuedJob>,
    /// How the next queued job is picked (only read under this lock).
    policy: QueuePolicy,
    next_seq: u64,
    shutdown: bool,
}

struct PoolShared {
    threads: usize,
    /// Round barrier reused by every job (and across jobs).
    barrier: Barrier,
    state: Mutex<PoolState>,
    go: Condvar,
}

impl PoolShared {
    /// Promote the next queued job to active. Caller holds the state
    /// lock; returns whether a job was started (the caller must then
    /// notify `go`).
    fn start_next_locked(&self, st: &mut PoolState) -> bool {
        if st.remaining != 0 || st.job.is_some() || st.queue.is_empty() {
            return false;
        }
        let idx = match st.policy {
            QueuePolicy::Fifo => 0,
            QueuePolicy::SmallestFirst => st
                .queue
                .iter()
                .enumerate()
                .min_by_key(|(_, q)| (q.weight, q.seq))
                .map(|(i, _)| i)
                .expect("non-empty queue"),
        };
        let q = st.queue.remove(idx).expect("index in bounds");
        st.job = Some(q.job);
        st.active_status = Some(q.status);
        st.epoch += 1;
        st.remaining = self.threads;
        st.panicked = 0;
        true
    }
}

/// A persistent, reusable pool of ParAMD worker threads. Construct once,
/// run many orderings; drop to join the workers.
pub struct OrderingRuntime {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

impl OrderingRuntime {
    /// Spawn a pool of `threads` parked workers (at least one) with a
    /// FIFO job queue.
    pub fn new(threads: usize) -> Self {
        Self::new_with_policy(threads, QueuePolicy::Fifo)
    }

    /// Spawn a pool with an explicit queue policy.
    pub fn new_with_policy(threads: usize, policy: QueuePolicy) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            threads,
            barrier: Barrier::new(threads),
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                active_status: None,
                remaining: 0,
                panicked: 0,
                queue: VecDeque::new(),
                policy,
                next_seq: 0,
                shutdown: false,
            }),
            go: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|tid| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("paramd-{tid}"))
                    .spawn(move || worker_loop(tid, &sh))
                    .expect("spawn paramd worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Pool size; the effective ParAMD thread count for jobs run here.
    pub fn threads(&self) -> usize {
        self.shared.threads
    }

    /// The pool's reusable round barrier (sized to [`Self::threads`]).
    pub fn barrier(&self) -> &Barrier {
        &self.shared.barrier
    }

    /// The active queue policy.
    pub fn policy(&self) -> QueuePolicy {
        self.shared.state.lock().unwrap().policy
    }

    /// Switch the queue policy (applies to the next pop; already-queued
    /// jobs are re-ranked, not reordered in place).
    pub fn set_policy(&self, policy: QueuePolicy) {
        self.shared.state.lock().unwrap().policy = policy;
    }

    /// Number of jobs waiting in the queue (excludes the active job).
    pub fn queued_jobs(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// Whether a job is currently running on the workers.
    pub fn has_active_job(&self) -> bool {
        self.shared.state.lock().unwrap().job.is_some()
    }

    /// Run `job(tid)` on every worker and wait for it ([`Self::run_weighted`]
    /// with weight 0).
    pub fn run(&self, job: &(dyn Fn(usize) + Sync)) {
        self.run_weighted(0, job);
    }

    /// Enqueue `job` with a scheduling `weight` and block until the pool
    /// has run it on every worker. Concurrent submitters don't serialize
    /// on a lock: each waits only for its own job's completion, and the
    /// queue decides who runs next ([`QueuePolicy`]).
    ///
    /// If any worker's job closure panicked, the panic is re-raised here
    /// — after the job fully drained, so the pool stays usable for the
    /// next request (the workers themselves survived via `catch_unwind`).
    pub fn run_weighted(&self, weight: usize, job: &(dyn Fn(usize) + Sync)) {
        // SAFETY: we erase the borrow's lifetime to park it in the shared
        // queue, but do not return from this call until the job's status
        // flips to done, i.e. until no worker can touch it anymore.
        let erased = Job(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(job)
        });
        let status = Arc::new(JobStatus::default());
        let started = {
            let mut st = self.shared.state.lock().unwrap();
            // No workers remain after a shutdown; enqueueing would hang
            // the submitter forever, so fail loudly instead.
            assert!(!st.shutdown, "job submitted to a shut-down OrderingRuntime");
            let seq = st.next_seq;
            st.next_seq += 1;
            st.queue.push_back(QueuedJob {
                job: erased,
                weight,
                seq,
                status: Arc::clone(&status),
            });
            self.shared.start_next_locked(&mut st)
        };
        if started {
            self.shared.go.notify_all();
        }
        let panicked = {
            let mut s = status.state.lock().unwrap();
            while !s.done {
                s = status.cv.wait(s).unwrap();
            }
            s.panicked
        };
        assert!(
            panicked == 0,
            "{panicked} ParAMD worker(s) panicked during an ordering job"
        );
    }

    /// Stop accepting work, wake every parked worker, and join them.
    /// Queued jobs cannot exist here: `run*` callers hold `&self` borrows
    /// and block until their job drains, so by the time an exclusive
    /// borrow reaches this method the queue is empty. Idempotent — the
    /// second call finds no workers left to join.
    pub fn shutdown_join(&mut self) {
        {
            // Poison-tolerant: this also runs from Drop during unwinds
            // (e.g. after the submit-after-shutdown assertion fired).
            let mut st = self
                .shared
                .state
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            debug_assert!(st.queue.is_empty(), "shutdown with queued jobs");
            st.shutdown = true;
        }
        self.shared.go.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for OrderingRuntime {
    fn drop(&mut self) {
        self.shutdown_join();
    }
}

fn worker_loop(tid: usize, sh: &PoolShared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch > seen {
                    if let Some(job) = st.job {
                        seen = st.epoch;
                        break job;
                    }
                }
                st = sh.go.wait(st).unwrap();
            }
        };
        // SAFETY: the submitter blocks in `run_weighted` until this job's
        // status flips to done, keeping the borrow alive.
        let f: &(dyn Fn(usize) + Sync) = unsafe { &*job.0 };
        let ok = catch_unwind(AssertUnwindSafe(|| f(tid))).is_ok();
        let mut st = sh.state.lock().unwrap();
        if !ok {
            st.panicked += 1;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            // Last worker out: retire the job, wake its submitter, and
            // promote the next queued job (if any).
            st.job = None;
            let status = st.active_status.take().expect("active job has a status");
            let panicked = st.panicked;
            let started = sh.start_next_locked(&mut st);
            drop(st);
            {
                let mut s = status.state.lock().unwrap();
                s.done = true;
                s.panicked = panicked;
            }
            status.cv.notify_all();
            if started {
                sh.go.notify_all();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering::Relaxed};

    #[test]
    fn runs_jobs_on_all_workers_and_reuses_them() {
        let rt = OrderingRuntime::new(4);
        assert_eq!(rt.threads(), 4);
        let hits = AtomicUsize::new(0);
        for _ in 0..5 {
            rt.run(&|_tid| {
                hits.fetch_add(1, Relaxed);
            });
        }
        assert_eq!(hits.load(Relaxed), 20);
    }

    #[test]
    fn tids_cover_the_pool() {
        let rt = OrderingRuntime::new(3);
        let seen: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        rt.run(&|tid| {
            seen[tid].fetch_add(1, Relaxed);
        });
        for s in &seen {
            assert_eq!(s.load(Relaxed), 1);
        }
    }

    #[test]
    fn barrier_is_usable_inside_jobs_across_jobs() {
        let rt = OrderingRuntime::new(4);
        let counter = AtomicUsize::new(0);
        for round in 1..=3usize {
            rt.run(&|_tid| {
                counter.fetch_add(1, Relaxed);
                rt.barrier().wait();
                // After the barrier every worker must see all increments.
                assert_eq!(counter.load(Relaxed), 4 * round);
                rt.barrier().wait();
            });
        }
    }

    #[test]
    fn drop_joins_workers() {
        let rt = OrderingRuntime::new(2);
        rt.run(&|_| {});
        drop(rt); // must not hang
    }

    #[test]
    fn concurrent_submitters_all_complete() {
        let rt = OrderingRuntime::new(2);
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let rt = &rt;
                let total = &total;
                s.spawn(move || {
                    rt.run(&|_tid| {
                        total.fetch_add(1, Relaxed);
                    });
                });
            }
        });
        assert_eq!(total.load(Relaxed), 8);
    }

    /// Occupy the pool with a holdable job, queue three weighted jobs,
    /// then release and observe the execution order.
    fn queued_execution_order(policy: QueuePolicy, weights: [usize; 3]) -> Vec<usize> {
        let rt = OrderingRuntime::new_with_policy(1, policy);
        let release = AtomicBool::new(false);
        let order = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            let rt = &rt;
            let release = &release;
            let order = &order;
            s.spawn(move || {
                rt.run(&|_| {
                    while !release.load(Relaxed) {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                });
            });
            // Wait until the blocker is the active job (not merely queued).
            while !(rt.has_active_job() && rt.queued_jobs() == 0) {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            for &w in &weights {
                s.spawn(move || {
                    rt.run_weighted(w, &|_| {
                        order.lock().unwrap().push(w);
                    });
                });
            }
            while rt.queued_jobs() < 3 {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            release.store(true, Relaxed);
        });
        order.into_inner().unwrap()
    }

    #[test]
    fn smallest_first_policy_pops_light_jobs_first() {
        assert_eq!(
            queued_execution_order(QueuePolicy::SmallestFirst, [3, 1, 2]),
            vec![1, 2, 3]
        );
    }

    #[test]
    fn fifo_policy_preserves_submission_order() {
        // Submitter threads race to enqueue, so only the *set* is fixed;
        // with equal weights SmallestFirst degenerates to FIFO by seq,
        // proving the tie-break. Heavier check: all three ran exactly once.
        let mut got = queued_execution_order(QueuePolicy::Fifo, [5, 5, 5]);
        got.sort_unstable();
        assert_eq!(got, vec![5, 5, 5]);
    }

    #[test]
    fn shutdown_join_is_idempotent() {
        let mut rt = OrderingRuntime::new(2);
        rt.run(&|_| {});
        rt.shutdown_join();
        rt.shutdown_join(); // second call must be a no-op
    }

    #[test]
    #[should_panic(expected = "shut-down OrderingRuntime")]
    fn submit_after_shutdown_fails_loudly() {
        let mut rt = OrderingRuntime::new(1);
        rt.shutdown_join();
        rt.run(&|_| {}); // must panic, not hang on a workerless queue
    }
}
