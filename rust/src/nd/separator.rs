//! Vertex separator extraction from an edge cut: greedy minimal cover of
//! the cut edges, preferring the vertex covering more cut edges (the
//! standard METIS-style boundary-to-separator conversion).

use crate::graph::csr::SymGraph;

/// Given a 0/1 bisection, return `(left, right, separator)` vertex lists:
/// removing `separator` disconnects `left` from `right`.
pub fn vertex_separator(g: &SymGraph, parts: &[u8]) -> (Vec<i32>, Vec<i32>, Vec<i32>) {
    let n = g.n;
    // Count, per boundary vertex, how many cut edges it touches.
    let mut cut_deg = vec![0u32; n];
    for v in 0..n {
        for &u in g.neighbors(v) {
            if parts[v] != parts[u as usize] {
                cut_deg[v] += 1;
            }
        }
    }
    let mut in_sep = vec![false; n];
    // Greedy cover: repeatedly take the endpoint of an uncovered cut edge
    // with the larger cut degree. Process edges in a fixed order for
    // determinism.
    for v in 0..n {
        for &uu in g.neighbors(v) {
            let u = uu as usize;
            if u < v || parts[v] == parts[u] || in_sep[v] || in_sep[u] {
                continue;
            }
            let pick = if cut_deg[v] >= cut_deg[u] { v } else { u };
            in_sep[pick] = true;
        }
    }
    let mut left = Vec::new();
    let mut right = Vec::new();
    let mut sep = Vec::new();
    for v in 0..n {
        if in_sep[v] {
            sep.push(v as i32);
        } else if parts[v] == 0 {
            left.push(v as i32);
        } else {
            right.push(v as i32);
        }
    }
    (left, right, sep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::mesh2d;
    use crate::nd::bisect::multilevel_bisect;
    use crate::nd::NestedDissection;

    fn assert_separates(g: &SymGraph, left: &[i32], right: &[i32], sep: &[i32]) {
        let mut side = vec![-1i8; g.n];
        for &v in left {
            side[v as usize] = 0;
        }
        for &v in right {
            side[v as usize] = 1;
        }
        for &v in sep {
            side[v as usize] = 2;
        }
        assert!(side.iter().all(|&s| s != -1), "partition incomplete");
        for v in 0..g.n {
            if side[v] == 2 {
                continue;
            }
            for &u in g.neighbors(v) {
                let su = side[u as usize];
                assert!(
                    su == 2 || su == side[v],
                    "edge ({v},{u}) crosses the separator"
                );
            }
        }
    }

    #[test]
    fn separator_disconnects_mesh() {
        let g = mesh2d(14, 14);
        let parts = multilevel_bisect(&g, &NestedDissection::default());
        let (l, r, s) = vertex_separator(&g, &parts);
        assert_eq!(l.len() + r.len() + s.len(), g.n);
        assert!(!l.is_empty() && !r.is_empty());
        assert!(!s.is_empty());
        assert_separates(&g, &l, &r, &s);
        // Separator of a k×k mesh should be O(k).
        assert!(s.len() <= 4 * 14, "separator too large: {}", s.len());
    }

    #[test]
    fn path_graph_separator_is_single_vertex() {
        let n = 21;
        let edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
        let g = SymGraph::from_edges(n, &edges);
        // Hand-made balanced bisection at the midpoint.
        let parts: Vec<u8> = (0..n).map(|v| u8::from(v > n / 2)).collect();
        let (l, r, s) = vertex_separator(&g, &parts);
        assert_eq!(s.len(), 1);
        assert_separates(&g, &l, &r, &s);
    }

    #[test]
    fn no_cut_edges_gives_empty_separator() {
        let g = SymGraph::from_edges(4, &[(0, 1), (2, 3)]);
        let parts = vec![0u8, 0, 1, 1];
        let (l, r, s) = vertex_separator(&g, &parts);
        assert!(s.is_empty());
        assert_eq!(l, vec![0, 1]);
        assert_eq!(r, vec![2, 3]);
    }
}
