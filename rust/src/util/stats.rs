//! Descriptive statistics for benchmark reporting: mean ± std (Table 4.2),
//! percentiles and histogram bins (the Figure 4.2 violin plots).

/// Mean of a sample (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 for fewer than 2 points).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Linear-interpolated percentile, `q` in [0, 100]. Sorts a copy.
///
/// Total-order safe: `0.0` for an empty slice (never panics), `q` is
/// clamped to [0, 100], and NaN samples sort to the top via `total_cmp`
/// instead of panicking the comparator.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let pos = (q / 100.0).clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Number of buckets of a [`LogHistogram`] (fixed: the whole struct is
/// inline, no heap).
pub const LOG_HIST_BUCKETS: usize = 128;

/// Smallest distinguishable value of a [`LogHistogram`]; everything at or
/// below it (and every non-finite sample) lands in bucket 0.
const LOG_HIST_MIN: f64 = 1e-9;

/// Largest bucket edge; ~`1e9` with 128 buckets. Values beyond it clamp
/// into the last bucket.
const LOG_HIST_SPAN: f64 = 1e18;

/// A fixed-footprint, mergeable, log-bucketed histogram for latency-style
/// positive samples.
///
/// `LOG_HIST_BUCKETS` buckets span `[1e-9, 1e9]` seconds with geometric
/// width (~38% per bucket), so memory is **constant in the sample count**
/// — the replacement for unbounded `Vec<f64>` latency logs. The exact
/// `sum`/`count`/`min`/`max` ride along, so [`Self::mean`] is exact and
/// only the interior of [`Self::quantile`] is approximate (to within one
/// bucket's relative width).
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: [u64; LOG_HIST_BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self {
            counts: [0; LOG_HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl LogHistogram {
    /// Bucket index of `v` (clamped; non-finite and non-positive samples
    /// land in bucket 0).
    fn bucket(v: f64) -> usize {
        if !v.is_finite() || v <= LOG_HIST_MIN {
            return 0;
        }
        let ln_growth = LOG_HIST_SPAN.ln() / LOG_HIST_BUCKETS as f64;
        let b = ((v / LOG_HIST_MIN).ln() / ln_growth) as usize;
        b.min(LOG_HIST_BUCKETS - 1)
    }

    /// Low edge of bucket `b`.
    fn edge(b: usize) -> f64 {
        let ln_growth = LOG_HIST_SPAN.ln() / LOG_HIST_BUCKETS as f64;
        LOG_HIST_MIN * (ln_growth * b as f64).exp()
    }

    /// Record one sample. O(1), allocation-free.
    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() { v } else { 0.0 };
        self.counts[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold `other` into `self` (both keep constant footprint).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean (0 when empty) — sum and count are carried exactly, so
    /// this does not suffer bucket quantization.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile, `q` in [0, 1]: cumulative walk over the
    /// buckets with linear interpolation inside the target bucket,
    /// clamped to the exact observed `[min, max]`. 0 when empty; accurate
    /// to within one bucket's geometric width (~38%) in the interior and
    /// exact at q=0 / q=1.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = q * (self.count - 1) as f64;
        let mut below = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if (below + c) as f64 > rank {
                // Interpolate within bucket b by rank position.
                let frac = ((rank - below as f64) / c as f64).clamp(0.0, 1.0);
                let lo = Self::edge(b);
                let hi = Self::edge(b + 1);
                return (lo + frac * (hi - lo)).clamp(self.min, self.max);
            }
            below += c;
        }
        self.max
    }
}

/// Five-number summary + mean, the series a violin/box plot needs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub max: f64,
    pub mean: f64,
    pub n: usize,
}

pub fn summary(xs: &[f64]) -> Summary {
    Summary {
        min: percentile(xs, 0.0),
        p25: percentile(xs, 25.0),
        median: percentile(xs, 50.0),
        p75: percentile(xs, 75.0),
        max: percentile(xs, 100.0),
        mean: mean(xs),
        n: xs.len(),
    }
}

/// Histogram over `bins` equal-width buckets spanning `[min, max]` of the
/// data; returns `(bucket_low_edges, counts)`. Used to print violin-plot
/// density series as text.
pub fn histogram(xs: &[f64], bins: usize) -> (Vec<f64>, Vec<usize>) {
    assert!(bins > 0);
    if xs.is_empty() {
        return (vec![0.0; bins], vec![0; bins]);
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let width = if hi > lo { (hi - lo) / bins as f64 } else { 1.0 };
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let mut b = ((x - lo) / width) as usize;
        if b >= bins {
            b = bins - 1;
        }
        counts[b] += 1;
    }
    let edges = (0..bins).map(|i| lo + i as f64 * width).collect();
    (edges, counts)
}

/// Fraction of samples strictly below `threshold` (the paper quotes the
/// share of distance-2 sets with size < 64 in §4.4).
pub fn frac_below(xs: &[f64], threshold: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&x| x < threshold).count() as f64 / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(std_dev(&[3.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_ordered() {
        let xs = [5.0, 1.0, 9.0, 3.0, 7.0];
        let s = summary(&xs);
        assert!(s.min <= s.p25 && s.p25 <= s.median);
        assert!(s.median <= s.p75 && s.p75 <= s.max);
        assert_eq!(s.n, 5);
        assert_eq!(s.median, 5.0);
    }

    #[test]
    fn histogram_counts_all() {
        let xs = [0.0, 0.1, 0.5, 0.9, 1.0];
        let (_, counts) = histogram(&xs, 4);
        assert_eq!(counts.iter().sum::<usize>(), xs.len());
    }

    #[test]
    fn frac_below_works() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((frac_below(&xs, 3.0) - 0.5).abs() < 1e-12);
        assert_eq!(frac_below(&[], 3.0), 0.0);
    }

    #[test]
    fn percentile_tiny_inputs_and_extremes() {
        // 0-element: safe zero, any q.
        assert_eq!(percentile(&[], 0.0), 0.0);
        assert_eq!(percentile(&[], 100.0), 0.0);
        // 1-element: every q is that element.
        assert_eq!(percentile(&[7.5], 0.0), 7.5);
        assert_eq!(percentile(&[7.5], 50.0), 7.5);
        assert_eq!(percentile(&[7.5], 100.0), 7.5);
        // 2-element: p0/p100 are the ends, p50 interpolates halfway.
        assert_eq!(percentile(&[2.0, 10.0], 0.0), 2.0);
        assert_eq!(percentile(&[2.0, 10.0], 100.0), 10.0);
        assert!((percentile(&[2.0, 10.0], 50.0) - 6.0).abs() < 1e-12);
        // Out-of-range q clamps instead of indexing out of bounds.
        assert_eq!(percentile(&[1.0, 2.0], -5.0), 1.0);
        assert_eq!(percentile(&[1.0, 2.0], 250.0), 2.0);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // total_cmp sorts NaN to the top instead of panicking.
        let xs = [3.0, f64::NAN, 1.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
    }

    #[test]
    fn log_histogram_mean_is_exact() {
        let mut h = LogHistogram::default();
        for v in [0.5, 1.0, 1.5] {
            h.record(v);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean() - 1.0).abs() < 1e-12, "mean carries exact sum");
        assert!((h.sum() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn log_histogram_quantiles_within_one_bucket_of_exact() {
        // 10k synthetic samples over 5 decades; the histogram quantile
        // must stay within one geometric bucket (~38% relative) of the
        // exact percentile.
        let mut h = LogHistogram::default();
        let mut xs = Vec::new();
        let mut state = 0x9E3779B97F4A7C15u64;
        for _ in 0..10_000 {
            state = crate::util::rng::splitmix64(state);
            // Log-uniform in [1e-4, 10).
            let u = (state >> 11) as f64 / (1u64 << 53) as f64;
            let v = 1e-4 * 10f64.powf(5.0 * u);
            xs.push(v);
            h.record(v);
        }
        let bucket_ratio = (LOG_HIST_SPAN.ln() / LOG_HIST_BUCKETS as f64).exp();
        for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99] {
            let exact = percentile(&xs, q * 100.0);
            let approx = h.quantile(q);
            let ratio = approx / exact;
            assert!(
                ratio < bucket_ratio * 1.01 && ratio > 1.0 / (bucket_ratio * 1.01),
                "q={q}: approx {approx:.6} vs exact {exact:.6} (ratio {ratio:.3})"
            );
        }
        // Extremes are exact (clamped to observed min/max).
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(h.quantile(0.0), lo);
        assert_eq!(h.quantile(1.0), hi);
    }

    #[test]
    fn log_histogram_merge_equals_combined_recording() {
        let mut a = LogHistogram::default();
        let mut b = LogHistogram::default();
        let mut all = LogHistogram::default();
        for i in 0..500 {
            let v = 1e-3 * (1.0 + i as f64);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.sum() - all.sum()).abs() < 1e-9);
        for q in [0.0, 0.25, 0.5, 0.9, 1.0] {
            assert_eq!(a.quantile(q), all.quantile(q), "merge must be lossless");
        }
    }

    #[test]
    fn log_histogram_handles_degenerate_samples() {
        let mut h = LogHistogram::default();
        h.record(0.0);
        h.record(-1.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(1e30); // beyond the last edge: clamps, never panics
        assert_eq!(h.count(), 5);
        assert!(h.quantile(0.5).is_finite());
        let empty = LogHistogram::default();
        assert_eq!(empty.quantile(0.5), 0.0);
        assert_eq!(empty.mean(), 0.0);
    }
}
