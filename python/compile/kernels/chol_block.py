"""Layer-1 Pallas kernel: blocked dense Cholesky factorization.

The paper's end-to-end experiments run a GPU direct solver (cuDSS); our
substrate factors the dense trailing Schur complement of the sparse
factorization with this kernel (DESIGN.md §3, hardware adaptation).

TPU mapping (instead of a mechanical CUDA port):

- the whole tile lives in VMEM (a 256×256 f32 tile is 256 KiB — far under
  the ~16 MiB VMEM budget, leaving room for double buffering);
- the inner loop is organised around `bs×bs` blocks so the `trsm` panel
  solve and the rank-`bs` trailing update are MXU-shaped matmuls
  (`jax.lax.linalg.triangular_solve` / `@`), not scalar WMMA-style code;
- the block step uses full-height masked panels: dynamic shapes are not
  expressible in XLA, so each step does a fixed-shape (n×bs) solve and a
  masked (n×n) update. This wastes ≤3× FLOPs versus a perfectly shrinking
  trailing matrix but keeps every op a dense MXU matmul.

`interpret=True` is mandatory: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute; interpret mode lowers to
plain HLO ops with identical numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 32


def _inv_lower(l: jax.Array) -> jax.Array:
    """Explicit inverse of a small lower-triangular block by forward
    substitution (row-recurrence with vectorized matmuls).

    `jax.lax.linalg.triangular_solve` is avoided on purpose: its CPU
    lowering is a LAPACK typed-FFI custom-call that the xla_extension
    0.5.1 backing the Rust `xla` crate cannot parse; this formulation
    lowers to plain HLO ops (and is MXU-matmul-shaped on TPU).
    """
    n = l.shape[0]
    eye = jnp.eye(n, dtype=l.dtype)

    def step(i, y):
        row = (eye[i] - l[i] @ y) / l[i, i]
        return y.at[i].set(row)

    return jax.lax.fori_loop(0, n, step, jnp.zeros_like(l))


def _unblocked_cholesky(a: jax.Array) -> jax.Array:
    """Column-by-column Cholesky of a small (bs×bs) SPD block.

    Runs inside the kernel for the diagonal block; O(bs) sequential steps
    of vectorized column updates.
    """
    n = a.shape[0]
    idx = jnp.arange(n)

    def step(k, a):
        lkk = jnp.sqrt(a[k, k])
        col = jnp.where(idx > k, a[:, k] / lkk, 0.0)
        col = col.at[k].set(lkk)
        mask = (idx[:, None] > k) & (idx[None, :] > k)
        a = a - jnp.where(mask, jnp.outer(col, col), 0.0)
        a = a.at[:, k].set(col)
        return a

    a = jax.lax.fori_loop(0, n, step, a)
    return jnp.tril(a)


def _cholesky_kernel(a_ref, o_ref, *, bs: int):
    """Right-looking blocked Cholesky over the VMEM-resident tile."""
    a = a_ref[...]
    n = a.shape[0]
    nb = n // bs
    idx = jnp.arange(n)

    def block_step(b, a):
        off = b * bs
        # potrf: factor the bs×bs diagonal block.
        dblk = jax.lax.dynamic_slice(a, (off, off), (bs, bs))
        ld = _unblocked_cholesky(dblk)
        # trsm: full-height panel solve  P · ld^{-T}  (MXU matmul shape).
        pan = jax.lax.dynamic_slice(a, (0, off), (n, bs))
        sol = pan @ _inv_lower(ld).T
        below = idx[:, None] >= off + bs
        lpan = jnp.where(below, sol, 0.0)
        # Assemble the full block column of L: ld in the block rows, the
        # solved panel below, zeros above.
        ldfull = jax.lax.dynamic_update_slice(jnp.zeros((n, bs), a.dtype), ld, (off, 0))
        col_l = ldfull + lpan
        a = jax.lax.dynamic_update_slice(a, col_l, (0, off))

        # syrk: per-block-column trailing update. A full masked n×n update
        # would issue 3× the useful FLOPs (see EXPERIMENTS.md §Perf change
        # #4); instead each remaining block column jb gets an
        # (n×bs)·(bs×bs) matmul. Rows above the diagonal of later columns
        # receive garbage, but every later read (pan/dblk) masks or avoids
        # that region, and the final tril() discards it.
        def col_update(jb, a):
            joff = jb * bs
            colj = jax.lax.dynamic_slice(col_l, (joff, 0), (bs, bs))
            upd = col_l @ colj.T # (n, bs)
            blk = jax.lax.dynamic_slice(a, (0, joff), (n, bs))
            return jax.lax.dynamic_update_slice(a, blk - upd, (0, joff))

        a = jax.lax.fori_loop(b + 1, nb, col_update, a)
        return a

    a = jax.lax.fori_loop(0, nb, block_step, a)
    o_ref[...] = jnp.tril(a)


@functools.partial(jax.jit, static_argnames=("bs",))
def blocked_cholesky(a: jax.Array, bs: int = DEFAULT_BLOCK) -> jax.Array:
    """Factor a dense SPD matrix `a` (n×n, n a multiple of `bs`) into its
    lower Cholesky factor via the Pallas kernel.

    Not positive definite ⇒ NaNs in the output (checked by the caller;
    the Rust runtime converts NaN to an error).
    """
    n = a.shape[0]
    if n % bs != 0:
        raise ValueError(f"size {n} not a multiple of block {bs}")
    return pl.pallas_call(
        functools.partial(_cholesky_kernel, bs=bs),
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        interpret=True,  # CPU-PJRT execution path; see module docstring
    )(a)


def vmem_footprint_bytes(n: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of the kernel for an n×n tile: the tile,
    one block column, and the update product (double-buffered input)."""
    tile = n * n * dtype_bytes
    col = n * DEFAULT_BLOCK * dtype_bytes
    return 2 * tile + 2 * col


def mxu_utilization_estimate(n: int, bs: int = DEFAULT_BLOCK) -> float:
    """Fraction of issued MXU FLOPs that are mathematically useful.

    Per block step: inv_lower (bs³) + full-height trsm (n·bs²) + one
    (n×bs)·(bs×bs) matmul per remaining block column. Useful Cholesky
    work is n³/3. TPU-side utilization is this ratio times the MXU
    efficiency of the constituent matmuls.
    """
    nb = n // bs
    issued = nb * (bs**3 + n * bs * bs) + nb * (nb - 1) // 2 * (n * bs * bs)
    useful = n**3 / 3
    return useful / issued
