//! Triangular solves over the CSC factor.

use super::numeric::CscFactor;

/// In-place forward solve `L y = b` (columns store diagonal first).
pub fn lower_solve(l: &CscFactor, x: &mut [f64]) {
    assert_eq!(x.len(), l.n);
    for j in 0..l.n {
        let pd = l.lp[j];
        x[j] /= l.lx[pd];
        let xj = x[j];
        for p in pd + 1..l.lp[j + 1] {
            x[l.li[p] as usize] -= l.lx[p] * xj;
        }
    }
}

/// In-place backward solve `Lᵀ y = b`.
pub fn upper_solve(l: &CscFactor, x: &mut [f64]) {
    assert_eq!(x.len(), l.n);
    for j in (0..l.n).rev() {
        let pd = l.lp[j];
        let mut s = x[j];
        for p in pd + 1..l.lp[j + 1] {
            s -= l.lx[p] * x[l.li[p] as usize];
        }
        x[j] = s / l.lx[pd];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 2x2 lower factor [[2,0],[1,3]] in CSC.
    fn small_l() -> CscFactor {
        CscFactor {
            n: 2,
            lp: vec![0, 2, 3],
            li: vec![0, 1, 1],
            lx: vec![2.0, 1.0, 3.0],
        }
    }

    #[test]
    fn forward_solve_known() {
        let l = small_l();
        let mut x = vec![4.0, 7.0]; // L y = b => y = [2, 5/3]
        lower_solve(&l, &mut x);
        assert!((x[0] - 2.0).abs() < 1e-14);
        assert!((x[1] - 5.0 / 3.0).abs() < 1e-14);
    }

    #[test]
    fn backward_solve_known() {
        let l = small_l();
        // L^T x = b with b = [2, 3]: x[1] = 1, x[0] = (2 - 1*1)/2 = 0.5
        let mut x = vec![2.0, 3.0];
        upper_solve(&l, &mut x);
        assert!((x[1] - 1.0).abs() < 1e-14);
        assert!((x[0] - 0.5).abs() < 1e-14);
    }

    #[test]
    fn forward_then_backward_is_llt_solve() {
        // A = L L^T = [[4,2],[2,10]]; b = A·[1,1] = [6,12]
        let l = small_l();
        let mut x = vec![6.0, 12.0];
        lower_solve(&l, &mut x);
        upper_solve(&l, &mut x);
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 1.0).abs() < 1e-12);
    }
}
