//! 128-bit structural fingerprints of CSR graphs.
//!
//! The ordering result cache ([`crate::ordering::cache`]) needs a cheap,
//! deterministic identity for "the same graph came back": batched FEM
//! assembly traffic re-submits structurally identical components request
//! after request, and Fahrbach et al. (*On Computing Min-Degree
//! Elimination Orderings*) show hash-based sketching is the right
//! primitive for recognizing repeated minimum-degree structure without
//! comparing it. A [`Fingerprint`] is two **independent**
//! [`splitmix64`]-mixed passes over the same structural stream —
//! `(n, row lengths, edges)` of the CSR arrays — giving 128 bits, so an
//! accidental collision across both halves is negligible even at
//! millions-of-requests scale. The cache still verifies candidates with
//! an exact CSR compare (hashes nominate, bytes decide), so a collision
//! can cost a recompute but never a wrong permutation.
//!
//! The fingerprint is **label-sensitive** by design: it hashes the
//! compact CSR exactly as the ordering kernel will consume it. Requests
//! with scattered vertex ids still fingerprint equal at *component*
//! granularity because [`crate::graph::components::split_components`]
//! assigns local ids deterministically (increasing original-vertex
//! order), producing identical compact CSRs for identical components —
//! which is precisely where the cache probes.

use crate::graph::csr::SymGraph;
use crate::util::rng::splitmix64;

/// Domain-separation seeds of the two independent passes.
const PASS_HI: u64 = 0xF1C2_85E7_0DD5_11A0;
const PASS_LO: u64 = 0x93B1_4A6C_26F0_83D7;

/// A 128-bit structural graph fingerprint (two independent 64-bit
/// passes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fingerprint {
    pub hi: u64,
    pub lo: u64,
}

impl Fingerprint {
    /// The fingerprint as one 128-bit word (reports, debugging).
    pub fn as_u128(&self) -> u128 {
        ((self.hi as u128) << 64) | self.lo as u128
    }
}

/// One chained pass over `(n, row lengths, edges)`. Sequential (not
/// commutative) mixing: CSR rows are ordered and sorted, so position is
/// part of the structure being identified.
fn pass(g: &SymGraph, seed: u64) -> u64 {
    let mut h = splitmix64(seed ^ splitmix64(g.n as u64));
    for v in 0..g.n {
        h = splitmix64(h ^ g.degree(v) as u64);
    }
    for &u in &g.colind {
        h = splitmix64(h ^ u as u64);
    }
    h
}

/// Fingerprint `g`'s structure. Deterministic, platform-independent,
/// O(n + nnz) with two word-mixes per element.
pub fn fingerprint(g: &SymGraph) -> Fingerprint {
    Fingerprint {
        hi: pass(g, PASS_HI),
        lo: pass(g, PASS_LO),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{mesh2d, random_graph};

    #[test]
    fn identical_graphs_fingerprint_equal() {
        let a = mesh2d(9, 7);
        let b = mesh2d(9, 7);
        assert_eq!(fingerprint(&a), fingerprint(&b));
    }

    #[test]
    fn different_graphs_fingerprint_differently() {
        let mut seen = std::collections::HashSet::new();
        for seed in 0..8u64 {
            let g = random_graph(200, 5, seed);
            assert!(seen.insert(fingerprint(&g)), "collision at seed {seed}");
        }
        // Structure, not just size: same n/nnz class, different meshes.
        assert_ne!(fingerprint(&mesh2d(6, 8)), fingerprint(&mesh2d(8, 6)));
    }

    #[test]
    fn single_edge_change_flips_both_halves() {
        let a = SymGraph::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let b = SymGraph::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 5)]);
        let (fa, fb) = (fingerprint(&a), fingerprint(&b));
        assert_ne!(fa.hi, fb.hi, "hi pass must react to one edge");
        assert_ne!(fa.lo, fb.lo, "lo pass must react to one edge");
    }

    #[test]
    fn passes_are_independent() {
        let f = fingerprint(&mesh2d(10, 10));
        assert_ne!(f.hi, f.lo, "the two passes must not degenerate");
    }

    #[test]
    fn relabeled_graph_fingerprints_differently() {
        // Label-sensitivity is intentional: the cache keys compact CSRs.
        let g = random_graph(120, 4, 3);
        let mut rng = crate::util::rng::Rng::new(9);
        let p = rng.permutation(g.n);
        let h = crate::graph::perm::permute_graph(&g, &p);
        assert_ne!(fingerprint(&g), fingerprint(&h));
    }

    #[test]
    fn identical_components_fingerprint_equal_under_scattered_labels() {
        use crate::graph::components::{connected_components, split_components};
        // Two copies of one component shape, interleaved across the
        // vertex id space: the compact extractions must fingerprint
        // identically (extraction normalizes the scatter away).
        let g = crate::matgen::repeated_components(1, 23, 2);
        let c = connected_components(&g);
        let parts = split_components(&g, &c);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].graph, parts[1].graph, "extraction must normalize");
        assert_eq!(
            fingerprint(&parts[0].graph),
            fingerprint(&parts[1].graph),
            "identical components must share a fingerprint"
        );
    }

    #[test]
    fn empty_graph_has_a_stable_fingerprint() {
        let a = SymGraph::from_edges(0, &[]);
        let b = SymGraph::from_edges(0, &[]);
        assert_eq!(fingerprint(&a), fingerprint(&b));
        assert_ne!(fingerprint(&a), fingerprint(&SymGraph::from_edges(1, &[])));
    }
}
