//! Crash-recovery suite for the persistent result-cache tier: every
//! `persist-*` failpoint armed with `panic` loses at most the in-flight
//! batch and leaves a restartable store; checksummed-complete records
//! replay bit-identically; torn tails are truncated and counted into
//! `recovery_rejects` (never replayed); version tags and TTLs
//! invalidate at recovery; and a panicked flusher degrades to a lost
//! batch — cache miss on restart — not a cascade.
//!
//! The failpoint registry is process-global, so every test takes the
//! `serial()` gate and disarms on entry and exit, exactly like the
//! chaos suite.

use std::fs;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use paramd::coordinator::{Method, OrderError, OrderRequest, Service};
use paramd::graph::csr::SymGraph;
use paramd::graph::perm::is_valid_perm;
use paramd::matgen::mesh2d;
use paramd::ordering::cache::persist::record;
use paramd::ordering::cache::persist::{PersistConfig, PersistError, PersistTier};
use paramd::ordering::cache::{CacheKey, CachedOrdering};
use paramd::util::failpoint::{self, FailAction};

fn serial() -> std::sync::MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn req(g: SymGraph) -> OrderRequest {
    OrderRequest {
        matrix: None,
        pattern: Some(g),
        method: Method::ParAmd {
            threads: 1,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    }
}

/// A deterministic single-scheduler, single-shard service with the
/// persist tier attached at `dir` — recomputes are bit-reproducible, so
/// "replays bit-identically" is distinguishable from "recomputed
/// differently".
fn persistent_service(dir: &std::path::Path) -> Service {
    Service::new(1)
        .with_scheduler_threads(1)
        .with_shard_threads(1)
        .with_persist(dir)
        .expect("persist dir must open")
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("paramd_persist_{}_{name}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn unix_now() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map_or(0, |d| d.as_secs())
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < Duration::from_secs(10), "timeout waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// A synthetic cache entry over `g` (tier-level tests never execute the
/// permutation, so only bit-exactness matters).
fn value_for(g: &SymGraph, seed: i32) -> CachedOrdering {
    CachedOrdering {
        perm: (0..g.n as i32).map(|i| (i + seed) % g.n as i32).collect(),
        rounds: 4,
        gc_count: 1,
        gc_secs: 0.125,
        modeled_time: 0.25,
        set_sizes: vec![g.n as u32],
        reduced: 0,
    }
}

#[test]
fn warm_restart_replays_bit_identical_through_the_service() {
    let _g = serial();
    failpoint::disarm_all();
    let dir = fresh_dir("warm");
    let (g1, g2) = (mesh2d(15, 15), mesh2d(12, 18));
    let svc = persistent_service(&dir);
    let p1 = svc.order(&req(g1.clone())).perm;
    let p2 = svc.order(&req(g2.clone())).perm;
    assert!(is_valid_perm(&p1) && is_valid_perm(&p2));
    drop(svc); // drains the dirty queue and joins the flusher

    let svc2 = persistent_service(&dir);
    let pm = svc2.metrics().shards.persist.expect("tier attached");
    assert!(pm.warm_start_entries >= 2, "warm start empty: {pm:?}");
    assert!(pm.recovered_bytes > 0);
    assert_eq!(pm.recovery_rejects, 0, "clean shutdown must replay clean");
    assert_eq!(svc2.order(&req(g1.clone())).perm, p1, "g1 must replay bit-identically");
    assert_eq!(svc2.order(&req(g2.clone())).perm, p2, "g2 must replay bit-identically");
    assert!(
        svc2.metrics().cache.hits >= 2,
        "warm-started entries must answer as cache hits"
    );
    failpoint::disarm_all();
}

#[test]
fn append_and_fsync_panics_lose_at_most_the_inflight_batch() {
    let _g = serial();
    failpoint::disarm_all();
    for name in [failpoint::PERSIST_APPEND, failpoint::PERSIST_FSYNC] {
        let dir = fresh_dir(&format!("crash_{}", name.replace('-', "_")));
        let (ga, gb) = (mesh2d(14, 14), mesh2d(11, 16));
        let svc = persistent_service(&dir);
        // The first flushed batch dies mid-write: a torn tail for
        // `persist-append`, an unsynced batch for `persist-fsync`. The
        // flusher repairs the log back to the last fsynced offset.
        failpoint::arm(name, FailAction::Panic, Some(1));
        let pa = svc.order(&req(ga.clone())).perm;
        wait_until("the armed flush panic", || failpoint::fired(name) >= 1);
        let pb = svc.order(&req(gb.clone())).perm;
        let pm = svc.metrics().shards.persist.expect("tier attached");
        assert!(pm.flush_panics >= 1, "{name}: panic not contained+counted: {pm:?}");
        // Still serviceable after the contained panic.
        assert!(is_valid_perm(&svc.order(&req(ga.clone())).perm), "{name}: wedged");
        drop(svc);
        failpoint::disarm_all();

        let svc2 = persistent_service(&dir);
        let pm = svc2.metrics().shards.persist.expect("tier attached");
        assert_eq!(
            pm.recovery_rejects, 0,
            "{name}: runtime repair must leave no torn tail for recovery"
        );
        // Whatever survived replays bit-identically; whatever was lost
        // recomputes to the same answer on this deterministic config.
        assert_eq!(svc2.order(&req(ga)).perm, pa, "{name}: ga diverged after restart");
        assert_eq!(svc2.order(&req(gb)).perm, pb, "{name}: gb diverged after restart");
    }
    failpoint::disarm_all();
}

#[test]
fn aborted_recovery_degrades_to_empty_warm_start_and_the_next_open_replays() {
    let _g = serial();
    failpoint::disarm_all();
    let dir = fresh_dir("recover_panic");
    let g = mesh2d(13, 13);
    let svc = persistent_service(&dir);
    let p = svc.order(&req(g.clone())).perm;
    drop(svc);

    // A panic inside recovery is contained: the service opens with an
    // empty warm start on an untouched directory and keeps serving.
    failpoint::arm(failpoint::PERSIST_RECOVER, FailAction::Panic, Some(1));
    let degraded = persistent_service(&dir);
    assert_eq!(failpoint::fired(failpoint::PERSIST_RECOVER), 1);
    let pm = degraded.metrics().shards.persist.expect("tier attached");
    assert_eq!(pm.recovery_aborts, 1, "{pm:?}");
    assert_eq!(pm.warm_start_entries, 0);
    assert_eq!(degraded.order(&req(g.clone())).perm, p, "degraded open must still serve");
    drop(degraded);
    failpoint::disarm_all();

    // Nothing was lost: the next clean open replays everything.
    let svc3 = persistent_service(&dir);
    let pm = svc3.metrics().shards.persist.expect("tier attached");
    assert!(pm.warm_start_entries >= 1, "{pm:?}");
    assert_eq!(pm.recovery_rejects, 0);
    assert_eq!(svc3.order(&req(g)).perm, p);
    failpoint::disarm_all();
}

#[test]
fn snapshot_panic_keeps_old_state_and_the_next_compaction_succeeds() {
    let _g = serial();
    failpoint::disarm_all();
    let dir = fresh_dir("snapshot_panic");
    let cfg = PersistConfig::default();
    let (tier, recovered) = PersistTier::open(&dir, cfg).expect("open");
    assert!(recovered.is_empty());
    let g = mesh2d(9, 9);
    let keys: Vec<CacheKey> = (0..3).map(|s| CacheKey::new(&g, None, s)).collect();
    for (i, k) in keys.iter().enumerate() {
        tier.enqueue_frame(tier.encode_frame(k, &g, None, &value_for(&g, i as i32)));
    }
    tier.flush();

    // Compaction dies between writing snapshot.tmp and the publishing
    // rename: no snapshot appears, the log is untouched.
    failpoint::arm(failpoint::PERSIST_SNAPSHOT, FailAction::Panic, Some(1));
    assert!(catch_unwind(AssertUnwindSafe(|| tier.compact_now())).is_err());
    failpoint::disarm_all();
    let m = tier.metrics();
    assert_eq!(m.snapshots, 0, "{m:?}");
    assert!(!dir.join("snapshot.bin").exists(), "no half-published snapshot");
    assert!(m.log_bytes > record::FILE_HEADER_BYTES as u64, "log must be untouched");

    // The retry publishes cleanly and truncates the log.
    tier.compact_now().expect("second compaction");
    let m = tier.metrics();
    assert_eq!(m.snapshots, 1, "{m:?}");
    assert!(dir.join("snapshot.bin").exists());
    assert_eq!(m.log_bytes, record::FILE_HEADER_BYTES as u64);
    drop(tier);

    let (_tier2, recovered) = PersistTier::open(&dir, cfg).expect("reopen");
    assert_eq!(recovered.len(), keys.len(), "every record survives the failed compaction");
    failpoint::disarm_all();
}

#[test]
fn torn_tail_is_truncated_and_counted_while_complete_records_replay() {
    let _g = serial();
    failpoint::disarm_all();
    let dir = fresh_dir("torn_tail");
    let cfg = PersistConfig::default();
    let (tier, _) = PersistTier::open(&dir, cfg).expect("open");
    let (ga, gb) = (mesh2d(8, 8), mesh2d(7, 9));
    let (ka, kb) = (CacheKey::new(&ga, None, 1), CacheKey::new(&gb, None, 2));
    let (va, vb) = (value_for(&ga, 3), value_for(&gb, 5));
    tier.enqueue_frame(tier.encode_frame(&ka, &ga, None, &va));
    tier.enqueue_frame(tier.encode_frame(&kb, &gb, None, &vb));
    tier.flush();
    let clean_len = tier.metrics().log_bytes;
    drop(tier);

    // Simulate a kill mid-append: a partial frame header on the tail.
    let log = dir.join("log.bin");
    let mut bytes = fs::read(&log).expect("log readable");
    assert_eq!(bytes.len() as u64, clean_len);
    bytes.extend_from_slice(&[0xDE, 0xAD, 0xBE, 0xEF, 0x00, 0x01, 0x02]);
    fs::write(&log, &bytes).expect("append torn tail");

    let (tier2, recovered) = PersistTier::open(&dir, cfg).expect("reopen");
    let m = tier2.metrics();
    assert_eq!(m.recovery_rejects, 1, "the torn tail is counted: {m:?}");
    assert_eq!(m.warm_start_entries, 2, "complete records all replay: {m:?}");
    assert!(!tier2.recovery_errors().is_empty(), "quarantine keeps the reason");
    assert_eq!(
        fs::metadata(&log).expect("log present").len(),
        clean_len,
        "recovery truncates the torn tail so it is never replayed or followed"
    );
    // Bit-identical replay of every complete record.
    for (key, graph, value) in [(ka, &ga, &va), (kb, &gb, &vb)] {
        let rec = recovered
            .iter()
            .find(|r| r.key == key)
            .unwrap_or_else(|| panic!("record {key:?} missing from recovery"));
        assert_eq!(rec.graph, *graph);
        assert_eq!(rec.value.perm, value.perm);
        assert_eq!(rec.value.rounds, value.rounds);
        assert_eq!(rec.value.set_sizes, value.set_sizes);
        assert_eq!(rec.value.reduced, value.reduced);
    }
    failpoint::disarm_all();
}

#[test]
fn checksummed_garbage_is_quarantined_and_the_walk_continues() {
    let _g = serial();
    failpoint::disarm_all();
    let dir = fresh_dir("garbage_record");
    fs::create_dir_all(&dir).unwrap();
    let (ga, gb) = (mesh2d(6, 6), mesh2d(5, 7));
    let (ka, kb) = (CacheKey::new(&ga, None, 1), CacheKey::new(&gb, None, 2));
    let now = unix_now();
    // valid frame | well-framed semantic garbage | valid frame: the
    // garbage checksums, so the walk quarantines it and keeps going.
    let mut buf = record::file_header().to_vec();
    buf.extend_from_slice(&record::encode(&ka, 0, now, &ga, None, &value_for(&ga, 1)));
    buf.extend_from_slice(&record::frame(&[0xAB; 48]));
    buf.extend_from_slice(&record::encode(&kb, 0, now, &gb, None, &value_for(&gb, 2)));
    fs::write(dir.join("log.bin"), &buf).unwrap();

    let (tier, recovered) = PersistTier::open(&dir, PersistConfig::default()).expect("open");
    let m = tier.metrics();
    assert_eq!(m.recovery_rejects, 1, "{m:?}");
    assert_eq!(m.warm_start_entries, 2, "records on both sides of the garbage replay");
    assert!(recovered.iter().any(|r| r.key == ka));
    assert!(recovered.iter().any(|r| r.key == kb));
    let errs = tier.recovery_errors();
    assert!(
        errs.iter().any(|e| e.contains("corrupt persist record")),
        "quarantine reasons: {errs:?}"
    );
    assert_eq!(
        fs::metadata(dir.join("log.bin")).unwrap().len() as usize,
        buf.len(),
        "an interior quarantine is not a torn tail: nothing is truncated"
    );
    failpoint::disarm_all();
}

#[test]
fn version_tag_and_ttl_invalidate_at_recovery() {
    let _g = serial();
    failpoint::disarm_all();
    let dir = fresh_dir("version_ttl");
    fs::create_dir_all(&dir).unwrap();
    let g = mesh2d(6, 8);
    let (fresh_key, stale_key) = (CacheKey::new(&g, None, 1), CacheKey::new(&g, None, 2));
    let now = unix_now();
    let mut buf = record::file_header().to_vec();
    buf.extend_from_slice(&record::encode(&fresh_key, 0, now, &g, None, &value_for(&g, 1)));
    buf.extend_from_slice(&record::encode(&stale_key, 0, 1000, &g, None, &value_for(&g, 2)));
    fs::write(dir.join("log.bin"), &buf).unwrap();

    // TTL: the ancient record expires, the fresh one replays.
    let ttl_cfg = PersistConfig {
        ttl_secs: 3600,
        ..PersistConfig::default()
    };
    let (tier, recovered) = PersistTier::open(&dir, ttl_cfg).expect("ttl open");
    let m = tier.metrics();
    assert_eq!(m.ttl_drops, 1, "{m:?}");
    assert_eq!(m.warm_start_entries, 1);
    assert_eq!(recovered[0].key, fresh_key);
    drop(tier);

    // Version tag: bumping the store version orphans every record
    // written under the old tag — the "reused graph id, changed
    // structure" invalidation path.
    let bumped = PersistConfig {
        version: 1,
        ..PersistConfig::default()
    };
    let (tier, recovered) = PersistTier::open(&dir, bumped).expect("bumped open");
    let m = tier.metrics();
    assert_eq!(m.version_drops, 2, "{m:?}");
    assert_eq!(m.warm_start_entries, 0);
    assert!(recovered.is_empty());
    drop(tier);

    // The matching tag still replays both (nothing was truncated).
    let (tier, recovered) = PersistTier::open(&dir, PersistConfig::default()).expect("open");
    assert_eq!(recovered.len(), 2);
    assert_eq!(tier.metrics().recovery_rejects, 0);
    failpoint::disarm_all();
}

#[test]
fn flusher_panic_degrades_to_a_lost_batch_not_a_cascade() {
    let _g = serial();
    failpoint::disarm_all();
    let dir = fresh_dir("flusher_panic");
    let cfg = PersistConfig::default();
    let (tier, _) = PersistTier::open(&dir, cfg).expect("open");
    let g = mesh2d(8, 10);
    let (k1, k2) = (CacheKey::new(&g, None, 1), CacheKey::new(&g, None, 2));

    // Batch 1 panics mid-append; flush() must still return (the batch
    // is acked as lost), the panic is counted, and the log is repaired.
    failpoint::arm(failpoint::PERSIST_APPEND, FailAction::Panic, Some(1));
    tier.enqueue_frame(tier.encode_frame(&k1, &g, None, &value_for(&g, 1)));
    tier.flush();
    assert_eq!(failpoint::fired(failpoint::PERSIST_APPEND), 1);
    let m = tier.metrics();
    assert_eq!(m.flush_panics, 1, "{m:?}");
    assert_eq!(m.log_bytes, record::FILE_HEADER_BYTES as u64, "repaired to last fsync");

    // The flusher thread survived its contained panic: batch 2 lands.
    tier.enqueue_frame(tier.encode_frame(&k2, &g, None, &value_for(&g, 2)));
    tier.flush();
    let m = tier.metrics();
    assert_eq!(m.appended_records, 1, "{m:?}");
    assert!(m.log_bytes > record::FILE_HEADER_BYTES as u64);
    drop(tier);
    failpoint::disarm_all();

    // Restart: the lost record is a cache miss, the later one replays.
    let (tier2, recovered) = PersistTier::open(&dir, cfg).expect("reopen");
    assert_eq!(tier2.metrics().recovery_rejects, 0, "repair left no torn bytes");
    assert_eq!(recovered.len(), 1);
    assert_eq!(recovered[0].key, k2);
    failpoint::disarm_all();
}

#[test]
fn compaction_dedups_last_wins_and_drops_over_budget_oldest_first() {
    let _g = serial();
    failpoint::disarm_all();
    let g = mesh2d(7, 7);
    let (k1, k2) = (CacheKey::new(&g, None, 1), CacheKey::new(&g, None, 2));
    let (old_v, new_v, other_v) = (value_for(&g, 1), value_for(&g, 9), value_for(&g, 4));

    // Dedup: two generations of k1 plus one k2; the snapshot keeps the
    // newer k1 (last write in log order wins).
    let dir = fresh_dir("compact_dedup");
    let cfg = PersistConfig::default();
    let (tier, _) = PersistTier::open(&dir, cfg).expect("open");
    tier.enqueue_frame(record::encode(&k1, 0, 100, &g, None, &old_v));
    tier.enqueue_frame(record::encode(&k2, 0, 200, &g, None, &other_v));
    tier.enqueue_frame(record::encode(&k1, 0, 300, &g, None, &new_v));
    tier.compact_now().expect("compact");
    let m = tier.metrics();
    assert_eq!(m.snapshots, 1, "{m:?}");
    assert_eq!(m.snapshot_dropped, 0);
    drop(tier);
    let (_t, recovered) = PersistTier::open(&dir, cfg).expect("reopen");
    assert_eq!(recovered.len(), 2, "compaction deduplicates by key");
    let k1_rec = recovered.iter().find(|r| r.key == k1).expect("k1 survives");
    assert_eq!(k1_rec.value.perm, new_v.perm, "last write wins");
    assert_eq!(k1_rec.created_at, 300);
    drop(_t);

    // Budget: a snapshot that only fits one record keeps the newest.
    let dir = fresh_dir("compact_budget");
    let frame_len = record::encode(&k1, 0, 100, &g, None, &old_v).len() as u64;
    let tight = PersistConfig {
        max_bytes: record::FILE_HEADER_BYTES as u64 + frame_len,
        ..PersistConfig::default()
    };
    let (tier, _) = PersistTier::open(&dir, tight).expect("open tight");
    tier.enqueue_frame(record::encode(&k1, 0, 100, &g, None, &old_v));
    tier.enqueue_frame(record::encode(&k2, 0, 300, &g, None, &other_v));
    tier.compact_now().expect("compact tight");
    let m = tier.metrics();
    assert_eq!(m.snapshot_dropped, 1, "{m:?}");
    drop(tier);
    let (_t, recovered) = PersistTier::open(&dir, tight).expect("reopen tight");
    assert_eq!(recovered.len(), 1, "over-budget records are dropped");
    assert_eq!(recovered[0].key, k2, "oldest-created is dropped first");
    failpoint::disarm_all();
}

#[test]
fn opening_over_a_plain_file_is_a_typed_io_error() {
    let _g = serial();
    failpoint::disarm_all();
    let path = fresh_dir("not_a_dir");
    fs::write(&path, b"occupied").unwrap();
    match PersistTier::open(&path, PersistConfig::default()) {
        Err(PersistError::Io { op, .. }) => assert_eq!(op, "create dir"),
        Err(other) => panic!("expected Io, got {other}"),
        Ok(_) => panic!("opening over a plain file must fail"),
    }
    failpoint::disarm_all();
}

#[test]
fn chaos_failpoints_leave_a_persistent_service_serviceable() {
    let _g = serial();
    failpoint::disarm_all();
    let dir = fresh_dir("chaos");
    let svc = persistent_service(&dir);
    let cases: [(&str, FailAction, Option<u64>); 3] = [
        (failpoint::DISPATCHER_PANIC, FailAction::Panic, Some(1)),
        (
            failpoint::STAGE_LATENCY,
            FailAction::Sleep(Duration::from_millis(25)),
            Some(1),
        ),
        (failpoint::CACHE_VERIFY, FailAction::Reject, Some(1)),
    ];
    for (i, (name, action, limit)) in cases.into_iter().enumerate() {
        let g = mesh2d(10, 10 + i);
        failpoint::arm(name, action, limit);
        match svc.submit(req(g.clone())).wait_result() {
            Ok(rep) => assert!(is_valid_perm(&rep.perm), "{name}: bad perm"),
            Err(OrderError::Failed(why)) => {
                assert!(why.contains("panicked"), "{name}: unexpected failure: {why}")
            }
            Err(other) => panic!("{name}: unexpected outcome {other:?}"),
        }
        let rep = svc
            .submit(req(g.clone()))
            .wait_result()
            .unwrap_or_else(|e| panic!("{name}: follow-up failed with persistence on: {e}"));
        assert!(is_valid_perm(&rep.perm), "{name}: follow-up perm invalid");
        failpoint::disarm_all();
    }
    drop(svc);

    // The chaos run left a usable store behind.
    let svc2 = persistent_service(&dir);
    let pm = svc2.metrics().shards.persist.expect("tier attached");
    assert!(pm.warm_start_entries >= 1, "{pm:?}");
    assert_eq!(pm.recovery_rejects, 0);
    assert!(is_valid_perm(&svc2.order(&req(mesh2d(10, 10))).perm));
    failpoint::disarm_all();
}
