//! Figure 4.1: runtime breakdown of ParAMD (pre-process, distance-2
//! selection, core AMD) as threads scale 1 → 64.
//!
//! Wall-clock columns are CPU-time sums (1-core testbed); the modeled
//! column is the critical-path time, which is what scales — its decrease
//! with t is the figure's message. The pre-processing row reproduces the
//! paper's observation that `|A|+|Aᵀ|` symmetrization scales poorly.

#[path = "bench_common/mod.rs"]
mod bench_common;

use paramd::bench_util::Table;
use paramd::graph::symmetrize_parallel;
use paramd::matgen::{self, spd_from_graph};
use paramd::ordering::paramd::{cost, ParAmd};
use paramd::util::timer::Timer;

fn main() {
    bench_common::banner("Figure 4.1 — runtime breakdown vs threads", "paper §4.4 Fig 4.1");
    for name in ["mini_nd24k", "mini_flan", "mini_nlpkkt"] {
        let e = matgen::suite_entry(name).unwrap();
        let g = (e.gen)(bench_common::scale());
        let a = spd_from_graph(&g, 1.0);
        println!("--- {name} (n = {}, nnz = {}) ---", g.n, g.nnz());
        let mut table = Table::new(&[
            "threads",
            "pre (s)",
            "select cpu (s)",
            "core cpu (s)",
            "modeled total (s)",
            "model speedup",
        ]);
        // Calibrate work→seconds on the single-thread run.
        let mut work_per_sec = 0.0;
        for t in [1usize, 2, 4, 8, 16, 64] {
            let tp = Timer::new();
            let _ = symmetrize_parallel(&a, t);
            let pre = tp.secs();
            let (r, d) = ParAmd::new(t).order_detailed(&g);
            let select: f64 = d.select_secs.iter().sum();
            let core: f64 = d.elim_secs.iter().sum();
            if t == 1 {
                let total_work: u64 = d
                    .round_work
                    .iter()
                    .flatten()
                    .map(|w| w.select + w.elim)
                    .sum();
                work_per_sec = total_work as f64 / (select + core).max(1e-9);
            }
            let modeled = cost::modeled_time(&d.round_work, work_per_sec, 5e-6);
            table.row(vec![
                format!("{t}"),
                format!("{pre:.4}"),
                format!("{select:.4}"),
                format!("{core:.4}"),
                format!("{modeled:.4}"),
                format!("{:.2}x", d.model_speedup),
            ]);
            let _ = r;
        }
        table.print();
        println!();
    }
    println!("paper shape: 1-thread ParAMD slower than SuiteSparse (selection overhead);");
    println!("core AMD scales with D2-set size; pre-processing is a scaling bottleneck.");
}
