//! Small shared utilities: deterministic RNG, timers, statistics,
//! fault-injection failpoints, logging.

pub mod failpoint;
pub mod rng;
pub mod stats;
pub mod timer;

/// Ceiling division for `usize`.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Recover a guard from a poisoned lock (or poisoned condvar wait).
///
/// Used wherever the protected state is a plain counter, flag, or
/// container that no panicking holder leaves mid-mutation — queue deques,
/// ticket state enums, metric tallies. Propagating the poison there would
/// turn one contained panic into a wedged service; recovering keeps the
/// pipeline draining. Sites whose invariants genuinely span several
/// mutations (none today) should keep `.unwrap()` and say why.
#[inline]
pub fn lock_unpoisoned<T>(r: Result<T, std::sync::PoisonError<T>>) -> T {
    r.unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Best-effort human-readable message of a caught panic payload.
pub fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

/// [`panic_message`] prefixed with the request id (the service's submit
/// counter), so a `failed` ticket is attributable in logs and traces.
pub fn panic_message_for(req_id: u64, p: &(dyn std::any::Any + Send)) -> String {
    format!("req {req_id}: {}", panic_message(p))
}

/// Split `n` items into `t` contiguous chunks as evenly as possible and
/// return the `[start, end)` range of chunk `tid`.
///
/// The first `n % t` chunks get one extra item, matching OpenMP's static
/// schedule. Every index in `0..n` is covered exactly once.
#[inline]
pub fn chunk_range(n: usize, t: usize, tid: usize) -> (usize, usize) {
    debug_assert!(tid < t);
    let base = n / t;
    let rem = n % t;
    let start = tid * base + tid.min(rem);
    let len = base + usize::from(tid < rem);
    (start, start + len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panic_message_carries_request_id() {
        let payload: Box<dyn std::any::Any + Send> = Box::new("boom".to_string());
        assert_eq!(panic_message_for(42, payload.as_ref()), "req 42: boom");
        let opaque: Box<dyn std::any::Any + Send> = Box::new(3usize);
        assert_eq!(panic_message_for(7, opaque.as_ref()), "req 7: unknown panic");
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(3, 3), 1);
        assert_eq!(ceil_div(4, 3), 2);
    }

    #[test]
    fn chunk_range_covers_all() {
        for n in [0usize, 1, 7, 64, 65, 1000] {
            for t in [1usize, 2, 3, 7, 64] {
                let mut next = 0usize;
                for tid in 0..t {
                    let (s, e) = chunk_range(n, t, tid);
                    assert_eq!(s, next, "n={n} t={t} tid={tid}");
                    assert!(e >= s);
                    next = e;
                }
                assert_eq!(next, n);
            }
        }
    }

    #[test]
    fn chunk_range_balanced() {
        let (s0, e0) = chunk_range(10, 3, 0);
        let (s1, e1) = chunk_range(10, 3, 1);
        let (s2, e2) = chunk_range(10, 3, 2);
        assert_eq!((e0 - s0, e1 - s1, e2 - s2), (4, 3, 3));
    }
}
