//! Sharded-ordering integration: sharded-vs-unsharded equivalence, the
//! 16-component/4-shard acceptance run (permutation validity + identical
//! fill counts + observed shard concurrency), cancellation mid-batch,
//! batched submission, and ticket deadlines.

use std::time::Duration;

use paramd::coordinator::{Method, OrderRequest, Service, WaitTimeout};
use paramd::graph::components::connected_components;
use paramd::graph::csr::SymGraph;
use paramd::graph::perm::is_valid_perm;
use paramd::matgen::{mesh2d, multi_component};
use paramd::ordering::paramd::ParAmd;
use paramd::ordering::Ordering as _;
use paramd::symbolic::fill_in;

fn paramd_req(g: SymGraph, compute_fill: bool) -> OrderRequest {
    OrderRequest {
        matrix: None,
        pattern: Some(g),
        method: Method::ParAmd {
            threads: 1,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill,
    }
}

#[test]
fn sharded_connected_ordering_bitmatches_the_unsharded_path() {
    // A connected graph takes the singleton fast path: one job on one
    // shard, no extraction. With 1-thread shards ParAMD is fully
    // deterministic, so the sharded service must reproduce the direct
    // (unsharded) cold run bit for bit, whatever shard it lands on.
    let g = mesh2d(24, 24);
    assert_eq!(connected_components(&g).count, 1);
    let reference = ParAmd::new(1).order(&g);
    let svc = Service::new(1).with_shards(4).with_shard_threads(1);
    for _ in 0..3 {
        let rep = svc.order(&paramd_req(g.clone(), false));
        assert_eq!(rep.perm, reference.perm, "sharded run diverged");
    }
    let m = svc.metrics();
    assert_eq!(m.shards.decomposed, 0, "connected graphs must not split");
    assert_eq!(m.shards.components, 3);
}

#[test]
fn sixteen_components_through_four_shards_match_the_unsharded_fill() {
    // The acceptance run: a 16-component graph ordered through 4 shards
    // must produce a valid permutation with exactly the fill count of
    // the unsharded (1-shard) path — sharding changes where components
    // run, never what is computed.
    let g = multi_component(16, &[150, 90, 200, 60]);
    assert_eq!(connected_components(&g).count, 16);

    let sharded = Service::new(1).with_shards(4).with_shard_threads(1);
    let rep4 = sharded.order(&paramd_req(g.clone(), true));
    let unsharded = Service::new(1);
    let rep1 = unsharded.order(&paramd_req(g.clone(), true));

    assert!(is_valid_perm(&rep4.perm), "sharded perm invalid");
    assert!(is_valid_perm(&rep1.perm), "unsharded perm invalid");
    assert_eq!(rep4.fill_in, rep1.fill_in, "fill must not depend on sharding");
    assert_eq!(rep4.perm, rep1.perm, "1-thread shards are deterministic");

    // Quality sanity against the whole-graph cold path: ordering
    // components independently must stay in the same fill band.
    let whole = fill_in(&g, &ParAmd::new(1).order(&g).perm) as f64;
    let sharded_fill = rep4.fill_in.unwrap() as f64;
    assert!(
        sharded_fill <= whole * 1.5 + 100.0,
        "sharded fill {sharded_fill} out of band vs whole-graph {whole}"
    );

    let m = sharded.metrics();
    assert_eq!(m.shards.decomposed, 1);
    assert_eq!(m.shards.components, 16);
    let jobs: u64 = m.shards.per_shard.iter().map(|s| s.jobs).sum();
    assert_eq!(jobs, 16, "every component ran as its own shard job");
}

#[test]
fn comparable_components_keep_multiple_shards_busy_concurrently() {
    // k = 8 comparable components through 4 shards: the ShardMetrics
    // concurrency peak must show >1 shard busy at the same time (the
    // acceptance criterion). Components are big enough that the 4
    // dispatchers necessarily overlap.
    let g = multi_component(8, &[900]);
    let svc = Service::new(2).with_shards(4).with_shard_threads(2);
    let rep = svc.order(&paramd_req(g.clone(), false));
    assert!(is_valid_perm(&rep.perm));
    assert_eq!(rep.perm.len(), g.n);

    let m = svc.metrics();
    assert!(
        m.shards.busy_peak > 1,
        "expected >1 shard busy concurrently, peak was {}",
        m.shards.busy_peak
    );
    assert_eq!(m.shards.components, 8);
    let jobs: u64 = m.shards.per_shard.iter().map(|s| s.jobs).sum();
    assert_eq!(jobs, 8);
    let busy_shards = m.shards.per_shard.iter().filter(|s| s.jobs > 0).count();
    assert!(busy_shards > 1, "work must spread over >1 shard");
}

#[test]
fn cancellation_mid_batch_leaves_the_sharded_service_healthy() {
    // Cancel a decomposed request while its component jobs are in
    // flight: queued jobs are skipped, running ones abort at a round
    // boundary, and the next request must come out clean.
    let svc = Service::new(1).with_shards(4).with_shard_threads(1);
    let big = multi_component(6, &[2500]);
    let ticket = svc.submit(paramd_req(big, false));
    std::thread::sleep(Duration::from_millis(2));
    ticket.cancel();
    drop(ticket);

    let g = mesh2d(13, 13);
    let rep = svc.submit(paramd_req(g.clone(), false)).wait();
    assert!(is_valid_perm(&rep.perm));
    assert_eq!(rep.perm.len(), g.n);

    let m = svc.metrics();
    assert_eq!(m.pipeline.submitted, 2);
    assert_eq!(m.pipeline.failed, 0);
    // The cancelled ticket resolves exactly one way (raced completion is
    // legal); the live one completed.
    assert_eq!(m.pipeline.completed + m.pipeline.cancelled, 2);
}

#[test]
fn submit_all_through_a_tiny_queue_resolves_in_order() {
    // Batch (8) larger than the queue cap (3): the single reservation
    // must chunk through backpressure while schedulers drain it.
    let svc = Service::new(1).with_queue_cap(3).with_scheduler_threads(2);
    let reqs: Vec<OrderRequest> = (0..8)
        .map(|i| paramd_req(mesh2d(6 + i, 7), false))
        .collect();
    let sizes: Vec<usize> = (0..8).map(|i| (6 + i) * 7).collect();
    let tickets = svc.submit_all(reqs);
    assert_eq!(tickets.len(), 8);
    for (ticket, n) in tickets.into_iter().zip(sizes) {
        let rep = ticket.wait();
        assert_eq!(rep.perm.len(), n, "reply matched to the wrong request");
        assert!(is_valid_perm(&rep.perm));
    }
    let m = svc.metrics();
    assert_eq!(m.pipeline.submitted, 8);
    assert_eq!(m.pipeline.completed, 8);
}

#[test]
fn wait_deadline_bounds_tail_latency_and_cancels() {
    // One scheduler, occupied by a slow request: the fast request behind
    // it cannot start, so its deadline must fire and cancel it.
    let svc = Service::new(1);
    let slow = svc.submit(paramd_req(multi_component(4, &[2000]), false));
    let fast = svc.submit(paramd_req(mesh2d(10, 10), false));
    let err = fast
        .wait_deadline(Duration::from_millis(1))
        .expect_err("queued request must time out behind the slow one");
    assert_eq!(err, WaitTimeout);

    // The slow request is unaffected and the pipeline stays healthy.
    let rep = slow.wait();
    assert!(is_valid_perm(&rep.perm));
    let final_rep = svc.order(&paramd_req(mesh2d(8, 8), false));
    assert_eq!(final_rep.perm.len(), 64);
    let m = svc.metrics();
    assert_eq!(m.pipeline.cancelled, 1, "expired ticket must cancel its job");
    assert_eq!(m.pipeline.failed, 0);
}

#[test]
fn wait_deadline_returns_the_reply_when_in_time() {
    let svc = Service::new(1);
    let ticket = svc.submit(paramd_req(mesh2d(9, 9), false));
    let rep = ticket
        .wait_deadline(Duration::from_secs(60))
        .expect("generous deadline must resolve");
    assert_eq!(rep.perm.len(), 81);
}
