//! Shared helpers for the paper-table benches.

use paramd::graph::csr::SymGraph;
use paramd::graph::perm::permute_graph;
use paramd::matgen::Scale;
use paramd::util::rng::Rng;

/// Benchmark scale from `PARAMD_SCALE` (tiny|small|full; default small).
pub fn scale() -> Scale {
    match std::env::var("PARAMD_SCALE").as_deref() {
        Ok("tiny") => Scale::Tiny,
        Ok("full") => Scale::Full,
        _ => Scale::Small,
    }
}

/// Thread count from `PARAMD_THREADS` (default 8; the paper used 64 — on
/// this 1-core testbed more logical threads only add oversubscription).
pub fn threads() -> usize {
    std::env::var("PARAMD_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8)
}

/// The paper's evaluation protocol (§2.5.4 / Table 4.2): `k` fixed random
/// input permutations shared by every method.
pub fn random_permutations(g: &SymGraph, k: usize) -> Vec<SymGraph> {
    (0..k)
        .map(|i| {
            let mut rng = Rng::new(0x7AB1E + i as u64);
            permute_graph(g, &rng.permutation(g.n))
        })
        .collect()
}

/// Banner with reproduction context.
pub fn banner(what: &str, paper_ref: &str) {
    println!("=== {what} ===");
    println!("(reproduces {paper_ref}; 1-core testbed — see DESIGN.md §2 for the");
    println!(" scale/hardware substitutions; shapes, not absolute numbers, compare)");
    println!();
}
