//! The sharded ordering engine: parallelism *across* independent
//! orderings, layered between the coordinator pipeline and the ParAMD
//! runtimes.
//!
//! PR 2 left one [`OrderingRuntime`] serializing the elimination phase —
//! every scheduler thread funnelled into a single ParAMD instance, the
//! exact "limited parallelism within elimination steps" wall the paper
//! identifies (§1, §4). The paper escapes it by parallelizing across
//! independent work; disconnected components are the cheapest such
//! independence (AMD never lets elimination in one component influence
//! another), and concurrent *requests* are the second. A [`ShardEngine`]
//! exploits both:
//!
//! ```text
//!            ShardEngine::order(g)
//!                   │
//!        connected_components(g)          (graph/components.rs)
//!           │               │
//!      connected        k components → split_components
//!           │               │
//!        reduce          reduce ×k        (ordering/reduce: twins, dense
//!           │               │              rows, leaves — parallel
//!           │               │              across components)
//!      router::pick     router::plan      (heaviest *reduced* kernel →
//!           │               │              wide shard, rest → least
//!           │               │              estimated finish time)
//!           ▼               ▼
//!   ┌─ shard 0 (wide) ─┐ ┌─ shard 1.. (narrow) ─┐
//!   │ queue → dispatch │ │ queue → dispatch     │   each shard: its own
//!   │ OrderingRuntime  │ │ OrderingRuntime      │   runtime + ArenaPool
//!   │ ArenaPool        │ │ ArenaPool            │
//!   └────────┬─────────┘ └─────────┬────────────┘
//!            └────── batch latch ──┘
//!                       │
//!                stitch::stitch            (ascending-size order)
//! ```
//!
//! ## Shards
//!
//! A shard owns an independent `OrderingRuntime` (persistent worker
//! pool), an `ArenaPool`, a policy-aware job queue, and one dispatcher
//! thread that drains the queue and runs each job warm
//! (`ParAmd::order_into_cancellable` on a pooled arena). Shards are
//! **size-classed** ([`ShardSpec`]): shard 0 is *wide* (most threads,
//! gets the largest component of every decomposed request), the rest
//! are *narrow*. With N shards, N orderings really do run concurrently —
//! components of one request, or whole requests from concurrent callers.
//!
//! ## Pre-ordering reduction
//!
//! Before routing, every component (and every connected request) passes
//! through the [`reduce`](crate::ordering::reduce) layer — on by default,
//! tunable via [`ShardEngine::set_reduce`]. A non-trivial
//! [`ReductionPlan`] turns the job into a **reduced job**: the dispatcher
//! orders the twin-compressed kernel with seed supervariables
//! (`ParAmd::order_into_cancellable_weighted`) and expands the kernel
//! permutation back (prefix ++ twin classes ++ dense tail) before
//! stitching. A trivial plan keeps the original path — including the
//! zero-copy borrow for connected requests — so irreducible graphs are
//! bit-identical to the pre-reduction engine. The router sees
//! post-reduction [`router::work_estimate`] units, so a component that
//! compresses 10× no longer hogs the wide shard.
//!
//! ## Hybrid ND×AMD path
//!
//! One huge *connected* graph defeats both parallelism sources above: it
//! is a single component and a single request. When the engine's
//! [`HybridConfig`] is enabled and the request clears its size
//! threshold, [`hybrid::plan`] runs recursive multilevel bisection
//! (reusing the `nd` stack) to cut the graph into independent
//! subdomains plus vertex-separator blocks. The subdomains then flow
//! through the *same* machinery as the components of a decomposed
//! request — reduction, kernel-level cache probes, LPT routing across
//! shards — as one concurrent batch; the separator blocks run as a
//! second batch strictly after, and
//! [`hybrid::stitch::stitch_hybrid`] merges `[subdomains…,
//! separators…]` into one valid elimination order. See the `hybrid`
//! module docs for the fill trade-off.
//!
//! ## Jobs and cancellation
//!
//! Every component (or connected request) becomes its own cancellable
//! job sharing the request's cancel flag. A cancelled job is skipped if
//! still queued and aborts at the next elimination-round boundary if
//! running; the submitting `order_cancellable` call always waits for
//! every job of its batch to resolve (done, cancelled, or panicked)
//! before returning, which is also what makes the lifetime-erased
//! borrows in [`GraphRef`]/[`CancelRef`] sound.
//!
//! ## Deadlines, lanes, and quality shedding
//!
//! [`ShardEngine::order_opts`] carries the coordinator's per-request
//! scheduling attributes into the engine. A request-carried deadline is
//! re-checked at every engine seam — before reduction, before routing,
//! and at dispatch — and an expired request resolves to `None` without
//! dispatching further work. Interactive-lane jobs overtake queued
//! batch jobs in every shard queue (priority changes service order,
//! never buffering). Under `shed_quality` the engine trades ordering
//! quality for availability: the hybrid partition and the
//! mid-elimination sweeps are skipped — by transforming the *effective*
//! configs before any cache salt is taken, so cache identity always
//! reflects what actually ran — and small components run inline through
//! sequential AMD, bypassing router, queue, runtime, and arena
//! entirely. Sequential stand-ins are valid orderings but not ParAMD's,
//! so they never enter the result cache. Every shed is tallied in
//! [`ShardMetrics`].
//!
//! ## Result cache
//!
//! Every engine owns a fingerprinted **result cache**
//! ([`crate::ordering::cache`], on by default, byte-budgeted). Probes
//! happen at two points: a whole-request probe short-circuits repeated
//! connected requests before reduction even runs, and a per-component
//! probe (after split + reduction, keyed on the compact kernel CSR +
//! weights) resolves repeated components without touching a router,
//! queue, runtime, or arena — the repeated-FEM-assembly workload where
//! identical components recur under scattered vertex labels. Misses
//! insert on completion; hits are exact-verified against the stored CSR
//! so a fingerprint collision downgrades to a miss instead of
//! corrupting a reply. A cache hit performs **zero** ParAMD work: shard
//! job counters do not move.
//!
//! ## Stitching
//!
//! Per-component permutations merge in ascending-component-size order
//! (deterministic, shard-placement-independent; see [`stitch`]), so a
//! sharded ordering of a given graph is a pure function of the graph
//! and the per-shard thread counts — with 1-thread shards it is fully
//! deterministic, which the bit-match tests rely on. (A cache hit
//! replays the *first* run's result for the same graph and knobs; see
//! the cache module docs for the width caveat.)

pub mod metrics;
pub mod router;
pub mod stitch;

pub use metrics::{ShardMetrics, ShardStat};

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::Lane;
use crate::graph::components::{connected_components, split_components, Component};
use crate::graph::csr::SymGraph;
use crate::ordering::amd_seq::AmdSeq;
use crate::ordering::cache::{
    config_salt, hybrid_salt, reduce_salt, CacheKey, CacheMetrics, CachedOrdering, ResultCache,
};
use crate::ordering::hybrid::{self, HybridConfig};
use crate::ordering::paramd::arena::ArenaPool;
use crate::ordering::paramd::runtime::{OrderingRuntime, QueuePolicy};
use crate::ordering::paramd::ParAmd;
use crate::ordering::reduce::{try_reduce, ReduceConfig, ReductionPlan};
use crate::ordering::{Ordering as _, RoundSample};
use crate::telemetry::{shard_lane, RequestTrace, LANE_ENGINE};
use crate::util::failpoint;
use crate::util::lock_unpoisoned;
use crate::util::panic_message;
use crate::util::panic_message_for;
use crate::util::stats::LogHistogram;
use crate::util::timer::Timer;

use metrics::EngineCounters;
use stitch::ComponentResult;

/// Shape of a shard engine: how many shards, and the size classes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Total shards (at least 1).
    pub shards: usize,
    /// Worker threads of shard 0, the wide shard.
    pub wide_threads: usize,
    /// Worker threads of every other shard.
    pub narrow_threads: usize,
}

impl ShardSpec {
    pub fn new(shards: usize, wide_threads: usize, narrow_threads: usize) -> Self {
        Self {
            shards: shards.max(1),
            wide_threads: wide_threads.max(1),
            narrow_threads: narrow_threads.max(1),
        }
    }

    /// All shards the same width.
    pub fn uniform(shards: usize, threads: usize) -> Self {
        Self::new(shards, threads, threads)
    }

    /// Per-shard thread counts, indexed by shard id.
    fn thread_plan(&self) -> Vec<usize> {
        (0..self.shards)
            .map(|s| {
                if s == 0 {
                    self.wide_threads
                } else {
                    self.narrow_threads
                }
            })
            .collect()
    }
}

/// Engine-level mid-elimination re-reduction settings, overriding the
/// corresponding [`ParAmd`] knobs of every job the engine dispatches
/// (see [`ShardEngine::set_rereduce`]) — the same layering as the
/// pre-ordering [`ReduceConfig`], but for the sweep that runs *inside*
/// the kernel at round boundaries.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RereduceSettings {
    /// Master switch for the sweep.
    pub enabled: bool,
    /// Fire every `every` rounds (0 disables the cadence trigger).
    pub every: u32,
    /// Fire when a round's pivot count drops below `elbow × threads`
    /// (0.0 disables the starvation trigger).
    pub elbow: f64,
}

impl Default for RereduceSettings {
    fn default() -> Self {
        let d = ParAmd::new(1);
        Self {
            enabled: d.rereduce,
            every: d.rereduce_every,
            elbow: d.rereduce_elbow,
        }
    }
}

impl RereduceSettings {
    /// The settings a [`ParAmd`] config carries.
    pub fn from_paramd(cfg: &ParAmd) -> Self {
        Self {
            enabled: cfg.rereduce,
            every: cfg.rereduce_every,
            elbow: cfg.rereduce_elbow,
        }
    }

    /// Impose these settings on a job config.
    fn apply(&self, cfg: ParAmd) -> ParAmd {
        cfg.with_rereduce(self.enabled)
            .with_rereduce_every(self.every)
            .with_rereduce_elbow(self.elbow)
    }
}

/// Reply of a sharded ordering: the stitched permutation plus the merged
/// round log (see [`stitch`] for the merge semantics).
#[derive(Clone, Debug)]
pub struct ShardReply {
    pub perm: Vec<i32>,
    pub rounds: u64,
    pub gc_count: u64,
    /// Stop-the-world GC seconds across the request's runs.
    pub gc_secs: f64,
    pub modeled_time: f64,
    /// Merged per-round pivot counts across components.
    pub set_sizes: Vec<u32>,
    /// Components the request split into (1 = connected fast path).
    pub components: usize,
    /// Vertices the reduction layer removed from the ordering problems
    /// (leaf prefixes + dense tails + merged twins, summed).
    pub reduced: usize,
    /// Per-round elimination samples of the request's **dominant** (most
    /// vertices) live kernel run — the Fig-4-style decay curve. Empty
    /// for cache replays (no elimination ran) and non-ParAMD configs.
    pub round_samples: Vec<RoundSample>,
    /// Elbow `claim` failures summed over the request's live jobs.
    pub claim_failures: u64,
}

/// Components (or post-reduction kernels) at or under this vertex count
/// run inline through sequential AMD when a request sheds quality —
/// small enough that the sequential pass is cheap, large enough to
/// relieve the shard queues of most FEM-style component swarms.
pub const SEQ_SHED_MAX_N: usize = 2048;

/// Per-request scheduling and degradation options of
/// [`ShardEngine::order_opts`] — the engine-side view of the
/// coordinator's admission, deadline, and shedding machinery.
pub struct OrderOptions<'a> {
    /// Cooperative cancellation flag shared with the submitter.
    pub cancel: &'a AtomicBool,
    /// Absolute deadline, re-checked at every engine seam (before
    /// reduction, before routing, at dispatch); an expired request
    /// resolves to `None` without dispatching further work.
    pub deadline: Option<Instant>,
    /// Priority lane: interactive jobs overtake queued batch jobs in
    /// every shard queue.
    pub lane: Lane,
    /// Trade ordering quality for availability: skip the hybrid
    /// partition and the mid-elimination sweeps, and order components
    /// at or under [`SEQ_SHED_MAX_N`] vertices inline through
    /// sequential AMD.
    pub shed_quality: bool,
    /// Flight recorder of the submitting request, when it carries one.
    pub trace: Option<&'a Arc<RequestTrace>>,
}

impl<'a> OrderOptions<'a> {
    /// Default options: batch lane, no deadline, full quality, untraced.
    pub fn new(cancel: &'a AtomicBool) -> Self {
        Self {
            cancel,
            deadline: None,
            lane: Lane::Batch,
            shed_quality: false,
            trace: None,
        }
    }
}

/// Has the request-carried deadline lapsed?
fn expired(deadline: Option<Instant>) -> bool {
    deadline.is_some_and(|d| Instant::now() >= d)
}

/// Where a job's graph lives: component jobs own their extracted
/// subgraph; the connected fast path borrows the caller's graph without
/// a copy.
enum GraphRef {
    Owned(SymGraph),
    /// Lifetime-erased borrow from an `order*` caller, which blocks on
    /// the batch until every job resolves (the same pattern as the
    /// runtime's `Job` and the pipeline's `BorrowedRequest`).
    Borrowed(*const SymGraph),
}

// SAFETY: the pointee is only read, and the submitting `order*` call
// keeps the borrow alive until the dispatcher resolved the job.
unsafe impl Send for GraphRef {}

impl GraphRef {
    fn get(&self) -> &SymGraph {
        match self {
            GraphRef::Owned(g) => g,
            // SAFETY: see the `Send` impl above.
            GraphRef::Borrowed(p) => unsafe { &**p },
        }
    }
}

/// Lifetime-erased borrow of the request's cancel flag (same soundness
/// argument as [`GraphRef`]).
struct CancelRef(*const AtomicBool);

// SAFETY: `AtomicBool` is `Sync`; the submitter outlives the job.
unsafe impl Send for CancelRef {}

impl CancelRef {
    fn get(&self) -> &AtomicBool {
        // SAFETY: see the `Send` impl above.
        unsafe { &*self.0 }
    }
}

/// What a job orders: the original graph, or a reduced kernel plus the
/// plan that expands its permutation back to the component's vertices.
enum JobPayload {
    Direct(GraphRef),
    Reduced(Box<ReductionPlan>),
}

/// One queued component (or whole-graph) ordering job.
struct ShardJob {
    payload: JobPayload,
    /// Post-reduction work units ([`router::work_estimate`]) — the
    /// queue's SmallestFirst key and the router's load unit.
    weight: usize,
    cfg: ParAmd,
    cancel: CancelRef,
    batch: Arc<Batch>,
    index: usize,
    /// When set, this job was a cache miss under this key: the
    /// dispatcher inserts the (kernel-level) result on completion.
    cache_key: Option<CacheKey>,
    /// Priority lane: interactive jobs overtake batch jobs at pop time.
    lane: Lane,
    /// The submitting request's deadline: a job found expired at pop
    /// time resolves `Cancelled` without dispatching.
    deadline: Option<Instant>,
    /// The submitting request's flight recorder, when it carries one:
    /// the dispatcher records its dispatch/elimination spans on
    /// [`shard_lane`]`(shard id)`.
    trace: Option<Arc<RequestTrace>>,
}

/// How one job of a batch resolved.
enum SlotState {
    Pending,
    Done(CompDone),
    Cancelled,
    Panicked(String),
}

/// The data a finished job leaves for the stitcher.
struct CompDone {
    perm: Vec<i32>,
    rounds: u64,
    gc_count: u64,
    gc_secs: f64,
    modeled_time: f64,
    set_sizes: Vec<u32>,
    /// Dispatcher seconds this job actually burned (0.0 for cache
    /// replays) — the hybrid path's per-subdomain busy attribution.
    busy_secs: f64,
    /// Mid-elimination re-reduction tally of this job's live kernel run
    /// (all zero for cache replays: no sweeps executed).
    rereduce_count: u64,
    mid_twins_merged: u64,
    mid_dense_postponed: u64,
    elements_absorbed: u64,
    rereduce_secs: f64,
    /// Per-round samples of this job's kernel run (empty for cache
    /// replays — the entry stores the permutation, not the telemetry).
    round_samples: Vec<RoundSample>,
    /// Elbow `claim` failures of this job's kernel run (0 for replays).
    claim_failures: u64,
}

impl CompDone {
    /// The cache-entry view of this result (kernel/component level;
    /// `reduced` is the caller's bookkeeping, not the entry's).
    fn to_cached(&self) -> CachedOrdering {
        CachedOrdering {
            perm: self.perm.clone(),
            rounds: self.rounds,
            gc_count: self.gc_count,
            gc_secs: self.gc_secs,
            modeled_time: self.modeled_time,
            set_sizes: self.set_sizes.clone(),
            reduced: 0,
        }
    }

    fn from_cached(c: CachedOrdering) -> Self {
        Self {
            perm: c.perm,
            rounds: c.rounds,
            gc_count: c.gc_count,
            gc_secs: c.gc_secs,
            modeled_time: c.modeled_time,
            set_sizes: c.set_sizes,
            busy_secs: 0.0,
            rereduce_count: 0,
            mid_twins_merged: 0,
            mid_dense_postponed: 0,
            elements_absorbed: 0,
            rereduce_secs: 0.0,
            round_samples: Vec::new(),
            claim_failures: 0,
        }
    }
}

/// Expand a kernel-level ordering result into the component-level result
/// a reduced job reports: the permutation expands through the plan and
/// the prefix/tail vertices surface as one extra "reduction round" (the
/// same accounting the live dispatch path uses, so cache hits and misses
/// are indistinguishable downstream).
fn expand_done(plan: &ReductionPlan, kernel: &CachedOrdering) -> CompDone {
    let pre = plan.pre_ordered();
    let mut set_sizes = Vec::with_capacity(kernel.set_sizes.len() + 1);
    if pre > 0 {
        set_sizes.push(pre as u32);
    }
    set_sizes.extend_from_slice(&kernel.set_sizes);
    CompDone {
        perm: plan.expand(&kernel.perm),
        rounds: kernel.rounds + u64::from(pre > 0),
        gc_count: kernel.gc_count,
        gc_secs: kernel.gc_secs,
        modeled_time: kernel.modeled_time,
        set_sizes,
        busy_secs: 0.0,
        rereduce_count: 0,
        mid_twins_merged: 0,
        mid_dense_postponed: 0,
        elements_absorbed: 0,
        rereduce_secs: 0.0,
        round_samples: Vec::new(),
        claim_failures: 0,
    }
}

/// Order `g` inline with sequential AMD — the quality-shed stand-in for
/// a small component. The whole component surfaces as one "round" in
/// the merged log (sequential AMD has no independent-set structure),
/// and the result carries no ParAMD telemetry.
fn sequential_done(g: &SymGraph) -> CompDone {
    let r = AmdSeq::default().order(g);
    CompDone {
        perm: r.perm,
        rounds: r.stats.rounds,
        gc_count: r.stats.gc_count,
        gc_secs: r.stats.gc_secs,
        modeled_time: r.stats.modeled_time,
        set_sizes: if g.n > 0 { vec![g.n as u32] } else { Vec::new() },
        busy_secs: 0.0,
        rereduce_count: 0,
        mid_twins_merged: 0,
        mid_dense_postponed: 0,
        elements_absorbed: 0,
        rereduce_secs: 0.0,
        round_samples: Vec::new(),
        claim_failures: 0,
    }
}

/// Batch-level observability aggregates a `run_parts` call returns
/// alongside its component results.
#[derive(Default)]
struct PartsTelemetry {
    /// Vertices the reduction layer removed across the batch.
    reduced: usize,
    /// Dispatcher busy seconds the batch's live jobs consumed (cache
    /// hits contribute zero).
    busy_secs: f64,
    /// Round samples of the batch's dominant (most vertices) live run.
    round_samples: Vec<RoundSample>,
    /// Elbow `claim` failures summed over the batch's live jobs.
    claim_failures: u64,
}

/// Completion latch of one request's jobs: dispatchers resolve slots,
/// the submitter blocks until all of them did.
struct Batch {
    state: Mutex<BatchState>,
    done: Condvar,
}

struct BatchState {
    remaining: usize,
    slots: Vec<SlotState>,
}

impl Batch {
    fn new(jobs: usize) -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new(BatchState {
                remaining: jobs,
                slots: (0..jobs).map(|_| SlotState::Pending).collect(),
            }),
            done: Condvar::new(),
        })
    }

    fn resolve(&self, index: usize, outcome: SlotState) {
        // Poison recovery: slot/counter updates are single-assignment,
        // so a panicking peer can never leave them mid-mutation — and a
        // poisoned batch latch would wedge its blocked submitter.
        let mut st = lock_unpoisoned(self.state.lock());
        debug_assert!(matches!(st.slots[index], SlotState::Pending));
        st.slots[index] = outcome;
        st.remaining -= 1;
        if st.remaining == 0 {
            drop(st);
            self.done.notify_all();
        }
    }

    fn wait(&self) -> Vec<SlotState> {
        let mut st = lock_unpoisoned(self.state.lock());
        while st.remaining > 0 {
            st = lock_unpoisoned(self.done.wait(st));
        }
        std::mem::take(&mut st.slots)
    }
}

/// A shard's job queue: FIFO or smallest-graph-first (the same
/// [`QueuePolicy`] the runtimes use), closeable for shutdown.
struct JobQueue {
    state: Mutex<JobQueueState>,
    available: Condvar,
}

struct JobQueueState {
    jobs: VecDeque<ShardJob>,
    policy: QueuePolicy,
    closed: bool,
}

impl JobQueue {
    fn new() -> Self {
        Self {
            state: Mutex::new(JobQueueState {
                jobs: VecDeque::new(),
                policy: QueuePolicy::Fifo,
                closed: false,
            }),
            available: Condvar::new(),
        }
    }

    fn push(&self, job: ShardJob) -> Result<(), ShardJob> {
        let mut st = lock_unpoisoned(self.state.lock());
        if st.closed {
            return Err(job);
        }
        st.jobs.push_back(job);
        drop(st);
        self.available.notify_one();
        Ok(())
    }

    /// Pop the next job: the interactive lane drains before any queued
    /// batch work, and within a lane the configured policy picks (FIFO
    /// age or smallest weight). Blocks until a job arrives or the queue
    /// closes.
    fn pop(&self) -> Option<ShardJob> {
        let mut st = lock_unpoisoned(self.state.lock());
        loop {
            if !st.jobs.is_empty() {
                let pick = |st: &JobQueueState, interactive_only: bool| -> Option<usize> {
                    let candidates = st
                        .jobs
                        .iter()
                        .enumerate()
                        .filter(|(_, j)| !interactive_only || j.lane == Lane::Interactive);
                    match st.policy {
                        QueuePolicy::Fifo => candidates.map(|(i, _)| i).next(),
                        QueuePolicy::SmallestFirst => candidates
                            .min_by_key(|(i, j)| (j.weight, *i))
                            .map(|(i, _)| i),
                    }
                };
                let idx = pick(&st, true)
                    .or_else(|| pick(&st, false))
                    .expect("non-empty queue");
                return st.jobs.remove(idx);
            }
            if st.closed {
                return None;
            }
            st = lock_unpoisoned(self.available.wait(st));
        }
    }

    fn set_policy(&self, policy: QueuePolicy) {
        lock_unpoisoned(self.state.lock()).policy = policy;
    }

    fn policy(&self) -> QueuePolicy {
        lock_unpoisoned(self.state.lock()).policy
    }

    fn close(&self) {
        lock_unpoisoned(self.state.lock()).closed = true;
        self.available.notify_all();
    }
}

/// One shard: an independent warm ordering lane.
struct Shard {
    /// Shard index — the trace lane key ([`shard_lane`]).
    id: usize,
    threads: usize,
    rt: OrderingRuntime,
    arenas: ArenaPool,
    queue: JobQueue,
    /// Pending + active work units — post-reduction
    /// [`router::work_estimate`] — the router's load signal.
    load: AtomicU64,
    jobs_done: AtomicU64,
    busy_nanos: AtomicU64,
    /// Fixed-footprint per-job busy-seconds distribution (the p95 line
    /// in [`ShardMetrics::report`]); cache replays never record.
    busy_hist: Mutex<LogHistogram>,
}

fn dispatcher_loop(shard: &Shard, counters: &EngineCounters, cache: &ResultCache) {
    while let Some(job) = shard.queue.pop() {
        let ShardJob {
            payload,
            weight,
            cfg,
            cancel,
            batch,
            index,
            cache_key,
            lane: _,
            deadline,
            trace,
        } = job;
        // An expired deadline is handled like a cancellation at pickup:
        // the slot resolves without dispatching (the submitter's pipeline
        // classifies the abandonment as deadline-exceeded).
        let outcome = if cancel.get().load(Relaxed) || expired(deadline) {
            SlotState::Cancelled
        } else {
            let dispatch_start = trace.as_ref().map(|t| t.now_us());
            counters.enter_busy();
            let res = catch_unwind(AssertUnwindSafe(|| {
                // The pooled warm storage; the guard releases on every
                // exit path, including unwind.
                let mut arena = shard.arenas.checkout();
                // Armed by the chaos suite: a worker panic right before
                // elimination, with the arena checked out — the unwind
                // must return it to the pool through the guard.
                failpoint::hit(failpoint::DISPATCHER_PANIC);
                let cancel = cancel.get();
                // Busy time starts after the arena is in hand, so it
                // measures ordering work, not checkout waits.
                let elim_start = trace.as_ref().map(|tr| tr.now_us());
                let t = Timer::new();
                let mut out = match &payload {
                    JobPayload::Direct(graph) => cfg
                        .order_into_cancellable(&shard.rt, &mut arena, graph.get(), cancel)
                        .map(|r| {
                            let done = CompDone {
                                perm: r.perm.clone(),
                                rounds: r.stats.rounds,
                                gc_count: r.stats.gc_count,
                                gc_secs: r.stats.gc_secs,
                                modeled_time: r.stats.modeled_time,
                                set_sizes: r.stats.set_sizes.clone(),
                                busy_secs: 0.0,
                                rereduce_count: r.stats.rereduce_count,
                                mid_twins_merged: r.stats.mid_twins_merged,
                                mid_dense_postponed: r.stats.mid_dense_postponed,
                                elements_absorbed: r.stats.elements_absorbed,
                                rereduce_secs: r.stats.rereduce_secs,
                                round_samples: r.stats.round_samples.clone(),
                                claim_failures: r.stats.claim_failures,
                            };
                            let insert = cache_key.map(|_| done.to_cached());
                            (done, insert)
                        }),
                    JobPayload::Reduced(plan) => cfg
                        .order_into_cancellable_weighted(
                            &shard.rt,
                            &mut arena,
                            &plan.kernel,
                            Some(&plan.weights),
                            cancel,
                        )
                        .map(|r| {
                            // The cacheable unit is the *kernel* result:
                            // a later component that reduces to the same
                            // weighted kernel expands it through its own
                            // plan. Expansion reports the prefix/tail
                            // vertices as one extra "reduction round" so
                            // the merged log still accounts for every
                            // pre-ordered vertex.
                            let kernel = CachedOrdering {
                                perm: r.perm.clone(),
                                rounds: r.stats.rounds,
                                gc_count: r.stats.gc_count,
                                gc_secs: r.stats.gc_secs,
                                modeled_time: r.stats.modeled_time,
                                set_sizes: r.stats.set_sizes.clone(),
                                reduced: 0,
                            };
                            let mut done = expand_done(plan, &kernel);
                            // `expand_done` zeroes the sweep tally (its
                            // other caller replays cache hits); this run
                            // was live, so report its actual sweeps.
                            done.rereduce_count = r.stats.rereduce_count;
                            done.mid_twins_merged = r.stats.mid_twins_merged;
                            done.mid_dense_postponed = r.stats.mid_dense_postponed;
                            done.elements_absorbed = r.stats.elements_absorbed;
                            done.rereduce_secs = r.stats.rereduce_secs;
                            done.round_samples = r.stats.round_samples.clone();
                            done.claim_failures = r.stats.claim_failures;
                            let insert = cache_key.map(|_| kernel);
                            (done, insert)
                        }),
                };
                let elapsed = t.elapsed();
                shard.busy_nanos.fetch_add(elapsed.as_nanos() as u64, Relaxed);
                lock_unpoisoned(shard.busy_hist.lock()).record(elapsed.as_secs_f64());
                if let Some((done, _)) = &mut out {
                    done.busy_secs = elapsed.as_secs_f64();
                    if let (Some(tr), Some(s0)) = (&trace, elim_start) {
                        tr.record("elimination", shard_lane(shard.id), s0);
                        // Synthesized aggregate: the in-elimination sweep
                        // total, nested at the elimination span's start.
                        let sweep_us = (done.rereduce_secs * 1e6) as u64;
                        if sweep_us > 0 {
                            tr.record_at(
                                "rereduce-sweeps",
                                shard_lane(shard.id),
                                s0,
                                sweep_us,
                            );
                        }
                    }
                }
                out
            }));
            shard.jobs_done.fetch_add(1, Relaxed);
            counters.exit_busy();
            let outcome = match res {
                Ok(Some((done, insert))) => {
                    counters.note_job_gc(done.gc_count, done.gc_secs);
                    counters.note_job_rereduce(
                        done.rereduce_count,
                        done.mid_twins_merged,
                        done.mid_dense_postponed,
                        done.elements_absorbed,
                        done.rereduce_secs,
                    );
                    counters.note_job_claim_failures(done.claim_failures);
                    if let (Some(key), Some(value)) = (cache_key, insert) {
                        // A miss inserts on completion; the payload is
                        // consumed into the entry's exact-verify copy.
                        let (graph, weights): (SymGraph, Option<Vec<i32>>) = match payload {
                            JobPayload::Direct(GraphRef::Owned(g)) => (g, None),
                            JobPayload::Direct(GraphRef::Borrowed(_)) => unreachable!(
                                "borrowed jobs use request-level inserts, never a job-level key"
                            ),
                            JobPayload::Reduced(plan) => {
                                let plan = *plan;
                                (plan.kernel, Some(plan.weights))
                            }
                        };
                        cache.insert(key, graph, weights, value);
                    }
                    SlotState::Done(done)
                }
                Ok(None) => SlotState::Cancelled,
                // Tag the panic with the request id when the job carries
                // a tagged trace, so a failed reply names its request.
                Err(p) => SlotState::Panicked(match &trace {
                    Some(tr) if tr.id() != 0 => panic_message_for(tr.id(), &p),
                    _ => panic_message(&p),
                }),
            };
            // The dispatch span wraps the elimination span (arena
            // checkout + ordering + cache insert) on the shard's lane.
            if let (Some(tr), Some(s0)) = (&trace, dispatch_start) {
                tr.record("dispatch", shard_lane(shard.id), s0);
            }
            outcome
        };
        shard.load.fetch_sub(weight as u64, Relaxed);
        // Resolve last: the submitter may drop the graph/cancel borrows
        // the moment its batch completes.
        batch.resolve(index, outcome);
    }
}

/// Take a span start for [`engine_span`] — `None` when untraced, so the
/// clock is never read on the untraced hot path.
fn span_start(trace: Option<&Arc<RequestTrace>>) -> Option<u64> {
    trace.map(|t| t.now_us())
}

/// Record `name` on [`LANE_ENGINE`] when the request carries a trace.
fn engine_span(trace: Option<&Arc<RequestTrace>>, name: &'static str, start: Option<u64>) {
    if let (Some(t), Some(s)) = (trace, start) {
        t.record(name, LANE_ENGINE, s);
    }
}

/// N independent ordering lanes behind a component router. See the
/// module docs for the architecture; construct once, order many graphs,
/// drop (or [`Self::shutdown_join`]) to stop the lanes.
pub struct ShardEngine {
    shards: Vec<Arc<Shard>>,
    counters: Arc<EngineCounters>,
    dispatchers: Vec<JoinHandle<()>>,
    spec: ShardSpec,
    /// Pre-ordering reduction config (on by default; see [`Self::set_reduce`]).
    reduce_cfg: Mutex<ReduceConfig>,
    /// Mid-elimination re-reduction settings imposed on every job's
    /// kernel config (on by default; see [`Self::set_rereduce`]).
    rereduce_cfg: Mutex<RereduceSettings>,
    /// ND×AMD hybrid planning for huge connected requests (off by
    /// default; see [`Self::set_hybrid`]).
    hybrid_cfg: Mutex<HybridConfig>,
    /// The fingerprinted result cache, shared with every dispatcher (the
    /// coordinator carries the same handle across engine rebuilds so
    /// warm entries survive a reshape).
    cache: Arc<ResultCache>,
}

impl ShardEngine {
    /// An engine with a fresh default-budget result cache.
    pub fn new(spec: ShardSpec) -> Self {
        Self::with_result_cache(
            spec,
            Arc::new(ResultCache::new(crate::ordering::cache::DEFAULT_BUDGET_BYTES)),
        )
    }

    /// An engine sharing an existing result cache — the rebuild path:
    /// entries cached by a replaced engine keep serving the new one.
    pub fn with_result_cache(spec: ShardSpec, cache: Arc<ResultCache>) -> Self {
        let shards: Vec<Arc<Shard>> = spec
            .thread_plan()
            .into_iter()
            .enumerate()
            .map(|(id, t)| {
                Arc::new(Shard {
                    id,
                    threads: t,
                    rt: OrderingRuntime::new(t),
                    arenas: ArenaPool::new(),
                    queue: JobQueue::new(),
                    load: AtomicU64::new(0),
                    jobs_done: AtomicU64::new(0),
                    busy_nanos: AtomicU64::new(0),
                    busy_hist: Mutex::new(LogHistogram::default()),
                })
            })
            .collect();
        let counters = Arc::new(EngineCounters::new());
        let dispatchers = shards
            .iter()
            .enumerate()
            .map(|(i, sh)| {
                let sh = Arc::clone(sh);
                let c = Arc::clone(&counters);
                let cache = Arc::clone(&cache);
                std::thread::Builder::new()
                    .name(format!("paramd-shard-{i}"))
                    .spawn(move || dispatcher_loop(&sh, &c, &cache))
                    .expect("spawn shard dispatcher")
            })
            .collect();
        Self {
            shards,
            counters,
            dispatchers,
            spec,
            // Fingerprint scans parallelize over the wide pool's width.
            reduce_cfg: Mutex::new(ReduceConfig {
                threads: spec.wide_threads,
                ..ReduceConfig::default()
            }),
            rereduce_cfg: Mutex::new(RereduceSettings::default()),
            hybrid_cfg: Mutex::new(HybridConfig::disabled()),
            cache,
        }
    }

    /// The engine's result cache handle (budget knobs, metrics; hand it
    /// to [`Self::with_result_cache`] when rebuilding the engine).
    pub fn result_cache(&self) -> &Arc<ResultCache> {
        &self.cache
    }

    /// Snapshot of the result-cache counters.
    pub fn cache_metrics(&self) -> CacheMetrics {
        self.cache.metrics()
    }

    /// The spec this engine was built with.
    pub fn spec(&self) -> ShardSpec {
        self.spec
    }

    /// Replace the pre-ordering reduction config (pass
    /// [`ReduceConfig::disabled`] to switch the layer off).
    pub fn set_reduce(&self, cfg: ReduceConfig) {
        *lock_unpoisoned(self.reduce_cfg.lock()) = cfg;
    }

    /// The reduction config currently in force.
    pub fn reduce_config(&self) -> ReduceConfig {
        *lock_unpoisoned(self.reduce_cfg.lock())
    }

    /// Replace the mid-elimination re-reduction settings. They override
    /// the matching [`ParAmd`] knobs of every subsequently dispatched
    /// job, and fold into each job's cache salt — toggling them on a
    /// warm engine misses and recomputes rather than replaying the
    /// other configuration's permutation.
    pub fn set_rereduce(&self, cfg: RereduceSettings) {
        *lock_unpoisoned(self.rereduce_cfg.lock()) = cfg;
    }

    /// The mid-elimination re-reduction settings currently in force.
    pub fn rereduce_config(&self) -> RereduceSettings {
        *lock_unpoisoned(self.rereduce_cfg.lock())
    }

    /// Replace the hybrid ND×AMD config (pass [`HybridConfig::on`] to
    /// partition huge connected requests into parallel subdomain jobs).
    pub fn set_hybrid(&self, cfg: HybridConfig) {
        *lock_unpoisoned(self.hybrid_cfg.lock()) = cfg;
    }

    /// The hybrid config currently in force.
    pub fn hybrid_config(&self) -> HybridConfig {
        *lock_unpoisoned(self.hybrid_cfg.lock())
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Worker threads of the wide shard — the effective ParAMD thread
    /// count for a connected request routed there.
    pub fn wide_threads(&self) -> usize {
        self.spec.wide_threads
    }

    /// Idle pooled arenas across every shard.
    pub fn idle_arenas(&self) -> usize {
        self.shards.iter().map(|s| s.arenas.idle()).sum()
    }

    /// Arenas evicted across every shard's pool.
    pub fn arena_evictions(&self) -> u64 {
        self.shards.iter().map(|s| s.arenas.evictions()).sum()
    }

    /// Every shard's arena pool saturated: no idle arena anywhere and
    /// each pool at its checkout capacity — the memory-pressure signal
    /// the coordinator's quality shedding keys on. Unbounded pools
    /// (the `usize::MAX` default cap) never report pressure.
    pub fn arena_pressure(&self) -> bool {
        self.shards
            .iter()
            .all(|s| s.arenas.idle() == 0 && s.arenas.outstanding() >= s.arenas.capacity())
    }

    /// Bound **each shard's** arena pool to `cap` arenas. With one
    /// dispatcher per shard at most one arena is checked out at a time,
    /// so the cap bounds *retained* (idle) warm storage per shard.
    pub fn set_arena_cap(&self, cap: usize) {
        for s in &self.shards {
            s.arenas.set_capacity(cap);
        }
    }

    /// The per-shard arena cap currently in force.
    pub fn arena_cap(&self) -> usize {
        self.shards[0].arenas.capacity()
    }

    /// Apply a queue policy to every shard queue (and its runtime).
    pub fn set_policy(&self, policy: QueuePolicy) {
        for s in &self.shards {
            s.queue.set_policy(policy);
            s.rt.set_policy(policy);
        }
    }

    /// The queue policy currently in force.
    pub fn policy(&self) -> QueuePolicy {
        self.shards[0].queue.policy()
    }

    /// Snapshot of the engine's metrics.
    pub fn metrics(&self) -> ShardMetrics {
        let per_shard = self
            .shards
            .iter()
            .map(|s| ShardStat {
                threads: s.threads,
                jobs: s.jobs_done.load(Relaxed),
                busy_secs: s.busy_nanos.load(Relaxed) as f64 / 1e9,
                busy_p95_secs: lock_unpoisoned(s.busy_hist.lock()).quantile(0.95),
            })
            .collect();
        let mut m = self.counters.snapshot(per_shard);
        m.persist = self.cache.persist_metrics();
        m
    }

    /// Order `g`, never cancelled ([`Self::order_cancellable`] with a
    /// flag that stays false).
    pub fn order(&self, g: &SymGraph, cfg: ParAmd) -> ShardReply {
        let cancel = AtomicBool::new(false);
        self.order_cancellable(g, cfg, &cancel)
            .expect("a never-cancelled sharded run always completes")
    }

    /// Order `g` through the shards: decompose into connected
    /// components, route each to a shard as its own cancellable job, and
    /// stitch the per-component permutations (ascending-size order) into
    /// one reply. A connected graph skips extraction entirely and runs
    /// as a single borrowed job on the least-loaded shard.
    ///
    /// Returns `None` when `cancel` fired: queued jobs are skipped,
    /// running ones abort at their next round boundary, and this call
    /// still waits for every job to resolve before returning (so the
    /// borrows it handed out are dead by then).
    pub fn order_cancellable(
        &self,
        g: &SymGraph,
        cfg: ParAmd,
        cancel: &AtomicBool,
    ) -> Option<ShardReply> {
        self.order_traced(g, cfg, cancel, None)
    }

    /// [`Self::order_cancellable`] with a flight recorder: every engine
    /// phase (cc-split, reduce, cache-probe, route, stitch) records a
    /// span on [`LANE_ENGINE`], and each dispatched job records its
    /// dispatch/elimination spans on its shard's lane — so concurrent
    /// component jobs render as parallel tracks in the Chrome trace.
    /// `trace: None` is exactly the untraced path (no clock reads).
    pub fn order_traced(
        &self,
        g: &SymGraph,
        cfg: ParAmd,
        cancel: &AtomicBool,
        trace: Option<&Arc<RequestTrace>>,
    ) -> Option<ShardReply> {
        self.order_opts(
            g,
            cfg,
            &OrderOptions {
                trace,
                ..OrderOptions::new(cancel)
            },
        )
    }

    /// [`Self::order_traced`] with the full per-request option set —
    /// deadline propagation, priority lane, and quality shedding (see
    /// the module docs). This is the coordinator pipeline's entry
    /// point; the narrower `order*` wrappers all funnel here.
    pub fn order_opts(
        &self,
        g: &SymGraph,
        cfg: ParAmd,
        opts: &OrderOptions<'_>,
    ) -> Option<ShardReply> {
        self.counters.requests.fetch_add(1, Relaxed);
        if expired(opts.deadline) {
            return None;
        }
        let cancel = opts.cancel;
        let trace = opts.trace;
        // The engine-level sweep settings are imposed before the salt is
        // taken, so the cache identity always reflects what actually
        // ran. A quality shed disables the sweep through the same
        // transform — ahead of the salt — so a shed request's cache
        // identity is the disabled-sweep configuration, never a lie.
        let rr = self.rereduce_config();
        let rr = if opts.shed_quality && rr.enabled {
            self.counters.shed_rereduce.fetch_add(1, Relaxed);
            RereduceSettings {
                enabled: false,
                every: 0,
                elbow: 0.0,
            }
        } else {
            rr
        };
        let cfg = rr.apply(cfg);
        let salt = config_salt(&cfg);
        let t0 = span_start(trace);
        let comps = connected_components(g);
        engine_span(trace, "cc-split", t0);
        if comps.is_connected() {
            self.counters.components.fetch_add(1, Relaxed);
            self.counters.note_component(g.n);
            let rcfg = self.reduce_config();
            let hcfg = self.hybrid_config();
            // Shedding skips the partition entirely — subdomain quality
            // and partition latency traded for availability — again by
            // transforming the effective config ahead of its salt.
            let hcfg = if opts.shed_quality && hcfg.applies(g.n) {
                self.counters.shed_hybrid.fetch_add(1, Relaxed);
                HybridConfig::disabled()
            } else {
                hcfg
            };
            // The whole-request probe lives on the connected path (only
            // connected replies store request-level entries) — so a
            // disconnected request never pays a guaranteed-miss
            // fingerprint of its full CSR; its cache identity lives at
            // component granularity, where compact extraction
            // normalizes scattered vertex labels away. A request-level
            // entry bakes the reduction *and* hybrid outcomes into its
            // stored permutation, so its salt folds in both configs —
            // toggling `--no-reduce`, `α`, or any hybrid knob on a warm
            // engine must miss and recompute, never replay a stale
            // path. (Hits don't move the per-shard job counters: those
            // are the dispatched-work signal.)
            let request_key = if self.cache.is_enabled() && g.n > 0 && !cancel.load(Relaxed) {
                let p0 = span_start(trace);
                let request_salt =
                    crate::util::rng::splitmix64(salt ^ reduce_salt(&rcfg) ^ hybrid_salt(&hcfg));
                let key = CacheKey::new(g, None, request_salt);
                let hit = self.cache.get(&key, g, None);
                engine_span(trace, "cache-probe", p0);
                if let Some(hit) = hit {
                    return Some(Self::reply_from_cached(hit));
                }
                Some(key)
            } else {
                None
            };
            // A shed request small enough for the sequential fallback
            // runs inline on this thread: no router, queue, runtime, or
            // arena. The stand-in is a valid ordering but not ParAMD's
            // answer under these knobs, so it never enters the cache —
            // `request_key` is deliberately dropped. (The full-quality
            // probe above still applies: a warm hit is strictly better.)
            if opts.shed_quality && g.n <= SEQ_SHED_MAX_N {
                self.counters.shed_sequential.fetch_add(1, Relaxed);
                let d = sequential_done(g);
                return Some(ShardReply {
                    perm: d.perm,
                    rounds: d.rounds,
                    gc_count: d.gc_count,
                    gc_secs: d.gc_secs,
                    modeled_time: d.modeled_time,
                    set_sizes: d.set_sizes,
                    components: 1,
                    reduced: 0,
                    round_samples: Vec::new(),
                    claim_failures: 0,
                });
            }
            if hcfg.applies(g.n) && !cancel.load(Relaxed) {
                let p0 = span_start(trace);
                let t = Timer::new();
                let plan = hybrid::plan(g, &hcfg);
                self.counters
                    .partition_nanos
                    .fetch_add(t.elapsed().as_nanos() as u64, Relaxed);
                engine_span(trace, "partition", p0);
                // A degenerate partition (no balanced cut) falls back to
                // the single-job path — deterministically, so the
                // hybrid-salted request entry stays coherent.
                if let Some(plan) = plan {
                    return self.order_hybrid(g, plan, cfg, salt, request_key, opts);
                }
            }
            return self.order_connected(g, cfg, salt, rcfg, request_key, opts);
        }

        self.counters.decomposed.fetch_add(1, Relaxed);
        self.counters.components.fetch_add(comps.count as u64, Relaxed);
        for &s in &comps.sizes {
            self.counters.note_component(s);
        }
        let p0 = span_start(trace);
        let parts = split_components(g, &comps);
        engine_span(trace, "split", p0);
        let (results, tel) = self.run_parts(parts, cfg, salt, opts)?;
        let k = results.len();
        let p0 = span_start(trace);
        let stitched = stitch::stitch(g.n, &results);
        engine_span(trace, "stitch", p0);
        Some(ShardReply {
            perm: stitched.perm,
            rounds: stitched.rounds,
            gc_count: stitched.gc_count,
            gc_secs: stitched.gc_secs,
            modeled_time: stitched.modeled_time,
            set_sizes: stitched.set_sizes,
            components: k,
            reduced: tel.reduced,
            round_samples: tel.round_samples,
            claim_failures: tel.claim_failures,
        })
    }

    /// Reduce, cache-probe, route, dispatch, and collect a set of
    /// independent parts — the connected components of a decomposed
    /// request, or the subdomains / separator blocks of one hybrid
    /// phase — as one batch of shard jobs. Results come back in part
    /// order; `None` means `cancel` fired. A [`PartsTelemetry`] rides
    /// along with the batch-level observability aggregates.
    ///
    /// Reduction runs first (in parallel across parts) so routing works
    /// on post-reduction sizes. Per-part cache probe: a hit resolves
    /// its part on the spot — no router, queue, runtime, or arena — and
    /// only misses become jobs (which insert on completion). All probes
    /// precede all enqueues, so resolution within a batch is
    /// deterministic.
    fn run_parts(
        &self,
        parts: Vec<Component>,
        cfg: ParAmd,
        salt: u64,
        opts: &OrderOptions<'_>,
    ) -> Option<(Vec<ComponentResult>, PartsTelemetry)> {
        let cancel = opts.cancel;
        let trace = opts.trace;
        // Deadline seam: nothing is reduced or enqueued yet, so lapsing
        // here abandons the batch with zero work dispatched.
        if expired(opts.deadline) {
            return None;
        }
        let p0 = span_start(trace);
        let (payloads, works, reduced) = self.reduce_components(parts);
        engine_span(trace, "reduce", p0);
        let k = payloads.len();

        let mut resolved: Vec<Option<CompDone>> = Vec::new();
        resolved.resize_with(k, || None);
        let mut keys: Vec<Option<CacheKey>> = vec![None; k];
        if self.cache.is_enabled() && !cancel.load(Relaxed) {
            let p0 = span_start(trace);
            for (i, (payload, _)) in payloads.iter().enumerate() {
                let (graph, weights): (&SymGraph, Option<&[i32]>) = match payload {
                    JobPayload::Direct(gr) => (gr.get(), None),
                    JobPayload::Reduced(plan) => (&plan.kernel, Some(&plan.weights)),
                };
                let key = CacheKey::new(graph, weights, salt);
                match self.cache.get(&key, graph, weights) {
                    Some(hit) => {
                        resolved[i] = Some(match payload {
                            JobPayload::Direct(_) => CompDone::from_cached(hit),
                            JobPayload::Reduced(plan) => expand_done(plan, &hit),
                        })
                    }
                    None => keys[i] = Some(key),
                }
            }
            engine_span(trace, "cache-probe", p0);
        }

        // Quality shed: small parts (post-reduction kernels included)
        // resolve inline through sequential AMD on this thread — no
        // router, queue, runtime, or arena. The stand-ins are valid
        // orderings but not ParAMD's under these knobs, so their keys
        // are dropped: a shed result must never enter the result cache.
        if opts.shed_quality && !cancel.load(Relaxed) {
            for (i, (payload, _)) in payloads.iter().enumerate() {
                if resolved[i].is_some() {
                    continue;
                }
                let done = match payload {
                    JobPayload::Direct(gr) if gr.get().n <= SEQ_SHED_MAX_N => {
                        Some(sequential_done(gr.get()))
                    }
                    JobPayload::Reduced(plan) if plan.kernel.n <= SEQ_SHED_MAX_N => {
                        let d = sequential_done(&plan.kernel);
                        // The single synthesized "round" covers the
                        // kernel's *weighted* vertex total, so the merged
                        // round log still accounts for twin-merged
                        // vertices (Σ set_sizes == component n).
                        let covered: i32 = plan.weights.iter().sum();
                        Some(expand_done(
                            plan,
                            &CachedOrdering {
                                perm: d.perm,
                                rounds: d.rounds,
                                gc_count: d.gc_count,
                                gc_secs: d.gc_secs,
                                modeled_time: d.modeled_time,
                                set_sizes: if covered > 0 {
                                    vec![covered as u32]
                                } else {
                                    Vec::new()
                                },
                                reduced: 0,
                            },
                        ))
                    }
                    _ => None,
                };
                if let Some(d) = done {
                    self.counters.shed_sequential.fetch_add(1, Relaxed);
                    keys[i] = None;
                    resolved[i] = Some(d);
                }
            }
        }

        // Deadline seam: the router and queues are still untouched, so
        // an expiry here sheds the batch without orphaning a slot.
        if expired(opts.deadline) {
            return None;
        }
        let miss_works: Vec<u64> = (0..k)
            .filter(|&i| resolved[i].is_none())
            .map(|i| works[i])
            .collect();
        let p0 = span_start(trace);
        let assign = router::plan(&miss_works, &self.loads(), &self.thread_counts());
        engine_span(trace, "route", p0);
        let batch = Batch::new(miss_works.len());
        let mut comp_of_slot: Vec<usize> = Vec::with_capacity(miss_works.len());
        let mut old_maps: Vec<Vec<i32>> = Vec::with_capacity(k);
        for (i, (payload, old_of_new)) in payloads.into_iter().enumerate() {
            old_maps.push(old_of_new);
            if resolved[i].is_some() {
                continue; // cache hit: the payload (and any plan) is spent
            }
            let slot = comp_of_slot.len();
            comp_of_slot.push(i);
            let job = ShardJob {
                payload,
                weight: works[i] as usize,
                cfg,
                cancel: CancelRef(cancel as *const AtomicBool),
                batch: Arc::clone(&batch),
                index: slot,
                cache_key: keys[i],
                lane: opts.lane,
                deadline: opts.deadline,
                trace: trace.cloned(),
            };
            self.enqueue(assign[slot], job);
        }

        let slots = batch.wait();
        let mut cancelled = false;
        let mut panicked: Option<String> = None;
        for (slot, state) in slots.into_iter().enumerate() {
            match state {
                SlotState::Done(d) => resolved[comp_of_slot[slot]] = Some(d),
                SlotState::Cancelled => cancelled = true,
                SlotState::Panicked(why) => panicked = Some(why),
                SlotState::Pending => unreachable!("batch resolved with a pending slot"),
            }
        }
        if let Some(why) = panicked {
            panic!("sharded ordering job panicked: {why}");
        }
        if cancelled {
            return None;
        }
        let mut tel = PartsTelemetry {
            reduced,
            ..PartsTelemetry::default()
        };
        let mut dominant = 0usize;
        let mut results: Vec<ComponentResult> = Vec::with_capacity(k);
        for (i, done) in resolved.into_iter().enumerate() {
            let d = done.expect("every uncancelled part resolves");
            tel.busy_secs += d.busy_secs;
            tel.claim_failures += d.claim_failures;
            // The reply surfaces the *dominant* part's decay curve (the
            // request-level signal a caller plots); smaller parts keep
            // theirs in the engine's aggregate counters.
            if !d.round_samples.is_empty() && d.perm.len() > dominant {
                dominant = d.perm.len();
                tel.round_samples = d.round_samples;
            }
            results.push(ComponentResult {
                old_of_new: std::mem::take(&mut old_maps[i]),
                perm: d.perm,
                rounds: d.rounds,
                gc_count: d.gc_count,
                gc_secs: d.gc_secs,
                modeled_time: d.modeled_time,
                set_sizes: d.set_sizes,
            });
        }
        Some((results, tel))
    }

    /// A [`ShardReply`] replayed from a request-level cache entry.
    fn reply_from_cached(hit: CachedOrdering) -> ShardReply {
        ShardReply {
            perm: hit.perm,
            rounds: hit.rounds,
            gc_count: hit.gc_count,
            gc_secs: hit.gc_secs,
            modeled_time: hit.modeled_time,
            set_sizes: hit.set_sizes,
            components: 1,
            reduced: hit.reduced,
            // A replay ran no elimination: no samples, no contention.
            round_samples: Vec::new(),
            claim_failures: 0,
        }
    }

    /// Promote a finished connected reply to a request-level cache entry
    /// keyed on the caller's graph, so the next identical request
    /// short-circuits before reduction even runs.
    fn insert_request_entry(&self, key: Option<CacheKey>, g: &SymGraph, reply: &ShardReply) {
        if let Some(key) = key {
            self.cache.insert(
                key,
                g.clone(),
                None,
                CachedOrdering {
                    perm: reply.perm.clone(),
                    rounds: reply.rounds,
                    gc_count: reply.gc_count,
                    gc_secs: reply.gc_secs,
                    modeled_time: reply.modeled_time,
                    set_sizes: reply.set_sizes.clone(),
                    reduced: reply.reduced,
                },
            );
        }
    }

    /// Run the reduction layer over extracted components — chunked over
    /// scoped threads when there is more than one component — and turn
    /// each into a job payload plus its post-reduction work estimate.
    /// Returns `(payload, old_of_new)` pairs in component order, the
    /// router's work array, and the total vertex count reduced away.
    #[allow(clippy::type_complexity)]
    fn reduce_components(
        &self,
        parts: Vec<Component>,
    ) -> (Vec<(JobPayload, Vec<i32>)>, Vec<u64>, usize) {
        let rcfg = self.reduce_config();
        let t = Timer::new();
        let k = parts.len();
        let mut plans: Vec<Option<ReductionPlan>> = Vec::new();
        plans.resize_with(k, || None);
        if rcfg.is_enabled() {
            let workers = rcfg.threads.max(1).min(k);
            if workers <= 1 || k <= 1 {
                for (slot, part) in plans.iter_mut().zip(&parts) {
                    *slot = try_reduce(&part.graph, &rcfg);
                }
            } else {
                // Contiguous chunks of the component list per scoped
                // worker (fingerprint scans stay single-threaded inside —
                // no nested scopes). Per-component reduction is a pure
                // function, so the outcome is worker-count independent.
                let inner = ReduceConfig { threads: 1, ..rcfg };
                std::thread::scope(|s| {
                    let mut rest = plans.as_mut_slice();
                    for tid in 0..workers {
                        let (lo, hi) = crate::util::chunk_range(k, workers, tid);
                        let (chunk, tail) = rest.split_at_mut(hi - lo);
                        rest = tail;
                        let (parts, inner) = (&parts, &inner);
                        s.spawn(move || {
                            for (slot, part) in chunk.iter_mut().zip(&parts[lo..hi]) {
                                *slot = try_reduce(&part.graph, inner);
                            }
                        });
                    }
                });
            }
        }
        self.counters
            .reduce_nanos
            .fetch_add(t.elapsed().as_nanos() as u64, Relaxed);

        let mut payloads: Vec<(JobPayload, Vec<i32>)> = Vec::with_capacity(k);
        let mut works: Vec<u64> = Vec::with_capacity(k);
        let mut reduced = 0usize;
        for (part, plan) in parts.into_iter().zip(plans) {
            match plan {
                // `try_reduce` only returns a plan when a rule fired.
                Some(plan) => {
                    self.counters.note_reduction(&plan.stats);
                    reduced += plan.reduced_away();
                    works.push(router::work_estimate(
                        plan.kernel.n,
                        plan.kernel.nedges(),
                    ));
                    payloads.push((JobPayload::Reduced(Box::new(plan)), part.old_of_new));
                }
                None => {
                    works.push(router::work_estimate(part.graph.n, part.graph.nedges()));
                    payloads.push((
                        JobPayload::Direct(GraphRef::Owned(part.graph)),
                        part.old_of_new,
                    ));
                }
            }
        }
        (payloads, works, reduced)
    }

    /// Connected (or empty) fast path: one job, no subgraph extraction,
    /// placed on the least-finish-time shard so concurrent requests fan
    /// out across shards. The reduction layer runs first; when no rule
    /// fires the caller's graph is borrowed without a copy, exactly as
    /// before, so irreducible inputs keep the zero-copy bit-match path.
    #[allow(clippy::too_many_arguments)]
    fn order_connected(
        &self,
        g: &SymGraph,
        cfg: ParAmd,
        salt: u64,
        rcfg: ReduceConfig,
        request_key: Option<CacheKey>,
        opts: &OrderOptions<'_>,
    ) -> Option<ShardReply> {
        let cancel = opts.cancel;
        let trace = opts.trace;
        let mut reduced = 0usize;
        let payload = if rcfg.is_enabled() && g.n > 0 {
            let p0 = span_start(trace);
            let t = Timer::new();
            let plan = try_reduce(g, &rcfg);
            self.counters
                .reduce_nanos
                .fetch_add(t.elapsed().as_nanos() as u64, Relaxed);
            engine_span(trace, "reduce", p0);
            match plan {
                None => JobPayload::Direct(GraphRef::Borrowed(g as *const SymGraph)),
                Some(plan) => {
                    self.counters.note_reduction(&plan.stats);
                    reduced = plan.reduced_away();
                    JobPayload::Reduced(Box::new(plan))
                }
            }
        } else {
            JobPayload::Direct(GraphRef::Borrowed(g as *const SymGraph))
        };
        // Kernel-level probe: a different request that reduces to the
        // same weighted kernel replays it here; the expanded reply is
        // then promoted to a request-level entry for next time. An
        // irreducible (borrowed) request needs no job-level key — the
        // request-level entry inserted on completion *is* its identity.
        let mut cache_key: Option<CacheKey> = None;
        if let JobPayload::Reduced(plan) = &payload {
            if self.cache.is_enabled() && !cancel.load(Relaxed) {
                let p0 = span_start(trace);
                let key = CacheKey::new(&plan.kernel, Some(&plan.weights), salt);
                let hit = self.cache.get(&key, &plan.kernel, Some(&plan.weights));
                engine_span(trace, "cache-probe", p0);
                if let Some(hit) = hit {
                    let d = expand_done(plan, &hit);
                    let reply = ShardReply {
                        perm: d.perm,
                        rounds: d.rounds,
                        gc_count: d.gc_count,
                        gc_secs: d.gc_secs,
                        modeled_time: d.modeled_time,
                        set_sizes: d.set_sizes,
                        components: 1,
                        reduced,
                        round_samples: Vec::new(),
                        claim_failures: 0,
                    };
                    self.insert_request_entry(request_key, g, &reply);
                    return Some(reply);
                }
                cache_key = Some(key);
            }
        }
        // Deadline seam: the job is not yet routed or enqueued, so an
        // expiry here abandons the request with zero dispatched work.
        if expired(opts.deadline) {
            return None;
        }
        let work = match &payload {
            JobPayload::Reduced(plan) => {
                router::work_estimate(plan.kernel.n, plan.kernel.nedges())
            }
            JobPayload::Direct(_) => router::work_estimate(g.n, g.nedges()),
        };
        let p0 = span_start(trace);
        let s = router::pick_shard(work, &self.loads(), &self.thread_counts());
        engine_span(trace, "route", p0);
        let batch = Batch::new(1);
        let job = ShardJob {
            payload,
            weight: work as usize,
            cfg,
            cancel: CancelRef(cancel as *const AtomicBool),
            batch: Arc::clone(&batch),
            index: 0,
            cache_key,
            lane: opts.lane,
            deadline: opts.deadline,
            trace: trace.cloned(),
        };
        self.enqueue(s, job);
        let mut slots = batch.wait();
        match slots.pop().expect("one slot") {
            SlotState::Done(d) => {
                let reply = ShardReply {
                    perm: d.perm,
                    rounds: d.rounds,
                    gc_count: d.gc_count,
                    gc_secs: d.gc_secs,
                    modeled_time: d.modeled_time,
                    set_sizes: d.set_sizes,
                    components: 1,
                    reduced,
                    round_samples: d.round_samples,
                    claim_failures: d.claim_failures,
                };
                self.insert_request_entry(request_key, g, &reply);
                Some(reply)
            }
            SlotState::Cancelled => None,
            SlotState::Panicked(why) => panic!("sharded ordering job panicked: {why}"),
            SlotState::Pending => unreachable!("batch resolved with a pending slot"),
        }
    }

    /// Extract the induced subgraphs of `lists` (original-vertex-id
    /// lists, pairwise disjoint) as independent parts — in parallel
    /// across lists on scoped threads sized by the wide shard's width,
    /// since extraction of a hybrid plan's subdomains is O(n + m) work
    /// that would otherwise serialize ahead of the fan-out.
    fn extract_parts(&self, g: &SymGraph, lists: &[Vec<i32>]) -> Vec<Component> {
        let k = lists.len();
        let workers = self.spec.wide_threads.max(1).min(k.max(1));
        let mut parts: Vec<Option<Component>> = Vec::new();
        parts.resize_with(k, || None);
        if workers <= 1 || k <= 1 {
            for (slot, list) in parts.iter_mut().zip(lists) {
                let (graph, old_of_new) = crate::nd::induced_subgraph(g, list);
                *slot = Some(Component { graph, old_of_new });
            }
        } else {
            std::thread::scope(|s| {
                let mut rest = parts.as_mut_slice();
                for tid in 0..workers {
                    let (lo, hi) = crate::util::chunk_range(k, workers, tid);
                    let (chunk, tail) = rest.split_at_mut(hi - lo);
                    rest = tail;
                    s.spawn(move || {
                        for (slot, list) in chunk.iter_mut().zip(&lists[lo..hi]) {
                            let (graph, old_of_new) = crate::nd::induced_subgraph(g, list);
                            *slot = Some(Component { graph, old_of_new });
                        }
                    });
                }
            });
        }
        parts
            .into_iter()
            .map(|p| p.expect("every list extracted"))
            .collect()
    }

    /// The hybrid fan-out of one huge connected request: order the
    /// plan's independent subdomains as concurrent shard jobs — each
    /// through reduction, kernel-level cache probes, and LPT routing
    /// like any component — then, strictly after every subdomain
    /// resolved, order the separator blocks (deepest level first) the
    /// same way, and stitch `[subdomains…, separators…]` into one
    /// permutation. The two-phase barrier is what keeps the result a
    /// valid elimination order: no separator vertex precedes a
    /// subdomain vertex, matching the ND partial order. Separator
    /// blocks that the reduction layer compresses run through the
    /// weighted ParAMD entry point exactly like reduced components.
    #[allow(clippy::too_many_arguments)]
    fn order_hybrid(
        &self,
        g: &SymGraph,
        plan: hybrid::HybridPlan,
        cfg: ParAmd,
        salt: u64,
        request_key: Option<CacheKey>,
        opts: &OrderOptions<'_>,
    ) -> Option<ShardReply> {
        let trace = opts.trace;
        self.counters.hybrid_requests.fetch_add(1, Relaxed);
        self.counters
            .subdomain_jobs
            .fetch_add(plan.subdomains.len() as u64, Relaxed);
        self.counters
            .separator_jobs
            .fetch_add(plan.separators.len() as u64, Relaxed);
        self.counters
            .separator_vertices
            .fetch_add(plan.separator_vertices as u64, Relaxed);
        self.counters.hybrid_vertices.fetch_add(g.n as u64, Relaxed);

        let sub_parts = self.extract_parts(g, &plan.subdomains);
        let (sub_results, sub_tel) = self.run_parts(sub_parts, cfg, salt, opts)?;
        self.counters
            .subdomain_busy_nanos
            .fetch_add((sub_tel.busy_secs * 1e9) as u64, Relaxed);

        let sep_parts = self.extract_parts(g, &plan.separators);
        let (sep_results, sep_tel) = self.run_parts(sep_parts, cfg, salt, opts)?;

        let p0 = span_start(trace);
        let stitched = hybrid::stitch::stitch_hybrid(g.n, &sub_results, &sep_results);
        engine_span(trace, "stitch", p0);
        let reply = ShardReply {
            perm: stitched.perm,
            rounds: stitched.rounds,
            gc_count: stitched.gc_count,
            gc_secs: stitched.gc_secs,
            modeled_time: stitched.modeled_time,
            set_sizes: stitched.set_sizes,
            components: 1,
            reduced: sub_tel.reduced + sep_tel.reduced,
            // The dominant subdomain's decay curve stands in for the
            // request (separator blocks are strictly smaller).
            round_samples: sub_tel.round_samples,
            claim_failures: sub_tel.claim_failures + sep_tel.claim_failures,
        };
        self.insert_request_entry(request_key, g, &reply);
        Some(reply)
    }

    fn enqueue(&self, s: usize, job: ShardJob) {
        self.shards[s].load.fetch_add(job.weight as u64, Relaxed);
        if self.shards[s].queue.push(job).is_err() {
            // Mirrors the runtime's loud failure: enqueueing onto closed
            // shards would hang the submitter forever.
            panic!("job submitted to a shut-down ShardEngine");
        }
    }

    fn loads(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.load.load(Relaxed)).collect()
    }

    fn thread_counts(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.threads).collect()
    }

    /// Close every shard queue and join the dispatchers (their runtimes
    /// join when the last shard handle drops). No jobs can be queued
    /// here: submitters hold `&self` borrows and block until their batch
    /// drains. Idempotent.
    pub fn shutdown_join(&mut self) {
        for s in &self.shards {
            s.queue.close();
        }
        for d in self.dispatchers.drain(..) {
            let _ = d.join();
        }
    }
}

impl Drop for ShardEngine {
    fn drop(&mut self) {
        self.shutdown_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::perm::is_valid_perm;
    use crate::matgen::{mesh2d, multi_component};
    use crate::ordering::Ordering as _;

    #[test]
    fn connected_graph_matches_the_direct_runtime_path() {
        let g = mesh2d(18, 18);
        let cfg = ParAmd::new(1);
        let cold = cfg.order(&g);
        let engine = ShardEngine::new(ShardSpec::uniform(3, 1));
        let rep = engine.order(&g, cfg);
        assert_eq!(rep.perm, cold.perm, "sharded connected run must bit-match");
        assert_eq!(rep.components, 1);
        let m = engine.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.decomposed, 0);
        assert_eq!(m.components, 1);
    }

    #[test]
    fn decomposed_request_covers_every_vertex() {
        let g = multi_component(5, &[40, 90, 17]);
        let engine = ShardEngine::new(ShardSpec::new(2, 2, 1));
        let rep = engine.order(&g, ParAmd::new(2));
        assert!(is_valid_perm(&rep.perm));
        assert_eq!(rep.perm.len(), g.n);
        assert_eq!(rep.components, 5);
        let total: u32 = rep.set_sizes.iter().sum();
        assert_eq!(total as usize, g.n, "merged round log covers every pivot");
        let m = engine.metrics();
        assert_eq!(m.decomposed, 1);
        assert_eq!(m.components, 5);
        let jobs: u64 = m.per_shard.iter().map(|s| s.jobs).sum();
        assert_eq!(jobs, 5);
    }

    #[test]
    fn sharded_result_is_placement_independent() {
        // Same graph through 1, 2, and 4 single-thread shards: identical
        // stitched permutations (per-component runs are deterministic and
        // the stitch order is size-based, not shard-based).
        let g = multi_component(6, &[30, 55, 80]);
        let reference = ShardEngine::new(ShardSpec::uniform(1, 1)).order(&g, ParAmd::new(1));
        for shards in [2usize, 4] {
            let engine = ShardEngine::new(ShardSpec::uniform(shards, 1));
            let rep = engine.order(&g, ParAmd::new(1));
            assert_eq!(rep.perm, reference.perm, "{shards} shards diverged");
        }
    }

    #[test]
    fn precancelled_order_returns_none_and_engine_survives() {
        let g = multi_component(4, &[60]);
        let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        let cancel = AtomicBool::new(true);
        assert!(engine.order_cancellable(&g, ParAmd::new(1), &cancel).is_none());
        // The engine still serves a live request afterwards.
        let rep = engine.order(&g, ParAmd::new(1));
        assert!(is_valid_perm(&rep.perm));
    }

    #[test]
    fn empty_graph_orders_to_the_empty_permutation() {
        let g = crate::graph::csr::SymGraph::from_edges(0, &[]);
        let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        let rep = engine.order(&g, ParAmd::new(1));
        assert!(rep.perm.is_empty());
    }

    #[test]
    fn reduced_connected_request_expands_to_a_valid_permutation() {
        // twin_heavy compresses ~6x; the engine must order the kernel
        // and expand back over every original vertex.
        let g = crate::matgen::twin_heavy(180, 6);
        let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        let rep = engine.order(&g, ParAmd::new(1));
        assert!(is_valid_perm(&rep.perm));
        assert_eq!(rep.perm.len(), g.n);
        assert_eq!(rep.components, 1);
        assert_eq!(rep.reduced, 150, "30-vertex kernel ← 180 vertices");
        let m = engine.metrics();
        assert_eq!(m.reduced_jobs, 1);
        assert_eq!(m.twins_merged, 150);
        assert!(m.reduce_secs >= 0.0);
    }

    #[test]
    fn disabling_reduction_restores_the_direct_path() {
        let g = crate::matgen::twin_heavy(120, 4);
        let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        engine.set_reduce(crate::ordering::reduce::ReduceConfig::disabled());
        let direct = ParAmd::new(1).order(&g);
        let rep = engine.order(&g, ParAmd::new(1));
        assert_eq!(rep.perm, direct.perm, "disabled reduction must bit-match");
        assert_eq!(rep.reduced, 0);
        assert_eq!(engine.metrics().reduced_jobs, 0);
    }

    #[test]
    fn reduction_survives_decomposition_and_stitching() {
        // Components with leaf tails: prefixes strip per component and
        // every vertex still lands in the stitched permutation exactly
        // once, identically for any shard count.
        let g = multi_component(6, &[40, 70]);
        let reference = ShardEngine::new(ShardSpec::uniform(1, 1)).order(&g, ParAmd::new(1));
        assert!(is_valid_perm(&reference.perm));
        let engine = ShardEngine::new(ShardSpec::uniform(3, 1));
        let rep = engine.order(&g, ParAmd::new(1));
        assert_eq!(rep.perm, reference.perm, "placement must not change the result");
        assert_eq!(rep.reduced, reference.reduced);
        let m = engine.metrics();
        assert!(
            m.leaves_stripped > 0,
            "path tails must strip as leaf prefixes"
        );
    }

    #[test]
    fn shutdown_join_is_idempotent_and_drop_safe() {
        let mut engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        engine.order(&mesh2d(6, 6), ParAmd::new(1));
        engine.shutdown_join();
        engine.shutdown_join();
        drop(engine); // must not hang
    }

    fn total_jobs(engine: &ShardEngine) -> u64 {
        engine.metrics().per_shard.iter().map(|s| s.jobs).sum()
    }

    #[test]
    fn repeated_connected_request_hits_the_cache_with_zero_jobs() {
        let g = mesh2d(15, 15);
        let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        let first = engine.order(&g, ParAmd::new(1));
        let jobs = total_jobs(&engine);
        assert_eq!(jobs, 1);
        let second = engine.order(&g, ParAmd::new(1));
        assert_eq!(second.perm, first.perm, "hit must bit-match the first run");
        assert_eq!(second.rounds, first.rounds);
        assert_eq!(second.set_sizes, first.set_sizes);
        assert_eq!(
            total_jobs(&engine),
            jobs,
            "a cache hit must perform zero ParAMD work"
        );
        let cm = engine.cache_metrics();
        assert_eq!(cm.hits, 1);
        assert!(cm.entries >= 1);
    }

    #[test]
    fn repeated_components_hit_per_component_with_zero_jobs() {
        // A repeat of the whole request re-splits deterministically into
        // the same compact component CSRs, so every component probe hits.
        let g = multi_component(6, &[40, 55, 70]);
        let engine = ShardEngine::new(ShardSpec::uniform(3, 1));
        let first = engine.order(&g, ParAmd::new(1));
        let jobs = total_jobs(&engine);
        assert_eq!(jobs, 6, "cold request orders every component");
        let second = engine.order(&g, ParAmd::new(1));
        assert_eq!(second.perm, first.perm);
        assert_eq!(second.components, 6);
        assert_eq!(
            total_jobs(&engine),
            jobs,
            "repeat must be served entirely from the component cache"
        );
        assert_eq!(engine.cache_metrics().hits, 6);
    }

    #[test]
    fn reduced_connected_repeat_skips_reduction_via_the_request_entry() {
        let g = crate::matgen::twin_heavy(180, 6);
        let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        let first = engine.order(&g, ParAmd::new(1));
        let reduce_jobs = engine.metrics().reduced_jobs;
        assert_eq!(reduce_jobs, 1);
        let second = engine.order(&g, ParAmd::new(1));
        assert_eq!(second.perm, first.perm);
        assert_eq!(second.reduced, first.reduced, "hit replays the reduced count");
        assert_eq!(
            engine.metrics().reduced_jobs,
            reduce_jobs,
            "the request-level entry must short-circuit before reduction"
        );
    }

    #[test]
    fn toggling_reduction_invalidates_request_entries() {
        // A request-level entry bakes the reduction outcome into its
        // permutation; flipping the reduction knobs must recompute, not
        // replay the stale path.
        let g = crate::matgen::twin_heavy(160, 4);
        let engine = ShardEngine::new(ShardSpec::uniform(1, 1));
        let first = engine.order(&g, ParAmd::new(1));
        assert!(first.reduced > 0, "twin-heavy input must reduce");
        engine.set_reduce(crate::ordering::reduce::ReduceConfig::disabled());
        let second = engine.order(&g, ParAmd::new(1));
        assert_eq!(second.reduced, 0, "disabled reduction must not replay");
        assert_eq!(total_jobs(&engine), 2, "the toggled repeat must re-order");
    }

    #[test]
    fn different_quality_knobs_do_not_share_entries() {
        let g = mesh2d(14, 14);
        let engine = ShardEngine::new(ShardSpec::uniform(1, 1));
        engine.order(&g, ParAmd::new(1));
        engine.order(&g, ParAmd::new(1).with_mult(1.4));
        assert_eq!(
            total_jobs(&engine),
            2,
            "a different mult must miss, not replay the wrong knobs"
        );
    }

    #[test]
    fn rereduce_settings_shape_the_cache_identity() {
        let g = crate::matgen::emergent_twins(220, 3);
        let engine = ShardEngine::new(ShardSpec::uniform(1, 1));
        let first = engine.order(&g, ParAmd::new(1));
        assert_eq!(total_jobs(&engine), 1);
        // An identical repeat replays bit-for-bit from the cache.
        let again = engine.order(&g, ParAmd::new(1));
        assert_eq!(again.perm, first.perm);
        assert_eq!(total_jobs(&engine), 1, "identical knobs must hit");
        // Changing any sweep knob on the warm engine must miss.
        engine.set_rereduce(RereduceSettings {
            every: 1,
            ..RereduceSettings::default()
        });
        engine.order(&g, ParAmd::new(1));
        assert_eq!(total_jobs(&engine), 2, "a new cadence must re-order");
        engine.set_rereduce(RereduceSettings {
            enabled: false,
            ..RereduceSettings::default()
        });
        engine.order(&g, ParAmd::new(1));
        assert_eq!(total_jobs(&engine), 3, "disabling the sweep must re-order");
        // Back to the defaults: the original entry is still warm.
        engine.set_rereduce(RereduceSettings::default());
        let replay = engine.order(&g, ParAmd::new(1));
        assert_eq!(replay.perm, first.perm);
        assert_eq!(total_jobs(&engine), 3, "the default entry must survive");
    }

    #[test]
    fn rereduce_tallies_surface_in_engine_metrics() {
        let g = crate::matgen::emergent_twins(220, 3);
        let engine = ShardEngine::new(ShardSpec::uniform(1, 1));
        engine.set_rereduce(RereduceSettings {
            every: 1,
            ..RereduceSettings::default()
        });
        engine.order(&g, ParAmd::new(1));
        let m = engine.metrics();
        assert!(m.rereduce_passes > 0, "sweeps must fire every round");
        assert!(m.elements_absorbed > 0, "sweeps must absorb elements");
        assert!(m.mid_twins_merged > 0, "sweeps must merge emergent twins");
        assert!(m.rereduce_secs > 0.0);
        assert!(m.report().contains("rereduce: passes="));
        // A cache replay performs no sweeps: the tallies must not move.
        engine.order(&g, ParAmd::new(1));
        assert_eq!(engine.metrics().rereduce_passes, m.rereduce_passes);
    }

    #[test]
    fn disabled_cache_reorders_every_repeat() {
        let g = mesh2d(12, 12);
        let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        engine.result_cache().set_budget(0);
        engine.order(&g, ParAmd::new(1));
        engine.order(&g, ParAmd::new(1));
        assert_eq!(total_jobs(&engine), 2, "no-cache repeats must re-order");
        let cm = engine.cache_metrics();
        assert_eq!((cm.hits, cm.misses, cm.entries), (0, 0, 0));
    }

    fn test_hybrid() -> HybridConfig {
        HybridConfig {
            enabled: true,
            partition_threshold: 1_000,
            recursion_depth: 2,
            balance_factor: 1.5,
        }
    }

    #[test]
    fn hybrid_fans_one_connected_mesh_across_shards() {
        let g = mesh2d(60, 60);
        let engine = ShardEngine::new(ShardSpec::uniform(4, 1));
        // Congruent mesh quadrants can fingerprint-collide as identical
        // kernels; disable the cache so every plan part really runs.
        engine.result_cache().set_budget(0);
        engine.set_hybrid(test_hybrid());
        let rep = engine.order(&g, ParAmd::new(1));
        assert!(is_valid_perm(&rep.perm));
        assert_eq!(rep.perm.len(), g.n);
        assert_eq!(rep.components, 1, "hybrid reply is still one component");
        let total: u32 = rep.set_sizes.iter().sum();
        assert_eq!(total as usize, g.n, "merged round log covers every pivot");
        let m = engine.metrics();
        assert_eq!(m.hybrid_requests, 1);
        assert!(m.subdomains >= 4, "depth 2 must yield 4 subdomain jobs");
        assert!(m.separators >= 1, "bisections must surface separators");
        let frac = m.separator_frac();
        assert!(frac > 0.0 && frac < 0.5, "separator fraction {frac}");
        assert_eq!(
            total_jobs(&engine),
            m.subdomains + m.separators,
            "every plan part becomes exactly one shard job"
        );
        assert!(m.partition_secs >= 0.0);
    }

    #[test]
    fn hybrid_below_threshold_keeps_the_single_job_path() {
        let g = mesh2d(10, 10);
        let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        engine.set_hybrid(HybridConfig {
            partition_threshold: 10_000,
            ..test_hybrid()
        });
        let rep = engine.order(&g, ParAmd::new(1));
        assert!(is_valid_perm(&rep.perm));
        assert_eq!(total_jobs(&engine), 1, "below threshold: one borrowed job");
        assert_eq!(engine.metrics().hybrid_requests, 0);
    }

    #[test]
    fn hybrid_result_is_placement_independent() {
        // The plan is a pure function of the graph and knobs, per-part
        // single-thread runs are deterministic, and the stitch follows
        // plan order — so shard count must not change the permutation.
        let g = mesh2d(50, 50);
        let mut perms = Vec::new();
        for shards in [2usize, 4] {
            let engine = ShardEngine::new(ShardSpec::uniform(shards, 1));
            engine.set_hybrid(test_hybrid());
            perms.push(engine.order(&g, ParAmd::new(1)).perm);
        }
        assert_eq!(perms[0], perms[1], "shard count changed the hybrid result");
    }

    #[test]
    fn hybrid_repeat_hits_the_request_cache_with_zero_jobs() {
        let g = mesh2d(50, 50);
        let engine = ShardEngine::new(ShardSpec::uniform(4, 1));
        engine.set_hybrid(test_hybrid());
        let first = engine.order(&g, ParAmd::new(1));
        let jobs = total_jobs(&engine);
        let second = engine.order(&g, ParAmd::new(1));
        assert_eq!(second.perm, first.perm, "hit must bit-match the first run");
        assert_eq!(
            total_jobs(&engine),
            jobs,
            "a hybrid repeat must be served from the request entry"
        );
        assert_eq!(
            engine.metrics().hybrid_requests,
            1,
            "the repeat never re-partitions"
        );
    }

    #[test]
    fn reply_round_samples_close_the_books_and_replays_are_empty() {
        let g = mesh2d(18, 18);
        let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        let rep = engine.order(&g, ParAmd::new(1));
        let weight: u64 = rep.round_samples.iter().map(|s| u64::from(s.weight)).sum();
        assert_eq!(weight as usize, g.n, "samples account for every column");
        let pivots: u64 = rep.round_samples.iter().map(|s| u64::from(s.pivots)).sum();
        assert!(pivots > 0);
        let m = engine.metrics();
        assert!(
            m.per_shard.iter().any(|s| s.busy_p95_secs > 0.0),
            "the live job must land in a shard's busy histogram"
        );
        assert!(m.report().contains("p95="));
        // The cached replay ran no elimination: honestly empty samples.
        let again = engine.order(&g, ParAmd::new(1));
        assert!(again.round_samples.is_empty());
        assert_eq!(again.claim_failures, 0);
    }

    #[test]
    fn decomposed_reply_surfaces_the_dominant_components_samples() {
        let g = multi_component(5, &[40, 90, 17]);
        let engine = ShardEngine::new(ShardSpec::new(2, 2, 1));
        let rep = engine.order(&g, ParAmd::new(2));
        assert!(
            !rep.round_samples.is_empty(),
            "a live decomposed request must carry a decay curve"
        );
        let weight: u64 = rep.round_samples.iter().map(|s| u64::from(s.weight)).sum();
        assert!(
            weight > 0 && weight <= 90,
            "dominant component's kernel weight, got {weight}"
        );
    }

    #[test]
    fn traced_request_records_engine_and_shard_spans() {
        let g = multi_component(4, &[30, 50]);
        let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        let trace = Arc::new(RequestTrace::new());
        let cancel = AtomicBool::new(false);
        let rep = engine
            .order_traced(&g, ParAmd::new(1), &cancel, Some(&trace))
            .expect("uncancelled run completes");
        assert!(is_valid_perm(&rep.perm));
        let spans = trace.spans();
        for name in ["cc-split", "split", "reduce", "cache-probe", "route", "stitch"] {
            assert!(
                spans.iter().any(|s| s.name == name && s.lane == LANE_ENGINE),
                "missing engine span {name}: {spans:?}"
            );
        }
        assert!(
            spans
                .iter()
                .any(|s| s.name == "elimination" && s.lane >= shard_lane(0)),
            "shard lanes must record eliminations: {spans:?}"
        );
        assert!(trace.invariant_violations().is_empty());
        // The untraced entry point records nothing and still works.
        let cached = engine.order_cancellable(&g, ParAmd::new(1), &cancel);
        assert!(cached.is_some());
        assert_eq!(trace.spans().len(), spans.len());
    }

    #[test]
    fn precancelled_hybrid_request_returns_none() {
        let g = mesh2d(50, 50);
        let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        engine.set_hybrid(test_hybrid());
        let cancel = AtomicBool::new(true);
        assert!(engine.order_cancellable(&g, ParAmd::new(1), &cancel).is_none());
        let rep = engine.order(&g, ParAmd::new(1));
        assert!(is_valid_perm(&rep.perm), "engine survives a cancelled hybrid");
    }

    #[test]
    fn interactive_jobs_overtake_queued_batch_work() {
        static CANCEL: AtomicBool = AtomicBool::new(false);
        let make = |weight: usize, lane: Lane, index: usize, batch: &Arc<Batch>| ShardJob {
            payload: JobPayload::Direct(GraphRef::Owned(SymGraph::from_edges(0, &[]))),
            weight,
            cfg: ParAmd::new(1),
            cancel: CancelRef(&CANCEL as *const AtomicBool),
            batch: Arc::clone(batch),
            index,
            cache_key: None,
            lane,
            deadline: None,
            trace: None,
        };
        // Two batch jobs queued first, two interactive jobs after: the
        // interactive lane drains first under either in-lane policy, and
        // within a lane the policy still decides (FIFO age vs weight).
        for (policy, want) in [
            (QueuePolicy::Fifo, [2usize, 3, 0, 1]),
            (QueuePolicy::SmallestFirst, [3, 2, 1, 0]),
        ] {
            let q = JobQueue::new();
            q.set_policy(policy);
            let batch = Batch::new(4);
            assert!(q.push(make(50, Lane::Batch, 0, &batch)).is_ok());
            assert!(q.push(make(10, Lane::Batch, 1, &batch)).is_ok());
            assert!(q.push(make(40, Lane::Interactive, 2, &batch)).is_ok());
            assert!(q.push(make(20, Lane::Interactive, 3, &batch)).is_ok());
            let got: Vec<usize> = (0..4).map(|_| q.pop().expect("queued job").index).collect();
            assert_eq!(got, want, "{policy:?} lane order");
        }
    }

    #[test]
    fn lapsed_deadline_abandons_before_any_dispatch() {
        let g = mesh2d(20, 20);
        let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        let cancel = AtomicBool::new(false);
        let opts = OrderOptions {
            deadline: Some(Instant::now()),
            ..OrderOptions::new(&cancel)
        };
        assert!(engine.order_opts(&g, ParAmd::new(1), &opts).is_none());
        assert_eq!(total_jobs(&engine), 0, "expired request must dispatch nothing");
        // The engine still serves a live request afterwards.
        let rep = engine.order(&g, ParAmd::new(1));
        assert!(is_valid_perm(&rep.perm));
    }

    #[test]
    fn shed_quality_orders_small_components_sequentially() {
        let g = multi_component(4, &[40, 60]);
        let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
        let cancel = AtomicBool::new(false);
        let rep = engine
            .order_opts(
                &g,
                ParAmd::new(1),
                &OrderOptions {
                    shed_quality: true,
                    ..OrderOptions::new(&cancel)
                },
            )
            .expect("a shed run still completes");
        assert!(is_valid_perm(&rep.perm));
        assert_eq!(rep.perm.len(), g.n);
        let total: u32 = rep.set_sizes.iter().sum();
        assert_eq!(total as usize, g.n, "merged round log covers every vertex");
        let m = engine.metrics();
        assert_eq!(m.shed_sequential, 4, "every small component runs inline");
        assert_eq!(total_jobs(&engine), 0, "a shed request dispatches no shard job");
        assert_eq!(
            engine.cache_metrics().entries,
            0,
            "shed stand-ins must never enter the result cache"
        );
        assert!(m.report().contains("shed:"), "{}", m.report());
        // A full-quality repeat really recomputes through the shards.
        let full = engine.order(&g, ParAmd::new(1));
        assert!(is_valid_perm(&full.perm));
        assert!(total_jobs(&engine) > 0, "full quality dispatches jobs again");
    }
}
