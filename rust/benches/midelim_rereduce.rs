//! Mid-elimination re-reduction speedup: kernel throughput with the
//! round-boundary sweep (global twin re-compression + dense
//! re-postponement + aggressive element absorption) on vs off.
//!
//! Two workloads, ordered at the kernel level with the pre-ordering
//! reduction layer out of the picture, so every collapse the sweep
//! finds is work the baseline really pays for:
//!
//! - **twin_heavy** (`matgen::twin_heavy`) — k-DOF twins visible from
//!   round one; the first sweep folds them k-fold, shedding the rounds
//!   and `L_e` traffic the baseline spends telling copies apart.
//! - **emergent_twins** (`matgen::emergent_twins`) — vertices that
//!   become twins only after the early elimination waves retire their
//!   distinguishing structure; invisible to any up-front pass, and the
//!   baseline eliminates the near-twins one at a time because shared
//!   hubs keep them distance-2 dependent.
//!
//! Reported columns include eliminated weight per round (how much
//! each stop-the-world round retires — the sweep's whole point is
//! raising it) and the seconds spent inside the sweep itself.
//!
//! Writes `BENCH_midelim_rereduce.json` (override with
//! `PARAMD_BENCH_MIDELIM_OUT`; default lands in the repository root
//! when run via `cargo bench` from `rust/`).
//!
//! Knobs: `PARAMD_THREADS` (default 8), `PARAMD_REPS` (default 6), or
//! `--smoke` for a one-pass CI run. In smoke mode the run *asserts*
//! the acceptance bars: >= 1.2x throughput over the sweep-disabled
//! baseline and fill within 1.05x, on both workloads.

#[path = "bench_common/mod.rs"]
#[allow(dead_code)] // shared helper module; this bench uses a subset
mod bench_common;

use paramd::graph::csr::SymGraph;
use paramd::matgen::{emergent_twins, twin_heavy};
use paramd::ordering::paramd::ParAmd;
use paramd::ordering::Ordering as _;
use paramd::symbolic::fill_in;
use paramd::util::timer::Timer;

struct Meas {
    secs: f64,
    fill: f64,
    weight_per_round: f64,
    rereduce_secs: f64,
    rereduce_count: u64,
    twins: u64,
    absorbed: u64,
}

/// Best-of-`reps` kernel ordering time for `cfg` on `g`, plus the
/// sweep tallies and fill of the (deterministic) result.
fn measure(g: &SymGraph, cfg: ParAmd, reps: usize) -> Meas {
    let mut best = f64::MAX;
    let mut last = None;
    for _ in 0..reps {
        let t = Timer::new();
        let r = cfg.order(g);
        best = best.min(t.secs());
        assert_eq!(r.perm.len(), g.n);
        last = Some(r);
    }
    let r = last.expect("reps >= 1");
    Meas {
        secs: best,
        fill: fill_in(g, &r.perm) as f64,
        weight_per_round: g.n as f64 / r.stats.rounds.max(1) as f64,
        rereduce_secs: r.stats.rereduce_secs,
        rereduce_count: r.stats.rereduce_count,
        twins: r.stats.mid_twins_merged,
        absorbed: r.stats.elements_absorbed,
    }
}

fn main() {
    bench_common::banner(
        "Mid-elimination re-reduction — sweep on vs off kernel throughput",
        "ISSUE 7 perf subsystem; not a paper table",
    );
    let smoke = std::env::args().any(|a| a == "--smoke");
    let threads = bench_common::threads().max(4);
    let reps: usize = if smoke {
        2
    } else {
        std::env::var("PARAMD_REPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(6)
    };

    let workloads: Vec<(&str, SymGraph)> = if smoke {
        vec![
            ("twin_heavy", twin_heavy(4000, 8)),
            ("emergent_twins", emergent_twins(2100, 3)),
        ]
    } else {
        vec![
            ("twin_heavy", twin_heavy(32_000, 8)),
            ("emergent_twins", emergent_twins(9_100, 3)),
        ]
    };

    println!(
        "{:<15} {:>7} {:>10} {:>10} {:>8} {:>10} {:>10} {:>9} {:>7} {:>9}",
        "workload",
        "n",
        "off(s)",
        "on(s)",
        "speedup",
        "w/rnd off",
        "w/rnd on",
        "rr(s)",
        "twins",
        "absorbed"
    );
    let mut rows = Vec::new();
    for (name, g) in &workloads {
        let off = measure(g, ParAmd::new(threads).with_rereduce(false), reps);
        let on = measure(g, ParAmd::new(threads).with_rereduce_every(1), reps);
        let speedup = off.secs / on.secs.max(1e-12);
        let fill_ratio = on.fill / off.fill.max(1.0);
        println!(
            "{:<15} {:>7} {:>10.4} {:>10.4} {:>7.2}x {:>10.1} {:>10.1} {:>9.4} {:>7} {:>9}",
            name,
            g.n,
            off.secs,
            on.secs,
            speedup,
            off.weight_per_round,
            on.weight_per_round,
            on.rereduce_secs,
            on.twins,
            on.absorbed
        );
        assert!(on.rereduce_count > 0, "{name}: the sweep must have fired");
        if smoke {
            assert!(
                speedup >= 1.2,
                "{name}: sweep speedup {speedup:.2}x below the 1.2x acceptance bar"
            );
            assert!(
                on.fill <= off.fill * 1.05 + 50.0,
                "{name}: sweep fill {} exceeds 1.05x of baseline {}",
                on.fill,
                off.fill
            );
        }
        rows.push(format!(
            "    {{\"workload\": \"{name}\", \"n\": {}, \"off_secs\": {:.6}, \
             \"on_secs\": {:.6}, \"speedup\": {speedup:.3}, \"fill_ratio\": {fill_ratio:.4}, \
             \"weight_per_round_off\": {:.2}, \"weight_per_round_on\": {:.2}, \
             \"rereduce_secs\": {:.6}, \"rereduce_passes\": {}, \
             \"mid_twins_merged\": {}, \"elements_absorbed\": {}}}",
            g.n,
            off.secs,
            on.secs,
            off.weight_per_round,
            on.weight_per_round,
            on.rereduce_secs,
            on.rereduce_count,
            on.twins,
            on.absorbed
        ));
    }

    let out = std::env::var("PARAMD_BENCH_MIDELIM_OUT")
        .unwrap_or_else(|_| "../BENCH_midelim_rereduce.json".into());
    let json = format!(
        "{{\n  \"bench\": \"midelim_rereduce\",\n  \"status\": \"measured\",\n  \
         \"threads\": {threads},\n  \"reps\": {reps},\n  \
         \"acceptance\": \"speedup >= 1.2 and fill_ratio <= 1.05 on both workloads\",\n  \
         \"workloads\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write(&out, &json).expect("write bench json");
    println!("\nwrote {out}");
}
