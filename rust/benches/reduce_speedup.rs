//! Reduction speedup: ordering throughput with the pre-ordering
//! reduction layer on vs off, on workloads built to exercise each rule.
//!
//! Two workloads, each ordered warm through the `Service` pipeline:
//!
//! - **twin-heavy** (`matgen::twin_heavy`) — FEM-style k-DOF blow-up;
//!   twin compression shrinks the kernel k-fold, so rounds, barriers,
//!   and `L_e` traffic all drop. The acceptance bar is ≥ 1.3× ordering
//!   throughput here.
//! - **dense-rows** (`matgen::with_dense_rows`) — a sparse mesh with a
//!   few near-dense rows; postponement keeps them out of every quotient
//!   scan.
//!
//! Writes the JSON trajectory file `BENCH_reduce_speedup.json` (override
//! with `PARAMD_BENCH_REDUCE_OUT`; default lands in the repository root
//! when run via `cargo bench` from `rust/`).
//!
//! Knobs: `PARAMD_THREADS` (default 8), `PARAMD_REPS` (default 8), or
//! `--smoke` for a one-pass CI run.

#[path = "bench_common/mod.rs"]
#[allow(dead_code)] // shared helper module; this bench uses a subset
mod bench_common;

use paramd::coordinator::{Method, OrderRequest, Service};
use paramd::graph::csr::SymGraph;
use paramd::matgen::{twin_heavy, with_dense_rows};
use paramd::util::timer::Timer;

fn paramd_req(g: SymGraph) -> OrderRequest {
    OrderRequest {
        matrix: None,
        pattern: Some(g),
        method: Method::ParAmd {
            threads: 4,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    }
}

/// Mean warm ordering seconds of `g` on a fresh service.
fn measure(g: &SymGraph, reduce_on: bool, threads: usize, reps: usize) -> (f64, u64) {
    let svc = Service::new(2)
        .with_order_threads(threads)
        .with_reduction(reduce_on)
        // This bench measures the reduction layer, not the result cache:
        // repeats of one request must genuinely re-order.
        .with_result_cache(0);
    let req = paramd_req(g.clone());
    svc.order(&req); // warm the arenas
    let t = Timer::new();
    for _ in 0..reps {
        let rep = svc.order(&req);
        assert_eq!(rep.perm.len(), g.n);
    }
    let secs = t.secs() / reps as f64;
    (secs, svc.metrics().shards.twins_merged)
}

fn main() {
    bench_common::banner(
        "Reduction speedup — twin compression, dense postponement, leaf stripping",
        "ISSUE 4 perf subsystem; not a paper table",
    );
    let smoke = std::env::args().any(|a| a == "--smoke");
    let threads = bench_common::threads().max(4);
    let reps: usize = if smoke {
        2
    } else {
        std::env::var("PARAMD_REPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(8)
    };

    let workloads: Vec<(&str, SymGraph)> = if smoke {
        vec![
            ("twin_heavy", twin_heavy(4000, 8)),
            ("dense_rows", with_dense_rows(3000, 900, 6)),
        ]
    } else {
        vec![
            ("twin_heavy", twin_heavy(48_000, 8)),
            ("dense_rows", with_dense_rows(40_000, 8_000, 12)),
        ]
    };

    println!(
        "{:<12} {:>9} {:>12} {:>12} {:>9} {:>12}",
        "workload", "n", "off(s)", "on(s)", "speedup", "twins_merged"
    );
    let mut rows = Vec::new();
    for (name, g) in &workloads {
        let (off_secs, _) = measure(g, false, threads, reps);
        let (on_secs, twins) = measure(g, true, threads, reps);
        let speedup = off_secs / on_secs.max(1e-12);
        println!(
            "{:<12} {:>9} {:>12.4} {:>12.4} {:>8.2}x {:>12}",
            name, g.n, off_secs, on_secs, speedup, twins
        );
        rows.push(format!(
            "    {{\"workload\": \"{name}\", \"n\": {}, \"unreduced_secs\": {off_secs:.6}, \
             \"reduced_secs\": {on_secs:.6}, \"speedup\": {speedup:.3}, \
             \"twins_merged\": {twins}}}",
            g.n
        ));
    }

    let out = std::env::var("PARAMD_BENCH_REDUCE_OUT")
        .unwrap_or_else(|_| "../BENCH_reduce_speedup.json".into());
    let json = format!(
        "{{\n  \"bench\": \"reduce_speedup\",\n  \"status\": \"measured\",\n  \
         \"threads\": {threads},\n  \"reps\": {reps},\n  \
         \"acceptance\": \"twin_heavy speedup >= 1.3\",\n  \"workloads\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write(&out, &json).expect("write bench json");
    println!("\nwrote {out}");
}
