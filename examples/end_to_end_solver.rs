//! End-to-end driver (the repository's full-system validation): for each
//! SPD matrix in the suite, run ordering (sequential AMD, ParAMD, ND) and
//! then factor + solve the reordered system through the three-layer stack
//! — Rust sparse solver dispatching its dense trailing block to the
//! AOT-compiled JAX/Pallas kernel via PJRT. Reports the paper's Table 4.3
//! layout (ordering time vs solver time) plus residuals.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end_solver`

use paramd::bench_util::Table;
use paramd::coordinator::{Method, OrderRequest, Service, SolveSpec};
use paramd::matgen::{self, Scale};

fn main() {
    // Two schedulers: the ordering stage of one request overlaps the
    // pre-processing/fill of the next (`solve` rides the same pipeline).
    let svc = Service::new(2)
        .with_scheduler_threads(2)
        .with_pjrt_solver("artifacts".into())
        .expect("PJRT solver (run `make artifacts`; needs the `pjrt` feature)");

    let methods = [
        ("SuiteSparse-style AMD", Method::Amd),
        (
            "ParAMD 8t",
            Method::ParAmd {
                threads: 8,
                mult: 1.1,
                lim_total: 8192,
            },
        ),
        ("ND", Method::Nd),
    ];

    let mut table = Table::new(&[
        "Matrix", "Method", "Ordering (s)", "Factor (s)", "Solve (s)", "Residual", "nnz(L)",
        "tail",
    ]);
    for entry in matgen::suite() {
        if !entry.symmetric {
            continue; // Table 4.3 restricts to SPD systems
        }
        let g = (entry.gen)(Scale::Tiny);
        let a = matgen::spd_from_graph(&g, 1.0);
        for (label, method) in methods {
            let req = OrderRequest {
                matrix: Some(a.clone()),
                pattern: None,
                method,
                compute_fill: false,
            };
            let rep = svc.solve(&req, &SolveSpec::OnesSolution).expect(label);
            assert!(
                rep.residual < 1e-8,
                "{}/{label}: residual {:e}",
                entry.name,
                rep.residual
            );
            table.row(vec![
                entry.name.into(),
                label.into(),
                format!("{:.4}", rep.order_secs),
                format!("{:.4}", rep.factor_secs),
                format!("{:.4}", rep.solve_secs),
                format!("{:.1e}", rep.residual),
                format!("{:.2e}", rep.nnz_l as f64),
                format!("{}", rep.dense_tail_cols),
            ]);
        }
    }
    table.print();
    println!("\n{}", svc.metrics().report());
    println!("All systems solved through ordering -> sparse factor -> PJRT dense tail.");
    println!("(cf. paper Table 4.3: ordering computed on CPU, system solved by cuDSS)");
}
