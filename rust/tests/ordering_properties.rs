//! Property-based integration tests across the ordering algorithms: every
//! algorithm must produce a valid permutation on any graph family, fill-in
//! metrics must be internally consistent, and the AMD-family invariants
//! (upper-bound degrees, supervariable exchangeability) must hold.

use paramd::graph::perm::{invert_perm, is_valid_perm, permute_graph};
use paramd::nd::NestedDissection;
use paramd::ordering::{
    amd_seq::AmdSeq, md::MinDegree, mmd::Mmd, paramd::ParAmd, Ordering, OrderingResult,
};
use paramd::prop::{arb_graph, forall, Config};
use paramd::symbolic;
use paramd::util::rng::Rng;

fn check_valid(g: &paramd::graph::csr::SymGraph, r: &OrderingResult) -> Result<(), String> {
    if r.perm.len() != g.n {
        return Err(format!("perm length {} != n {}", r.perm.len(), g.n));
    }
    if !is_valid_perm(&r.perm) {
        return Err("not a permutation".into());
    }
    let inv = invert_perm(&r.perm);
    for k in 0..g.n {
        if inv[r.perm[k] as usize] != k as i32 {
            return Err("iperm mismatch".into());
        }
    }
    Ok(())
}

#[test]
fn every_ordering_is_valid_on_arbitrary_graphs() {
    forall(
        Config {
            cases: 25,
            seed: 0xA11,
        },
        |rng| arb_graph(rng, 120),
        |g| {
            check_valid(g, &AmdSeq::default().order(g))?;
            check_valid(g, &Mmd::default().order(g))?;
            check_valid(g, &ParAmd::new(3).order(g))?;
            check_valid(g, &NestedDissection::default().order(g))?;
            Ok(())
        },
    );
}

#[test]
fn fill_in_fast_matches_naive_on_arbitrary_graphs() {
    forall(
        Config {
            cases: 20,
            seed: 0xF111,
        },
        |rng| {
            let g = arb_graph(rng, 50);
            let p = rng.permutation(g.n);
            (g, p)
        },
        |(g, p)| {
            let fast = symbolic::fill_in(g, p);
            let slow = symbolic::fill_in_naive(g, p);
            if fast != slow {
                return Err(format!("fast {fast} != naive {slow}"));
            }
            Ok(())
        },
    );
}

#[test]
fn amd_never_worse_than_reverse_quality_bound() {
    // AMD's fill must never exceed the dense bound and must be ≥ 0.
    forall(
        Config {
            cases: 20,
            seed: 0xB0B,
        },
        |rng| arb_graph(rng, 100),
        |g| {
            let r = AmdSeq::default().order(g);
            let f = symbolic::fill_in(g, &r.perm);
            let dense_bound = (g.n * (g.n - 1)) as i64 / 2 - g.nedges() as i64;
            if f < 0 || f > dense_bound.max(0) {
                return Err(format!("fill {f} outside [0, {dense_bound}]"));
            }
            Ok(())
        },
    );
}

#[test]
fn fill_is_permutation_covariant() {
    // fill(g, p) computed directly must equal fill of the pre-permuted
    // graph under the induced ordering.
    forall(
        Config {
            cases: 15,
            seed: 0xC07,
        },
        |rng| {
            let g = arb_graph(rng, 60);
            let p = rng.permutation(g.n);
            (g, p)
        },
        |(g, p)| {
            let f1 = symbolic::fill_in(g, p);
            let pg = permute_graph(g, p);
            let id: Vec<i32> = (0..g.n as i32).collect();
            let f2 = symbolic::fill_in(&pg, &id);
            if f1 != f2 {
                return Err(format!("{f1} != {f2}"));
            }
            Ok(())
        },
    );
}

#[test]
fn amd_tracks_exact_min_degree_on_small_graphs() {
    // On small graphs AMD (approximate) should stay within a constant
    // factor of exact minimum degree.
    forall(
        Config {
            cases: 15,
            seed: 0x3AD,
        },
        |rng| arb_graph(rng, 60),
        |g| {
            let f_amd = symbolic::fill_in(g, &AmdSeq::default().order(g).perm) as f64;
            let f_md = symbolic::fill_in(g, &MinDegree.order(g).perm) as f64;
            if f_amd > 3.0 * f_md + 60.0 {
                return Err(format!("AMD {f_amd} vs MD {f_md}"));
            }
            Ok(())
        },
    );
}

#[test]
fn paramd_quality_tracks_sequential_amd() {
    forall(
        Config {
            cases: 12,
            seed: 0x9AD,
        },
        |rng| arb_graph(rng, 150),
        |g| {
            let f_seq = symbolic::fill_in(g, &AmdSeq::default().order(g).perm) as f64;
            let f_par = symbolic::fill_in(g, &ParAmd::new(4).order(g).perm) as f64;
            if f_par > 2.0 * f_seq + 100.0 {
                return Err(format!("ParAMD {f_par} vs AMD {f_seq}"));
            }
            Ok(())
        },
    );
}

#[test]
fn orderings_invariant_to_isolated_vertex_padding() {
    // Adding isolated vertices must not change relative quality and all
    // algorithms must handle them.
    let mut rng = Rng::new(0x150);
    let base = arb_graph(&mut rng, 40);
    let padded = paramd::graph::csr::SymGraph {
        n: base.n + 10,
        rowptr: {
            let mut rp = base.rowptr.clone();
            let last = *rp.last().unwrap();
            rp.extend(std::iter::repeat(last).take(10));
            rp
        },
        colind: base.colind.clone(),
    };
    padded.validate().unwrap();
    for r in [
        AmdSeq::default().order(&padded),
        ParAmd::new(2).order(&padded),
        NestedDissection::default().order(&padded),
    ] {
        check_valid(&padded, &r).unwrap();
    }
}
