//! Reverse Cuthill–McKee ordering — a classical bandwidth-reducing
//! baseline included for the quality comparisons (it predates the minimum
//! degree family and typically produces far more fill on 3D problems,
//! which the ablation/quality benches demonstrate).

use crate::graph::csr::SymGraph;
use crate::ordering::{Ordering, OrderingResult};
use crate::util::timer::Timer;

/// Reverse Cuthill–McKee.
#[derive(Clone, Copy, Debug, Default)]
pub struct Rcm;

impl Ordering for Rcm {
    fn name(&self) -> &'static str {
        "rcm"
    }

    fn order(&self, g: &SymGraph) -> OrderingResult {
        let t = Timer::new();
        let n = g.n;
        let mut visited = vec![false; n];
        let mut order: Vec<i32> = Vec::with_capacity(n);
        let mut nbrs: Vec<i32> = Vec::new();
        for start in 0..n {
            if visited[start] {
                continue;
            }
            // Pseudo-peripheral start for this component (2 BFS sweeps).
            let s = pseudo_peripheral(g, start);
            let head = order.len();
            visited[s] = true;
            order.push(s as i32);
            let mut q = head;
            while q < order.len() {
                let v = order[q] as usize;
                q += 1;
                nbrs.clear();
                nbrs.extend(
                    g.neighbors(v)
                        .iter()
                        .filter(|&&u| !visited[u as usize]),
                );
                // Cuthill–McKee visits neighbors by increasing degree.
                nbrs.sort_by_key(|&u| g.degree(u as usize));
                for &u in &nbrs {
                    if !visited[u as usize] {
                        visited[u as usize] = true;
                        order.push(u);
                    }
                }
            }
        }
        order.reverse();
        let mut r = OrderingResult::new(order);
        r.phases.add("core", t.secs());
        r
    }
}

fn pseudo_peripheral(g: &SymGraph, seed: usize) -> usize {
    let mut v = seed;
    for _ in 0..2 {
        let mut dist = vec![-1i32; g.n];
        let mut queue = std::collections::VecDeque::new();
        dist[v] = 0;
        queue.push_back(v);
        let mut last = v;
        while let Some(x) = queue.pop_front() {
            last = x;
            for &u in g.neighbors(x) {
                if dist[u as usize] == -1 {
                    dist[u as usize] = dist[x] + 1;
                    queue.push_back(u as usize);
                }
            }
        }
        v = last;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{mesh2d, mesh3d, random_graph};
    use crate::ordering::test_support::check_ordering_contract;
    use crate::ordering::{amd_seq::AmdSeq, Ordering as _};
    use crate::symbolic::fill_in;

    #[test]
    fn valid_on_meshes_and_random() {
        for g in [mesh2d(12, 12), random_graph(200, 5, 3)] {
            let r = Rcm.order(&g);
            check_ordering_contract(&g, &r);
        }
    }

    #[test]
    fn handles_disconnected() {
        let g = SymGraph::from_edges(6, &[(0, 1), (3, 4)]);
        let r = Rcm.order(&g);
        check_ordering_contract(&g, &r);
    }

    #[test]
    fn path_graph_is_banded() {
        // RCM on a path gives a bandwidth-1 ordering → zero fill.
        let n = 30;
        let edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
        let g = SymGraph::from_edges(n, &edges);
        let r = Rcm.order(&g);
        assert_eq!(fill_in(&g, &r.perm), 0);
    }

    #[test]
    fn amd_beats_rcm_on_3d_mesh() {
        // The classical result motivating minimum-degree methods.
        let g = mesh3d(8, 8, 8);
        let f_rcm = fill_in(&g, &Rcm.order(&g).perm);
        let f_amd = fill_in(&g, &AmdSeq::default().order(&g).perm);
        assert!(f_amd < f_rcm, "amd {f_amd} vs rcm {f_rcm}");
    }
}
