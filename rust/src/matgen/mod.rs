//! Synthetic matrix/graph generators — the stand-in for the paper's matrix
//! suite (SuiteSparse Matrix Collection + M3E, Table 4.1).
//!
//! AMD's behaviour is driven by sparsity *structure* (mesh dimensionality,
//! degree distribution, separator size), so each generator reproduces the
//! structural family of a paper matrix at laptop scale; [`suite`] names the
//! analogs (`mini_nd24k`, `mini_nlpkkt`, …). See DESIGN.md §2.

pub mod spd;

use crate::graph::csr::{CsrMatrix, SymGraph};
use crate::util::rng::Rng;

pub use spd::{laplacian_matrix, spd_from_graph};

/// 5-point stencil on an `nx × ny` grid (2D mesh problem).
pub fn mesh2d(nx: usize, ny: usize) -> SymGraph {
    let id = |x: usize, y: usize| x * ny + y;
    let mut edges = Vec::with_capacity(2 * nx * ny);
    for x in 0..nx {
        for y in 0..ny {
            if x + 1 < nx {
                edges.push((id(x, y), id(x + 1, y)));
            }
            if y + 1 < ny {
                edges.push((id(x, y), id(x, y + 1)));
            }
        }
    }
    SymGraph::from_edges(nx * ny, &edges)
}

/// 9-point stencil on an `nx × ny` grid (denser 2D mesh; structural FEM-ish).
pub fn mesh2d_9pt(nx: usize, ny: usize) -> SymGraph {
    let id = |x: usize, y: usize| x * ny + y;
    let mut edges = Vec::new();
    for x in 0..nx {
        for y in 0..ny {
            for (dx, dy) in [(1i64, 0i64), (0, 1), (1, 1), (1, -1)] {
                let (xx, yy) = (x as i64 + dx, y as i64 + dy);
                if xx >= 0 && (xx as usize) < nx && yy >= 0 && (yy as usize) < ny {
                    edges.push((id(x, y), id(xx as usize, yy as usize)));
                }
            }
        }
    }
    SymGraph::from_edges(nx * ny, &edges)
}

/// 7-point stencil on an `nx × ny × nz` grid (3D mesh problem — the
/// structural family of nd24k / Flan_1565 / Cube5317k).
pub fn mesh3d(nx: usize, ny: usize, nz: usize) -> SymGraph {
    let id = |x: usize, y: usize, z: usize| (x * ny + y) * nz + z;
    let mut edges = Vec::with_capacity(3 * nx * ny * nz);
    for x in 0..nx {
        for y in 0..ny {
            for z in 0..nz {
                if x + 1 < nx {
                    edges.push((id(x, y, z), id(x + 1, y, z)));
                }
                if y + 1 < ny {
                    edges.push((id(x, y, z), id(x, y + 1, z)));
                }
                if z + 1 < nz {
                    edges.push((id(x, y, z), id(x, y, z + 1)));
                }
            }
        }
    }
    SymGraph::from_edges(nx * ny * nz, &edges)
}

/// 27-point stencil 3D mesh (denser 3D elements, nd24k-like density).
pub fn mesh3d_27pt(nx: usize, ny: usize, nz: usize) -> SymGraph {
    let id = |x: usize, y: usize, z: usize| (x * ny + y) * nz + z;
    let mut edges = Vec::new();
    for x in 0..nx {
        for y in 0..ny {
            for z in 0..nz {
                for dx in -1i64..=1 {
                    for dy in -1i64..=1 {
                        for dz in -1i64..=1 {
                            if (dx, dy, dz) <= (0, 0, 0) {
                                continue; // each undirected edge once
                            }
                            let (xx, yy, zz) =
                                (x as i64 + dx, y as i64 + dy, z as i64 + dz);
                            if xx >= 0
                                && (xx as usize) < nx
                                && yy >= 0
                                && (yy as usize) < ny
                                && zz >= 0
                                && (zz as usize) < nz
                            {
                                edges.push((
                                    id(x, y, z),
                                    id(xx as usize, yy as usize, zz as usize),
                                ));
                            }
                        }
                    }
                }
            }
        }
    }
    SymGraph::from_edges(nx * ny * nz, &edges)
}

/// KKT saddle-point structure `[H  J^T; J  0]` where `H` is a 3D-mesh
/// Hessian over `np` primal variables and `J` couples each of the `nc`
/// constraints to a few primal variables (the nlpkkt240 family).
pub fn kkt(nx: usize, ny: usize, nz: usize, couple: usize, seed: u64) -> SymGraph {
    let h = mesh3d(nx, ny, nz);
    let np = h.n;
    let nc = np / 2;
    let mut rng = Rng::new(seed);
    let mut edges: Vec<(usize, usize)> = Vec::with_capacity(h.nedges() + nc * couple);
    for v in 0..np {
        for &u in h.neighbors(v) {
            if (u as usize) > v {
                edges.push((v, u as usize));
            }
        }
    }
    for c in 0..nc {
        // Constraint c couples a small contiguous window plus a random far
        // variable — reproduces the bipartite KKT coupling pattern.
        let base = (c * 2).min(np - 1);
        for k in 0..couple {
            edges.push((np + c, (base + k) % np));
        }
        edges.push((np + c, rng.below(np)));
    }
    SymGraph::from_edges(np + nc, &edges)
}

/// Erdős–Rényi-ish random symmetric pattern with expected degree `deg`.
pub fn random_graph(n: usize, deg: usize, seed: u64) -> SymGraph {
    let mut rng = Rng::new(seed);
    let m = n * deg / 2;
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let u = rng.below(n);
        let v = rng.below(n);
        if u != v {
            edges.push((u, v));
        }
    }
    SymGraph::from_edges(n, &edges)
}

/// A graph with **known component structure**: `k` connected components
/// where component `i` has exactly `sizes[i % sizes.len()]` vertices
/// (each a near-square 2D grid plus a path tail, so the components are
/// mesh-like at any size). Vertex ids are deterministically scattered
/// across the whole range — component decomposition must not rely on
/// contiguous labels. The shard tests and benches build their inputs
/// here.
pub fn multi_component(k: usize, sizes: &[usize]) -> SymGraph {
    assert!(k > 0, "need at least one component");
    assert!(!sizes.is_empty(), "need at least one size");
    let mut edges = Vec::new();
    let mut base = 0usize;
    for i in 0..k {
        let s = sizes[i % sizes.len()].max(1);
        // Near-square grid core covering most of the component...
        let rows = (s as f64).sqrt() as usize;
        let rows = rows.max(1);
        let cols = s / rows;
        let id = |x: usize, y: usize| base + x * cols + y;
        for x in 0..rows {
            for y in 0..cols {
                if x + 1 < rows {
                    edges.push((id(x, y), id(x + 1, y)));
                }
                if y + 1 < cols {
                    edges.push((id(x, y), id(x, y + 1)));
                }
            }
        }
        // ...and a path tail for the remainder, hung off vertex 0.
        for t in rows * cols..s {
            let prev = if t == rows * cols { base } else { base + t - 1 };
            edges.push((prev, base + t));
        }
        base += s;
    }
    let g = SymGraph::from_edges(base, &edges);
    // Scatter the block labeling with a deterministic permutation.
    let mut rng = Rng::new(0xC0_3B_17 ^ ((k as u64) << 32) ^ base as u64);
    crate::graph::perm::permute_graph(&g, &rng.permutation(base))
}

/// A graph of `k * copies` connected components in which every
/// component shape **repeats exactly `copies` times**: `k` distinct
/// mesh-like archetypes (sizes `n`, `n+1`, …, `n+k-1`, each a near-square
/// grid plus a path tail — the same construction [`multi_component`]
/// uses), each instantiated `copies` times, under deterministically
/// scattered vertex labels. This is the result cache's target workload
/// (batched FEM assembly re-submitting identical components request
/// after request): the *whole-graph* CSR varies with the scatter, but
/// compact component extraction is label-normalizing, so every copy of
/// an archetype yields an identical compact CSR — and identical
/// fingerprints.
pub fn repeated_components(k: usize, n: usize, copies: usize) -> SymGraph {
    repeated_components_seeded(k, n, copies, 0)
}

/// [`repeated_components`] with an explicit scatter seed: different
/// seeds scatter the same component population differently, modeling
/// *distinct requests* that share components (each seed's graph
/// fingerprints differently at request level while every component still
/// extracts — and fingerprints — identically at component level).
pub fn repeated_components_seeded(k: usize, n: usize, copies: usize, seed: u64) -> SymGraph {
    assert!(k > 0 && copies > 0, "need at least one component");
    assert!(n > 0, "components need at least one vertex");
    let mut edges = Vec::new();
    let mut block_start = Vec::with_capacity(k * copies);
    let mut base = 0usize;
    // Copies of one archetype are built consecutively from the same
    // recipe, so their block graphs are identical by construction.
    for arch in 0..k {
        let s = n + arch;
        for _ in 0..copies {
            block_start.push(base);
            let rows = ((s as f64).sqrt() as usize).max(1);
            let cols = s / rows;
            let id = |x: usize, y: usize| base + x * cols + y;
            for x in 0..rows {
                for y in 0..cols {
                    if x + 1 < rows {
                        edges.push((id(x, y), id(x + 1, y)));
                    }
                    if y + 1 < cols {
                        edges.push((id(x, y), id(x, y + 1)));
                    }
                }
            }
            for t in rows * cols..s {
                let prev = if t == rows * cols { base } else { base + t - 1 };
                edges.push((prev, base + t));
            }
            base += s;
        }
    }
    let g = SymGraph::from_edges(base, &edges);

    // Order-preserving interleave: shuffle which global id slots each
    // component occupies, but keep every component's own vertices in
    // increasing order — the way FEM assembly interleaves elements. (A
    // fully random scatter would also permute labels *within* each
    // component, and compact extraction would then yield isomorphic but
    // non-identical CSRs, which is not the workload the cache targets.)
    let count = k * copies;
    let mut owner: Vec<u32> = Vec::with_capacity(base);
    for (c, &start) in block_start.iter().enumerate() {
        let end = block_start.get(c + 1).copied().unwrap_or(base);
        owner.extend(std::iter::repeat(c as u32).take(end - start));
    }
    let mut rng = Rng::new(
        0x2E9E_A7ED ^ ((k as u64) << 40) ^ ((copies as u64) << 20) ^ (base as u64) ^ seed,
    );
    rng.shuffle(&mut owner);
    // perm[pos] = the next unconsumed block vertex of the component that
    // owns global slot `pos` (permute_graph: old `perm[pos]` → new `pos`).
    let mut next = vec![0usize; count];
    let perm: Vec<i32> = owner
        .iter()
        .map(|&c| {
            let c = c as usize;
            let old = block_start[c] + next[c];
            next[c] += 1;
            old as i32
        })
        .collect();
    crate::graph::perm::permute_graph(&g, &perm)
}

/// A graph that is **heavy in indistinguishable (twin) vertices**: a
/// near-square 2D grid over `⌈n/k⌉` classes, blown up so each base
/// vertex becomes a clique of `k` copies and each base edge a complete
/// bipartite coupling between the copies — the structure FEM assembly
/// with `k` degrees of freedom per node produces. Every class is a set
/// of pairwise *true twins* (identical closed neighborhoods), so the
/// reduction layer compresses this graph `k`-fold; vertex ids are
/// deterministically scattered so reducers cannot rely on contiguous
/// class labels. `n` is rounded up to a multiple of `k`.
pub fn twin_heavy(n: usize, k: usize) -> SymGraph {
    assert!(k >= 1, "class size must be positive");
    let classes = crate::util::ceil_div(n.max(1), k);
    let total = classes * k;
    // Base grid over the classes (same shape multi_component uses).
    let rows = ((classes as f64).sqrt() as usize).max(1);
    let cols = crate::util::ceil_div(classes, rows);
    let base_edges: Vec<(usize, usize)> = {
        let id = |x: usize, y: usize| x * cols + y;
        let mut e = Vec::new();
        for x in 0..rows {
            for y in 0..cols {
                let c = id(x, y);
                if c >= classes {
                    continue;
                }
                if x + 1 < rows && id(x + 1, y) < classes {
                    e.push((c, id(x + 1, y)));
                }
                if y + 1 < cols && id(x, y + 1) < classes {
                    e.push((c, id(x, y + 1)));
                }
            }
        }
        e
    };
    let mut edges = Vec::with_capacity(base_edges.len() * k * k + classes * k * (k - 1) / 2);
    for c in 0..classes {
        for i in 0..k {
            for j in i + 1..k {
                edges.push((c * k + i, c * k + j)); // intra-class clique
            }
        }
    }
    for &(a, b) in &base_edges {
        for i in 0..k {
            for j in 0..k {
                edges.push((a * k + i, b * k + j)); // complete bipartite
            }
        }
    }
    let g = SymGraph::from_edges(total, &edges);
    let mut rng = Rng::new(0x7714 ^ ((classes as u64) << 16) ^ k as u64);
    crate::graph::perm::permute_graph(&g, &rng.permutation(total))
}

/// A graph whose vertices are **not twins initially but become twins
/// mid-elimination** — the mid-elimination re-reduction sweep's target
/// workload ([`crate::ordering::reduce::live`]). Classes of `k` members
/// share one class *seed* vertex, every member carries one private
/// *distinguisher* (adjacent only to the member and the seed), and a
/// few global hubs tie the classes together:
///
/// - initially no two vertices share a neighborhood (each member is
///   distinguished by its private distinguisher, each distinguisher by
///   its member, each seed by its class, each hub by the one seed it
///   additionally touches);
/// - the first waves eliminate the distinguishers (degree 2, the
///   minimum): `x_i`'s element is `{m_i, seed}`. The seeds go next;
///   because the seed's own weight is counted in `x_i`'s element's
///   degree, that element keeps a phantom external degree at the
///   seed's elimination and is **not** absorbed locally — every member
///   leaves the wave holding the class element plus a private element
///   whose only *live* vertex is itself. The per-pivot supervariable
///   detection can therefore never merge the members (their element
///   lists always differ, and no later pivot holds two members until
///   the hubs go). Only the global sweep sees that each private
///   element's live list is a subset of the class element, absorbs it,
///   and collapses the members of each class into one supervariable;
/// - the hubs (degree ≈ total members) cross the dense threshold
///   mid-run once enough of the graph has been eliminated.
///
/// `n` is a target total vertex count (rounded to the class grid);
/// `k ≥ 2` is the class size — keep `k ≤ 4` so the seed wave strictly
/// precedes the member wave. Vertex ids are deterministically scattered.
pub fn emergent_twins(n: usize, k: usize) -> SymGraph {
    const HUBS: usize = 3;
    let k = k.max(2);
    // Per class: k members + k distinguishers + 1 seed.
    let per = 2 * k + 1;
    let classes = crate::util::ceil_div(n.max(per + HUBS).saturating_sub(HUBS), per).max(HUBS);
    let total = classes * per + HUBS;
    let member = |c: usize, i: usize| c * per + i;
    let distinguisher = |c: usize, i: usize| c * per + k + i;
    let seed_of = |c: usize| c * per + 2 * k;
    let hub = |j: usize| classes * per + j;
    let mut edges = Vec::with_capacity(classes * k * (3 + HUBS) + HUBS);
    for c in 0..classes {
        for i in 0..k {
            edges.push((member(c, i), distinguisher(c, i)));
            edges.push((distinguisher(c, i), seed_of(c)));
            edges.push((member(c, i), seed_of(c)));
            for j in 0..HUBS {
                edges.push((member(c, i), hub(j)));
            }
        }
    }
    for j in 0..HUBS {
        // Touching one distinct seed keeps the hubs from being twins of
        // each other at time zero.
        edges.push((hub(j), seed_of(j)));
    }
    let g = SymGraph::from_edges(total, &edges);
    let mut rng = Rng::new(0xE41C ^ ((classes as u64) << 16) ^ k as u64);
    crate::graph::perm::permute_graph(&g, &rng.permutation(total))
}

/// A 2D mesh of `n` vertices plus `count` **dense rows**: extra vertices
/// each coupled to `d` distinct mesh vertices (deterministic
/// pseudo-random placement). Exercises the reduction layer's dense-row
/// postponement — with the default `α = 10` threshold the injected rows
/// only qualify when `d > max(16, 10·√n)`.
pub fn with_dense_rows(n: usize, d: usize, count: usize) -> SymGraph {
    assert!(d <= n, "a dense row cannot couple to more than n vertices");
    let rows = ((n as f64).sqrt() as usize).max(1);
    let cols = crate::util::ceil_div(n, rows);
    let id = |x: usize, y: usize| x * cols + y;
    let mut edges = Vec::new();
    for x in 0..rows {
        for y in 0..cols {
            let v = id(x, y);
            if v >= n {
                continue;
            }
            if x + 1 < rows && id(x + 1, y) < n {
                edges.push((v, id(x + 1, y)));
            }
            if y + 1 < cols && id(x, y + 1) < n {
                edges.push((v, id(x, y + 1)));
            }
        }
    }
    let mut rng = Rng::new(0xDE52 ^ ((n as u64) << 8) ^ count as u64);
    let mut picked = vec![false; n];
    for c in 0..count {
        let row = n + c;
        let mut remaining = d;
        for p in picked.iter_mut() {
            *p = false;
        }
        while remaining > 0 {
            let v = rng.below(n);
            if !picked[v] {
                picked[v] = true;
                edges.push((row, v));
                remaining -= 1;
            }
        }
    }
    SymGraph::from_edges(n + count, &edges)
}

/// A nonsymmetric CFD-like matrix (HV15R family): a 3D mesh pattern with
/// one-directional "convection" arcs added, returned as a general
/// [`CsrMatrix`] so the `|A|+|A^T|` pre-processing path is exercised.
pub fn nonsymmetric_flow(nx: usize, ny: usize, nz: usize, seed: u64) -> CsrMatrix {
    let g = mesh3d(nx, ny, nz);
    let mut rng = Rng::new(seed);
    let mut trip: Vec<(usize, usize, f64)> = Vec::with_capacity(g.nnz() + g.n * 2);
    for v in 0..g.n {
        trip.push((v, v, 8.0));
        for &u in g.neighbors(v) {
            // Keep ~70% of the off-diagonal arcs, direction-dependent.
            if rng.chance(0.7) {
                trip.push((v, u as usize, -1.0));
            }
        }
        // Downstream convection arc (one-directional).
        if v + ny * nz < g.n && rng.chance(0.5) {
            trip.push((v, v + ny * nz, -0.25));
        }
    }
    CsrMatrix::from_triplets(g.n, g.n, &trip)
}

/// A named matrix in the evaluation suite.
pub struct SuiteEntry {
    /// Analog name (`mini_<paper matrix>`).
    pub name: &'static str,
    /// The paper matrix this stands in for.
    pub paper_name: &'static str,
    /// Structural family description.
    pub family: &'static str,
    /// Whether the pattern is symmetric (Table 4.1 column).
    pub symmetric: bool,
    /// Generator.
    pub gen: fn(Scale) -> SymGraph,
}

/// Global size multiplier for the suite (small for tests, large for benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// ~1–4k vertices per matrix: unit/integration tests.
    Tiny,
    /// ~10–40k vertices: default benchmark scale.
    Small,
    /// ~60–250k vertices: the headline benchmark scale.
    Full,
}

impl Scale {
    fn pick(self, tiny: usize, small: usize, full: usize) -> usize {
        match self {
            Scale::Tiny => tiny,
            Scale::Small => small,
            Scale::Full => full,
        }
    }
}

/// The named analog suite, ordered like the paper's Table 4.1 (by density /
/// structural family). See DESIGN.md §2 for the substitution rationale.
pub fn suite() -> Vec<SuiteEntry> {
    vec![
        SuiteEntry {
            name: "mini_nd24k",
            paper_name: "nd24k",
            family: "dense 3D mesh (27-pt)",
            symmetric: true,
            gen: |s| {
                let k = s.pick(8, 16, 28);
                mesh3d_27pt(k, k, k)
            },
        },
        SuiteEntry {
            name: "mini_ldoor",
            paper_name: "ldoor",
            family: "thin structural shell (9-pt 2D)",
            symmetric: true,
            gen: |s| {
                let k = s.pick(16, 64, 160);
                mesh2d_9pt(4 * k, k)
            },
        },
        SuiteEntry {
            name: "mini_serena",
            paper_name: "Serena",
            family: "3D structural mesh (7-pt)",
            symmetric: true,
            gen: |s| {
                let k = s.pick(10, 24, 44);
                mesh3d(k, k, k)
            },
        },
        SuiteEntry {
            name: "mini_flan",
            paper_name: "Flan_1565",
            family: "3D structural mesh (27-pt, elongated)",
            symmetric: true,
            gen: |s| {
                let k = s.pick(6, 12, 20);
                mesh3d_27pt(4 * k, k, k)
            },
        },
        SuiteEntry {
            name: "mini_hv15r",
            paper_name: "HV15R",
            family: "nonsymmetric CFD (sym. pre-processing path)",
            symmetric: false,
            gen: |s| {
                let k = s.pick(9, 20, 36);
                let a = nonsymmetric_flow(k, k, k, 0x4815);
                crate::graph::symmetrize(&a)
            },
        },
        SuiteEntry {
            name: "mini_queen",
            paper_name: "Queen_4147",
            family: "large 3D structural mesh",
            symmetric: true,
            gen: |s| {
                let k = s.pick(11, 26, 48);
                mesh3d(k, k, k)
            },
        },
        SuiteEntry {
            name: "mini_nlpkkt",
            paper_name: "nlpkkt240",
            family: "KKT saddle-point (optimization)",
            symmetric: true,
            gen: |s| {
                let k = s.pick(8, 20, 36);
                kkt(k, k, k, 3, 0x240)
            },
        },
    ]
}

/// Look up a suite entry by analog name.
pub fn suite_entry(name: &str) -> Option<SuiteEntry> {
    suite().into_iter().find(|e| e.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh2d_structure() {
        let g = mesh2d(3, 3);
        g.validate().unwrap();
        assert_eq!(g.n, 9);
        assert_eq!(g.nedges(), 12); // 2*3*2 horizontal + vertical
        assert_eq!(g.degree(4), 4); // center of 3x3
        assert_eq!(g.degree(0), 2); // corner
    }

    #[test]
    fn mesh3d_structure() {
        let g = mesh3d(3, 3, 3);
        g.validate().unwrap();
        assert_eq!(g.n, 27);
        assert_eq!(g.nedges(), 3 * 3 * 3 * 2); // 3 directions * 2*9 each = 54
        assert_eq!(g.degree(13), 6); // center
    }

    #[test]
    fn mesh3d_27pt_center_degree() {
        let g = mesh3d_27pt(3, 3, 3);
        g.validate().unwrap();
        assert_eq!(g.degree(13), 26);
    }

    #[test]
    fn mesh2d_9pt_center_degree() {
        let g = mesh2d_9pt(3, 3);
        g.validate().unwrap();
        assert_eq!(g.degree(4), 8);
    }

    #[test]
    fn kkt_is_saddle_shaped() {
        let g = kkt(4, 4, 4, 3, 1);
        g.validate().unwrap();
        let np = 64;
        // Constraint rows only touch primal variables (no constraint-constraint edges).
        for c in np..g.n {
            for &u in g.neighbors(c) {
                assert!((u as usize) < np, "constraint-constraint edge");
            }
        }
    }

    #[test]
    fn multi_component_has_exactly_the_requested_structure() {
        use crate::graph::components::connected_components;
        let g = multi_component(5, &[7, 12, 1]);
        g.validate().unwrap();
        assert_eq!(g.n, 7 + 12 + 1 + 7 + 12);
        let c = connected_components(&g);
        assert_eq!(c.count, 5);
        let mut sizes = c.sizes.clone();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![1, 7, 7, 12, 12]);
    }

    #[test]
    fn multi_component_is_deterministic() {
        let a = multi_component(3, &[20, 9]);
        let b = multi_component(3, &[20, 9]);
        assert_eq!(a, b);
    }

    #[test]
    fn multi_component_single_is_connected() {
        use crate::graph::components::connected_components;
        let g = multi_component(1, &[30]);
        assert_eq!(connected_components(&g).count, 1);
        assert_eq!(g.n, 30);
    }

    #[test]
    fn repeated_components_extracts_identical_copies() {
        use crate::graph::components::{connected_components, split_components};
        let g = repeated_components(3, 20, 4);
        g.validate().unwrap();
        assert_eq!(g.n, 4 * (20 + 21 + 22));
        let c = connected_components(&g);
        assert_eq!(c.count, 12);
        let parts = split_components(&g, &c);
        // Component ids ascend by size, so the 4 copies of each
        // archetype are adjacent — and must extract to *identical*
        // compact CSRs (not merely isomorphic ones).
        for arch in 0..3 {
            let first = &parts[arch * 4].graph;
            assert_eq!(first.n, 20 + arch);
            for copy in 1..4 {
                assert_eq!(
                    &parts[arch * 4 + copy].graph, first,
                    "copy {copy} of archetype {arch} must extract identically"
                );
            }
        }
    }

    #[test]
    fn repeated_components_seeds_scatter_requests_but_share_components() {
        use crate::graph::components::{connected_components, split_components};
        let a = repeated_components_seeded(2, 15, 2, 1);
        let b = repeated_components_seeded(2, 15, 2, 2);
        assert_ne!(a, b, "different seeds must scatter differently");
        let pa = split_components(&a, &connected_components(&a));
        let pb = split_components(&b, &connected_components(&b));
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x.graph, y.graph, "components must match across requests");
        }
        assert_eq!(
            repeated_components(2, 15, 2),
            repeated_components(2, 15, 2),
            "deterministic"
        );
    }

    #[test]
    fn twin_heavy_has_exact_twin_classes() {
        let g = twin_heavy(60, 4);
        g.validate().unwrap();
        assert_eq!(g.n, 60, "60 is already a multiple of 4");
        // Every vertex has exactly k-1 twins: vertices with identical
        // closed neighborhoods.
        let closed = |v: usize| {
            let mut s: Vec<i32> = g.neighbors(v).to_vec();
            s.push(v as i32);
            s.sort_unstable();
            s
        };
        for v in 0..g.n {
            let mine = closed(v);
            let twins = (0..g.n)
                .filter(|&u| u != v && closed(u) == mine)
                .count();
            assert_eq!(twins, 3, "vertex {v} must have exactly 3 true twins");
        }
    }

    #[test]
    fn twin_heavy_rounds_up_and_stays_connected() {
        use crate::graph::components::connected_components;
        let g = twin_heavy(50, 4); // rounds to 52
        g.validate().unwrap();
        assert_eq!(g.n, 52);
        assert_eq!(connected_components(&g).count, 1);
        assert_eq!(twin_heavy(50, 4), twin_heavy(50, 4), "deterministic");
    }

    #[test]
    fn emergent_twins_has_no_initial_twins() {
        let g = emergent_twins(120, 3);
        g.validate().unwrap();
        // No two vertices may share an open OR a closed neighborhood:
        // the twins only *emerge* once elimination starts.
        let open = |v: usize| {
            let mut s: Vec<i32> = g.neighbors(v).to_vec();
            s.sort_unstable();
            s
        };
        let closed = |v: usize| {
            let mut s: Vec<i32> = g.neighbors(v).to_vec();
            s.push(v as i32);
            s.sort_unstable();
            s
        };
        let mut opens: Vec<Vec<i32>> = (0..g.n).map(open).collect();
        opens.sort_unstable();
        opens.dedup();
        assert_eq!(opens.len(), g.n, "open-neighborhood (false) twins exist");
        let mut closeds: Vec<Vec<i32>> = (0..g.n).map(closed).collect();
        closeds.sort_unstable();
        closeds.dedup();
        assert_eq!(closeds.len(), g.n, "closed-neighborhood (true) twins exist");
    }

    #[test]
    fn emergent_twins_is_connected_and_deterministic() {
        use crate::graph::components::connected_components;
        let g = emergent_twins(150, 3);
        g.validate().unwrap();
        assert_eq!(connected_components(&g).count, 1);
        assert_eq!(emergent_twins(150, 3), emergent_twins(150, 3));
        // Degree structure: distinguishers (2, the strict minimum —
        // they form the first elimination wave), members (5), seeds
        // (2k or 2k+1), hubs (≈ member count).
        let mut degs: Vec<usize> = (0..g.n).map(|v| g.degree(v)).collect();
        degs.sort_unstable();
        assert_eq!(degs[0], 2, "distinguishers lead the degree order");
        assert!(*degs.last().unwrap() > g.n / 4, "hubs see every member");
    }

    #[test]
    fn with_dense_rows_injects_rows_of_requested_degree() {
        let g = with_dense_rows(100, 40, 3);
        g.validate().unwrap();
        assert_eq!(g.n, 103);
        for r in 100..103 {
            assert_eq!(g.degree(r), 40, "dense row {r} degree");
            // Dense rows couple only to base vertices.
            assert!(g.neighbors(r).iter().all(|&u| (u as usize) < 100));
        }
        // Base mesh vertices stay sparse.
        let max_base = (0..100).map(|v| g.degree(v)).max().unwrap();
        assert!(max_base <= 4 + 3, "base degree {max_base} too high");
        assert_eq!(
            with_dense_rows(100, 40, 3),
            with_dense_rows(100, 40, 3),
            "deterministic"
        );
    }

    #[test]
    fn random_graph_valid() {
        let g = random_graph(500, 8, 3);
        g.validate().unwrap();
        assert!(g.nedges() > 500);
    }

    #[test]
    fn nonsymmetric_flow_is_nonsymmetric() {
        let a = nonsymmetric_flow(5, 5, 5, 7);
        assert!(!a.is_pattern_symmetric());
        let g = crate::graph::symmetrize(&a);
        g.validate().unwrap();
    }

    #[test]
    fn suite_generates_at_tiny_scale() {
        for e in suite() {
            let g = (e.gen)(Scale::Tiny);
            g.validate().unwrap();
            assert!(g.n >= 256, "{} too small: {}", e.name, g.n);
            assert!(g.n <= 100_000, "{} too large for tiny: {}", e.name, g.n);
        }
    }

    #[test]
    fn suite_lookup() {
        assert!(suite_entry("mini_nd24k").is_some());
        assert!(suite_entry("nope").is_none());
    }
}
