//! Overload behavior: what a shed costs versus what service costs, and
//! what quality shedding buys under pressure.
//!
//! Two measurements:
//!
//! - **shed cost** — a service pinned at `max_inflight = 1` while a
//!   large request occupies its only scheduler; `try_submit` must
//!   answer each excess request immediately with a structured
//!   rejection. Reported as nanoseconds per shed — the price of saying
//!   no, which must stay microseconds-scale so admission control can
//!   front a hot loop.
//! - **quality shed throughput** — a many-small-components request
//!   ordered at full quality (reduction, sweeps, per-component shard
//!   dispatch) versus under `shed_quality` (sweeps off, small
//!   components inline through sequential AMD). The degraded path
//!   trades fill quality for latency; this prints what that trade buys.
//!
//! Writes the JSON trajectory file `BENCH_overload_shed.json`
//! (override with `PARAMD_BENCH_OVERLOAD_OUT`; default lands in the
//! repository root when run via `cargo bench` from `rust/`).
//!
//! Knobs: `PARAMD_THREADS` (default 8), `PARAMD_REPS` (default 10), or
//! `--smoke` for a quick CI pass.

#[path = "bench_common/mod.rs"]
#[allow(dead_code)] // shared helper module; this bench uses a subset
mod bench_common;

use paramd::coordinator::{Method, OrderRequest, Service};
use paramd::graph::csr::SymGraph;
use paramd::matgen::{mesh2d, multi_component};
use paramd::util::timer::Timer;

fn paramd_req(g: SymGraph) -> OrderRequest {
    OrderRequest {
        matrix: None,
        pattern: Some(g),
        method: Method::ParAmd {
            threads: 4,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    }
}

fn main() {
    bench_common::banner(
        "Overload — admission shed cost and quality-shed throughput",
        "ISSUE 9 robustness subsystem; not a paper table",
    );
    let smoke = std::env::args().any(|a| a == "--smoke");
    let threads = bench_common::threads();
    let reps: usize = if smoke {
        3
    } else {
        std::env::var("PARAMD_REPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(10)
    };
    let shed_reps = if smoke { 200 } else { 5000 };

    // Shed cost: one scheduler, in-flight budget 1, and a blocker big
    // enough to hold the budget for the whole measurement loop — every
    // try_submit below must shed immediately.
    let guarded = Service::new(2).with_scheduler_threads(1).with_max_inflight(1);
    let blocker_side = if smoke { 100 } else { 250 };
    let blocker = guarded.submit(paramd_req(mesh2d(blocker_side, blocker_side)));
    let tiny = mesh2d(8, 8);
    let t = Timer::new();
    let mut sheds = 0usize;
    for _ in 0..shed_reps {
        // An accepted ticket (possible once the blocker resolves late
        // in the loop) is dropped, which cancels it — never waited.
        if guarded.try_submit(paramd_req(tiny.clone())).is_err() {
            sheds += 1;
        }
    }
    let shed_ns = t.secs() * 1e9 / shed_reps.max(1) as f64;
    let rep = blocker.wait_result().expect("blocker must complete");
    assert!(!rep.perm.is_empty());
    drop(guarded);

    // Quality shed throughput: the same many-small-components request
    // at full quality vs under shed (threshold 0 = shed every request).
    let comps = if smoke { 8 } else { 32 };
    let g = multi_component(comps, &[300, 500, 800]);
    let full = Service::new(2)
        .with_shards(2)
        .with_order_threads(threads)
        .with_result_cache(0);
    full.order(&paramd_req(g.clone())); // warm arenas
    let t = Timer::new();
    for _ in 0..reps {
        let rep = full.order(&paramd_req(g.clone()));
        assert_eq!(rep.perm.len(), g.n);
    }
    let full_secs = t.secs() / reps as f64;
    drop(full);

    let degraded = Service::new(2)
        .with_shards(2)
        .with_order_threads(threads)
        .with_result_cache(0)
        .with_shed_quality(true)
        .with_shed_threshold(0);
    degraded.order(&paramd_req(g.clone()));
    let t = Timer::new();
    for _ in 0..reps {
        let rep = degraded.order(&paramd_req(g.clone()));
        assert_eq!(rep.perm.len(), g.n);
    }
    let shed_secs = t.secs() / reps as f64;
    let m = degraded.metrics();
    let speedup = full_secs / shed_secs.max(1e-12);

    println!("{:<22} {:>14}", "measurement", "value");
    println!("{:<22} {:>11.0} ns", "cost per shed", shed_ns);
    println!("{:<22} {:>12.5}s", "full quality", full_secs);
    println!("{:<22} {:>12.5}s", "shed quality", shed_secs);
    println!("{:<22} {:>13.2}x", "degraded speedup", speedup);
    println!(
        "sheds: admission={sheds} sequential={} rereduce={} hybrid={}",
        m.shards.shed_sequential, m.shards.shed_rereduce, m.shards.shed_hybrid
    );
    if shed_ns > 50_000.0 {
        eprintln!("WARNING: shed cost {shed_ns:.0}ns above the 50us bar");
    }

    let out = std::env::var("PARAMD_BENCH_OVERLOAD_OUT")
        .unwrap_or_else(|_| "../BENCH_overload_shed.json".into());
    let json = format!(
        "{{\n  \"bench\": \"overload_shed\",\n  \"status\": \"measured\",\n  \
         \"threads\": {threads},\n  \"reps\": {reps},\n  \
         \"workload\": \"multi_component({comps}, [300, 500, 800])\",\n  \
         \"acceptance\": \"shed answers in microseconds; degraded mode never slower\",\n  \
         \"shed_cost_ns\": {shed_ns:.1},\n  \
         \"admission_sheds\": {sheds},\n  \
         \"full_quality_secs\": {full_secs:.6},\n  \
         \"shed_quality_secs\": {shed_secs:.6},\n  \
         \"degraded_speedup\": {speedup:.3},\n  \
         \"shed_sequential\": {},\n  \"shed_rereduce\": {}\n}}\n",
        m.shards.shed_sequential, m.shards.shed_rereduce
    );
    std::fs::write(&out, &json).expect("write bench json");
    println!("\nwrote {out}");
}
