//! Repeated-ordering throughput: cold vs warm ParAMD.
//!
//! Cold = the seed behavior: every `order()` spawns a fresh thread pool
//! and allocates every O(n)/O(nnz) array. Warm = one persistent
//! `OrderingRuntime` plus one pooled `ParAmdArena` reused across
//! requests. Reports orders/sec for both and writes the JSON trajectory
//! file `BENCH_paramd_throughput.json` (override with
//! `PARAMD_BENCH_OUT`; default lands in the repository root when run via
//! `cargo bench` from `rust/`).
//!
//! Knobs: `PARAMD_THREADS` (default 8), `PARAMD_REPS` (default 20), or
//! `--smoke` for a quick compile-and-run-once CI pass.

#[path = "bench_common/mod.rs"]
#[allow(dead_code)] // shared helper module; this bench uses a subset
mod bench_common;

use paramd::graph::csr::SymGraph;
use paramd::matgen::{mesh2d, mesh3d, random_graph};
use paramd::ordering::paramd::arena::ParAmdArena;
use paramd::ordering::paramd::runtime::OrderingRuntime;
use paramd::ordering::paramd::ParAmd;
use paramd::ordering::Ordering as _;
use paramd::util::timer::Timer;

fn main() {
    bench_common::banner(
        "ParAMD repeated-ordering throughput — cold vs warm",
        "ROADMAP warm-path PR; not a paper table",
    );
    let t = bench_common::threads();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let reps: usize = if smoke {
        2
    } else {
        std::env::var("PARAMD_REPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(20)
    };
    let graphs: Vec<(&str, SymGraph)> = vec![
        ("mesh2d_60x60", mesh2d(60, 60)),
        ("mesh3d_14", mesh3d(14, 14, 14)),
        ("random_5k_d8", random_graph(5000, 8, 42)),
    ];

    println!(
        "{:<14} {:>8} {:>10} {:>14} {:>14} {:>9}",
        "graph", "n", "nnz", "cold ord/s", "warm ord/s", "speedup"
    );
    let mut rows: Vec<String> = Vec::new();
    for (name, g) in &graphs {
        let cfg = ParAmd::new(t);

        // Cold: per-request pool spawn + fresh allocations (seed behavior).
        let tc = Timer::new();
        for _ in 0..reps {
            let r = cfg.order(g);
            assert_eq!(r.perm.len(), g.n);
        }
        let cold = reps as f64 / tc.secs();

        // Warm: persistent pool + pooled arena; first run sizes the arena.
        let rt = OrderingRuntime::new(t);
        let mut arena = ParAmdArena::new();
        cfg.order_into(&rt, &mut arena, g);
        let tw = Timer::new();
        for _ in 0..reps {
            let r = cfg.order_into(&rt, &mut arena, g);
            assert_eq!(r.perm.len(), g.n);
        }
        let warm = reps as f64 / tw.secs();
        let speedup = warm / cold;

        println!(
            "{name:<14} {:>8} {:>10} {cold:>14.2} {warm:>14.2} {speedup:>8.2}x",
            g.n,
            g.nnz()
        );
        rows.push(format!(
            "    {{\"graph\": \"{name}\", \"n\": {}, \"nnz\": {}, \"threads\": {t}, \
             \"reps\": {reps}, \"cold_orders_per_sec\": {cold:.3}, \
             \"warm_orders_per_sec\": {warm:.3}, \"warm_speedup\": {speedup:.3}, \
             \"arena_grow_events\": {}}}",
            g.n,
            g.nnz(),
            arena.grow_events()
        ));
    }

    let out = std::env::var("PARAMD_BENCH_OUT")
        .unwrap_or_else(|_| "../BENCH_paramd_throughput.json".into());
    let json = format!(
        "{{\n  \"bench\": \"paramd_throughput\",\n  \"status\": \"measured\",\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write(&out, &json).expect("write bench json");
    println!("\nwrote {out}");
}
