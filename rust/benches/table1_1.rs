//! Table 1.1: the motivation — sequential AMD ordering time compared to
//! the time a (fast, improving) direct solver takes on the reordered
//! system. The paper used cuSolverSp/cuDSS on an A100; our stand-in is
//! the three-layer solver (Rust sparse factor + PJRT dense tail).

#[path = "bench_common/mod.rs"]
mod bench_common;

use paramd::bench_util::Table;
use paramd::cholesky::{factor, residual, solve, DenseTail};
use paramd::graph::symmetrize;
use paramd::matgen::{self, spd_from_graph};
use paramd::ordering::{amd_seq::AmdSeq, Ordering as _};
use paramd::runtime::{PjrtDense, PjrtEngine};
use paramd::util::timer::Timer;

fn main() {
    bench_common::banner("Table 1.1 — AMD vs solver time", "paper §1 Table 1.1");
    let engine = PjrtEngine::load_default().expect("run `make artifacts` first");
    let dense = PjrtDense { engine: &engine };
    let mut table = Table::new(&["Matrix", "AMD (s)", "Solver (s)", "residual"]);
    for e in matgen::suite() {
        if !e.symmetric {
            continue;
        }
        let g = (e.gen)(bench_common::scale());
        let a = spd_from_graph(&g, 1.0);
        let gs = symmetrize(&a);
        let t = Timer::new();
        let ord = AmdSeq::default().order(&gs);
        let amd_secs = t.secs();
        let t = Timer::new();
        let f = factor(
            &a,
            &ord.perm,
            DenseTail::Auto {
                max: 256,
                min_density: 0.5,
            },
            &dense,
        )
        .unwrap();
        let b = vec![1.0; a.nrows];
        let x = solve(&f, &b);
        let solver_secs = t.secs();
        table.row(vec![
            e.name.into(),
            format!("{amd_secs:.3}"),
            format!("{solver_secs:.3}"),
            format!("{:.1e}", residual(&a, &x, &b)),
        ]);
    }
    table.print();
    println!(
        "\npaper (A100/cuDSS): AMD 0.82–13.94s vs solve 1.97–43.9s — ordering is a\n\
         growing fraction of end-to-end time as solvers improve; same shape here\n\
         (ordering within a small factor of the full solve)."
    );
}
