//! Per-thread workspaces and work counters.
//!
//! Each thread carries its own `w`/`wflg` timestamp array for the
//! Algorithm 2.1 degree scan — the paper's O(nt) memory term — plus
//! scratch buffers, an RNG stream for Luby priorities, and the per-round
//! per-phase work counters that feed the critical-path cost model
//! (DESIGN.md §7).

use crate::util::rng::Rng;

/// Work counters for one thread in one round.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundWork {
    /// Words touched during candidate collection + Luby selection.
    pub select: u64,
    /// Words touched during pivot elimination (core AMD).
    pub elim: u64,
    /// Pivots this thread eliminated this round.
    pub pivots: u32,
}

/// Per-thread mutable state.
pub struct Workspace {
    pub tid: usize,
    /// Timestamp array shared between "v ∈ L_me" marking and element
    /// weights (disjoint id spaces), like the sequential engine.
    pub w: Vec<u64>,
    pub wflg: u64,
    n: usize,
    /// Epoch stride: the largest value a run may add to a mark (an
    /// element weight is bounded by the quotient graph's total column
    /// weight). `n` for unweighted runs; raised via
    /// [`Self::set_epoch_stride`] when seed supervariables push weighted
    /// degrees past `n`.
    stride: u64,
    /// Scratch for building L_me; the mid-elimination sweep
    /// ([`crate::ordering::reduce::live`]) borrows it for element
    /// member lists between rounds, when no pivot owns it.
    pub lme: Vec<i32>,
    /// Scratch for candidate collection.
    pub candidates: Vec<i32>,
    /// Scratch for the pivots this thread won this round.
    pub my_pivots: Vec<i32>,
    /// Scratch for neighborhood enumeration.
    pub nbrs: Vec<i32>,
    /// Per-round cache of candidate neighborhoods (flat CSR layout),
    /// filled by the Luby reset phase and reused by min/validate.
    pub nbr_buf: Vec<i32>,
    pub nbr_ptr: Vec<usize>,
    /// Per-round Luby priorities, aligned with `candidates` (reused across
    /// rounds instead of a fresh `Vec<u64>` per round).
    pub prios: Vec<u64>,
    /// Luby priority RNG.
    pub rng: Rng,
    /// Per-round work log (indexed by round).
    pub work_log: Vec<RoundWork>,
    /// Scratch for supervariable hashing: (hash, var). Also reused by
    /// the mid-elimination sweep's dense-candidate sort.
    pub hash_scratch: Vec<(u64, i32)>,
}

impl Workspace {
    pub fn new(tid: usize, n: usize, seed: u64) -> Self {
        Self {
            tid,
            w: vec![0u64; n],
            wflg: 1,
            n,
            stride: n as u64,
            lme: Vec::new(),
            candidates: Vec::new(),
            my_pivots: Vec::new(),
            nbrs: Vec::new(),
            nbr_buf: Vec::new(),
            nbr_ptr: Vec::new(),
            prios: Vec::new(),
            rng: Rng::new(seed ^ (tid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            work_log: Vec::new(),
            hash_scratch: Vec::new(),
        }
    }

    /// Re-initialize for a fresh run over a graph of `n` vertices, reusing
    /// every buffer that still fits (the arena's warm path). The `w`
    /// timestamp array is reset by **epoch bumping**: the mark floor jumps
    /// past any value a previous run could have stored (`≤ wflg + w.len()`),
    /// so its O(n) contents are never rewritten. Returns 1 if `w` grew.
    pub fn reset(&mut self, n: usize, seed: u64) -> u32 {
        // Jump past anything the previous run stored: its marks advanced
        // by at most its stride per epoch.
        self.wflg += self.stride.max(self.w.len().max(n) as u64) + 2;
        let mut grew = 0;
        if self.w.len() < n {
            self.w.resize(n, 0);
            grew = 1;
        }
        self.n = n;
        self.stride = n as u64;
        self.rng = Rng::new(seed ^ (self.tid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        self.lme.clear();
        self.candidates.clear();
        self.my_pivots.clear();
        self.nbrs.clear();
        self.nbr_buf.clear();
        self.nbr_ptr.clear();
        self.prios.clear();
        self.work_log.clear();
        self.hash_scratch.clear();
        grew
    }

    /// Raise the epoch stride to the run's total column weight so
    /// weighted element degrees (`mark + degree ≤ mark + weight`) can
    /// never collide with the next epoch. Call right after
    /// [`Self::reset`], before the first [`Self::bump_epoch`].
    pub fn set_epoch_stride(&mut self, weight: usize) {
        self.stride = self.stride.max(weight as u64);
    }

    /// Start a fresh mark epoch, advanced past any stored weight
    /// (`mark + degree ≤ mark + stride`) to avoid epoch collisions.
    #[inline]
    pub fn bump_epoch(&mut self) -> u64 {
        self.wflg += self.stride.max(self.n as u64) + 2;
        self.wflg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epochs_never_collide_with_stored_weights() {
        let mut ws = Workspace::new(0, 100, 7);
        let m1 = ws.bump_epoch();
        // Largest value stored under epoch m1 is m1 + n.
        let stored = m1 + 100;
        let m2 = ws.bump_epoch();
        assert!(m2 > stored);
    }

    #[test]
    fn reset_bumps_epoch_past_stale_marks() {
        let mut ws = Workspace::new(2, 50, 9);
        let mark = ws.bump_epoch();
        ws.w[10] = mark + 50; // largest value a run can store
        let stale = ws.w[10];
        assert_eq!(ws.reset(50, 9), 0, "same-size reset must not grow");
        assert!(ws.wflg > stale, "stale w entries must read as expired");
        // Shrinking then regrowing keeps the invariant too.
        ws.reset(8, 9);
        let stale_small = ws.wflg + 8;
        assert_eq!(ws.reset(120, 9), 1, "larger graph must grow w");
        assert!(ws.wflg > stale_small);
    }

    #[test]
    fn weighted_stride_keeps_epochs_apart() {
        let mut ws = Workspace::new(0, 10, 3);
        ws.set_epoch_stride(500); // weighted run: degrees up to 500
        let m1 = ws.bump_epoch();
        ws.w[3] = m1 + 500; // largest weighted element mark
        let m2 = ws.bump_epoch();
        assert!(m2 > m1 + 500, "next epoch must clear weighted marks");
        // A reset after a weighted run must also clear them.
        ws.w[4] = m2 + 500;
        let stale = ws.w[4];
        ws.reset(10, 3);
        assert!(ws.wflg > stale, "reset must jump the weighted stride");
    }

    #[test]
    fn reset_restores_seeded_rng_stream() {
        let mut a = Workspace::new(1, 16, 77);
        let first = a.rng.next_u64();
        let _ = a.rng.next_u64();
        a.reset(16, 77);
        assert_eq!(a.rng.next_u64(), first, "reset must re-seed the stream");
    }

    #[test]
    fn rng_streams_differ_by_tid() {
        let mut a = Workspace::new(0, 8, 42);
        let mut b = Workspace::new(1, 8, 42);
        assert_ne!(a.rng.next_u64(), b.rng.next_u64());
    }
}
