//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them from
//! the Rust request path (Python never runs here).
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. One compiled executable per artifact
//! size; the engine picks the smallest size ≥ the request and pads.
//!
//! ## Feature gate
//!
//! The real backend needs the image-local `xla` crate, which is not on
//! crates.io; it compiles only with the **`pjrt` feature** enabled (add
//! the vendored `xla` crate as a path dependency first). The default
//! build ships an API-identical stub whose loaders return an error, so
//! every caller — the coordinator's solver thread, the paper-table
//! benches — compiles and degrades gracefully to the native engine.

/// Kinds of artifacts emitted by `python/compile/aot.py`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ArtifactKind {
    /// `cholesky_factor`: `(f64[n,n]) -> (f64[n,n],)`.
    Chol,
    /// `cholesky_solve`: `(f64[n,n], f64[n]) -> (f64[n],)`.
    Solve,
}

impl ArtifactKind {
    #[cfg(feature = "pjrt")]
    fn parse(s: &str) -> Option<Self> {
        match s {
            "chol" => Some(Self::Chol),
            "solve" => Some(Self::Solve),
            _ => None,
        }
    }
}

/// Default artifact directory: `$PARAMD_ARTIFACTS` or `./artifacts`.
fn default_artifacts_dir() -> String {
    std::env::var("PARAMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

#[cfg(feature = "pjrt")]
mod backend {
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use anyhow::{anyhow, Context, Result};

    use super::{default_artifacts_dir, ArtifactKind};
    use crate::cholesky::dense::DenseCholesky;

    struct Loaded {
        exe: xla::PjRtLoadedExecutable,
    }

    /// The PJRT engine: a CPU client plus compiled executables keyed by
    /// `(kind, size)`.
    pub struct PjrtEngine {
        client: xla::PjRtClient,
        execs: BTreeMap<(ArtifactKind, usize), Loaded>,
        /// PJRT executions are serialized (single-device CPU client).
        lock: Mutex<()>,
    }

    impl PjrtEngine {
        /// Load every artifact listed in `<dir>/manifest.txt`.
        pub fn load_dir(dir: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(wrap)?;
            let manifest = dir.join("manifest.txt");
            let text = std::fs::read_to_string(&manifest).with_context(|| {
                format!("read {} — run `make artifacts` first", manifest.display())
            })?;
            let mut execs = BTreeMap::new();
            for line in text.lines() {
                let mut it = line.split_whitespace();
                let (Some(kind), Some(size), Some(file)) = (it.next(), it.next(), it.next())
                else {
                    continue;
                };
                let kind = ArtifactKind::parse(kind)
                    .ok_or_else(|| anyhow!("unknown artifact kind {kind:?}"))?;
                let size: usize = size.parse()?;
                let path: PathBuf = dir.join(file);
                let proto =
                    xla::HloModuleProto::from_text_file(path.to_str().unwrap()).map_err(wrap)?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp).map_err(wrap)?;
                execs.insert((kind, size), Loaded { exe });
            }
            if execs.is_empty() {
                return Err(anyhow!("no artifacts in {}", dir.display()));
            }
            Ok(Self {
                client,
                execs,
                lock: Mutex::new(()),
            })
        }

        /// Load from `$PARAMD_ARTIFACTS` or `./artifacts`.
        pub fn load_default() -> Result<Self> {
            Self::load_dir(Path::new(&default_artifacts_dir()))
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Sizes available for a kind (ascending).
        pub fn sizes(&self, kind: ArtifactKind) -> Vec<usize> {
            self.execs
                .keys()
                .filter(|(k, _)| *k == kind)
                .map(|&(_, s)| s)
                .collect()
        }

        /// Smallest compiled size ≥ `n` for `kind`.
        pub fn pick_size(&self, kind: ArtifactKind, n: usize) -> Option<usize> {
            self.sizes(kind).into_iter().find(|&s| s >= n)
        }

        /// Execute the Cholesky-factor artifact on an `n×n` row-major
        /// matrix, padding up to the artifact size with an identity tail
        /// (which factors to itself and cannot pollute the leading block).
        pub fn dense_cholesky(&self, a: &[f64], n: usize) -> Result<Vec<f64>> {
            assert_eq!(a.len(), n * n);
            let size = self.pick_size(ArtifactKind::Chol, n).ok_or_else(|| {
                anyhow!(
                    "no chol artifact ≥ {n} (have {:?})",
                    self.sizes(ArtifactKind::Chol)
                )
            })?;
            let mut padded = vec![0f64; size * size];
            for i in 0..n {
                padded[i * size..i * size + n].copy_from_slice(&a[i * n..(i + 1) * n]);
            }
            for i in n..size {
                padded[i * size + i] = 1.0;
            }
            let out = {
                let _g = self.lock.lock().unwrap();
                let lit = xla::Literal::vec1(&padded)
                    .reshape(&[size as i64, size as i64])
                    .map_err(wrap)?;
                let exe = &self.execs[&(ArtifactKind::Chol, size)].exe;
                let result = exe.execute::<xla::Literal>(&[lit]).map_err(wrap)?[0][0]
                    .to_literal_sync()
                    .map_err(wrap)?;
                result
                    .to_tuple1()
                    .map_err(wrap)?
                    .to_vec::<f64>()
                    .map_err(wrap)?
            };
            let mut l = vec![0f64; n * n];
            for i in 0..n {
                l[i * n..(i + 1) * n].copy_from_slice(&out[i * size..i * size + n]);
            }
            Ok(l)
        }

        /// Execute the fused factor+solve artifact: solves `A x = b`.
        pub fn dense_solve(&self, a: &[f64], b: &[f64], n: usize) -> Result<Vec<f64>> {
            assert_eq!(a.len(), n * n);
            assert_eq!(b.len(), n);
            let size = self
                .pick_size(ArtifactKind::Solve, n)
                .ok_or_else(|| anyhow!("no solve artifact ≥ {n}"))?;
            let mut pa = vec![0f64; size * size];
            for i in 0..n {
                pa[i * size..i * size + n].copy_from_slice(&a[i * n..(i + 1) * n]);
            }
            for i in n..size {
                pa[i * size + i] = 1.0;
            }
            let mut pb = vec![0f64; size];
            pb[..n].copy_from_slice(b);
            let out = {
                let _g = self.lock.lock().unwrap();
                let la = xla::Literal::vec1(&pa)
                    .reshape(&[size as i64, size as i64])
                    .map_err(wrap)?;
                let lb = xla::Literal::vec1(&pb)
                    .reshape(&[size as i64])
                    .map_err(wrap)?;
                let exe = &self.execs[&(ArtifactKind::Solve, size)].exe;
                let result = exe.execute::<xla::Literal>(&[la, lb]).map_err(wrap)?[0][0]
                    .to_literal_sync()
                    .map_err(wrap)?;
                result
                    .to_tuple1()
                    .map_err(wrap)?
                    .to_vec::<f64>()
                    .map_err(wrap)?
            };
            Ok(out[..n].to_vec())
        }
    }

    fn wrap(e: xla::Error) -> anyhow::Error {
        anyhow!("xla: {e}")
    }

    /// [`DenseCholesky`] engine backed by the PJRT executables — plugs the
    /// AOT Pallas kernel into the sparse solver's dense trailing block.
    pub struct PjrtDense<'a> {
        pub engine: &'a PjrtEngine,
    }

    impl DenseCholesky for PjrtDense<'_> {
        fn factor(&self, a: &mut [f64], n: usize) -> Result<(), String> {
            if n == 0 {
                return Ok(());
            }
            let l = self
                .engine
                .dense_cholesky(a, n)
                .map_err(|e| format!("pjrt dense cholesky: {e}"))?;
            if l.iter().any(|v| !v.is_finite()) {
                return Err("matrix not positive definite (NaN from kernel)".into());
            }
            a.copy_from_slice(&l);
            Ok(())
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use std::path::Path;

    use anyhow::{anyhow, Result};

    use super::{default_artifacts_dir, ArtifactKind};
    use crate::cholesky::dense::DenseCholesky;

    const DISABLED: &str = "PJRT runtime disabled: built without the `pjrt` feature \
         (vendored `xla` crate + `make artifacts` required)";

    /// API-identical stub of the PJRT engine; every loader refuses, so
    /// callers fall back to the native dense engine.
    pub struct PjrtEngine {
        _priv: (),
    }

    impl PjrtEngine {
        pub fn load_dir(dir: &Path) -> Result<Self> {
            Err(anyhow!("{DISABLED} (artifacts dir {})", dir.display()))
        }

        pub fn load_default() -> Result<Self> {
            Self::load_dir(Path::new(&default_artifacts_dir()))
        }

        pub fn platform(&self) -> String {
            "disabled".into()
        }

        pub fn sizes(&self, _kind: ArtifactKind) -> Vec<usize> {
            Vec::new()
        }

        pub fn pick_size(&self, _kind: ArtifactKind, _n: usize) -> Option<usize> {
            None
        }

        pub fn dense_cholesky(&self, _a: &[f64], _n: usize) -> Result<Vec<f64>> {
            Err(anyhow!(DISABLED))
        }

        pub fn dense_solve(&self, _a: &[f64], _b: &[f64], _n: usize) -> Result<Vec<f64>> {
            Err(anyhow!(DISABLED))
        }
    }

    /// Stub of the PJRT-backed dense engine (unreachable in practice: the
    /// stub `PjrtEngine` cannot be constructed).
    pub struct PjrtDense<'a> {
        pub engine: &'a PjrtEngine,
    }

    impl DenseCholesky for PjrtDense<'_> {
        fn factor(&self, _a: &mut [f64], _n: usize) -> Result<(), String> {
            Err(DISABLED.into())
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}

pub use backend::{PjrtDense, PjrtEngine};

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn stub_loaders_refuse_with_a_clear_error() {
        let err = PjrtEngine::load_dir(Path::new("artifacts"))
            .err()
            .expect("stub must refuse");
        assert!(err.to_string().contains("pjrt"), "{err}");
        assert!(PjrtEngine::load_default().is_err());
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root.
        PathBuf::from("artifacts")
    }

    fn engine() -> PjrtEngine {
        PjrtEngine::load_dir(&artifacts_dir()).expect("run `make artifacts` before cargo test")
    }

    #[test]
    fn loads_manifest_and_reports_sizes() {
        let e = engine();
        let sizes = e.sizes(ArtifactKind::Chol);
        assert!(sizes.contains(&32) && sizes.contains(&256), "{sizes:?}");
        assert_eq!(e.pick_size(ArtifactKind::Chol, 33), Some(64));
        assert_eq!(e.pick_size(ArtifactKind::Chol, 257), None);
        assert_eq!(e.platform(), "cpu");
    }

    #[test]
    fn dense_cholesky_exact_size() {
        let e = engine();
        let n = 32;
        let a: Vec<f64> = (0..n * n)
            .map(|i| if i % (n + 1) == 0 { 9.0 } else { 0.0 })
            .collect();
        let l = e.dense_cholesky(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 3.0 } else { 0.0 };
                assert!((l[i * n + j] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn dense_cholesky_padded_size_matches_native() {
        let e = engine();
        let n = 50; // pads to 64
        crate::cholesky::dense::check_dense_factor(&PjrtDense { engine: &e }, n, 1234);
    }

    #[test]
    fn dense_solve_roundtrip() {
        let e = engine();
        let n = 40;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let b: Vec<f64> = (0..n * n).map(|_| rng.f64() - 0.5).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64) / n as f64 - 0.3).collect();
        let mut rhs = vec![0.0; n];
        for i in 0..n {
            rhs[i] = (0..n).map(|j| a[i * n + j] * x_true[j]).sum();
        }
        let x = e.dense_solve(&a, &rhs, n).unwrap();
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8, "{xi} vs {ti}");
        }
    }

    #[test]
    fn pjrt_dense_rejects_indefinite() {
        use crate::cholesky::dense::DenseCholesky as _;
        let e = engine();
        let mut a = vec![-1.0, 0.0, 0.0, -1.0];
        let r = PjrtDense { engine: &e }.factor(&mut a, 2);
        assert!(r.is_err());
    }

    #[test]
    fn sparse_solver_with_pjrt_tail() {
        use crate::cholesky::{factor, residual, solve, DenseTail};
        use crate::matgen::laplacian_matrix;
        use crate::ordering::{amd_seq::AmdSeq, Ordering as _};

        let e = engine();
        let a = laplacian_matrix(14, 14);
        let g = crate::graph::symmetrize(&a);
        let perm = AmdSeq::default().order(&g).perm;
        let f = factor(&a, &perm, DenseTail::Fixed(100), &PjrtDense { engine: &e }).unwrap();
        let b = vec![1.0; a.nrows];
        let x = solve(&f, &b);
        let r = residual(&a, &x, &b);
        assert!(r < 1e-10, "residual {r:e} via PJRT tail");
    }
}
