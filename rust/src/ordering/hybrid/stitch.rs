//! Merge a hybrid request's two phases — `[subdomains…, separators…]` —
//! into one reply.
//!
//! Within a phase the jobs really do run concurrently across shards, so
//! the phase log merges exactly like [`crate::ordering::shard::stitch`]:
//! `rounds` and `modeled_time` take the slowest job's value, set sizes
//! sum round-wise, GC counters add. *Across* the phases the dependency
//! is real — no separator vertex is eliminated before every subdomain
//! resolved — so rounds and modeled time **add** and the per-round logs
//! **concatenate** instead of overlapping.

use crate::ordering::shard::stitch::{ComponentResult, StitchedOrdering};

/// The concurrent merge of one phase's results.
struct PhaseLog {
    rounds: u64,
    gc_count: u64,
    gc_secs: f64,
    modeled_time: f64,
    set_sizes: Vec<u32>,
}

fn merge_phase(perm: &mut Vec<i32>, comps: &[ComponentResult]) -> PhaseLog {
    let mut log = PhaseLog {
        rounds: 0,
        gc_count: 0,
        gc_secs: 0.0,
        modeled_time: 0.0,
        set_sizes: Vec::new(),
    };
    for c in comps {
        debug_assert_eq!(c.perm.len(), c.old_of_new.len());
        for &p in &c.perm {
            perm.push(c.old_of_new[p as usize]);
        }
        log.rounds = log.rounds.max(c.rounds);
        log.gc_count += c.gc_count;
        log.gc_secs += c.gc_secs;
        log.modeled_time = log.modeled_time.max(c.modeled_time);
        for (r, &s) in c.set_sizes.iter().enumerate() {
            if log.set_sizes.len() <= r {
                log.set_sizes.push(0);
            }
            log.set_sizes[r] += s;
        }
    }
    log
}

/// Merge subdomain results (plan order) and separator results
/// (elimination order, deepest level first) into one ordering of `n`
/// original vertices. Panics unless the phases cover `n` exactly.
pub fn stitch_hybrid(
    n: usize,
    subdomains: &[ComponentResult],
    separators: &[ComponentResult],
) -> StitchedOrdering {
    let mut perm = Vec::with_capacity(n);
    let sub = merge_phase(&mut perm, subdomains);
    let sep = merge_phase(&mut perm, separators);
    assert_eq!(perm.len(), n, "hybrid phases must cover the graph");
    let mut set_sizes = sub.set_sizes;
    set_sizes.extend(sep.set_sizes);
    StitchedOrdering {
        perm,
        rounds: sub.rounds + sep.rounds,
        gc_count: sub.gc_count + sep.gc_count,
        gc_secs: sub.gc_secs + sep.gc_secs,
        modeled_time: sub.modeled_time + sep.modeled_time,
        set_sizes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::perm::is_valid_perm;

    fn comp(old: Vec<i32>, perm: Vec<i32>, rounds: u64, sets: Vec<u32>) -> ComponentResult {
        ComponentResult {
            old_of_new: old,
            perm,
            rounds,
            gc_count: 1,
            gc_secs: 0.25,
            modeled_time: rounds as f64,
            set_sizes: sets,
        }
    }

    #[test]
    fn phases_concatenate_and_logs_add_across_phases() {
        // Subdomains {0,1} and {2,3} (concurrent), separator {4} after.
        let s = stitch_hybrid(
            5,
            &[
                comp(vec![0, 1], vec![1, 0], 2, vec![1, 1]),
                comp(vec![2, 3], vec![0, 1], 1, vec![2]),
            ],
            &[comp(vec![4], vec![0], 1, vec![1])],
        );
        assert_eq!(s.perm, vec![1, 0, 2, 3, 4]);
        assert!(is_valid_perm(&s.perm));
        assert_eq!(s.rounds, 3, "phase maxima add: max(2,1) + 1");
        assert!((s.modeled_time - 3.0).abs() < 1e-12);
        assert_eq!(s.gc_count, 3);
        assert!((s.gc_secs - 0.75).abs() < 1e-12);
        assert_eq!(
            s.set_sizes,
            vec![3, 1, 1],
            "subdomain rounds sum element-wise, separator rounds append"
        );
        let pivots: u32 = s.set_sizes.iter().sum();
        assert_eq!(pivots, 5, "merged round log covers every pivot");
    }

    #[test]
    fn empty_separator_phase_degrades_to_the_plain_merge() {
        let s = stitch_hybrid(
            3,
            &[
                comp(vec![2, 0], vec![0, 1], 1, vec![2]),
                comp(vec![1], vec![0], 1, vec![1]),
            ],
            &[],
        );
        assert_eq!(s.perm, vec![2, 0, 1]);
        assert_eq!(s.rounds, 1);
        assert_eq!(s.set_sizes, vec![3]);
    }

    #[test]
    #[should_panic(expected = "cover the graph")]
    fn missing_vertices_panic() {
        stitch_hybrid(4, &[comp(vec![0, 1], vec![0, 1], 1, vec![2])], &[]);
    }
}
