//! Quickstart: order a 3D-mesh matrix with sequential AMD and ParAMD,
//! compare fill-in and runtime, show the cost-model speedup.
//!
//! Run: `cargo run --release --example quickstart`

use paramd::matgen;
use paramd::ordering::{amd_seq::AmdSeq, paramd::ParAmd, Ordering as _};
use paramd::symbolic;
use paramd::util::timer::Timer;

fn main() {
    // A 3D structural mesh, the AMD sweet spot (paper Table 4.1 family).
    let g = matgen::mesh3d(20, 20, 20);
    println!("matrix: 3D 7-pt mesh, n = {}, nnz = {}", g.n, g.nnz());

    let t = Timer::new();
    let seq = AmdSeq::default().order(&g);
    let t_seq = t.secs();
    let fill_seq = symbolic::fill_in(&g, &seq.perm);
    println!("\nsequential AMD : {t_seq:.3}s, fill-ins = {:.3e}", fill_seq as f64);

    let t = Timer::new();
    let (par, detail) = ParAmd::new(8).order_detailed(&g);
    let t_par = t.secs();
    let fill_par = symbolic::fill_in(&g, &par.perm);
    println!(
        "ParAMD (8 thr) : {t_par:.3}s wall (1-core testbed), fill-ins = {:.3e}",
        fill_par as f64
    );
    println!(
        "fill ratio     : {:.3}x  (paper Table 4.2 band: 1.01–1.19x)",
        fill_par as f64 / fill_seq as f64
    );
    println!(
        "rounds         : {} multiple-elimination rounds, avg |D2 set| = {:.1}",
        par.stats.rounds,
        par.stats.pivots as f64 / par.stats.rounds as f64
    );
    println!(
        "cost model     : {:.2}x speedup on an ideal 8-core machine \
         (critical-path over per-round work)",
        detail.model_speedup
    );
}
