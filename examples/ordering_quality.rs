//! Ordering-quality comparison across the matrix suite: AMD vs ParAMD vs
//! MMD vs ND, with #fill-ins and timing — the paper's Table 4.2/4.4 view.
//!
//! Run: `cargo run --release --example ordering_quality [-- --scale small]`

use paramd::bench_util::{fmt_sci, Table};
use paramd::matgen::{self, Scale};
use paramd::nd::NestedDissection;
use paramd::ordering::{amd_seq::AmdSeq, mmd::Mmd, paramd::ParAmd, Ordering, OrderingResult};
use paramd::symbolic;
use paramd::util::timer::Timer;

fn main() {
    let scale = if std::env::args().any(|a| a == "small") {
        Scale::Small
    } else {
        Scale::Tiny
    };
    let mut table = Table::new(&["Matrix", "Method", "Time (s)", "#Fill-ins", "vs AMD"]);
    for e in matgen::suite() {
        let g = (e.gen)(scale);
        let mut base_fill = 0f64;
        let runs: Vec<(&str, Box<dyn Fn() -> OrderingResult>)> = vec![
            ("amd", Box::new(|| AmdSeq::default().order(&g))),
            ("paramd-8", Box::new(|| ParAmd::new(8).order(&g))),
            ("mmd", Box::new(|| Mmd::default().order(&g))),
            ("nd", Box::new(|| NestedDissection::default().order(&g))),
        ];
        for (name, run) in runs {
            let t = Timer::new();
            let r = run();
            let secs = t.secs();
            let fill = symbolic::fill_in(&g, &r.perm) as f64;
            if name == "amd" {
                base_fill = fill;
            }
            table.row(vec![
                e.name.into(),
                name.into(),
                format!("{secs:.3}"),
                fmt_sci(fill),
                format!("{:.2}x", fill / base_fill),
            ]);
        }
    }
    table.print();
}
