//! The per-request **flight recorder**: a [`RequestTrace`] of timestamped
//! spans carried with every pipeline request.
//!
//! One trace is created per [`Ticket`](crate::coordinator::Ticket) and
//! shared (`Arc`) down the whole path — scheduler, shard engine, per-shard
//! dispatchers — each of which records *complete spans* (`name`, lane,
//! start, duration) against the trace's single epoch. Lanes map to Chrome
//! trace `tid`s: lane 0 is the pipeline/scheduler, lane 1 the shard
//! engine's request-level phases, and lane `2 + s` shard `s`'s
//! dispatcher, so concurrent component jobs render as parallel tracks.
//!
//! Recording is O(1) amortized (a mutexed `Vec` push); timestamps are
//! microseconds since the trace epoch (ticket creation), which is exactly
//! the `ts` unit Chrome trace-event JSON wants. [`RequestTrace::to_chrome_json`]
//! renders the whole trace as a Perfetto/about:tracing-loadable document,
//! and [`RequestTrace::coverage`] measures how much of the request's wall
//! time the recorded spans explain (the acceptance bar is ≥95%).

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;
use std::time::Instant;

/// Well-known lanes (Chrome `tid`s). Shard dispatchers use
/// [`shard_lane`].
pub const LANE_PIPELINE: u32 = 0;
/// The shard engine's request-level phases (cc-split, reduce, route,
/// stitch).
pub const LANE_ENGINE: u32 = 1;

/// Lane of shard `s`'s dispatcher.
pub fn shard_lane(shard: usize) -> u32 {
    2 + shard as u32
}

/// One completed span: `[start_us, start_us + dur_us)` on `lane`,
/// relative to the owning trace's epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    pub name: &'static str,
    pub lane: u32,
    pub start_us: u64,
    pub dur_us: u64,
}

impl SpanRecord {
    /// Exclusive end timestamp.
    pub fn end_us(&self) -> u64 {
        self.start_us + self.dur_us
    }
}

/// The flight recorder of one request. See the module docs.
#[derive(Debug)]
pub struct RequestTrace {
    epoch: Instant,
    id: AtomicU64,
    spans: Mutex<Vec<SpanRecord>>,
}

impl Default for RequestTrace {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestTrace {
    /// A fresh trace; the epoch is *now* (ticket creation time).
    pub fn new() -> Self {
        Self {
            epoch: Instant::now(),
            id: AtomicU64::new(0),
            spans: Mutex::new(Vec::with_capacity(16)),
        }
    }

    /// Tag the trace with the service's submit counter.
    pub fn set_id(&self, id: u64) {
        self.id.store(id, Relaxed);
    }

    /// The request id (submit counter; 0 until tagged).
    pub fn id(&self) -> u64 {
        self.id.load(Relaxed)
    }

    /// Microseconds elapsed since the trace epoch — use as a span start.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record a span that started at `start_us` and ends now.
    pub fn record(&self, name: &'static str, lane: u32, start_us: u64) {
        let end = self.now_us().max(start_us);
        self.record_at(name, lane, start_us, end - start_us);
    }

    /// Record a fully-specified span (used for synthesized/aggregate
    /// spans like the in-elimination sweep total).
    pub fn record_at(&self, name: &'static str, lane: u32, start_us: u64, dur_us: u64) {
        self.spans.lock().unwrap().push(SpanRecord {
            name,
            lane,
            start_us,
            dur_us,
        });
    }

    /// Snapshot of every span recorded so far.
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.spans.lock().unwrap().clone()
    }

    /// Fraction of the request's wall time (epoch → latest span end)
    /// covered by the union of all recorded span intervals, lanes merged.
    /// 1.0 means the spans explain every microsecond; 0.0 for an empty
    /// trace.
    pub fn coverage(&self) -> f64 {
        let mut spans = self.spans();
        if spans.is_empty() {
            return 0.0;
        }
        spans.sort_by_key(|s| s.start_us);
        let wall = spans.iter().map(SpanRecord::end_us).max().unwrap();
        if wall == 0 {
            return 1.0;
        }
        let mut covered = 0u64;
        let mut cur_start = spans[0].start_us;
        let mut cur_end = spans[0].end_us();
        for s in &spans[1..] {
            if s.start_us <= cur_end {
                cur_end = cur_end.max(s.end_us());
            } else {
                covered += cur_end - cur_start;
                cur_start = s.start_us;
                cur_end = s.end_us();
            }
        }
        covered += cur_end - cur_start;
        covered as f64 / wall as f64
    }

    /// Per-lane nesting/ordering violations, empty when well-formed: on
    /// each lane, two overlapping spans must be properly nested (one
    /// inside the other) — partial overlap means a span "ended" before a
    /// child did, i.e. mis-recorded timestamps.
    pub fn invariant_violations(&self) -> Vec<String> {
        let mut out = Vec::new();
        let mut spans = self.spans();
        spans.sort_by_key(|s| (s.lane, s.start_us, u64::MAX - s.dur_us));
        for w in spans.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            if a.lane != b.lane {
                continue;
            }
            let overlap = b.start_us < a.end_us();
            let nested = overlap && b.end_us() <= a.end_us();
            if overlap && !nested {
                out.push(format!(
                    "lane {}: '{}' [{}..{}] partially overlaps '{}' [{}..{}]",
                    a.lane,
                    a.name,
                    a.start_us,
                    a.end_us(),
                    b.name,
                    b.start_us,
                    b.end_us()
                ));
            }
        }
        out
    }

    /// Render the trace as Chrome trace-event JSON (the object form with
    /// a `traceEvents` array of `ph: "X"` complete events), loadable in
    /// Perfetto / `about:tracing`. Hand-rolled — span names are static
    /// identifiers, so no escaping is required.
    pub fn to_chrome_json(&self) -> String {
        let spans = self.spans();
        let id = self.id();
        let mut out = String::with_capacity(256 + spans.len() * 96);
        out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
        out.push_str(&format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
             \"args\":{{\"name\":\"paramd req {id}\"}}}}"
        ));
        for s in &spans {
            out.push_str(&format!(
                ",{{\"name\":\"{}\",\"cat\":\"request\",\"ph\":\"X\",\"ts\":{},\
                 \"dur\":{},\"pid\":1,\"tid\":{}}}",
                s.name, s.start_us, s.dur_us, s.lane
            ));
        }
        out.push_str("]}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_accumulate_and_ids_tag() {
        let t = RequestTrace::new();
        t.set_id(9);
        assert_eq!(t.id(), 9);
        let s0 = t.now_us();
        t.record("queued", LANE_PIPELINE, s0);
        t.record_at("order", LANE_ENGINE, 10, 50);
        let spans = t.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[1].end_us(), 60);
    }

    #[test]
    fn coverage_unions_overlapping_lanes() {
        let t = RequestTrace::new();
        // Wall = 100µs; [0,60) + [40,80) union to [0,80) => 0.8, the
        // disjoint [90,100) brings it to 0.9.
        t.record_at("a", 0, 0, 60);
        t.record_at("b", 1, 40, 40);
        t.record_at("c", 0, 90, 10);
        assert!((t.coverage() - 0.9).abs() < 1e-12);
        assert_eq!(RequestTrace::new().coverage(), 0.0);
    }

    #[test]
    fn nesting_invariants_catch_partial_overlap() {
        let good = RequestTrace::new();
        good.record_at("parent", 0, 0, 100);
        good.record_at("child", 0, 10, 20); // nested: fine
        good.record_at("sibling", 0, 40, 30); // disjoint from child: fine
        good.record_at("other-lane", 1, 50, 100); // overlap across lanes: fine
        assert!(good.invariant_violations().is_empty());

        let bad = RequestTrace::new();
        bad.record_at("parent", 0, 0, 50);
        bad.record_at("straddler", 0, 30, 40); // ends after parent: violation
        let v = bad.invariant_violations();
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("straddler"), "violation names the span: {v:?}");
    }

    #[test]
    fn record_is_monotone_even_with_stale_start() {
        let t = RequestTrace::new();
        // A start taken "in the future" (stale clock reuse) must clamp to
        // dur 0, never underflow.
        t.record("z", 0, u64::MAX - 5);
        assert_eq!(t.spans()[0].dur_us, 0);
    }

    #[test]
    fn chrome_json_has_the_expected_shape() {
        let t = RequestTrace::new();
        t.set_id(3);
        t.record_at("queued", LANE_PIPELINE, 0, 10);
        t.record_at("elimination", shard_lane(1), 12, 88);
        let j = t.to_chrome_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"traceEvents\":["));
        assert!(j.contains("\"ph\":\"X\""));
        assert!(j.contains("\"name\":\"elimination\""));
        assert!(j.contains("\"tid\":3"), "shard 1 renders on lane 3: {j}");
        assert!(j.contains("paramd req 3"));
        crate::telemetry::validate_json(&j).expect("chrome trace must be valid JSON");
    }
}
