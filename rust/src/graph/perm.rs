//! Permutation utilities.
//!
//! Convention (matches SuiteSparse AMD): `perm[k] = v` means vertex `v` of
//! the original graph is eliminated `k`-th, i.e. row/column `v` of `A` maps
//! to position `k` of `P A P^T`. `iperm` is the inverse: `iperm[v] = k`.

use crate::graph::csr::SymGraph;

/// Is `perm` a permutation of `0..n`?
pub fn is_valid_perm(perm: &[i32]) -> bool {
    let n = perm.len();
    let mut seen = vec![false; n];
    for &v in perm {
        if v < 0 || v as usize >= n || seen[v as usize] {
            return false;
        }
        seen[v as usize] = true;
    }
    true
}

/// Invert a permutation: `out[perm[k]] = k`.
pub fn invert_perm(perm: &[i32]) -> Vec<i32> {
    let mut inv = Vec::new();
    invert_perm_into(perm, &mut inv);
    inv
}

/// Invert a permutation into a reusable buffer (`out[perm[k]] = k`),
/// allocating only when `out`'s capacity is too small.
pub fn invert_perm_into(perm: &[i32], out: &mut Vec<i32>) {
    out.clear();
    out.resize(perm.len(), 0);
    for (k, &v) in perm.iter().enumerate() {
        out[v as usize] = k as i32;
    }
}

/// Compose permutations: applying `first` then `second`.
/// `(second ∘ first)[k] = first[second[k]]`.
pub fn compose(first: &[i32], second: &[i32]) -> Vec<i32> {
    second.iter().map(|&k| first[k as usize]).collect()
}

/// Relabel a graph by a permutation: vertex `perm[k]` becomes vertex `k` of
/// the result (i.e. the graph of `P A P^T`).
pub fn permute_graph(g: &SymGraph, perm: &[i32]) -> SymGraph {
    assert_eq!(perm.len(), g.n);
    debug_assert!(is_valid_perm(perm));
    let inv = invert_perm(perm);
    let mut rowptr = vec![0usize; g.n + 1];
    for k in 0..g.n {
        rowptr[k + 1] = rowptr[k] + g.degree(perm[k] as usize);
    }
    let mut colind = vec![0i32; g.nnz()];
    for k in 0..g.n {
        let v = perm[k] as usize;
        let dst = &mut colind[rowptr[k]..rowptr[k + 1]];
        for (i, &u) in g.neighbors(v).iter().enumerate() {
            dst[i] = inv[u as usize];
        }
        dst.sort_unstable();
    }
    SymGraph {
        n: g.n,
        rowptr,
        colind,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn validity() {
        assert!(is_valid_perm(&[2, 0, 1]));
        assert!(!is_valid_perm(&[0, 0, 1]));
        assert!(!is_valid_perm(&[0, 3, 1]));
        assert!(!is_valid_perm(&[-1, 0, 1]));
        assert!(is_valid_perm(&[]));
    }

    #[test]
    fn inverse_roundtrip() {
        let mut rng = Rng::new(11);
        let p = rng.permutation(50);
        let inv = invert_perm(&p);
        for k in 0..50 {
            assert_eq!(inv[p[k] as usize], k as i32);
            assert_eq!(p[inv[k] as usize], k as i32);
        }
    }

    #[test]
    fn compose_with_inverse_is_identity() {
        let mut rng = Rng::new(13);
        let p = rng.permutation(20);
        let inv = invert_perm(&p);
        let id = compose(&p, &inv);
        assert_eq!(id, (0..20).collect::<Vec<i32>>());
    }

    #[test]
    fn permute_graph_preserves_structure() {
        let g = SymGraph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)]);
        let mut rng = Rng::new(17);
        let p = rng.permutation(5);
        let pg = permute_graph(&g, &p);
        pg.validate().unwrap();
        assert_eq!(pg.nedges(), g.nedges());
        // Edge (perm[i], perm[j]) in g  <=>  edge (i, j) in pg.
        let inv = invert_perm(&p);
        for v in 0..5 {
            for &u in g.neighbors(v) {
                let (a, b) = (inv[v] as usize, inv[u as usize]);
                assert!(pg.neighbors(a).binary_search(&(b as i32)).is_ok());
            }
        }
    }

    #[test]
    fn permute_by_identity_is_noop() {
        let g = SymGraph::from_edges(4, &[(0, 1), (2, 3)]);
        let id: Vec<i32> = (0..4).collect();
        assert_eq!(permute_graph(&g, &id), g);
    }
}
