//! Mid-elimination re-reduction integration: the round-boundary sweep
//! (global twin re-compression + dense re-postponement + aggressive
//! element absorption) must keep valid permutations across the whole
//! knob grid, stay within the fill band of the sweep-free path, fold
//! into the request-cache identity, and surface its tallies in the
//! service metrics report.

use paramd::coordinator::{Method, OrderRequest, Service};
use paramd::graph::csr::SymGraph;
use paramd::graph::perm::is_valid_perm;
use paramd::matgen::{emergent_twins, mesh2d, twin_heavy};
use paramd::ordering::paramd::ParAmd;
use paramd::ordering::Ordering as _;
use paramd::symbolic::fill_in;

fn request(pattern: SymGraph) -> OrderRequest {
    OrderRequest {
        matrix: None,
        pattern: Some(pattern),
        method: Method::ParAmd {
            threads: 1,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    }
}

fn dispatched_jobs(svc: &Service) -> u64 {
    svc.metrics().shards.per_shard.iter().map(|s| s.jobs).sum()
}

#[test]
fn knob_grid_yields_valid_permutations() {
    let graphs = [
        ("mesh2d", mesh2d(16, 16)),
        ("twin_heavy", twin_heavy(200, 4)),
        ("emergent_twins", emergent_twins(180, 3)),
    ];
    let grid: &[(bool, u32, f64)] = &[
        (true, 1, 0.0),
        (true, 2, 0.0),
        (true, 4, 0.0),
        (true, 0, 2.0), // elbow-only trigger
        (true, 2, 1.5), // both triggers
        (false, 1, 2.0), // master switch wins over both triggers
    ];
    for (name, g) in &graphs {
        for threads in [1usize, 2] {
            for &(on, every, elbow) in grid {
                let r = ParAmd::new(threads)
                    .with_rereduce(on)
                    .with_rereduce_every(every)
                    .with_rereduce_elbow(elbow)
                    .order(g);
                assert_eq!(r.perm.len(), g.n, "{name} t={threads}");
                assert!(
                    is_valid_perm(&r.perm),
                    "{name} t={threads} on={on} every={every} elbow={elbow}"
                );
                if !on {
                    assert_eq!(r.stats.rereduce_count, 0, "{name}: off means off");
                }
            }
        }
    }
}

#[test]
fn fill_stays_within_1_05x_of_the_sweep_free_baseline() {
    // The acceptance band: merging exact twins and postponing
    // near-complete rows must not cost meaningful fill.
    let graphs = [
        ("mesh2d", mesh2d(24, 24)),
        ("twin_heavy", twin_heavy(300, 5)),
        ("emergent_twins", emergent_twins(240, 3)),
    ];
    for (name, g) in &graphs {
        let base = fill_in(g, &ParAmd::new(1).with_rereduce(false).order(g).perm) as f64;
        for every in [1u32, 4] {
            let swept =
                fill_in(g, &ParAmd::new(1).with_rereduce_every(every).order(g).perm) as f64;
            assert!(
                swept <= base * 1.05 + 50.0,
                "{name}: every={every} fill {swept} exceeds 1.05x of {base}"
            );
        }
    }
}

#[test]
fn request_cache_distinguishes_rereduce_configs() {
    let g = emergent_twins(220, 3);
    let svc = Service::new(1);
    let first = svc.order(&request(g.clone()));
    assert!(is_valid_perm(&first.perm));
    assert_eq!(dispatched_jobs(&svc), 1);
    // Identical knobs replay bit-for-bit with zero dispatched work.
    let second = svc.order(&request(g.clone()));
    assert_eq!(second.perm, first.perm, "warm repeat must bit-match");
    assert_eq!(dispatched_jobs(&svc), 1, "repeat must be a cache hit");
    // Every sweep knob is part of the cache identity: changing one on
    // the warm service must miss and recompute, never replay.
    let svc = svc.with_rereduce_every(1);
    assert!(is_valid_perm(&svc.order(&request(g.clone())).perm));
    assert_eq!(dispatched_jobs(&svc), 2, "a new cadence must recompute");
    let svc = svc.with_rereduce(false);
    assert!(is_valid_perm(&svc.order(&request(g.clone())).perm));
    assert_eq!(dispatched_jobs(&svc), 3, "disabling the sweep must recompute");
    let svc = svc.with_rereduce(true).with_rereduce_every(4);
    let replay = svc.order(&request(g.clone()));
    assert_eq!(replay.perm, first.perm, "default knobs find the first entry");
    assert_eq!(dispatched_jobs(&svc), 3, "the original entry is still warm");
}

#[test]
fn sweep_tallies_flow_into_the_service_report() {
    let g = emergent_twins(240, 3);
    let svc = Service::new(1).with_rereduce_every(1);
    let rep = svc.order(&request(g));
    assert!(is_valid_perm(&rep.perm));
    let m = svc.metrics();
    assert!(m.shards.rereduce_passes > 0, "sweeps must fire");
    assert!(
        m.shards.elements_absorbed > 0,
        "distinguisher elements must be absorbed mid-run"
    );
    assert!(
        m.shards.mid_twins_merged > 0,
        "emergent twins must be merged mid-run"
    );
    let r = m.shards.report();
    assert!(r.contains("rereduce: passes="), "report line present: {r}");
    assert!(!r.contains("rereduce: passes=0"), "tallies rendered: {r}");
}

#[test]
fn sweep_composes_with_the_pre_ordering_reduction_layer() {
    // twin_heavy reduces heavily up front; the sweep then runs on the
    // weighted kernel. Both layers on must still be valid and within
    // the band of both layers off.
    let g = twin_heavy(480, 8);
    let both = Service::new(1).with_rereduce_every(1);
    let rep_both = both.order(&request(g.clone()));
    let neither = Service::new(1).with_reduction(false).with_rereduce(false);
    let rep_neither = neither.order(&request(g.clone()));
    assert!(is_valid_perm(&rep_both.perm));
    assert!(is_valid_perm(&rep_neither.perm));
    assert_eq!(both.metrics().shards.reduced_jobs, 1);
}
