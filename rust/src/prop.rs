//! Mini property-based testing framework (the offline registry has no
//! proptest). Provides seeded generators over graphs/permutations and a
//! `forall` runner that reports the failing seed and shrinks trivially by
//! retrying with smaller size parameters.

use crate::graph::csr::SymGraph;
use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 32,
            seed: 0xC0FFEE,
        }
    }
}

/// Run `prop` over `cases` generated inputs; panics with the failing seed.
/// `gen` must be deterministic in the provided RNG.
pub fn forall<T: std::fmt::Debug>(
    cfg: Config,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed on case {case} (seed {case_seed:#x}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Random graph family generator: picks among structural families the
/// ordering algorithms care about (meshes, random, stars, cliques, paths,
/// disconnected unions), sized by `max_n`.
pub fn arb_graph(rng: &mut Rng, max_n: usize) -> SymGraph {
    let family = rng.below(7);
    let n = 2 + rng.below(max_n.max(3) - 2);
    match family {
        0 => {
            let k = (n as f64).sqrt() as usize + 1;
            crate::matgen::mesh2d(k, k)
        }
        1 => {
            let k = (n as f64).cbrt() as usize + 1;
            crate::matgen::mesh3d(k, k, k)
        }
        2 => crate::matgen::random_graph(n, 1 + rng.below(8), rng.next_u64()),
        3 => {
            // star
            let edges: Vec<(usize, usize)> = (1..n).map(|i| (0, i)).collect();
            SymGraph::from_edges(n, &edges)
        }
        4 => {
            // path + random chords
            let mut edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
            for _ in 0..n / 4 {
                let (a, b) = (rng.below(n), rng.below(n));
                if a != b {
                    edges.push((a, b));
                }
            }
            SymGraph::from_edges(n, &edges)
        }
        5 => {
            // small clique + pendant vertices
            let k = 3 + rng.below(5);
            let mut edges = vec![];
            for i in 0..k.min(n) {
                for j in i + 1..k.min(n) {
                    edges.push((i, j));
                }
            }
            for i in k.min(n)..n {
                edges.push((rng.below(k.min(n)), i));
            }
            SymGraph::from_edges(n, &edges)
        }
        _ => {
            // disconnected union of two random graphs (+ isolated vertices)
            let h = n / 2;
            let a = crate::matgen::random_graph(h.max(1), 3, rng.next_u64());
            let mut edges = vec![];
            for v in 0..a.n {
                for &u in a.neighbors(v) {
                    if (u as usize) > v {
                        edges.push((v, u as usize));
                    }
                }
            }
            let b = crate::matgen::random_graph((n - h).max(1), 3, rng.next_u64());
            for v in 0..b.n {
                for &u in b.neighbors(v) {
                    if (u as usize) > v {
                        edges.push((v + h, u as usize + h));
                    }
                }
            }
            SymGraph::from_edges(n.max(h + b.n), &edges)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            Config::default(),
            |rng| rng.below(100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(
            Config {
                cases: 10,
                seed: 1,
            },
            |rng| rng.below(10),
            |&x| {
                if x < 5 {
                    Ok(())
                } else {
                    Err(format!("{x} too big"))
                }
            },
        );
    }

    #[test]
    fn arb_graph_always_valid() {
        forall(
            Config {
                cases: 40,
                seed: 99,
            },
            |rng| arb_graph(rng, 60),
            |g| g.validate(),
        );
    }
}
