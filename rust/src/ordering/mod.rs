//! Fill-reducing ordering algorithms.
//!
//! - [`md`] — textbook minimum degree on explicit elimination graphs
//!   (the test oracle; O(n²), small inputs only).
//! - [`mmd`] — multiple minimum degree (Liu 1985): multiple elimination on
//!   maximal independent sets of minimum-degree pivots.
//! - [`amd_seq`] — the sequential approximate minimum degree algorithm
//!   (Amestoy–Davis–Duff 1996), data-structure-faithful to SuiteSparse
//!   `amd_2`: the paper's baseline.
//! - [`paramd`] — the paper's contribution: parallel AMD via multiple
//!   elimination on distance-2 independent sets.
//! - [`reduce`] — pre-ordering graph reduction (twin compression,
//!   dense-row postponement, leaf stripping) feeding ParAMD a smaller,
//!   weight-seeded kernel.
//! - [`shard`] — the sharded ordering engine: component decomposition +
//!   per-component reduction + routing across independent ParAMD
//!   runtimes.
//! - [`cache`] — the fingerprinted result cache: repeated graphs replay
//!   their permutation instead of re-running the kernel at all.
//! - [`hybrid`] — nested-dissection × ParAMD planning: cut one huge
//!   connected graph into independent subdomains the shard engine
//!   orders in parallel, separators last.

pub mod amd_seq;
pub mod cache;
pub mod hybrid;
pub mod md;
pub mod mmd;
pub mod rcm;
pub mod paramd;
pub mod reduce;
pub mod shard;

use crate::graph::csr::SymGraph;
use crate::util::timer::PhaseTimes;

/// Result of an ordering run.
#[derive(Clone, Debug)]
pub struct OrderingResult {
    /// `perm[k] = v`: original vertex `v` is eliminated k-th.
    pub perm: Vec<i32>,
    /// Inverse permutation: `iperm[v] = k`.
    pub iperm: Vec<i32>,
    /// Per-phase wall-clock seconds (Figure 4.1 breakdown).
    pub phases: PhaseTimes,
    /// Algorithm-specific counters (set sizes, contention stats, ...).
    pub stats: OrderingStats,
}

/// One outer elimination round's telemetry, recorded by the ParAMD
/// leader at each round boundary (the paper's Fig-4-style decay curve).
/// All rate-like fields are **deltas since the previous sample**; the
/// sweep time of round `r`'s boundary lands on sample `r + 1` (the sweep
/// runs after bookkeeping), with any post-final-round remainder folded
/// into a tail sample at assembly, so per-job sums are exact:
/// Σ`pivots` = total supervariable pivots, Σ`weight` = the kernel's
/// total column weight (= `n` for unreduced, unweighted runs).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundSample {
    /// Outer round index (0-based; `u32::MAX` tags the assembly tail
    /// sample that closes the books after the last round).
    pub round: u32,
    /// Supervariable pivots retired this round (mass eliminations and
    /// postponed pseudo-sets included).
    pub pivots: u32,
    /// Original columns retired this round (elimination-count delta —
    /// supervariable weights counted, so these sum to the kernel weight).
    pub weight: u32,
    /// Live (still-active) supervariables after the round.
    pub live_vars: u32,
    /// Live column weight after the round (total weight − eliminated).
    pub live_weight: u32,
    /// Elbow `claim` failures (memory contention → deferral + GC
    /// request) observed this round.
    pub claim_failures: u32,
    /// Stop-the-world GC seconds charged to this round.
    pub gc_secs: f64,
    /// Re-reduction sweep seconds charged to this round (the previous
    /// round boundary's sweep; see above).
    pub sweep_secs: f64,
}

/// Counters shared across ordering implementations; a superset — each
/// algorithm fills what applies to it.
#[derive(Clone, Debug, Default)]
pub struct OrderingStats {
    /// Number of elimination steps (outer rounds for multiple elimination).
    pub rounds: u64,
    /// Number of pivots eliminated (supervariables, not original columns).
    pub pivots: u64,
    /// Sizes of each selected independent set (ParAMD: distance-2 sets —
    /// the Figure 4.2 distribution; MMD: independent sets).
    pub set_sizes: Vec<u32>,
    /// Garbage collections / elbow exhaustion events.
    pub gc_count: u64,
    /// Cumulative stop-the-world seconds spent inside those collections
    /// (every worker is parked at a barrier while one thread compacts).
    pub gc_secs: f64,
    /// Global twins merged by the mid-elimination re-reduction sweep
    /// ([`reduce::live`]); 0 when the sweep is off or never fired.
    pub mid_twins_merged: u64,
    /// Rows re-postponed to the permutation tail mid-elimination.
    pub mid_dense_postponed: u64,
    /// Elements absorbed by a superset element mid-elimination.
    pub elements_absorbed: u64,
    /// Re-reduction sweeps executed (trigger count).
    pub rereduce_count: u64,
    /// Stop-the-world seconds spent inside those sweeps.
    pub rereduce_secs: f64,
    /// Total quotient-graph words touched (cost-model input).
    pub work_words: u64,
    /// Per-thread per-phase work counters (cost-model input; empty for
    /// sequential algorithms). Indexed `[thread][phase]`.
    pub thread_work: Vec<Vec<u64>>,
    /// Simulated parallel time from the critical-path cost model (seconds),
    /// 0.0 when not applicable.
    pub modeled_time: f64,
    /// Per-round telemetry samples (ParAMD only; at most
    /// [`paramd::arena::ROUND_RING_CAP`] retained, oldest dropped — see
    /// `round_samples_dropped`).
    pub round_samples: Vec<RoundSample>,
    /// Round samples dropped by the fixed-capacity ring (0 in practice —
    /// the cap far exceeds realistic round counts).
    pub round_samples_dropped: u64,
    /// Total elbow `claim` failures over the run (memory-contention
    /// signal; each one deferred a pivot and requested a GC).
    pub claim_failures: u64,
}

impl OrderingResult {
    pub fn new(perm: Vec<i32>) -> Self {
        let iperm = crate::graph::perm::invert_perm(&perm);
        Self {
            perm,
            iperm,
            phases: PhaseTimes::default(),
            stats: OrderingStats::default(),
        }
    }
}

/// Common interface for all ordering algorithms.
pub trait Ordering {
    /// Human-readable name for reports.
    fn name(&self) -> &'static str;
    /// Compute a fill-reducing ordering of the symmetric pattern `g`.
    fn order(&self, g: &SymGraph) -> OrderingResult;
}

/// Reconstruct the output permutation from a quotient-graph elimination:
/// `elim_order` lists the pivots in elimination order and `parent` is the
/// absorption forest (merged/mass-eliminated columns point at their
/// absorber; pivots and never-absorbed nodes hold -1 or a pivot).
///
/// Each original column is assigned to the pivot that consumed it (itself
/// if it was a pivot); buckets are emitted in elimination order with the
/// pivot first (intra-bucket order is free — absorbed columns are
/// indistinguishable from their pivot).
pub(crate) fn rebuild_perm(n: usize, elim_order: &[i32], parent: &[i32]) -> Vec<i32> {
    let mut scratch = RebuildScratch::default();
    let mut perm = Vec::new();
    rebuild_perm_into(n, elim_order, parent, &mut scratch, &mut perm);
    perm
}

/// Reusable buffers for [`rebuild_perm_into`] — lets warm-path callers
/// (the ParAMD arena) rebuild permutations without O(n) allocations.
#[derive(Debug, Default)]
pub(crate) struct RebuildScratch {
    pos_of_pivot: Vec<i32>,
    owner: Vec<i32>,
    cursor: Vec<usize>,
    chain: Vec<i32>,
}

/// [`rebuild_perm`] into a caller-owned output buffer; allocates only when
/// the scratch or output capacity is too small for `n`.
pub(crate) fn rebuild_perm_into(
    n: usize,
    elim_order: &[i32],
    parent: &[i32],
    s: &mut RebuildScratch,
    perm: &mut Vec<i32>,
) {
    s.pos_of_pivot.clear();
    s.pos_of_pivot.resize(n, -1);
    for (k, &e) in elim_order.iter().enumerate() {
        s.pos_of_pivot[e as usize] = k as i32;
    }
    s.owner.clear();
    s.owner.resize(n, -1);
    for v in 0..n {
        if s.owner[v] != -1 {
            continue;
        }
        s.chain.clear();
        s.chain.push(v as i32);
        let mut x = v;
        while s.pos_of_pivot[x] == -1 {
            let p = parent[x];
            debug_assert!(p >= 0, "node {x} neither pivot nor absorbed");
            x = p as usize;
            if s.owner[x] != -1 {
                x = s.owner[x] as usize;
                break;
            }
            s.chain.push(x as i32);
        }
        for &c in &s.chain {
            s.owner[c as usize] = x as i32;
        }
    }
    s.cursor.clear();
    s.cursor.resize(elim_order.len() + 1, 0);
    for v in 0..n {
        s.cursor[s.pos_of_pivot[s.owner[v] as usize] as usize + 1] += 1;
    }
    for k in 0..elim_order.len() {
        s.cursor[k + 1] += s.cursor[k];
    }
    perm.clear();
    perm.resize(n, 0);
    for (k, &e) in elim_order.iter().enumerate() {
        perm[s.cursor[k]] = e;
        s.cursor[k] += 1;
    }
    for v in 0..n {
        let k = s.pos_of_pivot[s.owner[v] as usize] as usize;
        if v as i32 != elim_order[k] {
            perm[s.cursor[k]] = v as i32;
            s.cursor[k] += 1;
        }
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::graph::perm::is_valid_perm;

    /// Assert the basic contract every ordering must satisfy.
    pub fn check_ordering_contract(g: &SymGraph, r: &OrderingResult) {
        assert_eq!(r.perm.len(), g.n);
        assert!(is_valid_perm(&r.perm), "perm is not a permutation");
        for k in 0..g.n {
            assert_eq!(r.iperm[r.perm[k] as usize], k as i32);
        }
    }
}
