//! Figure 4.2: distribution of distance-2 independent-set sizes across
//! elimination rounds (the violin plots), printed as five-number summaries
//! plus a text histogram and the share of rounds below 64 (the paper's
//! full-utilization threshold).

#[path = "bench_common/mod.rs"]
mod bench_common;

use paramd::bench_util::Table;
use paramd::matgen;
use paramd::ordering::paramd::ParAmd;
use paramd::util::stats;

fn main() {
    let t = bench_common::threads();
    bench_common::banner("Figure 4.2 — D2 set-size distributions", "paper §4.4 Fig 4.2");
    let mut table = Table::new(&[
        "Matrix", "rounds", "min", "p25", "median", "p75", "max", "frac < 64",
    ]);
    let mut hists = Vec::new();
    for e in matgen::suite() {
        let g = (e.gen)(bench_common::scale());
        let (r, _) = ParAmd::new(t).order_detailed(&g);
        let xs: Vec<f64> = r.stats.set_sizes.iter().map(|&s| s as f64).collect();
        let s = stats::summary(&xs);
        table.row(vec![
            e.name.into(),
            format!("{}", s.n),
            format!("{:.0}", s.min),
            format!("{:.0}", s.p25),
            format!("{:.0}", s.median),
            format!("{:.0}", s.p75),
            format!("{:.0}", s.max),
            format!("{:.2}", stats::frac_below(&xs, 64.0)),
        ]);
        hists.push((e.name, xs));
    }
    table.print();

    println!("\ntext violins (each row: size-bucket low edge, density bar):");
    for (name, xs) in hists {
        let (edges, counts) = stats::histogram(&xs, 8);
        let max = *counts.iter().max().unwrap_or(&1) as f64;
        println!("  {name}");
        for (e, c) in edges.iter().zip(&counts) {
            let bar = "#".repeat(((*c as f64 / max) * 40.0).round() as usize);
            println!("    {e:>8.0} | {bar}");
        }
    }
    println!("\npaper shape: nd24k's sets are smallest (worst scaling); a significant");
    println!("fraction of rounds sit below 64 even for the best matrices.");
}
