//! Chaos & overload integration suite: admission control sheds instead
//! of blocking, interactive traffic overtakes queued batch work end to
//! end, deadlines resolve to typed errors, and every production
//! failpoint — scheduler panic, dispatcher panic, arena exhaustion,
//! stage latency, cache verify-reject — leaves the service able to
//! serve a clean follow-up: no wedged waiter, no leaked arena, no
//! corrupted later permutation.
//!
//! The failpoint registry is process-global, so every test here takes
//! the `serial()` gate and disarms on entry and exit. This binary is
//! the one place the production site names may be armed (library unit
//! tests use `test-fp-*` names so they can never poison a service).

use std::sync::Mutex;
use std::time::{Duration, Instant};

use paramd::coordinator::{Method, OrderError, OrderRequest, Service, SubmitOptions};
use paramd::graph::csr::SymGraph;
use paramd::graph::perm::is_valid_perm;
use paramd::matgen::mesh2d;
use paramd::util::failpoint::{self, FailAction};

fn serial() -> std::sync::MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn req(g: SymGraph) -> OrderRequest {
    OrderRequest {
        matrix: None,
        pattern: Some(g),
        method: Method::ParAmd {
            threads: 1,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    }
}

#[test]
fn overload_sheds_with_rejected_instead_of_blocking() {
    let _g = serial();
    failpoint::disarm_all();
    // Every accepted request sleeps 40ms in the order stage, so the
    // in-flight gauge stays pinned while the burst lands.
    failpoint::arm(
        failpoint::STAGE_LATENCY,
        FailAction::Sleep(Duration::from_millis(40)),
        None,
    );
    let svc = Service::new(1)
        .with_scheduler_threads(1)
        .with_queue_cap(4)
        .with_max_inflight(2);
    let g = mesh2d(20, 20);
    let t0 = Instant::now();
    let mut accepted = Vec::new();
    let mut shed = 0usize;
    for _ in 0..12 {
        match svc.try_submit(req(g.clone())) {
            Ok(t) => accepted.push(t),
            Err(r) => {
                match r.error {
                    OrderError::Rejected { retry_after_hint } => {
                        assert!(retry_after_hint > Duration::ZERO, "hint must size a backoff")
                    }
                    ref other => panic!("expected Rejected, got {other:?}"),
                }
                // The shed hands the request back untouched for retry.
                assert!(r.request.pattern.is_some());
                shed += 1;
            }
        }
    }
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "try_submit must answer immediately, not block on the budget"
    );
    assert_eq!(accepted.len(), 2, "exactly the in-flight budget is admitted");
    assert_eq!(shed, 10);
    for t in accepted {
        let rep = t.wait_result().expect("admitted requests must complete");
        assert!(is_valid_perm(&rep.perm));
    }
    assert_eq!(svc.metrics().pipeline.rejected, 10);
    // Budget free again: a retry is admitted. The gauge drops just
    // *after* each ticket resolves, so back off briefly like a real
    // caller instead of asserting on the first attempt.
    let t1 = Instant::now();
    let ticket = loop {
        match svc.try_submit(req(g.clone())) {
            Ok(t) => break t,
            Err(_) if t1.elapsed() < Duration::from_secs(5) => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(r) => panic!("budget never drained: {}", r.error),
        }
    };
    assert!(is_valid_perm(&ticket.wait_result().unwrap().perm));
    failpoint::disarm_all();
}

#[test]
fn caller_quota_sheds_the_second_burst_token() {
    let _g = serial();
    failpoint::disarm_all();
    let svc = Service::new(1).with_scheduler_threads(1).with_caller_quota(1.0, 1.0);
    let opts = SubmitOptions::default().with_caller("tester");
    let g = mesh2d(10, 10);
    let first = svc.try_submit_opts(req(g.clone()), &opts).expect("burst token admits");
    let second = svc.try_submit_opts(req(g.clone()), &opts);
    match second {
        Err(r) => match r.error {
            OrderError::Rejected { retry_after_hint } => {
                assert!(retry_after_hint > Duration::ZERO)
            }
            ref other => panic!("expected Rejected, got {other:?}"),
        },
        Ok(_) => panic!("second submission must be out of quota tokens"),
    }
    // An anonymous submission is unmetered.
    let anon = svc.try_submit(req(g.clone())).expect("no caller, no quota");
    assert!(first.wait_result().is_ok());
    assert!(anon.wait_result().is_ok());
    failpoint::disarm_all();
}

#[test]
fn interactive_requests_overtake_queued_batch_work() {
    let _g = serial();
    failpoint::disarm_all();
    // One scheduler, every job slowed to 120ms: the blocker occupies
    // the scheduler while three batch jobs and one interactive job
    // queue behind it. The interactive lane must drain first.
    failpoint::arm(
        failpoint::STAGE_LATENCY,
        FailAction::Sleep(Duration::from_millis(120)),
        None,
    );
    let svc = Service::new(1).with_scheduler_threads(1).with_queue_cap(16);
    let g = mesh2d(12, 12);
    let blocker = svc.submit(req(g.clone()));
    std::thread::sleep(Duration::from_millis(60));
    let mut work = vec![("blocker", blocker)];
    for tag in ["batch-a", "batch-b", "batch-c"] {
        work.push((tag, svc.submit(req(g.clone()))));
    }
    let inter = svc.submit_opts(req(g.clone()), &SubmitOptions::interactive());
    work.push(("interactive", inter));
    let done: Mutex<Vec<(&str, Instant)>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for (tag, ticket) in work {
            let done = &done;
            s.spawn(move || {
                ticket.wait_result().unwrap_or_else(|e| panic!("{tag} failed: {e}"));
                done.lock().unwrap().push((tag, Instant::now()));
            });
        }
    });
    let done = done.into_inner().unwrap();
    let at = |tag: &str| {
        done.iter()
            .find(|(t, _)| *t == tag)
            .unwrap_or_else(|| panic!("{tag} never completed"))
            .1
    };
    for batch in ["batch-a", "batch-b", "batch-c"] {
        assert!(
            at("interactive") < at(batch),
            "interactive must complete before queued batch job {batch}"
        );
    }
    failpoint::disarm_all();
}

#[test]
fn deadlines_resolve_to_the_typed_error_never_a_panic() {
    let _g = serial();
    failpoint::disarm_all();
    let svc = Service::new(1).with_scheduler_threads(1);
    let g = mesh2d(10, 10);
    // Dead on arrival: the pickup check abandons it with zero work.
    let expired = SubmitOptions::default().with_deadline_in(Duration::ZERO);
    let doa = svc.submit_opts(req(g.clone()), &expired);
    assert_eq!(doa.wait_result(), Err(OrderError::DeadlineExceeded));
    // Mid-flight: the stage sleeps past the budget, and the next stage
    // boundary abandons the request.
    failpoint::arm(
        failpoint::STAGE_LATENCY,
        FailAction::Sleep(Duration::from_millis(100)),
        Some(1),
    );
    let late = svc.submit_opts(
        req(g.clone()),
        &SubmitOptions::default().with_deadline_in(Duration::from_millis(30)),
    );
    assert_eq!(late.wait_result(), Err(OrderError::DeadlineExceeded));
    // A deadline-free follow-up is untouched by the expiries.
    let rep = svc.submit(req(g.clone())).wait_result().expect("clean follow-up");
    assert!(is_valid_perm(&rep.perm));
    assert_eq!(svc.metrics().pipeline.deadline_exceeded, 2);
    failpoint::disarm_all();
}

#[test]
fn worker_panic_is_contained_and_the_arena_returns_to_the_pool() {
    let _g = serial();
    failpoint::disarm_all();
    // 1 shard x 1 thread x 1 arena, cache off: fully deterministic
    // recompute path, and a leaked arena would deadlock the follow-ups.
    let svc = Service::new(1)
        .with_scheduler_threads(1)
        .with_shards(1)
        .with_shard_threads(1)
        .with_arena_cap(1)
        .with_result_cache(0);
    let g = mesh2d(15, 15);
    let reference = svc.order(&req(g.clone())).perm;
    assert!(is_valid_perm(&reference));

    // Poison one request: the dispatcher panics with the arena checked
    // out, mid-elimination setup.
    failpoint::arm(failpoint::DISPATCHER_PANIC, FailAction::Panic, Some(1));
    match svc.submit(req(g.clone())).wait_result() {
        Err(OrderError::Failed(why)) => {
            assert!(why.contains("panicked"), "failure must name the panic: {why}")
        }
        other => panic!("poisoned request must fail typed, got {other:?}"),
    }
    assert_eq!(failpoint::fired(failpoint::DISPATCHER_PANIC), 1);
    assert_eq!(
        svc.idle_arenas(),
        1,
        "the unwind must return the checked-out arena to the pool"
    );

    // The service is clean: 100 follow-ups, all bit-identical to the
    // pre-panic reference.
    for i in 0..100 {
        let rep = svc
            .submit(req(g.clone()))
            .wait_result()
            .unwrap_or_else(|e| panic!("follow-up {i} failed after the contained panic: {e}"));
        assert_eq!(rep.perm, reference, "follow-up {i} diverged after the contained panic");
    }
    failpoint::disarm_all();
}

#[test]
fn every_failpoint_leaves_the_service_serviceable() {
    let _g = serial();
    failpoint::disarm_all();
    // Cache off so every request reaches the dispatcher/arena sites;
    // arena cap 1 so a leak would hang the follow-up instead of hiding.
    let svc = Service::new(1)
        .with_scheduler_threads(1)
        .with_shard_threads(1)
        .with_arena_cap(1)
        .with_result_cache(0);
    let g = mesh2d(18, 18);
    let cases: [(&str, FailAction, Option<u64>); 4] = [
        (failpoint::SCHEDULER_PANIC, FailAction::Panic, Some(1)),
        (failpoint::DISPATCHER_PANIC, FailAction::Panic, Some(1)),
        (failpoint::ARENA_CHECKOUT, FailAction::Panic, Some(1)),
        (
            failpoint::STAGE_LATENCY,
            FailAction::Sleep(Duration::from_millis(25)),
            Some(1),
        ),
    ];
    for (name, action, limit) in cases {
        failpoint::arm(name, action, limit);
        match svc.submit(req(g.clone())).wait_result() {
            Ok(rep) => assert!(is_valid_perm(&rep.perm), "{name}: bad perm"),
            Err(OrderError::Failed(why)) => {
                assert!(why.contains("panicked"), "{name}: unexpected failure: {why}")
            }
            Err(other) => panic!("{name}: unexpected outcome {other:?}"),
        }
        assert!(failpoint::fired(name) >= 1, "{name} never fired");
        let rep = svc
            .submit(req(g.clone()))
            .wait_result()
            .unwrap_or_else(|e| panic!("{name}: clean follow-up failed: {e}"));
        assert!(is_valid_perm(&rep.perm), "{name}: follow-up perm invalid");
        assert_eq!(svc.idle_arenas(), 1, "{name}: arena leaked");
        failpoint::disarm_all();
    }

    // Cache verify-reject: a forced reject downgrades a would-be hit to
    // a miss; the request still answers with the same permutation.
    let cached = Service::new(1).with_scheduler_threads(1).with_shard_threads(1);
    let cg = mesh2d(16, 16);
    let first = cached.order(&req(cg.clone()));
    failpoint::arm(failpoint::CACHE_VERIFY, FailAction::Reject, Some(1));
    let second = cached.order(&req(cg.clone()));
    assert_eq!(failpoint::fired(failpoint::CACHE_VERIFY), 1);
    assert_eq!(first.perm, second.perm, "verify-reject must never corrupt the reply");
    assert!(cached.metrics().cache.verify_rejects >= 1);
    failpoint::disarm_all();
}
