//! Warm-path integration: runtime/arena reuse correctness and concurrent
//! service requests through the shared persistent pool.

use paramd::coordinator::{Method, OrderRequest, Service};
use paramd::graph::perm::is_valid_perm;
use paramd::matgen::{mesh2d, mesh3d, random_graph};
use paramd::ordering::paramd::arena::{ArenaPool, ParAmdArena};
use paramd::ordering::paramd::runtime::OrderingRuntime;
use paramd::ordering::paramd::ParAmd;
use paramd::ordering::Ordering as _;

/// The full ordering contract every reply must satisfy (mirror of the
/// crate-internal `check_ordering_contract`, which integration tests
/// cannot reach).
fn assert_contract(n: usize, perm: &[i32]) {
    assert_eq!(perm.len(), n);
    assert!(is_valid_perm(perm), "perm is not a permutation");
}

#[test]
fn warm_runs_bitmatch_cold_across_seeds() {
    // Single-thread ParAMD is deterministic, so warm reuse must reproduce
    // the cold run exactly for every seed.
    let g = random_graph(500, 6, 17);
    let rt = OrderingRuntime::new(1);
    let mut arena = ParAmdArena::new();
    for seed in [1u64, 2, 3] {
        let cfg = ParAmd::new(1).with_seed(seed);
        let cold = cfg.order(&g);
        for _ in 0..2 {
            let warm = cfg.order_into(&rt, &mut arena, &g);
            assert_eq!(warm.perm, cold.perm, "seed {seed} diverged");
        }
    }
}

#[test]
fn warm_multithread_reuse_is_valid_on_mixed_sizes() {
    let rt = OrderingRuntime::new(4);
    let mut arena = ParAmdArena::new();
    let cfg = ParAmd::new(4);
    let graphs = [
        mesh2d(22, 22),
        mesh3d(7, 7, 7),
        mesh2d(3, 3),
        random_graph(900, 6, 5),
        mesh2d(22, 22),
    ];
    for g in &graphs {
        let r = cfg.order_into(&rt, &mut arena, g);
        assert_contract(g.n, &r.perm);
        for k in 0..g.n {
            assert_eq!(r.iperm[r.perm[k] as usize] as usize, k, "iperm broken");
        }
    }
}

#[test]
fn arena_pool_hands_out_warm_arenas() {
    let pool = ArenaPool::new();
    let rt = OrderingRuntime::new(2);
    let cfg = ParAmd::new(2);
    let g = mesh2d(18, 18);

    let mut arena = pool.acquire();
    cfg.order_into(&rt, &mut arena, &g);
    let grown = arena.grow_events();
    pool.release(arena);

    // Re-acquire: must be the same warm arena, and a same-size run must
    // not grow it.
    let mut arena = pool.acquire();
    assert_eq!(arena.grow_events(), grown);
    let r = cfg.order_into(&rt, &mut arena, &g);
    assert_contract(g.n, &r.perm);
    assert_eq!(arena.grow_events(), grown, "warm pooled run must not grow");
    pool.release(arena);
    assert_eq!(pool.idle(), 1);
}

#[test]
fn concurrent_service_requests_all_satisfy_the_contract() {
    let svc = Service::new(2);
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for i in 0..6u64 {
            let svc = &svc;
            handles.push(s.spawn(move || {
                let g = random_graph(200 + 60 * i as usize, 5, i);
                let rep = svc.order(&OrderRequest {
                    matrix: None,
                    pattern: Some(g.clone()),
                    method: Method::ParAmd {
                        threads: 2,
                        mult: 1.1,
                        lim_total: 0,
                    },
                    compute_fill: false,
                });
                assert_contract(g.n, &rep.perm);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
    assert_eq!(svc.metrics().total_requests(), 6);
    assert!(
        svc.idle_arenas() >= 1,
        "arenas must return to the pool after the burst"
    );
}

#[test]
fn service_mixed_methods_interleave_with_warm_paramd() {
    // ParAMD requests share the runtime while other methods run inline;
    // interleaving must not corrupt pooled state.
    let svc = Service::new(2);
    let g = mesh2d(16, 16);
    for i in 0..6 {
        let method = if i % 2 == 0 {
            Method::ParAmd {
                threads: 2,
                mult: 1.1,
                lim_total: 0,
            }
        } else {
            Method::Amd
        };
        let rep = svc.order(&OrderRequest {
            matrix: None,
            pattern: Some(g.clone()),
            method,
            compute_fill: true,
        });
        assert_contract(g.n, &rep.perm);
        assert!(rep.fill_in.unwrap() >= 0);
    }
}
