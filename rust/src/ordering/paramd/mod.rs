//! ParAMD — the paper's contribution (§3): parallel approximate minimum
//! degree via multiple elimination on distance-2 independent sets.
//!
//! Algorithm 3.3 round structure, executed by `threads` worker threads
//! synchronized with barriers:
//!
//! 1. every thread publishes its local minimum approximate degree
//!    (`LAMD`, Algorithm 3.1) — the global `amd` is their minimum;
//! 2. candidates with degree in `[amd, ⌊mult·amd⌋]` are gathered from the
//!    per-thread degree lists, at most `lim` per thread;
//! 3. one iteration of the distance-2 Luby analog (Algorithm 3.2) selects
//!    a distance-2 independent pivot set `D`;
//! 4. each thread eliminates the pivots it proposed, with concurrent
//!    connection updates (single elbow claim per pivot, §3.3.1) and
//!    concurrent degree lists (§3.3.2);
//! 5. a stop-the-world GC runs at the round boundary if any claim failed;
//! 6. at configured triggers (every K rounds and/or a small-set elbow) a
//!    **mid-elimination re-reduction** sweep runs in the same
//!    stop-the-world window ([`crate::ordering::reduce::live`]): all
//!    threads fingerprint the live quotient graph in parallel, then the
//!    leader merges global twins, absorbs subset elements, and
//!    re-postpones rows that crossed the dense threshold.
//!
//! ## Warm-path architecture (runtime + arena)
//!
//! The execution substrate is split from the algorithm so repeated
//! orderings are spawn-free and allocation-free:
//!
//! - [`runtime::OrderingRuntime`] — a persistent worker pool. Workers are
//!   spawned once, park on a condvar between requests, and synchronize on
//!   a reusable round [`Barrier`] while running.
//! - [`arena::ParAmdArena`] — pooled per-run storage: the [`SharedGraph`]
//!   slab, per-thread [`workspace::Workspace`]/[`lists::ThreadLists`]
//!   slots, the Luby `l_min` array, and the result-assembly scratch. All
//!   of it grows monotonically and is reset by bulk stores or epoch
//!   bumps, never reallocation, when the next graph fits.
//! - The per-thread hot counters (`lamds`, `sizes`) are cache-line padded
//!   ([`arena::CachePadded`]) against the intra-step false sharing the
//!   paper identifies in §4.
//!
//! [`ParAmd::order_into`] is the warm entry point: it borrows a runtime
//! and an arena and leaves the result in the arena's pooled buffers.
//! [`ParAmd::order`] / [`ParAmd::order_detailed`] remain the one-shot
//! convenience (cold: they build a transient runtime + arena per call).
//!
//! Memory: O(n·t) for the per-thread lists and `w` arrays plus the
//! `1.5×nnz`-style elbow — the paper's §3.5.1 budget.

pub mod arena;
pub mod cost;
pub mod dist2;
pub mod elim;
pub mod lists;
pub mod runtime;
pub mod shared;
pub mod workspace;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{Barrier, Mutex};

use crate::graph::csr::SymGraph;
use crate::ordering::reduce::live;
use crate::ordering::{Ordering, OrderingResult};
use crate::util::chunk_range;
use crate::util::timer::Timer;

use arena::{CachePadded, ParAmdArena, ThreadSlot};
use elim::Outcome;
use lists::Affinity;
use runtime::OrderingRuntime;
use shared::SharedGraph;
use workspace::RoundWork;

/// ParAMD configuration (paper defaults: `mult = 1.1`,
/// `lim = 8192 / threads`, elbow `1.5`).
#[derive(Clone, Copy, Debug)]
pub struct ParAmd {
    pub threads: usize,
    /// Multiplicative degree-relaxation factor (§3.2).
    pub mult: f64,
    /// Total candidate budget per round; each thread collects at most
    /// `lim_total / threads` (§4.3's heuristic). `0` selects the
    /// scale-adapted default `clamp(n/64, 64, 8192)` — the paper's 8192
    /// was tuned for n ≈ 10⁶–10⁷ (0.03–0.8% of n); keeping the *fraction*
    /// comparable preserves the ~1.1× fill-ratio target at any scale.
    pub lim_total: usize,
    /// Elbow-room factor over nnz (§3.3.1's empirical 1.5).
    pub elbow: f64,
    /// Aggressive element absorption (as in SuiteSparse).
    pub aggressive: bool,
    /// Seed for the Luby priorities.
    pub seed: u64,
    /// §5 future-work extension: dynamically adapt the relaxation factor
    /// when low workload is detected. When the last round's distance-2
    /// set was smaller than the thread count, `mult` is raised (up to
    /// `adaptive_mult_max`); when parallelism is plentiful it decays back
    /// toward the configured base, bounding the fill-quality cost.
    pub adaptive: bool,
    /// Upper bound for the adapted relaxation factor.
    pub adaptive_mult_max: f64,
    /// Mid-elimination re-reduction master switch: run the
    /// [`crate::ordering::reduce::live`] sweep (global twin
    /// re-compression + subset element absorption + dense
    /// re-postponement) at round boundaries.
    pub rereduce: bool,
    /// Run the sweep every K rounds (`0` disables the periodic trigger).
    pub rereduce_every: u32,
    /// Elbow trigger: sweep when the last distance-2 set was smaller
    /// than `rereduce_elbow × threads` — elimination is starved, so
    /// shrinking the graph is the best use of the boundary
    /// (`0.0` disables).
    pub rereduce_elbow: f64,
}

impl ParAmd {
    pub fn new(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
            mult: 1.1,
            lim_total: 0, // auto: clamp(n/64, 64, 8192)
            elbow: 1.5,
            aggressive: true,
            seed: 0x9a_2a_3d,
            adaptive: false,
            adaptive_mult_max: 1.5,
            rereduce: true,
            rereduce_every: 4,
            rereduce_elbow: 0.0,
        }
    }

    /// Enable the §5 future-work dynamic-relaxation extension.
    pub fn with_adaptive(mut self) -> Self {
        self.adaptive = true;
        self
    }

    pub fn with_mult(mut self, mult: f64) -> Self {
        self.mult = mult;
        self
    }

    pub fn with_lim_total(mut self, lim: usize) -> Self {
        self.lim_total = lim;
        self
    }

    pub fn with_elbow(mut self, elbow: f64) -> Self {
        self.elbow = elbow;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Toggle mid-elimination re-reduction (on by default).
    pub fn with_rereduce(mut self, on: bool) -> Self {
        self.rereduce = on;
        self
    }

    /// Periodic trigger: sweep every `every` rounds (`0` = never).
    pub fn with_rereduce_every(mut self, every: u32) -> Self {
        self.rereduce_every = every;
        self
    }

    /// Starvation trigger: sweep when the last distance-2 set dropped
    /// below `elbow × threads` (`0.0` = never).
    pub fn with_rereduce_elbow(mut self, elbow: f64) -> Self {
        self.rereduce_elbow = elbow;
        self
    }
}

impl Ordering for ParAmd {
    fn name(&self) -> &'static str {
        "paramd"
    }

    fn order(&self, g: &SymGraph) -> OrderingResult {
        self.order_detailed(g).0
    }
}

/// Detailed per-run data beyond [`OrderingResult`]: the inputs to the
/// Figure 4.1 / 4.2 analyses and the cost model.
#[derive(Clone, Debug, Default)]
pub struct ParAmdDetail {
    /// `work[r][tid]` — per-round per-thread work counters.
    pub round_work: Vec<Vec<RoundWork>>,
    /// Per-round distance-2 set sizes (Figure 4.2).
    pub set_sizes: Vec<u32>,
    /// Wall-clock seconds per thread spent in selection vs elimination.
    pub select_secs: Vec<f64>,
    pub elim_secs: Vec<f64>,
    /// Modeled parallel speedup from the critical-path cost model.
    pub model_speedup: f64,
}

impl ParAmd {
    /// One-shot run with detailed counters (cold path: builds a transient
    /// runtime and arena; thread count taken from `self.threads`).
    pub fn order_detailed(&self, g: &SymGraph) -> (OrderingResult, ParAmdDetail) {
        let rt = OrderingRuntime::new(self.threads.max(1));
        let mut arena = ParAmdArena::new();
        self.order_into(&rt, &mut arena, g);
        arena.take_results()
    }

    /// Warm entry point: run the ordering on a persistent [`OrderingRuntime`]
    /// using pooled [`ParAmdArena`] storage, leaving the result (and the
    /// detailed counters) in the arena's reusable buffers.
    ///
    /// The effective thread count is `rt.threads()` — the pool it runs on —
    /// not `self.threads`. When the arena's retained storage fits `g`, the
    /// whole run performs no O(n)- or O(nnz)-sized heap allocations
    /// (observable via [`ParAmdArena::grow_events`]).
    pub fn order_into<'a>(
        &self,
        rt: &OrderingRuntime,
        arena: &'a mut ParAmdArena,
        g: &SymGraph,
    ) -> &'a OrderingResult {
        let cancel = AtomicBool::new(false);
        self.order_into_cancellable(rt, arena, g, &cancel)
            .expect("a never-cancelled run always completes")
    }

    /// [`Self::order_into`] with a cooperative cancellation flag: when
    /// `cancel` is observed set, the run aborts at the next **round
    /// boundary** (the leader raises an abort flag in phase D and every
    /// worker exits after the barrier) and `None` is returned — no
    /// result is assembled and the arena's pooled state is simply reset
    /// by its next `prepare`. The coordinator wires a dropped request
    /// ticket into this flag so abandoned orderings stop wasting the
    /// shared pool mid-elimination instead of running to completion.
    pub fn order_into_cancellable<'a>(
        &self,
        rt: &OrderingRuntime,
        arena: &'a mut ParAmdArena,
        g: &SymGraph,
        cancel: &AtomicBool,
    ) -> Option<&'a OrderingResult> {
        self.order_into_cancellable_weighted(rt, arena, g, None, cancel)
    }

    /// [`Self::order_into_cancellable`] with **seed supervariables**:
    /// `weights[v]` becomes vertex `v`'s initial `nv` (the reduction
    /// layer's twin-class sizes), so elimination starts on the
    /// pre-compressed quotient graph. All degrees, candidate windows,
    /// and the elimination target are *weighted* (total column weight,
    /// not vertex count) — the run behaves exactly as if AMD itself had
    /// already merged the twins. The resulting permutation ranges over
    /// the `g.n` kernel vertices; callers expand it back
    /// ([`crate::ordering::reduce::ReductionPlan::expand`]).
    pub fn order_into_cancellable_weighted<'a>(
        &self,
        rt: &OrderingRuntime,
        arena: &'a mut ParAmdArena,
        g: &SymGraph,
        weights: Option<&[i32]>,
        cancel: &AtomicBool,
    ) -> Option<&'a OrderingResult> {
        let n = g.n;
        let t = rt.threads();
        let lim_total = if self.lim_total == 0 {
            (n / 64).clamp(64, 8192)
        } else {
            self.lim_total
        };
        let lim = (lim_total / t).max(1);
        let total_timer = Timer::new();

        assert!(
            n < dist2::MAX_VERTICES,
            "ParAMD supports up to 2^24 vertices (priority packing)"
        );
        arena.prepare(g, self, t, weights);
        // Total column weight: the elimination target and the degree
        // ceiling (== n unless supervariables were seeded).
        let wtot = arena.sg.weight;
        if n == 0 {
            return Some(&arena.result);
        }
        if cancel.load(Relaxed) {
            return None; // cancelled before the first round
        }

        {
            let shared = RunShared {
                cfg: *self,
                g,
                sg: &arena.sg,
                aff: &arena.aff,
                lmin: &arena.lmin[..n],
                lamds: &arena.lamds[..t],
                sizes: &arena.sizes[..t],
                barrier: rt.barrier(),
                progress_stall: &arena.progress_stall,
                adaptive_mult: &arena.adaptive_mult,
                poison: &arena.poison,
                abort: &arena.abort,
                cancel,
                gc_count: &arena.gc_count,
                gc_nanos: &arena.gc_nanos,
                rr: &arena.rereduce,
                round_log: &arena.round_log,
                set_sizes: &arena.set_sizes,
                t,
                lim,
                wtot,
            };
            let slots = &arena.slots;
            // Weight = vertex count, the SmallestFirst queue-policy key.
            rt.run_weighted(n, &|tid| {
                let mut slot = slots[tid].lock().unwrap();
                run_thread(tid, &shared, &mut slot);
            });
        }

        if arena.abort.load(Relaxed) {
            return None;
        }
        assert!(
            !arena.poison.load(Relaxed),
            "ParAMD stalled: elbow room exhausted even after GC — increase \
             `elbow` (paper §3.3.1: the 1.5 factor is empirical and \
             user-adjustable)"
        );
        assert_eq!(arena.sg.nel.load(Relaxed), wtot, "not all columns eliminated");

        arena.assemble(t, total_timer.secs());
        Some(&arena.result)
    }
}

/// Borrowed per-run state shared by every worker (all of it lives in the
/// arena or the runtime; this struct is just the view handed to threads).
struct RunShared<'a> {
    cfg: ParAmd,
    g: &'a SymGraph,
    sg: &'a SharedGraph,
    aff: &'a Affinity,
    lmin: &'a [AtomicU64],
    lamds: &'a [CachePadded<AtomicUsize>],
    sizes: &'a [CachePadded<AtomicUsize>],
    barrier: &'a Barrier,
    progress_stall: &'a AtomicUsize,
    /// Adapted relaxation factor as `f64::to_bits` (exact round-trip).
    adaptive_mult: &'a AtomicU64,
    poison: &'a AtomicBool,
    /// Raised by the leader once `cancel` is observed; every worker
    /// exits at the round boundary after it.
    abort: &'a AtomicBool,
    /// External cancellation request (e.g. a dropped service ticket).
    cancel: &'a AtomicBool,
    gc_count: &'a AtomicUsize,
    /// Stop-the-world GC nanoseconds (leader-only writes).
    gc_nanos: &'a AtomicU64,
    /// Mid-elimination re-reduction state: the leader-armed trigger
    /// flag, the shared fingerprint scratch, and the sweep counters.
    rr: &'a arena::RereduceState,
    /// Per-round telemetry ring (leader-only writes, phase D).
    round_log: &'a arena::RoundLog,
    set_sizes: &'a Mutex<Vec<u32>>,
    t: usize,
    lim: usize,
    /// Total column weight (`Σ nv` at setup): the weighted-degree
    /// ceiling and the empty-lists sentinel. Equals `n` unless seed
    /// supervariables were fed in.
    wtot: usize,
}

fn run_thread(tid: usize, sh: &RunShared<'_>, slot: &mut ThreadSlot) {
    let n = sh.g.n;
    let cfg = sh.cfg;

    // Initial population: static chunk of the vertices. Degrees come
    // from the quotient graph, which already holds the *weighted*
    // external degree when supervariables were seeded.
    let (lo, hi) = chunk_range(n, sh.t, tid);
    for v in lo..hi {
        slot.lists.insert(sh.aff, v, sh.sg.deg_of(v) as usize);
    }

    let mut round: u32 = 0;
    loop {
        let tsel = Timer::new();
        // Phase A: global minimum approximate degree.
        sh.lamds[tid].store(slot.lists.lamd(sh.aff), Relaxed);
        sh.barrier.wait();
        let amd = sh.lamds.iter().map(|a| a.load(Relaxed)).min().unwrap();
        if amd >= sh.wtot {
            break; // no live variables anywhere
        }

        // Phase B: candidates + Luby distance-2 independent set. The
        // round-stamped priorities make explicit l_min resets (and their
        // barrier) unnecessary.
        assert!(round <= dist2::MAX_ROUNDS, "round counter overflow");
        let mut work = RoundWork::default();
        let mult = if cfg.adaptive {
            f64::from_bits(sh.adaptive_mult.load(Relaxed))
        } else {
            cfg.mult
        };
        dist2::collect_candidates(
            &mut slot.lists,
            sh.aff,
            &mut slot.ws,
            amd,
            mult,
            sh.lim,
            sh.wtot,
        );
        dist2::luby_prepare(sh.sg, &mut slot.ws, round, &mut work.select);
        dist2::luby_min(&slot.ws, sh.lmin, &mut work.select);
        sh.barrier.wait();
        dist2::luby_validate(&mut slot.ws, sh.lmin, &mut work.select);
        slot.select_secs += tsel.secs();

        // Phase C: eliminate this thread's pivots.
        let telim = Timer::new();
        let mut eliminated_here: usize = 0;
        let pivots = std::mem::take(&mut slot.ws.my_pivots);
        for &p in &pivots {
            if sh.sg.st(p as usize) != shared::ST_VAR {
                debug_assert!(false, "pivot died before elimination");
                continue;
            }
            match elim::eliminate_pivot(
                sh.sg,
                &mut slot.ws,
                &mut slot.lists,
                sh.aff,
                p as usize,
                cfg.aggressive,
                &mut work.elim,
            ) {
                Outcome::Eliminated { .. } => {
                    slot.elim_log.push((round, p));
                    eliminated_here += 1;
                }
                Outcome::Deferred => break, // elbow exhausted; stop batch
            }
        }
        slot.ws.my_pivots = pivots;
        work.pivots = eliminated_here as u32;
        sh.sizes[tid].store(eliminated_here, Relaxed);
        slot.ws.work_log.push(work);
        slot.elim_secs += telim.secs();
        sh.barrier.wait();

        // Phase D: leader bookkeeping — GC, set sizes, stall detection.
        if tid == 0 {
            let total: usize = sh.sizes.iter().map(|s| s.load(Relaxed)).sum();
            if total > 0 {
                sh.set_sizes.lock().unwrap().push(total as u32);
                sh.progress_stall.store(0, Relaxed);
            } else {
                sh.progress_stall.fetch_add(1, Relaxed);
            }
            if sh.sg.gc_requested.load(Relaxed) {
                // Every peer is parked at the barrier below, so this
                // whole window is stop-the-world time.
                let tgc = Timer::new();
                sh.sg.garbage_collect_exclusive();
                sh.gc_count.fetch_add(1, Relaxed);
                sh.gc_nanos
                    .fetch_add(tgc.elapsed().as_nanos() as u64, Relaxed);
            }
            if cfg.adaptive {
                // §5 extension: widen the degree window when the round was
                // starved of parallelism; relax back otherwise.
                let cur = f64::from_bits(sh.adaptive_mult.load(Relaxed));
                let next = if total < sh.t {
                    (cur * 1.05).min(cfg.adaptive_mult_max)
                } else if total > 4 * sh.t {
                    (cur * 0.98).max(cfg.mult)
                } else {
                    cur
                };
                sh.adaptive_mult.store(next.to_bits(), Relaxed);
            }
            if sh.progress_stall.load(Relaxed) >= 3 {
                // Elbow exhausted and GC is no longer reclaiming anything:
                // poison the run so every thread exits at the next check
                // (a direct panic here would strand peers at the barrier).
                sh.poison.store(true, Relaxed);
            }
            if sh.cancel.load(Relaxed) {
                // The request was abandoned (dropped ticket): abort at
                // this round boundary instead of finishing the ordering.
                sh.abort.store(true, Relaxed);
            }
            // Arm (or disarm) the re-reduction sweep for phase E. The
            // leader stores every round, so the flag never goes stale.
            let by_round =
                cfg.rereduce_every > 0 && (round + 1) % cfg.rereduce_every == 0;
            let by_elbow =
                cfg.rereduce_elbow > 0.0 && (total as f64) < cfg.rereduce_elbow * sh.t as f64;
            sh.rr.flag.store(cfg.rereduce && (by_round || by_elbow), Relaxed);
            // Round telemetry: pivot/weight deltas, live census, and the
            // stop-the-world charges. Peers are parked at the barrier, so
            // the O(n) live scan runs inside time already accounted as a
            // round boundary. This boundary's phase-E sweep runs *after*
            // this record, so its time lands on the next sample.
            let live_vars = (0..n).filter(|&v| sh.sg.st(v) == shared::ST_VAR).count();
            sh.round_log.note_round(
                round,
                total as u32,
                live_vars as u32,
                sh.sg.nel.load(Relaxed),
                sh.wtot,
                sh.sg.claim_failures.load(Relaxed),
                sh.gc_nanos.load(Relaxed),
                sh.rr.nanos.load(Relaxed),
            );
        }
        sh.barrier.wait();
        if sh.poison.load(Relaxed) || sh.abort.load(Relaxed) {
            break;
        }

        // Phase E: mid-elimination re-reduction, inside the same
        // stop-the-world regime as GC. Every thread fingerprints its
        // static vertex chunk of the live quotient graph; after the
        // barrier the leader (sole mutator — peers park at the second
        // barrier) nominates, verifies and merges global twins, absorbs
        // subset elements, and re-postpones dense rows.
        if sh.rr.flag.load(Relaxed) {
            live::fingerprint_chunk(sh.sg, lo, hi, &sh.rr.fp[..n], &sh.rr.cnt[..n]);
            sh.barrier.wait();
            if tid == 0 {
                let trr = Timer::new();
                let mut keys = sh.rr.keys.lock().unwrap();
                let mut postponed = sh.rr.postponed.lock().unwrap();
                let out = live::rereduce_exclusive(
                    sh.sg,
                    sh.aff,
                    &mut slot.ws,
                    &sh.rr.fp[..n],
                    &sh.rr.cnt[..n],
                    &mut keys,
                    &mut postponed,
                );
                if out.dense_postponed > 0 {
                    // Postponed rows reach the permutation through the
                    // arena's tail, outside every per-thread elim log;
                    // an extra set-sizes entry keeps Σ sizes == pivots.
                    sh.set_sizes
                        .lock()
                        .unwrap()
                        .push(out.dense_postponed as u32);
                }
                sh.rr.passes.fetch_add(1, Relaxed);
                sh.rr.twins.fetch_add(out.twins_merged, Relaxed);
                sh.rr.dense.fetch_add(out.dense_postponed, Relaxed);
                sh.rr.absorbed.fetch_add(out.elements_absorbed, Relaxed);
                sh.rr.nanos
                    .fetch_add(trr.elapsed().as_nanos() as u64, Relaxed);
            }
            sh.barrier.wait();
        }
        round += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{mesh2d, mesh3d, random_graph};
    use crate::ordering::test_support::check_ordering_contract;
    use crate::ordering::{amd_seq::AmdSeq, Ordering as _};
    use crate::symbolic::fill_in;

    #[test]
    fn single_thread_valid_and_reasonable() {
        let g = mesh2d(16, 16);
        let r = ParAmd::new(1).order(&g);
        check_ordering_contract(&g, &r);
        let f_par = fill_in(&g, &r.perm) as f64;
        let f_seq = fill_in(&g, &AmdSeq::default().order(&g).perm) as f64;
        assert!(f_par <= f_seq * 1.6 + 100.0, "par={f_par} seq={f_seq}");
    }

    #[test]
    fn multi_thread_valid_permutations() {
        let g = mesh2d(20, 20);
        for t in [2, 4, 8] {
            let r = ParAmd::new(t).order(&g);
            check_ordering_contract(&g, &r);
        }
    }

    #[test]
    fn random_graphs_many_threads() {
        for seed in 0..4 {
            let g = random_graph(400, 6, seed);
            let r = ParAmd::new(4).with_seed(seed).order(&g);
            check_ordering_contract(&g, &r);
        }
    }

    #[test]
    fn mesh3d_quality_within_paper_band() {
        // The paper reports fill ratios of 1.01–1.19× over sequential AMD
        // (Table 4.2) with mult=1.1; allow a wider band at mini scale.
        let g = mesh3d(9, 9, 9);
        let f_seq = fill_in(&g, &AmdSeq::default().order(&g).perm) as f64;
        let r = ParAmd::new(4).order(&g);
        check_ordering_contract(&g, &r);
        let f_par = fill_in(&g, &r.perm) as f64;
        let ratio = f_par / f_seq;
        assert!(ratio < 1.6, "fill ratio {ratio:.3} out of band");
    }

    #[test]
    fn multiple_elimination_reduces_rounds() {
        let g = mesh2d(24, 24);
        let r = ParAmd::new(4).order(&g);
        assert!(r.stats.rounds > 0);
        assert!(
            (r.stats.rounds as usize) < g.n / 2,
            "rounds {} too close to n {}",
            r.stats.rounds,
            g.n
        );
        assert!(!r.stats.set_sizes.is_empty());
        let total: u32 = r.stats.set_sizes.iter().sum();
        assert_eq!(total as u64, r.stats.pivots);
    }

    #[test]
    fn mult_relaxation_grows_sets() {
        let g = mesh3d(8, 8, 8);
        let avg = |mult: f64| {
            let r = ParAmd::new(4).with_mult(mult).order(&g);
            let s = &r.stats.set_sizes;
            s.iter().map(|&x| x as f64).sum::<f64>() / s.len() as f64
        };
        let a10 = avg(1.0);
        let a12 = avg(1.2);
        assert!(
            a12 > a10,
            "relaxed sets should be larger: mult1.0={a10:.1} mult1.2={a12:.1}"
        );
    }

    #[test]
    fn tiny_elbow_triggers_gc_and_still_completes() {
        let g = mesh2d(30, 30);
        let r = ParAmd::new(2).with_elbow(0.30).order(&g);
        check_ordering_contract(&g, &r);
        assert!(r.stats.gc_count > 0, "expected GC under a tiny elbow");
        assert!(
            r.stats.gc_secs > 0.0,
            "stop-the-world GC time must be measured"
        );
        assert!(
            r.stats.claim_failures > 0,
            "every GC is triggered by at least one failed elbow claim"
        );
        let sampled: u64 = r
            .stats
            .round_samples
            .iter()
            .map(|s| u64::from(s.claim_failures))
            .sum();
        assert_eq!(
            sampled, r.stats.claim_failures,
            "per-round claim-failure deltas must sum to the run total"
        );
    }

    #[test]
    fn round_samples_close_the_books() {
        let g = mesh2d(20, 20);
        let r = ParAmd::new(2).order(&g);
        assert!(!r.stats.round_samples.is_empty(), "rounds must be sampled");
        assert_eq!(r.stats.round_samples_dropped, 0, "cap far exceeds rounds");
        let weight: u64 = r.stats.round_samples.iter().map(|s| u64::from(s.weight)).sum();
        assert_eq!(weight, g.n as u64, "weight deltas sum to the column total");
        let pivots: u64 = r.stats.round_samples.iter().map(|s| u64::from(s.pivots)).sum();
        assert_eq!(pivots, r.stats.pivots, "pivot deltas sum to the run total");
        // The live census decays monotonically across real rounds, and
        // the per-round indices are the outer round counter.
        for (i, w) in r.stats.round_samples.windows(2).enumerate() {
            if w[1].round != u32::MAX {
                assert_eq!(w[0].round as usize, i);
                assert!(w[1].live_weight <= w[0].live_weight, "live weight grew");
                assert!(w[1].live_vars <= w[0].live_vars, "live vars grew");
            }
        }
    }

    #[test]
    fn round_samples_reset_between_warm_runs() {
        let g = mesh2d(12, 12);
        let cfg = ParAmd::new(2);
        let rt = OrderingRuntime::new(2);
        let mut arena = ParAmdArena::new();
        cfg.order_into(&rt, &mut arena, &g);
        let first = arena.result().stats.round_samples.clone();
        let r = cfg.order_into(&rt, &mut arena, &g);
        let weight: u64 = r.stats.round_samples.iter().map(|s| u64::from(s.weight)).sum();
        assert_eq!(weight, g.n as u64, "stale samples must not accumulate");
        assert_eq!(
            r.stats.round_samples.len(),
            first.len(),
            "warm rerun records the same round count"
        );
    }

    #[test]
    fn gc_time_is_consistent_with_gc_count() {
        let g = mesh2d(10, 10);
        let r = ParAmd::new(1).order(&g); // default elbow: GC unexpected
        if r.stats.gc_count == 0 {
            assert_eq!(r.stats.gc_secs, 0.0, "no collections, no time");
        } else {
            assert!(r.stats.gc_secs > 0.0, "counted collections must be timed");
        }
    }

    #[test]
    fn single_thread_deterministic() {
        let g = random_graph(300, 5, 11);
        let a = ParAmd::new(1).with_seed(7).order(&g);
        let b = ParAmd::new(1).with_seed(7).order(&g);
        assert_eq!(a.perm, b.perm);
    }

    #[test]
    fn detail_counters_consistent() {
        let g = mesh2d(16, 16);
        let (r, d) = ParAmd::new(3).order_detailed(&g);
        check_ordering_contract(&g, &r);
        assert_eq!(d.round_work.len(), r.stats.rounds as usize);
        assert!(d.model_speedup > 0.0);
        let pivots: u32 = d.round_work.iter().flatten().map(|w| w.pivots).sum();
        assert_eq!(pivots as u64, r.stats.pivots);
        assert_eq!(d.select_secs.len(), 3);
    }

    #[test]
    fn adaptive_extension_grows_sets_when_starved() {
        // mini_nd24k-like: dense 3D mesh with small D2 sets.
        let g = crate::matgen::mesh3d_27pt(9, 9, 9);
        let (r_base, d_base) = ParAmd::new(8).order_detailed(&g);
        let (r_adapt, d_adapt) = ParAmd::new(8).with_adaptive().order_detailed(&g);
        check_ordering_contract(&g, &r_adapt);
        let avg = |r: &crate::ordering::OrderingResult| {
            r.stats.pivots as f64 / r.stats.rounds.max(1) as f64
        };
        assert!(
            avg(&r_adapt) > avg(&r_base) * 0.95,
            "adaptive should not shrink sets: {} vs {}",
            avg(&r_adapt),
            avg(&r_base)
        );
        assert!(d_adapt.model_speedup >= d_base.model_speedup * 0.8);
    }

    #[test]
    fn empty_graph() {
        let g = SymGraph::from_edges(0, &[]);
        let r = ParAmd::new(4).order(&g);
        assert!(r.perm.is_empty());
    }

    #[test]
    fn weighted_run_orders_the_kernel_vertices() {
        // A mesh kernel with non-uniform seed supervariables: the run
        // must eliminate every kernel vertex (total weight, not vertex
        // count, is the target) and produce a valid kernel permutation.
        let g = mesh2d(9, 9);
        let weights: Vec<i32> = (0..g.n as i32).map(|v| 1 + (v % 4)).collect();
        let rt = OrderingRuntime::new(2);
        let mut arena = ParAmdArena::new();
        let cancel = AtomicBool::new(false);
        let r = ParAmd::new(2)
            .order_into_cancellable_weighted(&rt, &mut arena, &g, Some(&weights), &cancel)
            .expect("uncancelled run completes");
        check_ordering_contract(&g, r);
    }

    #[test]
    fn weighted_and_unweighted_runs_share_an_arena() {
        // Interleave weighted and unweighted runs on one arena: the
        // epoch stride and degree-bucket bounds must reset correctly.
        let g = mesh2d(8, 8);
        let rt = OrderingRuntime::new(1);
        let mut arena = ParAmdArena::new();
        let cfg = ParAmd::new(1);
        let cancel = AtomicBool::new(false);
        let plain = cfg.order(&g).perm;
        let weights = vec![5i32; g.n];
        for _ in 0..2 {
            let w = cfg
                .order_into_cancellable_weighted(&rt, &mut arena, &g, Some(&weights), &cancel)
                .unwrap();
            check_ordering_contract(&g, w);
            // Uniform weights scale every degree equally, so the
            // single-thread pivot order must match the unweighted run.
            assert_eq!(w.perm, plain, "uniform weights must not change the order");
            let u = cfg.order_into(&rt, &mut arena, &g);
            assert_eq!(u.perm, plain, "arena must reset cleanly after a weighted run");
        }
    }

    #[test]
    fn isolated_vertices_only() {
        let g = SymGraph::from_edges(7, &[]);
        let r = ParAmd::new(3).order(&g);
        check_ordering_contract(&g, &r);
    }

    #[test]
    fn cancelled_run_aborts_and_arena_stays_reusable() {
        let g = mesh2d(20, 20);
        let cfg = ParAmd::new(2);
        let rt = OrderingRuntime::new(2);
        let mut arena = ParAmdArena::new();
        let cancel = AtomicBool::new(true);
        assert!(
            cfg.order_into_cancellable(&rt, &mut arena, &g, &cancel)
                .is_none(),
            "a pre-cancelled run must not produce a result"
        );
        // The same arena then serves a normal run.
        let r = cfg.order_into(&rt, &mut arena, &g);
        check_ordering_contract(&g, r);
    }

    #[test]
    fn mid_run_cancellation_leaves_arena_clean() {
        let g = mesh2d(50, 50);
        let cfg = ParAmd::new(2);
        let rt = OrderingRuntime::new(2);
        let mut arena = ParAmdArena::new();
        let cancel = AtomicBool::new(false);
        std::thread::scope(|s| {
            let cancel = &cancel;
            s.spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                cancel.store(true, Relaxed);
            });
            // Either outcome is legal depending on timing: completed
            // (Some) or aborted at a round boundary (None).
            let _ = cfg.order_into_cancellable(&rt, &mut arena, &g, cancel);
        });
        // The arena must serve a clean run afterwards regardless.
        let r = cfg.order_into(&rt, &mut arena, &g);
        check_ordering_contract(&g, r);
    }

    #[test]
    fn warm_arena_runs_bitmatch_cold_run() {
        // Single-thread ParAMD is fully deterministic, so a warm rerun on
        // pooled state must reproduce the cold run bit-for-bit.
        let g = mesh2d(20, 20);
        let cfg = ParAmd::new(1).with_seed(99);
        let cold = cfg.order(&g);
        let rt = OrderingRuntime::new(1);
        let mut arena = ParAmdArena::new();
        for run in 0..3 {
            let r = cfg.order_into(&rt, &mut arena, &g);
            assert_eq!(r.perm, cold.perm, "warm run {run} diverged from cold");
            assert_eq!(r.stats.pivots, cold.stats.pivots);
        }
        assert_eq!(arena.runs(), 3);
    }

    #[test]
    fn warm_path_does_not_grow_arena() {
        let g = mesh3d(8, 8, 8);
        let cfg = ParAmd::new(4);
        let rt = OrderingRuntime::new(4);
        let mut arena = ParAmdArena::new();
        cfg.order_into(&rt, &mut arena, &g);
        let after_first = arena.grow_events();
        assert!(after_first > 0, "cold run must size the arena");
        for _ in 0..3 {
            let r = cfg.order_into(&rt, &mut arena, &g);
            assert_eq!(r.perm.len(), g.n);
        }
        assert_eq!(
            arena.grow_events(),
            after_first,
            "warm runs must reuse the arena without growing it"
        );
    }

    #[test]
    fn warm_arena_handles_shrinking_and_growing_graphs() {
        let rt = OrderingRuntime::new(3);
        let mut arena = ParAmdArena::new();
        let cfg = ParAmd::new(3);
        let graphs = [
            mesh2d(15, 15),
            mesh2d(4, 4),
            random_graph(350, 5, 2),
            mesh3d(6, 6, 6),
            mesh2d(15, 15),
        ];
        for g in &graphs {
            let r = cfg.order_into(&rt, &mut arena, g).clone();
            check_ordering_contract(g, &r);
        }
        // A graph that fits previously-seen sizes must not grow the arena.
        let before = arena.grow_events();
        cfg.order_into(&rt, &mut arena, &mesh2d(10, 10));
        assert_eq!(arena.grow_events(), before);
    }

    #[test]
    fn rereduce_merges_emergent_twins_and_flows_into_stats() {
        // `emergent_twins` is built so its twin classes only become
        // fingerprint-identical after their private distinguisher
        // elements are absorbed by the class element — a merge the
        // per-pivot local detection can never make. A sweep every
        // round must absorb those elements, merge the members, and
        // surface both counts in the run's stats.
        let g = crate::matgen::emergent_twins(240, 3);
        let r = ParAmd::new(2).with_rereduce_every(1).order(&g);
        check_ordering_contract(&g, &r);
        assert!(r.stats.rereduce_count > 0, "sweep never fired");
        assert!(
            r.stats.rereduce_secs > 0.0,
            "fired sweeps must be timed like GC pauses"
        );
        assert!(
            r.stats.elements_absorbed > 0,
            "distinguisher elements must be absorbed by class elements"
        );
        assert!(
            r.stats.mid_twins_merged > 0,
            "emergent twins must be merged mid-elimination"
        );
        // Postponed rows are logged as their own pseudo-set, so the
        // set-size ledger still accounts for every pivot.
        let total: u32 = r.stats.set_sizes.iter().sum();
        assert_eq!(total as u64, r.stats.pivots);
    }

    #[test]
    fn rereduce_disabled_keeps_counters_zero() {
        let g = crate::matgen::emergent_twins(240, 3);
        let r = ParAmd::new(2).with_rereduce(false).order(&g);
        check_ordering_contract(&g, &r);
        assert_eq!(r.stats.rereduce_count, 0);
        assert_eq!(r.stats.mid_twins_merged, 0);
        assert_eq!(r.stats.mid_dense_postponed, 0);
        assert_eq!(r.stats.elements_absorbed, 0);
        assert_eq!(r.stats.rereduce_secs, 0.0);
    }

    #[test]
    fn rereduce_single_thread_deterministic() {
        // The sweep sorts its nomination keys and merges in vertex
        // order, so a single-thread run with the sweep on is as
        // deterministic as one without it.
        let g = crate::matgen::emergent_twins(200, 3);
        let a = ParAmd::new(1).with_seed(5).with_rereduce_every(1).order(&g);
        let b = ParAmd::new(1).with_seed(5).with_rereduce_every(1).order(&g);
        assert_eq!(a.perm, b.perm);
        assert_eq!(a.stats.mid_twins_merged, b.stats.mid_twins_merged);
        assert_eq!(a.stats.elements_absorbed, b.stats.elements_absorbed);
        assert_eq!(a.stats.mid_dense_postponed, b.stats.mid_dense_postponed);
    }

    #[test]
    fn rereduce_elbow_trigger_fires_on_set_starvation() {
        // An absurdly high elbow fraction makes every round "starved",
        // so the trigger must fire even with the round cadence off.
        let g = mesh2d(16, 16);
        let r = ParAmd::new(2)
            .with_rereduce_every(0)
            .with_rereduce_elbow(1.0e6)
            .order(&g);
        check_ordering_contract(&g, &r);
        assert!(r.stats.rereduce_count > 0, "elbow trigger never fired");
    }

    #[test]
    fn skewed_weights_survive_mid_flight_merges() {
        // ISSUE regression: a weighted kernel run whose seed
        // supervariables carry highly skewed weights must keep a valid
        // kernel permutation when the sweep merges mid-flight — the
        // run's own `nel == wtot` completion assert guards the exact
        // weight total.
        let g = crate::matgen::emergent_twins(180, 3);
        let weights: Vec<i32> = (0..g.n as i32).map(|v| if v % 3 == 0 { 50 } else { 1 }).collect();
        let rt = OrderingRuntime::new(2);
        let mut arena = ParAmdArena::new();
        let cancel = AtomicBool::new(false);
        let cfg = ParAmd::new(2).with_rereduce_every(1);
        let r = cfg
            .order_into_cancellable_weighted(&rt, &mut arena, &g, Some(&weights), &cancel)
            .expect("uncancelled run completes");
        check_ordering_contract(&g, r);
        // The arena must stay reusable after a sweep-heavy run.
        let again = cfg.order_into(&rt, &mut arena, &g);
        check_ordering_contract(&g, again);
    }

    use crate::graph::csr::SymGraph;
}
