//! Compressed sparse row storage.
//!
//! Two types:
//! - [`CsrMatrix`] — a general (possibly nonsymmetric, possibly valued)
//!   sparse matrix, used for I/O and for the numeric solver.
//! - [`SymGraph`] — the symmetric *pattern* the ordering algorithms consume:
//!   adjacency of the undirected graph of `|A| + |A^T|`, diagonal removed,
//!   no duplicate entries, neighbor lists sorted.

/// General CSR sparse matrix with `f64` values.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    pub nrows: usize,
    pub ncols: usize,
    /// Row pointers, length `nrows + 1`.
    pub rowptr: Vec<usize>,
    /// Column indices, length `nnz`.
    pub colind: Vec<i32>,
    /// Values, length `nnz` (may be empty for pattern-only matrices).
    pub values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from unsorted triplets; duplicates are summed.
    pub fn from_triplets(
        nrows: usize,
        ncols: usize,
        triplets: &[(usize, usize, f64)],
    ) -> Self {
        let mut count = vec![0usize; nrows + 1];
        for &(r, _, _) in triplets {
            assert!(r < nrows, "row index {r} out of bounds {nrows}");
            count[r + 1] += 1;
        }
        for i in 0..nrows {
            count[i + 1] += count[i];
        }
        let rowptr_raw = count.clone();
        let mut colind = vec![0i32; triplets.len()];
        let mut values = vec![0f64; triplets.len()];
        let mut next = rowptr_raw.clone();
        for &(r, c, v) in triplets {
            assert!(c < ncols, "col index {c} out of bounds {ncols}");
            let p = next[r];
            colind[p] = c as i32;
            values[p] = v;
            next[r] += 1;
        }
        let mut m = Self {
            nrows,
            ncols,
            rowptr: rowptr_raw,
            colind,
            values,
        };
        m.sort_and_dedup();
        m
    }

    /// Sort each row by column and sum duplicates in place.
    pub fn sort_and_dedup(&mut self) {
        let mut new_rowptr = vec![0usize; self.nrows + 1];
        let mut new_colind = Vec::with_capacity(self.colind.len());
        let mut new_values = Vec::with_capacity(self.values.len());
        let mut row: Vec<(i32, f64)> = Vec::new();
        for r in 0..self.nrows {
            row.clear();
            for p in self.rowptr[r]..self.rowptr[r + 1] {
                row.push((self.colind[p], self.values.get(p).copied().unwrap_or(1.0)));
            }
            row.sort_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < row.len() {
                let c = row[i].0;
                let mut v = 0.0;
                while i < row.len() && row[i].0 == c {
                    v += row[i].1;
                    i += 1;
                }
                new_colind.push(c);
                new_values.push(v);
            }
            new_rowptr[r + 1] = new_colind.len();
        }
        self.rowptr = new_rowptr;
        self.colind = new_colind;
        self.values = new_values;
    }

    pub fn nnz(&self) -> usize {
        self.colind.len()
    }

    /// Column indices of row `r`.
    pub fn row(&self, r: usize) -> &[i32] {
        &self.colind[self.rowptr[r]..self.rowptr[r + 1]]
    }

    /// Values of row `r`.
    pub fn row_values(&self, r: usize) -> &[f64] {
        &self.values[self.rowptr[r]..self.rowptr[r + 1]]
    }

    /// Transpose (also yields CSC of the original).
    pub fn transpose(&self) -> CsrMatrix {
        let mut count = vec![0usize; self.ncols + 1];
        for &c in &self.colind {
            count[c as usize + 1] += 1;
        }
        for i in 0..self.ncols {
            count[i + 1] += count[i];
        }
        let rowptr = count.clone();
        let mut next = count;
        let mut colind = vec![0i32; self.nnz()];
        let mut values = vec![0f64; self.nnz()];
        for r in 0..self.nrows {
            for p in self.rowptr[r]..self.rowptr[r + 1] {
                let c = self.colind[p] as usize;
                let q = next[c];
                colind[q] = r as i32;
                values[q] = self.values[p];
                next[c] += 1;
            }
        }
        CsrMatrix {
            nrows: self.ncols,
            ncols: self.nrows,
            rowptr,
            colind,
            values,
        }
    }

    /// Structural symmetry check (pattern only).
    pub fn is_pattern_symmetric(&self) -> bool {
        if self.nrows != self.ncols {
            return false;
        }
        let t = self.transpose();
        self.rowptr == t.rowptr && self.colind == t.colind
    }

    /// y = A x (dense vectors).
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        for r in 0..self.nrows {
            let mut acc = 0.0;
            for p in self.rowptr[r]..self.rowptr[r + 1] {
                acc += self.values[p] * x[self.colind[p] as usize];
            }
            y[r] = acc;
        }
    }
}

/// Symmetric adjacency pattern: what every ordering algorithm consumes.
///
/// Invariants (checked by [`SymGraph::validate`]):
/// - square, no self-loops, no duplicates, rows sorted;
/// - `(i, j)` present iff `(j, i)` present.
#[derive(Clone, Debug, PartialEq)]
pub struct SymGraph {
    pub n: usize,
    pub rowptr: Vec<usize>,
    pub colind: Vec<i32>,
}

impl SymGraph {
    /// Build from an edge list of undirected edges (self-loops dropped,
    /// duplicates merged).
    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Self {
        let mut trip = Vec::with_capacity(edges.len() * 2);
        for &(u, v) in edges {
            assert!(u < n && v < n);
            if u != v {
                trip.push((u, v, 1.0));
                trip.push((v, u, 1.0));
            }
        }
        let m = CsrMatrix::from_triplets(n, n, &trip);
        Self {
            n,
            rowptr: m.rowptr,
            colind: m.colind,
        }
    }

    pub fn nnz(&self) -> usize {
        self.colind.len()
    }

    /// Number of undirected edges.
    pub fn nedges(&self) -> usize {
        self.nnz() / 2
    }

    pub fn neighbors(&self, v: usize) -> &[i32] {
        &self.colind[self.rowptr[v]..self.rowptr[v + 1]]
    }

    pub fn degree(&self, v: usize) -> usize {
        self.rowptr[v + 1] - self.rowptr[v]
    }

    /// Check all structural invariants; returns an error string on failure.
    pub fn validate(&self) -> Result<(), String> {
        if self.rowptr.len() != self.n + 1 {
            return Err("rowptr length".into());
        }
        if self.rowptr[0] != 0 || *self.rowptr.last().unwrap() != self.colind.len() {
            return Err("rowptr endpoints".into());
        }
        for v in 0..self.n {
            if self.rowptr[v] > self.rowptr[v + 1] {
                return Err(format!("rowptr not monotone at {v}"));
            }
            let nb = self.neighbors(v);
            for w in nb.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {v} not strictly sorted"));
                }
            }
            for &u in nb {
                if u < 0 || u as usize >= self.n {
                    return Err(format!("row {v}: index {u} out of range"));
                }
                if u as usize == v {
                    return Err(format!("self loop at {v}"));
                }
                if self.neighbors(u as usize).binary_search(&(v as i32)).is_err() {
                    return Err(format!("asymmetric edge ({v},{u})"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triplets_sorted_and_summed() {
        let m = CsrMatrix::from_triplets(
            2,
            2,
            &[(0, 1, 1.0), (0, 1, 2.0), (1, 0, 5.0), (0, 0, 1.0)],
        );
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row(0), &[0, 1]);
        assert_eq!(m.row_values(0), &[1.0, 3.0]);
        assert_eq!(m.row(1), &[0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = CsrMatrix::from_triplets(3, 2, &[(0, 1, 1.0), (2, 0, 3.0), (1, 1, 2.0)]);
        let t = m.transpose();
        assert_eq!(t.nrows, 2);
        assert_eq!(t.ncols, 3);
        let tt = t.transpose();
        assert_eq!(m, tt);
    }

    #[test]
    fn matvec_identity_like() {
        let m = CsrMatrix::from_triplets(2, 2, &[(0, 0, 2.0), (1, 1, 3.0)]);
        let mut y = vec![0.0; 2];
        m.matvec(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![2.0, 3.0]);
    }

    #[test]
    fn symgraph_from_edges() {
        let g = SymGraph::from_edges(4, &[(0, 1), (1, 2), (1, 2), (3, 3)]);
        g.validate().unwrap();
        assert_eq!(g.nedges(), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.degree(3), 0);
    }

    #[test]
    fn symgraph_validate_catches_asymmetry() {
        let g = SymGraph {
            n: 2,
            rowptr: vec![0, 1, 1],
            colind: vec![1],
        };
        assert!(g.validate().is_err());
    }

    #[test]
    fn pattern_symmetry() {
        let sym = CsrMatrix::from_triplets(2, 2, &[(0, 1, 1.0), (1, 0, 9.0)]);
        assert!(sym.is_pattern_symmetric());
        let asym = CsrMatrix::from_triplets(2, 2, &[(0, 1, 1.0)]);
        assert!(!asym.is_pattern_symmetric());
    }
}
