//! The ordering **result cache**: a zero-recompute fast path for
//! repeated graphs.
//!
//! The paper's central finding is that parallelism *within* an
//! elimination step is contention-limited, so the wins come from
//! restructuring the work around the kernel — and the biggest remaining
//! restructuring is to not redo the work at all. Batched FEM/assembly
//! traffic re-submits structurally identical components request after
//! request; at service scale that means re-running identical ParAMD jobs
//! end to end. This module memoizes them:
//!
//! - **Keys** are a 128-bit structural [`Fingerprint`] of the compact
//!   CSR that will actually be ordered, plus a 64-bit *salt* mixing the
//!   ordering-relevant [`ParAmd`] knobs ([`config_salt`]) and the seed
//!   supervariable weights. The shard engine probes at two
//!   granularities: whole connected requests (before reduction even
//!   runs) and per-component kernels (after split + reduction, so
//!   requests with scattered vertex labels still share entries — compact
//!   component extraction is label-normalizing).
//! - **Values** are the kernel permutation plus the round-log summary
//!   (`rounds`, `set_sizes`, GC counters, `modeled_time`), everything a
//!   [`ShardReply`](crate::ordering::shard::ShardReply) replays on a hit.
//! - **Hits are verified**: a fingerprint match is followed by an exact
//!   CSR + weights compare against the stored graph, so a hash collision
//!   can cost one recompute (a *verify-reject* falls through to an
//!   ordinary miss) but can never corrupt a result.
//! - **Memory is byte-budgeted**: entries spread over `N` mutex shards
//!   (keyed by fingerprint high bits, so concurrent submitters rarely
//!   contend on lookups) under one **global** byte budget; when an
//!   insert pushes residency over it, globally least-recently-used
//!   entries are evicted (shards locked one at a time, never nested).
//!   A budget of `0` disables the cache entirely.
//!
//! What the salt deliberately **excludes**: the executing thread count.
//! ParAMD permutations are width-dependent, so a hit may replay a result
//! computed by a shard of a different width than the router would pick
//! today — a valid ordering of the same graph under the same quality
//! knobs, exactly like placement already depends on load. Disable the
//! cache (`Service::with_result_cache(0)` / `--no-cache`) when strict
//! placement-reproducibility matters more than latency.

pub mod persist;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{Arc, Mutex, OnceLock};

use crate::graph::csr::SymGraph;
use crate::graph::fingerprint::{fingerprint, Fingerprint};
use crate::ordering::paramd::ParAmd;
use crate::ordering::reduce::ReduceConfig;
use crate::util::failpoint;
use crate::util::lock_unpoisoned;
use crate::util::rng::splitmix64;

/// Default byte budget of a service's result cache (64 MiB).
pub const DEFAULT_BUDGET_BYTES: usize = 64 << 20;

/// Default number of mutex shards (keyed by fingerprint high bits).
const DEFAULT_SHARDS: usize = 16;

/// Hash the ordering-relevant [`ParAmd`] knobs into a cache salt. The
/// thread count is deliberately excluded (see the module docs); every
/// knob that changes the *pivot choice* for a fixed width is included.
pub fn config_salt(cfg: &ParAmd) -> u64 {
    let mut h = splitmix64(0xCA_C4E5 ^ cfg.mult.to_bits());
    h = splitmix64(h ^ cfg.lim_total as u64);
    h = splitmix64(h ^ cfg.elbow.to_bits());
    h = splitmix64(h ^ cfg.seed);
    h = splitmix64(h ^ (u64::from(cfg.aggressive) | (u64::from(cfg.adaptive) << 1)));
    h = splitmix64(h ^ cfg.adaptive_mult_max.to_bits());
    // Mid-elimination re-reduction changes merges, tails, and pivot
    // choices, so every sweep knob is ordering-relevant.
    h = splitmix64(h ^ (u64::from(cfg.rereduce) | ((cfg.rereduce_every as u64) << 1)));
    splitmix64(h ^ cfg.rereduce_elbow.to_bits())
}

/// Hash the reduction knobs that change *what gets ordered* into the
/// salt of **request-level** entries: those bake the whole reduction
/// outcome (prefix/tail/twin expansion) into the stored permutation, so
/// toggling `--no-reduce` or `α` on a warm service must miss instead of
/// replaying a stale path. Kernel-level entries don't need this — a
/// kernel already embodies its reduction — and the reduction thread
/// count is excluded because plans are worker-count independent.
pub fn reduce_salt(cfg: &ReduceConfig) -> u64 {
    let rules =
        u64::from(cfg.leaves) | (u64::from(cfg.dense) << 1) | (u64::from(cfg.twins) << 2);
    splitmix64(splitmix64(0x2ED0_CE ^ rules) ^ cfg.dense_alpha.to_bits())
}

/// Hash the hybrid ND×ParAMD knobs into the salt of **request-level**
/// entries, alongside [`reduce_salt`]: a hybrid ordering interleaves
/// subdomains and separators in a way no plain run reproduces, so
/// toggling `--hybrid` (or any partition knob while enabled) on a warm
/// service must miss instead of replaying the other path's permutation.
/// All disabled configs hash identically — the partition knobs are
/// inert then and must not fragment the cache.
pub fn hybrid_salt(cfg: &crate::ordering::hybrid::HybridConfig) -> u64 {
    if !cfg.enabled {
        return splitmix64(0x4B1D_0FF);
    }
    let mut h = splitmix64(0x4B1D_0 ^ cfg.partition_threshold as u64);
    h = splitmix64(h ^ cfg.recursion_depth as u64);
    splitmix64(h ^ cfg.balance_factor.to_bits())
}

/// Chained hash of the seed supervariable weights (`None` = unweighted).
fn weights_salt(weights: Option<&[i32]>) -> u64 {
    match weights {
        None => 0x57E1_64B5_0000_0001,
        Some(ws) => {
            let mut h = splitmix64(0x57E1_64B5 ^ ws.len() as u64);
            for &w in ws {
                h = splitmix64(h ^ w as u64);
            }
            h
        }
    }
}

/// A complete cache key: the graph fingerprint plus the config/weights
/// salt.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub fp: Fingerprint,
    pub salt: u64,
}

impl CacheKey {
    /// Key for ordering `g` with `weights` under the knobs hashed into
    /// `cfg_salt` (from [`config_salt`]).
    pub fn new(g: &SymGraph, weights: Option<&[i32]>, cfg_salt: u64) -> Self {
        Self {
            fp: fingerprint(g),
            salt: splitmix64(cfg_salt.wrapping_add(weights_salt(weights))),
        }
    }
}

/// A cached ordering result: the permutation over the graph that was
/// actually ordered, plus the round-log summary a reply replays.
#[derive(Clone, Debug)]
pub struct CachedOrdering {
    pub perm: Vec<i32>,
    pub rounds: u64,
    pub gc_count: u64,
    pub gc_secs: f64,
    pub modeled_time: f64,
    pub set_sizes: Vec<u32>,
    /// Vertices the reduction layer removed (request-level entries only;
    /// kernel-level entries store 0 — their caller holds the live plan).
    pub reduced: usize,
}

struct Entry {
    /// Exact-verify copy of the keyed graph.
    graph: SymGraph,
    weights: Option<Vec<i32>>,
    value: CachedOrdering,
    bytes: usize,
    /// Monotone LRU tick (refreshed on every hit).
    tick: u64,
}

fn entry_bytes(graph: &SymGraph, weights: &Option<Vec<i32>>, value: &CachedOrdering) -> usize {
    const FIXED: usize = 160; // struct + map-slot overhead, order of magnitude
    FIXED
        + graph.rowptr.len() * std::mem::size_of::<usize>()
        + graph.colind.len() * std::mem::size_of::<i32>()
        + weights.as_ref().map_or(0, |w| w.len() * std::mem::size_of::<i32>())
        + value.perm.len() * std::mem::size_of::<i32>()
        + value.set_sizes.len() * std::mem::size_of::<u32>()
}

#[derive(Default)]
struct CacheShard {
    entries: HashMap<CacheKey, Entry>,
    bytes: usize,
}

/// Counter snapshot of a [`ResultCache`] — the ISSUE's `CacheMetrics`
/// report section.
#[derive(Clone, Debug, Default)]
pub struct CacheMetrics {
    /// Lookups answered from the cache (verified exact matches).
    pub hits: u64,
    /// Lookups that found nothing usable (includes verify-rejects).
    pub misses: u64,
    /// Fingerprint matches whose exact CSR/weights compare failed — a
    /// hash collision safely downgraded to a miss.
    pub verify_rejects: u64,
    /// Entries stored (replacements included).
    pub insertions: u64,
    /// Entries dropped by the LRU byte-budget policy.
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: usize,
    /// Bytes currently resident.
    pub bytes: usize,
    /// Total byte budget (0 = cache disabled).
    pub budget_bytes: usize,
    /// Estimated ordering seconds short-circuited by hits, accumulated
    /// from each hit entry's `modeled_time`.
    pub saved_secs: f64,
}

impl CacheMetrics {
    /// Render a compact report section.
    pub fn report(&self) -> String {
        format!(
            "cache: hits={} misses={} rejects={} entries={} bytes={}/{} \
             evictions={} saved~={:.4}s\n",
            self.hits,
            self.misses,
            self.verify_rejects,
            self.entries,
            self.bytes,
            self.budget_bytes,
            self.evictions,
            self.saved_secs
        )
    }
}

/// A byte-budgeted, sharded, verifying LRU cache of ordering results.
/// See the module docs for the design; construct once (the coordinator
/// shares one across shard-engine rebuilds), probe with [`Self::get`],
/// fill with [`Self::insert`].
pub struct ResultCache {
    shards: Vec<Mutex<CacheShard>>,
    /// Total byte budget; 0 disables every operation.
    budget: AtomicUsize,
    /// Resident bytes across shards (kept in sync under shard locks).
    bytes: AtomicUsize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    verify_rejects: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
    saved_nanos: AtomicU64,
    /// Optional crash-consistent on-disk tier ([`persist`]): attached
    /// once, write-behind on every insert, warm-started on open.
    persist: OnceLock<Arc<persist::PersistTier>>,
}

impl ResultCache {
    /// A cache with `budget` bytes across [`DEFAULT_SHARDS`] mutex
    /// shards (`0` = disabled).
    pub fn new(budget: usize) -> Self {
        Self::with_shards(budget, DEFAULT_SHARDS)
    }

    /// A cache with an explicit shard count (tests use 1 for
    /// deterministic whole-cache LRU behavior).
    pub fn with_shards(budget: usize, shards: usize) -> Self {
        let shards = shards.max(1);
        Self {
            shards: (0..shards).map(|_| Mutex::new(CacheShard::default())).collect(),
            budget: AtomicUsize::new(budget),
            bytes: AtomicUsize::new(0),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            verify_rejects: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            saved_nanos: AtomicU64::new(0),
            persist: OnceLock::new(),
        }
    }

    /// Attach the crash-consistent on-disk tier (first call wins).
    /// Every later [`Self::insert`] is also queued to the tier's
    /// flusher; load recovered entries **before** attaching so the
    /// warm start is not re-appended to the log it just came from.
    pub fn attach_persist(&self, tier: Arc<persist::PersistTier>) {
        let _ = self.persist.set(tier);
    }

    /// The attached on-disk tier, if any.
    pub fn persist(&self) -> Option<&Arc<persist::PersistTier>> {
        self.persist.get()
    }

    /// Counter snapshot of the attached on-disk tier, if any.
    pub fn persist_metrics(&self) -> Option<persist::PersistMetrics> {
        self.persist.get().map(|t| t.metrics())
    }

    /// Whether the cache participates at all (budget > 0).
    pub fn is_enabled(&self) -> bool {
        self.budget.load(Relaxed) > 0
    }

    /// The total byte budget.
    pub fn budget(&self) -> usize {
        self.budget.load(Relaxed)
    }

    /// Re-budget the cache. Shrinking evicts globally-LRU entries
    /// immediately; `0` clears everything and disables further traffic.
    pub fn set_budget(&self, bytes: usize) {
        self.budget.store(bytes, Relaxed);
        self.evict_over_budget();
    }

    /// Drop globally least-recently-used entries until residency fits
    /// the budget. One scan gathers every candidate (shards locked one
    /// at a time, never nested), one sort ranks them by tick, then
    /// victims are removed oldest-first until residency fits — evicting
    /// a whole burst costs a single O(entries log entries) pass instead
    /// of a full rescan per victim. A concurrent hit can refresh a tick
    /// mid-scan, which at worst evicts a slightly-stale victim, never a
    /// wrong result.
    fn evict_over_budget(&self) {
        let budget = self.budget.load(Relaxed);
        if self.bytes.load(Relaxed) <= budget {
            return;
        }
        let mut candidates: Vec<(u64, usize, CacheKey)> = Vec::new();
        for (i, shard) in self.shards.iter().enumerate() {
            let sh = lock_unpoisoned(shard.lock());
            candidates.extend(sh.entries.iter().map(|(k, e)| (e.tick, i, *k)));
        }
        candidates.sort_unstable_by_key(|&(tick, _, _)| tick);
        for (_, i, key) in candidates {
            if self.bytes.load(Relaxed) <= budget {
                break;
            }
            let mut sh = lock_unpoisoned(self.shards[i].lock());
            if let Some(e) = sh.entries.remove(&key) {
                sh.bytes -= e.bytes;
                self.bytes.fetch_sub(e.bytes, Relaxed);
                self.evictions.fetch_add(1, Relaxed);
            }
        }
    }

    fn shard(&self, key: &CacheKey) -> &Mutex<CacheShard> {
        // High bits of the first pass pick the shard; the full key is
        // still compared inside.
        let i = (key.fp.hi >> 32) as usize % self.shards.len();
        &self.shards[i]
    }

    /// Probe for `key`. On a fingerprint match the stored graph and
    /// weights are compared **exactly**; a mismatch counts as a
    /// verify-reject and falls through to a miss, so collisions can
    /// never corrupt a result. A hit refreshes the entry's LRU tick and
    /// returns an owned copy of the cached result.
    ///
    /// The chaos suite forces the reject path through the
    /// [`failpoint::CACHE_VERIFY`] failpoint: armed with `reject`, a
    /// would-be hit downgrades to a verify-reject miss — proving the
    /// callers really treat rejects as misses and recompute.
    pub fn get(
        &self,
        key: &CacheKey,
        graph: &SymGraph,
        weights: Option<&[i32]>,
    ) -> Option<CachedOrdering> {
        if !self.is_enabled() {
            return None;
        }
        // Poison recovery: shard state is a plain map + byte tally kept
        // consistent within each critical section, so a panicking thread
        // (e.g. an armed failpoint) must not wedge every later probe.
        let mut sh = lock_unpoisoned(self.shard(key).lock());
        match sh.entries.get_mut(key) {
            Some(e)
                if e.graph == *graph
                    && e.weights.as_deref() == weights
                    && !failpoint::should_reject(failpoint::CACHE_VERIFY) =>
            {
                e.tick = self.tick.fetch_add(1, Relaxed) + 1;
                self.hits.fetch_add(1, Relaxed);
                self.saved_nanos
                    .fetch_add((e.value.modeled_time * 1e9) as u64, Relaxed);
                Some(e.value.clone())
            }
            Some(_) => {
                self.verify_rejects.fetch_add(1, Relaxed);
                self.misses.fetch_add(1, Relaxed);
                None
            }
            None => {
                self.misses.fetch_add(1, Relaxed);
                None
            }
        }
    }

    /// Store `value` for `key`, keeping `graph`/`weights` for the exact
    /// verification of later probes. Replaces an existing entry for the
    /// same key; an entry larger than the whole budget is silently not
    /// cached; otherwise globally-LRU entries are evicted until
    /// residency fits the budget again.
    pub fn insert(
        &self,
        key: CacheKey,
        graph: SymGraph,
        weights: Option<Vec<i32>>,
        value: CachedOrdering,
    ) {
        if !self.is_enabled() {
            return;
        }
        let bytes = entry_bytes(&graph, &weights, &value);
        if bytes > self.budget.load(Relaxed) {
            return; // would evict everything and still not fit
        }
        // Write-behind: encode the durable frame before the entry is
        // moved into the shard (no locks held), enqueue after the
        // locks are released.
        let frame = self
            .persist
            .get()
            .map(|t| t.encode_frame(&key, &graph, weights.as_deref(), &value));
        let tick = self.tick.fetch_add(1, Relaxed) + 1;
        {
            let mut sh = lock_unpoisoned(self.shard(&key).lock());
            if let Some(old) = sh.entries.insert(
                key,
                Entry {
                    graph,
                    weights,
                    value,
                    bytes,
                    tick,
                },
            ) {
                sh.bytes -= old.bytes;
                self.bytes.fetch_sub(old.bytes, Relaxed);
            }
            sh.bytes += bytes;
            self.bytes.fetch_add(bytes, Relaxed);
            self.insertions.fetch_add(1, Relaxed);
        } // release before evicting — eviction re-locks shard by shard
        self.evict_over_budget();
        if let (Some(tier), Some(frame)) = (self.persist.get(), frame) {
            tier.enqueue_frame(frame);
        }
    }

    /// Entries currently resident (sums the shards).
    pub fn entries(&self) -> usize {
        self.shards
            .iter()
            .map(|s| lock_unpoisoned(s.lock()).entries.len())
            .sum()
    }

    /// Snapshot every counter.
    pub fn metrics(&self) -> CacheMetrics {
        CacheMetrics {
            hits: self.hits.load(Relaxed),
            misses: self.misses.load(Relaxed),
            verify_rejects: self.verify_rejects.load(Relaxed),
            insertions: self.insertions.load(Relaxed),
            evictions: self.evictions.load(Relaxed),
            entries: self.entries(),
            bytes: self.bytes.load(Relaxed),
            budget_bytes: self.budget.load(Relaxed),
            saved_secs: self.saved_nanos.load(Relaxed) as f64 / 1e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{mesh2d, random_graph};

    fn value(n: usize, modeled: f64) -> CachedOrdering {
        CachedOrdering {
            perm: (0..n as i32).collect(),
            rounds: 3,
            gc_count: 1,
            gc_secs: 0.0,
            modeled_time: modeled,
            set_sizes: vec![n as u32],
            reduced: 0,
        }
    }

    #[test]
    fn roundtrip_hit_returns_the_stored_value() {
        let cache = ResultCache::new(1 << 20);
        let g = mesh2d(8, 8);
        let key = CacheKey::new(&g, None, 7);
        assert!(cache.get(&key, &g, None).is_none(), "cold probe misses");
        cache.insert(key, g.clone(), None, value(g.n, 0.5));
        let hit = cache.get(&key, &g, None).expect("warm probe hits");
        assert_eq!(hit.perm.len(), g.n);
        assert_eq!(hit.rounds, 3);
        let m = cache.metrics();
        assert_eq!((m.hits, m.misses, m.verify_rejects), (1, 1, 0));
        assert_eq!(m.entries, 1);
        assert!(m.bytes > 0 && m.bytes <= m.budget_bytes);
        assert!((m.saved_secs - 0.5).abs() < 1e-9, "saved = hit modeled_time");
    }

    #[test]
    fn forged_key_verify_rejects_and_misses() {
        // Simulate a full 128-bit collision: graph B probed under A's
        // key. The exact compare must reject and report a miss.
        let cache = ResultCache::with_shards(1 << 20, 1);
        let a = mesh2d(8, 8);
        let b = random_graph(64, 4, 1);
        let key_a = CacheKey::new(&a, None, 7);
        cache.insert(key_a, a.clone(), None, value(a.n, 0.0));
        assert!(
            cache.get(&key_a, &b, None).is_none(),
            "forged probe must fall through to a miss"
        );
        let m = cache.metrics();
        assert_eq!(m.verify_rejects, 1);
        assert_eq!(m.misses, 1, "a verify-reject is a miss");
        assert_eq!(m.hits, 0);
        // The honest probe still hits afterwards — nothing was corrupted.
        assert!(cache.get(&key_a, &a, None).is_some());
    }

    #[test]
    fn weights_are_part_of_the_identity() {
        // Same kernel CSR, different seed-supervariable weights: the
        // salts differ, so the entries never alias.
        let cache = ResultCache::new(1 << 20);
        let g = mesh2d(6, 6);
        let w1 = vec![1i32; g.n];
        let w2 = vec![2i32; g.n];
        let k1 = CacheKey::new(&g, Some(&w1), 7);
        let k2 = CacheKey::new(&g, Some(&w2), 7);
        assert_ne!(k1, k2);
        cache.insert(k1, g.clone(), Some(w1.clone()), value(g.n, 0.0));
        assert!(cache.get(&k1, &g, Some(&w1)).is_some());
        assert!(cache.get(&k2, &g, Some(&w2)).is_none());
    }

    #[test]
    fn config_salt_separates_quality_knobs_but_not_threads() {
        let base = ParAmd::new(4);
        assert_eq!(
            config_salt(&base),
            config_salt(&ParAmd::new(8)),
            "thread count must not change the cache identity"
        );
        assert_ne!(config_salt(&base), config_salt(&base.with_mult(1.3)));
        assert_ne!(config_salt(&base), config_salt(&base.with_lim_total(64)));
        assert_ne!(config_salt(&base), config_salt(&base.with_seed(1)));
        assert_ne!(config_salt(&base), config_salt(&base.with_adaptive()));
        // Every mid-elimination sweep knob is ordering-relevant.
        assert_ne!(config_salt(&base), config_salt(&base.with_rereduce(false)));
        assert_ne!(
            config_salt(&base),
            config_salt(&base.with_rereduce_every(1))
        );
        assert_ne!(
            config_salt(&base),
            config_salt(&base.with_rereduce_elbow(0.5))
        );
        // Repeating the same sweep config is the same identity.
        assert_eq!(
            config_salt(&base.with_rereduce_every(2)),
            config_salt(&base.with_rereduce_every(2))
        );
    }

    #[test]
    fn reduce_salt_separates_rule_switches_and_alpha() {
        let on = ReduceConfig::default();
        assert_ne!(reduce_salt(&on), reduce_salt(&ReduceConfig::disabled()));
        assert_ne!(
            reduce_salt(&on),
            reduce_salt(&ReduceConfig {
                dense_alpha: 3.5,
                ..on
            })
        );
        assert_eq!(
            reduce_salt(&on),
            reduce_salt(&ReduceConfig { threads: 8, ..on }),
            "reduction threads must not change the cache identity"
        );
    }

    #[test]
    fn hybrid_salt_separates_knobs_only_while_enabled() {
        use crate::ordering::hybrid::HybridConfig;
        let on = HybridConfig::on();
        assert_ne!(hybrid_salt(&on), hybrid_salt(&HybridConfig::disabled()));
        for tweaked in [
            HybridConfig {
                partition_threshold: on.partition_threshold + 1,
                ..on
            },
            HybridConfig {
                recursion_depth: on.recursion_depth + 1,
                ..on
            },
            HybridConfig {
                balance_factor: on.balance_factor + 0.25,
                ..on
            },
        ] {
            assert_ne!(hybrid_salt(&on), hybrid_salt(&tweaked));
        }
        // Disabled configs are all one identity: inert knobs must not
        // fragment the cache.
        let off = HybridConfig {
            enabled: false,
            partition_threshold: 5,
            recursion_depth: 9,
            balance_factor: 7.0,
        };
        assert_eq!(hybrid_salt(&off), hybrid_salt(&HybridConfig::disabled()));
    }

    #[test]
    fn lru_evicts_the_stalest_entry_under_a_tiny_budget() {
        let g0 = mesh2d(10, 10);
        let g1 = mesh2d(10, 11);
        let g2 = mesh2d(10, 12);
        let per_entry = entry_bytes(&g0, &None, &value(g0.n, 0.0));
        // Budget fits two entries but not three (single shard so the
        // whole budget is one LRU domain).
        let cache = ResultCache::with_shards(per_entry * 2 + per_entry / 2, 1);
        let (k0, k1, k2) = (
            CacheKey::new(&g0, None, 7),
            CacheKey::new(&g1, None, 7),
            CacheKey::new(&g2, None, 7),
        );
        cache.insert(k0, g0.clone(), None, value(g0.n, 0.0));
        cache.insert(k1, g1.clone(), None, value(g1.n, 0.0));
        // Touch g0 so g1 becomes the LRU victim.
        assert!(cache.get(&k0, &g0, None).is_some());
        cache.insert(k2, g2.clone(), None, value(g2.n, 0.0));
        let m = cache.metrics();
        assert_eq!(m.evictions, 1, "third insert must evict exactly one entry");
        assert!(m.bytes <= m.budget_bytes, "resident bytes respect the budget");
        assert!(cache.get(&k0, &g0, None).is_some(), "recently-used survives");
        assert!(cache.get(&k2, &g2, None).is_some(), "newest survives");
        assert!(cache.get(&k1, &g1, None).is_none(), "LRU entry evicted");
    }

    #[test]
    fn zero_budget_disables_everything() {
        let cache = ResultCache::new(0);
        let g = mesh2d(5, 5);
        let key = CacheKey::new(&g, None, 7);
        cache.insert(key, g.clone(), None, value(g.n, 0.0));
        assert!(cache.get(&key, &g, None).is_none());
        let m = cache.metrics();
        assert_eq!((m.hits, m.misses, m.entries), (0, 0, 0));
        assert!(!cache.is_enabled());
    }

    #[test]
    fn shrinking_the_budget_evicts_down_and_zero_clears() {
        let cache = ResultCache::with_shards(1 << 20, 1);
        for i in 0..4usize {
            let g = mesh2d(8, 8 + i);
            cache.insert(CacheKey::new(&g, None, 7), g.clone(), None, value(g.n, 0.0));
        }
        assert_eq!(cache.entries(), 4);
        let two = cache.metrics().bytes / 2;
        cache.set_budget(two);
        assert!(cache.metrics().bytes <= two);
        assert!(cache.entries() < 4);
        cache.set_budget(0);
        assert_eq!(cache.entries(), 0, "disabling clears residency");
        assert_eq!(cache.metrics().bytes, 0);
    }

    #[test]
    fn oversized_entries_are_not_cached() {
        let g = mesh2d(20, 20);
        let cache = ResultCache::with_shards(64, 1); // far below one entry
        let key = CacheKey::new(&g, None, 7);
        cache.insert(key, g.clone(), None, value(g.n, 0.0));
        assert_eq!(cache.entries(), 0);
        assert_eq!(cache.metrics().evictions, 0);
    }

    #[test]
    fn report_renders_the_counters() {
        let cache = ResultCache::new(1 << 20);
        let g = mesh2d(4, 4);
        let key = CacheKey::new(&g, None, 7);
        cache.insert(key, g.clone(), None, value(g.n, 0.0));
        cache.get(&key, &g, None);
        let r = cache.metrics().report();
        assert!(r.contains("hits=1"), "report: {r}");
        assert!(r.contains("entries=1"), "report: {r}");
    }
}
