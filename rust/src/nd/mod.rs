//! Multilevel nested dissection — the comparator ordering of the paper's
//! §4.6 (cuDSS ND, a METIS variant). Same algorithmic family as METIS:
//!
//! 1. coarsen by heavy-edge matching until the graph is small;
//! 2. bisect the coarsest graph by BFS region growing from a
//!    pseudo-peripheral vertex;
//! 3. uncoarsen, refining the edge cut with Fiduccia–Mattheyses passes at
//!    every level;
//! 4. turn the edge separator into a vertex separator (greedy cover);
//! 5. recurse on the two parts; order leaves with AMD; emit
//!    `[left, right, separator]`.
//!
//! Two consumers share this stack:
//!
//! - [`NestedDissection`] as an [`Ordering`] — the standalone `--algo nd`
//!   comparator. Leaves default to sequential AMD; route them through a
//!   pooled warm ParAMD runtime with [`NestedDissection::with_paramd_leaves`]
//!   (one runtime + one arena reused across every leaf).
//! - [`NestedDissection::partition`] — the reusable *partition API* the
//!   hybrid planner ([`crate::ordering::hybrid`]) builds on: it stops the
//!   recursion at a caller-chosen depth and returns the independent
//!   subdomains plus the separator blocks instead of ordering anything,
//!   recursing across sibling subtrees in parallel.

pub mod bisect;
pub mod coarsen;
pub mod separator;

use crate::graph::csr::SymGraph;
use crate::ordering::paramd::{arena::ParAmdArena, runtime::OrderingRuntime, ParAmd};
use crate::ordering::{amd_seq::AmdSeq, Ordering, OrderingResult};
use crate::util::timer::Timer;

/// Below this many vertices a subtree is cut sequentially: the spawn +
/// join overhead of a scoped thread outweighs the bisection work.
const PAR_SUBTREE_MIN: usize = 4096;

/// Nested dissection configuration.
#[derive(Clone, Copy, Debug)]
pub struct NestedDissection {
    /// Stop recursion below this many vertices; order the leaf with AMD.
    pub leaf_size: usize,
    /// Coarsening stops at this size.
    pub coarsen_to: usize,
    /// FM refinement passes per level.
    pub fm_passes: usize,
    /// RNG seed (matching + tie-breaking).
    pub seed: u64,
    /// When non-zero, leaves are ordered by a warm ParAMD runtime of this
    /// width (one pooled arena reused across all leaves) instead of
    /// sequential AMD.
    pub leaf_threads: usize,
}

impl Default for NestedDissection {
    fn default() -> Self {
        Self {
            leaf_size: 64,
            coarsen_to: 200,
            fm_passes: 4,
            seed: 0x5eed,
            leaf_threads: 0,
        }
    }
}

/// One separator block of a [`Partition`]. `level` is the block's depth
/// in the dissection tree: the root separator has level 0, its
/// children's separators level 1, and so on.
#[derive(Clone, Debug)]
pub struct SeparatorBlock {
    /// Tree depth of the bisection that produced this block.
    pub level: usize,
    /// Original vertex ids of the separator.
    pub verts: Vec<i32>,
}

/// The output of [`NestedDissection::partition`]: pairwise-disjoint
/// subdomains (no edge of the graph connects two of them) plus the
/// separator blocks that cut them apart. Eliminating all subdomains
/// first (any internal order) and then the separator blocks as returned
/// — deepest level first, root separator last — respects the nested
/// dissection partial order, so the concatenation is a valid elimination
/// ordering of the whole graph.
#[derive(Clone, Debug)]
pub struct Partition {
    /// Subdomain vertex lists (original ids), in left-to-right tree
    /// order. Every vertex of the graph is in exactly one subdomain or
    /// one separator block.
    pub subdomains: Vec<Vec<i32>>,
    /// Separator blocks sorted deepest-level-first (elimination order);
    /// within a level, left-to-right tree order.
    pub separators: Vec<SeparatorBlock>,
}

impl Partition {
    /// Total vertices across the separator blocks.
    pub fn separator_vertices(&self) -> usize {
        self.separators.iter().map(|b| b.verts.len()).sum()
    }
}

impl Ordering for NestedDissection {
    fn name(&self) -> &'static str {
        "nd"
    }

    fn order(&self, g: &SymGraph) -> OrderingResult {
        let t = Timer::new();
        let mut perm = Vec::with_capacity(g.n);
        let all: Vec<i32> = (0..g.n as i32).collect();
        if self.leaf_threads > 0 && g.n > 2 {
            // Pooled warm path: one runtime and one arena serve every
            // leaf, so the per-leaf cost is ordering work, not pool
            // spin-up or arena allocation.
            let rt = OrderingRuntime::new(self.leaf_threads);
            let mut arena = ParAmdArena::new();
            let cfg = ParAmd::new(self.leaf_threads);
            let mut leaf =
                |sub: &SymGraph| cfg.order_into(&rt, &mut arena, sub).perm.clone();
            self.dissect(g, &all, &mut perm, &mut leaf);
        } else {
            let mut leaf = |sub: &SymGraph| AmdSeq::default().order(sub).perm;
            self.dissect(g, &all, &mut perm, &mut leaf);
        }
        debug_assert_eq!(perm.len(), g.n);
        let mut r = OrderingResult::new(perm);
        r.phases.add("core", t.secs());
        r
    }
}

impl NestedDissection {
    /// Route leaves through a warm ParAMD runtime of `threads` workers
    /// (the standalone `--algo nd` path; 0 restores sequential AMD).
    pub fn with_paramd_leaves(mut self, threads: usize) -> Self {
        self.leaf_threads = threads;
        self
    }

    /// Recursively order the subgraph induced by `verts` (original ids),
    /// appending to `out` in elimination order. `leaf` orders one leaf
    /// subgraph (compact ids) and returns its local permutation.
    fn dissect(
        &self,
        g: &SymGraph,
        verts: &[i32],
        out: &mut Vec<i32>,
        leaf: &mut dyn FnMut(&SymGraph) -> Vec<i32>,
    ) {
        if verts.len() <= self.leaf_size {
            self.order_leaf(g, verts, out, leaf);
            return;
        }
        let (sub, ids) = induced_subgraph(g, verts);
        let parts = bisect::multilevel_bisect(&sub, self);
        let (left, right, sep) = separator::vertex_separator(&sub, &parts);
        // Degenerate split (refinement collapse): fall back to AMD on the
        // whole piece to guarantee progress.
        if left.is_empty() || right.is_empty() {
            self.order_leaf(g, verts, out, leaf);
            return;
        }
        let to_orig = |v: &i32| ids[*v as usize];
        let lverts: Vec<i32> = left.iter().map(to_orig).collect();
        let rverts: Vec<i32> = right.iter().map(to_orig).collect();
        self.dissect(g, &lverts, out, leaf);
        self.dissect(g, &rverts, out, leaf);
        out.extend(sep.iter().map(to_orig));
    }

    fn order_leaf(
        &self,
        g: &SymGraph,
        verts: &[i32],
        out: &mut Vec<i32>,
        leaf: &mut dyn FnMut(&SymGraph) -> Vec<i32>,
    ) {
        if verts.len() <= 2 {
            out.extend_from_slice(verts);
            return;
        }
        let (sub, ids) = induced_subgraph(g, verts);
        let p = leaf(&sub);
        out.extend(p.iter().map(|&v| ids[v as usize]));
    }

    /// Cut the connected graph `g` into independent subdomains by
    /// recursive multilevel bisection, `depth` levels deep. A node's
    /// split is kept only when the larger side stays within
    /// `balance_factor ×` the ideal half (and neither side is empty);
    /// a rejected or too-small node becomes a single subdomain. Sibling
    /// subtrees above [`PAR_SUBTREE_MIN`] vertices are cut on parallel
    /// scoped threads — the partition itself is deterministic either
    /// way, because every recursion is a pure function of its piece.
    pub fn partition(&self, g: &SymGraph, depth: usize, balance_factor: f64) -> Partition {
        let all: Vec<i32> = (0..g.n as i32).collect();
        let mut cut = self.cut_rec(g, all, depth, balance_factor);
        // Deepest separators are eliminated first, the root separator
        // last; stable sort keeps left-to-right tree order in a level.
        cut.separators.sort_by_key(|b| std::cmp::Reverse(b.level));
        cut
    }

    fn cut_rec(&self, g: &SymGraph, verts: Vec<i32>, depth: usize, balance: f64) -> Partition {
        if depth == 0 || verts.len() <= self.leaf_size.max(2) {
            return Partition {
                subdomains: vec![verts],
                separators: Vec::new(),
            };
        }
        let (sub, ids) = induced_subgraph(g, &verts);
        let parts = bisect::multilevel_bisect(&sub, self);
        let (left, right, sep) = separator::vertex_separator(&sub, &parts);
        let ideal = (left.len() + right.len()) as f64 / 2.0;
        if left.is_empty()
            || right.is_empty()
            || left.len().max(right.len()) as f64 > balance * ideal
        {
            // Degenerate or lopsided cut: keep the piece whole rather
            // than hand the shards a skewed fan-out.
            return Partition {
                subdomains: vec![verts],
                separators: Vec::new(),
            };
        }
        let to_orig = |v: &i32| ids[*v as usize];
        let lverts: Vec<i32> = left.iter().map(to_orig).collect();
        let rverts: Vec<i32> = right.iter().map(to_orig).collect();
        let sep_verts: Vec<i32> = sep.iter().map(to_orig).collect();
        let (lcut, rcut) = if lverts.len().min(rverts.len()) >= PAR_SUBTREE_MIN {
            std::thread::scope(|s| {
                let lh = s.spawn(move || self.cut_rec(g, lverts, depth - 1, balance));
                let rcut = self.cut_rec(g, rverts, depth - 1, balance);
                (lh.join().expect("nd subtree cut panicked"), rcut)
            })
        } else {
            (
                self.cut_rec(g, lverts, depth - 1, balance),
                self.cut_rec(g, rverts, depth - 1, balance),
            )
        };
        let mut subdomains = lcut.subdomains;
        subdomains.extend(rcut.subdomains);
        let mut separators =
            Vec::with_capacity(lcut.separators.len() + rcut.separators.len() + 1);
        for mut b in lcut.separators {
            b.level += 1;
            separators.push(b);
        }
        for mut b in rcut.separators {
            b.level += 1;
            separators.push(b);
        }
        separators.push(SeparatorBlock {
            level: 0,
            verts: sep_verts,
        });
        Partition {
            subdomains,
            separators,
        }
    }
}

/// Induced subgraph of `verts`; returns the subgraph plus the local→orig
/// id map.
pub fn induced_subgraph(g: &SymGraph, verts: &[i32]) -> (SymGraph, Vec<i32>) {
    let mut local = vec![-1i32; g.n];
    for (k, &v) in verts.iter().enumerate() {
        local[v as usize] = k as i32;
    }
    let mut rowptr = vec![0usize; verts.len() + 1];
    let mut colind = Vec::new();
    for (k, &v) in verts.iter().enumerate() {
        for &u in g.neighbors(v as usize) {
            if local[u as usize] != -1 {
                colind.push(local[u as usize]);
            }
        }
        rowptr[k + 1] = colind.len();
    }
    // Rows inherit sortedness only if `verts` is sorted; sort each row.
    for k in 0..verts.len() {
        colind[rowptr[k]..rowptr[k + 1]].sort_unstable();
    }
    (
        SymGraph {
            n: verts.len(),
            rowptr,
            colind,
        },
        verts.to_vec(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{mesh2d, mesh3d, random_graph};
    use crate::ordering::test_support::check_ordering_contract;
    use crate::symbolic::fill_in;

    #[test]
    fn valid_on_meshes() {
        let g = mesh2d(20, 20);
        let r = NestedDissection::default().order(&g);
        check_ordering_contract(&g, &r);
    }

    #[test]
    fn valid_on_random_graphs() {
        for seed in 0..4 {
            let g = random_graph(300, 6, seed);
            let r = NestedDissection::default().order(&g);
            check_ordering_contract(&g, &r);
        }
    }

    #[test]
    fn valid_on_disconnected_graphs() {
        // Two disjoint meshes.
        let a = mesh2d(10, 10);
        let mut edges = vec![];
        for v in 0..a.n {
            for &u in a.neighbors(v) {
                if (u as usize) > v {
                    edges.push((v, u as usize));
                    edges.push((v + a.n, u as usize + a.n));
                }
            }
        }
        let g = SymGraph::from_edges(2 * a.n, &edges);
        let r = NestedDissection::default().order(&g);
        check_ordering_contract(&g, &r);
    }

    #[test]
    fn beats_natural_ordering_on_3d_mesh() {
        let g = mesh3d(8, 8, 8);
        let r = NestedDissection::default().order(&g);
        check_ordering_contract(&g, &r);
        let natural: Vec<i32> = (0..g.n as i32).collect();
        assert!(fill_in(&g, &r.perm) < fill_in(&g, &natural));
    }

    #[test]
    fn fill_competitive_with_amd_on_meshes() {
        // The paper's Table 4.4: ND produces *fewer* fill-ins than AMD on
        // large 3D meshes; at mini scale we accept parity within 2×.
        let g = mesh3d(9, 9, 9);
        let f_nd = fill_in(&g, &NestedDissection::default().order(&g).perm) as f64;
        let f_amd = fill_in(&g, &AmdSeq::default().order(&g).perm) as f64;
        assert!(f_nd < 2.0 * f_amd, "nd={f_nd} amd={f_amd}");
    }

    #[test]
    fn induced_subgraph_correct() {
        let g = mesh2d(3, 3);
        let verts = vec![0i32, 1, 3, 4];
        let (sub, ids) = induced_subgraph(&g, &verts);
        sub.validate().unwrap();
        assert_eq!(ids, verts);
        // 0-1, 0-3, 1-4, 3-4 survive.
        assert_eq!(sub.nedges(), 4);
    }

    #[test]
    fn tiny_graphs() {
        for n in 0..5 {
            let g = SymGraph::from_edges(n, &[]);
            let r = NestedDissection::default().order(&g);
            check_ordering_contract(&g, &r);
        }
    }

    #[test]
    fn paramd_leaves_produce_a_valid_ordering() {
        let g = mesh2d(22, 22);
        let r = NestedDissection::default().with_paramd_leaves(2).order(&g);
        check_ordering_contract(&g, &r);
        for n in 0..5 {
            let t = SymGraph::from_edges(n, &[]);
            let r = NestedDissection::default().with_paramd_leaves(2).order(&t);
            check_ordering_contract(&t, &r);
        }
    }

    #[test]
    fn partition_covers_the_graph_exactly_once() {
        let g = mesh2d(30, 30);
        let cut = NestedDissection::default().partition(&g, 2, 1.5);
        assert!(cut.subdomains.len() >= 2, "a mesh must split");
        let mut seen = vec![false; g.n];
        let mut mark = |v: i32| {
            assert!(!seen[v as usize], "vertex {v} assigned twice");
            seen[v as usize] = true;
        };
        for d in &cut.subdomains {
            for &v in d {
                mark(v);
            }
        }
        for b in &cut.separators {
            for &v in &b.verts {
                mark(v);
            }
        }
        assert!(seen.iter().all(|&s| s), "every vertex assigned");
    }

    #[test]
    fn partition_subdomains_are_independent() {
        // No edge may connect two different subdomains: separators must
        // cut them apart completely.
        let g = mesh3d(9, 9, 9);
        let cut = NestedDissection::default().partition(&g, 2, 1.5);
        let mut owner = vec![-1i64; g.n];
        for (d, verts) in cut.subdomains.iter().enumerate() {
            for &v in verts {
                owner[v as usize] = d as i64;
            }
        }
        for v in 0..g.n {
            if owner[v] < 0 {
                continue; // separator vertex
            }
            for &u in g.neighbors(v) {
                let o = owner[u as usize];
                assert!(
                    o < 0 || o == owner[v],
                    "edge {v}-{u} crosses subdomains {} and {o}",
                    owner[v]
                );
            }
        }
    }

    #[test]
    fn partition_separators_come_deepest_first() {
        let g = mesh2d(40, 40);
        let cut = NestedDissection::default().partition(&g, 3, 1.6);
        for w in cut.separators.windows(2) {
            assert!(w[0].level >= w[1].level, "deepest level first");
        }
        assert_eq!(
            cut.separators.last().map(|b| b.level),
            Some(0),
            "the root separator is eliminated last"
        );
    }

    #[test]
    fn partition_depth_zero_is_one_subdomain() {
        let g = mesh2d(12, 12);
        let cut = NestedDissection::default().partition(&g, 0, 1.3);
        assert_eq!(cut.subdomains.len(), 1);
        assert!(cut.separators.is_empty());
        assert_eq!(cut.subdomains[0].len(), g.n);
    }

    #[test]
    fn partition_is_deterministic() {
        // The parallel subtree recursion must not perturb the result.
        let g = mesh2d(120, 120); // halves cross PAR_SUBTREE_MIN
        let a = NestedDissection::default().partition(&g, 2, 1.5);
        let b = NestedDissection::default().partition(&g, 2, 1.5);
        assert_eq!(a.subdomains, b.subdomains);
        assert_eq!(a.separators.len(), b.separators.len());
        for (x, y) in a.separators.iter().zip(&b.separators) {
            assert_eq!((x.level, &x.verts), (y.level, &y.verts));
        }
    }
}
