//! Matrix Market (.mtx) reader/writer.
//!
//! Supports the `matrix coordinate` format with `real | integer | pattern`
//! fields and `general | symmetric | skew-symmetric` symmetries — the
//! subset covering the SuiteSparse Matrix Collection files the paper uses.
//!
//! The read path is hardened against malformed input: truncated headers,
//! non-numeric tokens, 0/out-of-range indices, and header dimensions that
//! lie about the body (or overflow the `i32` index space the CSR layer
//! uses) all come back as a typed [`MmError`] — never a panic and never
//! an unbounded allocation — so a long-running service can reject a bad
//! upload and keep serving.

use std::io::{BufRead, BufReader, Write as _};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::graph::csr::CsrMatrix;

/// Parsed header of a Matrix Market file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MmSymmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

/// Typed error from [`read_matrix_market`].
#[derive(Debug)]
pub enum MmError {
    /// The file could not be opened or read.
    Io {
        /// Operation that failed (`"open"` or `"read"`).
        op: &'static str,
        /// File being read.
        path: PathBuf,
        /// Underlying I/O error.
        source: std::io::Error,
    },
    /// The file contents violate the Matrix Market grammar.
    Malformed {
        /// File being read.
        path: PathBuf,
        /// 1-based line number of the offending line (0 when the file
        /// ended before the expected line existed).
        line: u64,
        /// Human-readable description of the violation.
        reason: String,
    },
}

impl std::fmt::Display for MmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MmError::Io { op, path, source } => {
                write!(f, "{op} {}: {source}", path.display())
            }
            MmError::Malformed { path, line, reason } => {
                write!(f, "{}:{line}: malformed MatrixMarket file: {reason}", path.display())
            }
        }
    }
}

impl std::error::Error for MmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MmError::Io { source, .. } => Some(source),
            MmError::Malformed { .. } => None,
        }
    }
}

fn malformed(path: &Path, line: u64, reason: impl Into<String>) -> MmError {
    MmError::Malformed { path: path.to_path_buf(), line, reason: reason.into() }
}

/// Read one line into `buf`, bumping the 1-based line counter.
/// Returns `Ok(false)` at EOF.
fn next_line(
    r: &mut impl BufRead,
    buf: &mut String,
    lineno: &mut u64,
    path: &Path,
) -> Result<bool, MmError> {
    buf.clear();
    let n = r
        .read_line(buf)
        .map_err(|e| MmError::Io { op: "read", path: path.to_path_buf(), source: e })?;
    if n == 0 {
        return Ok(false);
    }
    *lineno += 1;
    Ok(true)
}

/// Parse one whitespace token as `T`; overflow and garbage both come
/// back as a typed [`MmError::Malformed`] carrying the line number.
fn parse_num<T: std::str::FromStr>(
    tok: &str,
    what: &str,
    path: &Path,
    lineno: u64,
) -> Result<T, MmError> {
    tok.parse::<T>()
        .map_err(|_| malformed(path, lineno, format!("non-numeric or overflowing {what} {tok:?}")))
}

/// Read a Matrix Market coordinate file into a [`CsrMatrix`].
/// Symmetric/skew storage is expanded to full storage.
///
/// Any malformed input — truncated header or body, non-numeric tokens,
/// 0-based or out-of-range indices, extra tokens on a data line, or
/// dimensions beyond the `i32` index range — returns [`MmError`]
/// instead of panicking.
pub fn read_matrix_market(path: &Path) -> Result<CsrMatrix, MmError> {
    let f = std::fs::File::open(path)
        .map_err(|e| MmError::Io { op: "open", path: path.to_path_buf(), source: e })?;
    let mut reader = BufReader::new(f);
    let mut line = String::new();
    let mut lineno = 0u64;

    if !next_line(&mut reader, &mut line, &mut lineno, path)? {
        return Err(malformed(path, 0, "empty file: missing %%MatrixMarket header"));
    }
    let header: Vec<String> = line.trim().split_whitespace().map(|s| s.to_lowercase()).collect();
    if header.len() < 5 || header[0] != "%%matrixmarket" || header[1] != "matrix" {
        return Err(malformed(
            path,
            lineno,
            format!("not a MatrixMarket matrix header: {:?}", line.trim()),
        ));
    }
    if header[2] != "coordinate" {
        return Err(malformed(
            path,
            lineno,
            format!("only coordinate format supported, got {:?}", header[2]),
        ));
    }
    let field = header[3].clone();
    if !matches!(field.as_str(), "real" | "integer" | "pattern") {
        return Err(malformed(path, lineno, format!("unsupported field type {field:?}")));
    }
    let sym = match header[4].as_str() {
        "general" => MmSymmetry::General,
        "symmetric" => MmSymmetry::Symmetric,
        "skew-symmetric" => MmSymmetry::SkewSymmetric,
        s => return Err(malformed(path, lineno, format!("unsupported symmetry {s:?}"))),
    };

    // Skip comments, read size line.
    let (nrows, ncols, nnz) = loop {
        if !next_line(&mut reader, &mut line, &mut lineno, path)? {
            return Err(malformed(path, lineno, "unexpected EOF before the size line"));
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let toks: Vec<&str> = t.split_whitespace().collect();
        if toks.len() != 3 {
            return Err(malformed(
                path,
                lineno,
                format!("size line needs exactly 3 tokens (rows cols nnz), got {}", toks.len()),
            ));
        }
        let nr: usize = parse_num(toks[0], "nrows", path, lineno)?;
        let nc: usize = parse_num(toks[1], "ncols", path, lineno)?;
        let nz: usize = parse_num(toks[2], "nnz", path, lineno)?;
        // The CSR layer indexes columns with i32; a header past that
        // range can never produce a valid matrix, so reject it up front
        // rather than overflow during conversion.
        if nr > i32::MAX as usize || nc > i32::MAX as usize {
            return Err(malformed(
                path,
                lineno,
                format!("dimensions {nr}x{nc} exceed the supported i32 index range"),
            ));
        }
        break (nr, nc, nz);
    };

    // Pre-size from the header but cap the trusted allocation: a lying
    // header (`nnz` in the billions over a 3-line body) must not OOM the
    // reader before the truncated-body check can fire.
    let want = nnz.saturating_mul(if sym == MmSymmetry::General { 1 } else { 2 });
    let mut triplets: Vec<(usize, usize, f64)> = Vec::with_capacity(want.min(1 << 20));
    let mut count = 0usize;
    while count < nnz {
        if !next_line(&mut reader, &mut line, &mut lineno, path)? {
            return Err(malformed(
                path,
                lineno,
                format!("unexpected EOF: read {count} of {nnz} entries"),
            ));
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let toks: Vec<&str> = t.split_whitespace().collect();
        let want_toks = if field == "pattern" { 2 } else { 3 };
        if toks.len() != want_toks {
            return Err(malformed(
                path,
                lineno,
                format!("entry line needs exactly {want_toks} tokens, got {}", toks.len()),
            ));
        }
        let r1: usize = parse_num(toks[0], "row index", path, lineno)?;
        let c1: usize = parse_num(toks[1], "col index", path, lineno)?;
        if r1 == 0 || c1 == 0 {
            return Err(malformed(path, lineno, "indices are 1-based; found 0"));
        }
        let (r, c) = (r1 - 1, c1 - 1);
        let v: f64 =
            if field == "pattern" { 1.0 } else { parse_num(toks[2], "value", path, lineno)? };
        if r >= nrows || c >= ncols {
            return Err(malformed(
                path,
                lineno,
                format!("entry ({r1},{c1}) out of bounds {nrows}x{ncols}"),
            ));
        }
        triplets.push((r, c, v));
        if r != c {
            match sym {
                MmSymmetry::Symmetric => triplets.push((c, r, v)),
                MmSymmetry::SkewSymmetric => triplets.push((c, r, -v)),
                MmSymmetry::General => {}
            }
        }
        count += 1;
    }
    Ok(CsrMatrix::from_triplets(nrows, ncols, &triplets))
}

/// Write a matrix in `general real coordinate` format.
pub fn write_matrix_market(path: &Path, m: &CsrMatrix) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by paramd")?;
    writeln!(w, "{} {} {}", m.nrows, m.ncols, m.nnz())?;
    for r in 0..m.nrows {
        for p in m.rowptr[r]..m.rowptr[r + 1] {
            writeln!(w, "{} {} {:.17e}", r + 1, m.colind[p] + 1, m.values[p])?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("paramd_mm_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_general() {
        let m = CsrMatrix::from_triplets(
            3,
            3,
            &[(0, 0, 1.5), (0, 2, -2.0), (1, 1, 3.0), (2, 0, 4.0)],
        );
        let p = tmp("rt.mtx");
        write_matrix_market(&p, &m).unwrap();
        let m2 = read_matrix_market(&p).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn symmetric_expansion() {
        let p = tmp("sym.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n3 3 3\n1 1 2.0\n2 1 5.0\n3 2 7.0\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.nnz(), 5);
        assert!(m.is_pattern_symmetric());
        assert_eq!(m.row(0), &[0, 1]);
        assert_eq!(m.row_values(1), &[5.0, 7.0]);
    }

    #[test]
    fn pattern_field() {
        let p = tmp("pat.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate pattern general\n% comment\n2 2 2\n1 2\n2 1\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.row_values(0), &[1.0]);
    }

    #[test]
    fn skew_symmetric() {
        let p = tmp("skew.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real skew-symmetric\n2 2 1\n2 1 3.0\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.row_values(0), &[-3.0]);
        assert_eq!(m.row_values(1), &[3.0]);
    }

    #[test]
    fn missing_file_is_a_typed_io_error() {
        let e = read_matrix_market(Path::new("/nonexistent/paramd.mtx")).unwrap_err();
        assert!(matches!(e, MmError::Io { op: "open", .. }), "{e}");
    }

    /// Malformed-corpus sweep: every corrupt shape returns a typed
    /// `MmError::Malformed` (with the offending line number in its
    /// Display form) — no panics, no unbounded allocation, no
    /// arithmetic underflow on 0-based indices.
    #[test]
    fn malformed_corpus_returns_typed_errors() {
        let corpus: &[(&str, &str, &str)] = &[
            ("empty", "", "missing %%MatrixMarket header"),
            ("truncated_header", "%%MatrixMarket matrix\n2 2 1\n1 1 1.0\n", "header"),
            ("not_mm", "hello world\n2 2 1\n1 1 1.0\n", "header"),
            ("bad_format", "%%MatrixMarket matrix array real general\n2 2\n", "coordinate"),
            ("bad_field", "%%MatrixMarket matrix coordinate complex general\n", "field type"),
            ("bad_symmetry", "%%MatrixMarket matrix coordinate real hermitian\n", "symmetry"),
            ("no_size_line", "%%MatrixMarket matrix coordinate real general\n% only\n", "EOF"),
            (
                "short_size_line",
                "%%MatrixMarket matrix coordinate real general\n2 2\n",
                "exactly 3 tokens",
            ),
            (
                "dup_size_tokens",
                "%%MatrixMarket matrix coordinate real general\n2 2 1 1\n1 1 1.0\n",
                "exactly 3 tokens",
            ),
            (
                "non_numeric_dims",
                "%%MatrixMarket matrix coordinate real general\na b c\n",
                "non-numeric",
            ),
            (
                "overflowing_dims",
                "%%MatrixMarket matrix coordinate real general\n\
                 99999999999999999999999999 2 1\n1 1 1.0\n",
                "overflowing",
            ),
            (
                "dims_past_i32",
                "%%MatrixMarket matrix coordinate real general\n3000000000 2 1\n1 1 1.0\n",
                "i32 index range",
            ),
            (
                "zero_based_index",
                "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n",
                "1-based",
            ),
            (
                "row_out_of_range",
                "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n",
                "out of bounds",
            ),
            (
                "col_out_of_range",
                "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 9 1.0\n",
                "out of bounds",
            ),
            (
                "non_numeric_index",
                "%%MatrixMarket matrix coordinate real general\n2 2 1\nx 1 1.0\n",
                "non-numeric",
            ),
            (
                "non_numeric_value",
                "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 abc\n",
                "non-numeric",
            ),
            (
                "missing_value_token",
                "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1\n",
                "exactly 3 tokens",
            ),
            (
                "extra_entry_tokens",
                "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n1 1 1.0\n",
                "exactly 2 tokens",
            ),
            (
                "truncated_body",
                "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n",
                "read 1 of 3 entries",
            ),
            (
                "lying_huge_nnz",
                "%%MatrixMarket matrix coordinate real symmetric\n\
                 2 2 18446744073709551615\n1 1 1.0\n",
                "entries",
            ),
        ];
        for (name, body, want) in corpus {
            let p = tmp(&format!("bad_{name}.mtx"));
            std::fs::write(&p, body).unwrap();
            let e = read_matrix_market(&p).unwrap_err();
            assert!(matches!(e, MmError::Malformed { .. }), "{name}: expected Malformed, got {e}");
            let msg = e.to_string();
            assert!(msg.contains(want), "{name}: {msg:?} missing {want:?}");
        }
    }

    #[test]
    fn malformed_error_carries_the_line_number() {
        let p = tmp("lineno.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n% c\n2 2 2\n1 1 1.0\n2 9 1.0\n",
        )
        .unwrap();
        match read_matrix_market(&p).unwrap_err() {
            MmError::Malformed { line, .. } => assert_eq!(line, 5),
            e => panic!("expected Malformed, got {e}"),
        }
    }
}
