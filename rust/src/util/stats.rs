//! Descriptive statistics for benchmark reporting: mean ± std (Table 4.2),
//! percentiles and histogram bins (the Figure 4.2 violin plots).

/// Mean of a sample (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 for fewer than 2 points).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Linear-interpolated percentile, `q` in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Five-number summary + mean, the series a violin/box plot needs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub max: f64,
    pub mean: f64,
    pub n: usize,
}

pub fn summary(xs: &[f64]) -> Summary {
    Summary {
        min: percentile(xs, 0.0),
        p25: percentile(xs, 25.0),
        median: percentile(xs, 50.0),
        p75: percentile(xs, 75.0),
        max: percentile(xs, 100.0),
        mean: mean(xs),
        n: xs.len(),
    }
}

/// Histogram over `bins` equal-width buckets spanning `[min, max]` of the
/// data; returns `(bucket_low_edges, counts)`. Used to print violin-plot
/// density series as text.
pub fn histogram(xs: &[f64], bins: usize) -> (Vec<f64>, Vec<usize>) {
    assert!(bins > 0);
    if xs.is_empty() {
        return (vec![0.0; bins], vec![0; bins]);
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let width = if hi > lo { (hi - lo) / bins as f64 } else { 1.0 };
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let mut b = ((x - lo) / width) as usize;
        if b >= bins {
            b = bins - 1;
        }
        counts[b] += 1;
    }
    let edges = (0..bins).map(|i| lo + i as f64 * width).collect();
    (edges, counts)
}

/// Fraction of samples strictly below `threshold` (the paper quotes the
/// share of distance-2 sets with size < 64 in §4.4).
pub fn frac_below(xs: &[f64], threshold: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&x| x < threshold).count() as f64 / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(std_dev(&[3.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_ordered() {
        let xs = [5.0, 1.0, 9.0, 3.0, 7.0];
        let s = summary(&xs);
        assert!(s.min <= s.p25 && s.p25 <= s.median);
        assert!(s.median <= s.p75 && s.p75 <= s.max);
        assert_eq!(s.n, 5);
        assert_eq!(s.median, 5.0);
    }

    #[test]
    fn histogram_counts_all() {
        let xs = [0.0, 0.1, 0.5, 0.9, 1.0];
        let (_, counts) = histogram(&xs, 4);
        assert_eq!(counts.iter().sum::<usize>(), xs.len());
    }

    #[test]
    fn frac_below_works() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((frac_below(&xs, 3.0) - 0.5).abs() < 1e-12);
        assert_eq!(frac_below(&[], 3.0), 0.0);
    }
}
