//! Pre-ordering graph reduction: shrink the quotient graph *before*
//! elimination starts.
//!
//! The paper shows ParAMD's per-round parallelism is bounded by the size
//! of the distance-2 independent sets and the per-pivot workload (§4);
//! every vertex removed up front cuts rounds, barriers, and memory
//! traffic at once. This module applies three classic, *exact-or-better*
//! data reductions (cf. Ost/Schulz/Strash, "Engineering Data Reduction
//! for Nested Dissection", and the dense-row handling in SuiteSparse
//! AMD) and records enough bookkeeping to expand a reduced ordering back
//! into a full permutation with exact fill accounting:
//!
//! 1. **Degree-0/1 leaf stripping** ([`ReduceConfig::leaves`]) —
//!    isolated and pendant vertices are peeled iteratively (a pendant
//!    chain unravels completely) straight into the **permutation
//!    prefix**. A vertex with at most one live neighbor at its
//!    elimination time causes zero fill, so the prefix is
//!    minimum-degree-optimal and exact.
//! 2. **Dense-row postponement** ([`ReduceConfig::dense`]) — rows with
//!    live degree above `max(16, α·√n)` (the SuiteSparse-style
//!    threshold; `α` is [`ReduceConfig::dense_alpha`]) are extracted and
//!    appended to the **permutation tail**, least-dense first. A dense
//!    row touches nearly every `L_e` scan of every round; postponing it
//!    to the end removes it from all of them, at a bounded fill cost
//!    (the tail rows factor as a near-dense trailing block — exactly
//!    what they would have become anyway).
//! 3. **Twin compression** ([`ReduceConfig::twins`]) — indistinguishable
//!    vertices (`N(u) \ {v} = N(v) \ {u}`, covering both adjacent "true"
//!    twins and non-adjacent "false" twins) are merged into a single
//!    **seed supervariable** whose weight feeds ParAMD's `nv` setup
//!    ([`crate::ordering::paramd::shared::SharedGraph::reset_from_weighted`]),
//!    so elimination starts pre-compressed instead of rediscovering the
//!    merge hash-by-hash mid-run. Detection is the same hash-then-verify
//!    scheme AMD uses internally: **parallel fingerprinting** of
//!    adjacency lists over vertex ranges, then exact comparison within
//!    hash buckets.
//!
//! ## Rule ordering
//!
//! Leaf stripping and dense postponement alternate to a fixpoint
//! (removing a dense row can expose new pendants; peeling pendants can
//! only lower degrees, never create new dense rows), then twins are
//! detected once on the surviving graph. Twin detection runs last
//! because the other two rules change live neighborhoods, and because
//! leaves/dense rows are cheaper to test for.
//!
//! ## Why expansion is exact
//!
//! [`ReductionPlan::expand`] emits `prefix ++ expand(kernel perm) ++
//! tail`. The prefix is fill-free by construction. Twin-class members
//! are emitted contiguously right after their representative — the same
//! bucket placement [`crate::ordering::rebuild_perm`] gives columns
//! absorbed into a supervariable mid-run, and twins are symbolically
//! interchangeable, so every member column of a class has the identical
//! factor-column pattern the representative's pivot established. The
//! merge forest ([`ReductionPlan::merge_parent`]) records exactly which
//! representative absorbed each member, so `fill_of`/`fill_in` on the
//! expanded permutation measures the true factorization, not an
//! approximation.

pub mod live;

use std::collections::VecDeque;

use crate::graph::csr::SymGraph;
use crate::util::chunk_range;
use crate::util::rng::splitmix64;

/// Vertex count below which fingerprinting stays single-threaded (spawn
/// cost outweighs the scan).
const PAR_FINGERPRINT_MIN: usize = 4096;

/// Which reduction rules to apply, and their knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReduceConfig {
    /// Iteratively peel degree-0/1 vertices into the permutation prefix.
    pub leaves: bool,
    /// Postpone rows with live degree > `max(16, dense_alpha·√n)` to the
    /// permutation tail.
    pub dense: bool,
    /// Merge indistinguishable vertices into seed supervariables.
    pub twins: bool,
    /// The `α` of the dense threshold `max(16, α·√n)`. SuiteSparse AMD
    /// uses 10·√n; smaller is more aggressive.
    pub dense_alpha: f64,
    /// Worker threads for the fingerprinting scan (1 = serial). The
    /// shard engine overrides this with its wide-shard width.
    pub threads: usize,
}

impl Default for ReduceConfig {
    fn default() -> Self {
        Self {
            leaves: true,
            dense: true,
            twins: true,
            dense_alpha: 10.0,
            threads: 1,
        }
    }
}

impl ReduceConfig {
    /// A config with every rule switched off ([`reduce`] then returns a
    /// trivial plan).
    pub fn disabled() -> Self {
        Self {
            leaves: false,
            dense: false,
            twins: false,
            ..Self::default()
        }
    }

    /// Whether any rule is active.
    pub fn is_enabled(&self) -> bool {
        self.leaves || self.dense || self.twins
    }
}

/// The dense-row cutoff: live degree strictly above this postpones a row.
pub fn dense_threshold(n: usize, alpha: f64) -> usize {
    let scaled = (alpha * (n as f64).sqrt()).floor();
    if scaled.is_finite() && scaled >= 16.0 {
        scaled as usize
    } else {
        16
    }
}

/// Per-rule reduction counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReduceStats {
    /// Vertices peeled into the prefix by leaf stripping.
    pub leaves: usize,
    /// Rows postponed to the tail.
    pub dense: usize,
    /// Vertices folded into a twin representative (class size − 1, summed).
    pub twins_merged: usize,
    /// Undirected edges that vanished from the ordering problem.
    pub edges_removed: usize,
}

/// The outcome of [`reduce`]: the kernel graph ParAMD actually orders,
/// plus everything needed to expand a kernel permutation back to the
/// original vertex space.
#[derive(Clone, Debug)]
pub struct ReductionPlan {
    /// Original vertex count.
    pub n: usize,
    /// Leaf-stripped vertices, in peel order (eliminated first, zero fill).
    pub prefix: Vec<i32>,
    /// Postponed dense rows, least-dense first (eliminated last).
    pub tail: Vec<i32>,
    /// The reduced graph over twin-class representatives.
    pub kernel: SymGraph,
    /// `old_of_new[k]` = original vertex of kernel vertex `k` (the class
    /// representative; strictly increasing).
    pub old_of_new: Vec<i32>,
    /// `weights[k]` = twin-class size of kernel vertex `k` — the `nv`
    /// seed fed into the quotient-graph setup.
    pub weights: Vec<i32>,
    /// Flattened twin-class member lists (original ids, representative
    /// first, ascending), indexed by `member_ptr` per kernel vertex.
    pub members: Vec<i32>,
    pub member_ptr: Vec<usize>,
    /// Per-rule counters.
    pub stats: ReduceStats,
}

impl ReductionPlan {
    /// True when no rule fired: the kernel *is* the input graph and
    /// callers should keep the original (possibly borrowed) path.
    pub fn is_trivial(&self) -> bool {
        self.prefix.is_empty() && self.tail.is_empty() && self.stats.twins_merged == 0
    }

    /// Vertices the kernel no longer contains (prefix + tail + merged
    /// twin members).
    pub fn reduced_away(&self) -> usize {
        self.n - self.kernel.n
    }

    /// Vertices ordered outside the kernel rounds entirely (prefix +
    /// tail) — the count the expanded round log reports as its
    /// reduction "round".
    pub fn pre_ordered(&self) -> usize {
        self.prefix.len() + self.tail.len()
    }

    /// The merge forest: `parent[v]` = the representative that absorbed
    /// twin `v`, `-1` for representatives and un-merged vertices — the
    /// same shape as the quotient graph's absorption forest.
    pub fn merge_parent(&self) -> Vec<i32> {
        let mut parent = vec![-1i32; self.n];
        for k in 0..self.kernel.n {
            let rep = self.old_of_new[k];
            for &m in &self.members[self.member_ptr[k] + 1..self.member_ptr[k + 1]] {
                parent[m as usize] = rep;
            }
        }
        parent
    }

    /// Expand a kernel permutation into a permutation of the original
    /// `n` vertices: prefix, then each kernel pivot's twin class
    /// (representative first), then the dense tail.
    pub fn expand(&self, kernel_perm: &[i32]) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.n);
        self.expand_into(kernel_perm, &mut out);
        out
    }

    /// [`Self::expand`] into a caller-owned buffer.
    pub fn expand_into(&self, kernel_perm: &[i32], out: &mut Vec<i32>) {
        assert_eq!(
            kernel_perm.len(),
            self.kernel.n,
            "kernel permutation does not match the reduced graph"
        );
        out.clear();
        out.extend_from_slice(&self.prefix);
        for &p in kernel_perm {
            let k = p as usize;
            out.extend_from_slice(&self.members[self.member_ptr[k]..self.member_ptr[k + 1]]);
        }
        out.extend_from_slice(&self.tail);
        assert_eq!(out.len(), self.n, "expansion must cover every vertex");
    }
}

/// Parallel fingerprint scan: for every live vertex, the commutative
/// hash of its live open neighborhood plus its live degree. Chunked
/// over vertex ranges; deterministic regardless of thread count.
fn fingerprints(g: &SymGraph, alive: &[bool], threads: usize) -> (Vec<u64>, Vec<u32>) {
    let n = g.n;
    let mut hash = vec![0u64; n];
    let mut ldeg = vec![0u32; n];
    let fill = |range: std::ops::Range<usize>, hash: &mut [u64], ldeg: &mut [u32]| {
        for (i, v) in range.enumerate() {
            if !alive[v] {
                continue;
            }
            let (mut h, mut d) = (0u64, 0u32);
            for &u in g.neighbors(v) {
                if alive[u as usize] {
                    // SplitMix64-mixed, summed: a commutative
                    // (order-independent) neighborhood fingerprint.
                    h = h.wrapping_add(splitmix64(u as u64));
                    d += 1;
                }
            }
            hash[i] = h;
            ldeg[i] = d;
        }
    };
    let t = threads.max(1).min(n.max(1));
    if t == 1 || n < PAR_FINGERPRINT_MIN {
        fill(0..n, &mut hash, &mut ldeg);
    } else {
        std::thread::scope(|s| {
            let mut rest_h = hash.as_mut_slice();
            let mut rest_d = ldeg.as_mut_slice();
            for tid in 0..t {
                let (lo, hi) = chunk_range(n, t, tid);
                let (h, rh) = rest_h.split_at_mut(hi - lo);
                let (d, rd) = rest_d.split_at_mut(hi - lo);
                rest_h = rh;
                rest_d = rd;
                let fill = &fill;
                s.spawn(move || fill(lo..hi, h, d));
            }
        });
    }
    (hash, ldeg)
}

/// Exact twin test: `N(a) \ {b} == N(b) \ {a}` over live vertices. Covers
/// adjacent (true) and non-adjacent (false) twins uniformly; hashes only
/// nominate candidates, this comparison is the ground truth.
fn twin_eq(g: &SymGraph, alive: &[bool], a: usize, b: usize) -> bool {
    let mut ia = g.neighbors(a).iter().filter(|&&u| {
        let uu = u as usize;
        alive[uu] && uu != b
    });
    let mut ib = g.neighbors(b).iter().filter(|&&u| {
        let uu = u as usize;
        alive[uu] && uu != a
    });
    loop {
        match (ia.next(), ib.next()) {
            (None, None) => return true,
            (Some(x), Some(y)) if x == y => continue,
            _ => return false,
        }
    }
}

/// Group live vertices by `(key, live degree)` and merge every verified
/// twin pair into the bucket's first unmerged vertex. `rep` is updated in
/// place; merged vertices are flagged in `in_class`.
fn merge_twin_buckets(
    g: &SymGraph,
    alive: &[bool],
    keys: &mut [(u64, u32, u32)],
    rep: &mut [i32],
    in_class: &mut [bool],
) -> usize {
    keys.sort_unstable();
    let mut merged = 0usize;
    let mut i = 0;
    while i < keys.len() {
        let mut j = i + 1;
        while j < keys.len() && keys[j].0 == keys[i].0 && keys[j].1 == keys[i].1 {
            j += 1;
        }
        for a_idx in i..j {
            let a = keys[a_idx].2 as usize;
            if rep[a] != a as i32 {
                continue; // already absorbed into an earlier class
            }
            for b_idx in a_idx + 1..j {
                let b = keys[b_idx].2 as usize;
                if rep[b] == b as i32 && twin_eq(g, alive, a, b) {
                    rep[b] = a as i32;
                    in_class[a] = true;
                    in_class[b] = true;
                    merged += 1;
                }
            }
        }
        i = j;
    }
    merged
}

/// Apply the configured reduction rules to `g` and return the plan —
/// [`try_reduce`] with a trivial identity plan (kernel = a plain copy of
/// `g`) when no rule fired. The plan is deterministic in `g` and `cfg`
/// (thread count included — the parallel fingerprint scan is a pure
/// per-vertex function).
pub fn reduce(g: &SymGraph, cfg: &ReduceConfig) -> ReductionPlan {
    try_reduce(g, cfg).unwrap_or_else(|| trivial_plan(g))
}

/// The identity plan of an irreducible graph: the kernel *is* the graph
/// (one bulk copy, no row relabeling), all weights 1, identity member
/// lists.
fn trivial_plan(g: &SymGraph) -> ReductionPlan {
    let n = g.n;
    ReductionPlan {
        n,
        prefix: Vec::new(),
        tail: Vec::new(),
        kernel: g.clone(),
        old_of_new: (0..n as i32).collect(),
        weights: vec![1; n],
        members: (0..n as i32).collect(),
        member_ptr: (0..=n).collect(),
        stats: ReduceStats::default(),
    }
}

/// [`reduce`], except a graph no rule touches returns `None` **before**
/// any kernel assembly — the hot path for irreducible inputs (most
/// meshes) skips the kernel copy, relabeling, and per-row sorts
/// entirely, and callers keep their original (possibly borrowed) graph.
pub fn try_reduce(g: &SymGraph, cfg: &ReduceConfig) -> Option<ReductionPlan> {
    let n = g.n;
    let mut alive = vec![true; n];
    let mut deg: Vec<usize> = (0..n).map(|v| g.degree(v)).collect();
    let mut prefix: Vec<i32> = Vec::new();
    let mut tail_raw: Vec<(usize, usize)> = Vec::new(); // (degree at extraction, v)
    let thresh = dense_threshold(n, cfg.dense_alpha);

    // Leaves and dense rows alternate to a fixpoint: peeling never
    // creates dense rows, but extracting a dense row can expose pendants.
    // Degrees only ever decrease, so no vertex *becomes* dense after the
    // first full dense sweep — the loop runs at most twice (leaves,
    // dense, newly-exposed leaves, no-op dense, break): O(n + e) total.
    loop {
        if cfg.leaves {
            let mut queue: VecDeque<usize> =
                (0..n).filter(|&v| alive[v] && deg[v] <= 1).collect();
            while let Some(v) = queue.pop_front() {
                if !alive[v] || deg[v] > 1 {
                    continue;
                }
                alive[v] = false;
                prefix.push(v as i32);
                for &u in g.neighbors(v) {
                    let uu = u as usize;
                    if alive[uu] {
                        deg[uu] -= 1;
                        if deg[uu] <= 1 {
                            queue.push_back(uu);
                        }
                    }
                }
            }
        }
        let mut extracted = false;
        if cfg.dense {
            for v in 0..n {
                if alive[v] && deg[v] > thresh {
                    alive[v] = false;
                    tail_raw.push((deg[v], v));
                    for &u in g.neighbors(v) {
                        let uu = u as usize;
                        if alive[uu] {
                            deg[uu] -= 1;
                        }
                    }
                    extracted = true;
                }
            }
        }
        if !extracted {
            break;
        }
    }
    // Least-dense postponed row first: it re-enters the (conceptual)
    // elimination closest to where plain AMD would have picked it.
    tail_raw.sort_unstable();
    let tail: Vec<i32> = tail_raw.iter().map(|&(_, v)| v as i32).collect();
    let dense_count = tail.len();

    // Twin compression on the survivors.
    let mut rep: Vec<i32> = (0..n as i32).collect();
    let mut twins_merged = 0usize;
    if cfg.twins && n > 0 {
        let (hopen, ldeg) = fingerprints(g, &alive, cfg.threads);
        let mut in_class = vec![false; n];
        // Pass 1 — true twins: closed-neighborhood hash (`h(N(v)) + h(v)`
        // is invariant across members of an adjacent twin class).
        let mut keys: Vec<(u64, u32, u32)> = (0..n)
            .filter(|&v| alive[v])
            .map(|v| (hopen[v].wrapping_add(splitmix64(v as u64)), ldeg[v], v as u32))
            .collect();
        twins_merged += merge_twin_buckets(g, &alive, &mut keys, &mut rep, &mut in_class);
        // Pass 2 — false twins among vertices no closed class claimed:
        // open-neighborhood hash. (A vertex cannot have both a true and
        // a false twin — the definitions contradict — so skipping
        // `in_class` members loses nothing.)
        keys.clear();
        keys.extend(
            (0..n)
                .filter(|&v| alive[v] && !in_class[v])
                .map(|v| (hopen[v], ldeg[v], v as u32)),
        );
        twins_merged += merge_twin_buckets(g, &alive, &mut keys, &mut rep, &mut in_class);
    }

    if prefix.is_empty() && dense_count == 0 && twins_merged == 0 {
        return None; // nothing fired — skip kernel assembly entirely
    }

    // Kernel assembly: representatives keep their relative order, so the
    // sorted-neighbor invariant needs only a per-row sort after class
    // relabeling.
    let mut new_of_old = vec![-1i32; n];
    let mut old_of_new: Vec<i32> = Vec::new();
    for v in 0..n {
        if alive[v] && rep[v] == v as i32 {
            new_of_old[v] = old_of_new.len() as i32;
            old_of_new.push(v as i32);
        }
    }
    let kn = old_of_new.len();
    let mut weights = vec![0i32; kn];
    let mut members: Vec<i32> = Vec::with_capacity(n - prefix.len() - dense_count);
    let mut member_ptr = vec![0usize; kn + 1];
    for v in 0..n {
        if alive[v] {
            member_ptr[new_of_old[rep[v] as usize] as usize + 1] += 1;
        }
    }
    for k in 0..kn {
        member_ptr[k + 1] += member_ptr[k];
    }
    {
        let mut cursor = member_ptr.clone();
        members.resize(*member_ptr.last().unwrap(), 0);
        // Ascending v ⇒ each class lists its members ascending, and the
        // representative (the class minimum) lands first.
        for v in 0..n {
            if alive[v] {
                let k = new_of_old[rep[v] as usize] as usize;
                members[cursor[k]] = v as i32;
                cursor[k] += 1;
                weights[k] += 1;
            }
        }
    }

    let mut kernel = SymGraph {
        n: kn,
        rowptr: Vec::with_capacity(kn + 1),
        colind: Vec::new(),
    };
    kernel.rowptr.push(0);
    let mut row: Vec<i32> = Vec::new();
    for &ov in &old_of_new {
        row.clear();
        for &u in g.neighbors(ov as usize) {
            let uu = u as usize;
            if alive[uu] {
                let r = new_of_old[rep[uu] as usize];
                if r != new_of_old[ov as usize] {
                    row.push(r);
                }
            }
        }
        // Class relabeling can both reorder and duplicate (several
        // members of one neighboring class).
        row.sort_unstable();
        row.dedup();
        kernel.colind.extend_from_slice(&row);
        kernel.rowptr.push(kernel.colind.len());
    }
    debug_assert!(kernel.validate().is_ok(), "kernel lost an invariant");

    let stats = ReduceStats {
        leaves: prefix.len(),
        dense: dense_count,
        twins_merged,
        edges_removed: g.nedges() - kernel.nedges(),
    };
    debug_assert_eq!(prefix.len() + dense_count + members.len(), n);
    Some(ReductionPlan {
        n,
        prefix,
        tail,
        kernel,
        old_of_new,
        weights,
        members,
        member_ptr,
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::perm::is_valid_perm;
    use crate::matgen::{mesh2d, twin_heavy, with_dense_rows};

    fn full_cfg() -> ReduceConfig {
        ReduceConfig::default()
    }

    /// Expansion with the identity kernel permutation must always be a
    /// valid permutation of the original vertex space.
    fn check_plan(g: &SymGraph, plan: &ReductionPlan) {
        assert_eq!(plan.n, g.n);
        plan.kernel.validate().unwrap();
        assert_eq!(plan.weights.len(), plan.kernel.n);
        assert_eq!(plan.old_of_new.len(), plan.kernel.n);
        let total: i32 = plan.weights.iter().sum();
        assert_eq!(
            plan.prefix.len() + plan.tail.len() + total as usize,
            g.n,
            "every vertex is prefix, tail, or a class member"
        );
        let id: Vec<i32> = (0..plan.kernel.n as i32).collect();
        let perm = plan.expand(&id);
        assert!(is_valid_perm(&perm), "expanded identity perm invalid");
        // Representative-first, ascending members per class.
        for k in 0..plan.kernel.n {
            let m = &plan.members[plan.member_ptr[k]..plan.member_ptr[k + 1]];
            assert_eq!(m[0], plan.old_of_new[k], "representative must lead");
            for w in m.windows(2) {
                assert!(w[0] < w[1], "class members must ascend");
            }
        }
    }

    #[test]
    fn mesh_is_irreducible() {
        let g = mesh2d(12, 12);
        assert!(
            try_reduce(&g, &full_cfg()).is_none(),
            "a 12x12 mesh has no leaves/twins/dense rows — no plan to assemble"
        );
        let plan = reduce(&g, &full_cfg());
        assert!(plan.is_trivial());
        assert_eq!(plan.kernel, g, "trivial kernel is the graph itself");
        assert_eq!(plan.stats.edges_removed, 0);
        check_plan(&g, &plan);
    }

    #[test]
    fn pendant_chain_peels_completely() {
        // A pure path: stripping vertex 0 exposes 1, which exposes 2, ...
        let edges: Vec<(usize, usize)> = (0..9).map(|i| (i, i + 1)).collect();
        let g = SymGraph::from_edges(10, &edges);
        let plan = reduce(&g, &full_cfg());
        assert_eq!(plan.stats.leaves, 10, "the whole chain unravels");
        assert_eq!(plan.kernel.n, 0);
        assert!(is_valid_perm(&plan.expand(&[])));
        check_plan(&g, &plan);
    }

    #[test]
    fn isolated_vertices_land_in_the_prefix() {
        let g = SymGraph::from_edges(5, &[(1, 3)]);
        let plan = reduce(&g, &full_cfg());
        assert_eq!(plan.stats.leaves, 5, "degree-0 and the lone edge all peel");
        assert_eq!(plan.kernel.n, 0);
    }

    #[test]
    fn star_center_survives_until_its_leaves_are_gone() {
        // A star: every leaf peels, then the center is isolated and peels
        // too — the prefix must list the center last.
        let edges: Vec<(usize, usize)> = (1..8).map(|i| (0, i)).collect();
        let g = SymGraph::from_edges(8, &edges);
        let plan = reduce(&g, &ReduceConfig { dense: false, ..full_cfg() });
        assert_eq!(plan.stats.leaves, 8);
        assert_eq!(*plan.prefix.last().unwrap(), 0, "center peels last");
    }

    #[test]
    fn true_twins_merge_into_weighted_representatives() {
        // K4 blown up from an edge: {0,1} and {2,3} are adjacent twin
        // pairs... build explicitly: class A = {0,1} clique, class B =
        // {2,3} clique, complete bipartite between them.
        let g = SymGraph::from_edges(
            4,
            &[(0, 1), (2, 3), (0, 2), (0, 3), (1, 2), (1, 3)],
        );
        let plan = reduce(&g, &ReduceConfig { leaves: false, dense: false, ..full_cfg() });
        // K4: all four vertices are pairwise twins — one class of 4.
        assert_eq!(plan.kernel.n, 1);
        assert_eq!(plan.weights, vec![4]);
        assert_eq!(plan.stats.twins_merged, 3);
        check_plan(&g, &plan);
    }

    #[test]
    fn false_twins_merge_without_adjacency() {
        // 0 and 2 share N = {1, 3} but are not adjacent (a 4-cycle):
        // both diagonal pairs are false twins.
        let g = SymGraph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let plan = reduce(&g, &ReduceConfig { leaves: false, dense: false, ..full_cfg() });
        assert_eq!(plan.kernel.n, 2);
        assert_eq!(plan.weights, vec![2, 2]);
        assert_eq!(plan.old_of_new, vec![0, 1], "class minima represent");
        check_plan(&g, &plan);
    }

    #[test]
    fn twin_heavy_compresses_to_the_base_graph() {
        let g = twin_heavy(240, 6);
        let plan = reduce(&g, &ReduceConfig { dense: false, ..full_cfg() });
        assert_eq!(plan.kernel.n, 40, "each class of 6 folds to one vertex");
        assert!(plan.weights.iter().all(|&w| w == 6));
        check_plan(&g, &plan);
    }

    #[test]
    fn dense_rows_are_postponed_least_dense_first() {
        let g = with_dense_rows(400, 200, 2);
        let plan = reduce(&g, &ReduceConfig { dense_alpha: 1.0, ..full_cfg() });
        assert_eq!(plan.stats.dense, 2, "both injected rows exceed 1.0·√n");
        assert!(plan.tail.iter().all(|&v| v as usize >= 400));
        check_plan(&g, &plan);
        // Expansion puts the tail at the very end.
        let id: Vec<i32> = (0..plan.kernel.n as i32).collect();
        let perm = plan.expand(&id);
        for (i, &t) in plan.tail.iter().enumerate() {
            assert_eq!(perm[g.n - plan.tail.len() + i], t);
        }
    }

    #[test]
    fn dense_extraction_exposes_new_leaves() {
        // A hub joined to every path vertex: remove the hub (dense) and
        // the path's ends become pendant again.
        let mut edges: Vec<(usize, usize)> = (0..20).map(|i| (i, i + 1)).collect();
        for v in 0..21 {
            edges.push((21, v));
        }
        let g = SymGraph::from_edges(22, &edges);
        let plan = reduce(&g, &ReduceConfig { dense_alpha: 0.9, ..full_cfg() });
        assert_eq!(plan.stats.dense, 1, "only the hub is dense");
        assert_eq!(
            plan.stats.leaves, 21,
            "the path unravels once the hub is gone"
        );
        assert_eq!(plan.kernel.n, 0);
    }

    #[test]
    fn merge_parent_forms_the_class_forest() {
        let g = twin_heavy(30, 3);
        let plan = reduce(&g, &ReduceConfig { dense: false, ..full_cfg() });
        let parent = plan.merge_parent();
        let mut absorbed = 0;
        for v in 0..g.n {
            if parent[v] >= 0 {
                absorbed += 1;
                assert!(parent[v] < v as i32, "members point at the class minimum");
                assert_eq!(parent[parent[v] as usize], -1, "forest depth 1");
            }
        }
        assert_eq!(absorbed, plan.stats.twins_merged);
    }

    #[test]
    fn disabled_config_is_a_noop() {
        let g = twin_heavy(60, 3);
        assert!(try_reduce(&g, &ReduceConfig::disabled()).is_none());
        let plan = reduce(&g, &ReduceConfig::disabled());
        assert!(plan.is_trivial());
        assert_eq!(plan.kernel, g);
        assert_eq!(plan.reduced_away(), 0);
        check_plan(&g, &plan);
    }

    #[test]
    fn parallel_fingerprints_match_serial() {
        let g = twin_heavy(5000, 5); // above PAR_FINGERPRINT_MIN
        let alive = vec![true; g.n];
        let (h1, d1) = fingerprints(&g, &alive, 1);
        let (h4, d4) = fingerprints(&g, &alive, 4);
        assert_eq!(h1, h4, "fingerprints must not depend on thread count");
        assert_eq!(d1, d4);
    }

    #[test]
    fn reduction_is_deterministic() {
        let g = twin_heavy(300, 4);
        let a = reduce(&g, &full_cfg());
        let b = reduce(&g, &full_cfg());
        assert_eq!(a.prefix, b.prefix);
        assert_eq!(a.tail, b.tail);
        assert_eq!(a.kernel, b.kernel);
        assert_eq!(a.weights, b.weights);
        assert_eq!(a.members, b.members);
    }

    #[test]
    fn empty_graph_reduces_to_nothing() {
        let g = SymGraph::from_edges(0, &[]);
        let plan = reduce(&g, &full_cfg());
        assert!(plan.is_trivial());
        assert_eq!(plan.expand(&[]), Vec::<i32>::new());
    }
}
