//! Nested-dissection × ParAMD hybrid: parallelism *inside* one huge
//! connected graph.
//!
//! The shard engine's cross-request parallelism (PR 3) serializes its
//! common worst case — one giant connected FEM mesh lands on the single
//! wide shard and every other lane idles. The paper's own scaling story
//! (multiple elimination on independent sets, §4) points at the fix:
//! manufacture independence where the component decomposition finds
//! none. A [`plan`] cuts a connected graph with top-level multilevel
//! nested dissection ([`crate::nd`]):
//!
//! ```text
//!              connected g (n ≥ partition_threshold)
//!                     │  NestedDissection::partition
//!        ┌────────────┼───────────────┐
//!   subdomain 0  subdomain 1 …   separator blocks
//!        │            │          (deepest level first)
//!   independent ParAMD jobs           │
//!   across the shard lanes     ordered last, after all
//!   (reduce → route → order)   subdomains resolved
//!        └────────────┴───────────────┘
//!          stitch::stitch_hybrid  →  one valid permutation
//! ```
//!
//! Subdomains are pairwise independent (no edge connects two of them),
//! so their elimination orders compose freely; every separator block is
//! eliminated after everything it separates, which is exactly the nested
//! dissection partial order — the concatenation
//! `[subdomains…, separators…]` is a valid elimination ordering of the
//! whole graph, with fill accounted exactly by the downstream symbolic
//! pass.
//!
//! The planner is pure; the dispatch lives in
//! [`crate::ordering::shard::ShardEngine`] (`--hybrid` et al. on the
//! CLI), and the hybrid knobs are salted into request-level cache keys
//! by [`crate::ordering::cache::hybrid_salt`] so hybrid and non-hybrid
//! orderings of the same graph can never replay each other.

pub mod stitch;

use crate::graph::csr::SymGraph;
use crate::nd::NestedDissection;

/// Knobs of the hybrid ND×ParAMD path (the CLI's `--hybrid`,
/// `--partition-threshold`, `--recursion-depth`, `--balance-factor`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HybridConfig {
    /// Master switch; off by default.
    pub enabled: bool,
    /// Connected components below this many vertices keep the plain
    /// single-job path — partitioning them would cost more than the
    /// fan-out wins back.
    pub partition_threshold: usize,
    /// Levels of recursive bisection (depth `d` yields up to `2^d`
    /// subdomains).
    pub recursion_depth: usize,
    /// A bisection is kept only while its larger side stays within this
    /// factor of the ideal half; lopsided cuts leave the piece whole.
    pub balance_factor: f64,
}

impl HybridConfig {
    /// The default-off configuration with standard knob values.
    pub fn disabled() -> Self {
        Self {
            enabled: false,
            partition_threshold: 32_768,
            recursion_depth: 2,
            balance_factor: 1.3,
        }
    }

    /// The hybrid path switched on with default knob values.
    pub fn on() -> Self {
        Self {
            enabled: true,
            ..Self::disabled()
        }
    }

    /// Should a connected component of `n` vertices take the hybrid
    /// path?
    pub fn applies(&self, n: usize) -> bool {
        self.enabled && self.recursion_depth > 0 && n >= self.partition_threshold.max(2)
    }
}

impl Default for HybridConfig {
    fn default() -> Self {
        Self::disabled()
    }
}

/// A planned hybrid dispatch: independent subdomain jobs plus the
/// separator tail.
#[derive(Clone, Debug)]
pub struct HybridPlan {
    /// Subdomain vertex lists (original ids) — pairwise independent,
    /// each becomes its own shard job.
    pub subdomains: Vec<Vec<i32>>,
    /// Separator blocks in elimination order (deepest dissection level
    /// first, root separator last), ordered only after every subdomain
    /// resolved.
    pub separators: Vec<Vec<i32>>,
    /// Total vertices across the separator blocks (the separator
    /// fraction metric's numerator).
    pub separator_vertices: usize,
}

/// Partition a connected graph for hybrid dispatch. Returns `None` when
/// the dissection degenerates to a single subdomain (no balanced cut
/// exists at the root) — the caller then falls back to the plain
/// connected path.
pub fn plan(g: &SymGraph, cfg: &HybridConfig) -> Option<HybridPlan> {
    let cut = NestedDissection::default().partition(g, cfg.recursion_depth, cfg.balance_factor);
    if cut.subdomains.len() < 2 {
        return None;
    }
    let separator_vertices = cut.separator_vertices();
    let separators: Vec<Vec<i32>> = cut
        .separators
        .into_iter()
        .map(|b| b.verts)
        // A zero-cut bisection (the piece was internally disconnected)
        // leaves an empty block; nothing to order there.
        .filter(|v| !v.is_empty())
        .collect();
    Some(HybridPlan {
        subdomains: cut.subdomains,
        separators,
        separator_vertices,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{mesh2d, random_graph};

    #[test]
    fn applies_respects_threshold_and_switch() {
        let mut cfg = HybridConfig::on();
        cfg.partition_threshold = 1000;
        assert!(cfg.applies(1000));
        assert!(!cfg.applies(999));
        cfg.enabled = false;
        assert!(!cfg.applies(10_000));
        let mut flat = HybridConfig::on();
        flat.recursion_depth = 0;
        assert!(!flat.applies(1_000_000), "depth 0 can never split");
    }

    #[test]
    fn plan_splits_a_mesh_and_covers_it() {
        let g = mesh2d(40, 40);
        let cfg = HybridConfig {
            enabled: true,
            partition_threshold: 100,
            recursion_depth: 2,
            balance_factor: 1.5,
        };
        let p = plan(&g, &cfg).expect("a mesh splits");
        assert!(p.subdomains.len() >= 2);
        assert!(!p.separators.is_empty());
        let total: usize = p.subdomains.iter().map(|d| d.len()).sum::<usize>()
            + p.separators.iter().map(|b| b.len()).sum::<usize>();
        assert_eq!(total, g.n);
        assert_eq!(
            p.separator_vertices,
            p.separators.iter().map(|b| b.len()).sum::<usize>()
        );
    }

    #[test]
    fn plan_is_deterministic() {
        let g = random_graph(2000, 5, 7);
        let cfg = HybridConfig {
            enabled: true,
            partition_threshold: 100,
            recursion_depth: 2,
            balance_factor: 1.5,
        };
        let (a, b) = (plan(&g, &cfg), plan(&g, &cfg));
        match (a, b) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                assert_eq!(a.subdomains, b.subdomains);
                assert_eq!(a.separators, b.separators);
            }
            _ => panic!("plan must be deterministic"),
        }
    }

    #[test]
    fn impossible_balance_returns_none() {
        // balance_factor below 1.0 rejects every cut, including perfect
        // halves — the planner must degrade to None, not panic.
        let g = mesh2d(30, 30);
        let cfg = HybridConfig {
            enabled: true,
            partition_threshold: 100,
            recursion_depth: 2,
            balance_factor: 0.5,
        };
        assert!(plan(&g, &cfg).is_none());
    }
}
