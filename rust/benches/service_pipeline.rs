//! Service pipeline throughput: blocking `order()` calls vs an async
//! ticket burst through the bounded queue.
//!
//! Sync = one caller looping `order()` (submit+wait per request). Async
//! = submit every request up front, then harvest the tickets; with 2
//! scheduler threads the fill analysis of one request overlaps the
//! ordering of the next, and the arena pool is capped at 4 so the run
//! also exercises the backpressure path. Reports requests/sec for both
//! modes, the wait-vs-service latency split, and queue/eviction gauges,
//! and writes the JSON trajectory file `BENCH_service_pipeline.json`
//! (override with `PARAMD_BENCH_PIPELINE_OUT`; default lands in the
//! repository root when run via `cargo bench` from `rust/`).
//!
//! Knobs: `PARAMD_THREADS` (default 8), `PARAMD_REPS` (default 12), or
//! `--smoke` for a one-pass CI run.

#[path = "bench_common/mod.rs"]
#[allow(dead_code)] // shared helper module; this bench uses a subset
mod bench_common;

use paramd::coordinator::{Method, OrderRequest, Service, Ticket};
use paramd::graph::csr::SymGraph;
use paramd::matgen::{mesh2d, mesh3d, random_graph};
use paramd::util::timer::Timer;

fn requests(graphs: &[(&str, SymGraph)], reps: usize) -> Vec<OrderRequest> {
    let mut out = Vec::new();
    for _ in 0..reps {
        for (_, g) in graphs {
            out.push(OrderRequest {
                matrix: None,
                pattern: Some(g.clone()),
                method: Method::ParAmd {
                    threads: 4,
                    mult: 1.1,
                    lim_total: 8192,
                },
                compute_fill: true,
            });
        }
    }
    out
}

fn main() {
    bench_common::banner(
        "Service pipeline throughput — sync order() vs async ticket burst",
        "ROADMAP async-pipeline PR; not a paper table",
    );
    let smoke = std::env::args().any(|a| a == "--smoke");
    let t = bench_common::threads();
    let reps: usize = if smoke {
        1
    } else {
        std::env::var("PARAMD_REPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(12)
    };
    let graphs: Vec<(&str, SymGraph)> = vec![
        ("mesh2d_40x40", mesh2d(40, 40)),
        ("mesh3d_10", mesh3d(10, 10, 10)),
        ("random_2k5_d7", random_graph(2500, 7, 9)),
    ];
    let total = reps * graphs.len();

    // Sync mode: the submit+wait shim, one caller. The result cache is
    // off throughout: this bench measures ordering throughput, and the
    // request stream repeats its graphs (see benches/cache_hot.rs for
    // the cached numbers).
    let svc = Service::new(t).with_result_cache(0);
    let reqs = requests(&graphs, reps);
    let ts = Timer::new();
    for req in &reqs {
        let rep = svc.order(req);
        assert_eq!(rep.perm.len(), req.n());
    }
    let sync_rps = total as f64 / ts.secs();
    drop(svc);

    // Async mode: submit everything, then wait; 2 schedulers overlap
    // pre/fill with ordering, arena pool capped at 4.
    let svc = Service::new(t)
        .with_result_cache(0)
        .with_scheduler_threads(2)
        .with_arena_cap(4)
        .with_queue_cap(64);
    let reqs = requests(&graphs, reps);
    let ta = Timer::new();
    let tickets: Vec<Ticket> = reqs.into_iter().map(|r| svc.submit(r)).collect();
    for ticket in tickets {
        let rep = ticket.wait();
        assert!(!rep.perm.is_empty());
    }
    let async_rps = total as f64 / ta.secs();
    let m = svc.metrics();
    let paramd = m.get("paramd").expect("paramd requests recorded");
    let speedup = async_rps / sync_rps;

    println!("{:<10} {:>6} {:>12} {:>12}", "mode", "reqs", "req/s", "");
    println!("{:<10} {:>6} {:>12.2}", "sync", total, sync_rps);
    println!("{:<10} {:>6} {:>12.2} {:>11.2}x", "async", total, async_rps, speedup);
    println!(
        "async wait/service split: {:.4}s / {:.4}s mean; queue peak {}; evictions {}",
        paramd.mean_wait(),
        paramd.mean_service(),
        m.pipeline.queue_depth_peak,
        m.pipeline.arena_evictions
    );

    let out = std::env::var("PARAMD_BENCH_PIPELINE_OUT")
        .unwrap_or_else(|_| "../BENCH_service_pipeline.json".into());
    let json = format!(
        "{{\n  \"bench\": \"service_pipeline\",\n  \"status\": \"measured\",\n  \
         \"threads\": {t},\n  \"requests\": {total},\n  \
         \"sync_requests_per_sec\": {sync_rps:.3},\n  \
         \"async_requests_per_sec\": {async_rps:.3},\n  \
         \"async_speedup\": {speedup:.3},\n  \
         \"mean_wait_secs\": {:.6},\n  \"mean_service_secs\": {:.6},\n  \
         \"queue_depth_peak\": {},\n  \"arena_evictions\": {}\n}}\n",
        paramd.mean_wait(),
        paramd.mean_service(),
        m.pipeline.queue_depth_peak,
        m.pipeline.arena_evictions
    );
    std::fs::write(&out, &json).expect("write bench json");
    println!("\nwrote {out}");
}
