//! `paramd` — CLI for the parallel AMD ordering library.
//!
//! Subcommands:
//!   order --matrix <file.mtx | gen:NAME> [--method amd|paramd|mmd|nd]
//!         [--threads T] [--mult M] [--lim L] [--scale tiny|small|full]
//!         [--hybrid] [--partition-threshold N] [--recursion-depth D]
//!         [--balance-factor B]
//!         (`--algo` is accepted as an alias of `--method`)
//!   solve --matrix <...> [--method ...] [--pjrt] — order+factor+solve
//!   gen   --name mini_nd24k --scale small --out m.mtx
//!   suite — list the built-in matrix suite
//!   serve --requests N [--pjrt] [--pipeline] [--sched-threads S]
//!         [--arena-cap A] [--queue-cap Q] [--small-first]
//!         [--shards K] [--shard-threads T]
//!         [--no-reduce] [--dense-alpha A]
//!         [--no-rereduce] [--rereduce-every K] [--rereduce-elbow E]
//!         [--cache-mb MB] [--no-cache]
//!         [--persist-dir D] [--persist-max-mb MB] [--cache-ttl-secs S]
//!         [--cache-version V]
//!         [--hybrid] [--partition-threshold N] [--recursion-depth D]
//!         [--balance-factor B]
//!         [--max-inflight N] [--quota RATE[:BURST]] [--deadline-ms MS]
//!         [--shed-quality] [--shed-threshold Q] [--failpoints SPEC]
//!         [--metrics-every N] [--trace-dir D] [--trace-slow-ms MS]
//!         — service demo with metrics; `--pipeline` submits every
//!         request as a ticket up front (async, backpressured) instead
//!         of blocking per request; `--shards`/`--shard-threads` shard
//!         the ordering engine K ways (narrow shards T threads wide) so
//!         components and concurrent requests order in parallel;
//!         `--no-reduce` disables the pre-ordering reduction layer
//!         (twin compression / dense-row postponement / leaf stripping,
//!         on by default) and `--dense-alpha` tunes its `max(16, α·√n)`
//!         dense-row threshold; `--no-rereduce` disables the
//!         mid-elimination re-reduction sweep (global twin
//!         re-compression + dense re-postponement + aggressive element
//!         absorption on the live quotient graph at round boundaries,
//!         on by default), `--rereduce-every` sets its round cadence
//!         (default 4, 0 = off) and `--rereduce-elbow` adds a
//!         set-starvation trigger (fire when a round eliminates fewer
//!         than E×threads pivots; default 0 = off);
//!         `--cache-mb` budgets the fingerprinted
//!         ordering result cache (default 64 MiB — repeated graphs and
//!         components replay instead of re-ordering) and `--no-cache`
//!         disables it; `--persist-dir D` attaches the crash-consistent
//!         **on-disk tier** under the result cache: every insert is
//!         appended (write-behind, group-commit fsync) to `D/log.bin`
//!         and a restarted serve warms straight from `D` — recovery
//!         replays `snapshot.bin` then `log.bin`, truncates torn tail
//!         writes, and quarantines corrupt records into the counted
//!         `paramd_cache_recovery_rejects_total` family instead of
//!         replaying them. On-disk records are length-prefixed frames
//!         (`magic | payload_len | checksum | payload`, all
//!         little-endian) carrying the fingerprint + config/weights
//!         salt, a **version tag**, a creation timestamp, the
//!         exact-verify CSR and the permutation payload; files start
//!         with a `magic | format_version` header. `--persist-max-mb`
//!         bounds the on-disk footprint (compaction drops
//!         oldest-created records beyond it, default 256 MiB),
//!         `--cache-ttl-secs S` expires records older than S seconds
//!         at recovery (default 0 = keep forever), and
//!         `--cache-version V` sets the version tag — callers that
//!         reuse graph ids with changed structure bump V to invalidate
//!         every record written under the old tag;
//!         `--hybrid` turns on the nested-dissection ×
//!         ParAMD path for huge connected graphs (cut into independent
//!         subdomains that order in parallel across the shards,
//!         separators last): `--partition-threshold` is the vertex
//!         count where it engages (default 32768),
//!         `--recursion-depth` the bisection depth (default 2, up to
//!         2^D subdomains), `--balance-factor` the tolerated
//!         larger-side/ideal-half ratio (default 1.3);
//!         `--metrics-every N` prints the Prometheus metrics page after
//!         every N completed requests (0 = off), `--trace-dir D` dumps
//!         per-request flight-recorder traces as Chrome trace-event
//!         JSON files into D (loadable in Perfetto / about:tracing) and
//!         `--trace-slow-ms MS` restricts the dumps to requests at
//!         least MS milliseconds end to end (default 0 = every request)
//!
//! Overload & fault-injection flags (`serve --pipeline`):
//!   `--max-inflight N` caps admitted-but-unresolved requests; with it
//!   (or `--quota`) set, submissions go through the non-blocking
//!   admission path and excess requests are shed immediately with a
//!   structured rejection (counted in
//!   `paramd_pipeline_rejected_total`) instead of queueing. `--quota
//!   RATE[:BURST]` meters the demo caller with a token bucket of RATE
//!   sustained requests/s and BURST peak (default BURST = 2×RATE).
//!   `--deadline-ms MS` attaches a deadline MS milliseconds out to
//!   every request: work lapsing past it is abandoned at the next
//!   stage boundary and the ticket resolves to a typed
//!   deadline-exceeded error (`paramd_pipeline_deadline_exceeded_total`).
//!   `--shed-quality` trades ordering quality for availability under
//!   pressure (skip hybrid partitioning, skip re-reduction sweeps,
//!   sequential AMD for small components — `paramd_shed_*_total`);
//!   `--shed-threshold Q` sets the queue depth where shedding starts
//!   (default 1; 0 = shed every request while enabled). `--failpoints
//!   'name=action[*count],...'` arms named fault-injection points
//!   (actions: panic | reject | sleep:<ms>; the `PARAMD_FAILPOINTS`
//!   env var arms the same grammar at startup) so the chaos suite and
//!   CI can prove one poisoned request never wedges the service.

use std::time::Duration;

use paramd::cli::Args;
use paramd::coordinator::{
    HybridConfig, Method, OrderRequest, QueuePolicy, Service, SolveSpec, SubmitOptions, Ticket,
};
use paramd::graph::csr::CsrMatrix;
use paramd::graph::mm;
use paramd::matgen::{self, Scale};
use paramd::util::failpoint;

fn scale_of(s: &str) -> Scale {
    match s {
        "tiny" => Scale::Tiny,
        "full" => Scale::Full,
        _ => Scale::Small,
    }
}

/// Resolve `--matrix`: a Matrix Market path or `gen:<suite name>`.
fn load_matrix(spec: &str, scale: Scale) -> Result<CsrMatrix, String> {
    if let Some(name) = spec.strip_prefix("gen:") {
        let e = matgen::suite_entry(name)
            .ok_or_else(|| format!("unknown suite matrix {name:?}; try `paramd suite`"))?;
        let g = (e.gen)(scale);
        Ok(matgen::spd_from_graph(&g, 1.0))
    } else {
        mm::read_matrix_market(std::path::Path::new(spec)).map_err(|e| e.to_string())
    }
}

fn method_of(args: &Args) -> Result<Method, String> {
    let threads = args.get_parse("threads", 8usize);
    let mult = args.get_parse("mult", 1.1f64);
    let lim = args.get_parse("lim", 8192usize);
    let name = args
        .get("method")
        .or_else(|| args.get("algo"))
        .unwrap_or("paramd");
    Method::parse(name, threads, mult, lim)
        .ok_or_else(|| "unknown method (amd|paramd|mmd|md|nd)".into())
}

/// The hybrid ND×ParAMD config the `--hybrid` flag family selects, or
/// `None` when the switch is absent (the engine default: off).
fn hybrid_of(args: &Args) -> Option<HybridConfig> {
    if !args.has("hybrid") {
        return None;
    }
    let d = HybridConfig::on();
    Some(HybridConfig {
        enabled: true,
        partition_threshold: args.get_parse("partition-threshold", d.partition_threshold),
        recursion_depth: args.get_parse("recursion-depth", d.recursion_depth),
        balance_factor: args.get_parse("balance-factor", d.balance_factor),
    })
}

fn main() {
    if let Err(e) = failpoint::arm_from_env() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
    let args = Args::from_env(&[
        "pjrt",
        "no-fill",
        "pipeline",
        "small-first",
        "no-reduce",
        "no-rereduce",
        "no-cache",
        "hybrid",
        "shed-quality",
    ]);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "order" => cmd_order(&args),
        "solve" => cmd_solve(&args),
        "gen" => cmd_gen(&args),
        "suite" => cmd_suite(),
        "serve" => cmd_serve(&args),
        _ => {
            eprintln!(
                "usage: paramd <order|solve|gen|suite|serve> [flags]\n\
                 see `rust/src/main.rs` header for the flag list"
            );
            Ok(())
        }
    }
    .map_err(|e: String| {
        eprintln!("error: {e}");
        1
    })
    .err()
    .unwrap_or(0);
    std::process::exit(code);
}

fn cmd_order(args: &Args) -> Result<(), String> {
    let scale = scale_of(args.get_or("scale", "small"));
    let matrix = load_matrix(args.get("matrix").ok_or("--matrix required")?, scale)?;
    let method = method_of(args)?;
    let mut svc = Service::new(args.get_parse("pre-threads", 4usize));
    if let Some(h) = hybrid_of(args) {
        svc = svc.with_hybrid(h);
    }
    let req = OrderRequest {
        matrix: Some(matrix),
        pattern: None,
        method,
        compute_fill: !args.has("no-fill"),
    };
    let rep = svc.order(&req);
    println!("method      : {}", method.name());
    println!("n           : {}", rep.perm.len());
    println!("pre-process : {:.4}s", rep.pre_secs);
    println!("ordering    : {:.4}s", rep.order_secs);
    if rep.modeled_time > 0.0 {
        println!(
            "modeled-par : {:.4}s (critical-path cost model)",
            rep.modeled_time
        );
    }
    if let Some(f) = rep.fill_in {
        println!("fill-ins    : {:.3e}", f as f64);
    }
    if rep.gc_count > 0 {
        println!(
            "gc          : {} stop-the-world collections, {:.4}s",
            rep.gc_count, rep.gc_secs
        );
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<(), String> {
    let scale = scale_of(args.get_or("scale", "small"));
    let matrix = load_matrix(args.get("matrix").ok_or("--matrix required")?, scale)?;
    let method = method_of(args)?;
    let mut svc = Service::new(args.get_parse("pre-threads", 4usize));
    if args.has("pjrt") {
        svc = svc.with_pjrt_solver(args.get_or("artifacts", "artifacts").into())?;
    }
    let req = OrderRequest {
        matrix: Some(matrix),
        pattern: None,
        method,
        compute_fill: false,
    };
    let rep = svc.solve(&req, &SolveSpec::OnesSolution)?;
    println!("method      : {}", method.name());
    println!("engine      : {}", rep.engine);
    println!("ordering    : {:.4}s", rep.order_secs);
    println!(
        "factor      : {:.4}s (nnz(L) = {:.3e}, dense tail = {} cols)",
        rep.factor_secs, rep.nnz_l as f64, rep.dense_tail_cols
    );
    println!("solve       : {:.4}s", rep.solve_secs);
    println!("residual    : {:.3e}", rep.residual);
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<(), String> {
    let name = args.get("name").ok_or("--name required")?;
    let scale = scale_of(args.get_or("scale", "small"));
    let out = args.get("out").ok_or("--out required")?;
    let e = matgen::suite_entry(name).ok_or_else(|| format!("unknown matrix {name:?}"))?;
    let g = (e.gen)(scale);
    let a = matgen::spd_from_graph(&g, 1.0);
    mm::write_matrix_market(std::path::Path::new(out), &a).map_err(|e| e.to_string())?;
    println!("wrote {out}: n={} nnz={}", a.nrows, a.nnz());
    Ok(())
}

fn cmd_suite() -> Result<(), String> {
    println!("{:<14} {:<12} {}", "name", "stands for", "family");
    for e in matgen::suite() {
        println!("{:<14} {:<12} {}", e.name, e.paper_name, e.family);
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let n_req = args.get_parse("requests", 8usize);
    let shards = args.get_parse("shards", 1usize);
    if let Some(spec) = args.get("failpoints") {
        failpoint::arm_spec(spec)?;
    }
    let mut svc = Service::new(args.get_parse("pre-threads", 2usize))
        .with_shards(shards)
        .with_shard_threads(args.get_parse("shard-threads", 2usize))
        .with_scheduler_threads(args.get_parse("sched-threads", 2usize))
        .with_arena_cap(args.get_parse("arena-cap", usize::MAX))
        .with_queue_cap(args.get_parse("queue-cap", 64usize))
        .with_dense_alpha(args.get_parse("dense-alpha", 10.0f64))
        .with_rereduce_every(args.get_parse("rereduce-every", 4u32))
        .with_rereduce_elbow(args.get_parse("rereduce-elbow", 0.0f64))
        .with_result_cache(if args.has("no-cache") {
            0
        } else {
            args.get_parse("cache-mb", 64usize) << 20
        });
    if let Some(dir) = args.get("persist-dir") {
        let cfg = paramd::ordering::cache::persist::PersistConfig {
            max_bytes: (args.get_parse("persist-max-mb", 256u64)) << 20,
            ttl_secs: args.get_parse("cache-ttl-secs", 0u64),
            version: args.get_parse("cache-version", 0u64),
        };
        svc = svc
            .with_persist_config(std::path::Path::new(dir), cfg)
            .map_err(|e| e.to_string())?;
    }
    if args.has("no-reduce") {
        svc = svc.with_reduction(false);
    }
    if args.has("no-rereduce") {
        svc = svc.with_rereduce(false);
    }
    // Admission control: either knob flips --pipeline submissions onto
    // the non-blocking try_submit path (excess requests shed, never
    // queued behind the cap).
    let admission = args.get("max-inflight").is_some() || args.get("quota").is_some();
    if let Some(n) = args.get("max-inflight") {
        let n: usize = n.parse().map_err(|_| format!("bad --max-inflight '{n}'"))?;
        svc = svc.with_max_inflight(n);
    }
    if let Some(spec) = args.get("quota") {
        let (rate, burst) = match spec.split_once(':') {
            Some((r, b)) => (
                r.parse().map_err(|_| format!("bad --quota rate '{r}'"))?,
                b.parse().map_err(|_| format!("bad --quota burst '{b}'"))?,
            ),
            None => {
                let r: f64 = spec.parse().map_err(|_| format!("bad --quota '{spec}'"))?;
                (r, (r * 2.0).max(1.0))
            }
        };
        svc = svc.with_caller_quota(rate, burst);
    }
    if args.has("shed-quality") {
        svc = svc
            .with_shed_quality(true)
            .with_shed_threshold(args.get_parse("shed-threshold", 1usize));
    }
    let deadline_ms = args.get_parse("deadline-ms", 0u64);
    let submit_opts = || {
        let opts = SubmitOptions::default().with_caller("serve-demo");
        if deadline_ms > 0 {
            opts.with_deadline_in(Duration::from_millis(deadline_ms))
        } else {
            opts
        }
    };
    if let Some(h) = hybrid_of(args) {
        svc = svc.with_hybrid(h);
    }
    if args.has("small-first") {
        svc = svc.with_queue_policy(QueuePolicy::SmallestFirst);
    }
    if args.has("pjrt") {
        svc = svc.with_pjrt_solver(args.get_or("artifacts", "artifacts").into())?;
    }
    if let Some(dir) = args.get("trace-dir") {
        svc = svc.with_trace_dump(dir.into(), args.get_parse("trace-slow-ms", 0u64));
    }
    let metrics_every = args.get_parse("metrics-every", 0usize);
    let expose = |svc: &Service, completed: usize| {
        if metrics_every > 0 && completed % metrics_every == 0 {
            println!("{}", paramd::telemetry::export::prometheus(&svc.metrics()));
        }
    };
    let suite = matgen::suite();
    let build = |i: usize| {
        let e = &suite[i % suite.len()];
        let g = (e.gen)(Scale::Tiny);
        let method = if i % 2 == 0 {
            Method::ParAmd {
                threads: 4,
                mult: 1.1,
                lim_total: 8192,
            }
        } else {
            Method::Amd
        };
        let req = OrderRequest {
            matrix: Some(matgen::spd_from_graph(&g, 1.0)),
            pattern: None,
            method,
            compute_fill: true,
        };
        (e.name, method, req)
    };

    if args.has("pipeline") {
        // Async mode: enqueue everything (submit blocks only when the
        // bounded queue is full; with admission control on, excess
        // requests shed immediately instead), then harvest the tickets
        // in order — failures print as typed errors, never panic.
        let mut pending: Vec<(usize, &str, Method, Ticket)> = Vec::new();
        let mut shed = 0usize;
        for i in 0..n_req {
            let (name, method, req) = build(i);
            if admission {
                match svc.try_submit_opts(req, &submit_opts()) {
                    Ok(t) => pending.push((i, name, method, t)),
                    Err(r) => {
                        shed += 1;
                        println!("req {i:>3}: {:<12} shed: {}", name, r.error);
                    }
                }
            } else {
                pending.push((i, name, method, svc.submit_opts(req, &submit_opts())));
            }
        }
        println!(
            "submitted {} tickets, shed {shed} (queue depth now {})",
            pending.len(),
            svc.queue_depth()
        );
        for (i, name, method, ticket) in pending {
            match ticket.wait_result() {
                Ok(rep) => println!(
                    "req {i:>3}: {:<12} {:<7} n={:<7} {:.4}s fill={:.2e}",
                    name,
                    method.name(),
                    rep.perm.len(),
                    rep.total_secs,
                    rep.fill_in.unwrap_or(0) as f64
                ),
                Err(e) => println!("req {i:>3}: {:<12} {:<7} error: {e}", name, method.name()),
            }
            expose(&svc, i + 1);
        }
    } else {
        for i in 0..n_req {
            let (name, method, req) = build(i);
            let rep = svc.order(&req);
            println!(
                "req {i:>3}: {:<12} {:<7} n={:<7} {:.4}s fill={:.2e}",
                name,
                method.name(),
                rep.perm.len(),
                rep.total_secs,
                rep.fill_in.unwrap_or(0) as f64
            );
            expose(&svc, i + 1);
        }
    }
    println!("\n{}", svc.metrics().report());
    Ok(())
}
