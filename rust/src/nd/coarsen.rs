//! Heavy-edge-matching coarsening for multilevel nested dissection.
//!
//! Works on weighted graphs: vertex weights are the number of original
//! vertices collapsed into each coarse vertex; edge weights count collapsed
//! multi-edges — the quantities FM refinement balances and cuts.

use crate::util::rng::Rng;

/// A weighted graph for the multilevel hierarchy.
#[derive(Clone, Debug)]
pub struct WeightedGraph {
    pub n: usize,
    pub rowptr: Vec<usize>,
    pub colind: Vec<i32>,
    /// Edge weights, parallel to `colind`.
    pub eweight: Vec<i64>,
    /// Vertex weights.
    pub vweight: Vec<i64>,
}

impl WeightedGraph {
    pub fn from_sym(g: &crate::graph::csr::SymGraph) -> Self {
        Self {
            n: g.n,
            rowptr: g.rowptr.clone(),
            colind: g.colind.clone(),
            eweight: vec![1; g.nnz()],
            vweight: vec![1; g.n],
        }
    }

    pub fn neighbors(&self, v: usize) -> impl Iterator<Item = (i32, i64)> + '_ {
        (self.rowptr[v]..self.rowptr[v + 1]).map(move |p| (self.colind[p], self.eweight[p]))
    }

    pub fn total_vweight(&self) -> i64 {
        self.vweight.iter().sum()
    }
}

/// One coarsening level: the coarse graph plus the fine→coarse vertex map.
pub struct CoarseLevel {
    pub graph: WeightedGraph,
    pub map: Vec<i32>,
}

/// Heavy-edge matching: visit vertices in random order, match each
/// unmatched vertex with its unmatched neighbor of maximum edge weight.
/// Returns the fine→coarse map and the number of coarse vertices.
pub fn heavy_edge_matching(g: &WeightedGraph, rng: &mut Rng) -> (Vec<i32>, usize) {
    let n = g.n;
    let mut match_of = vec![-1i32; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    for &vu in &order {
        let v = vu as usize;
        if match_of[v] != -1 {
            continue;
        }
        let mut best = -1i32;
        let mut best_w = i64::MIN;
        for (u, w) in g.neighbors(v) {
            if match_of[u as usize] == -1 && u as usize != v && w > best_w {
                best = u;
                best_w = w;
            }
        }
        if best != -1 {
            match_of[v] = best;
            match_of[best as usize] = v as i32;
        } else {
            match_of[v] = v as i32; // self-matched (isolated or all matched)
        }
    }
    // Assign coarse ids: each pair gets one id.
    let mut map = vec![-1i32; n];
    let mut next = 0i32;
    for v in 0..n {
        if map[v] != -1 {
            continue;
        }
        let m = match_of[v] as usize;
        map[v] = next;
        map[m] = next;
        next += 1;
    }
    (map, next as usize)
}

/// Contract the graph along a matching map.
pub fn contract(g: &WeightedGraph, map: &[i32], coarse_n: usize) -> WeightedGraph {
    // Accumulate coarse adjacency with a dense scratch keyed by coarse id.
    let mut vweight = vec![0i64; coarse_n];
    for v in 0..g.n {
        vweight[map[v] as usize] += g.vweight[v];
    }
    let mut rowptr = vec![0usize; coarse_n + 1];
    let mut colind: Vec<i32> = Vec::with_capacity(g.colind.len() / 2 + coarse_n);
    let mut eweight: Vec<i64> = Vec::with_capacity(colind.capacity());
    // Group fine vertices by coarse id.
    let mut members_head = vec![-1i32; coarse_n];
    let mut members_next = vec![-1i32; g.n];
    for v in (0..g.n).rev() {
        let c = map[v] as usize;
        members_next[v] = members_head[c];
        members_head[c] = v as i32;
    }
    let mut seen = vec![-1i32; coarse_n]; // coarse id -> index into this row
    for c in 0..coarse_n {
        let row_start = colind.len();
        let mut m = members_head[c];
        while m != -1 {
            let v = m as usize;
            for (u, w) in g.neighbors(v) {
                let cu = map[u as usize] as usize;
                if cu == c {
                    continue; // internal edge disappears
                }
                if seen[cu] >= row_start as i32 {
                    eweight[seen[cu] as usize] += w;
                } else {
                    seen[cu] = colind.len() as i32;
                    colind.push(cu as i32);
                    eweight.push(w);
                }
            }
            m = members_next[v];
        }
        rowptr[c + 1] = colind.len();
    }
    WeightedGraph {
        n: coarse_n,
        rowptr,
        colind,
        eweight,
        vweight,
    }
}

/// Build the full coarsening hierarchy down to ~`target` vertices.
/// `levels[0]` is the coarsest. Stops early if coarsening stalls.
pub fn coarsen_hierarchy(
    g0: WeightedGraph,
    target: usize,
    rng: &mut Rng,
) -> (WeightedGraph, Vec<CoarseLevel>) {
    let mut levels: Vec<CoarseLevel> = Vec::new();
    let mut g = g0;
    while g.n > target {
        let (map, coarse_n) = heavy_edge_matching(&g, rng);
        if coarse_n as f64 > g.n as f64 * 0.95 {
            break; // stalled (e.g. star graphs)
        }
        let coarse = contract(&g, &map, coarse_n);
        levels.push(CoarseLevel {
            graph: g,
            map,
        });
        g = coarse;
    }
    (g, levels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::mesh2d;

    #[test]
    fn matching_is_valid() {
        let g = WeightedGraph::from_sym(&mesh2d(8, 8));
        let mut rng = Rng::new(1);
        let (map, cn) = heavy_edge_matching(&g, &mut rng);
        assert!(cn >= g.n / 2 && cn <= g.n);
        // Every coarse id has 1 or 2 members.
        let mut count = vec![0; cn];
        for &c in &map {
            count[c as usize] += 1;
        }
        assert!(count.iter().all(|&c| c == 1 || c == 2));
    }

    #[test]
    fn contraction_preserves_total_weight() {
        let g = WeightedGraph::from_sym(&mesh2d(10, 10));
        let total = g.total_vweight();
        let mut rng = Rng::new(2);
        let (map, cn) = heavy_edge_matching(&g, &mut rng);
        let c = contract(&g, &map, cn);
        assert_eq!(c.total_vweight(), total);
        assert_eq!(c.n, cn);
        // Symmetric adjacency with positive weights.
        for v in 0..c.n {
            for (u, w) in c.neighbors(v) {
                assert!(w > 0);
                assert!(c.neighbors(u as usize).any(|(x, _)| x as usize == v));
            }
        }
    }

    #[test]
    fn hierarchy_reaches_target() {
        let g = WeightedGraph::from_sym(&mesh2d(20, 20));
        let mut rng = Rng::new(3);
        let (coarsest, levels) = coarsen_hierarchy(g, 50, &mut rng);
        assert!(coarsest.n <= 120, "coarsest still {} vertices", coarsest.n);
        assert!(!levels.is_empty());
        assert_eq!(coarsest.total_vweight(), 400);
    }
}
