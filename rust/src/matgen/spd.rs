//! Numeric SPD matrices built on top of the structural generators — the
//! inputs to the end-to-end solver experiments (Tables 1.1 / 4.3).

use crate::graph::csr::{CsrMatrix, SymGraph};

/// Turn a symmetric pattern into a numerically SPD matrix: graph Laplacian
/// plus `shift` on the diagonal (strictly diagonally dominant → SPD).
pub fn spd_from_graph(g: &SymGraph, shift: f64) -> CsrMatrix {
    assert!(shift > 0.0, "need a positive shift for positive definiteness");
    let mut trip: Vec<(usize, usize, f64)> = Vec::with_capacity(g.nnz() + g.n);
    for v in 0..g.n {
        trip.push((v, v, g.degree(v) as f64 + shift));
        for &u in g.neighbors(v) {
            trip.push((v, u as usize, -1.0));
        }
    }
    CsrMatrix::from_triplets(g.n, g.n, &trip)
}

/// Standard 5-point Laplacian of an `nx × ny` grid, as an SPD matrix.
pub fn laplacian_matrix(nx: usize, ny: usize) -> CsrMatrix {
    spd_from_graph(&crate::matgen::mesh2d(nx, ny), 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::mesh2d;

    #[test]
    fn spd_is_diagonally_dominant() {
        let g = mesh2d(6, 6);
        let a = spd_from_graph(&g, 0.5);
        assert!(a.is_pattern_symmetric());
        for r in 0..a.nrows {
            let mut diag = 0.0;
            let mut off = 0.0;
            for (c, v) in a.row(r).iter().zip(a.row_values(r)) {
                if *c as usize == r {
                    diag = *v;
                } else {
                    off += v.abs();
                }
            }
            assert!(diag > off, "row {r} not strictly dominant");
        }
    }

    #[test]
    fn laplacian_size() {
        let a = laplacian_matrix(4, 5);
        assert_eq!(a.nrows, 20);
        assert_eq!(a.nnz(), 20 + 2 * (3 * 5 + 4 * 4)); // diag + 2*edges
    }
}
