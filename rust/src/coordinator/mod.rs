//! The Layer-3 coordinator: an asynchronous ordering/solve *service*.
//!
//! The paper's AMD use case is a pipeline stage inside a sparse direct
//! solver; this module packages the library as one deployable component
//! built around a **ticket-based request pipeline**:
//!
//! ```text
//!  submit(req) ──► bounded queue ──► scheduler thread(s) ──► Ticket
//!      │            (backpressure)     │            │
//!      ▼                               ▼            ▼
//!   Ticket          pre-process on   ordering on the sharded
//!  wait()/try_get()  `pre_threads`   ShardEngine (N runtimes)
//! ```
//!
//! ## Request lifecycle
//!
//! [`Service::submit`] enqueues an [`OrderRequest`] onto a **bounded
//! MPMC queue** and returns a [`Ticket`] immediately
//! ([`Service::submit_all`] enqueues a whole batch through one queue
//! reservation). Scheduler threads drain the queue: each request is
//! symmetrized (pre-processing, §4.2), ordered, optionally
//! fill-counted, and the reply is delivered through the ticket —
//! [`Ticket::wait`] blocks for it, [`Ticket::wait_deadline`] bounds the
//! wait and cancels on expiry, [`Ticket::try_get`] polls. The old
//! synchronous [`Service::order`] is now a thin submit+wait shim, so
//! its replies are produced by exactly the same path (and bit-match
//! ticketed replies for deterministic methods).
//!
//! ## Backpressure
//!
//! Memory is bounded and the bound surfaces as *waiting*, never as
//! unbounded growth. The request queue has a capacity
//! ([`Service::with_queue_cap`]) — when it is full, `submit` blocks —
//! and each shard processes its jobs serially, so a slow ordering
//! stalls its shard queue, batches resolve late, schedulers stay busy,
//! the request queue fills, and the stall propagates back to
//! submitters. Each shard's arena pool is bounded too
//! ([`Service::with_arena_cap`]): its single dispatcher checks out at
//! most one arena at a time, so the cap governs *retained* warm
//! storage, with idle arenas over capacity evicted LRU-by-slab-size
//! (see [`ArenaPool`](crate::ordering::paramd::arena::ArenaPool)).
//!
//! ## Cancellation
//!
//! **Dropping a [`Ticket`] cancels its request.** A still-queued job is
//! skipped outright; a running ParAMD job observes the flag at its next
//! round boundary and aborts, releasing the worker pool and arena to
//! live requests (`ParAmd::order_into_cancellable`).
//!
//! ## Sharded warm ordering path
//!
//! The service owns a **[`ShardEngine`]** — N independent
//! [`OrderingRuntime`](crate::ordering::paramd::runtime::OrderingRuntime)s
//! (size-classed: one *wide* shard plus narrow ones, see
//! [`Service::with_shards`] / [`Service::with_shard_threads`]), each
//! with its own bounded arena pool and dispatcher. A ParAMD request is
//! decomposed into connected components; each component is routed to a
//! shard as its own cancellable job and the per-component permutations
//! are stitched back (ascending-size order) into one reply. Connected
//! graphs skip extraction and land on the least-loaded shard, so
//! **concurrent requests and components of one request run truly in
//! parallel** instead of serializing behind a single runtime. Every job
//! runs warm: persistent workers, pooled arenas, no O(n)/O(nnz)
//! steady-state allocations. A request's `Method::ParAmd.threads` knob
//! is superseded by the shard widths.
//!
//! Before routing, every ParAMD job passes through the **pre-ordering
//! reduction layer** ([`crate::ordering::reduce`], on by default):
//! pendant chains peel into the permutation prefix, dense rows are
//! postponed to the tail, and indistinguishable vertices merge into
//! seed supervariables, so the shards order a smaller weighted kernel
//! and the router places jobs by their *post-reduction* size. Tune with
//! [`Service::with_reduction`] / [`Service::with_dense_alpha`] (CLI:
//! `--no-reduce`, `--dense-alpha`); per-rule counters land in the
//! [`ShardMetrics`] snapshot.
//!
//! Batched callers pair [`Service::submit_all`] with
//! [`Service::wait_all`], which harvests replies in completion order
//! through a single batch condvar instead of one wakeup per ticket.
//!
//! ## Result cache
//!
//! The engine carries a fingerprinted **result cache**
//! ([`crate::ordering::cache`], on by default with a 64 MiB budget):
//! repeated connected requests and repeated components replay their
//! permutation without touching a runtime or arena at all — the
//! batched-FEM-assembly traffic pattern where identical components
//! recur across requests under scattered vertex labels. Budget it with
//! [`Service::with_result_cache`] (CLI: `--cache-mb`, `--no-cache`;
//! `0` disables); hits, misses, verify-rejects, residency, and
//! estimated seconds saved land in the [`CacheMetrics`] section of
//! [`Service::metrics`]. The cache survives engine rebuilds
//! (`with_shards` et al.) — warm entries keep serving the new shape.
//!
//! Metrics ([`Service::metrics`]) split each request's latency into
//! queue **wait** vs **service** time and expose queue depth (current +
//! peak), cancellations, arena evictions, and the shard snapshot
//! ([`ShardMetrics`]): per-shard jobs/busy time, the component-size
//! histogram, and the shard-concurrency peak. Latency series are
//! log-bucketed histograms, so the snapshot's footprint is constant in
//! the request count; [`crate::telemetry::export`] renders it as
//! Prometheus text or JSON.
//!
//! ## Flight recorder
//!
//! Every ticket carries a [`RequestTrace`](crate::telemetry::RequestTrace):
//! per-request spans (queued → preprocess → order → fill on the pipeline
//! lane, plus the shard engine's cc-split/reduce/cache-probe/route/stitch
//! phases and per-shard dispatch/elimination lanes) retrievable via
//! [`Ticket::trace`] and renderable as Chrome trace-event JSON. Point the
//! service at a dump directory with [`Service::with_trace_dump`] and every
//! request slower than the threshold auto-dumps its trace (the serve
//! CLI's `--trace-dir` / `--trace-slow-ms`).
//!
//! ## Admission control & overload
//!
//! Blocking `submit` applies backpressure; **[`Service::try_submit`]**
//! applies *admission control*: it never blocks, and sheds with a
//! structured [`OrderError::Rejected`] — carrying a retry-after hint,
//! and handing the request back ([`Rejection`]) so a retry costs no
//! clone — whenever the global in-flight budget
//! ([`Service::with_max_inflight`]), the queue bound, or the caller's
//! token quota ([`Service::with_caller_quota`]) is exhausted. Every
//! submission carries a priority [`Lane`] ([`SubmitOptions`]):
//! interactive traffic overtakes batch work in the pipeline queue *and*
//! in every shard's job queue — priority reorders service, it never
//! grows a buffer.
//!
//! ## Deadline propagation
//!
//! A [`SubmitOptions::with_deadline_in`] budget rides the request. A
//! reaper thread fires expiry into the job's cancel flag (the same flag
//! ParAMD already polls between elimination rounds), and every stage
//! boundary — queue pickup, preprocess, order, fill, plus the engine's
//! reduce/cache-probe/route seams — re-checks the deadline, so doomed
//! work is abandoned at the next boundary and the ticket resolves to
//! [`OrderError::DeadlineExceeded`] through [`Ticket::wait_result`]:
//! never a panic, never a wedged waiter.
//!
//! ## Graceful degradation
//!
//! With [`Service::with_shed_quality`] armed, overload — queue depth at
//! or over the [`Service::with_shed_threshold`] watermark, or arena
//! pressure — sheds *quality* before availability: hybrid partitioning
//! is skipped, mid-elimination re-reduction sweeps are skipped, and
//! small components fall back to sequential AMD instead of waiting for
//! a shard slot. Every shed is tallied in [`ShardMetrics`] and visible
//! on the request trace; shed replies are still valid orderings — they
//! may just admit more fill.
//!
//! ## Fault injection
//!
//! The failure-critical sites (pipeline scheduler, shard dispatcher,
//! arena checkout, result-cache verify, the order stage) carry named
//! [failpoints](crate::util::failpoint) — one relaxed atomic load when
//! disarmed; armable from tests, `serve --failpoints`, or
//! `PARAMD_FAILPOINTS` — which the chaos suite uses to prove that one
//! poisoned request fails alone: its arena returns to the pool, the
//! queue keeps draining, and follow-up requests produce bit-identical
//! permutations.

pub mod metrics;
pub mod pipeline;
pub mod request;

pub use metrics::{MethodMetrics, Metrics, PipelineMetrics};
pub use pipeline::{OrderError, Ticket, WaitTimeout};
pub use request::{Lane, Method, OrderReply, OrderRequest, SolveReply, SolveSpec, SubmitOptions};

pub use crate::ordering::cache::{CacheMetrics, ResultCache};
pub use crate::ordering::hybrid::HybridConfig;
pub use crate::ordering::paramd::runtime::QueuePolicy;
pub use crate::ordering::reduce::{ReduceConfig, ReduceStats};
pub use crate::ordering::shard::{RereduceSettings, ShardMetrics, ShardSpec};

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cholesky::{self, DenseTail, NativeDense};
use crate::graph::csr::SymGraph;
use crate::graph::symmetrize_parallel;
use crate::nd::NestedDissection;
use crate::ordering::cache::persist::{PersistConfig, PersistError, PersistTier};
use crate::ordering::shard::{OrderOptions, ShardEngine};
use crate::ordering::{
    amd_seq::AmdSeq, md::MinDegree, mmd::Mmd, paramd::ParAmd, Ordering as _, OrderingResult,
    RoundSample,
};
use crate::symbolic;
use crate::telemetry::{RequestTrace, LANE_PIPELINE};
use crate::util::failpoint;
use crate::util::lock_unpoisoned;
use crate::util::panic_message;
use crate::util::panic_message_for;
use crate::util::timer::Timer;

use pipeline::{
    BorrowedRequest, BoundedQueue, PipelineJob, RequestSlot, TicketInner, TryPushError, WaitBatch,
};

/// Default bound of the request queue (requests, not bytes).
const DEFAULT_QUEUE_CAP: usize = 64;

/// A shed [`Service::try_submit`]: the typed reason plus the request
/// handed back unchanged, so a caller can back off and retry without
/// ever cloning the graph.
#[derive(Debug)]
pub struct Rejection {
    /// Why admission refused — always [`OrderError::Rejected`] today,
    /// carrying the retry-after hint.
    pub error: OrderError,
    /// The request, returned to the caller.
    pub request: OrderRequest,
}

/// Per-caller token-bucket quota: `rate_per_sec` sustained, `burst`
/// peak (see [`Service::with_caller_quota`]).
#[derive(Clone, Copy, Debug)]
struct QuotaConfig {
    rate_per_sec: f64,
    burst: f64,
}

/// One caller's bucket; refilled lazily on access.
struct QuotaBucket {
    tokens: f64,
    last: Instant,
}

/// Quota configuration + per-caller buckets behind one lock.
#[derive(Default)]
struct QuotaState {
    cfg: Option<QuotaConfig>,
    buckets: HashMap<String, QuotaBucket>,
}

/// When to trade ordering quality for availability (see
/// [`Service::with_shed_quality`]).
struct ShedPolicy {
    enabled: AtomicBool,
    /// Shed once the pipeline queue is at least this deep (`0` = always
    /// shed while enabled — the forced-degraded mode tests use).
    queue_depth: AtomicUsize,
}

/// Deadline-reaper worklist: `(expiry, ticket)` pairs. Weak handles so
/// a resolved/abandoned ticket never outlives its waiters here.
#[derive(Default)]
struct ReaperState {
    entries: Vec<(Instant, Weak<TicketInner>)>,
    closed: bool,
}

/// The ordering service. Construct once, submit requests (from any number
/// of threads), wait on tickets, read metrics.
pub struct Service {
    /// Always `Some` outside of `with_order_threads`'s rebuild window
    /// (the `Option` exists because `Service: Drop` forbids moving the
    /// field out directly).
    core: Option<Arc<ServiceCore>>,
    /// Dense-tail policy handed to the solver.
    tail: DenseTail,
    /// Channel to the dedicated PJRT solver thread (None = native only).
    solver: Option<SolverHandle>,
    /// Scheduler threads to spawn (fixed at first submit).
    sched_threads: usize,
    /// Lazily-spawned scheduler threads draining the request queue.
    sched: OnceLock<Vec<JoinHandle<()>>>,
}

/// State shared between the service handle and its scheduler threads.
struct ServiceCore {
    metrics: Mutex<Metrics>,
    /// Threads used for the symmetrization pre-processing (§4.2).
    pre_threads: usize,
    /// The sharded ordering engine: N persistent runtimes (each with its
    /// own arena pool) behind a component router.
    shards: ShardEngine,
    /// The bounded request queue the pipeline drains.
    queue: BoundedQueue<PipelineJob>,
    /// Monotone request-id source: every submitted ticket's trace is
    /// tagged from it (ids start at 1; 0 marks a never-submitted trace).
    submit_seq: AtomicU64,
    /// Slow-request trace dump target (`None` = no dumps). Lives on the
    /// core so engine rebuilds preserve it and schedulers can reach it.
    trace_sink: Mutex<Option<TraceSink>>,
    /// Admitted-but-unresolved requests (queued + processing) — the
    /// gauge `try_submit`'s in-flight budget gates on. Signed so a
    /// transient decrement race can never wrap a usize.
    inflight: AtomicI64,
    /// In-flight budget enforced by `try_submit` (`0` = unlimited).
    max_inflight: AtomicUsize,
    /// Per-caller token quotas (`cfg: None` = unmetered).
    quota: Mutex<QuotaState>,
    /// Quality-shedding policy for graceful degradation.
    shed: ShedPolicy,
    /// Deadline-reaper worklist; the reaper thread sleeps on the condvar
    /// until the earliest registered expiry.
    reaper: Mutex<ReaperState>,
    reaper_cv: Condvar,
}

/// Where (and above what latency) the schedulers dump flight-recorder
/// traces; see [`Service::with_trace_dump`].
struct TraceSink {
    dir: std::path::PathBuf,
    /// Dump only requests at least this slow end to end (0 = all).
    slow_ms: u64,
}

struct SolverHandle {
    tx: Mutex<mpsc::Sender<SolveJob>>,
    _thread: std::thread::JoinHandle<()>,
}

struct SolveJob {
    a: crate::graph::csr::CsrMatrix,
    perm: Vec<i32>,
    b: Vec<f64>,
    tail: DenseTail,
    reply: mpsc::Sender<Result<SolveReply, String>>,
}

impl Service {
    /// A service with the native dense engine only. The ordering engine
    /// starts as **one wide shard** sized to `pre_threads` (see
    /// [`Self::with_order_threads`] / [`Self::with_shards`] to reshape
    /// it); one scheduler thread drains the pipeline (see
    /// [`Self::with_scheduler_threads`]).
    pub fn new(pre_threads: usize) -> Self {
        let pre_threads = pre_threads.max(1);
        Self {
            core: Some(Arc::new(ServiceCore {
                metrics: Mutex::new(Metrics::default()),
                pre_threads,
                shards: ShardEngine::new(ShardSpec::uniform(1, pre_threads)),
                queue: BoundedQueue::new(DEFAULT_QUEUE_CAP),
                submit_seq: AtomicU64::new(0),
                trace_sink: Mutex::new(None),
                inflight: AtomicI64::new(0),
                max_inflight: AtomicUsize::new(0),
                quota: Mutex::new(QuotaState::default()),
                shed: ShedPolicy {
                    enabled: AtomicBool::new(false),
                    queue_depth: AtomicUsize::new(1),
                },
                reaper: Mutex::new(ReaperState::default()),
                reaper_cv: Condvar::new(),
            })),
            tail: DenseTail::default(),
            solver: None,
            sched_threads: 1,
            sched: OnceLock::new(),
        }
    }

    fn core(&self) -> &ServiceCore {
        self.core.as_deref().expect("core present")
    }

    /// Rebuild the shard engine with a new spec. The pipeline is drained
    /// first (queue closed, schedulers joined — so every accepted
    /// request resolves) and the replaced engine's dispatchers and
    /// runtime workers are explicitly shut down and joined, not leaked.
    /// The arena cap and queue policy carry over to the new engine; a
    /// spec identical to the current one is a no-op.
    fn rebuild_engine(mut self, f: impl FnOnce(ShardSpec) -> ShardSpec) -> Self {
        let spec = f(self.core().shards.spec());
        if spec == self.core().shards.spec() {
            return self;
        }
        self.stop_schedulers();
        let core_arc = self.core.take().expect("core present");
        let mut core = match Arc::try_unwrap(core_arc) {
            Ok(core) => core,
            Err(_) => unreachable!("schedulers joined; no other owner of the core exists"),
        };
        // The result cache is shared, not rebuilt: entries cached by the
        // old engine keep serving the new shape (the cache key excludes
        // shard widths by design — see the cache module docs).
        let cache = Arc::clone(core.shards.result_cache());
        let mut old =
            std::mem::replace(&mut core.shards, ShardEngine::with_result_cache(spec, cache));
        core.shards.set_arena_cap(old.arena_cap());
        core.shards.set_policy(old.policy());
        // Rule switches and α carry over; the fingerprint parallelism
        // follows the new wide-shard width.
        core.shards.set_reduce(ReduceConfig {
            threads: spec.wide_threads,
            ..old.reduce_config()
        });
        core.shards.set_hybrid(old.hybrid_config());
        core.shards.set_rereduce(old.rereduce_config());
        old.shutdown_join();
        drop(old);
        // The old queue is closed; the pipeline restarts on a fresh one.
        // Admission state (budget, quotas, shed policy) lives on the core
        // and carries over untouched; the reaper worklist restarts empty.
        core.queue = BoundedQueue::new(core.queue.capacity());
        core.reaper = Mutex::new(ReaperState::default());
        self.core = Some(Arc::new(core));
        self.sched = OnceLock::new();
        self
    }

    /// Reshape the shard engine in one step (one rebuild instead of one
    /// per [`Self::with_shards`] / `with_*_threads` call).
    pub fn with_shard_spec(self, spec: ShardSpec) -> Self {
        self.rebuild_engine(|_| spec)
    }

    /// Resize the **wide shard** to `threads` workers (the effective
    /// ParAMD thread count for connected graphs routed there).
    pub fn with_order_threads(self, threads: usize) -> Self {
        self.rebuild_engine(|spec| ShardSpec::new(spec.shards, threads, spec.narrow_threads))
    }

    /// Shard the ordering engine `n` ways: one wide runtime (the
    /// current order-thread count) plus `n - 1` narrow ones. Components
    /// of a disconnected request and concurrent requests then order
    /// truly in parallel across the shards.
    pub fn with_shards(self, n: usize) -> Self {
        self.rebuild_engine(|spec| ShardSpec::new(n, spec.wide_threads, spec.narrow_threads))
    }

    /// Worker threads of each **narrow** shard (shard 0 stays at the
    /// [`Self::with_order_threads`] width).
    pub fn with_shard_threads(self, threads: usize) -> Self {
        self.rebuild_engine(|spec| ShardSpec::new(spec.shards, spec.wide_threads, threads))
    }

    /// Number of scheduler threads draining the pipeline. More than one
    /// overlaps pre-processing/fill of one request with the ordering of
    /// another (and keeps multiple shards fed with concurrent requests).
    /// Must be called before the first submit.
    pub fn with_scheduler_threads(mut self, n: usize) -> Self {
        assert!(
            self.sched.get().is_none(),
            "set scheduler threads before the first submit"
        );
        self.sched_threads = n.max(1);
        self
    }

    /// Bound **each shard's** arena pool to `cap` live arenas — the cap
    /// on retained warm storage per shard, with LRU-by-slab-size
    /// eviction; see the module docs. Survives later engine rebuilds.
    pub fn with_arena_cap(self, cap: usize) -> Self {
        self.core().shards.set_arena_cap(cap);
        self
    }

    /// Bound the request queue to `cap` queued requests; a full queue
    /// blocks `submit` (backpressure).
    pub fn with_queue_cap(self, cap: usize) -> Self {
        self.core().queue.set_capacity(cap);
        self
    }

    /// Pick how each shard orders its job queue (FIFO by default;
    /// `SmallestFirst` lets small graphs overtake a monster).
    pub fn with_queue_policy(self, policy: QueuePolicy) -> Self {
        self.core().shards.set_policy(policy);
        self
    }

    /// Bound the number of **admitted-but-unresolved** requests
    /// [`Self::try_submit`] will accept (the CLI's `--max-inflight`;
    /// `0` = unlimited, the default). Over the budget, `try_submit`
    /// sheds with [`OrderError::Rejected`] instead of queueing. Blocking
    /// `submit` ignores the budget — its backpressure *is* the bound.
    /// Survives engine rebuilds.
    pub fn with_max_inflight(self, n: usize) -> Self {
        self.core().max_inflight.store(n, Relaxed);
        self
    }

    /// Meter callers named via [`SubmitOptions::with_caller`] with a
    /// token bucket: `rate_per_sec` sustained requests, `burst` peak
    /// (the CLI's `--quota`). Out-of-token submissions shed with
    /// [`OrderError::Rejected`] whose hint says when the next token
    /// lands. Unnamed callers are unmetered. Survives engine rebuilds.
    pub fn with_caller_quota(self, rate_per_sec: f64, burst: f64) -> Self {
        let mut q = lock_unpoisoned(self.core().quota.lock());
        q.cfg = Some(QuotaConfig {
            rate_per_sec: rate_per_sec.max(0.0),
            burst: burst.max(1.0),
        });
        q.buckets.clear();
        drop(q);
        self
    }

    /// Switch **graceful degradation** on: under overload (queue depth
    /// at or over the [`Self::with_shed_threshold`] watermark, or arena
    /// pressure) requests shed ordering *quality* — hybrid partitioning
    /// and re-reduction sweeps are skipped, small components fall back
    /// to sequential AMD — instead of shedding availability (the CLI's
    /// `--shed-quality`). Off by default. Survives engine rebuilds.
    pub fn with_shed_quality(self, on: bool) -> Self {
        self.core().shed.enabled.store(on, Relaxed);
        self
    }

    /// Queue depth at which [`Self::with_shed_quality`] starts shedding
    /// (default 1: any backlog counts as overload; `0` sheds every
    /// request while shedding is enabled — the forced-degraded mode).
    pub fn with_shed_threshold(self, queued: usize) -> Self {
        self.core().shed.queue_depth.store(queued, Relaxed);
        self
    }

    /// Switch the pre-ordering reduction layer (twin compression,
    /// dense-row postponement, leaf stripping — **on by default**) on or
    /// off. Disabling restores the exact pre-reduction ordering path
    /// (the CLI's `--no-reduce`).
    pub fn with_reduction(self, on: bool) -> Self {
        let cur = self.core().shards.reduce_config();
        self.core().shards.set_reduce(ReduceConfig {
            leaves: on,
            dense: on,
            twins: on,
            ..cur
        });
        self
    }

    /// Set the `α` of the dense-row threshold `max(16, α·√n)` (the
    /// CLI's `--dense-alpha`; default 10.0, smaller postpones more
    /// rows). Does not re-enable a disabled reduction layer.
    pub fn with_dense_alpha(self, alpha: f64) -> Self {
        let cur = self.core().shards.reduce_config();
        self.core().shards.set_reduce(ReduceConfig {
            dense_alpha: alpha,
            ..cur
        });
        self
    }

    /// Full control over the reduction layer (rule switches, α,
    /// fingerprint threads).
    pub fn with_reduce_config(self, cfg: ReduceConfig) -> Self {
        self.core().shards.set_reduce(cfg);
        self
    }

    /// Switch the **mid-elimination re-reduction sweep** (global twin
    /// re-compression, dense re-postponement, aggressive element
    /// absorption on the live quotient graph — **on by default**) on or
    /// off (the CLI's `--no-rereduce`). Survives later engine rebuilds.
    pub fn with_rereduce(self, on: bool) -> Self {
        let cur = self.core().shards.rereduce_config();
        self.core()
            .shards
            .set_rereduce(RereduceSettings { enabled: on, ..cur });
        self
    }

    /// Fire the sweep every `every` rounds (the CLI's
    /// `--rereduce-every`; default 4, 0 disables the cadence trigger).
    /// Does not re-enable a disabled sweep.
    pub fn with_rereduce_every(self, every: u32) -> Self {
        let cur = self.core().shards.rereduce_config();
        self.core()
            .shards
            .set_rereduce(RereduceSettings { every, ..cur });
        self
    }

    /// Fire the sweep when a round eliminates fewer than
    /// `elbow × threads` pivots — the distance-2 set-size elbow (the
    /// CLI's `--rereduce-elbow`; default 0.0 = off).
    pub fn with_rereduce_elbow(self, elbow: f64) -> Self {
        let cur = self.core().shards.rereduce_config();
        self.core()
            .shards
            .set_rereduce(RereduceSettings { elbow, ..cur });
        self
    }

    /// Configure the hybrid ND×ParAMD path (**off by default**; the
    /// CLI's `--hybrid`, `--partition-threshold`, `--recursion-depth`,
    /// `--balance-factor`): connected requests at or above the threshold
    /// are cut into independent subdomains that fan out across the
    /// shards, with the vertex separators ordered last. Survives later
    /// engine rebuilds.
    pub fn with_hybrid(self, cfg: HybridConfig) -> Self {
        self.core().shards.set_hybrid(cfg);
        self
    }

    /// Budget the ordering **result cache** to `bytes` (default 64 MiB;
    /// `0` disables and clears it — the CLI's `--cache-mb` /
    /// `--no-cache`). Repeated graphs and repeated components then
    /// replay their permutation instead of re-running ParAMD; see the
    /// module docs. Shrinking evicts LRU entries immediately; the
    /// setting (and the entries) survive engine rebuilds.
    pub fn with_result_cache(self, bytes: usize) -> Self {
        self.core().shards.result_cache().set_budget(bytes);
        self
    }

    /// Attach the **crash-consistent on-disk tier** under the result
    /// cache at `dir` with default knobs ([`PersistConfig`]); see
    /// [`Self::with_persist_config`]. The CLI's `serve --persist-dir`.
    pub fn with_persist(self, dir: &std::path::Path) -> Result<Self, PersistError> {
        self.with_persist_config(dir, PersistConfig::default())
    }

    /// Attach the on-disk tier with explicit knobs: open (or create)
    /// the persist directory, replay snapshot → log into the in-memory
    /// cache (torn/corrupt records are quarantined and counted, never
    /// replayed — see [`crate::ordering::cache::persist`]), and start
    /// the write-behind flusher. Call **after**
    /// [`Self::with_result_cache`] so the warm start loads under the
    /// final budget. The tier rides on the shared cache handle, so it
    /// survives engine rebuilds (`with_shards` et al.) exactly like
    /// the in-memory entries; recovered entries are exact-verified
    /// against their stored CSR on first hit like any other entry.
    /// Only environmental failures (unusable directory) error.
    pub fn with_persist_config(
        self,
        dir: &std::path::Path,
        cfg: PersistConfig,
    ) -> Result<Self, PersistError> {
        let cache = Arc::clone(self.core().shards.result_cache());
        let (tier, recovered) = PersistTier::open(dir, cfg)?;
        for rec in recovered {
            cache.insert(rec.key, rec.graph, rec.weights, rec.value);
        }
        // Attach *after* the warm start so recovered entries are not
        // re-appended to the log they just came from.
        cache.attach_persist(tier);
        Ok(self)
    }

    /// Dump the flight-recorder trace of every request slower than
    /// `slow_ms` milliseconds (queue wait + service, end to end) as a
    /// Chrome trace-event JSON file `trace-req<id>.json` under `dir`
    /// (the CLI's `--trace-dir` / `--trace-slow-ms`; `slow_ms = 0`
    /// dumps every request). The directory is created on the first
    /// dump; I/O failures never fail the request. Survives engine
    /// rebuilds.
    pub fn with_trace_dump(self, dir: std::path::PathBuf, slow_ms: u64) -> Self {
        *lock_unpoisoned(self.core().trace_sink.lock()) = Some(TraceSink { dir, slow_ms });
        self
    }

    /// Attach the PJRT-backed solver thread. The engine is created *on*
    /// the thread (its FFI handles are not `Sync`, DESIGN.md §4) from
    /// the given artifacts directory.
    pub fn with_pjrt_solver(mut self, artifacts_dir: std::path::PathBuf) -> Result<Self, String> {
        let (tx, rx) = mpsc::channel::<SolveJob>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize, String>>();
        let thread = std::thread::spawn(move || {
            let engine = match crate::runtime::PjrtEngine::load_dir(&artifacts_dir) {
                Ok(e) => {
                    let max = e
                        .sizes(crate::runtime::ArtifactKind::Chol)
                        .last()
                        .copied()
                        .unwrap_or(0);
                    let _ = ready_tx.send(Ok(max));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            let dense = crate::runtime::PjrtDense { engine: &engine };
            while let Ok(job) = rx.recv() {
                let out = solve_with(&job.a, &job.perm, &job.b, job.tail, &dense, "pjrt");
                let _ = job.reply.send(out);
            }
        });
        let max_tile = ready_rx
            .recv()
            .map_err(|e| e.to_string())?
            .map_err(|e| format!("pjrt solver init: {e}"))?;
        // Clamp the dense-tail policy to what the artifacts can factor.
        if let DenseTail::Auto { max, min_density } = self.tail {
            self.tail = DenseTail::Auto {
                max: max.min(max_tile),
                min_density,
            };
        }
        self.solver = Some(SolverHandle {
            tx: Mutex::new(tx),
            _thread: thread,
        });
        Ok(self)
    }

    pub fn with_tail(mut self, tail: DenseTail) -> Self {
        self.tail = tail;
        self
    }

    /// Snapshot of the per-method, pipeline, shard, and cache metrics.
    pub fn metrics(&self) -> Metrics {
        let core = self.core();
        let mut m = lock_unpoisoned(core.metrics.lock()).clone();
        m.pipeline.queue_depth = core.queue.len();
        m.pipeline.arena_evictions = core.shards.arena_evictions();
        m.shards = core.shards.metrics();
        m.cache = core.shards.cache_metrics();
        m
    }

    /// Number of idle pooled arenas across all shards (observability
    /// hook).
    pub fn idle_arenas(&self) -> usize {
        self.core().shards.idle_arenas()
    }

    /// Requests currently waiting in the pipeline queue.
    pub fn queue_depth(&self) -> usize {
        self.core().queue.len()
    }

    /// Submit an ordering request to the pipeline. Returns immediately
    /// with a [`Ticket`] unless the bounded queue is full, in which case
    /// this call blocks until a scheduler drains a slot (backpressure).
    /// Drop the ticket to cancel the request.
    pub fn submit(&self, req: OrderRequest) -> Ticket {
        self.submit_slot(RequestSlot::Owned(req), &SubmitOptions::default())
    }

    /// [`Self::submit`] with explicit scheduling attributes: the
    /// priority [`Lane`], a request-carried deadline, a caller name.
    /// Still blocks on a full queue (backpressure); admission control is
    /// [`Self::try_submit_opts`]'s job.
    pub fn submit_opts(&self, req: OrderRequest, opts: &SubmitOptions) -> Ticket {
        self.submit_slot(RequestSlot::Owned(req), opts)
    }

    /// Non-blocking, admission-controlled submit: either the request is
    /// in (a [`Ticket`], exactly like [`Self::submit`]) or it is shed
    /// **immediately** with a structured [`Rejection`] — in-flight
    /// budget exhausted, queue full, or caller out of quota tokens —
    /// that hands the request back for a later retry. Never blocks,
    /// never drops a request silently.
    pub fn try_submit(&self, req: OrderRequest) -> Result<Ticket, Rejection> {
        self.try_submit_opts(req, &SubmitOptions::default())
    }

    /// [`Self::try_submit`] with explicit scheduling attributes.
    pub fn try_submit_opts(
        &self,
        req: OrderRequest,
        opts: &SubmitOptions,
    ) -> Result<Ticket, Rejection> {
        self.ensure_schedulers();
        let core = self.core();
        if let Some(hint) = core.quota_deficit(opts.caller.as_deref()) {
            lock_unpoisoned(core.metrics.lock()).note_rejected();
            return Err(Rejection {
                error: OrderError::Rejected {
                    retry_after_hint: hint,
                },
                request: req,
            });
        }
        let max = core.max_inflight.load(Relaxed);
        if max > 0 && core.inflight.fetch_add(1, Relaxed) >= max as i64 {
            core.inflight.fetch_sub(1, Relaxed);
            lock_unpoisoned(core.metrics.lock()).note_rejected();
            return Err(Rejection {
                error: OrderError::Rejected {
                    retry_after_hint: core.retry_hint(),
                },
                request: req,
            });
        }
        if max == 0 {
            core.inflight.fetch_add(1, Relaxed);
        }
        let (ticket, inner) = Ticket::new();
        self.tag_trace(inner.trace());
        let reaper_entry = opts.deadline.map(|at| (at, Arc::clone(&inner)));
        let job = PipelineJob {
            req: RequestSlot::Owned(req),
            ticket: inner,
            queued: Timer::new(),
            lane: opts.lane,
            deadline: opts.deadline,
        };
        match core.queue.try_push(job, opts.lane) {
            Ok(depth) => {
                lock_unpoisoned(core.metrics.lock()).note_submit(depth);
                if let Some((at, inner)) = reaper_entry {
                    core.register_deadline(at, &inner);
                }
                Ok(ticket)
            }
            Err(TryPushError::Full(job)) | Err(TryPushError::Closed(job)) => {
                core.inflight.fetch_sub(1, Relaxed);
                lock_unpoisoned(core.metrics.lock()).note_rejected();
                let request = match job.req {
                    RequestSlot::Owned(r) => r,
                    RequestSlot::Borrowed(_) => unreachable!("try_submit owns its request"),
                };
                Err(Rejection {
                    error: OrderError::Rejected {
                        retry_after_hint: core.retry_hint(),
                    },
                    request,
                })
            }
        }
    }

    /// Submit a batch of requests through **one queue reservation**: the
    /// bounded queue is locked once per chunk of free slots instead of
    /// once per request, and every ticket exists before the first job is
    /// visible to a scheduler. Blocks (backpressure) whenever the batch
    /// outruns the queue capacity, exactly like repeated [`Self::submit`]
    /// calls would, and returns the tickets in request order.
    pub fn submit_all(&self, reqs: Vec<OrderRequest>) -> Vec<Ticket> {
        self.ensure_schedulers();
        let mut tickets = Vec::with_capacity(reqs.len());
        let jobs: Vec<PipelineJob> = reqs
            .into_iter()
            .map(|req| {
                let (ticket, inner) = Ticket::new();
                self.tag_trace(inner.trace());
                tickets.push(ticket);
                PipelineJob {
                    req: RequestSlot::Owned(req),
                    ticket: inner,
                    queued: Timer::new(),
                    lane: Lane::Batch,
                    deadline: None,
                }
            })
            .collect();
        let n = jobs.len() as u64;
        self.core().inflight.fetch_add(n as i64, Relaxed);
        match self.core().queue.push_all(jobs, Lane::Batch) {
            Ok(depth) => lock_unpoisoned(self.core().metrics.lock()).note_submit_batch(n, depth),
            // See `submit_slot`: teardown cannot overlap a `&self` call.
            Err(_) => unreachable!("submit_all raced a service teardown"),
        }
        tickets
    }

    /// Harvest a whole batch of tickets **in completion order** through
    /// a single batch condvar: each resolving ticket pokes the shared
    /// [`WaitBatch`] queue once, so a burst of `k` replies costs `k`
    /// wakeups of one waiter instead of `k` condvars each woken for one
    /// reply (the ROADMAP `wait_all` item). Returns `(submit index,
    /// outcome)` pairs — `Err` carries the failure message where
    /// [`Ticket::wait`] would panic (cancellation, scheduler panic), so
    /// one bad request doesn't lose the rest of the batch.
    pub fn wait_all(tickets: Vec<Ticket>) -> Vec<(usize, Result<OrderReply, String>)> {
        let batch = WaitBatch::new();
        let mut out = Vec::with_capacity(tickets.len());
        let mut pending = 0usize;
        for (index, ticket) in tickets.iter().enumerate() {
            if ticket.attach_watcher(&batch, index) {
                pending += 1;
            } else {
                // Resolved before we could watch it: harvest now (these
                // lead the completion order — they really did finish
                // first).
                let outcome = ticket
                    .take_result()
                    .expect("a non-pending ticket has an outcome");
                out.push((index, outcome));
            }
        }
        while pending > 0 {
            let index = batch.wait_one();
            let outcome = tickets[index]
                .take_result()
                .expect("a batch notification implies resolution");
            out.push((index, outcome));
            pending -= 1;
        }
        out
    }

    /// Run an ordering request synchronously. This is a thin submit+wait
    /// shim over the pipeline: the request flows through the same queue
    /// and schedulers as [`Self::submit`], so replies are identical to
    /// the ticketed path. The request is borrowed, not cloned — the
    /// blocking wait keeps it alive for the scheduler.
    pub fn order(&self, req: &OrderRequest) -> OrderReply {
        // SAFETY: we block on the ticket below; the scheduler's last
        // access to the borrow strictly precedes ticket resolution.
        let slot = RequestSlot::Borrowed(unsafe { BorrowedRequest::new(req) });
        self.submit_slot(slot, &SubmitOptions::default()).wait()
    }

    /// Tag a fresh ticket's trace with the next request id (1-based).
    fn tag_trace(&self, trace: &RequestTrace) {
        trace.set_id(self.core().submit_seq.fetch_add(1, Relaxed) + 1);
    }

    fn submit_slot(&self, slot: RequestSlot, opts: &SubmitOptions) -> Ticket {
        self.ensure_schedulers();
        let (ticket, inner) = Ticket::new();
        self.tag_trace(inner.trace());
        let reaper_entry = opts.deadline.map(|at| (at, Arc::clone(&inner)));
        let job = PipelineJob {
            req: slot,
            ticket: inner,
            queued: Timer::new(),
            lane: opts.lane,
            deadline: opts.deadline,
        };
        self.core().inflight.fetch_add(1, Relaxed);
        match self.core().queue.push_lane(job, opts.lane) {
            // Poison-tolerant: once the job is enqueued, nothing on this
            // path may panic — a borrowed `order()` request must stay
            // alive until its ticket resolves.
            Ok(depth) => lock_unpoisoned(self.core().metrics.lock()).note_submit(depth),
            // The queue only closes while `&mut self` methods run, which
            // cannot overlap a `&self` submit.
            Err(_) => unreachable!("submit raced a service teardown"),
        }
        if let Some((at, inner)) = reaper_entry {
            self.core().register_deadline(at, &inner);
        }
        ticket
    }

    fn ensure_schedulers(&self) {
        let core_arc = self.core.as_ref().expect("core present");
        self.sched.get_or_init(|| {
            let mut handles: Vec<JoinHandle<()>> = (0..self.sched_threads)
                .map(|i| {
                    let core = Arc::clone(core_arc);
                    std::thread::Builder::new()
                        .name(format!("paramd-sched-{i}"))
                        .spawn(move || core.scheduler_loop())
                        .expect("spawn scheduler thread")
                })
                .collect();
            // The deadline reaper rides with the schedulers: parked on
            // its condvar until a deadline is registered, joined with
            // them at teardown.
            let core = Arc::clone(core_arc);
            handles.push(
                std::thread::Builder::new()
                    .name("paramd-reaper".into())
                    .spawn(move || core.reaper_loop())
                    .expect("spawn reaper thread"),
            );
            handles
        });
    }

    /// Close the queue and join the schedulers (and the reaper); every
    /// accepted request resolves (reply or failure) before this returns.
    fn stop_schedulers(&mut self) {
        if let Some(core) = &self.core {
            core.queue.close();
            let mut st = lock_unpoisoned(core.reaper.lock());
            st.closed = true;
            st.entries.clear();
            drop(st);
            core.reaper_cv.notify_all();
        }
        if let Some(handles) = self.sched.take() {
            for h in handles {
                let _ = h.join();
            }
        }
    }

    /// Order + factor + solve. Uses the PJRT solver thread when attached,
    /// otherwise the native dense engine inline.
    pub fn solve(&self, req: &OrderRequest, spec: &SolveSpec) -> Result<SolveReply, String> {
        let a = req
            .matrix
            .as_ref()
            .ok_or("solve requires an explicit matrix")?
            .clone();
        let ordered = self.order(req);
        // The reply's permutation is *moved* into the solve (the solver
        // thread takes ownership; the native path borrows) — no extra
        // O(n) copy on the request path.
        let OrderReply {
            perm,
            pre_secs,
            order_secs,
            total_secs,
            ..
        } = ordered;
        let b = match spec {
            SolveSpec::OnesSolution => {
                let ones = vec![1.0; a.nrows];
                let mut b = vec![0.0; a.nrows];
                a.matvec(&ones, &mut b);
                b
            }
            other => other.rhs(a.nrows),
        };
        let t = Timer::new();
        let mut out = if let Some(handle) = &self.solver {
            let (reply_tx, reply_rx) = mpsc::channel();
            lock_unpoisoned(handle.tx.lock())
                .send(SolveJob {
                    a,
                    perm,
                    b,
                    tail: self.tail,
                    reply: reply_tx,
                })
                .map_err(|e| e.to_string())?;
            reply_rx.recv().map_err(|e| e.to_string())??
        } else {
            solve_with(&a, &perm, &b, self.tail, &NativeDense, "native")?
        };
        out.order_secs = order_secs;
        out.pre_secs = pre_secs;
        out.total_secs = total_secs + t.secs();
        Ok(out)
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.stop_schedulers();
        // Field drop order then joins the shard engine's dispatchers and
        // runtime workers (via the last `Arc<ServiceCore>`) and closes
        // the solver channel.
    }
}

impl ServiceCore {
    /// Scheduler thread body: drain the queue until it closes, resolving
    /// every job's ticket (reply, cancellation, deadline expiry, or
    /// failure). The in-flight gauge drops exactly once per job, after
    /// its ticket resolved.
    fn scheduler_loop(&self) {
        while let Some(job) = self.queue.pop() {
            self.run_job(job);
            self.inflight.fetch_sub(1, Relaxed);
        }
    }

    /// An abandoned job's typed outcome: a fired (or lapsed) deadline
    /// routes to `DeadlineExceeded`, anything else was a cancellation.
    fn abandonment(job: &PipelineJob) -> OrderError {
        if job.ticket.deadline_fired() || job.deadline.is_some_and(|d| Instant::now() >= d) {
            OrderError::DeadlineExceeded
        } else {
            OrderError::Cancelled
        }
    }

    fn fail_abandoned(&self, job: &PipelineJob) {
        let err = Self::abandonment(job);
        let mut m = lock_unpoisoned(self.metrics.lock());
        match err {
            OrderError::DeadlineExceeded => m.note_deadline_exceeded(),
            _ => m.note_cancelled(),
        }
        drop(m);
        job.ticket.fail_with(err);
    }

    fn run_job(&self, job: PipelineJob) {
        let wait_secs = job.queued.secs();
        let trace = Arc::clone(job.ticket.trace());
        let lapsed = job.deadline.is_some_and(|d| Instant::now() >= d);
        if job.ticket.is_cancelled() || lapsed {
            self.fail_abandoned(&job);
            return;
        }
        // The queue dwell ends the moment a scheduler claims the
        // job; its span starts at the trace epoch (ticket creation).
        trace.record("queued", LANE_PIPELINE, 0);
        let method_name = job.req.get().method.name();
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            failpoint::hit(failpoint::SCHEDULER_PANIC);
            self.process(&job, &trace)
        }));
        match outcome {
            Ok(Some(reply)) => {
                // Record before fulfilling so a woken waiter already
                // sees its request in the metrics.
                {
                    let mut m = lock_unpoisoned(self.metrics.lock());
                    m.record_split(method_name, wait_secs, reply.total_secs, reply.fill_in);
                    m.note_completed();
                }
                // Dump before fulfilling too: when the waiter wakes,
                // its trace file (if any) is already on disk.
                self.dump_slow_trace(&trace, wait_secs + reply.total_secs);
                job.ticket.fulfill(reply);
            }
            Ok(None) => self.fail_abandoned(&job),
            Err(panic) => {
                // Name the request id in the failure so a crash in a
                // fleet of concurrent requests stays attributable.
                let why = match trace.id() {
                    0 => panic_message(&panic),
                    id => panic_message_for(id, &panic),
                };
                lock_unpoisoned(self.metrics.lock()).note_failed();
                job.ticket.fail(format!("ordering panicked: {why}"));
            }
        }
    }

    /// Dump a finished request's flight recorder as Chrome trace-event
    /// JSON when a sink is configured and the request was slow enough.
    /// Best-effort: I/O failures must never fail the request itself.
    /// Consume one quota token for `caller`. `None` = admitted (or
    /// unmetered); `Some(hint)` = out of tokens, with the time until the
    /// next token lands as the retry hint.
    fn quota_deficit(&self, caller: Option<&str>) -> Option<Duration> {
        let caller = caller?;
        let mut q = lock_unpoisoned(self.quota.lock());
        let cfg = q.cfg?;
        let now = Instant::now();
        let bucket = q.buckets.entry(caller.to_string()).or_insert(QuotaBucket {
            tokens: cfg.burst,
            last: now,
        });
        let dt = now.duration_since(bucket.last).as_secs_f64();
        bucket.last = now;
        bucket.tokens = (bucket.tokens + dt * cfg.rate_per_sec).min(cfg.burst);
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            None
        } else {
            let deficit = 1.0 - bucket.tokens;
            Some(Duration::from_secs_f64(
                (deficit / cfg.rate_per_sec.max(1e-9)).min(3600.0),
            ))
        }
    }

    /// Back-off hint for budget/queue sheds: scale with the visible
    /// backlog so callers spread their retries under deeper overload.
    fn retry_hint(&self) -> Duration {
        let backlog = self.queue.len() as u64 + self.inflight.load(Relaxed).max(0) as u64;
        Duration::from_millis(5 * (backlog + 1))
    }

    /// Hand a ticket to the deadline reaper.
    fn register_deadline(&self, at: Instant, inner: &Arc<TicketInner>) {
        let mut st = lock_unpoisoned(self.reaper.lock());
        st.entries.push((at, Arc::downgrade(inner)));
        drop(st);
        self.reaper_cv.notify_all();
    }

    /// Reaper thread body: sleep until the earliest registered deadline,
    /// then fire expiry into the ticket's cancel flag
    /// ([`TicketInner::expire_deadline`]) so queued jobs are skipped at
    /// pickup and running eliminations abort at their next round
    /// boundary. The reaper only *flags* — it never resolves a ticket
    /// itself, because a borrowed `order()` request must stay alive
    /// until the scheduler's last access, which strictly precedes
    /// resolution.
    fn reaper_loop(&self) {
        let mut st = lock_unpoisoned(self.reaper.lock());
        loop {
            if st.closed {
                return;
            }
            let now = Instant::now();
            st.entries.retain(|(at, weak)| match weak.upgrade() {
                Some(inner) if inner.is_pending() => {
                    if now >= *at {
                        inner.expire_deadline();
                        false
                    } else {
                        true
                    }
                }
                // Resolved or dropped: nothing left to reap.
                _ => false,
            });
            let next = st.entries.iter().map(|(at, _)| *at).min();
            st = match next {
                Some(at) => {
                    let wait = at.saturating_duration_since(now);
                    lock_unpoisoned(self.reaper_cv.wait_timeout(st, wait)).0
                }
                None => lock_unpoisoned(self.reaper_cv.wait(st)),
            };
        }
    }

    fn dump_slow_trace(&self, trace: &RequestTrace, latency_secs: f64) {
        let guard = lock_unpoisoned(self.trace_sink.lock());
        if let Some(sink) = guard.as_ref() {
            if latency_secs * 1e3 >= sink.slow_ms as f64 {
                let _ = std::fs::create_dir_all(&sink.dir);
                let path = sink.dir.join(format!("trace-req{}.json", trace.id()));
                let _ = std::fs::write(path, trace.to_chrome_json());
            }
        }
    }

    /// Whether this moment calls for trading ordering quality for
    /// availability: shedding armed and either the pipeline queue at its
    /// watermark or every arena in use with waiters behind them.
    fn shed_quality_now(&self) -> bool {
        self.shed.enabled.load(Relaxed)
            && (self.queue.len() >= self.shed.queue_depth.load(Relaxed)
                || self.shards.arena_pressure())
    }

    /// Process one request end to end: pre-process, order, count fill —
    /// each stage recorded as a span on the trace's pipeline lane.
    /// Returns `None` when the request's cancellation flag fired or its
    /// deadline lapsed (checked between stages and, for ParAMD, between
    /// elimination rounds).
    fn process(&self, job: &PipelineJob, trace: &Arc<RequestTrace>) -> Option<OrderReply> {
        let req = job.req.get();
        let cancel = job.ticket.cancel_flag();
        let deadline = job.deadline;
        let expired = || deadline.is_some_and(|d| Instant::now() >= d);
        let total = Timer::new();
        let tpre = Timer::new();
        let pre0 = trace.now_us();
        // Borrow an explicit pattern outright — no O(nnz) copy on the
        // steady-state path; only the symmetrize arm materializes one.
        let symmetrized;
        let g: &SymGraph = if let Some(g) = &req.pattern {
            g
        } else {
            symmetrized = symmetrize_parallel(
                req.matrix.as_ref().expect("matrix or pattern"),
                self.pre_threads,
            );
            &symmetrized
        };
        let pre_secs = tpre.secs();
        trace.record("preprocess", LANE_PIPELINE, pre0);
        if cancel.load(Relaxed) || expired() {
            return None;
        }
        failpoint::hit(failpoint::STAGE_LATENCY);

        // What a reply needs from an ordering: the owned permutation plus
        // four scalar stats and the round-sample trail. Extracting just
        // these keeps the warm ParAMD arm down to a single O(n) copy
        // (the reply's own `perm`).
        fn parts(r: OrderingResult) -> (Vec<i32>, u64, u64, f64, f64, Vec<RoundSample>) {
            (
                r.perm,
                r.stats.rounds,
                r.stats.gc_count,
                r.stats.gc_secs,
                r.stats.modeled_time,
                r.stats.round_samples,
            )
        }

        let tord = Timer::new();
        let ord0 = trace.now_us();
        let (perm, rounds, gc_count, gc_secs, modeled_time, round_samples) = match &req.method {
            Method::Amd => parts(AmdSeq::default().order(g)),
            Method::Mmd => parts(Mmd::default().order(g)),
            Method::MinDegree => parts(MinDegree.order(g)),
            // ND leaves order through pooled ParAMD arenas at the wide
            // shard's width instead of cold sequential AMD per leaf.
            Method::Nd => parts(
                NestedDissection::default()
                    .with_paramd_leaves(self.shards.wide_threads())
                    .order(g),
            ),
            Method::ParAmd {
                threads: _,
                mult,
                lim_total,
            } => {
                // Sharded warm path: the engine decomposes the graph into
                // components, routes each to a shard (persistent pool +
                // pooled arena), and stitches the permutations back. The
                // request's `threads` knob is superseded by the shard
                // widths. A busy shard holds its batch open — the stall
                // that fills the request queue (backpressure).
                let cfg = ParAmd::new(self.shards.wide_threads())
                    .with_mult(*mult)
                    .with_lim_total(*lim_total);
                let rep = self.shards.order_opts(
                    g,
                    cfg,
                    &OrderOptions {
                        cancel,
                        deadline,
                        lane: job.lane,
                        shed_quality: self.shed_quality_now(),
                        trace: Some(trace),
                    },
                )?;
                (
                    rep.perm,
                    rep.rounds,
                    rep.gc_count,
                    rep.gc_secs,
                    rep.modeled_time,
                    rep.round_samples,
                )
            }
        };
        let order_secs = tord.secs();
        trace.record("order", LANE_PIPELINE, ord0);

        if cancel.load(Relaxed) || expired() {
            return None; // don't burn fill analysis on a doomed ticket
        }
        let fill = if req.compute_fill {
            let fill0 = trace.now_us();
            let f = symbolic::fill_in(g, &perm);
            trace.record("fill", LANE_PIPELINE, fill0);
            Some(f)
        } else {
            None
        };
        Some(OrderReply {
            perm,
            fill_in: fill,
            pre_secs,
            order_secs,
            total_secs: total.secs(),
            rounds,
            gc_count,
            gc_secs,
            modeled_time,
            round_samples,
        })
    }
}

/// Shared solve path (used inline and on the solver thread).
fn solve_with(
    a: &crate::graph::csr::CsrMatrix,
    perm: &[i32],
    b: &[f64],
    tail: DenseTail,
    dense: &dyn crate::cholesky::DenseCholesky,
    engine: &'static str,
) -> Result<SolveReply, String> {
    let tfac = Timer::new();
    let f = cholesky::factor(a, perm, tail, dense)?;
    let factor_secs = tfac.secs();
    let tsol = Timer::new();
    let x = cholesky::solve(&f, b);
    let solve_secs = tsol.secs();
    let resid = cholesky::residual(a, &x, b);
    Ok(SolveReply {
        x,
        residual: resid,
        nnz_l: f.nnz_l,
        dense_tail_cols: f.perm.len() - f.split,
        factor_secs,
        solve_secs,
        engine,
        order_secs: 0.0,
        pre_secs: 0.0,
        total_secs: 0.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{mesh2d, spd_from_graph};

    fn spd_request(method: Method) -> OrderRequest {
        OrderRequest {
            matrix: Some(spd_from_graph(&mesh2d(12, 12), 1.0)),
            pattern: None,
            method,
            compute_fill: true,
        }
    }

    #[test]
    fn order_via_every_method() {
        let svc = Service::new(2);
        for m in [
            Method::Amd,
            Method::Mmd,
            Method::Nd,
            Method::ParAmd {
                threads: 2,
                mult: 1.1,
                lim_total: 8192,
            },
        ] {
            let rep = svc.order(&spd_request(m));
            assert_eq!(rep.perm.len(), 144);
            assert!(rep.fill_in.unwrap() >= 0);
        }
        assert_eq!(svc.metrics().total_requests(), 4);
    }

    #[test]
    fn repeated_paramd_requests_reuse_the_arena() {
        let svc = Service::new(2);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(mesh2d(14, 14)),
            method: Method::ParAmd {
                threads: 2,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: false,
        };
        for _ in 0..3 {
            let rep = svc.order(&req);
            assert_eq!(rep.perm.len(), 196);
        }
        assert_eq!(svc.idle_arenas(), 1, "sequential requests share one arena");
    }

    #[test]
    fn concurrent_paramd_requests_pass_contract() {
        use crate::ordering::test_support::check_ordering_contract;
        let svc = Service::new(2).with_scheduler_threads(2);
        std::thread::scope(|s| {
            for i in 0..4usize {
                let svc = &svc;
                s.spawn(move || {
                    let g = mesh2d(8 + i, 9);
                    let rep = svc.order(&OrderRequest {
                        matrix: None,
                        pattern: Some(g.clone()),
                        method: Method::ParAmd {
                            threads: 2,
                            mult: 1.1,
                            lim_total: 0,
                        },
                        compute_fill: false,
                    });
                    let r = crate::ordering::OrderingResult::new(rep.perm);
                    check_ordering_contract(&g, &r);
                });
            }
        });
        assert_eq!(svc.metrics().total_requests(), 4);
    }

    #[test]
    fn submit_returns_tickets_that_resolve() {
        let svc = Service::new(2);
        let t1 = svc.submit(spd_request(Method::Amd));
        let t2 = svc.submit(spd_request(Method::ParAmd {
            threads: 2,
            mult: 1.1,
            lim_total: 0,
        }));
        let r1 = t1.wait();
        let r2 = t2.wait();
        assert_eq!(r1.perm.len(), 144);
        assert_eq!(r2.perm.len(), 144);
        let m = svc.metrics();
        assert_eq!(m.pipeline.submitted, 2);
        assert_eq!(m.pipeline.completed, 2);
        assert!(m.pipeline.queue_depth_peak >= 1);
    }

    #[test]
    fn try_get_polls_until_ready() {
        let svc = Service::new(1);
        let ticket = svc.submit(spd_request(Method::Amd));
        let reply = loop {
            if let Some(r) = ticket.try_get() {
                break r;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        };
        assert_eq!(reply.perm.len(), 144);
    }

    #[test]
    fn with_order_threads_drains_and_restarts_the_pipeline() {
        let svc = Service::new(1);
        let before = svc.order(&spd_request(Method::Amd)); // starts schedulers
        let svc = svc.with_order_threads(3);
        let after = svc.order(&spd_request(Method::Amd));
        assert_eq!(before.perm, after.perm, "amd is deterministic");
        assert_eq!(
            svc.metrics().total_requests(),
            2,
            "metrics survive the pool rebuild"
        );
    }

    #[test]
    fn engine_rebuilds_preserve_arena_cap_and_queue_policy() {
        let svc = Service::new(1)
            .with_arena_cap(2)
            .with_queue_policy(QueuePolicy::SmallestFirst)
            .with_shards(3)
            .with_order_threads(2);
        let shards = &svc.core().shards;
        assert_eq!(shards.spec(), ShardSpec::new(3, 2, 1));
        assert_eq!(shards.arena_cap(), 2, "arena cap must survive rebuilds");
        assert_eq!(shards.policy(), QueuePolicy::SmallestFirst);
    }

    #[test]
    fn with_shard_spec_reshapes_in_one_step() {
        let svc = Service::new(1).with_shard_spec(ShardSpec::new(2, 4, 3));
        assert_eq!(svc.core().shards.spec(), ShardSpec::new(2, 4, 3));
        let rep = svc.order(&spd_request(Method::ParAmd {
            threads: 4,
            mult: 1.1,
            lim_total: 0,
        }));
        assert_eq!(rep.perm.len(), 144);
    }

    #[test]
    fn submit_all_resolves_every_ticket_in_order() {
        let svc = Service::new(1).with_queue_cap(2);
        let reqs: Vec<OrderRequest> = (0..5).map(|_| spd_request(Method::Amd)).collect();
        let tickets = svc.submit_all(reqs);
        assert_eq!(tickets.len(), 5);
        for t in tickets {
            assert_eq!(t.wait().perm.len(), 144);
        }
        let m = svc.metrics();
        assert_eq!(m.pipeline.submitted, 5);
        assert_eq!(m.pipeline.completed, 5);
    }

    #[test]
    fn wait_all_harvests_every_ticket() {
        let svc = Service::new(1).with_scheduler_threads(2);
        let reqs: Vec<OrderRequest> = (0..6).map(|_| spd_request(Method::Amd)).collect();
        let tickets = svc.submit_all(reqs);
        let results = Service::wait_all(tickets);
        assert_eq!(results.len(), 6);
        let mut seen: Vec<usize> = results.iter().map(|(i, _)| *i).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..6).collect::<Vec<_>>(), "every index exactly once");
        for (i, outcome) in results {
            let rep = outcome.unwrap_or_else(|e| panic!("ticket {i} failed: {e}"));
            assert_eq!(rep.perm.len(), 144);
        }
        let m = svc.metrics();
        assert_eq!(m.pipeline.completed, 6);
    }

    #[test]
    fn wait_all_reports_cancellations_as_errors() {
        let svc = Service::new(1);
        let tickets = svc.submit_all(vec![spd_request(Method::Amd), spd_request(Method::Amd)]);
        tickets[1].cancel();
        let results = Service::wait_all(tickets);
        assert_eq!(results.len(), 2);
        let oks = results.iter().filter(|(_, r)| r.is_ok()).count();
        // The cancelled ticket may still have raced to completion, but
        // nothing panics and both outcomes arrive.
        assert!(oks >= 1, "the live request must succeed");
    }

    #[test]
    fn reduction_is_on_by_default_and_togglable() {
        use crate::matgen::twin_heavy;
        let svc = Service::new(1);
        let g = twin_heavy(150, 5);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(g.clone()),
            method: Method::ParAmd {
                threads: 1,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: false,
        };
        let rep = svc.order(&req);
        assert!(crate::graph::perm::is_valid_perm(&rep.perm));
        let m = svc.metrics();
        assert_eq!(m.shards.reduced_jobs, 1, "reduction must be on by default");
        assert_eq!(m.shards.twins_merged, 120, "30 classes of 5 merge 120");
        assert!(m.report().contains("reduce: jobs=1"));

        let off = Service::new(1).with_reduction(false);
        let rep2 = off.order(&req);
        assert!(crate::graph::perm::is_valid_perm(&rep2.perm));
        assert_eq!(off.metrics().shards.reduced_jobs, 0);
    }

    #[test]
    fn reduce_knobs_survive_engine_rebuilds() {
        let svc = Service::new(1)
            .with_dense_alpha(3.5)
            .with_reduction(false)
            .with_shards(2);
        let cfg = svc.core().shards.reduce_config();
        assert!(!cfg.leaves && !cfg.dense && !cfg.twins, "off must survive");
        assert_eq!(cfg.dense_alpha, 3.5, "α must survive the rebuild");
        let svc = svc.with_reduction(true);
        let cfg = svc.core().shards.reduce_config();
        assert!(cfg.leaves && cfg.dense && cfg.twins);
        assert_eq!(cfg.dense_alpha, 3.5, "re-enabling keeps the tuned α");
    }

    #[test]
    fn rereduce_knobs_survive_engine_rebuilds_and_reach_the_engine() {
        let svc = Service::new(1)
            .with_rereduce_every(1)
            .with_rereduce_elbow(2.5)
            .with_rereduce(false)
            .with_shards(2);
        let cfg = svc.core().shards.rereduce_config();
        assert!(!cfg.enabled, "off must survive the reshape");
        assert_eq!(cfg.every, 1, "cadence must survive the reshape");
        assert_eq!(cfg.elbow, 2.5, "elbow must survive the reshape");
        let svc = svc.with_rereduce(true);
        assert!(svc.core().shards.rereduce_config().enabled);
        // A sweep-heavy request through the full service path surfaces
        // the tally in the service metrics report.
        let g = crate::matgen::emergent_twins(220, 3);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(g.clone()),
            method: Method::ParAmd {
                threads: 1,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: false,
        };
        let rep = svc.order(&req);
        assert!(crate::graph::perm::is_valid_perm(&rep.perm));
        let m = svc.metrics();
        assert!(m.shards.rereduce_passes > 0);
        assert!(m.shards.mid_twins_merged > 0);
        assert!(m.shards.elements_absorbed > 0);
        assert!(m.report().contains("rereduce: passes="));
    }

    #[test]
    fn hybrid_knobs_survive_engine_rebuilds_and_reach_the_engine() {
        let cfg = HybridConfig {
            enabled: true,
            partition_threshold: 2_000,
            recursion_depth: 3,
            balance_factor: 1.4,
        };
        let svc = Service::new(1).with_hybrid(cfg).with_shards(2);
        assert_eq!(
            svc.core().shards.hybrid_config(),
            cfg,
            "hybrid knobs must survive the reshape"
        );
        // A hybrid-sized connected request through the full service path
        // fans out and still yields a valid permutation.
        let g = mesh2d(50, 50);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(g.clone()),
            method: Method::ParAmd {
                threads: 1,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: false,
        };
        let rep = svc.order(&req);
        assert!(crate::graph::perm::is_valid_perm(&rep.perm));
        assert_eq!(rep.perm.len(), g.n);
        let m = svc.metrics();
        assert_eq!(m.shards.hybrid_requests, 1);
        assert!(m.shards.subdomains >= 2);
        assert!(m.report().contains("hybrid: requests=1"));
    }

    #[test]
    fn result_cache_is_on_by_default_and_serves_repeats() {
        let svc = Service::new(1);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(mesh2d(13, 13)),
            method: Method::ParAmd {
                threads: 1,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: false,
        };
        let first = svc.order(&req);
        let jobs: u64 = svc.metrics().shards.per_shard.iter().map(|s| s.jobs).sum();
        let second = svc.order(&req);
        assert_eq!(second.perm, first.perm, "hit must bit-match");
        let m = svc.metrics();
        assert_eq!(m.cache.hits, 1);
        assert!(m.cache.entries >= 1);
        assert_eq!(
            m.shards.per_shard.iter().map(|s| s.jobs).sum::<u64>(),
            jobs,
            "a hit performs zero ParAMD work"
        );
        assert!(m.report().contains("cache: hits=1"), "report gains a cache section");
    }

    #[test]
    fn with_result_cache_zero_disables_and_hides_the_section() {
        let svc = Service::new(1).with_result_cache(0);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(mesh2d(10, 10)),
            method: Method::ParAmd {
                threads: 1,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: false,
        };
        svc.order(&req);
        svc.order(&req);
        let m = svc.metrics();
        assert_eq!((m.cache.hits, m.cache.misses), (0, 0));
        assert_eq!(
            m.shards.per_shard.iter().map(|s| s.jobs).sum::<u64>(),
            2,
            "disabled cache must re-order every repeat"
        );
        assert!(!m.report().contains("cache: hits="));
    }

    #[test]
    fn cache_entries_survive_engine_rebuilds() {
        let svc = Service::new(1);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(mesh2d(12, 12)),
            method: Method::ParAmd {
                threads: 1,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: false,
        };
        let first = svc.order(&req);
        let svc = svc.with_shards(2); // rebuild: same cache handle carries over
        let second = svc.order(&req);
        assert_eq!(second.perm, first.perm);
        let m = svc.metrics();
        assert_eq!(m.cache.hits, 1, "warm entry must serve the rebuilt engine");
        assert_eq!(
            m.shards.per_shard.iter().map(|s| s.jobs).sum::<u64>(),
            0,
            "the rebuilt engine never ran a job for the repeat"
        );
    }

    #[test]
    fn sharded_service_orders_disconnected_requests() {
        use crate::matgen::multi_component;
        let svc = Service::new(2).with_shards(3).with_shard_threads(1);
        let g = multi_component(6, &[50, 80]);
        let rep = svc.order(&OrderRequest {
            matrix: None,
            pattern: Some(g.clone()),
            method: Method::ParAmd {
                threads: 2,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: false,
        });
        assert_eq!(rep.perm.len(), g.n);
        assert!(crate::graph::perm::is_valid_perm(&rep.perm));
        let m = svc.metrics();
        assert_eq!(m.shards.per_shard.len(), 3);
        assert_eq!(m.shards.decomposed, 1);
        assert_eq!(m.shards.components, 6);
        assert!(m.report().contains("shards:"), "report gains a shard section");
    }

    #[test]
    fn solve_native_end_to_end() {
        let svc = Service::new(1);
        let req = spd_request(Method::Amd);
        let rep = svc
            .solve(&req, &SolveSpec::OnesSolution)
            .expect("solve must succeed");
        assert!(rep.residual < 1e-10, "residual {:e}", rep.residual);
        // b was built from x = ones.
        for xi in &rep.x {
            assert!((xi - 1.0).abs() < 1e-8);
        }
        assert_eq!(rep.engine, "native");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn solve_pjrt_end_to_end() {
        let svc = Service::new(1).with_pjrt_solver("artifacts".into());
        let svc = match svc {
            Ok(s) => s,
            Err(e) => panic!("pjrt solver init failed: {e} (run `make artifacts`)"),
        };
        let a = crate::matgen::laplacian_matrix(10, 10);
        let req = OrderRequest {
            matrix: Some(a),
            pattern: None,
            method: Method::ParAmd {
                threads: 2,
                mult: 1.1,
                lim_total: 8192,
            },
            compute_fill: false,
        };
        let rep = svc.solve(&req, &SolveSpec::RandomRhs { seed: 3 }).unwrap();
        assert!(rep.residual < 1e-10, "residual {:e}", rep.residual);
        assert_eq!(rep.engine, "pjrt");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_solver_reports_disabled_feature() {
        let err = Service::new(1)
            .with_pjrt_solver("artifacts".into())
            .err()
            .expect("stub must refuse");
        assert!(err.contains("pjrt"), "unexpected error: {err}");
    }

    #[test]
    fn pattern_requests_skip_preprocessing() {
        let svc = Service::new(1);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(mesh2d(10, 10)),
            method: Method::Amd,
            compute_fill: false,
        };
        let rep = svc.order(&req);
        assert_eq!(rep.perm.len(), 100);
    }

    #[test]
    fn warm_request_traces_cover_the_wall_and_render_valid_json() {
        let svc = Service::new(2);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(mesh2d(16, 16)),
            method: Method::ParAmd {
                threads: 2,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: true,
        };
        svc.order(&req); // cold pass: spawns schedulers, warms the pools
        let ticket = svc.submit(req);
        let trace = ticket.trace();
        let rep = ticket.wait();
        assert_eq!(rep.perm.len(), 256);
        assert_eq!(trace.id(), 2, "submits tag monotone 1-based request ids");
        let spans = trace.spans();
        for name in ["queued", "preprocess", "order", "fill"] {
            let hit = spans.iter().any(|s| s.name == name && s.lane == LANE_PIPELINE);
            assert!(hit, "missing pipeline span {name}: {spans:?}");
        }
        let violations = trace.invariant_violations();
        assert!(violations.is_empty(), "mis-nested spans: {violations:?}");
        assert!(
            trace.coverage() >= 0.95,
            "spans must explain >=95% of the wall, got {}",
            trace.coverage()
        );
        crate::telemetry::validate_json(&trace.to_chrome_json()).expect("chrome trace JSON");
    }

    #[test]
    fn paramd_replies_carry_round_samples_that_close_the_books() {
        let svc = Service::new(1);
        let g = mesh2d(18, 18);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(g.clone()),
            method: Method::ParAmd {
                threads: 1,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: false,
        };
        let rep = svc.order(&req);
        assert!(!rep.round_samples.is_empty(), "a live run must sample rounds");
        let weight: u64 = rep.round_samples.iter().map(|s| u64::from(s.weight)).sum();
        assert_eq!(weight, g.n as u64, "round retirements must account for every column");
        let pivots: u64 = rep.round_samples.iter().map(|s| u64::from(s.pivots)).sum();
        assert!(pivots > 0 && pivots <= g.n as u64);
        // Replays and sequential methods are honest about not sampling.
        let again = svc.order(&req);
        assert!(again.round_samples.is_empty(), "cache replays record no rounds");
        let amd = svc.order(&OrderRequest {
            method: Method::Amd,
            ..req.clone()
        });
        assert!(amd.round_samples.is_empty());
    }

    #[test]
    fn slow_request_traces_dump_as_chrome_json() {
        let dir = std::env::temp_dir().join(format!("paramd-trace-dump-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let svc = Service::new(1).with_trace_dump(dir.clone(), 0);
        svc.order(&spd_request(Method::ParAmd {
            threads: 1,
            mult: 1.1,
            lim_total: 0,
        }));
        let dumped: Vec<_> = std::fs::read_dir(&dir)
            .expect("dump directory must exist")
            .map(|e| e.unwrap().path())
            .collect();
        assert_eq!(dumped.len(), 1, "slow_ms = 0 dumps every request");
        let name = dumped[0].file_name().unwrap().to_string_lossy().into_owned();
        assert!(name.starts_with("trace-req") && name.ends_with(".json"), "{name}");
        let text = std::fs::read_to_string(&dumped[0]).unwrap();
        crate::telemetry::validate_json(&text).expect("dumped trace must parse");
        assert!(text.contains("\"name\":\"order\""), "order span missing: {text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn try_submit_sheds_over_the_inflight_budget_and_recovers() {
        let svc = Service::new(1).with_max_inflight(1);
        let slow = OrderRequest {
            matrix: None,
            pattern: Some(mesh2d(60, 60)),
            method: Method::ParAmd {
                threads: 1,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: true,
        };
        let first = svc.submit(slow);
        // The budget of one is spent on the in-flight request above, so
        // a non-blocking submit sheds immediately instead of queueing.
        let rej = svc
            .try_submit(spd_request(Method::Amd))
            .expect_err("over the in-flight budget");
        match rej.error {
            OrderError::Rejected { retry_after_hint } => {
                assert!(retry_after_hint > Duration::ZERO)
            }
            ref other => panic!("expected Rejected, got {other:?}"),
        }
        assert_eq!(rej.request.n(), 144, "request handed back for retry");
        assert_eq!(first.wait_result().unwrap().perm.len(), 3600);
        // Budget freed: the handed-back request is admitted on retry
        // (polling absorbs the scheduler's post-resolution decrement).
        let mut req = rej.request;
        let ticket = loop {
            match svc.try_submit(req) {
                Ok(t) => break t,
                Err(r) => {
                    req = r.request;
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        };
        assert_eq!(ticket.wait_result().unwrap().perm.len(), 144);
        let m = svc.metrics();
        assert!(m.pipeline.rejected >= 1);
        assert_eq!(m.pipeline.completed, 2);
    }

    #[test]
    fn caller_quota_sheds_with_a_retry_hint() {
        let svc = Service::new(1).with_caller_quota(0.001, 1.0);
        let opts = SubmitOptions::default().with_caller("tenant-a");
        let t = svc
            .try_submit_opts(spd_request(Method::Amd), &opts)
            .expect("the burst token admits the first request");
        assert_eq!(t.wait_result().unwrap().perm.len(), 144);
        let rej = svc
            .try_submit_opts(spd_request(Method::Amd), &opts)
            .expect_err("tenant-a is out of tokens");
        match rej.error {
            OrderError::Rejected { retry_after_hint } => assert!(
                retry_after_hint > Duration::from_secs(60),
                "hint must reflect the 1000s refill: {retry_after_hint:?}"
            ),
            ref other => panic!("expected Rejected, got {other:?}"),
        }
        // Unnamed callers are unmetered.
        let t = svc.try_submit(spd_request(Method::Amd)).expect("unmetered");
        t.wait_result().unwrap();
        assert_eq!(svc.metrics().pipeline.rejected, 1);
    }

    #[test]
    fn lapsed_deadline_resolves_to_deadline_exceeded_at_pickup() {
        let svc = Service::new(1);
        let opts = SubmitOptions::default().with_deadline_in(Duration::ZERO);
        let t = svc.submit_opts(spd_request(Method::Amd), &opts);
        assert_eq!(t.wait_result(), Err(OrderError::DeadlineExceeded));
        let m = svc.metrics();
        assert_eq!(m.pipeline.deadline_exceeded, 1);
        assert_eq!(m.pipeline.cancelled, 0, "a deadline is not a cancellation");
    }

    #[test]
    fn reaper_aborts_a_running_request_at_its_deadline() {
        let svc = Service::new(1);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(mesh2d(160, 160)),
            method: Method::ParAmd {
                threads: 1,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: true,
        };
        let opts = SubmitOptions::default().with_deadline_in(Duration::from_millis(5));
        let t = svc.submit_opts(req, &opts);
        assert_eq!(
            t.wait_result(),
            Err(OrderError::DeadlineExceeded),
            "the reaper must abort the elimination at a round boundary"
        );
        assert_eq!(svc.metrics().pipeline.deadline_exceeded, 1);
        // The service is healthy afterwards: pools released, clean reply.
        let rep = svc.order(&spd_request(Method::Amd));
        assert_eq!(rep.perm.len(), 144);
    }

    #[test]
    fn shed_quality_skips_hybrid_and_rereduce_under_pressure() {
        let hybrid = HybridConfig {
            enabled: true,
            partition_threshold: 2_000,
            recursion_depth: 3,
            balance_factor: 1.4,
        };
        let svc = Service::new(1)
            .with_hybrid(hybrid)
            .with_shed_quality(true)
            .with_shed_threshold(0); // forced-degraded: shed every request
        let g = mesh2d(50, 50);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(g.clone()),
            method: Method::ParAmd {
                threads: 1,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: false,
        };
        let rep = svc.order(&req);
        assert!(crate::graph::perm::is_valid_perm(&rep.perm));
        assert_eq!(rep.perm.len(), g.n);
        let m = svc.metrics();
        assert_eq!(m.shards.hybrid_requests, 0, "shedding skips partitioning");
        assert!(m.shards.shed_hybrid >= 1, "the skip is tallied");
        assert!(m.shards.shed_rereduce >= 1, "sweeps are shed too");
        assert!(m.report().contains("shed:"), "report gains a shed section");
    }

    #[test]
    fn interactive_submissions_complete_like_batch_ones() {
        let svc = Service::new(1);
        let t = svc.submit_opts(spd_request(Method::Amd), &SubmitOptions::interactive());
        assert_eq!(t.wait_result().unwrap().perm.len(), 144);
        assert_eq!(svc.metrics().pipeline.completed, 1);
    }

    #[test]
    fn admission_settings_survive_engine_rebuilds() {
        let svc = Service::new(1)
            .with_max_inflight(7)
            .with_shed_quality(true)
            .with_shed_threshold(3)
            .with_shards(2);
        let core = svc.core();
        assert_eq!(core.max_inflight.load(Relaxed), 7);
        assert!(core.shed.enabled.load(Relaxed));
        assert_eq!(core.shed.queue_depth.load(Relaxed), 3);
        // The rebuilt pipeline still serves.
        let rep = svc.order(&spd_request(Method::Amd));
        assert_eq!(rep.perm.len(), 144);
    }
}
