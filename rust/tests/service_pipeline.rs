//! Async pipeline integration: shim equivalence, a capped-pool stress
//! run, cancellation via dropped tickets, and backpressure sanity.

use paramd::coordinator::{Method, OrderRequest, QueuePolicy, Service};
use paramd::graph::csr::SymGraph;
use paramd::graph::perm::is_valid_perm;
use paramd::matgen::{mesh2d, random_graph};

/// The full ordering contract every reply must satisfy (mirror of the
/// crate-internal `check_ordering_contract`, which integration tests
/// cannot reach).
fn assert_contract(n: usize, perm: &[i32]) {
    assert_eq!(perm.len(), n, "reply matched to the wrong request");
    assert!(is_valid_perm(perm), "perm is not a permutation");
}

fn paramd_req(g: SymGraph, compute_fill: bool) -> OrderRequest {
    OrderRequest {
        matrix: None,
        pattern: Some(g),
        method: Method::ParAmd {
            threads: 2,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill,
    }
}

#[test]
fn ticket_replies_bitmatch_the_sync_order_shim() {
    // A 1-worker pool makes ParAMD fully deterministic (and AMD always
    // is), so the same request through `order()` and through
    // `submit().wait()` must produce bit-identical replies.
    let svc = Service::new(1);
    for seed in 0..3u64 {
        let g = random_graph(300 + 50 * seed as usize, 5, seed);
        let req = paramd_req(g.clone(), true);
        let sync = svc.order(&req);
        let ticketed = svc.submit(req).wait();
        assert_eq!(sync.perm, ticketed.perm, "seed {seed}: perm diverged");
        assert_eq!(sync.fill_in, ticketed.fill_in, "seed {seed}: fill diverged");

        let amd = OrderRequest {
            matrix: None,
            pattern: Some(g),
            method: Method::Amd,
            compute_fill: true,
        };
        let sync = svc.order(&amd);
        let ticketed = svc.submit(amd.clone()).wait();
        assert_eq!(sync.perm, ticketed.perm);
        assert_eq!(sync.fill_in, ticketed.fill_in);
    }
}

#[test]
fn stress_16_submitters_through_a_4_arena_pool() {
    // 6 schedulers against a 4-arena cap: two schedulers are always
    // blocked in `acquire`, so the backpressure path is genuinely
    // exercised while 16 submitters with mixed graph sizes hammer the
    // queue. Every reply must satisfy the contract *for its own graph*.
    let svc = Service::new(2)
        .with_scheduler_threads(6)
        .with_arena_cap(4)
        .with_queue_cap(8)
        .with_queue_policy(QueuePolicy::SmallestFirst);
    std::thread::scope(|s| {
        for i in 0..16usize {
            let svc = &svc;
            s.spawn(move || {
                let g = if i % 2 == 0 {
                    mesh2d(6 + i, 7)
                } else {
                    random_graph(120 + 35 * i, 5, i as u64)
                };
                let ticket = svc.submit(paramd_req(g.clone(), i % 4 == 0));
                let rep = ticket.wait();
                assert_contract(g.n, &rep.perm);
            });
        }
    });
    assert!(
        svc.idle_arenas() <= 4,
        "idle arenas {} exceed the cap of 4",
        svc.idle_arenas()
    );
    let m = svc.metrics();
    assert_eq!(m.pipeline.submitted, 16);
    assert_eq!(m.pipeline.completed, 16);
    assert_eq!(m.pipeline.cancelled, 0);
    assert_eq!(m.pipeline.failed, 0);
    assert_eq!(m.total_requests(), 16);
}

#[test]
fn dropped_tickets_cancel_and_free_the_pipeline() {
    let svc = Service::new(2).with_arena_cap(2).with_queue_cap(4);
    // Queue up more work than the queue holds and abandon every ticket;
    // submit's backpressure (cap 4) must still let all 6 through as the
    // scheduler drains/skips them.
    for i in 0..6u64 {
        drop(svc.submit(paramd_req(random_graph(600, 6, i), true)));
    }
    // A live request behind the abandoned ones must still come out right.
    let g = mesh2d(13, 13);
    let rep = svc.submit(paramd_req(g.clone(), false)).wait();
    assert_contract(g.n, &rep.perm);
    let m = svc.metrics();
    assert_eq!(m.pipeline.submitted, 7);
    assert_eq!(m.pipeline.failed, 0);
    // Depending on timing a dropped ticket may have completed before the
    // drop landed; every job resolves exactly one way.
    assert_eq!(m.pipeline.completed + m.pipeline.cancelled, 7);
    assert!(svc.idle_arenas() <= 2);
}

#[test]
fn queue_backpressure_blocks_submitters_at_capacity() {
    use std::sync::atomic::{AtomicUsize, Ordering::Relaxed};
    // One scheduler, tiny queue: a flood from 4 submitters must all
    // eventually land (blocking, not erroring, when the queue is full).
    let svc = Service::new(1).with_queue_cap(2);
    let done = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for i in 0..4usize {
            let svc = &svc;
            let done = &done;
            s.spawn(move || {
                let g = mesh2d(8 + i, 8);
                let rep = svc.submit(paramd_req(g.clone(), false)).wait();
                assert_contract(g.n, &rep.perm);
                done.fetch_add(1, Relaxed);
            });
        }
    });
    assert_eq!(done.load(Relaxed), 4);
    assert_eq!(svc.metrics().pipeline.completed, 4);
}

#[test]
fn wait_and_service_latencies_are_recorded() {
    let svc = Service::new(2);
    let g = mesh2d(14, 14);
    svc.order(&paramd_req(g, false));
    let m = svc.metrics();
    let e = m.get("paramd").expect("paramd metrics recorded");
    assert_eq!(e.requests, 1);
    assert!(e.mean_service() > 0.0, "service time must be measured");
    assert!(
        (e.mean_latency() - (e.mean_wait() + e.mean_service())).abs() < 1e-12,
        "total latency must be the wait + service split"
    );
}
