//! Ablation study of ParAMD's design choices (DESIGN.md §Perf): aggressive
//! absorption on/off, the §5 adaptive-relaxation extension, and candidate
//! budget — their effect on fill quality, rounds, and modeled scaling.
//! Also positions the MD-family against RCM.

#[path = "bench_common/mod.rs"]
mod bench_common;

use paramd::bench_util::{fmt_sci, Table};
use paramd::matgen;
use paramd::ordering::{amd_seq::AmdSeq, paramd::ParAmd, rcm::Rcm, Ordering as _};
use paramd::symbolic::fill_in;

fn main() {
    let t = bench_common::threads();
    bench_common::banner("Ablation — ParAMD design choices", "DESIGN.md §Perf / paper §5");
    for name in ["mini_nd24k", "mini_nlpkkt"] {
        let e = matgen::suite_entry(name).unwrap();
        let g = (e.gen)(bench_common::scale());
        let f_seq = fill_in(&g, &AmdSeq::default().order(&g).perm) as f64;
        let f_rcm = fill_in(&g, &Rcm.order(&g).perm) as f64;
        println!("--- {name} (seq AMD fill {}; RCM fill {} = {:.1}x AMD) ---",
            fmt_sci(f_seq), fmt_sci(f_rcm), f_rcm / f_seq);
        let mut table = Table::new(&["variant", "fill ratio", "rounds", "avg |D|", "model speedup"]);
        let variants: Vec<(&str, ParAmd)> = vec![
            ("default", ParAmd::new(t)),
            ("no aggressive absorption", {
                let mut c = ParAmd::new(t);
                c.aggressive = false;
                c
            }),
            ("adaptive mult (§5 ext.)", ParAmd::new(t).with_adaptive()),
            ("mult=1.0 (no relaxation)", ParAmd::new(t).with_mult(1.0)),
            ("lim_total=paper 8192", ParAmd::new(t).with_lim_total(8192)),
        ];
        for (label, cfg) in variants {
            let (r, d) = cfg.order_detailed(&g);
            let fill = fill_in(&g, &r.perm) as f64;
            let avg = r.stats.pivots as f64 / r.stats.rounds.max(1) as f64;
            table.row(vec![
                label.into(),
                format!("{:.3}x", fill / f_seq),
                format!("{}", r.stats.rounds),
                format!("{avg:.1}"),
                format!("{:.2}x", d.model_speedup),
            ]);
        }
        table.print();
        println!();
    }
}
