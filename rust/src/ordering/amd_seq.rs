//! Sequential approximate minimum degree (Amestoy–Davis–Duff 1996) — the
//! SuiteSparse-`amd_2`-faithful baseline the paper compares against.
//!
//! The quotient graph lives in a single workspace `iw` with per-node
//! pointers (`pe`) and lengths, elbow room at the tail, and garbage
//! collection on exhaustion (§3.3.1 of the paper describes exactly this
//! storage scheme). All the classic techniques are implemented:
//!
//! - **approximate external degrees** with the two-pass `w(e)` scan
//!   (Algorithm 2.1 of the paper),
//! - **mass elimination** (a neighbor whose adjacency collapses into the
//!   pivot's element is eliminated together with the pivot),
//! - **element absorption** (all elements adjacent to the pivot are
//!   absorbed, plus *aggressive absorption* when `|L_e \ L_p| = 0`),
//! - **indistinguishable-variable detection** via hashing and exact set
//!   comparison, merging supervariables,
//! - **degree lists** for O(1) pivot selection.
//!
//! Node states are tracked explicitly (`state[]`) instead of SuiteSparse's
//! sign-flip encodings, trading a few bytes for clarity; the data-structure
//! design and per-step algorithm follow AMD96 / `amd_2.c`.

use crate::graph::csr::SymGraph;
use crate::ordering::{Ordering, OrderingResult, OrderingStats};
use crate::util::timer::Timer;

/// Node role in the quotient graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeState {
    /// Live (super)variable.
    Var,
    /// Live element (eliminated pivot whose clique is still referenced).
    Elem,
    /// Variable absorbed into a supervariable or mass-eliminated into an
    /// element; `parent[]` holds the absorber.
    DeadVar,
    /// Element absorbed into another element (or an empty root element).
    DeadElem,
}

/// Per-step instrumentation for the paper's Table 3.1: the amount of
/// intra-elimination parallelism available at each pivot.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// `|L_p|` — variables adjacent to the pivot (supervariable count).
    pub lp: u32,
    /// `Σ_{v∈L_p} |E_v|` — the work of the degree-update scan.
    pub work: u32,
    /// `|∪_{v∈L_p} E_v|` — unique elements touched (memory contention).
    pub unique_elems: u32,
}

/// Sequential AMD configuration.
#[derive(Clone, Copy, Debug)]
pub struct AmdSeq {
    /// Enable aggressive element absorption (SuiteSparse default: on).
    pub aggressive: bool,
    /// Collect per-step [`StepStats`] (Table 3.1); costs some time.
    pub collect_step_stats: bool,
    /// Elbow-room factor over nnz (SuiteSparse uses ~1.2×nnz total; the
    /// paper's parallel version pre-allocates 1.5).
    pub elbow: f64,
}

impl Default for AmdSeq {
    fn default() -> Self {
        Self {
            aggressive: true,
            collect_step_stats: false,
            elbow: 0.5,
        }
    }
}

impl Ordering for AmdSeq {
    fn name(&self) -> &'static str {
        "amd_seq"
    }

    fn order(&self, g: &SymGraph) -> OrderingResult {
        let t = Timer::new();
        let mut core = AmdCore::new(g, *self);
        core.run();
        let secs = t.secs();
        let (perm, stats) = core.finish();
        let mut r = OrderingResult::new(perm);
        r.stats = stats;
        r.phases.add("core", secs);
        r
    }
}

impl AmdSeq {
    /// Run and also return the Table 3.1 per-step statistics.
    pub fn order_with_step_stats(&self, g: &SymGraph) -> (OrderingResult, Vec<StepStats>) {
        let cfg = AmdSeq {
            collect_step_stats: true,
            ..*self
        };
        let t = Timer::new();
        let mut core = AmdCore::new(g, cfg);
        core.run();
        let secs = t.secs();
        let steps = std::mem::take(&mut core.step_stats);
        let (perm, stats) = core.finish();
        let mut r = OrderingResult::new(perm);
        r.stats = stats;
        r.phases.add("core", secs);
        (r, steps)
    }
}

/// The quotient-graph elimination engine.
pub(crate) struct AmdCore {
    cfg: AmdSeq,
    n: usize,
    /// Workspace holding all adjacency lists. A live variable `v`'s list at
    /// `pe[v] .. pe[v]+len[v]` holds `elen[v]` elements first, then
    /// variables. An element `e`'s list is `L_e` (variables only).
    iw: Vec<i32>,
    pe: Vec<usize>,
    len: Vec<i32>,
    elen: Vec<i32>,
    /// Supervariable size; 0 once dead. For elements: pivot block size.
    nv: Vec<i32>,
    /// For variables: approximate external degree (weighted). For elements:
    /// weighted `|L_e|` (possibly stale-high; refreshed during GC).
    degree: Vec<i32>,
    state: Vec<NodeState>,
    /// Absorption target for dead nodes (-1 if none).
    parent: Vec<i32>,
    /// Timestamp workspace (Algorithm 2.1's `w`); `u64` so it never wraps.
    w: Vec<u64>,
    wflg: u64,
    /// Degree lists: `dhead[d]` -> first var with degree `d`; doubly linked.
    dhead: Vec<i32>,
    dnext: Vec<i32>,
    dprev: Vec<i32>,
    mindeg: usize,
    /// First free slot in `iw`.
    pfree: usize,
    /// Number of original columns eliminated so far.
    nel: usize,
    /// Pivots in elimination order.
    elim_order: Vec<i32>,
    /// Hash buckets for supervariable detection.
    hhead: Vec<i32>,
    hnext: Vec<i32>,
    hash_of: Vec<u64>,
    pub(crate) step_stats: Vec<StepStats>,
    stats: OrderingStats,
}

impl AmdCore {
    pub fn new(g: &SymGraph, cfg: AmdSeq) -> Self {
        let n = g.n;
        let nnz = g.nnz();
        let iwlen = nnz + (nnz as f64 * cfg.elbow) as usize + n + 16;
        let mut iw = vec![0i32; iwlen];
        let mut pe = vec![0usize; n];
        let mut len = vec![0i32; n];
        for v in 0..n {
            pe[v] = g.rowptr[v];
            len[v] = g.degree(v) as i32;
        }
        iw[..nnz].copy_from_slice(&g.colind);
        let degree: Vec<i32> = (0..n).map(|v| g.degree(v) as i32).collect();

        let mut s = Self {
            cfg,
            n,
            iw,
            pe,
            len,
            elen: vec![0i32; n],
            nv: vec![1i32; n],
            degree,
            state: vec![NodeState::Var; n],
            parent: vec![-1i32; n],
            w: vec![0u64; n],
            wflg: 1,
            dhead: vec![-1i32; n + 1],
            dnext: vec![-1i32; n],
            dprev: vec![-1i32; n],
            mindeg: 0,
            pfree: nnz,
            nel: 0,
            elim_order: Vec::with_capacity(n),
            hhead: vec![-1i32; n + 1],
            hnext: vec![-1i32; n],
            hash_of: vec![0u64; n],
            step_stats: Vec::new(),
            stats: OrderingStats::default(),
        };
        for v in 0..n {
            s.deg_list_insert(v);
        }
        s
    }

    // ---- degree lists ---------------------------------------------------

    fn deg_list_insert(&mut self, v: usize) {
        let d = (self.degree[v].max(0) as usize).min(self.n);
        let h = self.dhead[d];
        self.dnext[v] = h;
        self.dprev[v] = -1;
        if h != -1 {
            self.dprev[h as usize] = v as i32;
        }
        self.dhead[d] = v as i32;
        if d < self.mindeg {
            self.mindeg = d;
        }
    }

    fn deg_list_remove(&mut self, v: usize) {
        let prev = self.dprev[v];
        let next = self.dnext[v];
        if prev != -1 {
            self.dnext[prev as usize] = next;
        } else {
            let d = (self.degree[v].max(0) as usize).min(self.n);
            debug_assert_eq!(self.dhead[d], v as i32);
            self.dhead[d] = next;
        }
        if next != -1 {
            self.dprev[next as usize] = prev;
        }
        self.dnext[v] = -1;
        self.dprev[v] = -1;
    }

    fn pop_min_degree(&mut self) -> Option<usize> {
        while self.mindeg <= self.n {
            let h = self.dhead[self.mindeg];
            if h != -1 {
                let v = h as usize;
                self.deg_list_remove(v);
                return Some(v);
            }
            self.mindeg += 1;
        }
        None
    }

    // ---- storage ----------------------------------------------------------

    /// Ensure at least `need` free slots at `pfree`, running GC and then
    /// growing if still insufficient.
    fn reserve(&mut self, need: usize) {
        if self.pfree + need <= self.iw.len() {
            return;
        }
        self.garbage_collect();
        if self.pfree + need > self.iw.len() {
            let newlen = (self.pfree + need) * 3 / 2 + 16;
            self.iw.resize(newlen, 0);
        }
    }

    /// Compact all live lists to the front of `iw`, pruning dead entries
    /// (and refreshing element weights).
    fn garbage_collect(&mut self) {
        self.stats.gc_count += 1;
        let mut order: Vec<u32> = (0..self.n as u32)
            .filter(|&i| {
                matches!(self.state[i as usize], NodeState::Var | NodeState::Elem)
                    && self.len[i as usize] > 0
            })
            .collect();
        order.sort_by_key(|&i| self.pe[i as usize]);
        let mut dst = 0usize;
        for &iu in &order {
            let i = iu as usize;
            let src = self.pe[i];
            debug_assert!(src >= dst, "live lists must not overlap");
            match self.state[i] {
                NodeState::Elem => {
                    // Prune dead variables from L_e; refresh weighted size.
                    let mut weight = 0i32;
                    let mut kept = 0usize;
                    for k in 0..self.len[i] as usize {
                        let v = self.iw[src + k];
                        if self.state[v as usize] == NodeState::Var {
                            self.iw[dst + kept] = v;
                            kept += 1;
                            weight += self.nv[v as usize];
                        }
                    }
                    self.pe[i] = dst;
                    self.len[i] = kept as i32;
                    self.degree[i] = weight;
                    dst += kept;
                }
                NodeState::Var => {
                    // Prune dead elements and dead variables; keep the
                    // [elements][variables] layout.
                    let mut kept_e = 0usize;
                    for k in 0..self.elen[i] as usize {
                        let e = self.iw[src + k];
                        if self.state[e as usize] == NodeState::Elem {
                            self.iw[dst + kept_e] = e;
                            kept_e += 1;
                        }
                    }
                    let mut kept = kept_e;
                    for k in self.elen[i] as usize..self.len[i] as usize {
                        let v = self.iw[src + k];
                        if self.state[v as usize] == NodeState::Var {
                            self.iw[dst + kept] = v;
                            kept += 1;
                        }
                    }
                    self.pe[i] = dst;
                    self.elen[i] = kept_e as i32;
                    self.len[i] = kept as i32;
                    dst += kept;
                }
                _ => unreachable!(),
            }
        }
        self.pfree = dst;
    }

    // ---- the elimination loop --------------------------------------------

    pub fn run(&mut self) {
        while self.nel < self.n {
            let me = match self.pop_min_degree() {
                Some(v) => v,
                None => break,
            };
            debug_assert_eq!(self.state[me], NodeState::Var);
            self.eliminate(me);
        }
        debug_assert_eq!(self.nel, self.n);
    }

    /// Eliminate pivot `me`: build `L_me`, absorb elements, update degrees
    /// of all `v ∈ L_me`, merge indistinguishable variables.
    pub(crate) fn eliminate(&mut self, me: usize) {
        let nv_me = self.nv[me];
        debug_assert!(nv_me > 0);
        self.stats.rounds += 1;
        self.stats.pivots += 1;
        self.nel += nv_me as usize;

        // ---- Phase 1: build L_me into fresh space -----------------------
        let mut cap = (self.len[me] - self.elen[me]) as usize;
        for k in 0..self.elen[me] as usize {
            let e = self.iw[self.pe[me] + k] as usize;
            if self.state[e] == NodeState::Elem {
                cap += self.len[e] as usize;
            }
        }
        self.reserve(cap);

        self.wflg += self.n as u64 + 2; // past any stored w (≤ old mark + n)
        let mark = self.wflg;
        self.w[me] = mark; // exclude me itself
        let pme = self.pfree;
        // Weighted |L_me| is recomputed exactly in Phase 5 after mass
        // eliminations and merges; no running accumulator is needed.
        {
            let p = self.pe[me];
            let elen_me = self.elen[me] as usize;
            let len_me = self.len[me] as usize;
            // Variables directly adjacent (A_me).
            for k in elen_me..len_me {
                let v = self.iw[p + k];
                let vu = v as usize;
                if self.state[vu] == NodeState::Var && self.w[vu] != mark {
                    self.w[vu] = mark;
                    self.iw[self.pfree] = v;
                    self.pfree += 1;
                }
            }
            // Cliques of adjacent elements (∪ L_e), absorbing each element.
            for k in 0..elen_me {
                let e = self.iw[p + k] as usize;
                if self.state[e] != NodeState::Elem {
                    continue;
                }
                let ep = self.pe[e];
                for q in 0..self.len[e] as usize {
                    let v = self.iw[ep + q];
                    let vu = v as usize;
                    if self.state[vu] == NodeState::Var && self.w[vu] != mark {
                        self.w[vu] = mark;
                        self.iw[self.pfree] = v;
                        self.pfree += 1;
                    }
                }
                self.state[e] = NodeState::DeadElem;
                self.parent[e] = me as i32;
            }
        }
        let lme_len = self.pfree - pme;
        self.pe[me] = pme;
        self.len[me] = lme_len as i32;
        self.elen[me] = 0;
        self.state[me] = NodeState::Elem;
        self.stats.work_words += (lme_len + cap) as u64;

        // Remove L_me's variables from the degree lists (re-inserted after
        // their degrees are updated).
        for k in 0..lme_len {
            let v = self.iw[pme + k] as usize;
            self.deg_list_remove(v);
        }

        // ---- Phase 2: Algorithm 2.1 pass 1 — w(e)-based |L_e \ L_me| ----
        // Elements and variables share the `w` array but have disjoint ids,
        // so the `mark` epoch serves both the "v ∈ L_me" flag and the
        // element weights.
        let mut step = StepStats {
            lp: lme_len as u32,
            ..Default::default()
        };
        for k in 0..lme_len {
            let v = self.iw[pme + k] as usize;
            let p = self.pe[v];
            let elen_v = self.elen[v] as usize;
            step.work += elen_v as u32;
            for q in 0..elen_v {
                let e = self.iw[p + q] as usize;
                if self.state[e] != NodeState::Elem {
                    continue;
                }
                if self.w[e] >= mark {
                    self.w[e] -= self.nv[v] as u64;
                } else {
                    // First touch this step: init from the (possibly
                    // stale-high) weighted |L_e|.
                    self.w[e] = mark + self.degree[e] as u64 - self.nv[v] as u64;
                    step.unique_elems += 1;
                }
            }
        }
        self.stats.work_words += step.work as u64;

        // ---- Phase 3: pass 2 — degree update, in-place list rebuild,
        // aggressive absorption, mass elimination, supervariable hashing --
        let mut nvpiv = nv_me; // grows with mass eliminations
        let mut hash_list: Vec<i32> = Vec::new();
        for k in 0..lme_len {
            let v = self.iw[pme + k] as usize;
            debug_assert_eq!(self.state[v], NodeState::Var);
            let p = self.pe[v];
            let elen_v = self.elen[v] as usize;
            let len_v = self.len[v] as usize;

            // Rebuild the element list in place, accumulating Σ|L_e \ L_me|.
            let mut deg: i64 = 0;
            let mut hash: u64 = 0;
            let mut pn = p; // write cursor (never passes the read cursor)
            for q in 0..elen_v {
                let e = self.iw[p + q] as usize;
                if self.state[e] != NodeState::Elem {
                    continue; // absorbed this step or earlier
                }
                let dext = (self.w[e] - mark) as i64;
                if dext > 0 || !self.cfg.aggressive {
                    deg += dext;
                    self.iw[pn] = e as i32;
                    pn += 1;
                    hash = hash.wrapping_add(e as u64);
                } else {
                    // |L_e \ L_me| = 0: aggressive absorption into me.
                    debug_assert_eq!(dext, 0);
                    self.state[e] = NodeState::DeadElem;
                    self.parent[e] = me as i32;
                }
            }
            let p3 = pn; // end of kept elements
            // Rebuild the variable list: drop members of L_me (now covered
            // by element me) and dead variables.
            for q in elen_v..len_v {
                let u = self.iw[p + q];
                let uu = u as usize;
                if self.state[uu] != NodeState::Var || self.w[uu] == mark {
                    continue;
                }
                deg += self.nv[uu] as i64;
                self.iw[pn] = u;
                pn += 1;
                hash = hash.wrapping_add(u as u64);
            }

            if deg == 0 && pn == p3 && self.cfg.aggressive {
                // Mass elimination: N_v ⊆ L_me ∪ {me}.
                self.state[v] = NodeState::DeadVar;
                self.parent[v] = me as i32;
                nvpiv += self.nv[v];
                self.nel += self.nv[v] as usize;
                self.nv[v] = 0;
                continue;
            }
            // Splice `me` in at the elements/variables boundary: move the
            // first kept variable (if any) to the end, put me at p3. At
            // least one original entry was dropped (me from A_v, or a dead
            // element from E_v), so the extra slot fits in v's allocation.
            debug_assert!(pn - p < len_v, "rebuild must shrink v's list");
            if pn > p3 {
                self.iw[pn] = self.iw[p3];
            }
            self.iw[p3] = me as i32;
            pn += 1;
            hash = hash.wrapping_add(me as u64);
            self.elen[v] = (p3 - p + 1) as i32;
            self.len[v] = (pn - p) as i32;

            if deg == 0 && pn - p == 1 {
                // Non-aggressive mode mass elimination (E_v = {me} only).
                self.state[v] = NodeState::DeadVar;
                self.parent[v] = me as i32;
                nvpiv += self.nv[v];
                self.nel += self.nv[v] as usize;
                self.nv[v] = 0;
                continue;
            }

            // Partial degree (without the |L_me \ v| term, added in
            // Phase 5 after supervariable merging — as amd_2 does).
            let d = (self.degree[v] as i64).min(deg).max(0);
            self.degree[v] = d as i32;
            self.hash_of[v] = hash;
            hash_list.push(v as i32);
        }
        self.stats.work_words += lme_len as u64;

        // ---- Phase 4: supervariable detection ---------------------------
        self.detect_supervariables(&hash_list);

        // ---- Phase 5: compact L_me, final degrees, reinsert survivors ---
        let mut kept = 0usize;
        let mut degme_final = 0i32;
        for k in 0..lme_len {
            let v = self.iw[pme + k];
            if self.state[v as usize] == NodeState::Var {
                self.iw[pme + kept] = v;
                kept += 1;
                degme_final += self.nv[v as usize];
            }
        }
        self.len[me] = kept as i32;
        self.degree[me] = degme_final;
        self.nv[me] = nvpiv;
        self.pfree = pme + kept;
        if kept == 0 {
            // Empty element: nothing references it.
            self.state[me] = NodeState::DeadElem;
            self.parent[me] = -1;
        }
        for k in 0..kept {
            let v = self.iw[pme + k] as usize;
            // d_v = min(n - nel - nv_v, partial + |L_me \ v|), at least 1.
            let ext = (degme_final - self.nv[v]) as i64;
            let bound = (self.n - self.nel) as i64 - self.nv[v] as i64;
            let d = (self.degree[v] as i64 + ext).min(bound).max(1);
            self.degree[v] = d as i32;
            self.deg_list_insert(v);
        }

        self.elim_order.push(me as i32);
        if self.cfg.collect_step_stats {
            self.step_stats.push(step);
        }
    }

    /// Hash-based indistinguishable-variable detection among the updated
    /// neighbors of the current pivot (Phase 4).
    fn detect_supervariables(&mut self, hash_list: &[i32]) {
        // Insert into buckets.
        let nbuckets = self.n + 1;
        for &vi in hash_list {
            let v = vi as usize;
            if self.state[v] != NodeState::Var {
                continue;
            }
            let b = (self.hash_of[v] % nbuckets as u64) as usize;
            self.hnext[v] = self.hhead[b];
            self.hhead[b] = vi;
        }
        // For each bucket, compare pairs.
        for &vi in hash_list {
            let v = vi as usize;
            let b = (self.hash_of[v] % nbuckets as u64) as usize;
            let mut i = self.hhead[b];
            if i == -1 {
                continue; // bucket already processed
            }
            // Pairwise comparison within the bucket, merging into the
            // earlier list entry.
            while i != -1 {
                let iu = i as usize;
                let mut j = self.hnext[iu];
                while j != -1 {
                    let ju = j as usize;
                    let jnext = self.hnext[ju];
                    if self.state[ju] == NodeState::Var
                        && self.state[iu] == NodeState::Var
                        && self.hash_of[iu] == self.hash_of[ju]
                        && self.elen[iu] == self.elen[ju]
                        && self.len[iu] == self.len[ju]
                        && self.lists_identical(iu, ju)
                    {
                        // Merge j into i.
                        self.nv[iu] += self.nv[ju];
                        self.nv[ju] = 0;
                        self.state[ju] = NodeState::DeadVar;
                        self.parent[ju] = i;
                    }
                    j = jnext;
                }
                i = self.hnext[iu];
            }
            self.hhead[b] = -1;
        }
        // Reset chains.
        for &vi in hash_list {
            self.hnext[vi as usize] = -1;
        }
    }

    /// Exact set comparison of two variables' lists (elements + variables),
    /// using a fresh mark epoch.
    fn lists_identical(&mut self, a: usize, b: usize) -> bool {
        self.wflg += self.n as u64 + 2; // past any stored w (≤ old mark + n)
        let mark = self.wflg;
        let (pa, la) = (self.pe[a], self.len[a] as usize);
        for k in 0..la {
            self.w[self.iw[pa + k] as usize] = mark;
        }
        let (pb, lb) = (self.pe[b], self.len[b] as usize);
        debug_assert_eq!(la, lb);
        (0..lb).all(|k| self.w[self.iw[pb + k] as usize] == mark)
    }

    // ---- helpers for multiple-elimination drivers (MMD) -----------------

    /// Current state of a node.
    pub(crate) fn node_state(&self, v: usize) -> NodeState {
        self.state[v]
    }

    /// Columns eliminated so far.
    pub(crate) fn eliminated(&self) -> usize {
        self.nel
    }

    /// Remove a live variable from the degree lists (pre-elimination).
    pub(crate) fn remove_from_degree_list(&mut self, v: usize) {
        self.deg_list_remove(v);
    }

    /// Collect an independent set (in the *elimination graph*) of pivots
    /// whose approximate degree is within `mindeg + delta`, greedily and
    /// deterministically — Liu's multiple elimination (§2.3). Does not
    /// modify the degree lists.
    pub(crate) fn collect_independent_min_degree_set(&mut self, delta: i32) -> Vec<i32> {
        while self.mindeg <= self.n && self.dhead[self.mindeg] == -1 {
            self.mindeg += 1;
        }
        if self.mindeg > self.n {
            return Vec::new();
        }
        let limit = (self.mindeg + delta.max(0) as usize).min(self.n);
        let mut candidates: Vec<i32> = Vec::new();
        for d in self.mindeg..=limit {
            let mut h = self.dhead[d];
            while h != -1 {
                candidates.push(h);
                h = self.dnext[h as usize];
            }
        }
        self.wflg += self.n as u64 + 2; // past any stored w (≤ old mark + n)
        let mark = self.wflg;
        let mut selected = Vec::new();
        'cand: for &vi in &candidates {
            let v = vi as usize;
            if self.state[v] != NodeState::Var {
                continue;
            }
            // v conflicts if it lies in a selected pivot's neighborhood:
            // directly marked, or sharing a marked element.
            if self.w[v] == mark {
                continue;
            }
            let (p, el, l) = (self.pe[v], self.elen[v] as usize, self.len[v] as usize);
            for q in 0..el {
                let e = self.iw[p + q] as usize;
                if self.state[e] == NodeState::Elem && self.w[e] == mark {
                    continue 'cand;
                }
            }
            // Select v; mark its neighborhood (A_v vars and E_v elements).
            for q in 0..el {
                let e = self.iw[p + q] as usize;
                if self.state[e] == NodeState::Elem {
                    self.w[e] = mark;
                }
            }
            for q in el..l {
                let u = self.iw[p + q] as usize;
                if self.state[u] == NodeState::Var {
                    self.w[u] = mark;
                }
            }
            self.w[v] = mark;
            selected.push(vi);
        }
        selected
    }

    /// Reconstruct the final permutation from the elimination order and the
    /// absorption forest, and return the collected statistics.
    pub fn finish(self) -> (Vec<i32>, OrderingStats) {
        let perm = crate::ordering::rebuild_perm(self.n, &self.elim_order, &self.parent);
        (perm, self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::SymGraph;
    use crate::matgen::{mesh2d, mesh3d, random_graph};
    use crate::ordering::test_support::check_ordering_contract;
    use crate::ordering::{md::MinDegree, Ordering as _};
    use crate::symbolic::fill_in;
    use crate::util::rng::Rng;

    #[test]
    fn path_graph_no_fill() {
        let n = 12;
        let edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
        let g = SymGraph::from_edges(n, &edges);
        let r = AmdSeq::default().order(&g);
        check_ordering_contract(&g, &r);
        assert_eq!(fill_in(&g, &r.perm), 0);
    }

    #[test]
    fn star_no_fill() {
        let g = SymGraph::from_edges(6, &[(0, 5), (1, 5), (2, 5), (3, 5), (4, 5)]);
        let r = AmdSeq::default().order(&g);
        check_ordering_contract(&g, &r);
        assert_eq!(fill_in(&g, &r.perm), 0);
    }

    #[test]
    fn complete_graph_valid() {
        let mut edges = vec![];
        for i in 0..7 {
            for j in i + 1..7 {
                edges.push((i, j));
            }
        }
        let g = SymGraph::from_edges(7, &edges);
        let r = AmdSeq::default().order(&g);
        check_ordering_contract(&g, &r);
        assert_eq!(fill_in(&g, &r.perm), 0);
    }

    #[test]
    fn empty_and_isolated() {
        let g = SymGraph::from_edges(5, &[]);
        let r = AmdSeq::default().order(&g);
        check_ordering_contract(&g, &r);
        let g2 = SymGraph::from_edges(4, &[(1, 2)]);
        let r2 = AmdSeq::default().order(&g2);
        check_ordering_contract(&g2, &r2);
        assert_eq!(fill_in(&g2, &r2.perm), 0);
    }

    #[test]
    fn random_graphs_valid_permutations() {
        for seed in 0..10 {
            let g = random_graph(200, 6, seed);
            let r = AmdSeq::default().order(&g);
            check_ordering_contract(&g, &r);
        }
    }

    #[test]
    fn quality_close_to_exact_min_degree() {
        // AMD's fill should be within a modest factor of exact MD's.
        for seed in 0..5 {
            let g = random_graph(120, 5, seed);
            let amd = AmdSeq::default().order(&g);
            let md = MinDegree.order(&g);
            let f_amd = fill_in(&g, &amd.perm) as f64;
            let f_md = fill_in(&g, &md.perm) as f64;
            assert!(
                f_amd <= (f_md * 2.0).max(f_md + 50.0),
                "seed={seed}: AMD fill {f_amd} vs MD fill {f_md}"
            );
        }
    }

    #[test]
    fn quality_beats_natural_on_meshes() {
        let g = mesh2d(20, 20);
        let r = AmdSeq::default().order(&g);
        check_ordering_contract(&g, &r);
        let natural: Vec<i32> = (0..g.n as i32).collect();
        let f_amd = fill_in(&g, &r.perm);
        let f_nat = fill_in(&g, &natural);
        assert!(f_amd < f_nat, "AMD {f_amd} vs natural {f_nat}");
    }

    #[test]
    fn works_on_3d_mesh() {
        let g = mesh3d(7, 7, 7);
        let r = AmdSeq::default().order(&g);
        check_ordering_contract(&g, &r);
    }

    #[test]
    fn non_aggressive_mode() {
        let cfg = AmdSeq {
            aggressive: false,
            ..Default::default()
        };
        for seed in 0..3 {
            let g = random_graph(150, 6, seed);
            let r = cfg.order(&g);
            check_ordering_contract(&g, &r);
        }
    }

    #[test]
    fn tiny_elbow_forces_gc() {
        let cfg = AmdSeq {
            elbow: 0.01,
            ..Default::default()
        };
        let g = mesh2d(40, 40);
        let r = cfg.order(&g);
        check_ordering_contract(&g, &r);
        assert!(r.stats.gc_count > 0, "expected at least one GC");
        // Same ordering quality ballpark as the default config.
        let f = fill_in(&g, &r.perm);
        let f_def = fill_in(&g, &AmdSeq::default().order(&g).perm);
        assert!((f as f64) < 3.0 * f_def as f64 + 100.0);
    }

    #[test]
    fn supervariables_detected_on_duplicate_columns() {
        // A graph where vertices 1 and 2 are indistinguishable.
        let g = SymGraph::from_edges(
            6,
            &[(0, 1), (0, 2), (1, 3), (2, 3), (1, 4), (2, 4), (1, 2), (3, 5), (4, 5)],
        );
        let r = AmdSeq::default().order(&g);
        check_ordering_contract(&g, &r);
        // Fewer pivots than columns => merging and/or mass elimination fired.
        assert!(r.stats.pivots < 6);
    }

    #[test]
    fn step_stats_collected() {
        let g = mesh2d(12, 12);
        let (r, steps) = AmdSeq::default().order_with_step_stats(&g);
        check_ordering_contract(&g, &r);
        assert_eq!(steps.len(), r.stats.pivots as usize);
        assert!(steps.iter().any(|s| s.lp > 0));
        for s in &steps {
            assert!(s.unique_elems <= s.work.max(1));
        }
    }

    #[test]
    fn deterministic_given_input() {
        let g = random_graph(300, 6, 42);
        let a = AmdSeq::default().order(&g);
        let b = AmdSeq::default().order(&g);
        assert_eq!(a.perm, b.perm);
    }

    #[test]
    fn fill_quality_on_permuted_inputs_is_stable() {
        // The evaluation protocol: 5 random input permutations (§2.5.4).
        let g = mesh2d(16, 16);
        let mut fills = vec![];
        for seed in 0..5 {
            let mut rng = Rng::new(seed);
            let p = rng.permutation(g.n);
            let pg = crate::graph::perm::permute_graph(&g, &p);
            let r = AmdSeq::default().order(&pg);
            check_ordering_contract(&pg, &r);
            fills.push(fill_in(&pg, &r.perm) as f64);
        }
        let mean = crate::util::stats::mean(&fills);
        for &f in &fills {
            assert!((f - mean).abs() < mean * 0.9 + 50.0, "fills={fills:?}");
        }
    }
}
