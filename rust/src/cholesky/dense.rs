//! Dense Cholesky kernels for the trailing Schur-complement block.
//!
//! [`NativeDense`] is the pure-Rust fallback; the PJRT-backed
//! implementation ([`crate::runtime::PjrtDense`]) runs the AOT-compiled
//! JAX/Pallas kernel and satisfies the same trait, so the sparse solver is
//! oblivious to which engine factors its tail.

/// A dense lower-Cholesky engine: factor `a` (n×n, row-major, full
/// symmetric content) in place into its lower factor `L` (upper part
/// zeroed). Returns `Err` if the matrix is not positive definite.
///
/// Deliberately not `Sync`: the PJRT-backed engine wraps non-thread-safe
/// FFI handles, so the coordinator pins it to a dedicated solver thread.
pub trait DenseCholesky {
    fn factor(&self, a: &mut [f64], n: usize) -> Result<(), String>;
    /// Engine name for reports.
    fn name(&self) -> &'static str;
}

/// Straightforward right-looking dense Cholesky (kij), cache-blocked
/// enough for the tail sizes we use (≤ 1024).
pub struct NativeDense;

impl DenseCholesky for NativeDense {
    fn factor(&self, a: &mut [f64], n: usize) -> Result<(), String> {
        assert_eq!(a.len(), n * n);
        for k in 0..n {
            let akk = a[k * n + k];
            if akk <= 0.0 || !akk.is_finite() {
                return Err(format!(
                    "matrix not positive definite at dense column {k} (pivot {akk:e})"
                ));
            }
            let lkk = akk.sqrt();
            a[k * n + k] = lkk;
            let inv = 1.0 / lkk;
            for i in k + 1..n {
                a[i * n + k] *= inv;
            }
            for j in k + 1..n {
                let ljk = a[j * n + k];
                if ljk != 0.0 {
                    // Update the lower triangle of the trailing block.
                    for i in j..n {
                        a[i * n + j] -= a[i * n + k] * ljk;
                    }
                }
            }
        }
        // Zero the strict upper triangle for a clean L.
        for i in 0..n {
            for j in i + 1..n {
                a[i * n + j] = 0.0;
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
pub(crate) fn check_dense_factor(engine: &dyn DenseCholesky, n: usize, seed: u64) {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    // SPD: A = B B^T + n·I
    let b: Vec<f64> = (0..n * n).map(|_| rng.f64() * 2.0 - 1.0).collect();
    let mut a = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += b[i * n + k] * b[j * n + k];
            }
            a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
        }
    }
    let orig = a.clone();
    engine.factor(&mut a, n).unwrap();
    // L L^T == A
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..=i.min(j) {
                s += a[i * n + k] * a[j * n + k];
            }
            assert!(
                (s - orig[i * n + j]).abs() < 1e-8 * n as f64,
                "({i},{j}): {s} vs {}",
                orig[i * n + j]
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_factors_spd() {
        for n in [1usize, 2, 5, 16, 33] {
            check_dense_factor(&NativeDense, n, n as u64);
        }
    }

    #[test]
    fn native_identity() {
        let mut a = vec![0.0; 9];
        for i in 0..3 {
            a[i * 3 + i] = 4.0;
        }
        NativeDense.factor(&mut a, 3).unwrap();
        for i in 0..3 {
            assert_eq!(a[i * 3 + i], 2.0);
        }
    }

    #[test]
    fn native_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(NativeDense.factor(&mut a, 2).is_err());
    }

    #[test]
    fn zero_size() {
        let mut a: Vec<f64> = vec![];
        NativeDense.factor(&mut a, 0).unwrap();
    }
}
