//! Micro-benchmark harness (criterion is unavailable in the offline
//! registry). Benches are plain binaries with `harness = false`; this
//! module provides warmup + repeated timing with mean ± std reporting and
//! simple Markdown table emission matching the paper's table layouts.

use crate::util::stats;
use crate::util::timer::Timer;

/// Time `f` `reps` times after `warmup` runs; returns per-rep seconds.
pub fn time_reps<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    (0..reps)
        .map(|_| {
            let t = Timer::new();
            std::hint::black_box(f());
            t.secs()
        })
        .collect()
}

/// `mean ± std` formatting used throughout the paper's Table 4.2/4.3.
pub fn fmt_mean_std(xs: &[f64]) -> String {
    format!("{:.3} ± {:.3}", stats::mean(xs), stats::std_dev(xs))
}

/// Scientific notation like the paper's fill-in columns (`5.03e+08`).
pub fn fmt_sci(x: f64) -> String {
    format!("{x:.2e}")
}

/// A Markdown table writer for bench reports.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with per-column alignment.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for c in 0..ncol {
            width[c] = self.header[c].len();
            for r in &self.rows {
                width[c] = width[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", cell, w = width[c]));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('|');
        for w in &width {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reps_counts() {
        let xs = time_reps(1, 5, || 1 + 1);
        assert_eq!(xs.len(), 5);
        assert!(xs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["Matrix", "Time"]);
        t.row(vec!["nd24k".into(), "0.82".into()]);
        let s = t.render();
        assert!(s.contains("| Matrix |"));
        assert!(s.contains("| nd24k  |"));
        assert_eq!(s.lines().count(), 3);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_sci(5.03e8), "5.03e8");
        assert!(fmt_mean_std(&[1.0, 1.0]).starts_with("1.000 ±"));
    }
}
