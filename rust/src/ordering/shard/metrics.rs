//! Shard-engine observability: per-shard job/busy counters, the
//! component-size histogram, and the concurrency high-water mark the
//! stress tests assert against.
//!
//! The engine updates [`EngineCounters`] (interior-mutable atomics) from
//! its dispatcher threads; [`crate::ordering::shard::ShardEngine::metrics`]
//! snapshots them into the plain-data [`ShardMetrics`] the coordinator
//! embeds in its service metrics.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};

/// Number of log2 buckets in the component-size histogram: bucket `b`
/// counts components with `2^b <= n < 2^(b+1)` (the last bucket is
/// open-ended). 24 buckets cover ParAMD's 2^24-vertex ceiling.
pub const SIZE_HIST_BUCKETS: usize = 24;

/// One shard's snapshot.
#[derive(Clone, Debug, Default)]
pub struct ShardStat {
    /// Worker threads of this shard's `OrderingRuntime`.
    pub threads: usize,
    /// Component/singleton ordering jobs this shard has executed
    /// (cancelled-before-start jobs are not counted).
    pub jobs: u64,
    /// Wall-clock seconds this shard's dispatcher spent running jobs.
    pub busy_secs: f64,
    /// Approximate 95th-percentile per-job busy seconds (±1 bucket of
    /// the shard's log-bucketed busy histogram).
    pub busy_p95_secs: f64,
}

/// Engine-wide snapshot: routing counters, the per-shard table, and the
/// component-size histogram.
#[derive(Clone, Debug, Default)]
pub struct ShardMetrics {
    /// Ordering requests routed through the engine.
    pub requests: u64,
    /// Requests that split into more than one component.
    pub decomposed: u64,
    /// Component orderings served (singleton requests count one;
    /// result-cache hits count here too — per-shard `jobs` is the
    /// dispatched-work signal and does not move on a hit).
    pub components: u64,
    /// Most shards observed busy at the same time — the concurrency
    /// witness the acceptance test asserts on.
    pub busy_peak: usize,
    /// Jobs that ran on a reduced kernel (at least one reduction rule
    /// fired) instead of the original component graph.
    pub reduced_jobs: u64,
    /// Vertices peeled into permutation prefixes by leaf stripping.
    pub leaves_stripped: u64,
    /// Rows postponed to permutation tails by the dense rule.
    pub dense_postponed: u64,
    /// Vertices folded into twin-class representatives.
    pub twins_merged: u64,
    /// Undirected edges removed from the ordering problems.
    pub reduce_edges_removed: u64,
    /// Wall-clock seconds spent inside the reduction layer.
    pub reduce_secs: f64,
    /// Stop-the-world quotient-graph garbage collections executed by
    /// jobs on this engine (cache hits replay results and count none).
    pub gc_count: u64,
    /// Cumulative stop-the-world seconds those collections froze a
    /// shard's worker pool for.
    pub gc_secs: f64,
    /// Mid-elimination re-reduction sweeps executed by jobs on this
    /// engine (cache hits replay results and count none).
    pub rereduce_passes: u64,
    /// Global twins merged on live quotient graphs by those sweeps.
    pub mid_twins_merged: u64,
    /// Rows re-postponed to permutation tails mid-elimination.
    pub mid_dense_postponed: u64,
    /// Elements absorbed by superset elements mid-elimination.
    pub elements_absorbed: u64,
    /// Cumulative stop-the-world seconds spent inside those sweeps.
    pub rereduce_secs: f64,
    /// Elbow `claim` failures (memory contention → pivot deferral + GC
    /// request) across every job on this engine.
    pub claim_failures: u64,
    /// Connected requests that took the hybrid ND×ParAMD fan-out path.
    pub hybrid_requests: u64,
    /// Subdomain jobs dispatched by hybrid requests.
    pub subdomains: u64,
    /// Separator-block jobs dispatched by hybrid requests.
    pub separators: u64,
    /// Vertices hybrid requests placed in separator blocks; with
    /// `hybrid_vertices` this yields the separator fraction.
    pub separator_vertices: u64,
    /// Total vertices across hybrid requests (fraction denominator).
    pub hybrid_vertices: u64,
    /// Wall-clock seconds spent inside the nested-dissection partitioner.
    pub partition_secs: f64,
    /// Dispatcher busy seconds attributed to hybrid **subdomain** jobs
    /// (divide by `subdomains` for per-subdomain busy time).
    pub subdomain_busy_secs: f64,
    /// Quality sheds that skipped the hybrid ND×ParAMD partition on a
    /// connected request (served single-job instead).
    pub shed_hybrid: u64,
    /// Quality sheds that disabled the mid-elimination re-reduction
    /// sweep for a request.
    pub shed_rereduce: u64,
    /// Components/kernels a quality shed ordered inline with sequential
    /// AMD instead of dispatching a ParAMD shard job.
    pub shed_sequential: u64,
    /// Per-shard job/busy table, indexed by shard id (0 = wide shard).
    pub per_shard: Vec<ShardStat>,
    /// log2-bucketed component sizes ([`SIZE_HIST_BUCKETS`] buckets).
    pub size_hist: Vec<u64>,
    /// Persistent result-cache tier counters (`None` unless the engine's
    /// cache has an attached [`persist`](crate::ordering::cache::persist)
    /// tier; filled by `ShardEngine::metrics`, not by the counters).
    pub persist: Option<crate::ordering::cache::persist::PersistMetrics>,
}

impl ShardMetrics {
    /// Fraction of hybrid-request vertices that landed in separator
    /// blocks (0.0 when no hybrid request ran).
    pub fn separator_frac(&self) -> f64 {
        if self.hybrid_vertices == 0 {
            0.0
        } else {
            self.separator_vertices as f64 / self.hybrid_vertices as f64
        }
    }
}

impl ShardMetrics {
    /// Render a compact report section.
    pub fn report(&self) -> String {
        let mut s = format!(
            "shards: requests={} decomposed={} components={} busy_peak={}\n",
            self.requests, self.decomposed, self.components, self.busy_peak
        );
        s.push_str(&format!(
            "  reduce: jobs={} leaves={} dense={} twins={} edges=-{} time={:.4}s\n",
            self.reduced_jobs,
            self.leaves_stripped,
            self.dense_postponed,
            self.twins_merged,
            self.reduce_edges_removed,
            self.reduce_secs
        ));
        s.push_str(&format!(
            "  gc: collections={} stop_the_world={:.4}s\n",
            self.gc_count, self.gc_secs
        ));
        s.push_str(&format!(
            "  rereduce: passes={} twins={} dense={} absorbed={} time={:.4}s\n",
            self.rereduce_passes,
            self.mid_twins_merged,
            self.mid_dense_postponed,
            self.elements_absorbed,
            self.rereduce_secs
        ));
        if self.hybrid_requests > 0 {
            let per_sub = self.subdomain_busy_secs / self.subdomains.max(1) as f64;
            s.push_str(&format!(
                "  hybrid: requests={} subdomains={} separators={} sep_frac={:.4} \
                 partition={:.4}s busy/subdomain={:.4}s\n",
                self.hybrid_requests,
                self.subdomains,
                self.separators,
                self.separator_frac(),
                self.partition_secs,
                per_sub
            ));
        }
        if self.shed_hybrid + self.shed_rereduce + self.shed_sequential > 0 {
            s.push_str(&format!(
                "  shed: hybrid={} rereduce={} sequential={}\n",
                self.shed_hybrid, self.shed_rereduce, self.shed_sequential
            ));
        }
        if let Some(p) = &self.persist {
            s.push_str("  ");
            s.push_str(&p.report());
        }
        for (i, st) in self.per_shard.iter().enumerate() {
            s.push_str(&format!(
                "  shard {i}: threads={} jobs={} busy={:.4}s p95={:.4}s\n",
                st.threads, st.jobs, st.busy_secs, st.busy_p95_secs
            ));
        }
        let hist: Vec<String> = self
            .size_hist
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(b, &c)| format!("2^{b}:{c}"))
            .collect();
        if !hist.is_empty() {
            s.push_str(&format!("  component sizes: {}\n", hist.join(" ")));
        }
        s
    }
}

/// Live engine counters, updated lock-free from dispatchers and routers.
#[derive(Debug)]
pub(crate) struct EngineCounters {
    pub(crate) requests: AtomicU64,
    pub(crate) decomposed: AtomicU64,
    pub(crate) components: AtomicU64,
    pub(crate) reduced_jobs: AtomicU64,
    pub(crate) leaves_stripped: AtomicU64,
    pub(crate) dense_postponed: AtomicU64,
    pub(crate) twins_merged: AtomicU64,
    pub(crate) reduce_edges_removed: AtomicU64,
    pub(crate) reduce_nanos: AtomicU64,
    pub(crate) hybrid_requests: AtomicU64,
    pub(crate) subdomain_jobs: AtomicU64,
    pub(crate) separator_jobs: AtomicU64,
    pub(crate) separator_vertices: AtomicU64,
    pub(crate) hybrid_vertices: AtomicU64,
    pub(crate) partition_nanos: AtomicU64,
    pub(crate) subdomain_busy_nanos: AtomicU64,
    pub(crate) shed_hybrid: AtomicU64,
    pub(crate) shed_rereduce: AtomicU64,
    pub(crate) shed_sequential: AtomicU64,
    gc_count: AtomicU64,
    gc_nanos: AtomicU64,
    rereduce_passes: AtomicU64,
    mid_twins_merged: AtomicU64,
    mid_dense_postponed: AtomicU64,
    elements_absorbed: AtomicU64,
    rereduce_nanos: AtomicU64,
    claim_failures: AtomicU64,
    busy_now: AtomicUsize,
    busy_peak: AtomicUsize,
    size_hist: [AtomicU64; SIZE_HIST_BUCKETS],
}

impl EngineCounters {
    pub(crate) fn new() -> Self {
        Self {
            requests: AtomicU64::new(0),
            decomposed: AtomicU64::new(0),
            components: AtomicU64::new(0),
            reduced_jobs: AtomicU64::new(0),
            leaves_stripped: AtomicU64::new(0),
            dense_postponed: AtomicU64::new(0),
            twins_merged: AtomicU64::new(0),
            reduce_edges_removed: AtomicU64::new(0),
            reduce_nanos: AtomicU64::new(0),
            hybrid_requests: AtomicU64::new(0),
            subdomain_jobs: AtomicU64::new(0),
            separator_jobs: AtomicU64::new(0),
            separator_vertices: AtomicU64::new(0),
            hybrid_vertices: AtomicU64::new(0),
            partition_nanos: AtomicU64::new(0),
            subdomain_busy_nanos: AtomicU64::new(0),
            shed_hybrid: AtomicU64::new(0),
            shed_rereduce: AtomicU64::new(0),
            shed_sequential: AtomicU64::new(0),
            gc_count: AtomicU64::new(0),
            gc_nanos: AtomicU64::new(0),
            rereduce_passes: AtomicU64::new(0),
            mid_twins_merged: AtomicU64::new(0),
            mid_dense_postponed: AtomicU64::new(0),
            elements_absorbed: AtomicU64::new(0),
            rereduce_nanos: AtomicU64::new(0),
            claim_failures: AtomicU64::new(0),
            busy_now: AtomicUsize::new(0),
            busy_peak: AtomicUsize::new(0),
            size_hist: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Fold one non-trivial reduction into the counters.
    pub(crate) fn note_reduction(&self, stats: &crate::ordering::reduce::ReduceStats) {
        self.reduced_jobs.fetch_add(1, Relaxed);
        self.leaves_stripped.fetch_add(stats.leaves as u64, Relaxed);
        self.dense_postponed.fetch_add(stats.dense as u64, Relaxed);
        self.twins_merged.fetch_add(stats.twins_merged as u64, Relaxed);
        self.reduce_edges_removed
            .fetch_add(stats.edges_removed as u64, Relaxed);
    }

    /// Fold one finished job's stop-the-world GC tally into the engine
    /// counters (dispatchers only — replayed cache hits never call this).
    pub(crate) fn note_job_gc(&self, count: u64, secs: f64) {
        if count > 0 {
            self.gc_count.fetch_add(count, Relaxed);
            self.gc_nanos.fetch_add((secs * 1e9) as u64, Relaxed);
        }
    }

    /// Fold one finished job's mid-elimination re-reduction tally into
    /// the engine counters (dispatchers only, like [`Self::note_job_gc`]).
    pub(crate) fn note_job_rereduce(
        &self,
        passes: u64,
        twins: u64,
        dense: u64,
        absorbed: u64,
        secs: f64,
    ) {
        if passes > 0 {
            self.rereduce_passes.fetch_add(passes, Relaxed);
            self.mid_twins_merged.fetch_add(twins, Relaxed);
            self.mid_dense_postponed.fetch_add(dense, Relaxed);
            self.elements_absorbed.fetch_add(absorbed, Relaxed);
            self.rereduce_nanos.fetch_add((secs * 1e9) as u64, Relaxed);
        }
    }

    /// Fold one finished job's elbow `claim`-failure tally into the
    /// engine counters (dispatchers only, like [`Self::note_job_gc`]).
    pub(crate) fn note_job_claim_failures(&self, count: u64) {
        if count > 0 {
            self.claim_failures.fetch_add(count, Relaxed);
        }
    }

    /// Record one dispatched component of `n` vertices in the histogram.
    pub(crate) fn note_component(&self, n: usize) {
        let bucket = (n.max(1).ilog2() as usize).min(SIZE_HIST_BUCKETS - 1);
        self.size_hist[bucket].fetch_add(1, Relaxed);
    }

    /// A shard started running a job; maintains the concurrency peak.
    pub(crate) fn enter_busy(&self) {
        let now = self.busy_now.fetch_add(1, Relaxed) + 1;
        self.busy_peak.fetch_max(now, Relaxed);
    }

    /// The matching end-of-job decrement.
    pub(crate) fn exit_busy(&self) {
        self.busy_now.fetch_sub(1, Relaxed);
    }

    pub(crate) fn snapshot(&self, per_shard: Vec<ShardStat>) -> ShardMetrics {
        ShardMetrics {
            requests: self.requests.load(Relaxed),
            decomposed: self.decomposed.load(Relaxed),
            components: self.components.load(Relaxed),
            busy_peak: self.busy_peak.load(Relaxed),
            reduced_jobs: self.reduced_jobs.load(Relaxed),
            leaves_stripped: self.leaves_stripped.load(Relaxed),
            dense_postponed: self.dense_postponed.load(Relaxed),
            twins_merged: self.twins_merged.load(Relaxed),
            reduce_edges_removed: self.reduce_edges_removed.load(Relaxed),
            reduce_secs: self.reduce_nanos.load(Relaxed) as f64 / 1e9,
            gc_count: self.gc_count.load(Relaxed),
            gc_secs: self.gc_nanos.load(Relaxed) as f64 / 1e9,
            rereduce_passes: self.rereduce_passes.load(Relaxed),
            mid_twins_merged: self.mid_twins_merged.load(Relaxed),
            mid_dense_postponed: self.mid_dense_postponed.load(Relaxed),
            elements_absorbed: self.elements_absorbed.load(Relaxed),
            rereduce_secs: self.rereduce_nanos.load(Relaxed) as f64 / 1e9,
            claim_failures: self.claim_failures.load(Relaxed),
            hybrid_requests: self.hybrid_requests.load(Relaxed),
            subdomains: self.subdomain_jobs.load(Relaxed),
            separators: self.separator_jobs.load(Relaxed),
            separator_vertices: self.separator_vertices.load(Relaxed),
            hybrid_vertices: self.hybrid_vertices.load(Relaxed),
            partition_secs: self.partition_nanos.load(Relaxed) as f64 / 1e9,
            subdomain_busy_secs: self.subdomain_busy_nanos.load(Relaxed) as f64 / 1e9,
            shed_hybrid: self.shed_hybrid.load(Relaxed),
            shed_rereduce: self.shed_rereduce.load(Relaxed),
            shed_sequential: self.shed_sequential.load(Relaxed),
            per_shard,
            size_hist: self.size_hist.iter().map(|b| b.load(Relaxed)).collect(),
            persist: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_peak_tracks_the_high_water_mark() {
        let c = EngineCounters::new();
        c.enter_busy();
        c.enter_busy();
        c.exit_busy();
        c.enter_busy();
        let m = c.snapshot(Vec::new());
        assert_eq!(m.busy_peak, 2);
        c.exit_busy();
        c.exit_busy();
        assert_eq!(c.snapshot(Vec::new()).busy_peak, 2, "peak never decays");
    }

    #[test]
    fn histogram_buckets_by_log2() {
        let c = EngineCounters::new();
        c.note_component(1); // bucket 0
        c.note_component(2); // bucket 1
        c.note_component(3); // bucket 1
        c.note_component(1024); // bucket 10
        c.note_component(usize::MAX); // clamped to the last bucket
        let m = c.snapshot(Vec::new());
        assert_eq!(m.size_hist[0], 1);
        assert_eq!(m.size_hist[1], 2);
        assert_eq!(m.size_hist[10], 1);
        assert_eq!(m.size_hist[SIZE_HIST_BUCKETS - 1], 1);
    }

    #[test]
    fn report_lists_shards_and_hist() {
        let c = EngineCounters::new();
        c.requests.fetch_add(3, Relaxed);
        c.note_component(8);
        let m = c.snapshot(vec![ShardStat {
            threads: 4,
            jobs: 3,
            busy_secs: 0.25,
            busy_p95_secs: 0.125,
        }]);
        let r = m.report();
        assert!(r.contains("requests=3"));
        assert!(
            r.contains("shard 0: threads=4 jobs=3 busy=0.2500s p95=0.1250s"),
            "per-shard line carries the p95 busy time: {r}"
        );
        assert!(r.contains("2^3:1"));
        assert!(r.contains("reduce: jobs=0"), "reduce line always present");
        assert!(r.contains("gc: collections=0"), "gc line always present");
        assert!(
            r.contains("rereduce: passes=0"),
            "rereduce line always present"
        );
    }

    #[test]
    fn hybrid_line_appears_only_after_a_hybrid_request() {
        let c = EngineCounters::new();
        assert!(!c.snapshot(Vec::new()).report().contains("hybrid:"));
        c.hybrid_requests.fetch_add(1, Relaxed);
        c.subdomain_jobs.fetch_add(4, Relaxed);
        c.separator_jobs.fetch_add(3, Relaxed);
        c.separator_vertices.fetch_add(50, Relaxed);
        c.hybrid_vertices.fetch_add(1000, Relaxed);
        let m = c.snapshot(Vec::new());
        assert!((m.separator_frac() - 0.05).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("hybrid: requests=1 subdomains=4 separators=3"));
        assert!(r.contains("sep_frac=0.0500"));
    }

    #[test]
    fn shed_line_appears_only_after_a_shed() {
        let c = EngineCounters::new();
        assert!(!c.snapshot(Vec::new()).report().contains("shed:"));
        c.shed_hybrid.fetch_add(1, Relaxed);
        c.shed_rereduce.fetch_add(2, Relaxed);
        c.shed_sequential.fetch_add(3, Relaxed);
        let m = c.snapshot(Vec::new());
        assert_eq!(
            (m.shed_hybrid, m.shed_rereduce, m.shed_sequential),
            (1, 2, 3)
        );
        assert!(m.report().contains("shed: hybrid=1 rereduce=2 sequential=3"));
    }

    #[test]
    fn gc_counters_accumulate_across_jobs() {
        let c = EngineCounters::new();
        c.note_job_gc(2, 0.25);
        c.note_job_gc(0, 0.0); // GC-free jobs leave no trace
        c.note_job_gc(1, 0.5);
        let m = c.snapshot(Vec::new());
        assert_eq!(m.gc_count, 3);
        assert!((m.gc_secs - 0.75).abs() < 1e-6);
        assert!(m.report().contains("gc: collections=3"));
    }

    #[test]
    fn rereduce_counters_accumulate_across_jobs() {
        let c = EngineCounters::new();
        c.note_job_rereduce(2, 10, 1, 4, 0.25);
        c.note_job_rereduce(0, 0, 0, 0, 0.0); // sweep-free jobs leave no trace
        c.note_job_rereduce(1, 5, 0, 2, 0.5);
        let m = c.snapshot(Vec::new());
        assert_eq!(m.rereduce_passes, 3);
        assert_eq!(m.mid_twins_merged, 15);
        assert_eq!(m.mid_dense_postponed, 1);
        assert_eq!(m.elements_absorbed, 6);
        assert!((m.rereduce_secs - 0.75).abs() < 1e-6);
        assert!(m
            .report()
            .contains("rereduce: passes=3 twins=15 dense=1 absorbed=6"));
    }

    #[test]
    fn claim_failure_counters_accumulate_across_jobs() {
        let c = EngineCounters::new();
        c.note_job_claim_failures(3);
        c.note_job_claim_failures(0); // contention-free jobs leave no trace
        c.note_job_claim_failures(2);
        assert_eq!(c.snapshot(Vec::new()).claim_failures, 5);
    }

    #[test]
    fn reduction_counters_accumulate_per_rule() {
        let c = EngineCounters::new();
        c.note_reduction(&crate::ordering::reduce::ReduceStats {
            leaves: 5,
            dense: 2,
            twins_merged: 9,
            edges_removed: 40,
        });
        c.note_reduction(&crate::ordering::reduce::ReduceStats {
            leaves: 1,
            dense: 0,
            twins_merged: 3,
            edges_removed: 6,
        });
        let m = c.snapshot(Vec::new());
        assert_eq!(m.reduced_jobs, 2);
        assert_eq!(m.leaves_stripped, 6);
        assert_eq!(m.dense_postponed, 2);
        assert_eq!(m.twins_merged, 12);
        assert_eq!(m.reduce_edges_removed, 46);
        assert!(m.report().contains("reduce: jobs=2 leaves=6 dense=2 twins=12"));
    }
}
