//! Per-method service metrics — request counts, latency summaries split
//! into **queue wait** vs **service** time, fill-in accumulation — plus
//! pipeline-wide gauges (queue depth, cancellations, arena evictions)
//! and the shard engine's snapshot (per-shard jobs/busy time, component
//! histogram, concurrency peak).
//!
//! Latency storage is **constant in the request count**: every series
//! lives in a fixed-footprint log-bucketed
//! [`LogHistogram`](crate::util::stats::LogHistogram) (exact mean/sum,
//! ±1-bucket quantiles) instead of an unbounded `Vec<f64>` — the
//! millions-of-users memory bound. The Prometheus/JSON renderers in
//! [`crate::telemetry::export`] read these snapshots.

use crate::ordering::cache::CacheMetrics;
use crate::ordering::shard::ShardMetrics;
use crate::util::stats::LogHistogram;

/// One method's accumulated numbers. Fixed memory footprint: the three
/// latency series are log-bucketed histograms, not sample vectors.
#[derive(Clone, Debug, Default)]
pub struct MethodMetrics {
    pub requests: u64,
    /// End-to-end latency per request (wait + service).
    latency: LogHistogram,
    /// Time spent queued before a scheduler picked the request up.
    wait: LogHistogram,
    /// Time spent actually processing (pre-process + order + fill).
    service: LogHistogram,
    pub total_fill: i64,
}

impl MethodMetrics {
    /// Exact mean end-to-end latency (the histogram carries an exact sum).
    pub fn mean_latency(&self) -> f64 {
        self.latency.mean()
    }

    /// Approximate 95th-percentile end-to-end latency (±1 bucket).
    pub fn p95_latency(&self) -> f64 {
        self.latency.quantile(0.95)
    }

    /// Approximate end-to-end latency quantile, `q` in [0, 1].
    pub fn latency_quantile(&self, q: f64) -> f64 {
        self.latency.quantile(q)
    }

    /// Exact sum of end-to-end latencies (Prometheus summary `_sum`).
    pub fn latency_sum(&self) -> f64 {
        self.latency.sum()
    }

    pub fn mean_wait(&self) -> f64 {
        self.wait.mean()
    }

    /// Approximate queue-wait quantile, `q` in [0, 1].
    pub fn wait_quantile(&self, q: f64) -> f64 {
        self.wait.quantile(q)
    }

    pub fn mean_service(&self) -> f64 {
        self.service.mean()
    }

    /// Approximate service-time quantile, `q` in [0, 1].
    pub fn service_quantile(&self, q: f64) -> f64 {
        self.service.quantile(q)
    }
}

/// Pipeline-wide gauges and counters. The `queue_depth` and
/// `arena_evictions` fields are snapshots stamped by `Service::metrics`;
/// the rest accumulate as requests flow.
#[derive(Clone, Debug, Default)]
pub struct PipelineMetrics {
    /// Tickets accepted by `submit` (including the sync shim).
    pub submitted: u64,
    /// Requests that produced a reply.
    pub completed: u64,
    /// Requests skipped or aborted because their ticket was cancelled.
    pub cancelled: u64,
    /// Requests whose processing panicked (ticket failed).
    pub failed: u64,
    /// `try_submit`s shed by admission control (in-flight budget, full
    /// queue, or caller quota) with a structured `Rejected` reply.
    pub rejected: u64,
    /// Requests abandoned because their request-carried deadline expired
    /// (resolved to `OrderError::DeadlineExceeded`).
    pub deadline_exceeded: u64,
    /// Queue depth at snapshot time.
    pub queue_depth: usize,
    /// Highest queue depth observed at any submit.
    pub queue_depth_peak: usize,
    /// Arenas dropped by the pool's eviction policy, at snapshot time.
    pub arena_evictions: u64,
}

/// Service-wide metrics keyed by method name.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    entries: Vec<(String, MethodMetrics)>,
    pub pipeline: PipelineMetrics,
    /// Shard-engine snapshot, stamped by `Service::metrics`.
    pub shards: ShardMetrics,
    /// Result-cache snapshot, stamped by `Service::metrics`.
    pub cache: CacheMetrics,
}

impl Metrics {
    /// Record a request with no queue wait (direct/inline callers).
    pub fn record(&mut self, method: &str, latency_secs: f64, fill: Option<i64>) {
        self.record_split(method, 0.0, latency_secs, fill);
    }

    /// Record a pipelined request: `wait_secs` in the queue, then
    /// `service_secs` of processing.
    pub fn record_split(
        &mut self,
        method: &str,
        wait_secs: f64,
        service_secs: f64,
        fill: Option<i64>,
    ) {
        let e = match self.entries.iter_mut().find(|(m, _)| m == method) {
            Some((_, e)) => e,
            None => {
                self.entries
                    .push((method.to_string(), MethodMetrics::default()));
                &mut self.entries.last_mut().unwrap().1
            }
        };
        e.requests += 1;
        e.latency.record(wait_secs + service_secs);
        e.wait.record(wait_secs);
        e.service.record(service_secs);
        e.total_fill += fill.unwrap_or(0);
    }

    /// A pipelined request produced a reply (scheduler-only; direct
    /// `record*` callers are not pipeline traffic).
    pub(crate) fn note_completed(&mut self) {
        self.pipeline.completed += 1;
    }

    pub(crate) fn note_submit(&mut self, queue_depth: usize) {
        self.note_submit_batch(1, queue_depth);
    }

    /// A batch of `n` requests was accepted in one queue reservation.
    pub(crate) fn note_submit_batch(&mut self, n: u64, queue_depth: usize) {
        self.pipeline.submitted += n;
        self.pipeline.queue_depth_peak = self.pipeline.queue_depth_peak.max(queue_depth);
    }

    pub(crate) fn note_cancelled(&mut self) {
        self.pipeline.cancelled += 1;
    }

    pub(crate) fn note_failed(&mut self) {
        self.pipeline.failed += 1;
    }

    pub(crate) fn note_rejected(&mut self) {
        self.pipeline.rejected += 1;
    }

    pub(crate) fn note_deadline_exceeded(&mut self) {
        self.pipeline.deadline_exceeded += 1;
    }

    pub fn get(&self, method: &str) -> Option<&MethodMetrics> {
        self.entries.iter().find(|(m, _)| m == method).map(|(_, e)| e)
    }

    pub fn total_requests(&self) -> u64 {
        self.entries.iter().map(|(_, e)| e.requests).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &MethodMetrics)> {
        self.entries.iter().map(|(m, e)| (m.as_str(), e))
    }

    /// Render a compact report.
    pub fn report(&self) -> String {
        let mut s = String::from("method     reqs   mean(s)    p95(s)     wait(s)    svc(s)\n");
        for (m, e) in self.iter() {
            s.push_str(&format!(
                "{:<10} {:<6} {:<10.4} {:<10.4} {:<10.4} {:<10.4}\n",
                m,
                e.requests,
                e.mean_latency(),
                e.p95_latency(),
                e.mean_wait(),
                e.mean_service()
            ));
        }
        let p = &self.pipeline;
        s.push_str(&format!(
            "pipeline: submitted={} completed={} cancelled={} failed={} \
             rejected={} deadline_exceeded={} queue_peak={} evictions={}\n",
            p.submitted,
            p.completed,
            p.cancelled,
            p.failed,
            p.rejected,
            p.deadline_exceeded,
            p.queue_depth_peak,
            p.arena_evictions
        ));
        if !self.shards.per_shard.is_empty() {
            s.push_str(&self.shards.report());
        }
        if self.cache.budget_bytes > 0 {
            s.push_str(&self.cache.report());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::default();
        m.record("amd", 0.5, Some(100));
        m.record("amd", 1.5, Some(200));
        m.record("paramd", 0.1, None);
        assert_eq!(m.total_requests(), 3);
        let amd = m.get("amd").unwrap();
        assert_eq!(amd.requests, 2);
        assert!((amd.mean_latency() - 1.0).abs() < 1e-12);
        assert_eq!(amd.total_fill, 300);
        assert!(m.report().contains("paramd"));
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn split_latencies_accumulate_both_halves() {
        let mut m = Metrics::default();
        m.record_split("paramd", 0.25, 0.75, None);
        m.record_split("paramd", 0.75, 0.25, None);
        let e = m.get("paramd").unwrap();
        assert!((e.mean_latency() - 1.0).abs() < 1e-12);
        assert!((e.mean_wait() - 0.5).abs() < 1e-12);
        assert!((e.mean_service() - 0.5).abs() < 1e-12);
        assert_eq!(
            m.pipeline.completed, 0,
            "direct record calls are not pipeline traffic"
        );
        m.note_completed();
        assert_eq!(m.pipeline.completed, 1);
    }

    #[test]
    fn batched_submissions_count_every_request() {
        let mut m = Metrics::default();
        m.note_submit_batch(5, 5);
        m.note_submit(2);
        assert_eq!(m.pipeline.submitted, 6);
        assert_eq!(m.pipeline.queue_depth_peak, 5);
    }

    #[test]
    fn latency_storage_is_constant_in_request_count() {
        // The millions-of-users bound: 10k recorded requests must not
        // grow the metrics' memory. MethodMetrics holds only inline
        // histograms (no Vec), so the entries table's heap usage is the
        // method-name strings + one fixed-size struct per method —
        // identical after 10 and after 10 000 requests.
        fn heap_bytes(m: &Metrics) -> usize {
            m.iter()
                .map(|(name, e)| name.len() + std::mem::size_of_val(e))
                .sum()
        }
        let mut m = Metrics::default();
        for i in 0..10 {
            m.record_split("paramd", 1e-4 * i as f64, 1e-3, Some(1));
        }
        let early = heap_bytes(&m);
        for i in 10..10_000u32 {
            m.record_split("paramd", 1e-4 * (i % 97) as f64, 1e-3 * (i % 13) as f64, Some(1));
        }
        assert_eq!(heap_bytes(&m), early, "10k requests must not grow metrics memory");
        let e = m.get("paramd").unwrap();
        assert_eq!(e.requests, 10_000);
        assert!(e.mean_latency() > 0.0);
        assert!(e.p95_latency() >= e.latency_quantile(0.5));
    }

    #[test]
    fn quantile_accessors_cover_all_three_series() {
        let mut m = Metrics::default();
        for _ in 0..100 {
            m.record_split("amd", 0.2, 0.8, None);
        }
        let e = m.get("amd").unwrap();
        assert!((e.latency_quantile(0.5) - 1.0).abs() < 0.4, "p50 within a bucket");
        assert!((e.wait_quantile(0.5) - 0.2).abs() < 0.1);
        assert!((e.service_quantile(0.5) - 0.8).abs() < 0.35);
        assert!((e.latency_sum() - 100.0).abs() < 1e-9, "summary sum is exact");
    }

    #[test]
    fn pipeline_counters_track_submissions() {
        let mut m = Metrics::default();
        m.note_submit(3);
        m.note_submit(1);
        m.note_cancelled();
        m.note_failed();
        m.note_rejected();
        m.note_rejected();
        m.note_deadline_exceeded();
        assert_eq!(m.pipeline.submitted, 2);
        assert_eq!(m.pipeline.queue_depth_peak, 3);
        assert_eq!(m.pipeline.cancelled, 1);
        assert_eq!(m.pipeline.failed, 1);
        assert_eq!(m.pipeline.rejected, 2);
        assert_eq!(m.pipeline.deadline_exceeded, 1);
        assert!(m.report().contains("queue_peak=3"));
        assert!(m.report().contains("rejected=2"));
        assert!(m.report().contains("deadline_exceeded=1"));
    }
}
