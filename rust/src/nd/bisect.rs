//! Multilevel bisection: BFS region growing on the coarsest graph,
//! Fiduccia–Mattheyses edge-cut refinement at every uncoarsening level.

use super::coarsen::{coarsen_hierarchy, WeightedGraph};
use super::NestedDissection;
use crate::graph::csr::SymGraph;
use crate::util::rng::Rng;

/// Bisect `g`, returning a 0/1 side per vertex.
pub fn multilevel_bisect(g: &SymGraph, cfg: &NestedDissection) -> Vec<u8> {
    let wg = WeightedGraph::from_sym(g);
    let mut rng = Rng::new(cfg.seed ^ (g.n as u64).rotate_left(17));
    let (coarsest, levels) = coarsen_hierarchy(wg, cfg.coarsen_to, &mut rng);
    let mut parts = initial_bisection(&coarsest, &mut rng);
    fm_refine(&coarsest, &mut parts, cfg.fm_passes);
    // Project back up the hierarchy, refining at each level.
    for level in levels.iter().rev() {
        let mut fine_parts = vec![0u8; level.graph.n];
        for v in 0..level.graph.n {
            fine_parts[v] = parts[level.map[v] as usize];
        }
        fm_refine(&level.graph, &mut fine_parts, cfg.fm_passes);
        parts = fine_parts;
    }
    parts
}

/// BFS region growing from a pseudo-peripheral vertex until half the total
/// vertex weight is claimed.
pub fn initial_bisection(g: &WeightedGraph, rng: &mut Rng) -> Vec<u8> {
    let n = g.n;
    if n == 0 {
        return vec![];
    }
    let start = pseudo_peripheral(g, rng.below(n));
    let half = g.total_vweight() / 2;
    let mut parts = vec![1u8; n];
    let mut weight = 0i64;
    let mut queue = std::collections::VecDeque::new();
    let mut visited = vec![false; n];
    queue.push_back(start);
    visited[start] = true;
    while let Some(v) = queue.pop_front() {
        if weight >= half {
            break;
        }
        parts[v] = 0;
        weight += g.vweight[v];
        for (u, _) in g.neighbors(v) {
            if !visited[u as usize] {
                visited[u as usize] = true;
                queue.push_back(u as usize);
            }
        }
    }
    // Disconnected remainder: BFS may exhaust a component early. Claim
    // unvisited vertices greedily until balanced.
    if weight < half {
        for v in 0..n {
            if weight >= half {
                break;
            }
            if parts[v] == 1 && !visited[v] {
                parts[v] = 0;
                weight += g.vweight[v];
            }
        }
    }
    parts
}

/// Find a far-from-`seed` vertex by repeated BFS (2 sweeps).
fn pseudo_peripheral(g: &WeightedGraph, seed: usize) -> usize {
    let mut v = seed;
    for _ in 0..2 {
        let mut dist = vec![-1i32; g.n];
        let mut queue = std::collections::VecDeque::new();
        dist[v] = 0;
        queue.push_back(v);
        let mut last = v;
        while let Some(x) = queue.pop_front() {
            last = x;
            for (u, _) in g.neighbors(x) {
                if dist[u as usize] == -1 {
                    dist[u as usize] = dist[x] + 1;
                    queue.push_back(u as usize);
                }
            }
        }
        v = last;
    }
    v
}

/// Total weight of cut edges (each undirected edge counted once).
pub fn cut_weight(g: &WeightedGraph, parts: &[u8]) -> i64 {
    let mut cut = 0i64;
    for v in 0..g.n {
        for (u, w) in g.neighbors(v) {
            if (u as usize) > v && parts[v] != parts[u as usize] {
                cut += w;
            }
        }
    }
    cut
}

/// Simplified Fiduccia–Mattheyses: passes of single-vertex moves in gain
/// order with a balance constraint; each pass keeps the best prefix.
pub fn fm_refine(g: &WeightedGraph, parts: &mut [u8], passes: usize) {
    let n = g.n;
    if n < 4 {
        return;
    }
    let total = g.total_vweight();
    let max_imbalance = (total / 10).max(2); // 10% slack
    let side_weight = |parts: &[u8]| -> [i64; 2] {
        let mut w = [0i64; 2];
        for v in 0..n {
            w[parts[v] as usize] += g.vweight[v];
        }
        w
    };
    for _ in 0..passes {
        let mut w = side_weight(parts);
        // gain(v) = external - internal edge weight.
        let gain = |v: usize, parts: &[u8]| -> i64 {
            let mut ext = 0i64;
            let mut int = 0i64;
            for (u, ew) in g.neighbors(v) {
                if parts[u as usize] == parts[v] {
                    int += ew;
                } else {
                    ext += ew;
                }
            }
            ext - int
        };
        let mut locked = vec![false; n];
        let mut moves: Vec<usize> = Vec::new();
        let mut cum_gain = 0i64;
        let mut best_prefix = 0usize;
        let mut best_gain = 0i64;
        // Greedy sequence of up to n/4 moves.
        for _ in 0..(n / 4).max(8).min(n) {
            let mut best_v = usize::MAX;
            let mut best_g = i64::MIN;
            for v in 0..n {
                if locked[v] {
                    continue;
                }
                let from = parts[v] as usize;
                // Balance: moving v must not over-drain its side.
                if w[from] - g.vweight[v] < total / 2 - max_imbalance {
                    continue;
                }
                let gv = gain(v, parts);
                if gv > best_g {
                    best_g = gv;
                    best_v = v;
                }
            }
            if best_v == usize::MAX {
                break;
            }
            let from = parts[best_v] as usize;
            parts[best_v] ^= 1;
            w[from] -= g.vweight[best_v];
            w[1 - from] += g.vweight[best_v];
            locked[best_v] = true;
            cum_gain += best_g;
            moves.push(best_v);
            if cum_gain > best_gain {
                best_gain = cum_gain;
                best_prefix = moves.len();
            }
        }
        // Roll back moves beyond the best prefix.
        for &v in &moves[best_prefix..] {
            parts[v] ^= 1;
        }
        if best_gain <= 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{mesh2d, random_graph};
    use crate::nd::NestedDissection;

    #[test]
    fn bisection_is_balanced() {
        let g = mesh2d(16, 16);
        let parts = multilevel_bisect(&g, &NestedDissection::default());
        let zero = parts.iter().filter(|&&p| p == 0).count();
        let frac = zero as f64 / g.n as f64;
        assert!(
            (0.25..=0.75).contains(&frac),
            "unbalanced bisection: {frac:.2}"
        );
    }

    #[test]
    fn refinement_does_not_worsen_cut() {
        let g0 = mesh2d(12, 12);
        let wg = WeightedGraph::from_sym(&g0);
        let mut rng = Rng::new(5);
        let mut parts = initial_bisection(&wg, &mut rng);
        let before = cut_weight(&wg, &parts);
        fm_refine(&wg, &mut parts, 4);
        let after = cut_weight(&wg, &parts);
        assert!(after <= before, "FM worsened the cut: {before} -> {after}");
    }

    #[test]
    fn mesh_cut_is_near_perimeter() {
        // A k×k mesh has a natural cut of ~k; multilevel bisection should
        // land within a small factor.
        let k = 20;
        let g = mesh2d(k, k);
        let parts = multilevel_bisect(&g, &NestedDissection::default());
        let wg = WeightedGraph::from_sym(&g);
        let cut = cut_weight(&wg, &parts);
        assert!(cut <= 4 * k as i64, "cut {cut} far above O(k)={k}");
        assert!(cut >= 1);
    }

    #[test]
    fn handles_random_graphs() {
        for seed in 0..3 {
            let g = random_graph(200, 4, seed);
            let parts = multilevel_bisect(&g, &NestedDissection::default());
            assert_eq!(parts.len(), g.n);
        }
    }
}
