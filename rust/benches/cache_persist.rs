//! Persistent result-cache tier — cold vs warm-restart throughput.
//!
//! Three laps over the same request stream of distinct meshes:
//!
//! - **cold populate** — a fresh service with `--persist-dir` on an
//!   empty directory: every request computes end to end while the
//!   write-behind flusher appends it to the log.
//! - **warm restart** — the service is dropped (draining the dirty
//!   queue) and reopened on the same directory: recovery replays the
//!   log into the in-memory cache, so the identical stream answers
//!   from verified warm-start hits.
//! - **cold restart** — the same reopen against an empty directory, as
//!   the recompute baseline a restart without persistence pays.
//!
//! The acceptance bar is warm-restart throughput ≥ 3× the cold
//! restart. Writes the JSON trajectory file `BENCH_cache_persist.json`
//! (override with `PARAMD_BENCH_CACHE_PERSIST_OUT`; default lands in
//! the repository root when run via `cargo bench` from `rust/`).
//!
//! Knobs: `PARAMD_THREADS` (default 8), `PARAMD_REPS` (default 24
//! requests), or `--smoke` for a quick CI pass.

#[path = "bench_common/mod.rs"]
#[allow(dead_code)] // shared helper module; this bench uses a subset
mod bench_common;

use paramd::coordinator::{Method, OrderRequest, Service};
use paramd::graph::csr::SymGraph;
use paramd::matgen::mesh2d;
use paramd::util::timer::Timer;

fn paramd_req(g: SymGraph) -> OrderRequest {
    OrderRequest {
        matrix: None,
        pattern: Some(g),
        method: Method::ParAmd {
            threads: 4,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    }
}

fn service(threads: usize, dir: &std::path::Path) -> Service {
    Service::new(2)
        .with_shards(2)
        .with_order_threads(threads)
        .with_scheduler_threads(2)
        .with_persist(dir)
        .expect("persist dir must open")
}

fn run(svc: &Service, graphs: &[SymGraph]) -> f64 {
    let t = Timer::new();
    for g in graphs {
        let rep = svc.order(&paramd_req(g.clone()));
        assert!(!rep.perm.is_empty());
    }
    t.secs()
}

fn main() {
    bench_common::banner(
        "Persistent result cache — cold vs warm-restart throughput",
        "ISSUE 10 robustness tier; not a paper table",
    );
    let smoke = std::env::args().any(|a| a == "--smoke");
    let threads = bench_common::threads();
    let requests: usize = if smoke {
        6
    } else {
        std::env::var("PARAMD_REPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(24)
    };
    let side = if smoke { 30 } else { 90 };
    let graphs: Vec<SymGraph> = (0..requests).map(|i| mesh2d(side, side + i)).collect();

    let warm_dir = std::env::temp_dir().join(format!("paramd_bench_persist_{}", std::process::id()));
    let cold_dir =
        std::env::temp_dir().join(format!("paramd_bench_persist_cold_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&warm_dir);
    let _ = std::fs::remove_dir_all(&cold_dir);

    // Cold populate: compute everything once, write-behind to the log.
    let svc = service(threads, &warm_dir);
    let cold_populate_secs = run(&svc, &graphs);
    drop(svc); // drains the dirty queue and fsyncs

    // Warm restart: recovery replays the log, the stream hits.
    let svc = service(threads, &warm_dir);
    let pm = svc.metrics().shards.persist.expect("tier attached");
    let warm_secs = run(&svc, &graphs);
    let hits = svc.metrics().cache.hits;
    drop(svc);

    // Cold restart: the same reopen with nothing on disk to replay.
    let svc = service(threads, &cold_dir);
    let cold_restart_secs = run(&svc, &graphs);
    drop(svc);

    let speedup = cold_restart_secs / warm_secs.max(1e-12);
    let thr = |secs: f64| requests as f64 / secs.max(1e-12);
    println!("{:<16} {:>12} {:>12} {:>10}", "lap", "secs", "req/s", "vs cold");
    println!(
        "{:<16} {:>12.4} {:>12.1} {:>10}",
        "cold populate",
        cold_populate_secs,
        thr(cold_populate_secs),
        "-"
    );
    println!(
        "{:<16} {:>12.4} {:>12.1} {:>9.1}x",
        "cold restart",
        cold_restart_secs,
        thr(cold_restart_secs),
        1.0
    );
    println!(
        "{:<16} {:>12.4} {:>12.1} {:>9.1}x",
        "warm restart", warm_secs, thr(warm_secs), speedup
    );
    println!(
        "persist: warm_start={} recovered_bytes={} rejects={} hits_after_restart={hits}",
        pm.warm_start_entries, pm.recovered_bytes, pm.recovery_rejects
    );
    if pm.warm_start_entries == 0 {
        eprintln!("WARNING: warm restart recovered nothing — persistence is not engaging");
    }
    if speedup < 3.0 {
        eprintln!("WARNING: warm-restart speedup {speedup:.1}x below the 3x acceptance bar");
    }

    let out = std::env::var("PARAMD_BENCH_CACHE_PERSIST_OUT")
        .unwrap_or_else(|_| "../BENCH_cache_persist.json".into());
    let json = format!(
        "{{\n  \"bench\": \"cache_persist\",\n  \"status\": \"measured\",\n  \
         \"threads\": {threads},\n  \"requests\": {requests},\n  \
         \"workload\": \"distinct mesh2d({side}, {side}..{side}+{requests}) stream, \
         persisted then restarted\",\n  \
         \"acceptance\": \"warm-restart throughput >= 3x cold restart\",\n  \
         \"cold_populate_secs\": {cold_populate_secs:.6},\n  \
         \"cold_restart_secs\": {cold_restart_secs:.6},\n  \
         \"warm_restart_secs\": {warm_secs:.6},\n  \
         \"warm_speedup\": {speedup:.3},\n  \
         \"warm_start_entries\": {},\n  \"recovered_bytes\": {},\n  \
         \"recovery_rejects\": {}\n}}\n",
        pm.warm_start_entries, pm.recovered_bytes, pm.recovery_rejects
    );
    std::fs::write(&out, &json).expect("write bench json");
    println!("\nwrote {out}");
    let _ = std::fs::remove_dir_all(&warm_dir);
    let _ = std::fs::remove_dir_all(&cold_dir);
}
