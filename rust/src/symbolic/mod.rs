//! Symbolic factorization analysis: elimination trees, postorders, exact
//! Cholesky column counts (Gilbert–Ng–Peyton), and the fill-in metric used
//! throughout the paper's evaluation (Tables 4.2 / 4.4).

pub mod colcount;
pub mod etree;

pub use colcount::{col_counts, nnz_l};
pub use etree::{etree, postorder};

use crate::graph::csr::SymGraph;
use crate::graph::perm::permute_graph;

/// Full symbolic analysis of `P A P^T` for a given ordering.
#[derive(Clone, Debug)]
pub struct SymbolicInfo {
    /// Elimination-tree parent of each (permuted) column, `-1` at roots.
    pub parent: Vec<i32>,
    /// Postorder of the elimination tree.
    pub post: Vec<i32>,
    /// nnz of each column of `L` (including the diagonal).
    pub counts: Vec<i64>,
    /// Total nnz(L) including the diagonal.
    pub nnz_l: i64,
    /// Fill-ins: nnz(L) minus nnz of the lower triangle of `A` (incl. diag).
    pub fill_in: i64,
    /// FLOPs for the numeric Cholesky factorization: Σ counts².
    pub flops: f64,
}

/// Analyze the ordering `perm` (AMD convention: `perm[k]` eliminated k-th)
/// applied to the symmetric pattern `g` (diagonal-free).
pub fn analyze(g: &SymGraph, perm: &[i32]) -> SymbolicInfo {
    let pg = permute_graph(g, perm);
    let parent = etree(&pg);
    let post = postorder(&parent);
    let counts = col_counts(&pg, &parent, &post);
    let nnz_l: i64 = counts.iter().sum();
    let lower_a = (g.nnz() / 2 + g.n) as i64;
    let flops = counts.iter().map(|&c| c as f64 * c as f64).sum();
    SymbolicInfo {
        parent,
        post,
        counts,
        nnz_l,
        fill_in: nnz_l - lower_a,
        flops,
    }
}

/// Convenience: just the fill-in count of an ordering.
pub fn fill_in(g: &SymGraph, perm: &[i32]) -> i64 {
    analyze(g, perm).fill_in
}

/// Reference fill-in computation by explicit elimination-graph simulation —
/// O(n²)-ish, used only as a test oracle on small graphs.
pub fn fill_in_naive(g: &SymGraph, perm: &[i32]) -> i64 {
    let n = g.n;
    let mut adj: Vec<std::collections::BTreeSet<i32>> = (0..n)
        .map(|v| g.neighbors(v).iter().cloned().collect())
        .collect();
    let mut eliminated = vec![false; n];
    let mut fill = 0i64;
    for &pv in perm {
        let p = pv as usize;
        let nbrs: Vec<i32> = adj[p]
            .iter()
            .cloned()
            .filter(|&u| !eliminated[u as usize])
            .collect();
        for (i, &a) in nbrs.iter().enumerate() {
            for &b in &nbrs[i + 1..] {
                if adj[a as usize].insert(b) {
                    adj[b as usize].insert(a);
                    fill += 1;
                }
            }
        }
        eliminated[p] = true;
    }
    fill
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{mesh2d, random_graph};
    use crate::util::rng::Rng;

    #[test]
    fn analyze_matches_naive_on_random_graphs() {
        for seed in 0..5 {
            let g = random_graph(60, 6, seed);
            let mut rng = Rng::new(seed + 100);
            let perm = rng.permutation(g.n);
            let info = analyze(&g, &perm);
            assert_eq!(info.fill_in, fill_in_naive(&g, &perm), "seed={seed}");
        }
    }

    #[test]
    fn analyze_matches_naive_on_mesh() {
        let g = mesh2d(7, 7);
        let id: Vec<i32> = (0..g.n as i32).collect();
        let info = analyze(&g, &id);
        assert_eq!(info.fill_in, fill_in_naive(&g, &id));
        // Natural ordering of a 7x7 5-pt grid is known to produce fill.
        assert!(info.fill_in > 0);
    }

    #[test]
    fn tree_graph_has_no_fill_with_leaf_ordering() {
        // A path graph eliminated end-to-start produces no fill.
        let n = 20;
        let edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
        let g = SymGraph::from_edges(n, &edges);
        let perm: Vec<i32> = (0..n as i32).collect();
        assert_eq!(fill_in(&g, &perm), 0);
        // nnz(L) = diagonal + one off-diagonal per non-root column.
        assert_eq!(analyze(&g, &perm).nnz_l, (2 * n - 1) as i64);
    }

    #[test]
    fn complete_graph_never_fills() {
        let n = 8;
        let mut edges = vec![];
        for i in 0..n {
            for j in i + 1..n {
                edges.push((i, j));
            }
        }
        let g = SymGraph::from_edges(n, &edges);
        let mut rng = Rng::new(5);
        let perm = rng.permutation(n);
        assert_eq!(fill_in(&g, &perm), 0);
    }

    #[test]
    fn empty_graph() {
        let g = SymGraph::from_edges(4, &[]);
        let perm: Vec<i32> = (0..4).collect();
        let info = analyze(&g, &perm);
        assert_eq!(info.fill_in, 0);
        assert_eq!(info.nnz_l, 4);
    }

    #[test]
    fn flops_positive_and_bounded() {
        let g = mesh2d(10, 10);
        let id: Vec<i32> = (0..g.n as i32).collect();
        let info = analyze(&g, &id);
        assert!(info.flops >= info.nnz_l as f64);
        assert!(info.flops <= (g.n as f64).powi(3));
    }
}
