//! The Layer-3 coordinator: an ordering/solve *service*.
//!
//! The paper's AMD use case is a pipeline stage inside a sparse direct
//! solver; this module packages the library as one deployable component:
//! a request queue, an ordering executor (ParAMD spawns its own thread
//! pool per request), and a dedicated **solver thread** that owns the
//! non-`Sync` PJRT engine and serves factor+solve requests over a channel.
//! Metrics (latency summaries, counters) are collected per method.

pub mod metrics;
pub mod request;

pub use metrics::Metrics;
pub use request::{Method, OrderReply, OrderRequest, SolveReply, SolveSpec};

use std::sync::mpsc;

use crate::cholesky::{self, DenseTail, NativeDense};
use crate::graph::symmetrize_parallel;
use crate::ordering::{
    amd_seq::AmdSeq, md::MinDegree, mmd::Mmd, paramd::ParAmd, Ordering as _, OrderingResult,
};
use crate::nd::NestedDissection;
use crate::symbolic;
use crate::util::timer::Timer;

/// The ordering service. Construct once, submit requests, read metrics.
pub struct Service {
    metrics: Metrics,
    /// Threads used for the symmetrization pre-processing (§4.2).
    pre_threads: usize,
    /// Dense-tail policy handed to the solver.
    tail: DenseTail,
    /// Channel to the dedicated PJRT solver thread (None = native only).
    solver: Option<SolverHandle>,
}

struct SolverHandle {
    tx: mpsc::Sender<SolveJob>,
    _thread: std::thread::JoinHandle<()>,
}

struct SolveJob {
    a: crate::graph::csr::CsrMatrix,
    perm: Vec<i32>,
    b: Vec<f64>,
    tail: DenseTail,
    reply: mpsc::Sender<Result<SolveReply, String>>,
}

impl Service {
    /// A service with the native dense engine only.
    pub fn new(pre_threads: usize) -> Self {
        Self {
            metrics: Metrics::default(),
            pre_threads: pre_threads.max(1),
            tail: DenseTail::default(),
            solver: None,
        }
    }

    /// Attach the PJRT-backed solver thread. The engine is created *on*
    /// the thread (its FFI handles are not `Sync`, DESIGN.md §4) from
    /// the given artifacts directory.
    pub fn with_pjrt_solver(mut self, artifacts_dir: std::path::PathBuf) -> Result<Self, String> {
        let (tx, rx) = mpsc::channel::<SolveJob>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize, String>>();
        let thread = std::thread::spawn(move || {
            let engine = match crate::runtime::PjrtEngine::load_dir(&artifacts_dir) {
                Ok(e) => {
                    let max = e
                        .sizes(crate::runtime::ArtifactKind::Chol)
                        .last()
                        .copied()
                        .unwrap_or(0);
                    let _ = ready_tx.send(Ok(max));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            let dense = crate::runtime::PjrtDense { engine: &engine };
            while let Ok(job) = rx.recv() {
                let out = solve_with(&job.a, &job.perm, &job.b, job.tail, &dense, "pjrt");
                let _ = job.reply.send(out);
            }
        });
        let max_tile = ready_rx
            .recv()
            .map_err(|e| e.to_string())?
            .map_err(|e| format!("pjrt solver init: {e}"))?;
        // Clamp the dense-tail policy to what the artifacts can factor.
        if let DenseTail::Auto { max, min_density } = self.tail {
            self.tail = DenseTail::Auto {
                max: max.min(max_tile),
                min_density,
            };
        }
        self.solver = Some(SolverHandle {
            tx,
            _thread: thread,
        });
        Ok(self)
    }

    pub fn with_tail(mut self, tail: DenseTail) -> Self {
        self.tail = tail;
        self
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Run an ordering request (synchronously; ParAMD parallelism happens
    /// inside). Includes the `|A| + |A^T|` pre-processing unless the
    /// request says the input is already symmetric (§4.2's advice).
    pub fn order(&mut self, req: &OrderRequest) -> OrderReply {
        let total = Timer::new();
        let tpre = Timer::new();
        let g = if let Some(g) = &req.pattern {
            g.clone()
        } else {
            symmetrize_parallel(req.matrix.as_ref().expect("matrix or pattern"), self.pre_threads)
        };
        let pre_secs = tpre.secs();

        let tord = Timer::new();
        let result: OrderingResult = match &req.method {
            Method::Amd => AmdSeq::default().order(&g),
            Method::Mmd => Mmd::default().order(&g),
            Method::MinDegree => MinDegree.order(&g),
            Method::Nd => NestedDissection::default().order(&g),
            Method::ParAmd {
                threads,
                mult,
                lim_total,
            } => ParAmd::new(*threads)
                .with_mult(*mult)
                .with_lim_total(*lim_total)
                .order(&g),
        };
        let order_secs = tord.secs();

        let fill = if req.compute_fill {
            Some(symbolic::fill_in(&g, &result.perm))
        } else {
            None
        };
        let reply = OrderReply {
            perm: result.perm,
            fill_in: fill,
            pre_secs,
            order_secs,
            total_secs: total.secs(),
            rounds: result.stats.rounds,
            gc_count: result.stats.gc_count,
            modeled_time: result.stats.modeled_time,
        };
        self.metrics
            .record(req.method.name(), reply.total_secs, reply.fill_in);
        reply
    }

    /// Order + factor + solve. Uses the PJRT solver thread when attached,
    /// otherwise the native dense engine inline.
    pub fn solve(&mut self, req: &OrderRequest, spec: &SolveSpec) -> Result<SolveReply, String> {
        let a = req
            .matrix
            .as_ref()
            .ok_or("solve requires an explicit matrix")?
            .clone();
        let ordered = self.order(req);
        let b = match spec {
            SolveSpec::OnesSolution => {
                let ones = vec![1.0; a.nrows];
                let mut b = vec![0.0; a.nrows];
                a.matvec(&ones, &mut b);
                b
            }
            other => other.rhs(a.nrows),
        };
        let t = Timer::new();
        let mut out = if let Some(handle) = &self.solver {
            let (reply_tx, reply_rx) = mpsc::channel();
            handle
                .tx
                .send(SolveJob {
                    a,
                    perm: ordered.perm.clone(),
                    b,
                    tail: self.tail,
                    reply: reply_tx,
                })
                .map_err(|e| e.to_string())?;
            reply_rx.recv().map_err(|e| e.to_string())??
        } else {
            solve_with(&a, &ordered.perm, &b, self.tail, &NativeDense, "native")?
        };
        out.order_secs = ordered.order_secs;
        out.pre_secs = ordered.pre_secs;
        out.total_secs = ordered.total_secs + t.secs();
        Ok(out)
    }
}

/// Shared solve path (used inline and on the solver thread).
fn solve_with(
    a: &crate::graph::csr::CsrMatrix,
    perm: &[i32],
    b: &[f64],
    tail: DenseTail,
    dense: &dyn crate::cholesky::DenseCholesky,
    engine: &'static str,
) -> Result<SolveReply, String> {
    let tfac = Timer::new();
    let f = cholesky::factor(a, perm, tail, dense)?;
    let factor_secs = tfac.secs();
    let tsol = Timer::new();
    let x = cholesky::solve(&f, b);
    let solve_secs = tsol.secs();
    let resid = cholesky::residual(a, &x, b);
    Ok(SolveReply {
        x,
        residual: resid,
        nnz_l: f.nnz_l,
        dense_tail_cols: f.perm.len() - f.split,
        factor_secs,
        solve_secs,
        engine,
        order_secs: 0.0,
        pre_secs: 0.0,
        total_secs: 0.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{laplacian_matrix, mesh2d, spd_from_graph};

    fn spd_request(method: Method) -> OrderRequest {
        OrderRequest {
            matrix: Some(spd_from_graph(&mesh2d(12, 12), 1.0)),
            pattern: None,
            method,
            compute_fill: true,
        }
    }

    #[test]
    fn order_via_every_method() {
        let mut svc = Service::new(2);
        for m in [
            Method::Amd,
            Method::Mmd,
            Method::Nd,
            Method::ParAmd {
                threads: 2,
                mult: 1.1,
                lim_total: 8192,
            },
        ] {
            let rep = svc.order(&spd_request(m));
            assert_eq!(rep.perm.len(), 144);
            assert!(rep.fill_in.unwrap() >= 0);
        }
        assert_eq!(svc.metrics().total_requests(), 4);
    }

    #[test]
    fn solve_native_end_to_end() {
        let mut svc = Service::new(1);
        let req = spd_request(Method::Amd);
        let rep = svc
            .solve(&req, &SolveSpec::OnesSolution)
            .expect("solve must succeed");
        assert!(rep.residual < 1e-10, "residual {:e}", rep.residual);
        // b was built from x = ones.
        for xi in &rep.x {
            assert!((xi - 1.0).abs() < 1e-8);
        }
        assert_eq!(rep.engine, "native");
    }

    #[test]
    fn solve_pjrt_end_to_end() {
        let svc = Service::new(1).with_pjrt_solver("artifacts".into());
        let mut svc = match svc {
            Ok(s) => s,
            Err(e) => panic!("pjrt solver init failed: {e} (run `make artifacts`)"),
        };
        let a = laplacian_matrix(10, 10);
        let req = OrderRequest {
            matrix: Some(a),
            pattern: None,
            method: Method::ParAmd {
                threads: 2,
                mult: 1.1,
                lim_total: 8192,
            },
            compute_fill: false,
        };
        let rep = svc.solve(&req, &SolveSpec::RandomRhs { seed: 3 }).unwrap();
        assert!(rep.residual < 1e-10, "residual {:e}", rep.residual);
        assert_eq!(rep.engine, "pjrt");
    }

    #[test]
    fn pattern_requests_skip_preprocessing() {
        let mut svc = Service::new(1);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(mesh2d(10, 10)),
            method: Method::Amd,
            compute_fill: false,
        };
        let rep = svc.order(&req);
        assert_eq!(rep.perm.len(), 100);
    }
}
