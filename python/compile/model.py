"""Layer-2 JAX model: dense factor/solve graphs over the Layer-1 kernel.

These functions are what `aot.py` lowers to HLO text; the Rust runtime
executes the artifacts on the request path (Python never runs there).
"""

import jax
import jax.numpy as jnp

from compile.kernels import chol_block


def cholesky_factor(a: jax.Array) -> tuple[jax.Array]:
    """Lower Cholesky factor of an SPD tile via the Pallas kernel."""
    return (chol_block.blocked_cholesky(a),)


def _forward_sub(l: jax.Array, b: jax.Array) -> jax.Array:
    """Solve L y = b by forward substitution (plain HLO ops; see
    kernels.chol_block._inv_lower for why triangular_solve is avoided)."""
    n = l.shape[0]

    def step(i, y):
        return y.at[i].set((b[i] - l[i] @ y) / l[i, i])

    return jax.lax.fori_loop(0, n, step, jnp.zeros_like(b))


def _backward_sub(l: jax.Array, b: jax.Array) -> jax.Array:
    """Solve Lᵀ x = b by backward substitution."""
    n = l.shape[0]

    def step(k, x):
        i = n - 1 - k
        return x.at[i].set((b[i] - l[:, i] @ x) / l[i, i])

    return jax.lax.fori_loop(0, n, step, jnp.zeros_like(b))


def cholesky_solve(a: jax.Array, b: jax.Array) -> tuple[jax.Array]:
    """Solve A x = b by factor + two triangular solves (fused into one
    HLO module with the kernel)."""
    (l,) = cholesky_factor(a)
    return (_backward_sub(l, _forward_sub(l, b)),)
