"""Layer-2 correctness: solve graph vs oracle, plus AOT lowering round-trip
(HLO text parses and is non-trivial)."""

import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_solve_matches_ref(seed):
    n = 64
    key = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(key)
    a = ref.random_spd(ka, n)
    b = jax.random.normal(kb, (n,), dtype=jnp.float32)
    (x,) = model.cholesky_solve(a, b)
    xref = ref.solve_ref(a, b)
    np.testing.assert_allclose(np.asarray(x), np.asarray(xref), rtol=2e-3, atol=2e-3)


def test_solve_residual_small():
    n = 96
    a = ref.random_spd(jax.random.PRNGKey(1), n)
    x_true = jnp.arange(n, dtype=jnp.float32) / n
    b = a @ x_true
    (x,) = model.cholesky_solve(a, b)
    rel = float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
    assert rel < 1e-4, rel


def test_factor_shapes():
    a = ref.random_spd(jax.random.PRNGKey(2), 32)
    (l,) = model.cholesky_factor(a)
    assert l.shape == (32, 32)
    assert l.dtype == a.dtype


@pytest.mark.parametrize("n", [32, 64])
def test_aot_lowering_produces_hlo_text(n):
    from compile import aot

    text = aot.lower_factor(n)
    assert text.startswith("HloModule"), text[:80]
    assert f"f64[{n},{n}]" in text
    text2 = aot.lower_solve(n)
    assert text2.startswith("HloModule")
    assert f"f64[{n}]" in text2


def test_aot_artifacts_deterministic():
    from compile import aot

    assert aot.lower_factor(32) == aot.lower_factor(32)
