//! Coordinator service demo: a stream of mixed ordering requests through
//! the `Service` queue with metrics reporting — the deployable-component
//! view of the library. The service owns one persistent ParAMD worker
//! pool and a pool of reusable arenas, so repeated ParAMD requests run
//! spawn-free and allocation-free (warm path); the final section shows
//! the warm-up effect on request latency.
//!
//! Run: `cargo run --release --example service_demo`

use paramd::coordinator::{Method, OrderRequest, Service, SolveSpec};
use paramd::matgen::{self, Scale};

fn main() {
    let svc = Service::new(2);
    let suite = matgen::suite();

    println!("== ordering requests ==");
    for i in 0..10 {
        let e = &suite[i % suite.len()];
        let g = (e.gen)(Scale::Tiny);
        let method = match i % 3 {
            0 => Method::Amd,
            1 => Method::ParAmd {
                threads: 4,
                mult: 1.1,
                lim_total: 8192,
            },
            _ => Method::Nd,
        };
        let rep = svc.order(&OrderRequest {
            matrix: Some(matgen::spd_from_graph(&g, 1.0)),
            pattern: None,
            method,
            compute_fill: true,
        });
        println!(
            "  {:<14} {:<7} n={:<6} {:.4}s fill={:.2e}",
            e.name,
            method.name(),
            rep.perm.len(),
            rep.total_secs,
            rep.fill_in.unwrap() as f64
        );
    }

    println!("\n== solve request (native dense tail) ==");
    let a = matgen::spd_from_graph(&(suite[0].gen)(Scale::Tiny), 1.0);
    let rep = svc
        .solve(
            &OrderRequest {
                matrix: Some(a),
                pattern: None,
                method: Method::ParAmd {
                    threads: 4,
                    mult: 1.1,
                    lim_total: 8192,
                },
                compute_fill: false,
            },
            &SolveSpec::OnesSolution,
        )
        .unwrap();
    println!(
        "  residual={:.2e} factor={:.3}s solve={:.3}s engine={}",
        rep.residual, rep.factor_secs, rep.solve_secs, rep.engine
    );

    println!("\n== warm path: repeated ParAMD requests on one graph ==");
    let g = (suite[0].gen)(Scale::Tiny);
    let warm_req = OrderRequest {
        matrix: None,
        pattern: Some(g.clone()),
        method: Method::ParAmd {
            threads: 4,
            mult: 1.1,
            lim_total: 8192,
        },
        compute_fill: false,
    };
    for i in 0..5 {
        let rep = svc.order(&warm_req);
        println!(
            "  request {i}: {:.5}s ({})",
            rep.order_secs,
            if i == 0 {
                "cold — arena sized here"
            } else {
                "warm — pooled arena, parked workers"
            }
        );
    }
    println!("  idle arenas pooled: {}", svc.idle_arenas());

    println!("\n== metrics ==\n{}", svc.metrics().report());
}
