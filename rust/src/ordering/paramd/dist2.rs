//! Parallel distance-2 independent set selection — Algorithm 3.2 of the
//! paper: a single iteration of the distance-2 analog of Luby's algorithm.
//!
//! Each thread gathers up to `lim` candidates from its local degree lists
//! within the `mult`-relaxed degree window, assigns each a random priority
//! `l(v) = (rand, v)`, resets `l_min` over `{v} ∪ N_v`, atomically
//! min-reduces the priorities over the same sets, and keeps `v` iff its
//! priority survived everywhere in its closed neighborhood. Two barriers
//! (provided by the driver) separate the reset / min / validate phases.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use super::lists::{Affinity, ThreadLists};
use super::shared::{SharedGraph, ST_ELEM, ST_VAR};
use super::workspace::Workspace;

/// Packed Luby priority: `(round_inv << 44) | (rand << 24) | v`.
///
/// The **inverted round number** in the top bits makes any `l_min` residue
/// from an earlier round compare *greater* than every priority of the
/// current round — i.e. stale entries act as +∞ — so the per-round
/// `l_min := ∞` reset pass (Alg 3.2 line 12) and its barrier disappear
/// entirely (EXPERIMENTS.md §Perf, change #2). Ties still break by index.
///
/// Layout: 20 bits inverted round | 20 bits random | 24 bits vertex.
pub const MAX_ROUNDS: u32 = (1 << 20) - 1;
pub const MAX_VERTICES: usize = 1 << 24;

#[inline]
pub fn priority(round: u32, rand: u32, v: usize) -> u64 {
    debug_assert!(round <= MAX_ROUNDS);
    debug_assert!(v < MAX_VERTICES);
    (((MAX_ROUNDS - round) as u64) << 44) | (((rand & 0xF_FFFF) as u64) << 24) | v as u64
}

/// Phase 1 (Alg 3.2 lines 4–9): gather candidates with approximate degree
/// in `[amd, floor(mult·amd)]` from this thread's lists, capped at `lim`.
/// `dmax` is the degree ceiling — the vertex count for ordinary runs, the
/// total column weight when seed supervariables are in play.
pub fn collect_candidates(
    lists: &mut ThreadLists,
    aff: &Affinity,
    ws: &mut Workspace,
    amd: usize,
    mult: f64,
    lim: usize,
    dmax: usize,
) {
    ws.candidates.clear();
    let hi = (((amd as f64) * mult).floor() as usize).min(dmax.saturating_sub(1));
    for d in amd..=hi {
        lists.get(aff, d, &mut ws.candidates);
        if ws.candidates.len() >= lim {
            ws.candidates.truncate(lim);
            break;
        }
    }
}

/// Enumerate the (closed) neighborhood of variable `v` in the current
/// quotient graph: `{v} ∪ A_v ∪ (∪_{e ∈ E_v} L_e)`, live entries only,
/// possibly with duplicates (harmless for idempotent min/reset updates).
pub fn closed_neighborhood(g: &SharedGraph, v: usize, out: &mut Vec<i32>, work: &mut u64) {
    out.clear();
    out.push(v as i32);
    let p = g.pe_of(v);
    let elen = g.elen_of(v) as usize;
    let len = g.len_of(v) as usize;
    *work += len as u64;
    for k in elen..len {
        let u = g.iw_at(p + k);
        if g.st(u as usize) == ST_VAR {
            out.push(u);
        }
    }
    for k in 0..elen {
        let e = g.iw_at(p + k) as usize;
        if g.st(e) != ST_ELEM {
            continue;
        }
        let ep = g.pe_of(e);
        let el = g.len_of(e) as usize;
        *work += el as u64;
        for q in 0..el {
            let u = g.iw_at(ep + q);
            if g.st(u as usize) == ST_VAR && u as usize != v {
                out.push(u);
            }
        }
    }
}

/// Phase 2 (lines 10–11): assign priorities and cache each candidate's
/// closed neighborhood. Fills `ws.prios`, aligned with `ws.candidates`.
///
/// Perf: the neighborhoods are enumerated **once** here and cached in the
/// workspace (`nbr_buf`/`nbr_ptr`) for the min and validate phases — the
/// quotient graph cannot change between the phases (barriers separate
/// them from any elimination), and the enumeration is ~half the selection
/// cost (EXPERIMENTS.md §Perf, change #1). The explicit `l_min := ∞`
/// reset of Alg 3.2 line 12 is subsumed by the round-stamped priorities
/// (see [`priority`], change #2). The priorities live in the reused
/// `ws.prios` buffer, so steady-state rounds allocate nothing.
pub fn luby_prepare(g: &SharedGraph, ws: &mut Workspace, round: u32, work: &mut u64) {
    let candidates = std::mem::take(&mut ws.candidates);
    let mut prios = std::mem::take(&mut ws.prios);
    prios.clear();
    ws.nbr_buf.clear();
    ws.nbr_ptr.clear();
    ws.nbr_ptr.push(0);
    for &vi in &candidates {
        let v = vi as usize;
        prios.push(priority(round, ws.rng.next_u32(), v));
        closed_neighborhood(g, v, &mut ws.nbrs, work);
        ws.nbr_buf.extend_from_slice(&ws.nbrs);
        ws.nbr_ptr.push(ws.nbr_buf.len());
    }
    ws.candidates = candidates;
    ws.prios = prios;
}

/// Phase 3 (lines 14–16): atomic min-reduction of each candidate's
/// priority (`ws.prios`) over its (cached) closed neighborhood.
pub fn luby_min(ws: &Workspace, lmin: &[AtomicU64], work: &mut u64) {
    for i in 0..ws.candidates.len() {
        let nbrs = &ws.nbr_buf[ws.nbr_ptr[i]..ws.nbr_ptr[i + 1]];
        *work += nbrs.len() as u64;
        for &u in nbrs {
            lmin[u as usize].fetch_min(ws.prios[i], Relaxed);
        }
    }
}

/// Phase 4 (lines 18–20): a candidate is valid iff its priority equals
/// `l_min` everywhere in its (cached) closed neighborhood. Fills
/// `ws.my_pivots`.
pub fn luby_validate(ws: &mut Workspace, lmin: &[AtomicU64], work: &mut u64) {
    let mut pivots = std::mem::take(&mut ws.my_pivots);
    pivots.clear();
    'cand: for i in 0..ws.candidates.len() {
        let nbrs = &ws.nbr_buf[ws.nbr_ptr[i]..ws.nbr_ptr[i + 1]];
        *work += nbrs.len() as u64;
        for &u in nbrs {
            if lmin[u as usize].load(Relaxed) != ws.prios[i] {
                continue 'cand;
            }
        }
        pivots.push(ws.candidates[i]);
    }
    ws.my_pivots = pivots;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::mesh2d;

    fn lmin_arr(n: usize) -> Vec<AtomicU64> {
        (0..n).map(|_| AtomicU64::new(u64::MAX)).collect()
    }

    /// Single-threaded end-to-end run of the four phases; checks the
    /// distance-2 property of the result on the initial quotient graph
    /// (where the elimination graph is the original graph).
    #[test]
    fn selected_set_is_distance2_independent() {
        let g0 = mesh2d(8, 8);
        let g = SharedGraph::new(&g0, 1.5);
        let aff = Affinity::new(g0.n);
        let mut lists = ThreadLists::new(0, g0.n);
        for v in 0..g0.n {
            lists.insert(&aff, v, g0.degree(v));
        }
        let mut ws = Workspace::new(0, g0.n, 1);
        let lmin = lmin_arr(g0.n);
        let mut work = 0u64;
        let amd = lists.lamd(&aff);
        collect_candidates(&mut lists, &aff, &mut ws, amd, 2.0, 10_000, g0.n);
        assert!(!ws.candidates.is_empty());
        luby_prepare(&g, &mut ws, 0, &mut work);
        assert_eq!(ws.prios.len(), ws.candidates.len());
        luby_min(&ws, &lmin, &mut work);
        luby_validate(&mut ws, &lmin, &mut work);
        let set: Vec<usize> = ws.my_pivots.iter().map(|&v| v as usize).collect();
        assert!(!set.is_empty(), "Luby round must select at least one pivot");
        // distance-2 check on the original mesh
        for (i, &a) in set.iter().enumerate() {
            for &b in &set[i + 1..] {
                assert!(!g0.neighbors(a).contains(&(b as i32)), "adjacent pivots");
                let common = g0
                    .neighbors(a)
                    .iter()
                    .filter(|x| g0.neighbors(b).contains(x))
                    .count();
                assert_eq!(common, 0, "pivots {a},{b} share a neighbor");
            }
        }
    }

    #[test]
    fn priority_ties_break_by_index() {
        assert!(priority(0, 5, 1) < priority(0, 5, 2));
        assert!(priority(0, 4, 9) < priority(0, 5, 0));
    }

    #[test]
    fn stale_rounds_read_as_infinity() {
        // Any priority of round r is smaller than any of round r-1.
        assert!(priority(1, 0xF_FFFF, (1 << 24) - 1) < priority(0, 0, 0));
        assert!(priority(7, 0, 0) < priority(6, 0xF_FFFF, 123));
    }

    #[test]
    fn candidate_window_respects_mult_and_lim() {
        let g0 = mesh2d(6, 6);
        let aff = Affinity::new(g0.n);
        let mut lists = ThreadLists::new(0, g0.n);
        for v in 0..g0.n {
            lists.insert(&aff, v, g0.degree(v));
        }
        let mut ws = Workspace::new(0, g0.n, 2);
        // amd = 2 (corners). mult = 1.0 → only degree-2 vertices.
        collect_candidates(&mut lists, &aff, &mut ws, 2, 1.0, 100, g0.n);
        assert_eq!(ws.candidates.len(), 4);
        // mult = 1.5 → degrees 2 and 3.
        collect_candidates(&mut lists, &aff, &mut ws, 2, 1.5, 100, g0.n);
        assert_eq!(ws.candidates.len(), 4 + 4 * 4);
        // lim caps the collection.
        collect_candidates(&mut lists, &aff, &mut ws, 2, 1.5, 7, g0.n);
        assert_eq!(ws.candidates.len(), 7);
    }

    #[test]
    fn closed_neighborhood_on_initial_graph() {
        let g0 = mesh2d(3, 3);
        let g = SharedGraph::new(&g0, 1.0);
        let mut out = vec![];
        let mut work = 0;
        closed_neighborhood(&g, 4, &mut out, &mut work);
        let mut got: Vec<i32> = out.clone();
        got.sort();
        assert_eq!(got, vec![1, 3, 4, 5, 7]);
        assert!(work > 0);
    }
}
