//! Mid-elimination re-reduction on the **live quotient graph** — the
//! round-boundary analogue of the parent module's pre-ordering rules.
//!
//! PR 4's reduction runs exactly once, up front; matrices become
//! twin-heavy and dense-row-heavy *as elimination proceeds*, and the
//! kernel's own supervariable detection
//! ([`crate::ordering::paramd::elim`]) only looks inside each pivot's
//! `L_me` — twins formed globally, across pivots, are never merged.
//! This module reuses the parent module's hash-nominate / exact-verify
//! shape directly on [`SharedGraph`] state so the ParAMD driver can run
//! it inside the stop-the-world round boundary (alongside GC, where
//! exclusive access is already guaranteed):
//!
//! - [`fingerprint_chunk`] — each worker thread fingerprints a vertex
//!   range of the live graph (commutative SplitMix64 sums over live
//!   adjacency, exactly like the parent's `fingerprints` scan but over
//!   quotient-graph element + variable lists instead of CSR rows);
//! - [`rereduce_exclusive`] — the leader thread then (a) absorbs
//!   elements whose live vertex list is a subset of another element's
//!   (shrinking every later Phase-2 set union, and — by erasing the
//!   lists' last differences — turning emergent twins into actual
//!   fingerprint twins), (b) merges verified global twins through the
//!   existing absorption forest (`parent`) with weighted `nv`
//!   bookkeeping, and (c) re-postpones variables whose live weighted
//!   degree crossed the dense threshold, pushing them to the
//!   permutation tail via the arena's postponed list.
//!
//! ## Why the merges are AMD-legal
//!
//! Twin merge: two live variables with identical live adjacency
//! (elements **and** variables, mutually excluded) are
//! indistinguishable supervariables — the same condition
//! `detect_supervariables` verifies locally — so folding `b` into `a`
//! (`nv[a] += nv[b]`, `b` dead, `parent[b] = a`) preserves the
//! elimination semantics; `a`'s stored degree stays a valid *upper
//! bound* (AMD degrees are approximate by contract) and the Ashcraft
//! bound is re-applied from live `nel`/`nv` at elimination time, so it
//! remains exact after merges. Element absorption: if the live vertex
//! list of `e` is contained in that of `f`, every clique edge `e`
//! implies is already implied by `f` and every member variable still
//! reaches `f` through its element list, so dropping `e` loses nothing
//! and only tightens degree approximations. Dense postponement: a
//! postponed variable is its own elimination root (parent stays `-1`,
//! `nv` kept, `nel += nv`), appended to the permutation tail by the
//! arena — the mid-run form of the parent module's
//! [`ReductionPlan`](super::ReductionPlan) tail accounting.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering::Relaxed};

use super::dense_threshold;
use crate::ordering::paramd::lists::Affinity;
use crate::ordering::paramd::shared::{
    SharedGraph, ST_DEAD_ELEM, ST_DEAD_VAR, ST_ELEM, ST_VAR,
};
use crate::ordering::paramd::workspace::Workspace;
use crate::util::rng::splitmix64;

/// The `α` of the mid-elimination dense threshold
/// `max(16, α·√live_n) × avg_live_weight` — the same SuiteSparse-style
/// default the pre-ordering pass uses. Degrees are compared in *average
/// live column weight* units so a uniformly-weighted run postpones
/// exactly the rows its unweighted counterpart would.
pub const MID_DENSE_ALPHA: f64 = 10.0;

/// Counters from one [`rereduce_exclusive`] sweep.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RereduceOutcome {
    /// Variables folded into a global twin representative.
    pub twins_merged: usize,
    /// Variables re-postponed to the permutation tail.
    pub dense_postponed: usize,
    /// Elements absorbed by a superset element (plus none for elements
    /// that simply ran out of live vertices — those are dropped
    /// silently, they carry no structure).
    pub elements_absorbed: usize,
}

/// The live entry at offset `k` of a variable's adjacency list
/// (elements first, then variables), or `None` for stale/dead entries.
#[inline]
fn live_entry(g: &SharedGraph, p: usize, k: usize, elen: usize) -> Option<usize> {
    let x = g.iw_at(p + k);
    debug_assert!(x >= 0, "adjacency entries are node ids");
    let xu = x as usize;
    let want = if k < elen { ST_ELEM } else { ST_VAR };
    (g.st(xu) == want).then_some(xu)
}

/// Fingerprint the live variables in `lo..hi`: `fp[v]` = commutative
/// SplitMix64 sum over `v`'s live adjacency (elements + variables —
/// they share one id space), `cnt[v]` = its live length. Non-variables
/// store zeros so stale values from an earlier sweep never leak.
/// Deterministic per vertex regardless of how the range is chunked
/// across threads.
pub fn fingerprint_chunk(
    g: &SharedGraph,
    lo: usize,
    hi: usize,
    fp: &[AtomicU64],
    cnt: &[AtomicU32],
) {
    for v in lo..hi {
        if g.st(v) != ST_VAR {
            fp[v].store(0, Relaxed);
            cnt[v].store(0, Relaxed);
            continue;
        }
        let p = g.pe_of(v);
        let el = g.elen_of(v) as usize;
        let ln = g.len_of(v) as usize;
        let (mut h, mut c) = (0u64, 0u32);
        for k in 0..ln {
            if let Some(x) = live_entry(g, p, k, el) {
                h = h.wrapping_add(splitmix64(x as u64));
                c += 1;
            }
        }
        fp[v].store(h, Relaxed);
        cnt[v].store(c, Relaxed);
    }
}

/// Exact live-adjacency twin test: the live entries of `a` excluding
/// `b` equal the live entries of `b` excluding `a`. Covers adjacent
/// ("true") and non-adjacent ("false") twins uniformly — for false
/// twins the exclusions are no-ops. Unlike the kernel's
/// `lists_identical` this skips dead entries and tolerates unequal raw
/// list lengths, which is exactly the state a mid-run quotient graph is
/// in. Hashes only nominate; this comparison is the ground truth.
fn live_twin_eq(g: &SharedGraph, ws: &mut Workspace, a: usize, b: usize) -> bool {
    let mark = ws.bump_epoch();
    let pa = g.pe_of(a);
    let ea = g.elen_of(a) as usize;
    let la = g.len_of(a) as usize;
    let mut ca = 0usize;
    for k in 0..la {
        if let Some(x) = live_entry(g, pa, k, ea) {
            if x != b && ws.w[x] != mark {
                ws.w[x] = mark;
                ca += 1;
            }
        }
    }
    let pb = g.pe_of(b);
    let eb = g.elen_of(b) as usize;
    let lb = g.len_of(b) as usize;
    let mut cb = 0usize;
    for k in 0..lb {
        if let Some(x) = live_entry(g, pb, k, eb) {
            if x != a {
                if ws.w[x] != mark {
                    return false;
                }
                cb += 1;
            }
        }
    }
    ca == cb
}

/// Sort `(hash, live_len, v)` keys, bucket by `(hash, live_len)`, and
/// merge every verified twin pair into the bucket's first still-live
/// variable — the quotient-graph mirror of the parent module's
/// `merge_twin_buckets`, writing the kernel's own merge protocol:
/// `nv[a] += nv[b]`, `b` dead, `parent[b] = a`, affinity cleared so
/// every thread's degree-list copy of `b` is lazily reclaimed.
fn merge_nominated(
    g: &SharedGraph,
    aff: &Affinity,
    ws: &mut Workspace,
    keys: &mut [(u64, u32, u32)],
) -> usize {
    keys.sort_unstable();
    let mut merged = 0usize;
    let mut i = 0;
    while i < keys.len() {
        let mut j = i + 1;
        while j < keys.len() && keys[j].0 == keys[i].0 && keys[j].1 == keys[i].1 {
            j += 1;
        }
        for ai in i..j {
            let a = keys[ai].2 as usize;
            if g.st(a) != ST_VAR {
                continue; // absorbed earlier in this sweep
            }
            for bi in ai + 1..j {
                let b = keys[bi].2 as usize;
                if g.st(b) == ST_VAR && live_twin_eq(g, ws, a, b) {
                    let w = g.nv_of(b);
                    g.nv[a].fetch_add(w, Relaxed);
                    g.nv[b].store(0, Relaxed);
                    g.set_st(b, ST_DEAD_VAR);
                    g.parent[b].store(a as i32, Relaxed);
                    aff.set(b, -1);
                    merged += 1;
                }
            }
        }
        i = j;
    }
    merged
}

/// One re-reduction sweep over the live quotient graph. **Stop-the-world
/// only**: the caller must guarantee every other worker is parked at a
/// barrier (the ParAMD driver runs this from the leader thread at the
/// round boundary, the same exclusion regime as
/// [`SharedGraph::garbage_collect_exclusive`]). `fp`/`cnt` must hold a
/// fresh [`fingerprint_chunk`] pass over `0..n`; `keys` and `postponed`
/// are caller-pooled scratch/output (postponed variables are appended —
/// the arena empties them into the elimination order's tail).
/// Deterministic for a fixed graph state.
pub fn rereduce_exclusive(
    g: &SharedGraph,
    aff: &Affinity,
    ws: &mut Workspace,
    fp: &[AtomicU64],
    cnt: &[AtomicU32],
    keys: &mut Vec<(u64, u32, u32)>,
    postponed: &mut Vec<i32>,
) -> RereduceOutcome {
    let n = g.n;
    let mut out = RereduceOutcome::default();

    // (a) Aggressive element absorption, FIRST — absorbing a subset
    // element is precisely what turns emergent twins into actual
    // fingerprint twins (their lists stop differing by the absorbed
    // element), so running it before nomination lets one sweep both
    // absorb and merge. `e` dies when another element `f` (found
    // through the first live member's element list — every absorber of
    // `e` must contain that member) covers all of `e`'s live vertices.
    // Each member's fingerprint is patched incrementally (the
    // commutative sum makes removal exact), so the twin pass below
    // nominates against post-absorption state. Elements with no live
    // vertex left carry no structure and are dropped outright.
    for e in 0..n {
        if g.st(e) != ST_ELEM {
            continue;
        }
        let pe = g.pe_of(e);
        let le = g.len_of(e) as usize;
        ws.lme.clear();
        for k in 0..le {
            let x = g.iw_at(pe + k) as usize;
            if g.st(x) == ST_VAR {
                ws.lme.push(x as i32);
            }
        }
        if ws.lme.is_empty() {
            g.set_st(e, ST_DEAD_ELEM);
            continue;
        }
        let needed = ws.lme.len();
        let v = ws.lme[0] as usize;
        let pv = g.pe_of(v);
        let ev = g.elen_of(v) as usize;
        for kf in 0..ev {
            let f = g.iw_at(pv + kf) as usize;
            if f == e || g.st(f) != ST_ELEM {
                continue;
            }
            // Mark e's live members, then count how many f covers;
            // clearing each mark as it is found makes duplicates in
            // L_f harmless (a member can count at most once).
            let mark = ws.bump_epoch();
            for &u in &ws.lme {
                ws.w[u as usize] = mark;
            }
            let pf = g.pe_of(f);
            let lf = g.len_of(f) as usize;
            let mut found = 0usize;
            for k in 0..lf {
                let u = g.iw_at(pf + k) as usize;
                if g.st(u) == ST_VAR && ws.w[u] == mark {
                    ws.w[u] = 0;
                    found += 1;
                }
            }
            if found == needed {
                g.set_st(e, ST_DEAD_ELEM);
                out.elements_absorbed += 1;
                // Patch the members' fingerprints: they no longer see e.
                for &u in &ws.lme {
                    fp[u as usize].fetch_sub(splitmix64(e as u64), Relaxed);
                    cnt[u as usize].fetch_sub(1, Relaxed);
                }
                break;
            }
        }
    }

    // (b) Global twin re-compression, two passes like the pre-ordering
    // rule: closed keys (`fp + h(v)` is invariant across an adjacent
    // twin class) then open keys for the remaining false twins.
    // Fingerprints of a merge survivor go stale the moment its twin
    // dies, but staleness is symmetric inside a class — every member
    // hashed the same now-dead neighbors — so nomination still
    // collides, and `live_twin_eq` re-checks against the *current*
    // graph before any merge; stale hashes can only miss merges, never
    // manufacture one. (Twin merges cannot create new element-subset
    // relations — exact twins share their whole element list — so
    // nothing is lost by not looping back to (a).)
    keys.clear();
    keys.extend((0..n).filter(|&v| g.st(v) == ST_VAR).map(|v| {
        let closed = fp[v].load(Relaxed).wrapping_add(splitmix64(v as u64));
        (closed, cnt[v].load(Relaxed), v as u32)
    }));
    out.twins_merged += merge_nominated(g, aff, ws, keys);
    keys.clear();
    keys.extend(
        (0..n)
            .filter(|&v| g.st(v) == ST_VAR)
            .map(|v| (fp[v].load(Relaxed), cnt[v].load(Relaxed), v as u32)),
    );
    out.twins_merged += merge_nominated(g, aff, ws, keys);

    // (c) Dense re-postponement, last — it must see post-merge
    // liveness. The cutoff is the pre-ordering threshold in units of
    // average live column weight (scale-invariant: a uniformly-weighted
    // run postpones exactly what its unweighted twin would), against
    // the live vertex count. Ascending (degree, v) order keeps the tail
    // least-dense-first and the sweep deterministic.
    let mut live_n = 0usize;
    for v in 0..n {
        if g.st(v) == ST_VAR {
            live_n += 1;
        }
    }
    if live_n > 0 {
        let live_weight = g.weight.saturating_sub(g.nel.load(Relaxed));
        let avg = (live_weight as f64 / live_n as f64).max(1.0);
        let thresh = dense_threshold(live_n, MID_DENSE_ALPHA) as f64 * avg;
        ws.hash_scratch.clear();
        for v in 0..n {
            if g.st(v) == ST_VAR && g.deg_of(v) as f64 > thresh {
                ws.hash_scratch.push((g.deg_of(v) as u64, v as i32));
            }
        }
        ws.hash_scratch.sort_unstable();
        for &(_, vi) in ws.hash_scratch.iter() {
            let v = vi as usize;
            // A postponed variable is its own root: parent stays -1,
            // nv is kept, and the arena appends it to the elimination
            // order's tail; `nel += nv` keeps the elimination target
            // and every later Ashcraft bound exact.
            g.set_st(v, ST_DEAD_VAR);
            g.nel.fetch_add(g.nv_of(v) as usize, Relaxed);
            aff.set(v, -1);
            postponed.push(vi);
            out.dense_postponed += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::SymGraph;

    fn scratch(n: usize) -> (Vec<AtomicU64>, Vec<AtomicU32>) {
        (
            (0..n).map(|_| AtomicU64::new(u64::MAX)).collect(),
            (0..n).map(|_| AtomicU32::new(u32::MAX)).collect(),
        )
    }

    fn sweep(g: &SharedGraph, aff: &Affinity, ws: &mut Workspace) -> (RereduceOutcome, Vec<i32>) {
        let (fp, cnt) = scratch(g.n);
        fingerprint_chunk(g, 0, g.n, &fp, &cnt);
        let mut keys = Vec::new();
        let mut postponed = Vec::new();
        let out = rereduce_exclusive(g, aff, ws, &fp, &cnt, &mut keys, &mut postponed);
        (out, postponed)
    }

    #[test]
    fn fingerprints_are_chunking_invariant() {
        let g = crate::matgen::mesh2d(6, 6);
        let sg = SharedGraph::new(&g, 1.0);
        let (f1, c1) = scratch(sg.n);
        fingerprint_chunk(&sg, 0, sg.n, &f1, &c1);
        let (f2, c2) = scratch(sg.n);
        fingerprint_chunk(&sg, 0, 13, &f2, &c2);
        fingerprint_chunk(&sg, 13, sg.n, &f2, &c2);
        for v in 0..sg.n {
            assert_eq!(f1[v].load(Relaxed), f2[v].load(Relaxed));
            assert_eq!(c1[v].load(Relaxed), c2[v].load(Relaxed));
        }
    }

    #[test]
    fn k4_collapses_to_one_weighted_supervariable() {
        // All four K4 vertices are pairwise (true) twins.
        let g = SymGraph::from_edges(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        let sg = SharedGraph::new(&g, 1.0);
        let aff = Affinity::new(4);
        let mut ws = Workspace::new(0, 4, 7);
        let (out, postponed) = sweep(&sg, &aff, &mut ws);
        assert_eq!(out.twins_merged, 3);
        assert_eq!(out.dense_postponed, 0);
        assert!(postponed.is_empty());
        assert_eq!(sg.st(0), ST_VAR);
        assert_eq!(sg.nv_of(0), 4, "class weight accumulates on the rep");
        for v in 1..4 {
            assert_eq!(sg.st(v), ST_DEAD_VAR);
            assert_eq!(sg.nv_of(v), 0);
            assert_eq!(sg.parent[v].load(Relaxed), 0, "forest points at the rep");
            assert_eq!(aff.get(v), -1, "degree-list copies invalidated");
        }
    }

    #[test]
    fn four_cycle_merges_both_false_twin_pairs() {
        let g = SymGraph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let sg = SharedGraph::new(&g, 1.0);
        let aff = Affinity::new(4);
        let mut ws = Workspace::new(0, 4, 7);
        let (out, _) = sweep(&sg, &aff, &mut ws);
        assert_eq!(out.twins_merged, 2, "both diagonals are false twins");
        assert_eq!(sg.st(0), ST_VAR);
        assert_eq!(sg.st(1), ST_VAR);
        assert_eq!(sg.parent[2].load(Relaxed), 0);
        assert_eq!(sg.parent[3].load(Relaxed), 1);
    }

    #[test]
    fn mesh_rows_are_not_twins() {
        let g = crate::matgen::mesh2d(5, 5);
        let sg = SharedGraph::new(&g, 1.0);
        let aff = Affinity::new(sg.n);
        let mut ws = Workspace::new(0, sg.n, 7);
        let (out, _) = sweep(&sg, &aff, &mut ws);
        assert_eq!(out, RereduceOutcome::default(), "a mesh is irreducible");
        assert!((0..sg.n).all(|v| sg.st(v) == ST_VAR));
    }

    #[test]
    fn subset_element_is_absorbed_and_its_members_merge() {
        // Hand-built quotient state over 5 nodes: element 0 with
        // L = {1,2}, element 4 with L = {1,2,3}; variables 1 and 2 see
        // exactly {e0, e4} (twins), variable 3 sees {e4}. Absorption
        // runs first and patches the members' fingerprints, so the twin
        // pass of the same sweep still nominates 1 and 2 correctly.
        let g = SymGraph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let sg = SharedGraph::new(&g, 4.0);
        let put = |node: usize, elems: &[i32], vars: &[i32]| {
            let off = sg.claim(elems.len() + vars.len()).unwrap();
            for (k, &x) in elems.iter().chain(vars.iter()).enumerate() {
                sg.iw_set(off + k, x);
            }
            sg.pe[node].store(off, Relaxed);
            sg.elen[node].store(elems.len() as i32, Relaxed);
            sg.len[node].store((elems.len() + vars.len()) as i32, Relaxed);
        };
        sg.set_st(0, ST_ELEM);
        put(0, &[], &[1, 2]); // element lists are all-vars (elen unused)
        sg.set_st(4, ST_ELEM);
        put(4, &[], &[1, 2, 3]);
        put(1, &[0, 4], &[]);
        put(2, &[0, 4], &[]);
        put(3, &[4], &[]);
        sg.nel.store(2, Relaxed); // the two pivots are eliminated
        let aff = Affinity::new(5);
        let mut ws = Workspace::new(0, 5, 7);
        let (out, postponed) = sweep(&sg, &aff, &mut ws);
        assert_eq!(out.twins_merged, 1, "vars 1 and 2 are quotient twins");
        assert_eq!(sg.st(2), ST_DEAD_VAR);
        assert_eq!(sg.nv_of(1), 2);
        assert_eq!(out.elements_absorbed, 1, "L_0 = {1} is inside L_4");
        assert_eq!(sg.st(0), ST_DEAD_ELEM);
        assert_eq!(sg.st(4), ST_ELEM, "the absorber survives");
        assert_eq!(out.dense_postponed, 0);
        assert!(postponed.is_empty());
    }

    #[test]
    fn absorption_turns_emergent_twins_into_merges_in_one_sweep() {
        // Vars 2 and 3 are NOT twins: both see the big element 0
        // (L = {2,3}) but each also sees a private singleton element
        // (1 = {2}, 5 = {3}) — the state left behind when their private
        // distinguishers were eliminated by different pivots. Absorbing
        // the singletons into element 0 erases the difference, and the
        // same sweep's twin pass (running on the patched fingerprints)
        // must then merge them.
        let g = SymGraph::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]);
        let sg = SharedGraph::new(&g, 4.0);
        let put = |node: usize, elems: &[i32], vars: &[i32]| {
            let off = sg.claim(elems.len() + vars.len()).unwrap();
            for (k, &x) in elems.iter().chain(vars.iter()).enumerate() {
                sg.iw_set(off + k, x);
            }
            sg.pe[node].store(off, Relaxed);
            sg.elen[node].store(elems.len() as i32, Relaxed);
            sg.len[node].store((elems.len() + vars.len()) as i32, Relaxed);
        };
        for e in [0usize, 1, 5] {
            sg.set_st(e, ST_ELEM);
        }
        put(0, &[], &[2, 3]);
        put(1, &[], &[2]);
        put(5, &[], &[3]);
        put(2, &[0, 1], &[]);
        put(3, &[0, 5], &[]);
        put(4, &[], &[]); // an unrelated isolated live variable
        sg.nel.store(3, Relaxed);
        let aff = Affinity::new(6);
        let mut ws = Workspace::new(0, 6, 7);
        let (out, _) = sweep(&sg, &aff, &mut ws);
        assert_eq!(out.elements_absorbed, 2, "both singletons fold into e0");
        assert_eq!(sg.st(1), ST_DEAD_ELEM);
        assert_eq!(sg.st(5), ST_DEAD_ELEM);
        assert_eq!(out.twins_merged, 1, "2 and 3 became twins mid-sweep");
        assert_eq!(sg.st(2), ST_VAR);
        assert_eq!(sg.st(3), ST_DEAD_VAR);
        assert_eq!(sg.parent[3].load(Relaxed), 2);
        assert_eq!(sg.nv_of(2), 2);
    }

    #[test]
    fn exhausted_element_is_dropped() {
        let g = SymGraph::from_edges(3, &[(0, 1), (1, 2)]);
        let sg = SharedGraph::new(&g, 2.0);
        sg.set_st(1, ST_ELEM); // its two "live vars" below are killed
        sg.set_st(0, ST_DEAD_VAR);
        sg.set_st(2, ST_DEAD_VAR);
        let aff = Affinity::new(3);
        let mut ws = Workspace::new(0, 3, 7);
        let (out, _) = sweep(&sg, &aff, &mut ws);
        assert_eq!(sg.st(1), ST_DEAD_ELEM, "no live vertex left");
        assert_eq!(out.elements_absorbed, 0, "drop, not absorption");
    }

    /// Hub-on-a-cycle: 151 vertices, the hub's live degree (150) tops
    /// `max(16, 10·√151) = 122`, every cycle vertex stays (degree 3).
    fn hub_on_cycle() -> SymGraph {
        let n = 150usize;
        let mut edges: Vec<(usize, usize)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        edges.extend((0..n).map(|i| (n, i)));
        SymGraph::from_edges(n + 1, &edges)
    }

    #[test]
    fn dense_hub_is_postponed_to_the_tail() {
        let g = hub_on_cycle();
        let sg = SharedGraph::new(&g, 1.0);
        let aff = Affinity::new(sg.n);
        aff.set(150, 0);
        let mut ws = Workspace::new(0, sg.n, 7);
        let (out, postponed) = sweep(&sg, &aff, &mut ws);
        assert_eq!(out.twins_merged, 0, "cycle neighborhoods are distinct");
        assert_eq!(out.dense_postponed, 1);
        assert_eq!(postponed, vec![150]);
        assert_eq!(sg.st(150), ST_DEAD_VAR);
        assert_eq!(sg.nv_of(150), 1, "a postponed root keeps its weight");
        assert_eq!(sg.parent[150].load(Relaxed), -1, "tail rows are roots");
        assert_eq!(sg.nel.load(Relaxed), 1, "the target advances by nv");
        assert_eq!(aff.get(150), -1);
    }

    #[test]
    fn dense_cutoff_is_invariant_under_uniform_weights() {
        // Uniform weight 5 scales every degree and the average alike:
        // the postponed set must be identical to the unweighted run.
        let g = hub_on_cycle();
        let mut sg = SharedGraph::empty();
        sg.reset_from_weighted(&g, 1.0, Some(&vec![5i32; g.n]));
        assert_eq!(sg.deg_of(150), 750, "weighted hub degree");
        let aff = Affinity::new(sg.n);
        let mut ws = Workspace::new(0, sg.n, 7);
        ws.set_epoch_stride(sg.weight);
        let (out, postponed) = sweep(&sg, &aff, &mut ws);
        assert_eq!(out.dense_postponed, 1);
        assert_eq!(postponed, vec![150]);
        assert_eq!(sg.nel.load(Relaxed), 5, "target advances by weighted nv");
    }

    #[test]
    fn sweep_is_deterministic() {
        let g = crate::matgen::twin_heavy(120, 4);
        let run = || {
            let sg = SharedGraph::new(&g, 1.0);
            let aff = Affinity::new(sg.n);
            let mut ws = Workspace::new(0, sg.n, 7);
            let (out, postponed) = sweep(&sg, &aff, &mut ws);
            let parents: Vec<i32> = sg.parent.iter().map(|p| p.load(Relaxed)).collect();
            (out, postponed, parents)
        };
        assert_eq!(run(), run());
    }
}
