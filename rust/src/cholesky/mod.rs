//! Sparse Cholesky solver — the end-to-end substrate standing in for the
//! paper's GPU solver (cuDSS, Tables 1.1 / 4.3).
//!
//! Pipeline: ordering → symbolic analysis (etree + column counts) →
//! up-looking numeric factorization (`cs_chol`-style) → triangular solves.
//!
//! The **dense trailing block** optimization connects the three layers:
//! AMD-style orderings leave a nearly-dense trailing submatrix; its Schur
//! complement is factored by a *dense* Cholesky kernel — either the native
//! fallback or the AOT-compiled JAX/Pallas executable loaded via PJRT
//! ([`crate::runtime`]). See DESIGN.md §3 (hardware adaptation).

pub mod dense;
pub mod numeric;
pub mod solve;

use crate::graph::csr::CsrMatrix;
use crate::graph::perm::invert_perm;
use crate::graph::symmetrize;
use crate::symbolic;

pub use dense::{DenseCholesky, NativeDense};
pub use numeric::CscFactor;

/// How to treat the trailing submatrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DenseTail {
    /// Pure simplicial sparse factorization.
    None,
    /// Choose the largest trailing region with symbolic density ≥ `min_density`,
    /// capped at `max` columns.
    Auto { max: usize, min_density: f64 },
    /// Fixed number of trailing columns.
    Fixed(usize),
}

impl Default for DenseTail {
    fn default() -> Self {
        DenseTail::Auto {
            max: 512,
            min_density: 0.5,
        }
    }
}

/// A factorized system `P A P^T = L L^T` ready to solve.
pub struct Factorization {
    pub l: CscFactor,
    /// `perm[k] = original column eliminated k-th`.
    pub perm: Vec<i32>,
    pub iperm: Vec<i32>,
    /// First column of the dense tail (== n when no tail).
    pub split: usize,
    /// nnz(L) actually stored.
    pub nnz_l: usize,
    /// Symbolic fill-in prediction (sparse; the dense tail may store more).
    pub predicted_nnz_l: i64,
}

/// Factor a symmetric positive definite matrix with a given ordering.
/// `dense_chol` factors the trailing Schur complement (native or PJRT).
pub fn factor(
    a: &CsrMatrix,
    perm: &[i32],
    tail: DenseTail,
    dense_chol: &dyn DenseCholesky,
) -> Result<Factorization, String> {
    let n = a.nrows;
    assert_eq!(a.ncols, n);
    assert_eq!(perm.len(), n);
    let g = symmetrize(a);
    let info = symbolic::analyze(&g, perm);
    let split = choose_split(n, &info.counts, tail);
    let l = numeric::factor_uplooking(a, perm, &info, split, dense_chol)?;
    let nnz_l = l.lp[n];
    Ok(Factorization {
        l,
        perm: perm.to_vec(),
        iperm: invert_perm(perm),
        split,
        nnz_l,
        predicted_nnz_l: info.nnz_l,
    })
}

/// Solve `A x = b` given a factorization (handles the permutation).
pub fn solve(f: &Factorization, b: &[f64]) -> Vec<f64> {
    let n = f.perm.len();
    assert_eq!(b.len(), n);
    // y = P b
    let mut y: Vec<f64> = (0..n).map(|k| b[f.perm[k] as usize]).collect();
    solve::lower_solve(&f.l, &mut y);
    solve::upper_solve(&f.l, &mut y);
    // x = P^T y
    let mut x = vec![0.0; n];
    for k in 0..n {
        x[f.perm[k] as usize] = y[k];
    }
    x
}

/// Relative residual `‖A x − b‖₂ / ‖b‖₂`.
pub fn residual(a: &CsrMatrix, x: &[f64], b: &[f64]) -> f64 {
    let mut ax = vec![0.0; a.nrows];
    a.matvec(x, &mut ax);
    let num: f64 = ax
        .iter()
        .zip(b)
        .map(|(axi, bi)| (axi - bi) * (axi - bi))
        .sum::<f64>()
        .sqrt();
    let den: f64 = b.iter().map(|v| v * v).sum::<f64>().sqrt();
    if den == 0.0 {
        num
    } else {
        num / den
    }
}

/// Pick the dense-tail split column from the symbolic column counts.
fn choose_split(n: usize, counts: &[i64], tail: DenseTail) -> usize {
    match tail {
        DenseTail::None => n,
        DenseTail::Fixed(m) => n - m.min(n),
        DenseTail::Auto { max, min_density } => {
            let lo = n.saturating_sub(max.min(n));
            // Find the smallest split ≥ lo whose tail is dense enough.
            let mut split = n;
            let mut tail_nnz: i64 = 0;
            let mut tail_cap: i64 = 0;
            for j in (lo..n).rev() {
                tail_nnz += counts[j];
                tail_cap += (n - j) as i64;
                let density = tail_nnz as f64 / tail_cap as f64;
                if density >= min_density {
                    split = j;
                }
            }
            // A tail of fewer than 8 columns isn't worth a kernel launch.
            if n - split < 8 {
                n
            } else {
                split
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{laplacian_matrix, mesh2d, spd_from_graph};
    use crate::ordering::{amd_seq::AmdSeq, Ordering as _};
    use crate::util::rng::Rng;

    fn check_solve(a: &CsrMatrix, tail: DenseTail) {
        let g = symmetrize(a);
        let perm = AmdSeq::default().order(&g).perm;
        let f = factor(a, &perm, tail, &NativeDense).unwrap();
        let n = a.nrows;
        let mut rng = Rng::new(42);
        let x_true: Vec<f64> = (0..n).map(|_| rng.f64() * 2.0 - 1.0).collect();
        let mut b = vec![0.0; n];
        a.matvec(&x_true, &mut b);
        let x = solve(&f, &b);
        let r = residual(a, &x, &b);
        assert!(r < 1e-10, "residual {r:e} (tail={tail:?})");
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8, "solution mismatch");
        }
    }

    #[test]
    fn solves_laplacian_simplicial() {
        let a = laplacian_matrix(12, 12);
        check_solve(&a, DenseTail::None);
    }

    #[test]
    fn solves_laplacian_with_dense_tail() {
        let a = laplacian_matrix(12, 12);
        check_solve(&a, DenseTail::Fixed(40));
        check_solve(&a, DenseTail::default());
    }

    #[test]
    fn dense_tail_matches_simplicial_factor_values() {
        let a = laplacian_matrix(8, 8);
        let g = symmetrize(&a);
        let perm = AmdSeq::default().order(&g).perm;
        let f1 = factor(&a, &perm, DenseTail::None, &NativeDense).unwrap();
        let f2 = factor(&a, &perm, DenseTail::Fixed(20), &NativeDense).unwrap();
        // Compare as dense matrices (the CSC layouts differ).
        let n = a.nrows;
        let to_dense = |f: &Factorization| {
            let mut d = vec![0.0; n * n];
            for j in 0..n {
                for p in f.l.lp[j]..f.l.lp[j + 1] {
                    d[f.l.li[p] as usize * n + j] = f.l.lx[p];
                }
            }
            d
        };
        let d1 = to_dense(&f1);
        let d2 = to_dense(&f2);
        for (v1, v2) in d1.iter().zip(&d2) {
            assert!((v1 - v2).abs() < 1e-9, "{v1} vs {v2}");
        }
    }

    #[test]
    fn identity_permutation_works() {
        let a = laplacian_matrix(6, 6);
        let id: Vec<i32> = (0..a.nrows as i32).collect();
        let f = factor(&a, &id, DenseTail::None, &NativeDense).unwrap();
        let b = vec![1.0; a.nrows];
        let x = solve(&f, &b);
        assert!(residual(&a, &x, &b) < 1e-10);
    }

    #[test]
    fn rejects_indefinite() {
        // -I is symmetric but not positive definite.
        let trip: Vec<(usize, usize, f64)> = (0..4).map(|i| (i, i, -1.0)).collect();
        let a = CsrMatrix::from_triplets(4, 4, &trip);
        let id: Vec<i32> = (0..4).collect();
        assert!(factor(&a, &id, DenseTail::None, &NativeDense).is_err());
    }

    #[test]
    fn nnz_matches_symbolic_prediction_when_simplicial() {
        let a = spd_from_graph(&mesh2d(9, 9), 1.0);
        let g = symmetrize(&a);
        let perm = AmdSeq::default().order(&g).perm;
        let f = factor(&a, &perm, DenseTail::None, &NativeDense).unwrap();
        assert_eq!(f.nnz_l as i64, f.predicted_nnz_l);
    }

    #[test]
    fn split_selection() {
        // counts for a fully dense 10-col factor.
        let counts: Vec<i64> = (0..10).map(|j| 10 - j).collect();
        let s = choose_split(
            10,
            &counts,
            DenseTail::Auto {
                max: 10,
                min_density: 0.9,
            },
        );
        assert_eq!(s, 0, "fully dense factor should go all-dense");
        assert_eq!(choose_split(10, &counts, DenseTail::None), 10);
        assert_eq!(choose_split(10, &counts, DenseTail::Fixed(4)), 6);
    }
}
