//! Request/reply types of the ordering service, plus the per-submission
//! scheduling attributes (priority lane, request-carried deadline,
//! caller identity for quotas).

use std::time::{Duration, Instant};

use crate::graph::csr::{CsrMatrix, SymGraph};
use crate::ordering::RoundSample;
use crate::util::rng::Rng;

/// Priority lane of a submission. Interactive requests overtake batch
/// requests in the pipeline queue *and* in every shard's job queue —
/// priority changes service order, never how much the service buffers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Lane {
    /// Latency-sensitive traffic: served before any queued batch work.
    Interactive,
    /// Throughput traffic (the default): drained FIFO behind interactive.
    #[default]
    Batch,
}

impl Lane {
    /// Queue-array index: interactive lane first.
    pub(crate) fn index(self) -> usize {
        match self {
            Lane::Interactive => 0,
            Lane::Batch => 1,
        }
    }
}

/// Per-submission scheduling attributes, all optional: the lane, a
/// request-carried deadline (checked at every pipeline stage boundary
/// and, via the abort flag, between elimination rounds), and a caller
/// name for per-caller token quotas. `Default` is a batch-lane request
/// with no deadline and no caller identity.
#[derive(Clone, Debug, Default)]
pub struct SubmitOptions {
    pub lane: Lane,
    /// Absolute deadline; expired work resolves the ticket to
    /// [`OrderError::DeadlineExceeded`](super::OrderError::DeadlineExceeded).
    pub deadline: Option<Instant>,
    /// Caller identity for admission quotas (`None` = unmetered).
    pub caller: Option<String>,
}

impl SubmitOptions {
    /// An interactive-lane submission.
    pub fn interactive() -> Self {
        Self {
            lane: Lane::Interactive,
            ..Self::default()
        }
    }

    pub fn with_lane(mut self, lane: Lane) -> Self {
        self.lane = lane;
        self
    }

    /// Set an absolute deadline.
    pub fn with_deadline(mut self, at: Instant) -> Self {
        self.deadline = Some(at);
        self
    }

    /// Set a deadline `budget` from now.
    pub fn with_deadline_in(self, budget: Duration) -> Self {
        self.with_deadline(Instant::now() + budget)
    }

    /// Attribute the submission to `caller` for quota accounting.
    pub fn with_caller(mut self, caller: impl Into<String>) -> Self {
        self.caller = Some(caller.into());
        self
    }
}

/// Which ordering algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    /// Sequential AMD (the SuiteSparse baseline).
    Amd,
    /// The paper's parallel AMD.
    ParAmd {
        threads: usize,
        mult: f64,
        lim_total: usize,
    },
    /// Multiple minimum degree (Liu 1985).
    Mmd,
    /// Exact minimum degree (oracle; small inputs only).
    MinDegree,
    /// Multilevel nested dissection.
    Nd,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Amd => "amd",
            Method::ParAmd { .. } => "paramd",
            Method::Mmd => "mmd",
            Method::MinDegree => "md",
            Method::Nd => "nd",
        }
    }

    /// Parse `amd | paramd | mmd | md | nd` with ParAMD parameters.
    pub fn parse(s: &str, threads: usize, mult: f64, lim_total: usize) -> Option<Method> {
        match s {
            "amd" => Some(Method::Amd),
            "paramd" => Some(Method::ParAmd {
                threads,
                mult,
                lim_total,
            }),
            "mmd" => Some(Method::Mmd),
            "md" => Some(Method::MinDegree),
            "nd" => Some(Method::Nd),
            _ => None,
        }
    }
}

/// An ordering request: either a numeric matrix (symmetrized by the
/// service, as SuiteSparse AMD always does — §4.2) or an explicit
/// symmetric pattern (skipping pre-processing, the paper's advice for
/// known-symmetric inputs).
#[derive(Clone, Debug)]
pub struct OrderRequest {
    pub matrix: Option<CsrMatrix>,
    pub pattern: Option<SymGraph>,
    pub method: Method,
    /// Compute exact #fill-ins (costs a symbolic analysis).
    pub compute_fill: bool,
}

impl OrderRequest {
    /// Problem size (vertex count) — the scheduling weight used by
    /// smallest-first queue policies. `0` when neither input is set.
    pub fn n(&self) -> usize {
        self.pattern
            .as_ref()
            .map(|g| g.n)
            .or_else(|| self.matrix.as_ref().map(|m| m.nrows))
            .unwrap_or(0)
    }
}

/// Ordering reply.
#[derive(Clone, Debug)]
pub struct OrderReply {
    pub perm: Vec<i32>,
    pub fill_in: Option<i64>,
    pub pre_secs: f64,
    pub order_secs: f64,
    pub total_secs: f64,
    pub rounds: u64,
    pub gc_count: u64,
    /// Cumulative stop-the-world seconds spent in quotient-graph GC.
    pub gc_secs: f64,
    pub modeled_time: f64,
    /// Per-round elimination samples of the request's dominant live
    /// ParAMD run (the Fig-4 decay curve); empty for non-ParAMD methods
    /// and cache replays.
    pub round_samples: Vec<RoundSample>,
}

/// Right-hand-side specification for solve requests.
#[derive(Clone, Debug)]
pub enum SolveSpec {
    /// b := A·1 (exact solution = ones; good for validation).
    OnesSolution,
    /// Uniform random b.
    RandomRhs { seed: u64 },
    /// Explicit b.
    Explicit(Vec<f64>),
}

impl SolveSpec {
    pub(crate) fn rhs(&self, n: usize) -> Vec<f64> {
        match self {
            // OnesSolution needs the matrix (b = A·1); the service
            // computes it before reaching here.
            SolveSpec::OnesSolution => unreachable!("handled by Service::solve"),
            SolveSpec::RandomRhs { seed } => {
                let mut rng = Rng::new(*seed);
                (0..n).map(|_| rng.f64() * 2.0 - 1.0).collect()
            }
            SolveSpec::Explicit(b) => b.clone(),
        }
    }
}

/// Reply of a solve request.
#[derive(Clone, Debug)]
pub struct SolveReply {
    pub x: Vec<f64>,
    pub residual: f64,
    pub nnz_l: usize,
    pub dense_tail_cols: usize,
    pub factor_secs: f64,
    pub solve_secs: f64,
    pub engine: &'static str,
    pub order_secs: f64,
    pub pre_secs: f64,
    pub total_secs: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("amd", 1, 1.1, 8192), Some(Method::Amd));
        assert_eq!(
            Method::parse("paramd", 4, 1.2, 100),
            Some(Method::ParAmd {
                threads: 4,
                mult: 1.2,
                lim_total: 100
            })
        );
        assert!(Method::parse("bogus", 1, 1.0, 1).is_none());
    }

    #[test]
    fn rhs_shapes() {
        assert_eq!(SolveSpec::RandomRhs { seed: 1 }.rhs(5).len(), 5);
        assert_eq!(SolveSpec::Explicit(vec![1.0, 2.0]).rhs(2), vec![1.0, 2.0]);
    }
}
