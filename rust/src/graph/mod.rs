//! Sparse-graph substrate: CSR symmetric graphs, Matrix Market I/O, the
//! parallel `|A| + |A^T|` symmetrization pre-processing step (paper §4.2),
//! connected-component decomposition, structural fingerprints, and
//! permutation utilities.

pub mod components;
pub mod csr;
pub mod fingerprint;
pub mod mm;
pub mod perm;
pub mod symmetrize;

pub use components::{connected_components, split_components, Component, Components};
pub use csr::{CsrMatrix, SymGraph};
pub use fingerprint::{fingerprint, Fingerprint};
pub use perm::{compose, invert_perm, is_valid_perm, permute_graph};
pub use symmetrize::{symmetrize, symmetrize_parallel};
