//! Critical-path cost model for simulated thread scaling (DESIGN.md §7).
//!
//! This testbed has a single physical core, so wall-clock speedups of a
//! 64-thread run are meaningless; what *is* machine-independent is the
//! per-round per-thread work distribution, which the engine records in
//! [`super::workspace::RoundWork`]. The model charges each round the
//! maximum per-thread work (the parallel critical path) plus a fixed
//! barrier cost, and reports
//!
//! ```text
//! speedup = Σ_r Σ_tid work(r, tid)  /  Σ_r (max_tid work(r, tid) + β)
//! ```
//!
//! i.e. ideal-work-over-critical-path — the same quantity a perfectly
//! memory-neutral 64-core machine would realize, degraded by imbalance and
//! round-synchronization exactly as the paper's Figure 4.1/4.2 analysis
//! describes (small distance-2 sets ⇒ idle threads ⇒ poor scaling).

use super::workspace::RoundWork;

/// Default per-round synchronization cost in work units (5 barriers per
/// round on real hardware, each O(µs); expressed relative to the ~ns-scale
/// per-word work counter).
pub const DEFAULT_BARRIER_COST: f64 = 2000.0;

/// Work-over-critical-path speedup for a recorded run.
/// `round_work[r][tid]`; returns 1.0 for degenerate inputs.
pub fn model_speedup(round_work: &[Vec<RoundWork>], barrier_cost: f64) -> f64 {
    let mut total = 0.0f64;
    let mut critical = 0.0f64;
    for round in round_work {
        let mut max_w = 0u64;
        for w in round {
            let wsum = w.select + w.elim;
            total += wsum as f64;
            max_w = max_w.max(wsum);
        }
        critical += max_w as f64 + barrier_cost;
    }
    if critical <= 0.0 || total <= 0.0 {
        return 1.0;
    }
    (total / critical).max(1.0 / 1e9)
}

/// Modeled wall-clock for `t` threads given a measured single-thread
/// throughput (`work_per_sec`) and a recorded `t`-thread work log.
pub fn modeled_time(round_work: &[Vec<RoundWork>], work_per_sec: f64, barrier_secs: f64) -> f64 {
    if work_per_sec <= 0.0 {
        return 0.0;
    }
    round_work
        .iter()
        .map(|round| {
            let max_w = round.iter().map(|w| w.select + w.elim).max().unwrap_or(0);
            max_w as f64 / work_per_sec + barrier_secs
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rw(select: u64, elim: u64) -> RoundWork {
        RoundWork {
            select,
            elim,
            pivots: 0,
        }
    }

    #[test]
    fn perfectly_balanced_rounds_scale_linearly() {
        // 4 threads, each 1000 units per round, no barrier cost:
        let log = vec![vec![rw(500, 500); 4]; 10];
        let s = model_speedup(&log, 0.0);
        assert!((s - 4.0).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn imbalance_caps_speedup() {
        // One thread does everything: speedup 1 regardless of t.
        let mut round = vec![rw(0, 0); 8];
        round[3] = rw(1000, 1000);
        let log = vec![round; 5];
        let s = model_speedup(&log, 0.0);
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn barrier_cost_degrades_small_rounds() {
        let log = vec![vec![rw(10, 10); 4]; 100];
        let no_bar = model_speedup(&log, 0.0);
        let with_bar = model_speedup(&log, 100.0);
        assert!(with_bar < no_bar);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(model_speedup(&[], 0.0), 1.0);
        let log = vec![vec![rw(0, 0); 2]];
        assert_eq!(model_speedup(&log, 10.0), 1.0);
    }

    #[test]
    fn modeled_time_sane() {
        let log = vec![vec![rw(1000, 0); 2]; 3];
        let t = modeled_time(&log, 1000.0, 0.001);
        assert!((t - (3.0 + 0.003)).abs() < 1e-9);
    }
}
