//! Result-cache integration: hit-vs-cold bit-match, forged-fingerprint
//! verify-reject, LRU eviction under a tiny byte cap, cross-request
//! component sharing under scattered labels, and a concurrent-hit
//! stress through the full service pipeline — including the acceptance
//! criterion that a cache hit performs **zero** ParAMD work (the shard
//! runtimes' job counters must not move for a repeated request).

use paramd::coordinator::{Method, Metrics, OrderRequest, Service};
use paramd::graph::csr::SymGraph;
use paramd::graph::fingerprint::fingerprint;
use paramd::graph::perm::is_valid_perm;
use paramd::matgen::{mesh2d, repeated_components_seeded};
use paramd::ordering::cache::{CacheKey, CachedOrdering, ResultCache};

fn paramd_req(g: SymGraph) -> OrderRequest {
    OrderRequest {
        matrix: None,
        pattern: Some(g),
        method: Method::ParAmd {
            threads: 1,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    }
}

fn shard_jobs(m: &Metrics) -> u64 {
    m.shards.per_shard.iter().map(|s| s.jobs).sum()
}

#[test]
fn hot_hit_bitmatches_the_cold_run_with_zero_paramd_work() {
    let svc = Service::new(1);
    let req = paramd_req(mesh2d(16, 16));
    let cold = svc.order(&req);
    let jobs_after_cold = shard_jobs(&svc.metrics());
    assert!(jobs_after_cold >= 1, "the cold run must order for real");
    for _ in 0..3 {
        let hot = svc.order(&req);
        assert_eq!(hot.perm, cold.perm, "hot hit must bit-match the cold run");
        assert_eq!(hot.rounds, cold.rounds);
        assert_eq!(hot.gc_count, cold.gc_count);
    }
    let m = svc.metrics();
    assert_eq!(
        shard_jobs(&m),
        jobs_after_cold,
        "acceptance: a cache hit performs zero ParAMD work"
    );
    assert_eq!(m.cache.hits, 3);
    assert_eq!(m.pipeline.completed, 4, "every request still gets a reply");
}

#[test]
fn forged_fingerprint_verify_rejects_into_a_correct_miss() {
    // Simulate a full 128-bit fingerprint collision by inserting graph
    // A's result under its key and probing with a different graph B
    // under that same key: the exact CSR compare must reject, the probe
    // must register as a miss, and nothing must be corrupted.
    let cache = ResultCache::with_shards(1 << 20, 1);
    let a = mesh2d(9, 9);
    let b = mesh2d(9, 10); // same archetype family, different structure
    assert_ne!(fingerprint(&a), fingerprint(&b), "honest keys differ");
    let key_a = CacheKey::new(&a, None, 42);
    cache.insert(
        key_a,
        a.clone(),
        None,
        CachedOrdering {
            perm: (0..a.n as i32).collect(),
            rounds: 1,
            gc_count: 0,
            gc_secs: 0.0,
            modeled_time: 0.0,
            set_sizes: vec![a.n as u32],
            reduced: 0,
        },
    );
    assert!(
        cache.get(&key_a, &b, None).is_none(),
        "forged probe must fall through to a miss, never return A's perm"
    );
    let m = cache.metrics();
    assert_eq!(m.verify_rejects, 1);
    assert_eq!(m.misses, 1);
    assert_eq!(m.hits, 0);
    // The honest owner of the key is still served.
    let honest = cache.get(&key_a, &a, None).expect("entry intact");
    assert_eq!(honest.perm.len(), a.n);
}

#[test]
fn lru_eviction_respects_a_tiny_byte_cap_through_the_service() {
    // A cache that holds one mesh entry but not two: alternating two
    // graphs keeps evicting, so repeats are misses again — and the
    // budget is never exceeded.
    let svc = Service::new(1).with_result_cache(8 << 10);
    let g1 = mesh2d(14, 14);
    let g2 = mesh2d(14, 15);
    for _ in 0..2 {
        svc.order(&paramd_req(g1.clone()));
        svc.order(&paramd_req(g2.clone()));
    }
    let m = svc.metrics();
    assert!(m.cache.evictions > 0, "the cap must force evictions");
    assert!(
        m.cache.bytes <= m.cache.budget_bytes,
        "residency {} exceeds budget {}",
        m.cache.bytes,
        m.cache.budget_bytes
    );
    assert_eq!(shard_jobs(&m), 4, "every evicted repeat re-orders");
}

#[test]
fn scattered_label_requests_share_component_entries() {
    // The cache's target workload: distinct requests whose whole-graph
    // CSRs differ (different scatter seeds) but whose components are
    // identical. The second request must be served entirely from the
    // component cache — zero new shard jobs.
    let svc = Service::new(1).with_shards(2).with_shard_threads(1);
    let first = svc.order(&paramd_req(repeated_components_seeded(3, 40, 2, 1)));
    assert!(is_valid_perm(&first.perm));
    let jobs_cold = shard_jobs(&svc.metrics());
    assert_eq!(jobs_cold, 6, "six components order cold");

    let second = svc.order(&paramd_req(repeated_components_seeded(3, 40, 2, 2)));
    assert!(is_valid_perm(&second.perm));
    assert_eq!(second.perm.len(), first.perm.len());
    let m = svc.metrics();
    assert_eq!(
        shard_jobs(&m),
        jobs_cold,
        "a scattered repeat must not touch the runtimes"
    );
    assert_eq!(m.cache.hits, 6, "every component of the repeat hits");
    assert!(m.cache.saved_secs >= 0.0);
}

#[test]
fn hybrid_knobs_are_part_of_the_request_cache_identity() {
    // A request-level entry bakes the hybrid outcome into its stored
    // permutation, so configs differing only in hybrid knobs must miss
    // each other — and the *same* knobs must still hit.
    use paramd::coordinator::HybridConfig;
    let g = mesh2d(40, 40);
    let depth2 = HybridConfig {
        enabled: true,
        partition_threshold: 500,
        recursion_depth: 2,
        balance_factor: 1.5,
    };

    let svc = Service::new(1).with_hybrid(HybridConfig {
        recursion_depth: 1,
        ..depth2
    });
    svc.order(&paramd_req(g.clone()));
    let jobs_d1 = shard_jobs(&svc.metrics());

    // Deeper recursion: a different partition, must re-order.
    let svc = svc.with_hybrid(depth2);
    let at_depth2 = svc.order(&paramd_req(g.clone()));
    let jobs_d2 = shard_jobs(&svc.metrics());
    assert!(jobs_d2 > jobs_d1, "a deeper recursion must miss, not replay");

    // Hybrid off: the plain single-job path, again a distinct identity.
    let svc = svc.with_hybrid(HybridConfig::disabled());
    svc.order(&paramd_req(g.clone()));
    let jobs_off = shard_jobs(&svc.metrics());
    assert!(jobs_off > jobs_d2, "toggling hybrid off must miss too");

    // Back to depth 2: the warm entry for those exact knobs replays.
    let svc = svc.with_hybrid(depth2);
    let replay = svc.order(&paramd_req(g.clone()));
    assert_eq!(replay.perm, at_depth2.perm, "same knobs must bit-match");
    assert_eq!(
        shard_jobs(&svc.metrics()),
        jobs_off,
        "the depth-2 replay must dispatch zero jobs"
    );
}

#[test]
fn stress_8_submitters_hit_concurrently_through_the_pipeline() {
    let svc = Service::new(2)
        .with_shards(2)
        .with_shard_threads(1)
        .with_scheduler_threads(4);
    let g = mesh2d(18, 18);
    // Warm the entry once, then hammer it from 8 threads.
    let warm = svc.order(&paramd_req(g.clone()));
    let jobs_after_warm = shard_jobs(&svc.metrics());
    std::thread::scope(|s| {
        for _ in 0..8 {
            let svc = &svc;
            let warm = &warm;
            let g = &g;
            s.spawn(move || {
                for _ in 0..4 {
                    let rep = svc.order(&paramd_req(g.clone()));
                    assert_eq!(rep.perm, warm.perm, "concurrent hit diverged");
                }
            });
        }
    });
    let m = svc.metrics();
    assert_eq!(m.cache.hits, 32, "all 32 repeats must hit");
    assert_eq!(m.cache.verify_rejects, 0);
    assert_eq!(
        shard_jobs(&m),
        jobs_after_warm,
        "32 concurrent hits must perform zero ParAMD work"
    );
    assert_eq!(m.pipeline.completed, 33);
}
