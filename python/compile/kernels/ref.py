"""Pure-jnp correctness oracles for the Layer-1 kernels."""

import jax
import jax.numpy as jnp


def cholesky_ref(a: jax.Array) -> jax.Array:
    """Reference lower Cholesky factor (XLA's built-in)."""
    return jnp.linalg.cholesky(a)


def solve_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Reference SPD solve."""
    return jnp.linalg.solve(a, b)


def random_spd(key, n: int, dtype=jnp.float32) -> jax.Array:
    """Well-conditioned random SPD matrix: B·Bᵀ + n·I."""
    b = jax.random.normal(key, (n, n), dtype=dtype)
    return b @ b.T + n * jnp.eye(n, dtype=dtype)
