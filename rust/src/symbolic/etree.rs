//! Elimination tree and postorder (Liu's algorithm, as in CSparse).

use crate::graph::csr::SymGraph;

/// Elimination tree of the (already permuted) symmetric pattern `pg`.
/// `parent[k]` is the etree parent of column `k`, or `-1` for roots.
///
/// Uses path compression through an `ancestor` array; entries with `i >= k`
/// are skipped so the full symmetric pattern can be passed directly.
pub fn etree(pg: &SymGraph) -> Vec<i32> {
    let n = pg.n;
    let mut parent = vec![-1i32; n];
    let mut ancestor = vec![-1i32; n];
    for k in 0..n {
        for &iv in pg.neighbors(k) {
            let mut i = iv;
            // Traverse from i up to the root of its current subtree, doing
            // path compression; stop when reaching k's territory.
            while i != -1 && (i as usize) < k {
                let inext = ancestor[i as usize];
                ancestor[i as usize] = k as i32;
                if inext == -1 {
                    parent[i as usize] = k as i32;
                }
                i = inext;
            }
        }
    }
    parent
}

/// Postorder of a forest given as a parent array. Children are visited in
/// increasing node order (deterministic).
pub fn postorder(parent: &[i32]) -> Vec<i32> {
    let n = parent.len();
    // Build first-child / next-sibling lists. Iterating nodes in *reverse*
    // and pushing to the head yields children linked in increasing order.
    let mut head = vec![-1i32; n];
    let mut next = vec![-1i32; n];
    for j in (0..n).rev() {
        let p = parent[j];
        if p != -1 {
            next[j] = head[p as usize];
            head[p as usize] = j as i32;
        }
    }
    let mut post = Vec::with_capacity(n);
    let mut stack: Vec<i32> = Vec::new();
    for root in 0..n {
        if parent[root] != -1 {
            continue;
        }
        stack.push(root as i32);
        while let Some(&top) = stack.last() {
            let child = head[top as usize];
            if child == -1 {
                post.push(top);
                stack.pop();
            } else {
                head[top as usize] = next[child as usize];
                stack.push(child);
            }
        }
    }
    post
}

/// Depth of each node in the etree (roots at depth 0). Useful to reason
/// about factorization parallelism (ND vs AMD comparison, §4.6).
pub fn etree_depths(parent: &[i32]) -> Vec<u32> {
    let n = parent.len();
    let mut depth = vec![u32::MAX; n];
    for mut j in 0..n {
        let mut path = Vec::new();
        while depth[j] == u32::MAX {
            path.push(j);
            if parent[j] == -1 {
                depth[j] = 0;
                break;
            }
            j = parent[j] as usize;
        }
        let base = depth[j];
        for (k, &v) in path.iter().rev().enumerate() {
            if depth[v] == u32::MAX {
                depth[v] = base + k as u32;
            }
        }
    }
    // Fix up: path recorded nodes bottom-up; recompute cleanly.
    let mut depth2 = vec![u32::MAX; n];
    fn dep(j: usize, parent: &[i32], depth: &mut [u32]) -> u32 {
        if depth[j] != u32::MAX {
            return depth[j];
        }
        let d = if parent[j] == -1 {
            0
        } else {
            dep(parent[j] as usize, parent, depth) + 1
        };
        depth[j] = d;
        d
    }
    for j in 0..n {
        dep(j, parent, &mut depth2);
    }
    depth2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::SymGraph;

    #[test]
    fn etree_of_path_graph() {
        // Path 0-1-2-3 with natural order: parent chain i -> i+1.
        let g = SymGraph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert_eq!(etree(&g), vec![1, 2, 3, -1]);
    }

    #[test]
    fn etree_of_star() {
        // Star centered at 3 (eliminated last): all leaves point to... fill
        // chain: eliminating 0 connects nothing (deg-1), parent[0]=3, etc.
        let g = SymGraph::from_edges(4, &[(0, 3), (1, 3), (2, 3)]);
        assert_eq!(etree(&g), vec![3, 3, 3, -1]);
    }

    #[test]
    fn etree_dense_is_chain() {
        let mut edges = vec![];
        for i in 0..5 {
            for j in i + 1..5 {
                edges.push((i, j));
            }
        }
        let g = SymGraph::from_edges(5, &edges);
        assert_eq!(etree(&g), vec![1, 2, 3, 4, -1]);
    }

    #[test]
    fn postorder_is_valid() {
        let parent = vec![2i32, 2, 4, 4, -1, -1]; // two trees: {0,1,2,3,4}, {5}
        let post = postorder(&parent);
        assert_eq!(post.len(), 6);
        // Every child appears before its parent.
        let pos: Vec<usize> = {
            let mut pos = vec![0; 6];
            for (i, &v) in post.iter().enumerate() {
                pos[v as usize] = i;
            }
            pos
        };
        for (j, &p) in parent.iter().enumerate() {
            if p != -1 {
                assert!(pos[j] < pos[p as usize]);
            }
        }
    }

    #[test]
    fn postorder_handles_empty_forest() {
        let parent = vec![-1i32; 3];
        let post = postorder(&parent);
        assert_eq!(post, vec![0, 1, 2]);
    }

    #[test]
    fn depths() {
        let parent = vec![2i32, 2, 4, 4, -1];
        let d = etree_depths(&parent);
        assert_eq!(d, vec![2, 2, 1, 1, 0]);
    }
}
