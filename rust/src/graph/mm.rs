//! Matrix Market (.mtx) reader/writer.
//!
//! Supports the `matrix coordinate` format with `real | integer | pattern`
//! fields and `general | symmetric | skew-symmetric` symmetries — the
//! subset covering the SuiteSparse Matrix Collection files the paper uses.

use std::io::{BufRead, BufReader, Write as _};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::csr::CsrMatrix;

/// Parsed header of a Matrix Market file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MmSymmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

/// Read a Matrix Market coordinate file into a [`CsrMatrix`].
/// Symmetric/skew storage is expanded to full storage.
pub fn read_matrix_market(path: &Path) -> Result<CsrMatrix> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut reader = BufReader::new(f);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let header: Vec<String> = line.trim().split_whitespace().map(|s| s.to_lowercase()).collect();
    if header.len() < 5 || header[0] != "%%matrixmarket" || header[1] != "matrix" {
        bail!("not a MatrixMarket matrix file: {line:?}");
    }
    if header[2] != "coordinate" {
        bail!("only coordinate format supported, got {}", header[2]);
    }
    let field = header[3].as_str();
    if !matches!(field, "real" | "integer" | "pattern") {
        bail!("unsupported field type {field}");
    }
    let sym = match header[4].as_str() {
        "general" => MmSymmetry::General,
        "symmetric" => MmSymmetry::Symmetric,
        "skew-symmetric" => MmSymmetry::SkewSymmetric,
        s => bail!("unsupported symmetry {s}"),
    };

    // Skip comments, read size line.
    let (nrows, ncols, nnz) = loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("missing size line");
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let nr: usize = it.next().context("nrows")?.parse()?;
        let nc: usize = it.next().context("ncols")?.parse()?;
        let nz: usize = it.next().context("nnz")?.parse()?;
        break (nr, nc, nz);
    };

    let mut triplets: Vec<(usize, usize, f64)> = Vec::with_capacity(
        nnz * if sym == MmSymmetry::General { 1 } else { 2 },
    );
    let mut count = 0usize;
    while count < nnz {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("unexpected EOF: read {count} of {nnz} entries");
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = it.next().context("row")?.parse::<usize>()? - 1;
        let c: usize = it.next().context("col")?.parse::<usize>()? - 1;
        let v: f64 = if field == "pattern" {
            1.0
        } else {
            it.next().context("value")?.parse()?
        };
        if r >= nrows || c >= ncols {
            bail!("entry ({},{}) out of bounds {}x{}", r + 1, c + 1, nrows, ncols);
        }
        triplets.push((r, c, v));
        if r != c {
            match sym {
                MmSymmetry::Symmetric => triplets.push((c, r, v)),
                MmSymmetry::SkewSymmetric => triplets.push((c, r, -v)),
                MmSymmetry::General => {}
            }
        }
        count += 1;
    }
    Ok(CsrMatrix::from_triplets(nrows, ncols, &triplets))
}

/// Write a matrix in `general real coordinate` format.
pub fn write_matrix_market(path: &Path, m: &CsrMatrix) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = std::io::BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by paramd")?;
    writeln!(w, "{} {} {}", m.nrows, m.ncols, m.nnz())?;
    for r in 0..m.nrows {
        for p in m.rowptr[r]..m.rowptr[r + 1] {
            writeln!(w, "{} {} {:.17e}", r + 1, m.colind[p] + 1, m.values[p])?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("paramd_mm_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_general() {
        let m = CsrMatrix::from_triplets(
            3,
            3,
            &[(0, 0, 1.5), (0, 2, -2.0), (1, 1, 3.0), (2, 0, 4.0)],
        );
        let p = tmp("rt.mtx");
        write_matrix_market(&p, &m).unwrap();
        let m2 = read_matrix_market(&p).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn symmetric_expansion() {
        let p = tmp("sym.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n3 3 3\n1 1 2.0\n2 1 5.0\n3 2 7.0\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.nnz(), 5);
        assert!(m.is_pattern_symmetric());
        assert_eq!(m.row(0), &[0, 1]);
        assert_eq!(m.row_values(1), &[5.0, 7.0]);
    }

    #[test]
    fn pattern_field() {
        let p = tmp("pat.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate pattern general\n% comment\n2 2 2\n1 2\n2 1\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.row_values(0), &[1.0]);
    }

    #[test]
    fn skew_symmetric() {
        let p = tmp("skew.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real skew-symmetric\n2 2 1\n2 1 3.0\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.row_values(0), &[-3.0]);
        assert_eq!(m.row_values(1), &[3.0]);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("bad.mtx");
        std::fs::write(&p, "hello world\n").unwrap();
        assert!(read_matrix_market(&p).is_err());
    }

    #[test]
    fn rejects_out_of_bounds() {
        let p = tmp("oob.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n",
        )
        .unwrap();
        assert!(read_matrix_market(&p).is_err());
    }
}
