//! Wall-clock timing helpers used by the benchmark harness and the
//! coordinator's metrics.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Clone, Debug)]
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn restart(&mut self) {
        self.start = Instant::now();
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::new();
    let out = f();
    (out, t.secs())
}

/// An accumulating phase timer: named buckets of seconds, used for the
/// paper's Figure 4.1 runtime breakdown.
#[derive(Clone, Debug, Default)]
pub struct PhaseTimes {
    entries: Vec<(String, f64)>,
}

impl PhaseTimes {
    pub fn add(&mut self, phase: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == phase) {
            e.1 += secs;
        } else {
            self.entries.push((phase.to_string(), secs));
        }
    }

    pub fn get(&self, phase: &str) -> f64 {
        self.entries
            .iter()
            .find(|(n, _)| n == phase)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.entries.iter().map(|(_, s)| s).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.entries.iter().map(|(n, s)| (n.as_str(), *s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::new();
        let a = t.secs();
        let b = t.secs();
        assert!(b >= a);
    }

    #[test]
    fn time_returns_value() {
        let (v, s) = time(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn phase_times_accumulate() {
        let mut p = PhaseTimes::default();
        p.add("select", 1.0);
        p.add("core", 2.0);
        p.add("select", 0.5);
        assert_eq!(p.get("select"), 1.5);
        assert_eq!(p.get("core"), 2.0);
        assert_eq!(p.get("missing"), 0.0);
        assert!((p.total() - 3.5).abs() < 1e-12);
    }
}
