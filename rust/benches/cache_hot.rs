//! Result-cache hot-hit vs cold-miss latency on the cache's target
//! workload: requests sharing identical components under scattered
//! vertex labels (`matgen::repeated_components_seeded`).
//!
//! Three measurements, same request stream:
//!
//! - **cold** — the stream on a cache-disabled twin service: full
//!   split + reduce + route + order + stitch per request (a true
//!   no-cache baseline — nearby archetypes share kernels after leaf
//!   stripping, so even a first pass with the cache on is partly hot).
//! - **hot (components)** — the identical stream with the cache on and
//!   warmed: whole-graph CSRs differ per scatter seed, but every
//!   component probe hits, so the shards do zero ParAMD work.
//! - **hot (request)** — an exact repeat of one connected request,
//!   served by the whole-request probe before reduction even runs.
//!
//! The acceptance bar is hot-hit latency ≥ 10× lower than the cold
//! miss. Writes the JSON trajectory file `BENCH_cache_hot.json`
//! (override with `PARAMD_BENCH_CACHE_OUT`; default lands in the
//! repository root when run via `cargo bench` from `rust/`).
//!
//! Knobs: `PARAMD_THREADS` (default 8), `PARAMD_REPS` (default 10), or
//! `--smoke` for a quick CI pass.

#[path = "bench_common/mod.rs"]
#[allow(dead_code)] // shared helper module; this bench uses a subset
mod bench_common;

use paramd::coordinator::{Method, OrderRequest, Service};
use paramd::graph::csr::SymGraph;
use paramd::matgen::{mesh2d, repeated_components_seeded};
use paramd::util::timer::Timer;

fn paramd_req(g: SymGraph) -> OrderRequest {
    OrderRequest {
        matrix: None,
        pattern: Some(g),
        method: Method::ParAmd {
            threads: 4,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    }
}

fn main() {
    bench_common::banner(
        "Result cache — hot-hit vs cold-miss ordering latency",
        "ISSUE 5 perf subsystem; not a paper table",
    );
    let smoke = std::env::args().any(|a| a == "--smoke");
    let threads = bench_common::threads();
    let reps: usize = if smoke {
        3
    } else {
        std::env::var("PARAMD_REPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(10)
    };
    let (k, n, copies) = if smoke { (4, 500, 3) } else { (6, 4000, 4) };
    let mesh_side = if smoke { 40 } else { 120 };

    // The exact request stream both laps replay: one component
    // population, scattered differently per request (pre-built so the
    // timers measure ordering, not graph generation).
    let reqs = |seed0: u64| -> Vec<OrderRequest> {
        (0..reps)
            .map(|i| paramd_req(repeated_components_seeded(k, n, copies, seed0 + i as u64)))
            .collect()
    };

    // Cold: the same workload on a cache-disabled twin service — a true
    // no-cache baseline (components of nearby archetypes share kernels
    // after leaf stripping, so a cache-enabled "first pass" would
    // already be partially hot).
    let cold_svc = Service::new(2)
        .with_shards(2)
        .with_order_threads(threads)
        .with_scheduler_threads(2)
        .with_result_cache(0);
    let cold_reqs = reqs(1);
    cold_svc.order(&paramd_req(repeated_components_seeded(k, n, copies, 0))); // warm arenas
    let t = Timer::new();
    for req in &cold_reqs {
        let rep = cold_svc.order(req);
        assert!(!rep.perm.is_empty());
    }
    let cold_secs = t.secs() / reps as f64;
    drop(cold_svc);

    let svc = Service::new(2)
        .with_shards(2)
        .with_order_threads(threads)
        .with_scheduler_threads(2);

    // Hot (components): identical request stream, cache on, entries
    // filled by the seed-0 warm-up — every component probe hits.
    svc.order(&paramd_req(repeated_components_seeded(k, n, copies, 0)));
    let hot_reqs = reqs(1);
    let t = Timer::new();
    for req in &hot_reqs {
        let rep = svc.order(req);
        assert!(!rep.perm.is_empty());
    }
    let hot_comp_secs = t.secs() / reps as f64;

    // Hot (request): an exact connected repeat short-circuits before
    // reduction even runs.
    let mesh = mesh2d(mesh_side, mesh_side);
    svc.order(&paramd_req(mesh.clone()));
    let t = Timer::new();
    for _ in 0..reps {
        let rep = svc.order(&paramd_req(mesh.clone()));
        assert_eq!(rep.perm.len(), mesh.n);
    }
    let hot_req_secs = t.secs() / reps as f64;

    let speedup = cold_secs / hot_comp_secs.max(1e-12);
    let m = svc.metrics();
    println!(
        "{:<18} {:>12} {:>14}",
        "mode", "latency(s)", "vs cold"
    );
    println!("{:<18} {:>12.5} {:>14}", "cold miss", cold_secs, "1.00x");
    println!(
        "{:<18} {:>12.5} {:>13.1}x",
        "hot (components)", hot_comp_secs, speedup
    );
    println!(
        "{:<18} {:>12.5} {:>13.1}x",
        "hot (request)",
        hot_req_secs,
        cold_secs / hot_req_secs.max(1e-12)
    );
    println!(
        "cache: hits={} misses={} rejects={} entries={} bytes={} saved~={:.3}s",
        m.cache.hits,
        m.cache.misses,
        m.cache.verify_rejects,
        m.cache.entries,
        m.cache.bytes,
        m.cache.saved_secs
    );
    if speedup < 10.0 {
        eprintln!("WARNING: hot-hit speedup {speedup:.1}x below the 10x acceptance bar");
    }

    let out = std::env::var("PARAMD_BENCH_CACHE_OUT")
        .unwrap_or_else(|_| "../BENCH_cache_hot.json".into());
    let json = format!(
        "{{\n  \"bench\": \"cache_hot\",\n  \"status\": \"measured\",\n  \
         \"threads\": {threads},\n  \"reps\": {reps},\n  \
         \"workload\": \"repeated_components(k={k}, n={n}, copies={copies})\",\n  \
         \"acceptance\": \"hot-hit latency >= 10x lower than cold miss\",\n  \
         \"cold_miss_secs\": {cold_secs:.6},\n  \
         \"hot_component_hit_secs\": {hot_comp_secs:.6},\n  \
         \"hot_request_hit_secs\": {hot_req_secs:.6},\n  \
         \"hot_speedup\": {speedup:.3},\n  \
         \"cache_hits\": {},\n  \"cache_misses\": {},\n  \
         \"cache_verify_rejects\": {}\n}}\n",
        m.cache.hits, m.cache.misses, m.cache.verify_rejects
    );
    std::fs::write(&out, &json).expect("write bench json");
    println!("\nwrote {out}");
}
