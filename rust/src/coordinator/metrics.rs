//! Per-method service metrics: request counts, latency summaries,
//! fill-in accumulation.

use crate::util::stats;

/// One method's accumulated numbers.
#[derive(Clone, Debug, Default)]
pub struct MethodMetrics {
    pub requests: u64,
    pub latencies: Vec<f64>,
    pub total_fill: i64,
}

impl MethodMetrics {
    pub fn mean_latency(&self) -> f64 {
        stats::mean(&self.latencies)
    }

    pub fn p95_latency(&self) -> f64 {
        stats::percentile(&self.latencies, 95.0)
    }
}

/// Service-wide metrics keyed by method name.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    entries: Vec<(String, MethodMetrics)>,
}

impl Metrics {
    pub fn record(&mut self, method: &str, latency_secs: f64, fill: Option<i64>) {
        let e = match self.entries.iter_mut().find(|(m, _)| m == method) {
            Some((_, e)) => e,
            None => {
                self.entries
                    .push((method.to_string(), MethodMetrics::default()));
                &mut self.entries.last_mut().unwrap().1
            }
        };
        e.requests += 1;
        e.latencies.push(latency_secs);
        e.total_fill += fill.unwrap_or(0);
    }

    pub fn get(&self, method: &str) -> Option<&MethodMetrics> {
        self.entries.iter().find(|(m, _)| m == method).map(|(_, e)| e)
    }

    pub fn total_requests(&self) -> u64 {
        self.entries.iter().map(|(_, e)| e.requests).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &MethodMetrics)> {
        self.entries.iter().map(|(m, e)| (m.as_str(), e))
    }

    /// Render a compact report.
    pub fn report(&self) -> String {
        let mut s = String::from("method     reqs   mean(s)    p95(s)\n");
        for (m, e) in self.iter() {
            s.push_str(&format!(
                "{:<10} {:<6} {:<10.4} {:<10.4}\n",
                m,
                e.requests,
                e.mean_latency(),
                e.p95_latency()
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::default();
        m.record("amd", 0.5, Some(100));
        m.record("amd", 1.5, Some(200));
        m.record("paramd", 0.1, None);
        assert_eq!(m.total_requests(), 3);
        let amd = m.get("amd").unwrap();
        assert_eq!(amd.requests, 2);
        assert!((amd.mean_latency() - 1.0).abs() < 1e-12);
        assert_eq!(amd.total_fill, 300);
        assert!(m.report().contains("paramd"));
        assert!(m.get("nope").is_none());
    }
}
