//! Sparse-graph substrate: CSR symmetric graphs, Matrix Market I/O, the
//! parallel `|A| + |A^T|` symmetrization pre-processing step (paper §4.2),
//! and permutation utilities.

pub mod csr;
pub mod mm;
pub mod perm;
pub mod symmetrize;

pub use csr::{CsrMatrix, SymGraph};
pub use perm::{compose, invert_perm, is_valid_perm, permute_graph};
pub use symmetrize::{symmetrize, symmetrize_parallel};
