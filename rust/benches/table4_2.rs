//! Table 4.2: the headline comparison — sequential AMD vs ParAMD over the
//! matrix suite, five shared random input permutations per matrix
//! (decoupling tie-breaking, §2.5.4): ordering time mean ± std, speedup,
//! #fill-ins, fill ratio.
//!
//! On this 1-core testbed the honest wall-clock of a multi-thread run is
//! meaningless, so the "speedup" column uses the critical-path cost model
//! (DESIGN.md §7) evaluated on the recorded per-round work distribution
//! of the t-thread run; 1-thread wall-clock is also reported.

#[path = "bench_common/mod.rs"]
mod bench_common;

use paramd::bench_util::{fmt_sci, Table};
use paramd::matgen;
use paramd::ordering::{amd_seq::AmdSeq, paramd::ParAmd, Ordering as _};
use paramd::symbolic::fill_in;
use paramd::util::stats;
use paramd::util::timer::Timer;

fn main() {
    let t = bench_common::threads();
    bench_common::banner("Table 4.2 — ordering comparison", "paper §4.3 Table 4.2");
    let mut table = Table::new(&[
        "Matrix",
        "Seq (s)",
        "ParAMD wall (s)",
        "Model speedup",
        "Fill seq",
        "Fill par",
        "Ratio",
    ]);
    for e in matgen::suite() {
        let g0 = (e.gen)(bench_common::scale());
        let perms = bench_common::random_permutations(&g0, 5);
        let mut seq_times = vec![];
        let mut par_times = vec![];
        let mut speedups = vec![];
        let mut fill_seq = vec![];
        let mut fill_par = vec![];
        for g in &perms {
            let timer = Timer::new();
            let rs = AmdSeq::default().order(g);
            seq_times.push(timer.secs());
            fill_seq.push(fill_in(g, &rs.perm) as f64);

            let timer = Timer::new();
            let (rp, d) = ParAmd::new(t).order_detailed(g);
            par_times.push(timer.secs());
            speedups.push(d.model_speedup);
            fill_par.push(fill_in(g, &rp.perm) as f64);
        }
        table.row(vec![
            e.name.into(),
            format!("{:.3} ± {:.3}", stats::mean(&seq_times), stats::std_dev(&seq_times)),
            format!("{:.3} ± {:.3}", stats::mean(&par_times), stats::std_dev(&par_times)),
            format!("{:.2}x", stats::mean(&speedups)),
            fmt_sci(stats::mean(&fill_seq)),
            fmt_sci(stats::mean(&fill_par)),
            format!("{:.2}x", stats::mean(&fill_par) / stats::mean(&fill_seq)),
        ]);
    }
    table.print();
    println!(
        "\npaper (64t, EPYC 7763): speedups 3.18–7.29x, fill ratios 1.01–1.19x.\n\
         Expected shape here: fill ratio ≈ 1.0–1.4x; model speedup grows with\n\
         avg D2-set size (mini_nd24k worst, mini_nlpkkt/flan best)."
    );
}
