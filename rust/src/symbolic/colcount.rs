//! Exact Cholesky column counts via the Gilbert–Ng–Peyton skeleton-leaf
//! algorithm (the `cs_counts` formulation), O(nnz(A) · α(n)) time.

use crate::graph::csr::SymGraph;

/// For each column `j` of the Cholesky factor of the (already permuted)
/// pattern `pg`, the number of nonzeros including the diagonal.
///
/// `parent` is the elimination tree, `post` its postorder.
pub fn col_counts(pg: &SymGraph, parent: &[i32], post: &[i32]) -> Vec<i64> {
    let n = pg.n;
    let mut delta = vec![0i64; n];
    let mut first = vec![-1i32; n];
    let mut maxfirst = vec![-1i32; n];
    let mut prevleaf = vec![-1i32; n];
    let mut ancestor: Vec<i32> = (0..n as i32).collect();

    // first[j] = postorder index of j's first descendant; delta[j] starts at
    // 1 exactly when j is a leaf of the etree.
    for (k, &jv) in post.iter().enumerate() {
        let mut j = jv;
        delta[j as usize] = i64::from(first[j as usize] == -1);
        while j != -1 && first[j as usize] == -1 {
            first[j as usize] = k as i32;
            j = parent[j as usize];
        }
    }

    for &jv in post {
        let j = jv as usize;
        if parent[j] != -1 {
            delta[parent[j] as usize] -= 1;
        }
        for &iv in pg.neighbors(j) {
            let i = iv as usize;
            if let Some((jleaf, q)) =
                leaf(i, j, &first, &mut maxfirst, &mut prevleaf, &mut ancestor)
            {
                if jleaf >= 1 {
                    delta[j] += 1;
                }
                if jleaf == 2 {
                    delta[q] -= 1;
                }
            }
        }
        if parent[j] != -1 {
            ancestor[j] = parent[j];
        }
    }

    // Accumulate child deltas up the tree: counts[parent] += counts[child].
    // Processing in postorder guarantees children are final first.
    let mut counts = delta;
    for &jv in post {
        let j = jv as usize;
        if parent[j] != -1 {
            counts[parent[j] as usize] += counts[j];
        }
    }
    counts
}

/// The `cs_leaf` helper: determine whether `j` is a leaf of the `i`-th row
/// subtree; returns `(jleaf, q)` where `jleaf` is 1 for the first leaf, 2
/// for a subsequent leaf (with `q` the least common ancestor of `j` and the
/// previous leaf), or `None` if `j` is not a leaf. Mutates the
/// path-compressed `ancestor` forest.
fn leaf(
    i: usize,
    j: usize,
    first: &[i32],
    maxfirst: &mut [i32],
    prevleaf: &mut [i32],
    ancestor: &mut [i32],
) -> Option<(u8, usize)> {
    if i <= j || first[j] <= maxfirst[i] {
        return None;
    }
    maxfirst[i] = first[j];
    let jprev = prevleaf[i];
    prevleaf[i] = j as i32;
    if jprev == -1 {
        return Some((1, i));
    }
    // q = root of the path-compressed tree containing jprev.
    let mut q = jprev as usize;
    while q != ancestor[q] as usize {
        q = ancestor[q] as usize;
    }
    // Path compression from jprev to q.
    let mut s = jprev as usize;
    while s != q {
        let sparent = ancestor[s] as usize;
        ancestor[s] = q as i32;
        s = sparent;
    }
    Some((2, q))
}

/// Total nnz(L) (incl. diagonal) for a permuted pattern.
pub fn nnz_l(pg: &SymGraph) -> i64 {
    let parent = super::etree(pg);
    let post = super::postorder(&parent);
    col_counts(pg, &parent, &post).iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::SymGraph;
    use crate::symbolic::{etree, postorder};

    /// Brute-force column counts by explicit symbolic factorization.
    fn counts_naive(pg: &SymGraph) -> Vec<i64> {
        let n = pg.n;
        // cols[j] = pattern of column j of L (rows >= j).
        let mut cols: Vec<std::collections::BTreeSet<usize>> = (0..n)
            .map(|j| {
                let mut s: std::collections::BTreeSet<usize> = pg
                    .neighbors(j)
                    .iter()
                    .filter(|&&i| (i as usize) > j)
                    .map(|&i| i as usize)
                    .collect();
                s.insert(j);
                s
            })
            .collect();
        for j in 0..n {
            // The parent is the smallest row index > j in column j.
            let parent = cols[j].iter().cloned().find(|&i| i > j);
            if let Some(p) = parent {
                let add: Vec<usize> = cols[j].iter().cloned().filter(|&i| i > j).collect();
                for i in add {
                    cols[p].insert(i);
                }
            }
        }
        cols.iter().map(|c| c.len() as i64).collect()
    }

    fn check(pg: &SymGraph) {
        let parent = etree(pg);
        let post = postorder(&parent);
        let fast = col_counts(pg, &parent, &post);
        let slow = counts_naive(pg);
        assert_eq!(fast, slow);
    }

    #[test]
    fn counts_on_small_meshes() {
        check(&crate::matgen::mesh2d(5, 5));
        check(&crate::matgen::mesh2d(4, 9));
        check(&crate::matgen::mesh3d(3, 3, 3));
    }

    #[test]
    fn counts_on_random_graphs() {
        for seed in 0..8 {
            check(&crate::matgen::random_graph(50, 5, seed));
        }
    }

    #[test]
    fn counts_on_permuted_graphs() {
        use crate::graph::perm::permute_graph;
        use crate::util::rng::Rng;
        let g = crate::matgen::mesh2d(6, 6);
        for seed in 0..4 {
            let mut rng = Rng::new(seed);
            let p = rng.permutation(g.n);
            check(&permute_graph(&g, &p));
        }
    }

    #[test]
    fn path_graph_counts() {
        let g = SymGraph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let parent = etree(&g);
        let post = postorder(&parent);
        assert_eq!(col_counts(&g, &parent, &post), vec![2, 2, 2, 1]);
    }

    #[test]
    fn isolated_vertices() {
        let g = SymGraph::from_edges(3, &[]);
        assert_eq!(nnz_l(&g), 3);
    }
}
