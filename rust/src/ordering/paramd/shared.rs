//! The concurrent quotient graph (§3.3.1 of the paper).
//!
//! All node arrays are plain atomics accessed with `Relaxed` ordering;
//! the round barriers in the driver provide the cross-thread
//! happens-before edges. Within a round, the distance-2 independence of
//! the pivots guarantees (see DESIGN.md §6):
//!
//! - every variable/element *written* during elimination is owned by
//!   exactly one pivot, hence one thread;
//! - elements *read* by several threads (an element shared between two
//!   pivots' periphery) are never concurrently absorbed or relocated;
//! - the only benign races are reads of `nv`/`degree`/`state` of nodes
//!   being merged by their owner — every observable value keeps the
//!   AMD degrees approximate upper bounds.
//!
//! Storage follows SuiteSparse's single-`iw` scheme with elbow room; the
//! elbow cursor `pfree` is claimed with a **single `fetch_add` per pivot**
//! after the pivot's connection updates are collected in thread-local
//! scratch, exactly as §3.3.1 prescribes. On exhaustion the pivot is
//! deferred and a stop-the-world GC runs at the next round boundary.
//!
//! The same stop-the-world round-boundary window also hosts the
//! mid-elimination re-reduction sweep ([`crate::ordering::reduce::live`]):
//! like GC it runs with every worker parked at a barrier, so it may
//! mutate `state`/`parent`/`nv` without any claim protocol. Dead entries
//! it leaves behind (`ST_DEAD_VAR` twins, `ST_DEAD_ELEM` absorbed
//! elements) are pruned by the next collection exactly like the
//! elimination phases' own casualties.

use std::sync::atomic::{AtomicBool, AtomicI32, AtomicU8, AtomicUsize, Ordering::Relaxed};
use std::sync::Mutex;

use crate::graph::csr::SymGraph;

/// Node states, stored as `u8` atomics.
pub const ST_VAR: u8 = 0;
pub const ST_ELEM: u8 = 1;
pub const ST_DEAD_VAR: u8 = 2;
pub const ST_DEAD_ELEM: u8 = 3;

/// The shared quotient graph.
pub struct SharedGraph {
    pub n: usize,
    /// Total column weight: `Σ nv` at setup. Equals `n` for an ordinary
    /// run; larger when the reduction layer seeds supervariables with
    /// `nv > 1` (each node then stands for `nv` original columns). This
    /// is the elimination target (`nel` reaches it) and the upper bound
    /// on every weighted degree.
    pub weight: usize,
    pub iw: Vec<AtomicI32>,
    pub pe: Vec<AtomicUsize>,
    pub len: Vec<AtomicI32>,
    pub elen: Vec<AtomicI32>,
    /// Supervariable size (vars); pivot block size (elements); 0 when dead.
    pub nv: Vec<AtomicI32>,
    /// Approximate external degree (vars) / weighted `|L_e|` (elements).
    pub degree: Vec<AtomicI32>,
    pub state: Vec<AtomicU8>,
    pub parent: Vec<AtomicI32>,
    /// Elbow cursor: next free slot in `iw`.
    pub pfree: AtomicUsize,
    /// Columns eliminated so far.
    pub nel: AtomicUsize,
    /// Set when a thread failed to claim elbow space; triggers GC.
    pub gc_requested: AtomicBool,
    /// Total failed `claim`s this run — the memory-contention signal the
    /// round telemetry samples (each failure deferred a pivot).
    pub claim_failures: AtomicUsize,
    /// Pooled GC compaction order — retained across collections (and
    /// arena reuse) so a warm GC performs no O(live) allocation. Behind a
    /// mutex only for interior mutability: GC runs stop-the-world.
    gc_scratch: Mutex<Vec<u32>>,
}

impl SharedGraph {
    /// Build from a symmetric pattern with `elbow × nnz` extra space
    /// (the paper's empirical 1.5 default lives in the ParAMD config).
    pub fn new(g: &SymGraph, elbow: f64) -> Self {
        let mut sg = Self::empty();
        sg.reset_from(g, elbow);
        sg
    }

    /// An unsized shell whose storage is populated by [`Self::reset_from`]
    /// — the arena's pooled slab starts here.
    pub fn empty() -> Self {
        SharedGraph {
            n: 0,
            weight: 0,
            iw: Vec::new(),
            pe: Vec::new(),
            len: Vec::new(),
            elen: Vec::new(),
            nv: Vec::new(),
            degree: Vec::new(),
            state: Vec::new(),
            parent: Vec::new(),
            pfree: AtomicUsize::new(0),
            nel: AtomicUsize::new(0),
            gc_requested: AtomicBool::new(false),
            claim_failures: AtomicUsize::new(0),
            gc_scratch: Mutex::new(Vec::new()),
        }
    }

    /// Re-initialize in place for a new input graph, growing the slab
    /// monotonically and reusing it whenever the graph fits (the warm
    /// path performs zero heap allocations). A retained slab larger than
    /// `elbow × nnz` simply acts as extra elbow room. Returns the number
    /// of storage groups that had to grow (0 on a fully warm reset).
    pub fn reset_from(&mut self, g: &SymGraph, elbow: f64) -> u32 {
        self.reset_from_weighted(g, elbow, None)
    }

    /// [`Self::reset_from`] with **seed supervariables**: `weights[v]`
    /// becomes node `v`'s initial `nv` (the number of original columns
    /// it stands for — the reduction layer's twin-class sizes) and every
    /// initial degree is the *weighted* external degree `Σ nv(u)` over
    /// the neighbors, exactly the state the quotient graph would be in
    /// had AMD itself merged those columns. `None` weights mean all-ones
    /// (the ordinary unweighted setup).
    pub fn reset_from_weighted(
        &mut self,
        g: &SymGraph,
        elbow: f64,
        weights: Option<&[i32]>,
    ) -> u32 {
        let n = g.n;
        if let Some(w) = weights {
            assert_eq!(w.len(), n, "one weight per vertex");
        }
        let nnz = g.nnz();
        let iwlen = nnz + (nnz as f64 * elbow) as usize + 16;
        let mut grew = 0;
        if self.iw.len() < iwlen {
            self.iw.resize_with(iwlen, || AtomicI32::new(0));
            grew += 1;
        }
        if self.pe.len() < n {
            self.pe.resize_with(n, || AtomicUsize::new(0));
            self.len.resize_with(n, || AtomicI32::new(0));
            self.elen.resize_with(n, || AtomicI32::new(0));
            self.nv.resize_with(n, || AtomicI32::new(0));
            self.degree.resize_with(n, || AtomicI32::new(0));
            self.state.resize_with(n, || AtomicU8::new(ST_VAR));
            self.parent.resize_with(n, || AtomicI32::new(-1));
            grew += 1;
        }
        self.n = n;
        for (i, &c) in g.colind.iter().enumerate() {
            self.iw[i].store(c, Relaxed);
        }
        let mut total = 0usize;
        for v in 0..n {
            let len = g.degree(v) as i32;
            let (w, deg) = match weights {
                None => (1, len),
                Some(ws) => {
                    debug_assert!(ws[v] > 0, "weights must be positive");
                    let deg: i32 = g.neighbors(v).iter().map(|&u| ws[u as usize]).sum();
                    (ws[v], deg)
                }
            };
            total += w as usize;
            self.pe[v].store(g.rowptr[v], Relaxed);
            self.len[v].store(len, Relaxed);
            self.elen[v].store(0, Relaxed);
            self.nv[v].store(w, Relaxed);
            self.degree[v].store(deg, Relaxed);
            self.state[v].store(ST_VAR, Relaxed);
            self.parent[v].store(-1, Relaxed);
        }
        self.weight = total;
        self.pfree.store(nnz, Relaxed);
        self.nel.store(0, Relaxed);
        self.gc_requested.store(false, Relaxed);
        self.claim_failures.store(0, Relaxed);
        grew
    }

    // -- relaxed accessors (all cross-thread sync comes from barriers) ---

    #[inline]
    pub fn st(&self, i: usize) -> u8 {
        self.state[i].load(Relaxed)
    }
    #[inline]
    pub fn set_st(&self, i: usize, s: u8) {
        self.state[i].store(s, Relaxed);
    }
    #[inline]
    pub fn iw_at(&self, p: usize) -> i32 {
        self.iw[p].load(Relaxed)
    }
    #[inline]
    pub fn iw_set(&self, p: usize, v: i32) {
        self.iw[p].store(v, Relaxed);
    }
    #[inline]
    pub fn nv_of(&self, i: usize) -> i32 {
        self.nv[i].load(Relaxed)
    }
    #[inline]
    pub fn deg_of(&self, i: usize) -> i32 {
        self.degree[i].load(Relaxed)
    }
    #[inline]
    pub fn pe_of(&self, i: usize) -> usize {
        self.pe[i].load(Relaxed)
    }
    #[inline]
    pub fn len_of(&self, i: usize) -> i32 {
        self.len[i].load(Relaxed)
    }
    #[inline]
    pub fn elen_of(&self, i: usize) -> i32 {
        self.elen[i].load(Relaxed)
    }

    /// Claim `need` slots of elbow room with one `fetch_add` (§3.3.1).
    /// Returns the start offset, or `None` when exhausted (the caller
    /// defers its pivot and requests a GC).
    ///
    /// Exhaustion is **sticky**: a failed claim leaves the cursor
    /// saturated past the end instead of rolling it back. A rollback
    /// (`fetch_sub`) could release slots that a concurrently-winning
    /// thread claimed in between — e.g. A fail-claims 20, B fail-claims 5,
    /// A rolls back (making room), C successfully claims the freed tail,
    /// then B's rollback frees C's slots for D: C and D now alias the same
    /// words. Until the round-boundary GC recomputes the cursor exactly,
    /// every further claim simply fails fast.
    pub fn claim(&self, need: usize) -> Option<usize> {
        let off = self.pfree.fetch_add(need, Relaxed);
        match off.checked_add(need) {
            Some(end) if end <= self.iw.len() => Some(off),
            _ => {
                self.gc_requested.store(true, Relaxed);
                self.claim_failures.fetch_add(1, Relaxed);
                None
            }
        }
    }

    /// Stop-the-world garbage collection: compact all live lists to the
    /// front of `iw`, pruning dead entries and refreshing element weights.
    /// Must be called while every other thread is parked at a barrier.
    /// The compaction order lives in pooled scratch whose capacity is
    /// retained, so only the very first collection allocates.
    pub fn garbage_collect_exclusive(&self) {
        let mut order = self.gc_scratch.lock().unwrap();
        order.clear();
        order.extend((0..self.n as u32).filter(|&i| {
            let s = self.st(i as usize);
            (s == ST_VAR || s == ST_ELEM) && self.len_of(i as usize) > 0
        }));
        order.sort_by_key(|&i| self.pe_of(i as usize));
        let mut dst = 0usize;
        for &iu in order.iter() {
            let i = iu as usize;
            let src = self.pe_of(i);
            debug_assert!(src >= dst);
            if self.st(i) == ST_ELEM {
                let mut weight = 0i32;
                let mut kept = 0usize;
                for k in 0..self.len_of(i) as usize {
                    let v = self.iw_at(src + k);
                    if self.st(v as usize) == ST_VAR {
                        self.iw_set(dst + kept, v);
                        kept += 1;
                        weight += self.nv_of(v as usize);
                    }
                }
                self.pe[i].store(dst, Relaxed);
                self.len[i].store(kept as i32, Relaxed);
                self.degree[i].store(weight, Relaxed);
                dst += kept;
            } else {
                let mut kept_e = 0usize;
                for k in 0..self.elen_of(i) as usize {
                    let e = self.iw_at(src + k);
                    if self.st(e as usize) == ST_ELEM {
                        self.iw_set(dst + kept_e, e);
                        kept_e += 1;
                    }
                }
                let mut kept = kept_e;
                for k in self.elen_of(i) as usize..self.len_of(i) as usize {
                    let v = self.iw_at(src + k);
                    if self.st(v as usize) == ST_VAR {
                        self.iw_set(dst + kept, v);
                        kept += 1;
                    }
                }
                self.pe[i].store(dst, Relaxed);
                self.elen[i].store(kept_e as i32, Relaxed);
                self.len[i].store(kept as i32, Relaxed);
                dst += kept;
            }
        }
        self.pfree.store(dst, Relaxed);
        self.gc_requested.store(false, Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::mesh2d;

    #[test]
    fn construction_mirrors_graph() {
        let g = mesh2d(4, 4);
        let sg = SharedGraph::new(&g, 1.5);
        assert_eq!(sg.n, 16);
        assert_eq!(sg.pfree.load(Relaxed), g.nnz());
        for v in 0..g.n {
            assert_eq!(sg.len_of(v) as usize, g.degree(v));
            assert_eq!(sg.deg_of(v) as usize, g.degree(v));
            assert_eq!(sg.st(v), ST_VAR);
            let p = sg.pe_of(v);
            let nbrs: Vec<i32> = (0..g.degree(v)).map(|k| sg.iw_at(p + k)).collect();
            assert_eq!(nbrs.as_slice(), g.neighbors(v));
        }
    }

    #[test]
    fn claim_and_exhaust() {
        let g = mesh2d(3, 3);
        let sg = SharedGraph::new(&g, 0.0);
        let avail = sg.iw.len() - sg.pfree.load(Relaxed);
        assert!(sg.claim(avail).is_some());
        assert!(sg.claim(1).is_none());
        assert!(sg.gc_requested.load(Relaxed));
    }

    #[test]
    fn claim_exhaustion_is_sticky() {
        // Regression for the rollback race: a failed claim used to
        // `fetch_sub` the cursor back, which could release slots that a
        // concurrently-winning claim had already taken (see `claim` docs
        // for the interleaving). Sticky exhaustion means that after any
        // failed claim, *no* later claim can succeed until GC recomputes
        // the cursor — so no freed-then-reclaimed aliasing is possible.
        let g = mesh2d(3, 3);
        let sg = SharedGraph::new(&g, 0.5);
        let avail = sg.iw.len() - sg.pfree.load(Relaxed);
        assert!(sg.claim(avail + 3).is_none(), "oversized claim must fail");
        assert!(sg.gc_requested.load(Relaxed));
        assert!(
            sg.pfree.load(Relaxed) > sg.iw.len(),
            "cursor must stay saturated, not roll back"
        );
        // This claim would have fit before the failed one; with the old
        // rollback it could overlap a winner's slots. Now it fails fast.
        assert!(sg.claim(1).is_none(), "exhaustion must be sticky");
        assert_eq!(
            sg.claim_failures.load(Relaxed),
            2,
            "every failed claim counts toward the contention telemetry"
        );
        // The round-boundary GC recomputes the cursor exactly.
        sg.garbage_collect_exclusive();
        assert!(!sg.gc_requested.load(Relaxed));
        assert!(sg.pfree.load(Relaxed) <= g.nnz());
        assert!(sg.claim(1).is_some(), "claims work again after GC");
    }

    #[test]
    fn reset_reuses_slab_and_mirrors_graph() {
        let big = mesh2d(6, 6);
        let small = mesh2d(3, 3);
        let mut sg = SharedGraph::new(&big, 1.5);
        let slab = sg.iw.len();
        // Dirty some state, then warm-reset onto a smaller graph.
        sg.set_st(0, ST_DEAD_VAR);
        sg.nel.store(5, Relaxed);
        assert_eq!(sg.reset_from(&small, 1.5), 0, "smaller graph must not grow");
        assert_eq!(sg.iw.len(), slab, "slab is retained");
        assert_eq!(sg.n, small.n);
        assert_eq!(sg.nel.load(Relaxed), 0);
        assert_eq!(sg.pfree.load(Relaxed), small.nnz());
        for v in 0..small.n {
            assert_eq!(sg.st(v), ST_VAR);
            assert_eq!(sg.len_of(v) as usize, small.degree(v));
            let p = sg.pe_of(v);
            let nbrs: Vec<i32> = (0..small.degree(v)).map(|k| sg.iw_at(p + k)).collect();
            assert_eq!(nbrs.as_slice(), small.neighbors(v));
        }
        // Back to the original size: the retained slab still fits (warm).
        assert_eq!(sg.reset_from(&big, 1.5), 0, "retained slab must be reused");
        assert_eq!(sg.n, big.n);
        assert_eq!(sg.pfree.load(Relaxed), big.nnz());
        // A strictly larger graph is the only thing that allocates.
        let bigger = mesh2d(9, 9);
        assert!(sg.reset_from(&bigger, 1.5) > 0, "larger graph must grow");
        assert_eq!(sg.n, bigger.n);
    }

    #[test]
    fn weighted_reset_seeds_nv_and_weighted_degrees() {
        // Path 0-1-2 with weights 3,1,2: degrees must be neighbor-weight
        // sums and `weight` the column total.
        let g = crate::graph::csr::SymGraph::from_edges(3, &[(0, 1), (1, 2)]);
        let mut sg = SharedGraph::empty();
        sg.reset_from_weighted(&g, 1.5, Some(&[3, 1, 2]));
        assert_eq!(sg.weight, 6);
        assert_eq!(sg.nv_of(0), 3);
        assert_eq!(sg.nv_of(1), 1);
        assert_eq!(sg.nv_of(2), 2);
        assert_eq!(sg.deg_of(0), 1, "0 sees only 1 (weight 1)");
        assert_eq!(sg.deg_of(1), 5, "1 sees 0 (3) and 2 (2)");
        assert_eq!(sg.deg_of(2), 1);
        // An unweighted reset restores the all-ones state.
        sg.reset_from(&g, 1.5);
        assert_eq!(sg.weight, 3);
        assert_eq!(sg.nv_of(0), 1);
        assert_eq!(sg.deg_of(1), 2);
    }

    #[test]
    fn gc_compacts_and_preserves_live_lists() {
        let g = mesh2d(4, 4);
        let sg = SharedGraph::new(&g, 1.0);
        // Kill vertex 0 and re-point vertex 1's list into the elbow.
        sg.set_st(0, ST_DEAD_VAR);
        sg.len[0].store(0, Relaxed);
        let off = sg.claim(2).unwrap();
        sg.iw_set(off, 2);
        sg.iw_set(off + 1, 5);
        sg.pe[1].store(off, Relaxed);
        sg.len[1].store(2, Relaxed);
        sg.elen[1].store(0, Relaxed);
        let before: Vec<i32> = (0..2).map(|k| sg.iw_at(sg.pe_of(1) + k)).collect();
        sg.garbage_collect_exclusive();
        let after: Vec<i32> = (0..sg.len_of(1) as usize)
            .map(|k| sg.iw_at(sg.pe_of(1) + k))
            .collect();
        assert_eq!(before, after);
        assert!(sg.pfree.load(Relaxed) < off + 2, "gc must reclaim space");
        assert!(!sg.gc_requested.load(Relaxed));
    }
}
