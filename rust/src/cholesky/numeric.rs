//! Up-looking numeric Cholesky (the `cs_chol` algorithm) with an optional
//! dense trailing block.
//!
//! Rows `k < split` follow the classic up-looking scheme: the pattern of
//! row `k` of `L` is the row subtree reached by `ereach`, and each entry
//! `L(k,i)` is appended to column `i`. Rows `k ≥ split` compute only their
//! *sparse* panel entries (`i < split`); what accumulates at columns
//! `[split, k]` is then exactly the Schur complement
//! `S = C[tail,tail] − L_panel L_panelᵀ`, which is handed to a dense
//! Cholesky engine (native or PJRT) and written back into the CSC factor.

use crate::graph::csr::CsrMatrix;
use crate::symbolic::SymbolicInfo;

use super::dense::DenseCholesky;

/// Lower-triangular factor in CSC form; each column stores the diagonal
/// first, then strictly-lower rows in increasing order.
pub struct CscFactor {
    pub n: usize,
    pub lp: Vec<usize>,
    pub li: Vec<i32>,
    pub lx: Vec<f64>,
}

/// Build `C = P A Pᵀ` (values included, rows sorted).
fn permute_matrix(a: &CsrMatrix, perm: &[i32]) -> CsrMatrix {
    let n = a.nrows;
    let mut inv = vec![0i32; n];
    for (k, &v) in perm.iter().enumerate() {
        inv[v as usize] = k as i32;
    }
    let mut trip = Vec::with_capacity(a.nnz());
    for k in 0..n {
        let v = perm[k] as usize;
        for p in a.rowptr[v]..a.rowptr[v + 1] {
            trip.push((k, inv[a.colind[p] as usize] as usize, a.values[p]));
        }
    }
    CsrMatrix::from_triplets(n, n, &trip)
}

/// Nonzero pattern of row `k` of `L`: walk the elimination tree from each
/// entry of row `k` of `C` (columns `< k`) until hitting a marked node;
/// emits the pattern in topological order into `s[top..n]`.
fn ereach(
    c: &CsrMatrix,
    k: usize,
    parent: &[i32],
    s: &mut [i32],
    wmark: &mut [i32],
) -> usize {
    let n = c.nrows;
    let mut top = n;
    wmark[k] = k as i32;
    for p in c.rowptr[k]..c.rowptr[k + 1] {
        let mut i = c.colind[p] as usize;
        if i >= k {
            continue;
        }
        let mut len = 0usize;
        while wmark[i] != k as i32 {
            s[len] = i as i32;
            len += 1;
            wmark[i] = k as i32;
            let pi = parent[i];
            if pi < 0 {
                break;
            }
            i = pi as usize;
        }
        // Push the path onto the output stack (reversing into topo order).
        while len > 0 {
            len -= 1;
            top -= 1;
            s[top] = s[len];
        }
    }
    top
}

/// Factor with the up-looking algorithm; `split == n` means fully sparse.
pub fn factor_uplooking(
    a: &CsrMatrix,
    perm: &[i32],
    info: &SymbolicInfo,
    split: usize,
    dense_chol: &dyn DenseCholesky,
) -> Result<CscFactor, String> {
    let n = a.nrows;
    let c = permute_matrix(a, perm);
    let m = n - split; // dense tail size

    // Column pointers: sparse columns use the symbolic counts; tail
    // columns hold a full dense triangle.
    let mut lp = vec![0usize; n + 1];
    for j in 0..n {
        let cap = if j < split {
            info.counts[j] as usize
        } else {
            n - j
        };
        lp[j + 1] = lp[j] + cap;
    }
    let nnz_cap = lp[n];
    let mut li = vec![0i32; nnz_cap];
    let mut lx = vec![0f64; nnz_cap];
    // Next free slot per column (cs_chol's `c` array).
    let mut cfree: Vec<usize> = lp[..n].to_vec();

    let mut x = vec![0f64; n]; // dense scratch row
    let mut s = vec![0i32; n]; // ereach stack
    let mut wmark = vec![-1i32; n];
    // Dense Schur block, row-major m×m (lower triangle filled).
    let mut schur = vec![0f64; m * m];

    for k in 0..n {
        let top = ereach(&c, k, &info.parent, &mut s, &mut wmark);
        // Scatter row k of C (columns ≤ k).
        let mut d = 0.0; // diagonal accumulator
        for p in c.rowptr[k]..c.rowptr[k + 1] {
            let j = c.colind[p] as usize;
            if j < k {
                x[j] = c.values[p];
            } else if j == k {
                d = c.values[p];
            }
        }
        // Sparse updates in topological order (skip tail columns — their
        // coupling lives in the dense Schur block).
        for &iv in &s[top..n] {
            let i = iv as usize;
            if i >= split {
                // Tail-tail coupling: leave x[i] in place — it is read into
                // the Schur row (and cleared) below.
                continue;
            }
            let pdiag = lp[i];
            let lkk = lx[pdiag];
            let lki = x[i] / lkk;
            x[i] = 0.0;
            for p in pdiag + 1..cfree[i] {
                x[li[p] as usize] -= lx[p] * lki;
            }
            d -= lki * lki;
            if k < split {
                // Append L(k,i) to column i.
                let p = cfree[i];
                debug_assert!(p < lp[i + 1], "column {i} overflow");
                li[p] = k as i32;
                lx[p] = lki;
                cfree[i] += 1;
            } else {
                // Panel entry of a tail row: also appended to column i so
                // later rows receive its updates.
                let p = cfree[i];
                debug_assert!(p < lp[i + 1], "column {i} overflow (panel)");
                li[p] = k as i32;
                lx[p] = lki;
                cfree[i] += 1;
            }
        }
        if k < split {
            if d <= 0.0 || !d.is_finite() {
                return Err(format!(
                    "matrix not positive definite at column {k} (pivot {d:e})"
                ));
            }
            let p = cfree[k];
            li[p] = k as i32;
            lx[p] = d.sqrt();
            cfree[k] += 1;
        } else {
            // Row of the Schur complement: S[t][u] sits in x[split..k], the
            // diagonal in d.
            let t = k - split;
            for u in 0..t {
                schur[t * m + u] = x[split + u];
                x[split + u] = 0.0;
            }
            schur[t * m + t] = d;
        }
    }

    if m > 0 {
        // Mirror to full symmetric content for the dense engine.
        for t in 0..m {
            for u in t + 1..m {
                schur[t * m + u] = schur[u * m + t];
            }
        }
        dense_chol.factor(&mut schur, m)?;
        // Write the dense factor back into the tail columns.
        for j in 0..m {
            let col = split + j;
            let mut p = lp[col];
            for i in j..m {
                li[p] = (split + i) as i32;
                lx[p] = schur[i * m + j];
                p += 1;
            }
            cfree[col] = p;
        }
    }

    // Compact columns to their actual fill (sparse columns always fill
    // exactly their symbolic count; keep an assert for the invariant).
    for j in 0..split {
        debug_assert_eq!(cfree[j], lp[j + 1], "column {j} underfilled");
    }
    Ok(CscFactor { n, lp, li, lx })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cholesky::dense::NativeDense;
    use crate::graph::symmetrize;
    use crate::matgen::laplacian_matrix;
    use crate::ordering::{amd_seq::AmdSeq, Ordering as _};
    use crate::symbolic::analyze;

    /// Reconstruct P A Pᵀ from L and compare.
    fn check_llt(a: &CsrMatrix, split_frac: f64) {
        let g = symmetrize(a);
        let perm = AmdSeq::default().order(&g).perm;
        let info = analyze(&g, &perm);
        let n = a.nrows;
        let split = ((n as f64) * split_frac) as usize;
        let l = factor_uplooking(a, &perm, &info, split, &NativeDense).unwrap();
        let c = permute_matrix(a, &perm);
        // dense L
        let mut dl = vec![0.0; n * n];
        for j in 0..n {
            for p in l.lp[j]..l.lp[j + 1] {
                dl[l.li[p] as usize * n + j] = l.lx[p];
            }
        }
        for i in 0..n {
            // row i of C as dense
            let mut row = vec![0.0; n];
            for p in c.rowptr[i]..c.rowptr[i + 1] {
                row[c.colind[p] as usize] = c.values[p];
            }
            for j in 0..=i {
                let mut sum = 0.0;
                for k in 0..=j {
                    sum += dl[i * n + k] * dl[j * n + k];
                }
                assert!(
                    (sum - row[j]).abs() < 1e-9,
                    "L L^T mismatch at ({i},{j}): {sum} vs {} (split={split})",
                    row[j]
                );
            }
        }
    }

    #[test]
    fn llt_reconstructs_simplicial() {
        check_llt(&laplacian_matrix(7, 7), 1.0);
    }

    #[test]
    fn llt_reconstructs_half_dense() {
        check_llt(&laplacian_matrix(7, 7), 0.5);
    }

    #[test]
    fn llt_reconstructs_fully_dense() {
        check_llt(&laplacian_matrix(5, 5), 0.0);
    }

    #[test]
    fn ereach_pattern_is_row_subtree() {
        // Path graph: row k of L has exactly {k-1} below-diagonal.
        let a = {
            let mut trip = vec![];
            for i in 0..6 {
                trip.push((i, i, 3.0));
                if i + 1 < 6 {
                    trip.push((i, i + 1, -1.0));
                    trip.push((i + 1, i, -1.0));
                }
            }
            CsrMatrix::from_triplets(6, 6, &trip)
        };
        let g = symmetrize(&a);
        let id: Vec<i32> = (0..6).collect();
        let info = analyze(&g, &id);
        let c = permute_matrix(&a, &id);
        let mut s = vec![0i32; 6];
        let mut w = vec![-1i32; 6];
        for k in 1..6 {
            let top = ereach(&c, k, &info.parent, &mut s, &mut w);
            assert_eq!(&s[top..6], &[(k - 1) as i32]);
        }
    }
}
