"""Layer-1 correctness: the Pallas blocked-Cholesky kernel against the
pure-jnp oracle, swept over shapes/dtypes/seeds with hypothesis."""

import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import chol_block, ref

BS = chol_block.DEFAULT_BLOCK


def tol(dtype):
    return dict(rtol=5e-4, atol=5e-3) if dtype == jnp.float32 else dict(rtol=1e-10, atol=1e-9)


@pytest.mark.parametrize("n", [32, 64, 96, 128])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_kernel_matches_ref(n, dtype):
    a = ref.random_spd(jax.random.PRNGKey(n), n, dtype)
    l = chol_block.blocked_cholesky(a)
    lref = ref.cholesky_ref(a)
    np.testing.assert_allclose(np.asarray(l), np.asarray(lref), **tol(dtype))


@settings(max_examples=20, deadline=None)
@given(
    nb=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(nb, seed):
    n = nb * BS
    a = ref.random_spd(jax.random.PRNGKey(seed), n)
    l = chol_block.blocked_cholesky(a)
    lref = ref.cholesky_ref(a)
    np.testing.assert_allclose(np.asarray(l), np.asarray(lref), rtol=5e-4, atol=5e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_factor_reconstructs_input(seed):
    n = 64
    a = ref.random_spd(jax.random.PRNGKey(seed), n)
    l = chol_block.blocked_cholesky(a)
    np.testing.assert_allclose(np.asarray(l @ l.T), np.asarray(a), rtol=1e-3, atol=5e-2)


def test_output_is_lower_triangular():
    a = ref.random_spd(jax.random.PRNGKey(7), 64)
    l = np.asarray(chol_block.blocked_cholesky(a))
    assert np.allclose(np.triu(l, 1), 0.0)


def test_indefinite_produces_nan():
    a = -jnp.eye(32, dtype=jnp.float32)
    l = chol_block.blocked_cholesky(a)
    assert bool(jnp.isnan(l).any())


def test_rejects_non_multiple_of_block():
    a = jnp.eye(33, dtype=jnp.float32)
    with pytest.raises(ValueError):
        chol_block.blocked_cholesky(a)


def test_identity_factor():
    a = 4.0 * jnp.eye(32, dtype=jnp.float32)
    l = np.asarray(chol_block.blocked_cholesky(a))
    assert np.allclose(l, 2.0 * np.eye(32))


def test_block_size_invariance():
    a = ref.random_spd(jax.random.PRNGKey(3), 64)
    l1 = chol_block.blocked_cholesky(a, bs=32)
    l2 = chol_block.blocked_cholesky(a, bs=16)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)


def test_vmem_and_mxu_estimates_sane():
    assert chol_block.vmem_footprint_bytes(256) < 16 * 2**20  # fits VMEM
    u = chol_block.mxu_utilization_estimate(256)
    assert 0.1 < u < 1.0
