//! Pull-based metrics exposition: render a
//! [`Metrics`](crate::coordinator::Metrics) snapshot as Prometheus text
//! format ([`prometheus`]) or as one JSON document ([`json_snapshot`]).
//!
//! Both renderers are pure functions over the snapshot — no I/O, no
//! global registry — so they cost O(methods + shards) per call and
//! nothing between calls. Latency quantiles come from the fixed-footprint
//! [`LogHistogram`](crate::util::stats::LogHistogram)s inside
//! [`MethodMetrics`](crate::coordinator::MethodMetrics), rendered as
//! Prometheus *summaries* (`quantile` labels plus exact `_sum`/`_count`).
//!
//! The serve CLI prints the Prometheus page under `--metrics-every N`;
//! a scrape endpoint would serve the same string verbatim.

use std::fmt::Write as _;

use crate::coordinator::Metrics;

/// The `quantile` labels every latency summary exposes.
const SUMMARY_QUANTILES: [f64; 3] = [0.5, 0.95, 0.99];

fn help(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

/// Render the snapshot in Prometheus text exposition format (one page of
/// `paramd_*` families). Counters end in `_total`, gauges don't;
/// per-method latencies are summaries with `quantile` labels.
pub fn prometheus(m: &Metrics) -> String {
    let mut out = String::with_capacity(4096);

    help(&mut out, "paramd_requests_total", "counter", "Requests recorded, by ordering method.");
    for (name, e) in m.iter() {
        let _ = writeln!(out, "paramd_requests_total{{method=\"{name}\"}} {}", e.requests);
    }

    help(
        &mut out,
        "paramd_request_latency_seconds",
        "summary",
        "End-to-end request latency (queue wait + service).",
    );
    for (name, e) in m.iter() {
        for q in SUMMARY_QUANTILES {
            let _ = writeln!(
                out,
                "paramd_request_latency_seconds{{method=\"{name}\",quantile=\"{q}\"}} {}",
                e.latency_quantile(q)
            );
        }
        let _ = writeln!(
            out,
            "paramd_request_latency_seconds_sum{{method=\"{name}\"}} {}",
            e.latency_sum()
        );
        let _ = writeln!(
            out,
            "paramd_request_latency_seconds_count{{method=\"{name}\"}} {}",
            e.requests
        );
    }

    help(
        &mut out,
        "paramd_request_wait_seconds",
        "summary",
        "Time queued before a scheduler picked the request up.",
    );
    for (name, e) in m.iter() {
        for q in SUMMARY_QUANTILES {
            let _ = writeln!(
                out,
                "paramd_request_wait_seconds{{method=\"{name}\",quantile=\"{q}\"}} {}",
                e.wait_quantile(q)
            );
        }
    }

    help(&mut out, "paramd_fill_in_total", "counter", "Accumulated fill-in, by method.");
    for (name, e) in m.iter() {
        let _ = writeln!(out, "paramd_fill_in_total{{method=\"{name}\"}} {}", e.total_fill);
    }

    let p = &m.pipeline;
    help(&mut out, "paramd_pipeline_submitted_total", "counter", "Tickets accepted by submit.");
    let _ = writeln!(out, "paramd_pipeline_submitted_total {}", p.submitted);
    help(&mut out, "paramd_pipeline_completed_total", "counter", "Requests that produced a reply.");
    let _ = writeln!(out, "paramd_pipeline_completed_total {}", p.completed);
    help(&mut out, "paramd_pipeline_cancelled_total", "counter", "Requests cancelled before completion.");
    let _ = writeln!(out, "paramd_pipeline_cancelled_total {}", p.cancelled);
    help(&mut out, "paramd_pipeline_failed_total", "counter", "Requests whose processing panicked.");
    let _ = writeln!(out, "paramd_pipeline_failed_total {}", p.failed);
    help(&mut out, "paramd_pipeline_rejected_total", "counter", "try_submits shed by admission control.");
    let _ = writeln!(out, "paramd_pipeline_rejected_total {}", p.rejected);
    help(&mut out, "paramd_pipeline_deadline_exceeded_total", "counter", "Requests abandoned past their deadline.");
    let _ = writeln!(out, "paramd_pipeline_deadline_exceeded_total {}", p.deadline_exceeded);
    help(&mut out, "paramd_queue_depth", "gauge", "Queue depth at snapshot time.");
    let _ = writeln!(out, "paramd_queue_depth {}", p.queue_depth);
    help(&mut out, "paramd_queue_depth_peak", "gauge", "Highest queue depth observed.");
    let _ = writeln!(out, "paramd_queue_depth_peak {}", p.queue_depth_peak);
    help(&mut out, "paramd_arena_evictions_total", "counter", "Arenas dropped by the pool policy.");
    let _ = writeln!(out, "paramd_arena_evictions_total {}", p.arena_evictions);

    let sh = &m.shards;
    help(&mut out, "paramd_engine_requests_total", "counter", "Requests routed through the shard engine.");
    let _ = writeln!(out, "paramd_engine_requests_total {}", sh.requests);
    help(&mut out, "paramd_engine_components_total", "counter", "Component orderings served.");
    let _ = writeln!(out, "paramd_engine_components_total {}", sh.components);
    help(&mut out, "paramd_engine_busy_peak", "gauge", "Most shards observed busy at once.");
    let _ = writeln!(out, "paramd_engine_busy_peak {}", sh.busy_peak);
    help(&mut out, "paramd_gc_collections_total", "counter", "Stop-the-world quotient-graph GCs.");
    let _ = writeln!(out, "paramd_gc_collections_total {}", sh.gc_count);
    help(&mut out, "paramd_gc_seconds_total", "counter", "Seconds frozen inside those GCs.");
    let _ = writeln!(out, "paramd_gc_seconds_total {}", sh.gc_secs);
    help(&mut out, "paramd_rereduce_passes_total", "counter", "Mid-elimination re-reduction sweeps.");
    let _ = writeln!(out, "paramd_rereduce_passes_total {}", sh.rereduce_passes);
    help(&mut out, "paramd_rereduce_seconds_total", "counter", "Seconds inside those sweeps.");
    let _ = writeln!(out, "paramd_rereduce_seconds_total {}", sh.rereduce_secs);
    help(
        &mut out,
        "paramd_claim_failures_total",
        "counter",
        "Elbow claim failures (memory contention) across all jobs.",
    );
    let _ = writeln!(out, "paramd_claim_failures_total {}", sh.claim_failures);
    help(&mut out, "paramd_shed_hybrid_total", "counter", "Quality sheds that skipped the hybrid partition.");
    let _ = writeln!(out, "paramd_shed_hybrid_total {}", sh.shed_hybrid);
    help(&mut out, "paramd_shed_rereduce_total", "counter", "Quality sheds that disabled the re-reduction sweep.");
    let _ = writeln!(out, "paramd_shed_rereduce_total {}", sh.shed_rereduce);
    help(
        &mut out,
        "paramd_shed_sequential_total",
        "counter",
        "Components ordered by the sequential-AMD quality shed.",
    );
    let _ = writeln!(out, "paramd_shed_sequential_total {}", sh.shed_sequential);

    help(&mut out, "paramd_shard_jobs_total", "counter", "Ordering jobs executed, by shard.");
    for (i, st) in sh.per_shard.iter().enumerate() {
        let _ = writeln!(out, "paramd_shard_jobs_total{{shard=\"{i}\"}} {}", st.jobs);
    }
    help(&mut out, "paramd_shard_busy_seconds_total", "counter", "Dispatcher busy seconds, by shard.");
    for (i, st) in sh.per_shard.iter().enumerate() {
        let _ = writeln!(
            out,
            "paramd_shard_busy_seconds_total{{shard=\"{i}\"}} {}",
            st.busy_secs
        );
    }
    help(
        &mut out,
        "paramd_shard_busy_p95_seconds",
        "gauge",
        "Approximate p95 of per-job busy seconds, by shard.",
    );
    for (i, st) in sh.per_shard.iter().enumerate() {
        let _ = writeln!(
            out,
            "paramd_shard_busy_p95_seconds{{shard=\"{i}\"}} {}",
            st.busy_p95_secs
        );
    }

    let c = &m.cache;
    help(&mut out, "paramd_cache_hits_total", "counter", "Result-cache verified hits.");
    let _ = writeln!(out, "paramd_cache_hits_total {}", c.hits);
    help(&mut out, "paramd_cache_misses_total", "counter", "Result-cache misses (verify-rejects included).");
    let _ = writeln!(out, "paramd_cache_misses_total {}", c.misses);
    help(&mut out, "paramd_cache_evictions_total", "counter", "Entries dropped by the LRU byte budget.");
    let _ = writeln!(out, "paramd_cache_evictions_total {}", c.evictions);
    help(&mut out, "paramd_cache_bytes", "gauge", "Result-cache resident bytes.");
    let _ = writeln!(out, "paramd_cache_bytes {}", c.bytes);
    help(&mut out, "paramd_cache_budget_bytes", "gauge", "Result-cache byte budget (0 = disabled).");
    let _ = writeln!(out, "paramd_cache_budget_bytes {}", c.budget_bytes);
    help(&mut out, "paramd_cache_saved_seconds_total", "counter", "Modeled ordering seconds short-circuited by hits.");
    let _ = writeln!(out, "paramd_cache_saved_seconds_total {}", c.saved_secs);

    // Persistent-tier families appear only once a persist dir is
    // attached (`serve --persist-dir`), mirroring the report section.
    if let Some(pm) = &m.shards.persist {
        help(&mut out, "paramd_cache_warm_start_entries", "gauge", "Entries replayed from disk at the last open.");
        let _ = writeln!(out, "paramd_cache_warm_start_entries {}", pm.warm_start_entries);
        help(&mut out, "paramd_cache_recovered_bytes", "gauge", "Payload bytes replayed from disk at the last open.");
        let _ = writeln!(out, "paramd_cache_recovered_bytes {}", pm.recovered_bytes);
        help(&mut out, "paramd_cache_recovery_rejects_total", "counter", "Torn or corrupt records quarantined at recovery/compaction.");
        let _ = writeln!(out, "paramd_cache_recovery_rejects_total {}", pm.recovery_rejects);
        help(&mut out, "paramd_cache_persist_appends_total", "counter", "Frames appended and fsynced to the record log.");
        let _ = writeln!(out, "paramd_cache_persist_appends_total {}", pm.appended_records);
        help(&mut out, "paramd_cache_persist_flush_lag", "gauge", "Frames waiting in the flusher's dirty queue.");
        let _ = writeln!(out, "paramd_cache_persist_flush_lag {}", pm.flush_lag);
        help(&mut out, "paramd_cache_persist_flush_panics_total", "counter", "Flusher batches lost to a contained panic.");
        let _ = writeln!(out, "paramd_cache_persist_flush_panics_total {}", pm.flush_panics);
        help(&mut out, "paramd_cache_persist_snapshots_total", "counter", "Compacted snapshots published.");
        let _ = writeln!(out, "paramd_cache_persist_snapshots_total {}", pm.snapshots);
        help(&mut out, "paramd_cache_persist_snapshot_seconds_total", "counter", "Wall seconds spent compacting snapshots.");
        let _ = writeln!(out, "paramd_cache_persist_snapshot_seconds_total {}", pm.snapshot_secs);
        help(&mut out, "paramd_cache_persist_log_bytes", "gauge", "Durable record-log length after the last flush.");
        let _ = writeln!(out, "paramd_cache_persist_log_bytes {}", pm.log_bytes);
    }

    out
}

/// Render a finite float as JSON (JSON has no NaN/Inf; degenerate values
/// collapse to 0).
fn jf(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".into()
    }
}

/// Render the snapshot as one JSON document (the machine-readable twin of
/// [`prometheus`]); always passes [`crate::telemetry::validate_json`].
pub fn json_snapshot(m: &Metrics) -> String {
    let mut out = String::with_capacity(2048);
    out.push_str("{\"methods\":[");
    for (i, (name, e)) in m.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"method\":\"{name}\",\"requests\":{},\"mean_latency\":{},\
             \"p95_latency\":{},\"mean_wait\":{},\"mean_service\":{},\"fill\":{}}}",
            e.requests,
            jf(e.mean_latency()),
            jf(e.p95_latency()),
            jf(e.mean_wait()),
            jf(e.mean_service()),
            e.total_fill
        );
    }
    let p = &m.pipeline;
    let _ = write!(
        out,
        "],\"pipeline\":{{\"submitted\":{},\"completed\":{},\"cancelled\":{},\
         \"failed\":{},\"rejected\":{},\"deadline_exceeded\":{},\
         \"queue_depth\":{},\"queue_depth_peak\":{},\"arena_evictions\":{}}}",
        p.submitted,
        p.completed,
        p.cancelled,
        p.failed,
        p.rejected,
        p.deadline_exceeded,
        p.queue_depth,
        p.queue_depth_peak,
        p.arena_evictions
    );
    let sh = &m.shards;
    let _ = write!(
        out,
        ",\"shards\":{{\"requests\":{},\"components\":{},\"busy_peak\":{},\
         \"gc_count\":{},\"gc_secs\":{},\"rereduce_passes\":{},\
         \"claim_failures\":{},\"shed_hybrid\":{},\"shed_rereduce\":{},\
         \"shed_sequential\":{},\"per_shard\":[",
        sh.requests,
        sh.components,
        sh.busy_peak,
        sh.gc_count,
        jf(sh.gc_secs),
        sh.rereduce_passes,
        sh.claim_failures,
        sh.shed_hybrid,
        sh.shed_rereduce,
        sh.shed_sequential
    );
    for (i, st) in sh.per_shard.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"shard\":{i},\"threads\":{},\"jobs\":{},\"busy_secs\":{},\
             \"busy_p95_secs\":{}}}",
            st.threads,
            st.jobs,
            jf(st.busy_secs),
            jf(st.busy_p95_secs)
        );
    }
    let c = &m.cache;
    let _ = write!(
        out,
        "]}},\"cache\":{{\"hits\":{},\"misses\":{},\"evictions\":{},\
         \"bytes\":{},\"budget_bytes\":{},\"saved_secs\":{}",
        c.hits,
        c.misses,
        c.evictions,
        c.bytes,
        c.budget_bytes,
        jf(c.saved_secs)
    );
    if let Some(pm) = &m.shards.persist {
        let _ = write!(
            out,
            ",\"persist\":{{\"warm_start_entries\":{},\"recovered_bytes\":{},\
             \"recovery_rejects\":{},\"version_drops\":{},\"ttl_drops\":{},\
             \"appended_records\":{},\"flush_lag\":{},\"flush_panics\":{},\
             \"io_errors\":{},\"snapshots\":{},\"snapshot_secs\":{},\
             \"log_bytes\":{},\"snapshot_bytes\":{}}}",
            pm.warm_start_entries,
            pm.recovered_bytes,
            pm.recovery_rejects,
            pm.version_drops,
            pm.ttl_drops,
            pm.appended_records,
            pm.flush_lag,
            pm.flush_panics,
            pm.io_errors,
            pm.snapshots,
            jf(pm.snapshot_secs),
            pm.log_bytes,
            pm.snapshot_bytes
        );
    }
    out.push_str("}}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_metrics() -> Metrics {
        let mut m = Metrics::default();
        m.record_split("paramd", 0.1, 0.4, Some(100));
        m.record_split("paramd", 0.2, 0.3, Some(50));
        m.record("amd", 0.25, None);
        m.pipeline.submitted = 3;
        m.pipeline.completed = 2;
        m.pipeline.rejected = 4;
        m.pipeline.deadline_exceeded = 1;
        m.shards.requests = 3;
        m.shards.claim_failures = 7;
        m.shards.shed_hybrid = 1;
        m.shards.shed_rereduce = 2;
        m.shards.shed_sequential = 5;
        m.shards.per_shard.push(crate::ordering::shard::ShardStat {
            threads: 4,
            jobs: 3,
            busy_secs: 0.5,
            busy_p95_secs: 0.2,
        });
        m.cache.hits = 1;
        m.cache.budget_bytes = 1 << 20;
        m
    }

    #[test]
    fn prometheus_page_exposes_every_family() {
        let page = prometheus(&sample_metrics());
        for family in [
            "paramd_requests_total{method=\"paramd\"} 2",
            "paramd_request_latency_seconds{method=\"paramd\",quantile=\"0.95\"}",
            "paramd_request_latency_seconds_count{method=\"paramd\"} 2",
            "paramd_pipeline_submitted_total 3",
            "paramd_pipeline_rejected_total 4",
            "paramd_pipeline_deadline_exceeded_total 1",
            "paramd_queue_depth 0",
            "paramd_claim_failures_total 7",
            "paramd_shed_hybrid_total 1",
            "paramd_shed_rereduce_total 2",
            "paramd_shed_sequential_total 5",
            "paramd_shard_jobs_total{shard=\"0\"} 3",
            "paramd_shard_busy_p95_seconds{shard=\"0\"} 0.2",
            "paramd_cache_hits_total 1",
        ] {
            assert!(page.contains(family), "missing {family:?} in:\n{page}");
        }
        // Every non-comment line is `name[{labels}] value`.
        for line in page.lines().filter(|l| !l.starts_with('#')) {
            let (name, value) = line.rsplit_once(' ').expect("metric line has a value");
            assert!(name.starts_with("paramd_"), "family prefix: {line}");
            assert!(value.parse::<f64>().is_ok(), "numeric value: {line}");
        }
    }

    #[test]
    fn latency_summary_sum_is_exact() {
        let m = sample_metrics();
        let page = prometheus(&m);
        let sum_line = page
            .lines()
            .find(|l| l.starts_with("paramd_request_latency_seconds_sum{method=\"paramd\"}"))
            .unwrap();
        let v: f64 = sum_line.rsplit_once(' ').unwrap().1.parse().unwrap();
        assert!((v - 1.0).abs() < 1e-9, "0.5 + 0.5 = 1.0 exactly: {sum_line}");
    }

    #[test]
    fn persist_families_appear_only_with_an_attached_tier() {
        let mut m = sample_metrics();
        assert!(
            !prometheus(&m).contains("paramd_cache_warm_start_entries"),
            "no persist tier, no persist families"
        );
        assert!(!json_snapshot(&m).contains("\"persist\""));
        m.shards.persist = Some(crate::ordering::cache::persist::PersistMetrics {
            warm_start_entries: 5,
            recovered_bytes: 4096,
            recovery_rejects: 1,
            appended_records: 9,
            ..Default::default()
        });
        let page = prometheus(&m);
        for family in [
            "paramd_cache_warm_start_entries 5",
            "paramd_cache_recovered_bytes 4096",
            "paramd_cache_recovery_rejects_total 1",
            "paramd_cache_persist_appends_total 9",
            "paramd_cache_persist_flush_lag 0",
            "paramd_cache_persist_log_bytes 0",
        ] {
            assert!(page.contains(family), "missing {family:?} in:\n{page}");
        }
        for line in page.lines().filter(|l| !l.starts_with('#')) {
            let (name, value) = line.rsplit_once(' ').expect("metric line has a value");
            assert!(name.starts_with("paramd_"), "family prefix: {line}");
            assert!(value.parse::<f64>().is_ok(), "numeric value: {line}");
        }
        let j = json_snapshot(&m);
        crate::telemetry::validate_json(&j).expect("snapshot must stay valid JSON");
        assert!(j.contains("\"persist\":{\"warm_start_entries\":5"));
        assert!(j.contains("\"recovery_rejects\":1"));
    }

    #[test]
    fn json_snapshot_is_valid_and_carries_the_counters() {
        let j = json_snapshot(&sample_metrics());
        crate::telemetry::validate_json(&j).expect("snapshot must be valid JSON");
        assert!(j.contains("\"method\":\"paramd\""));
        assert!(j.contains("\"claim_failures\":7"));
        assert!(j.contains("\"rejected\":4"));
        assert!(j.contains("\"deadline_exceeded\":1"));
        assert!(j.contains("\"shed_sequential\":5"));
        assert!(j.contains("\"busy_p95_secs\":0.2"));
        // Empty metrics render a valid document too.
        crate::telemetry::validate_json(&json_snapshot(&Metrics::default())).unwrap();
    }
}
