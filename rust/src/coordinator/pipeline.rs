//! Plumbing of the async ordering pipeline: the bounded MPMC job queue
//! the service enqueues onto, and the [`Ticket`] a submitter holds while
//! its request flows through the scheduler.
//!
//! See the [`coordinator`](crate::coordinator) module docs for the
//! request lifecycle; this module only defines the mechanisms.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::{OrderReply, OrderRequest};
use crate::util::timer::Timer;

/// A bounded MPMC queue. `push` blocks while the queue is full — this is
/// the pipeline's backpressure: submitters stall instead of the service
/// buffering unboundedly. `pop` blocks while empty and returns `None`
/// once the queue is closed *and* drained, so consumers finish every
/// accepted job before exiting.
pub(crate) struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

struct QueueState<T> {
    items: VecDeque<T>,
    cap: usize,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub(crate) fn new(cap: usize) -> Self {
        Self {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                cap: cap.max(1),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Enqueue, blocking while full. Returns the resulting depth, or the
    /// item back if the queue has been closed.
    pub(crate) fn push(&self, item: T) -> Result<usize, T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(item);
            }
            if st.items.len() < st.cap {
                st.items.push_back(item);
                let depth = st.items.len();
                drop(st);
                self.not_empty.notify_one();
                return Ok(depth);
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Enqueue a whole batch, blocking while full. The queue is locked
    /// once per chunk of available slots rather than once per item — the
    /// batched-submission fast path — and consumers are woken after each
    /// chunk so they can drain while the tail of the batch waits.
    /// Returns the final depth, or the unpushed remainder if the queue
    /// closed mid-batch.
    pub(crate) fn push_all(&self, items: Vec<T>) -> Result<usize, Vec<T>> {
        let mut it = items.into_iter();
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(it.collect());
            }
            let mut pushed = false;
            while st.items.len() < st.cap {
                match it.next() {
                    Some(x) => {
                        st.items.push_back(x);
                        pushed = true;
                    }
                    None => {
                        let depth = st.items.len();
                        drop(st);
                        if pushed {
                            self.not_empty.notify_all();
                        }
                        return Ok(depth);
                    }
                }
            }
            // Queue full with batch remaining: wake the consumers, then
            // wait for them to free slots.
            self.not_empty.notify_all();
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Dequeue, blocking while empty; `None` once closed and drained.
    pub(crate) fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub(crate) fn capacity(&self) -> usize {
        self.state.lock().unwrap().cap
    }

    pub(crate) fn set_capacity(&self, cap: usize) {
        self.state.lock().unwrap().cap = cap.max(1);
        self.not_full.notify_all();
    }

    /// Stop accepting pushes and wake everyone; queued items still drain
    /// through `pop`.
    pub(crate) fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }
}

/// Where a queued request's body lives.
pub(crate) enum RequestSlot {
    /// Submitted by value through `Service::submit`.
    Owned(OrderRequest),
    /// Lifetime-erased borrow from a blocking `Service::order` caller,
    /// which waits on the ticket before releasing the borrow.
    Borrowed(BorrowedRequest),
}

pub(crate) struct BorrowedRequest(*const OrderRequest);

// SAFETY: the pointer crosses to the scheduler thread, but the pointee
// is owned by an `order()` caller that blocks on the ticket until the
// scheduler's last access (fulfill/fail happens strictly after). Shared
// `&OrderRequest` access from another thread additionally requires
// `OrderRequest: Sync`, enforced at compile time below so a future
// interior-mutability field can't silently introduce a data race.
unsafe impl Send for BorrowedRequest {}

const _: () = {
    const fn assert_sync<T: Sync>() {}
    assert_sync::<OrderRequest>()
};

impl BorrowedRequest {
    /// SAFETY: the caller must outlive every scheduler access, which
    /// `Service::order` guarantees by blocking on the ticket.
    pub(crate) unsafe fn new(req: &OrderRequest) -> Self {
        Self(req as *const OrderRequest)
    }
}

impl RequestSlot {
    pub(crate) fn get(&self) -> &OrderRequest {
        match self {
            RequestSlot::Owned(req) => req,
            // SAFETY: see `BorrowedRequest::new`.
            RequestSlot::Borrowed(b) => unsafe { &*b.0 },
        }
    }
}

/// One queued request: its body, the submitter's ticket, and the queue
/// stopwatch (wait-vs-service latency split).
pub(crate) struct PipelineJob {
    pub(crate) req: RequestSlot,
    pub(crate) ticket: Arc<TicketInner>,
    pub(crate) queued: Timer,
}

#[derive(Debug)]
enum TicketState {
    Pending,
    Ready(OrderReply),
    Taken,
    Failed(String),
}

/// Shared half of a ticket: the scheduler resolves it, the submitter
/// waits on it, and the cancel flag flows down into the ordering rounds.
pub(crate) struct TicketInner {
    state: Mutex<TicketState>,
    cv: Condvar,
    cancel: AtomicBool,
}

impl TicketInner {
    pub(crate) fn fulfill(&self, reply: OrderReply) {
        let mut st = self.state.lock().unwrap();
        if matches!(*st, TicketState::Pending) {
            *st = TicketState::Ready(reply);
            drop(st);
            self.cv.notify_all();
        }
    }

    pub(crate) fn fail(&self, why: impl Into<String>) {
        let mut st = self.state.lock().unwrap();
        if matches!(*st, TicketState::Pending) {
            *st = TicketState::Failed(why.into());
            drop(st);
            self.cv.notify_all();
        }
    }

    pub(crate) fn is_cancelled(&self) -> bool {
        self.cancel.load(Relaxed)
    }

    /// The flag threaded into `ParAmd::order_into_cancellable`.
    pub(crate) fn cancel_flag(&self) -> &AtomicBool {
        &self.cancel
    }
}

/// Returned by [`Ticket::wait_deadline`] when the reply did not arrive
/// in time; the request has been cancelled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WaitTimeout;

impl std::fmt::Display for WaitTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("order ticket deadline expired; request cancelled")
    }
}

impl std::error::Error for WaitTimeout {}

/// A claim on one submitted ordering request. [`Ticket::wait`] blocks
/// for the reply ([`Ticket::wait_deadline`] bounds the wait and cancels
/// on expiry); [`Ticket::try_get`] polls. **Dropping a ticket without
/// consuming it cancels the request**: queued jobs are skipped outright
/// and a running ParAMD job aborts at its next round boundary, freeing
/// the shared pool for live requests.
pub struct Ticket {
    inner: Arc<TicketInner>,
}

impl Ticket {
    pub(crate) fn new() -> (Ticket, Arc<TicketInner>) {
        let inner = Arc::new(TicketInner {
            state: Mutex::new(TicketState::Pending),
            cv: Condvar::new(),
            cancel: AtomicBool::new(false),
        });
        (
            Ticket {
                inner: Arc::clone(&inner),
            },
            inner,
        )
    }

    /// Block until the reply arrives and take it.
    ///
    /// Panics if the pipeline abandoned the request (service shut down,
    /// the request was cancelled, or the ordering panicked) — the same
    /// contract the synchronous `order()` shim has always had.
    pub fn wait(self) -> OrderReply {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            match std::mem::replace(&mut *st, TicketState::Taken) {
                TicketState::Ready(reply) => return reply,
                TicketState::Pending => {
                    *st = TicketState::Pending;
                    st = self.inner.cv.wait(st).unwrap();
                }
                TicketState::Failed(why) => {
                    drop(st);
                    panic!("order ticket failed: {why}");
                }
                TicketState::Taken => {
                    drop(st);
                    panic!("order ticket already consumed");
                }
            }
        }
    }

    /// [`Self::wait`] with a deadline: block at most `timeout` for the
    /// reply. **On expiry the request is cancelled** (the consumed
    /// ticket withdraws interest exactly like a drop: a queued job is
    /// skipped, a running ParAMD job aborts at its next round boundary)
    /// and `Err(WaitTimeout)` is returned — the caller's tail latency is
    /// bounded and the shared pools are not left grinding on an answer
    /// nobody wants. A reply that lands right at the deadline is still
    /// taken and returned.
    ///
    /// Panics like [`Self::wait`] if the pipeline abandoned the request
    /// before the deadline.
    pub fn wait_deadline(self, timeout: Duration) -> Result<OrderReply, WaitTimeout> {
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.state.lock().unwrap();
        loop {
            match std::mem::replace(&mut *st, TicketState::Taken) {
                TicketState::Ready(reply) => return Ok(reply),
                TicketState::Pending => {
                    *st = TicketState::Pending;
                    let now = Instant::now();
                    if now >= deadline {
                        drop(st);
                        self.inner.cancel.store(true, Relaxed);
                        return Err(WaitTimeout);
                    }
                    st = self.inner.cv.wait_timeout(st, deadline - now).unwrap().0;
                }
                TicketState::Failed(why) => {
                    drop(st);
                    panic!("order ticket failed: {why}");
                }
                TicketState::Taken => {
                    drop(st);
                    panic!("order ticket already consumed");
                }
            }
        }
    }

    /// Non-blocking poll: `Some(reply)` once ready (takes it), `None`
    /// while pending. Panics like [`Self::wait`] on an abandoned ticket
    /// or a double take.
    pub fn try_get(&self) -> Option<OrderReply> {
        let mut st = self.inner.state.lock().unwrap();
        match std::mem::replace(&mut *st, TicketState::Taken) {
            TicketState::Ready(reply) => Some(reply),
            TicketState::Pending => {
                *st = TicketState::Pending;
                None
            }
            TicketState::Failed(why) => {
                drop(st);
                panic!("order ticket failed: {why}");
            }
            TicketState::Taken => {
                drop(st);
                panic!("order ticket already consumed");
            }
        }
    }

    /// Whether the ticket has resolved (reply ready, taken, or failed).
    pub fn is_finished(&self) -> bool {
        !matches!(*self.inner.state.lock().unwrap(), TicketState::Pending)
    }

    /// Explicitly cancel the request without dropping the ticket. After
    /// cancellation the pipeline may fail the ticket, so `wait`/`try_get`
    /// can panic; poll [`Self::is_finished`] if the race matters.
    pub fn cancel(&self) {
        self.inner.cancel.store(true, Relaxed);
    }
}

impl Drop for Ticket {
    fn drop(&mut self) {
        // Withdraw interest; harmless if the reply was already taken.
        self.inner.cancel.store(true, Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_queue_push_pop_fifo() {
        let q = BoundedQueue::new(4);
        assert_eq!(q.push(1).unwrap(), 1);
        assert_eq!(q.push(2).unwrap(), 2);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn bounded_queue_blocks_at_capacity() {
        use std::sync::atomic::AtomicBool;
        let q = BoundedQueue::new(1);
        q.push(0u32).unwrap();
        let pushed = AtomicBool::new(false);
        std::thread::scope(|s| {
            let q = &q;
            let pushed = &pushed;
            s.spawn(move || {
                q.push(1).unwrap(); // blocks until the pop below
                pushed.store(true, Relaxed);
            });
            std::thread::sleep(std::time::Duration::from_millis(30));
            assert!(!pushed.load(Relaxed), "push must block while full");
            assert_eq!(q.pop(), Some(0));
        });
        assert!(pushed.load(Relaxed));
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn closed_queue_rejects_pushes_and_drains_pops() {
        let q = BoundedQueue::new(4);
        q.push(7u8).unwrap();
        q.close();
        assert_eq!(q.push(8), Err(8));
        assert_eq!(q.pop(), Some(7), "accepted items still drain");
        assert_eq!(q.pop(), None, "closed + empty ends the consumer");
    }

    #[test]
    fn ticket_roundtrip_and_drop_cancels() {
        let (ticket, inner) = Ticket::new();
        assert!(!ticket.is_finished());
        assert!(ticket.try_get().is_none());
        inner.fulfill(OrderReply {
            perm: vec![0],
            fill_in: None,
            pre_secs: 0.0,
            order_secs: 0.0,
            total_secs: 0.0,
            rounds: 0,
            gc_count: 0,
            modeled_time: 0.0,
        });
        assert!(ticket.is_finished());
        let reply = ticket.wait();
        assert_eq!(reply.perm, vec![0]);

        let (ticket, inner) = Ticket::new();
        assert!(!inner.is_cancelled());
        drop(ticket);
        assert!(inner.is_cancelled(), "dropping a ticket must cancel it");
    }

    #[test]
    #[should_panic(expected = "order ticket failed")]
    fn failed_ticket_panics_on_wait() {
        let (ticket, inner) = Ticket::new();
        inner.fail("scheduler shut down");
        ticket.wait();
    }

    #[test]
    fn push_all_fits_in_one_reservation() {
        let q = BoundedQueue::new(8);
        assert_eq!(q.push_all(vec![1, 2, 3]).unwrap(), 3);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn push_all_larger_than_capacity_drains_through() {
        // cap 2, batch 5: the pusher must hand chunks to a concurrent
        // consumer instead of deadlocking.
        let q = BoundedQueue::new(2);
        std::thread::scope(|s| {
            let q = &q;
            s.spawn(move || {
                assert!(q.push_all((0..5u32).collect()).is_ok());
            });
            let mut got = Vec::new();
            for _ in 0..5 {
                got.push(q.pop().unwrap());
            }
            assert_eq!(got, vec![0, 1, 2, 3, 4], "batch order preserved");
        });
    }

    #[test]
    fn push_all_returns_remainder_when_closed() {
        let q = BoundedQueue::new(4);
        q.close();
        assert_eq!(q.push_all(vec![7u8, 8]), Err(vec![7, 8]));
    }

    #[test]
    fn wait_deadline_returns_ready_replies() {
        let (ticket, inner) = Ticket::new();
        inner.fulfill(OrderReply {
            perm: vec![0],
            fill_in: None,
            pre_secs: 0.0,
            order_secs: 0.0,
            total_secs: 0.0,
            rounds: 0,
            gc_count: 0,
            modeled_time: 0.0,
        });
        let reply = ticket
            .wait_deadline(Duration::from_secs(5))
            .expect("ready ticket resolves immediately");
        assert_eq!(reply.perm, vec![0]);
    }

    #[test]
    fn wait_deadline_expiry_cancels_the_request() {
        let (ticket, inner) = Ticket::new();
        let err = ticket
            .wait_deadline(Duration::from_millis(5))
            .expect_err("pending ticket must time out");
        assert_eq!(err, WaitTimeout);
        assert!(inner.is_cancelled(), "expiry must cancel the request");
    }
}
