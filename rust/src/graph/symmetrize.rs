//! The `|A| + |A^T|` pre-processing step (paper §4.2).
//!
//! SuiteSparse AMD always forms the symmetrized pattern before ordering so
//! that nonsymmetric inputs (UMFPACK-style use) are handled; the paper
//! parallelizes this step "using simple atomic operations" and reports it in
//! the runtime breakdown (Figure 4.1), where it is a scaling bottleneck.
//!
//! We provide both the sequential version and a faithful parallel version:
//! per-row counts accumulated with atomic fetch-adds, then a parallel
//! scatter into the output CSR, then per-row sort+dedup in parallel.

use std::sync::atomic::{AtomicUsize, Ordering as AO};

use crate::graph::csr::{CsrMatrix, SymGraph};
use crate::util::chunk_range;

/// Sequential symmetrization: pattern of `A + A^T` with the diagonal
/// dropped, as a [`SymGraph`].
pub fn symmetrize(a: &CsrMatrix) -> SymGraph {
    assert_eq!(a.nrows, a.ncols, "ordering needs a square matrix");
    let n = a.nrows;
    let mut edges = Vec::with_capacity(a.nnz());
    for r in 0..n {
        for &c in a.row(r) {
            let c = c as usize;
            if c != r {
                edges.push((r, c));
            }
        }
    }
    SymGraph::from_edges(n, &edges)
}

/// Parallel symmetrization with `t` threads, mirroring the paper's
/// atomic-based implementation. Deterministic output (rows are sorted and
/// deduplicated at the end).
pub fn symmetrize_parallel(a: &CsrMatrix, t: usize) -> SymGraph {
    assert_eq!(a.nrows, a.ncols, "ordering needs a square matrix");
    let n = a.nrows;
    let t = t.max(1);
    if t == 1 || n < 1024 {
        return symmetrize(a);
    }

    // Pass 1: atomic per-row counts of directed arcs in both directions.
    let count: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
    std::thread::scope(|s| {
        for tid in 0..t {
            let count = &count;
            s.spawn(move || {
                let (lo, hi) = chunk_range(n, t, tid);
                for r in lo..hi {
                    for &c in a.row(r) {
                        let c = c as usize;
                        if c != r {
                            count[r].fetch_add(1, AO::Relaxed);
                            count[c].fetch_add(1, AO::Relaxed);
                        }
                    }
                }
            });
        }
    });

    // Prefix sum (sequential; O(n)).
    let mut rowptr = vec![0usize; n + 1];
    for i in 0..n {
        rowptr[i + 1] = rowptr[i] + count[i].load(AO::Relaxed);
    }
    let total = rowptr[n];

    // Pass 2: parallel scatter with atomic cursors.
    let cursor: Vec<AtomicUsize> = rowptr[..n].iter().map(|&p| AtomicUsize::new(p)).collect();
    let colind: Vec<std::sync::atomic::AtomicI32> =
        (0..total).map(|_| std::sync::atomic::AtomicI32::new(-1)).collect();
    std::thread::scope(|s| {
        for tid in 0..t {
            let cursor = &cursor;
            let colind = &colind;
            s.spawn(move || {
                let (lo, hi) = chunk_range(n, t, tid);
                for r in lo..hi {
                    for &c in a.row(r) {
                        let c = c as usize;
                        if c != r {
                            let p = cursor[r].fetch_add(1, AO::Relaxed);
                            colind[p].store(c as i32, AO::Relaxed);
                            let q = cursor[c].fetch_add(1, AO::Relaxed);
                            colind[q].store(r as i32, AO::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let mut colind: Vec<i32> = colind.into_iter().map(|a| a.into_inner()).collect();

    // Pass 3: parallel per-row sort + dedup, then sequential compaction.
    let dedup_len: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
    {
        let colind_ptr = ColindPtr(colind.as_mut_ptr());
        std::thread::scope(|s| {
            for tid in 0..t {
                let dedup_len = &dedup_len;
                let rowptr = &rowptr;
                let cp = &colind_ptr;
                s.spawn(move || {
                    let (lo, hi) = chunk_range(n, t, tid);
                    for r in lo..hi {
                        // SAFETY: row ranges [rowptr[r], rowptr[r+1]) are
                        // disjoint across rows, and rows are partitioned
                        // across threads.
                        let row = unsafe {
                            std::slice::from_raw_parts_mut(
                                cp.0.add(rowptr[r]),
                                rowptr[r + 1] - rowptr[r],
                            )
                        };
                        row.sort_unstable();
                        let mut w = 0usize;
                        for i in 0..row.len() {
                            if w == 0 || row[i] != row[w - 1] {
                                row[w] = row[i];
                                w += 1;
                            }
                        }
                        dedup_len[r].store(w, AO::Relaxed);
                    }
                });
            }
        });
    }

    let mut out_rowptr = vec![0usize; n + 1];
    for i in 0..n {
        out_rowptr[i + 1] = out_rowptr[i] + dedup_len[i].load(AO::Relaxed);
    }
    let mut out_colind = vec![0i32; out_rowptr[n]];
    for r in 0..n {
        let len = dedup_len[r].load(AO::Relaxed);
        out_colind[out_rowptr[r]..out_rowptr[r] + len]
            .copy_from_slice(&colind[rowptr[r]..rowptr[r] + len]);
    }

    SymGraph {
        n,
        rowptr: out_rowptr,
        colind: out_colind,
    }
}

/// Raw-pointer wrapper so disjoint row slices can be mutated from multiple
/// threads (safe by the row-partition argument above).
struct ColindPtr(*mut i32);
unsafe impl Sync for ColindPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_square(n: usize, nnz: usize, seed: u64) -> CsrMatrix {
        let mut rng = Rng::new(seed);
        let trip: Vec<(usize, usize, f64)> = (0..nnz)
            .map(|_| (rng.below(n), rng.below(n), 1.0))
            .collect();
        CsrMatrix::from_triplets(n, n, &trip)
    }

    #[test]
    fn symmetrize_small_known() {
        // A = [[1, x], [0, 1]] -> pattern of A+A^T has edge (0,1).
        let a = CsrMatrix::from_triplets(2, 2, &[(0, 0, 1.0), (0, 1, 5.0), (1, 1, 1.0)]);
        let g = symmetrize(&a);
        g.validate().unwrap();
        assert_eq!(g.nedges(), 1);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[0]);
    }

    #[test]
    fn parallel_matches_sequential() {
        for seed in 0..3 {
            let a = random_square(2000, 12_000, seed);
            let g1 = symmetrize(&a);
            for t in [2, 4, 8] {
                let g2 = symmetrize_parallel(&a, t);
                assert_eq!(g1, g2, "t={t} seed={seed}");
            }
            g1.validate().unwrap();
        }
    }

    #[test]
    fn parallel_small_falls_back() {
        let a = random_square(50, 200, 9);
        let g1 = symmetrize(&a);
        let g2 = symmetrize_parallel(&a, 8);
        assert_eq!(g1, g2);
    }

    #[test]
    fn empty_matrix() {
        let a = CsrMatrix::from_triplets(5, 5, &[]);
        let g = symmetrize(&a);
        g.validate().unwrap();
        assert_eq!(g.nnz(), 0);
    }
}
