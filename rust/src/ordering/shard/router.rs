//! Component→shard routing: deterministic size-classed placement.
//!
//! Shards are *size-classed*: shard 0 is the **wide** runtime (most
//! worker threads), the rest are **narrow**. Routing works in estimated
//! finish time — a shard's queued **work** divided by its thread count —
//! so a narrow shard is only preferred when it genuinely finishes the
//! job earlier. Work is measured in [`work_estimate`] units computed
//! from the **post-reduction** vertex/edge counts: the reduction layer
//! can shrink a component 2–10×, and routing by the stale pre-reduction
//! size would systematically overestimate reduced components and skew
//! placement (ISSUE 4 satellite fix).
//!
//! - [`plan`] places the components of a decomposed request: the
//!   heaviest component is pinned to the wide shard (it dominates the
//!   critical path and deserves the widest pool), the rest follow the
//!   classic heaviest-first greedy (LPT) onto the shard with the least
//!   estimated finish time, ties to the lowest shard id.
//! - [`pick_shard`] places a whole connected request on the
//!   least-finish-time shard, so *concurrent* requests spread across
//!   shards instead of serializing behind one runtime.
//!
//! Both are pure functions of their load snapshot, so placement is
//! deterministic and unit-testable.

/// Scheduling work units of an ordering job: vertices plus undirected
/// edges of the graph that will actually be ordered (the reduced kernel
/// when reduction fired, the original graph otherwise). A linear proxy
/// for AMD cost that is cheap, monotone in both inputs, and — unlike a
/// vertex count alone — not fooled by twin-compressed kernels whose
/// remaining edges dominate.
pub fn work_estimate(vertices: usize, edges: usize) -> u64 {
    (vertices + edges) as u64
}

/// Estimated finish time of putting `work` more units on a shard.
fn finish_time(load: f64, work: u64, threads: usize) -> f64 {
    load + work as f64 / threads.max(1) as f64
}

/// Least-finish-time shard for one job of `work` units. `loads[s]` is
/// shard `s`'s pending+active work.
pub fn pick_shard(work: u64, loads: &[u64], threads: &[usize]) -> usize {
    debug_assert_eq!(loads.len(), threads.len());
    debug_assert!(!threads.is_empty());
    let mut best = 0usize;
    let mut best_cost = f64::INFINITY;
    for s in 0..threads.len() {
        let cost = finish_time(loads[s] as f64 / threads[s].max(1) as f64, work, threads[s]);
        if cost < best_cost {
            best_cost = cost;
            best = s;
        }
    }
    best
}

/// Assign the components of one request to shards. `work[c]` is
/// component `c`'s post-reduction [`work_estimate`] (any order — the
/// reduction layer breaks the ascending-size guarantee component ids
/// have); the returned vector maps component id → shard id.
pub fn plan(work: &[u64], loads: &[u64], threads: &[usize]) -> Vec<usize> {
    let shards = threads.len();
    debug_assert!(shards > 0);
    let mut assign = vec![0usize; work.len()];
    if work.is_empty() || shards == 1 {
        return assign;
    }
    let mut load: Vec<f64> = loads
        .iter()
        .zip(threads)
        .map(|(&l, &t)| l as f64 / t.max(1) as f64)
        .collect();
    // Heaviest-first (LPT) schedule; ties broken by component id so the
    // plan is deterministic.
    let mut order: Vec<usize> = (0..work.len()).collect();
    order.sort_by_key(|&c| (std::cmp::Reverse(work[c]), c));
    for (k, &c) in order.iter().enumerate() {
        let s = if k == 0 {
            0 // size-classing: the heaviest component gets the wide shard
        } else {
            let mut best = 0usize;
            let mut best_cost = f64::INFINITY;
            for s in 0..shards {
                let cost = finish_time(load[s], work[c], threads[s]);
                if cost < best_cost {
                    best_cost = cost;
                    best = s;
                }
            }
            best
        };
        assign[c] = s;
        load[s] += work[c] as f64 / threads[s].max(1) as f64;
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heaviest_component_lands_on_the_wide_shard() {
        // The heaviest must go to shard 0 even though shard 0 is already
        // the most loaded.
        let assign = plan(&[10, 20, 1000], &[500, 0, 0], &[8, 2, 2]);
        assert_eq!(assign[2], 0);
    }

    #[test]
    fn unsorted_work_still_pins_the_heaviest_to_shard_zero() {
        // Post-reduction work is not ascending in component id: a large
        // component can reduce below a small irreducible one.
        let assign = plan(&[40, 900, 15, 60], &[0, 0], &[4, 2]);
        assert_eq!(assign[1], 0, "argmax work → wide shard");
    }

    #[test]
    fn equal_components_spread_over_equal_shards() {
        let assign = plan(&[100, 100, 100, 100], &[0, 0, 0, 0], &[2, 2, 2, 2]);
        let mut sorted = assign.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3], "one component per shard");
    }

    #[test]
    fn plan_is_deterministic() {
        let a = plan(&[5, 9, 9, 40], &[3, 0, 7], &[4, 2, 2]);
        let b = plan(&[5, 9, 9, 40], &[3, 0, 7], &[4, 2, 2]);
        assert_eq!(a, b);
    }

    #[test]
    fn single_shard_takes_everything() {
        assert_eq!(plan(&[1, 2, 3], &[9], &[4]), vec![0, 0, 0]);
    }

    #[test]
    fn pick_shard_prefers_idle_over_loaded() {
        assert_eq!(pick_shard(100, &[1000, 0], &[4, 4]), 1);
        // All idle: the wide shard wins (fastest estimated finish).
        assert_eq!(pick_shard(100, &[0, 0], &[4, 2]), 0);
    }

    #[test]
    fn pick_shard_accounts_for_width() {
        // Same load, but shard 0 is twice as wide — it finishes earlier.
        assert_eq!(pick_shard(500, &[400, 400], &[8, 4]), 0);
    }

    #[test]
    fn work_estimate_counts_vertices_and_edges() {
        assert_eq!(work_estimate(10, 0), 10);
        assert_eq!(work_estimate(10, 25), 35);
        assert!(
            work_estimate(100, 4000) > work_estimate(300, 600),
            "edge-heavy kernels outweigh vertex-heavy ones"
        );
    }
}
