//! ParAMD — the paper's contribution (§3): parallel approximate minimum
//! degree via multiple elimination on distance-2 independent sets.
//!
//! Algorithm 3.3 round structure, executed by `threads` OS threads
//! synchronized with barriers:
//!
//! 1. every thread publishes its local minimum approximate degree
//!    (`LAMD`, Algorithm 3.1) — the global `amd` is their minimum;
//! 2. candidates with degree in `[amd, ⌊mult·amd⌋]` are gathered from the
//!    per-thread degree lists, at most `lim` per thread;
//! 3. one iteration of the distance-2 Luby analog (Algorithm 3.2) selects
//!    a distance-2 independent pivot set `D`;
//! 4. each thread eliminates the pivots it proposed, with concurrent
//!    connection updates (single elbow claim per pivot, §3.3.1) and
//!    concurrent degree lists (§3.3.2);
//! 5. a stop-the-world GC runs at the round boundary if any claim failed.
//!
//! Memory: O(n·t) for the per-thread lists and `w` arrays plus the
//! `1.5×nnz`-style elbow — the paper's §3.5.1 budget.

pub mod cost;
pub mod dist2;
pub mod elim;
pub mod lists;
pub mod shared;
pub mod workspace;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::Barrier;

use crate::graph::csr::SymGraph;
use crate::ordering::{Ordering, OrderingResult, OrderingStats};
use crate::util::chunk_range;
use crate::util::timer::Timer;

use elim::Outcome;
use lists::{Affinity, ThreadLists};
use shared::SharedGraph;
use workspace::{RoundWork, Workspace};

/// ParAMD configuration (paper defaults: `mult = 1.1`,
/// `lim = 8192 / threads`, elbow `1.5`).
#[derive(Clone, Copy, Debug)]
pub struct ParAmd {
    pub threads: usize,
    /// Multiplicative degree-relaxation factor (§3.2).
    pub mult: f64,
    /// Total candidate budget per round; each thread collects at most
    /// `lim_total / threads` (§4.3's heuristic). `0` selects the
    /// scale-adapted default `clamp(n/64, 64, 8192)` — the paper's 8192
    /// was tuned for n ≈ 10⁶–10⁷ (0.03–0.8% of n); keeping the *fraction*
    /// comparable preserves the ~1.1× fill-ratio target at any scale.
    pub lim_total: usize,
    /// Elbow-room factor over nnz (§3.3.1's empirical 1.5).
    pub elbow: f64,
    /// Aggressive element absorption (as in SuiteSparse).
    pub aggressive: bool,
    /// Seed for the Luby priorities.
    pub seed: u64,
    /// §5 future-work extension: dynamically adapt the relaxation factor
    /// when low workload is detected. When the last round's distance-2
    /// set was smaller than the thread count, `mult` is raised (up to
    /// `adaptive_mult_max`); when parallelism is plentiful it decays back
    /// toward the configured base, bounding the fill-quality cost.
    pub adaptive: bool,
    /// Upper bound for the adapted relaxation factor.
    pub adaptive_mult_max: f64,
}

impl ParAmd {
    pub fn new(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
            mult: 1.1,
            lim_total: 0, // auto: clamp(n/64, 64, 8192)
            elbow: 1.5,
            aggressive: true,
            seed: 0x9a_2a_3d,
            adaptive: false,
            adaptive_mult_max: 1.5,
        }
    }

    /// Enable the §5 future-work dynamic-relaxation extension.
    pub fn with_adaptive(mut self) -> Self {
        self.adaptive = true;
        self
    }

    pub fn with_mult(mut self, mult: f64) -> Self {
        self.mult = mult;
        self
    }

    pub fn with_lim_total(mut self, lim: usize) -> Self {
        self.lim_total = lim;
        self
    }

    pub fn with_elbow(mut self, elbow: f64) -> Self {
        self.elbow = elbow;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

impl Ordering for ParAmd {
    fn name(&self) -> &'static str {
        "paramd"
    }

    fn order(&self, g: &SymGraph) -> OrderingResult {
        self.order_detailed(g).0
    }
}

/// Detailed per-run data beyond [`OrderingResult`]: the inputs to the
/// Figure 4.1 / 4.2 analyses and the cost model.
#[derive(Clone, Debug, Default)]
pub struct ParAmdDetail {
    /// `work[r][tid]` — per-round per-thread work counters.
    pub round_work: Vec<Vec<RoundWork>>,
    /// Per-round distance-2 set sizes (Figure 4.2).
    pub set_sizes: Vec<u32>,
    /// Wall-clock seconds per thread spent in selection vs elimination.
    pub select_secs: Vec<f64>,
    pub elim_secs: Vec<f64>,
    /// Modeled parallel speedup from the critical-path cost model.
    pub model_speedup: f64,
}

struct ThreadOutput {
    ws: Workspace,
    elim_log: Vec<(u32, i32)>, // (round, pivot) in local order
    select_secs: f64,
    elim_secs: f64,
}

impl ParAmd {
    /// Run the ordering and return the detailed counters as well.
    pub fn order_detailed(&self, g: &SymGraph) -> (OrderingResult, ParAmdDetail) {
        let n = g.n;
        let t = self.threads.max(1);
        let lim_total = if self.lim_total == 0 {
            (n / 64).clamp(64, 8192)
        } else {
            self.lim_total
        };
        let lim = (lim_total / t).max(1);
        let total_timer = Timer::new();

        if n == 0 {
            return (OrderingResult::new(vec![]), ParAmdDetail::default());
        }

        assert!(
            n < dist2::MAX_VERTICES,
            "ParAMD supports up to 2^24 vertices (priority packing)"
        );
        let sg = SharedGraph::new(g, self.elbow);
        let aff = Affinity::new(n);
        // u64::MAX == "no candidate yet" (stale rounds also read as +∞,
        // see dist2::priority).
        let lmin: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(u64::MAX)).collect();
        let lamds: Vec<AtomicUsize> = (0..t).map(|_| AtomicUsize::new(n)).collect();
        let sizes: Vec<AtomicUsize> = (0..t).map(|_| AtomicUsize::new(0)).collect();
        let progress_stall = AtomicUsize::new(0);
        // Adapted relaxation factor in fixed-point (×1e6), leader-updated.
        let adaptive_mult = AtomicUsize::new((self.mult * 1e6) as usize);
        let poison = std::sync::atomic::AtomicBool::new(false);
        let gc_count = AtomicUsize::new(0);
        let barrier = Barrier::new(t);
        let set_sizes_leader: std::sync::Mutex<Vec<u32>> = std::sync::Mutex::new(Vec::new());

        let outputs: Vec<ThreadOutput> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(t);
            for tid in 0..t {
                let sg = &sg;
                let aff = &aff;
                let lmin = &lmin;
                let lamds = &lamds;
                let sizes = &sizes;
                let barrier = &barrier;
                let progress_stall = &progress_stall;
                let adaptive_mult = &adaptive_mult;
                let poison = &poison;
                let gc_count = &gc_count;
                let set_sizes_leader = &set_sizes_leader;
                let cfg = *self;
                handles.push(scope.spawn(move || {
                    run_thread(
                        tid, t, lim, cfg, g, sg, aff, lmin, lamds, sizes, barrier,
                        progress_stall, adaptive_mult, poison, gc_count, set_sizes_leader,
                    )
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        assert!(
            !poison.load(Relaxed),
            "ParAMD stalled: elbow room exhausted even after GC — increase \
             `elbow` (paper §3.3.1: the 1.5 factor is empirical and \
             user-adjustable)"
        );
        assert_eq!(sg.nel.load(Relaxed), n, "not all columns eliminated");

        // Merge elimination logs: (round, tid, local order) — deterministic
        // given identical per-thread logs.
        let mut merged: Vec<(u32, usize, usize, i32)> = Vec::new();
        for (tid, out) in outputs.iter().enumerate() {
            for (seq, &(round, p)) in out.elim_log.iter().enumerate() {
                merged.push((round, tid, seq, p));
            }
        }
        merged.sort_unstable();
        let elim_order: Vec<i32> = merged.iter().map(|&(_, _, _, p)| p).collect();
        let parent: Vec<i32> = sg.parent.iter().map(|a| a.load(Relaxed)).collect();
        let perm = crate::ordering::rebuild_perm(n, &elim_order, &parent);

        // Assemble detail + stats.
        let rounds = outputs
            .iter()
            .map(|o| o.ws.work_log.len())
            .max()
            .unwrap_or(0);
        let mut round_work = vec![vec![RoundWork::default(); t]; rounds];
        for (tid, out) in outputs.iter().enumerate() {
            for (r, w) in out.ws.work_log.iter().enumerate() {
                round_work[r][tid] = *w;
            }
        }
        let set_sizes = set_sizes_leader.into_inner().unwrap();
        let model_speedup = cost::model_speedup(&round_work, cost::DEFAULT_BARRIER_COST);

        let mut stats = OrderingStats {
            rounds: rounds as u64,
            pivots: elim_order.len() as u64,
            set_sizes: set_sizes.clone(),
            gc_count: gc_count.load(Relaxed) as u64,
            work_words: round_work
                .iter()
                .flatten()
                .map(|w| w.select + w.elim)
                .sum(),
            thread_work: outputs
                .iter()
                .map(|o| {
                    vec![
                        o.ws.work_log.iter().map(|w| w.select).sum::<u64>(),
                        o.ws.work_log.iter().map(|w| w.elim).sum::<u64>(),
                    ]
                })
                .collect(),
            modeled_time: 0.0,
        };
        let total = total_timer.secs();
        let select_total: f64 = outputs.iter().map(|o| o.select_secs).sum();
        let elim_total: f64 = outputs.iter().map(|o| o.elim_secs).sum();
        stats.modeled_time = if model_speedup > 0.0 {
            (select_total + elim_total) / model_speedup
        } else {
            0.0
        };

        let mut r = OrderingResult::new(perm);
        r.stats = stats;
        r.phases.add("select", select_total);
        r.phases.add("core", elim_total);
        r.phases
            .add("other", (total - select_total - elim_total).max(0.0));
        let detail = ParAmdDetail {
            round_work,
            set_sizes,
            select_secs: outputs.iter().map(|o| o.select_secs).collect(),
            elim_secs: outputs.iter().map(|o| o.elim_secs).collect(),
            model_speedup,
        };
        (r, detail)
    }
}

#[allow(clippy::too_many_arguments)]
fn run_thread(
    tid: usize,
    t: usize,
    lim: usize,
    cfg: ParAmd,
    g: &SymGraph,
    sg: &SharedGraph,
    aff: &Affinity,
    lmin: &[AtomicU64],
    lamds: &[AtomicUsize],
    sizes: &[AtomicUsize],
    barrier: &Barrier,
    progress_stall: &AtomicUsize,
    adaptive_mult: &AtomicUsize,
    poison: &std::sync::atomic::AtomicBool,
    gc_count: &AtomicUsize,
    set_sizes_leader: &std::sync::Mutex<Vec<u32>>,
) -> ThreadOutput {
    let n = g.n;
    let mut lists = ThreadLists::new(tid, n);
    let mut ws = Workspace::new(tid, n, cfg.seed);
    let mut elim_log: Vec<(u32, i32)> = Vec::new();
    let mut select_secs = 0.0;
    let mut elim_secs = 0.0;

    // Initial population: static chunk of the vertices.
    let (lo, hi) = chunk_range(n, t, tid);
    for v in lo..hi {
        lists.insert(aff, v, g.degree(v));
    }

    let mut round: u32 = 0;
    loop {
        let tsel = Timer::new();
        // Phase A: global minimum approximate degree.
        lamds[tid].store(lists.lamd(aff), Relaxed);
        barrier.wait();
        let amd = lamds.iter().map(|a| a.load(Relaxed)).min().unwrap();
        if amd >= n {
            break; // no live variables anywhere
        }

        // Phase B: candidates + Luby distance-2 independent set. The
        // round-stamped priorities make explicit l_min resets (and their
        // barrier) unnecessary.
        assert!(round <= dist2::MAX_ROUNDS, "round counter overflow");
        let mut work = RoundWork::default();
        let mult = if cfg.adaptive {
            adaptive_mult.load(Relaxed) as f64 / 1e6
        } else {
            cfg.mult
        };
        dist2::collect_candidates(&mut lists, aff, &mut ws, amd, mult, lim, n);
        let prios = dist2::luby_prepare(sg, &mut ws, round, &mut work.select);
        dist2::luby_min(sg, &mut ws, &prios, lmin, &mut work.select);
        barrier.wait();
        dist2::luby_validate(sg, &mut ws, &prios, lmin, &mut work.select);
        select_secs += tsel.secs();

        // Phase C: eliminate this thread's pivots.
        let telim = Timer::new();
        let mut eliminated_here: usize = 0;
        let pivots = std::mem::take(&mut ws.my_pivots);
        for &p in &pivots {
            if sg.st(p as usize) != shared::ST_VAR {
                debug_assert!(false, "pivot died before elimination");
                continue;
            }
            match elim::eliminate_pivot(
                sg,
                &mut ws,
                &mut lists,
                aff,
                p as usize,
                cfg.aggressive,
                &mut work.elim,
            ) {
                Outcome::Eliminated { .. } => {
                    elim_log.push((round, p));
                    eliminated_here += 1;
                }
                Outcome::Deferred => break, // elbow exhausted; stop batch
            }
        }
        ws.my_pivots = pivots;
        work.pivots = eliminated_here as u32;
        sizes[tid].store(eliminated_here, Relaxed);
        ws.work_log.push(work);
        elim_secs += telim.secs();
        barrier.wait();

        // Phase D: leader bookkeeping — GC, set sizes, stall detection.
        if tid == 0 {
            let total: usize = sizes.iter().map(|s| s.load(Relaxed)).sum();
            if total > 0 {
                set_sizes_leader.lock().unwrap().push(total as u32);
                progress_stall.store(0, Relaxed);
            } else {
                progress_stall.fetch_add(1, Relaxed);
            }
            if sg.gc_requested.load(Relaxed) {
                sg.garbage_collect_exclusive();
                gc_count.fetch_add(1, Relaxed);
            }
            if cfg.adaptive {
                // §5 extension: widen the degree window when the round was
                // starved of parallelism; relax back otherwise.
                let total: usize = sizes.iter().map(|s| s.load(Relaxed)).sum();
                let cur = adaptive_mult.load(Relaxed) as f64 / 1e6;
                let next = if total < t {
                    (cur * 1.05).min(cfg.adaptive_mult_max)
                } else if total > 4 * t {
                    (cur * 0.98).max(cfg.mult)
                } else {
                    cur
                };
                adaptive_mult.store((next * 1e6) as usize, Relaxed);
            }
            if progress_stall.load(Relaxed) >= 3 {
                // Elbow exhausted and GC is no longer reclaiming anything:
                // poison the run so every thread exits at the next check
                // (a direct panic here would strand peers at the barrier).
                poison.store(true, Relaxed);
            }
        }
        barrier.wait();
        if poison.load(Relaxed) {
            break;
        }
        round += 1;
    }

    ThreadOutput {
        ws,
        elim_log,
        select_secs,
        elim_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{mesh2d, mesh3d, random_graph};
    use crate::ordering::test_support::check_ordering_contract;
    use crate::ordering::{amd_seq::AmdSeq, Ordering as _};
    use crate::symbolic::fill_in;

    #[test]
    fn single_thread_valid_and_reasonable() {
        let g = mesh2d(16, 16);
        let r = ParAmd::new(1).order(&g);
        check_ordering_contract(&g, &r);
        let f_par = fill_in(&g, &r.perm) as f64;
        let f_seq = fill_in(&g, &AmdSeq::default().order(&g).perm) as f64;
        assert!(f_par <= f_seq * 1.6 + 100.0, "par={f_par} seq={f_seq}");
    }

    #[test]
    fn multi_thread_valid_permutations() {
        let g = mesh2d(20, 20);
        for t in [2, 4, 8] {
            let r = ParAmd::new(t).order(&g);
            check_ordering_contract(&g, &r);
        }
    }

    #[test]
    fn random_graphs_many_threads() {
        for seed in 0..4 {
            let g = random_graph(400, 6, seed);
            let r = ParAmd::new(4).with_seed(seed).order(&g);
            check_ordering_contract(&g, &r);
        }
    }

    #[test]
    fn mesh3d_quality_within_paper_band() {
        // The paper reports fill ratios of 1.01–1.19× over sequential AMD
        // (Table 4.2) with mult=1.1; allow a wider band at mini scale.
        let g = mesh3d(9, 9, 9);
        let f_seq = fill_in(&g, &AmdSeq::default().order(&g).perm) as f64;
        let r = ParAmd::new(4).order(&g);
        check_ordering_contract(&g, &r);
        let f_par = fill_in(&g, &r.perm) as f64;
        let ratio = f_par / f_seq;
        assert!(ratio < 1.6, "fill ratio {ratio:.3} out of band");
    }

    #[test]
    fn multiple_elimination_reduces_rounds() {
        let g = mesh2d(24, 24);
        let r = ParAmd::new(4).order(&g);
        assert!(r.stats.rounds > 0);
        assert!(
            (r.stats.rounds as usize) < g.n / 2,
            "rounds {} too close to n {}",
            r.stats.rounds,
            g.n
        );
        assert!(!r.stats.set_sizes.is_empty());
        let total: u32 = r.stats.set_sizes.iter().sum();
        assert_eq!(total as u64, r.stats.pivots);
    }

    #[test]
    fn mult_relaxation_grows_sets() {
        let g = mesh3d(8, 8, 8);
        let avg = |mult: f64| {
            let r = ParAmd::new(4).with_mult(mult).order(&g);
            let s = &r.stats.set_sizes;
            s.iter().map(|&x| x as f64).sum::<f64>() / s.len() as f64
        };
        let a10 = avg(1.0);
        let a12 = avg(1.2);
        assert!(
            a12 > a10,
            "relaxed sets should be larger: mult1.0={a10:.1} mult1.2={a12:.1}"
        );
    }

    #[test]
    fn tiny_elbow_triggers_gc_and_still_completes() {
        let g = mesh2d(30, 30);
        let r = ParAmd::new(2).with_elbow(0.30).order(&g);
        check_ordering_contract(&g, &r);
        assert!(r.stats.gc_count > 0, "expected GC under a tiny elbow");
    }

    #[test]
    fn single_thread_deterministic() {
        let g = random_graph(300, 5, 11);
        let a = ParAmd::new(1).with_seed(7).order(&g);
        let b = ParAmd::new(1).with_seed(7).order(&g);
        assert_eq!(a.perm, b.perm);
    }

    #[test]
    fn detail_counters_consistent() {
        let g = mesh2d(16, 16);
        let (r, d) = ParAmd::new(3).order_detailed(&g);
        check_ordering_contract(&g, &r);
        assert_eq!(d.round_work.len(), r.stats.rounds as usize);
        assert!(d.model_speedup > 0.0);
        let pivots: u32 = d.round_work.iter().flatten().map(|w| w.pivots).sum();
        assert_eq!(pivots as u64, r.stats.pivots);
        assert_eq!(d.select_secs.len(), 3);
    }

    #[test]
    fn adaptive_extension_grows_sets_when_starved() {
        // mini_nd24k-like: dense 3D mesh with small D2 sets.
        let g = crate::matgen::mesh3d_27pt(9, 9, 9);
        let (r_base, d_base) = ParAmd::new(8).order_detailed(&g);
        let (r_adapt, d_adapt) = ParAmd::new(8).with_adaptive().order_detailed(&g);
        check_ordering_contract(&g, &r_adapt);
        let avg = |r: &crate::ordering::OrderingResult| {
            r.stats.pivots as f64 / r.stats.rounds.max(1) as f64
        };
        assert!(
            avg(&r_adapt) > avg(&r_base) * 0.95,
            "adaptive should not shrink sets: {} vs {}",
            avg(&r_adapt),
            avg(&r_base)
        );
        assert!(d_adapt.model_speedup >= d_base.model_speedup * 0.8);
    }

    #[test]
    fn empty_graph() {
        let g = SymGraph::from_edges(0, &[]);
        let r = ParAmd::new(4).order(&g);
        assert!(r.perm.is_empty());
    }

    #[test]
    fn isolated_vertices_only() {
        let g = SymGraph::from_edges(7, &[]);
        let r = ParAmd::new(3).order(&g);
        check_ordering_contract(&g, &r);
    }

    use crate::graph::csr::SymGraph;
}
