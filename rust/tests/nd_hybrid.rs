//! ND×ParAMD hybrid integration: stitched-permutation validity across
//! the knob space, the fill-quality bound against pure ParAMD on 2D/3D
//! meshes, observed subdomain concurrency on one connected mesh, the
//! request-cache replay, and the disconnected-input bypass.

use paramd::graph::perm::is_valid_perm;
use paramd::matgen::{mesh2d, mesh3d, multi_component};
use paramd::ordering::hybrid::HybridConfig;
use paramd::ordering::paramd::ParAmd;
use paramd::ordering::shard::{ShardEngine, ShardSpec};
use paramd::ordering::Ordering as _;
use paramd::symbolic::fill_in;

fn hybrid(threshold: usize, depth: usize, balance: f64) -> HybridConfig {
    HybridConfig {
        enabled: true,
        partition_threshold: threshold,
        recursion_depth: depth,
        balance_factor: balance,
    }
}

#[test]
fn stitched_permutation_is_valid_across_the_knob_space() {
    let g = mesh2d(48, 48);
    for depth in 1..=3 {
        for balance in [1.2, 1.5, 2.0] {
            let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
            engine.set_hybrid(hybrid(500, depth, balance));
            let rep = engine.order(&g, ParAmd::new(1));
            assert!(
                is_valid_perm(&rep.perm),
                "invalid perm at depth={depth} balance={balance}"
            );
            assert_eq!(rep.perm.len(), g.n);
            let pivots: u32 = rep.set_sizes.iter().sum();
            assert_eq!(pivots as usize, g.n, "round log must cover every pivot");
        }
    }
}

#[test]
fn hybrid_fill_is_within_bounds_of_pure_paramd_on_mesh2d() {
    let g = mesh2d(64, 64);
    let pure = ParAmd::new(1).order(&g);
    let fill_pure = fill_in(&g, &pure.perm);
    let engine = ShardEngine::new(ShardSpec::uniform(4, 1));
    engine.set_hybrid(hybrid(1_000, 2, 1.5));
    let rep = engine.order(&g, ParAmd::new(1));
    assert!(is_valid_perm(&rep.perm));
    assert_eq!(engine.metrics().hybrid_requests, 1, "hybrid must engage");
    let fill_h = fill_in(&g, &rep.perm);
    assert!(
        (fill_h as f64) <= 1.15 * fill_pure as f64,
        "mesh2d hybrid fill {fill_h} exceeds 1.15x pure ParAMD {fill_pure}"
    );
}

#[test]
fn hybrid_fill_is_within_bounds_of_pure_paramd_on_mesh3d() {
    let g = mesh3d(12, 12, 12);
    let pure = ParAmd::new(1).order(&g);
    let fill_pure = fill_in(&g, &pure.perm);
    let engine = ShardEngine::new(ShardSpec::uniform(4, 1));
    engine.set_hybrid(hybrid(500, 1, 1.5));
    let rep = engine.order(&g, ParAmd::new(1));
    assert!(is_valid_perm(&rep.perm));
    assert_eq!(engine.metrics().hybrid_requests, 1, "hybrid must engage");
    let fill_h = fill_in(&g, &rep.perm);
    assert!(
        (fill_h as f64) <= 1.15 * fill_pure as f64,
        "mesh3d hybrid fill {fill_h} exceeds 1.15x pure ParAMD {fill_pure}"
    );
}

#[test]
fn one_connected_mesh_fans_out_and_runs_shards_concurrently() {
    // The whole point of the hybrid path: a single connected graph —
    // which the plain engine orders as ONE job on ONE shard — becomes
    // >= 4 independent subdomain jobs that demonstrably overlap
    // (busy_peak > 1 needs two dispatchers inside jobs at once).
    let g = mesh2d(120, 120);
    let engine = ShardEngine::new(ShardSpec::uniform(4, 1));
    engine.result_cache().set_budget(0); // every subdomain must dispatch
    engine.set_hybrid(hybrid(1_000, 2, 1.6));
    let rep = engine.order(&g, ParAmd::new(1));
    assert!(is_valid_perm(&rep.perm));
    assert_eq!(rep.perm.len(), g.n);
    let m = engine.metrics();
    assert_eq!(m.hybrid_requests, 1);
    assert!(m.subdomains >= 4, "depth 2 must cut >= 4 subdomains");
    assert!(
        m.busy_peak > 1,
        "subdomain jobs of one connected request must overlap (peak {})",
        m.busy_peak
    );
    let frac = m.separator_frac();
    assert!(frac > 0.0 && frac < 0.2, "separator fraction {frac}");
    assert!(m.subdomain_busy_secs > 0.0);
}

#[test]
fn repeated_hybrid_request_replays_from_the_request_cache() {
    let g = mesh2d(50, 50);
    let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
    engine.set_hybrid(hybrid(1_000, 2, 1.5));
    let first = engine.order(&g, ParAmd::new(1));
    let jobs: u64 = engine.metrics().per_shard.iter().map(|s| s.jobs).sum();
    let second = engine.order(&g, ParAmd::new(1));
    assert_eq!(second.perm, first.perm, "replay must bit-match");
    assert_eq!(second.rounds, first.rounds);
    let after: u64 = engine.metrics().per_shard.iter().map(|s| s.jobs).sum();
    assert_eq!(after, jobs, "a hybrid repeat must dispatch zero jobs");
    assert_eq!(
        engine.metrics().hybrid_requests,
        1,
        "the repeat must not re-partition"
    );
}

#[test]
fn disconnected_input_bypasses_the_hybrid_path() {
    // Hybrid planning targets one huge connected graph; a decomposed
    // request already has component parallelism and must not pay for
    // partitioning.
    let g = multi_component(4, &[400, 700]);
    let engine = ShardEngine::new(ShardSpec::uniform(2, 1));
    engine.set_hybrid(hybrid(100, 2, 1.5));
    let rep = engine.order(&g, ParAmd::new(1));
    assert!(is_valid_perm(&rep.perm));
    assert_eq!(rep.components, 4);
    let m = engine.metrics();
    assert_eq!(m.hybrid_requests, 0);
    assert_eq!(m.partition_secs, 0.0);
}
