//! # ParAMD — Parallel Approximate Minimum Degree Ordering
//!
//! A reproduction of *"Parallelizing the Approximate Minimum Degree Ordering
//! Algorithm: Strategies and Evaluation"* (Chang, Buluç, Demmel, 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)**: the parallel AMD algorithm itself — multiple
//!   elimination on distance-2 independent sets, concurrent degree lists and
//!   connection updates — plus every substrate the paper's evaluation needs:
//!   a SuiteSparse-faithful sequential AMD baseline, an MMD baseline, a
//!   multilevel nested-dissection comparator, symbolic analysis (elimination
//!   trees, exact fill-in counts), a sparse Cholesky solver, Matrix Market
//!   I/O, a synthetic matrix suite, and a coordinator service.
//! - **Layer 2 (python/compile/model.py)**: JAX blocked-Cholesky compute
//!   graphs, AOT-lowered to HLO text at build time.
//! - **Layer 1 (python/compile/kernels/)**: Pallas kernels for the dense
//!   factorization hot-spot, validated against pure-jnp oracles.
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT C API; Python
//! never runs on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use paramd::matgen;
//! use paramd::ordering::{amd_seq::AmdSeq, paramd::ParAmd, Ordering as _};
//!
//! let g = matgen::mesh2d(64, 64); // 5-point Laplacian pattern
//! let seq = AmdSeq::default().order(&g);
//! let par = ParAmd::new(8).order(&g);
//! let fill_seq = paramd::symbolic::fill_in(&g, &seq.perm);
//! let fill_par = paramd::symbolic::fill_in(&g, &par.perm);
//! println!("fill ratio = {:.3}", fill_par as f64 / fill_seq as f64);
//! ```

pub mod bench_util;
pub mod cli;
pub mod cholesky;
pub mod coordinator;
pub mod graph;
pub mod matgen;
pub mod nd;
pub mod ordering;
pub mod prop;
pub mod runtime;
pub mod symbolic;
pub mod telemetry;
pub mod util;
