//! The warm-path allocation guarantee, enforced at the allocator: a warm
//! `order_into` on pooled state must perform **zero** large (O(n)/O(nnz)-
//! sized) heap allocations. This file holds exactly one test so no other
//! test's allocations can pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering::Relaxed};

use paramd::matgen::mesh2d;
use paramd::ordering::paramd::arena::ParAmdArena;
use paramd::ordering::paramd::runtime::OrderingRuntime;
use paramd::ordering::paramd::ParAmd;

/// Counts allocations at least `BIG` bytes. For the mesh2d(80,80) graph
/// below (n = 6400, nnz ≈ 25k) every per-vertex array is ≥ 25 KB, well
/// above the threshold, while legitimately-small per-run bookkeeping
/// (per-round set sizes, per-thread second sums) stays far below it.
const BIG: usize = 16 * 1024;

static BIG_ALLOCS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if layout.size() >= BIG {
            BIG_ALLOCS.fetch_add(1, Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if new_size >= BIG {
            BIG_ALLOCS.fetch_add(1, Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn warm_order_makes_no_large_allocations() {
    let g = mesh2d(80, 80);
    // Single worker: the run is fully deterministic, so after the warm-up
    // runs every pooled buffer sits at its exact high-water mark and the
    // measured run cannot legitimately allocate — no flaky tolerance
    // needed. (Multi-thread warm reuse is covered by the arena
    // grow-counter tests, which don't depend on Vec doubling internals.)
    let cfg = ParAmd::new(1);
    let rt = OrderingRuntime::new(1);
    let mut arena = ParAmdArena::new();

    // Two warm-up runs: the first sizes the arena, the second settles any
    // lazily-grown scratch (logs, candidate buffers) at its high-water mark.
    cfg.order_into(&rt, &mut arena, &g);
    cfg.order_into(&rt, &mut arena, &g);

    let before = BIG_ALLOCS.load(Relaxed);
    let r = cfg.order_into(&rt, &mut arena, &g);
    assert_eq!(r.perm.len(), g.n);
    let after = BIG_ALLOCS.load(Relaxed);
    assert_eq!(
        after, before,
        "warm order_into must not perform any O(n)/O(nnz)-sized allocation"
    );
}
