//! Full-pipeline integration: matrix generation → (nonsymmetric)
//! symmetrization → ordering → symbolic → numeric factor (native + PJRT)
//! → solve → residual, plus Matrix Market round-trips — the composition
//! the paper's Tables 1.1/4.3 rely on.

use paramd::cholesky::{factor, residual, solve, DenseTail, NativeDense};
use paramd::coordinator::{Method, OrderRequest, Service, SolveSpec};
use paramd::graph::{mm, symmetrize};
use paramd::matgen::{self, nonsymmetric_flow, spd_from_graph, Scale};
use paramd::ordering::{amd_seq::AmdSeq, paramd::ParAmd, Ordering as _};

#[test]
fn suite_matrices_order_and_solve_native() {
    for e in matgen::suite() {
        let g = (e.gen)(Scale::Tiny);
        let a = spd_from_graph(&g, 1.0);
        let perm = ParAmd::new(2).order(&g).perm;
        let f = factor(&a, &perm, DenseTail::default(), &NativeDense).unwrap();
        let b = vec![1.0; a.nrows];
        let x = solve(&f, &b);
        let r = residual(&a, &x, &b);
        assert!(r < 1e-9, "{}: residual {r:e}", e.name);
    }
}

#[test]
fn nonsymmetric_input_via_symmetrization_path() {
    let a = nonsymmetric_flow(8, 8, 8, 3);
    assert!(!a.is_pattern_symmetric());
    let g = symmetrize(&a);
    let r = AmdSeq::default().order(&g);
    assert_eq!(r.perm.len(), a.nrows);
    // The ordering applies to A + A^T; factoring the SPD proxy built from
    // the symmetrized pattern must succeed.
    let spd = spd_from_graph(&g, 1.0);
    let f = factor(&spd, &r.perm, DenseTail::None, &NativeDense).unwrap();
    let b = vec![1.0; spd.nrows];
    let x = solve(&f, &b);
    assert!(residual(&spd, &x, &b) < 1e-10);
}

#[test]
fn matrix_market_roundtrip_through_pipeline() {
    let dir = std::env::temp_dir().join("paramd_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pipe.mtx");
    let g = matgen::mesh2d(9, 9);
    let a = spd_from_graph(&g, 1.0);
    mm::write_matrix_market(&path, &a).unwrap();
    let a2 = mm::read_matrix_market(&path).unwrap();
    assert_eq!(a, a2);
    let g2 = symmetrize(&a2);
    let perm = AmdSeq::default().order(&g2).perm;
    let f = factor(&a2, &perm, DenseTail::default(), &NativeDense).unwrap();
    let b = vec![2.0; a2.nrows];
    let x = solve(&f, &b);
    assert!(residual(&a2, &x, &b) < 1e-10);
}

#[test]
fn service_runs_mixed_workload_with_metrics() {
    let svc = Service::new(2);
    for (i, e) in matgen::suite().into_iter().enumerate() {
        let g = (e.gen)(Scale::Tiny);
        let method = if i % 2 == 0 {
            Method::ParAmd {
                threads: 2,
                mult: 1.1,
                lim_total: 0,
            }
        } else {
            Method::Amd
        };
        let rep = svc.order(&OrderRequest {
            matrix: Some(spd_from_graph(&g, 1.0)),
            pattern: None,
            method,
            compute_fill: true,
        });
        assert_eq!(rep.perm.len(), g.n);
    }
    assert_eq!(svc.metrics().total_requests() as usize, matgen::suite().len());
    let report = svc.metrics().report();
    assert!(report.contains("amd"));
    assert!(report.contains("paramd"));
}

#[test]
fn service_solve_via_pjrt_when_artifacts_present() {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the `pjrt` feature");
        return;
    }
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let svc = Service::new(1)
        .with_pjrt_solver("artifacts".into())
        .expect("pjrt init");
    let g = matgen::mesh2d(11, 11);
    let rep = svc
        .solve(
            &OrderRequest {
                matrix: Some(spd_from_graph(&g, 1.0)),
                pattern: None,
                method: Method::Amd,
                compute_fill: false,
            },
            &SolveSpec::OnesSolution,
        )
        .unwrap();
    assert_eq!(rep.engine, "pjrt");
    assert!(rep.residual < 1e-10, "{:e}", rep.residual);
    assert!(rep.dense_tail_cols > 0, "expected a PJRT-factored tail");
}

#[test]
fn ordering_reduces_solver_work_vs_natural() {
    // The whole point of fill-reducing orderings: nnz(L) with AMD must be
    // well below nnz(L) with the natural order on a 2D mesh.
    let g = matgen::mesh2d(24, 24);
    let a = spd_from_graph(&g, 1.0);
    let natural: Vec<i32> = (0..g.n as i32).collect();
    let amd = AmdSeq::default().order(&g).perm;
    let f_nat = factor(&a, &natural, DenseTail::None, &NativeDense).unwrap();
    let f_amd = factor(&a, &amd, DenseTail::None, &NativeDense).unwrap();
    assert!(
        (f_amd.nnz_l as f64) < 0.8 * f_nat.nnz_l as f64,
        "amd {} vs natural {}",
        f_amd.nnz_l,
        f_nat.nnz_l
    );
}
