//! Table 4.3: end-to-end solver comparison — ordering (sequential AMD,
//! ParAMD, ND) followed by the three-layer solver on the reordered SPD
//! system, over shared random permutations. The paper's GPU solver
//! (cuDSS) is replaced by our Rust + PJRT/Pallas solver (DESIGN.md §2).

#[path = "bench_common/mod.rs"]
mod bench_common;

use paramd::bench_util::Table;
use paramd::cholesky::{factor, residual, solve, DenseTail};
use paramd::matgen::{self, spd_from_graph};
use paramd::nd::NestedDissection;
use paramd::ordering::{amd_seq::AmdSeq, paramd::ParAmd, Ordering};
use paramd::runtime::{PjrtDense, PjrtEngine};
use paramd::util::stats;
use paramd::util::timer::Timer;

fn main() {
    let t = bench_common::threads();
    bench_common::banner("Table 4.3 — end-to-end solver comparison", "paper §4.6 Table 4.3");
    let engine = PjrtEngine::load_default().expect("run `make artifacts` first");
    let dense = PjrtDense { engine: &engine };
    let tail = DenseTail::Auto {
        max: 256,
        min_density: 0.5,
    };
    let mut table = Table::new(&[
        "Matrix",
        "Method",
        "Ordering (s)",
        "Solver (s)",
        "residual(max)",
    ]);
    for e in matgen::suite() {
        if !e.symmetric {
            continue; // SPD systems only, like the paper
        }
        let g0 = (e.gen)(bench_common::scale());
        let perms = bench_common::random_permutations(&g0, 3);
        let methods: Vec<(&str, Box<dyn Fn(&paramd::graph::csr::SymGraph) -> Vec<i32>>)> = vec![
            ("AMD (seq)", Box::new(|g| AmdSeq::default().order(g).perm)),
            (
                "ParAMD",
                Box::new(move |g| ParAmd::new(t).order(g).perm),
            ),
            ("ND", Box::new(|g| NestedDissection::default().order(g).perm)),
        ];
        for (label, run) in &methods {
            let mut ord_times = vec![];
            let mut solver_times = vec![];
            let mut worst_resid = 0f64;
            for g in &perms {
                let a = spd_from_graph(g, 1.0);
                let timer = Timer::new();
                let perm = run(g);
                ord_times.push(timer.secs());
                let timer = Timer::new();
                let f = factor(&a, &perm, tail, &dense).unwrap();
                let b = vec![1.0; a.nrows];
                let x = solve(&f, &b);
                solver_times.push(timer.secs());
                worst_resid = worst_resid.max(residual(&a, &x, &b));
            }
            table.row(vec![
                e.name.into(),
                label.to_string(),
                format!(
                    "{:.3} ± {:.3}",
                    stats::mean(&ord_times),
                    stats::std_dev(&ord_times)
                ),
                format!(
                    "{:.3} ± {:.3}",
                    stats::mean(&solver_times),
                    stats::std_dev(&solver_times)
                ),
                format!("{worst_resid:.1e}"),
            ]);
        }
    }
    table.print();
    println!(
        "\npaper shape: ParAMD cuts ordering time vs sequential AMD with a slight\n\
         solver-time increase (extra fill); ND orders slower/comparably but the\n\
         reordered system solves faster (fewer fill-ins)."
    );
}
