//! Table 3.1: why intra-elimination parallelism fails — average `|L_p|`
//! (parallelism), `Σ_{v∈L_p}|E_v|` (work), and `|∪_{v∈L_p}E_v|` (unique
//! elements = contention) across the elimination steps of sequential AMD.

#[path = "bench_common/mod.rs"]
mod bench_common;

use paramd::bench_util::Table;
use paramd::matgen::{self};
use paramd::ordering::amd_seq::AmdSeq;

fn main() {
    bench_common::banner("Table 3.1 — intra-elimination parallelism", "paper §3.1 Table 3.1");
    let mut table = Table::new(&["Matrix", "|L_p|", "Σ|E_v|", "|∪E_v|"]);
    for name in ["mini_nd24k", "mini_flan", "mini_nlpkkt"] {
        let e = matgen::suite_entry(name).unwrap();
        let g = (e.gen)(bench_common::scale());
        let (_, steps) = AmdSeq::default().order_with_step_stats(&g);
        let n = steps.len() as f64;
        let lp: f64 = steps.iter().map(|s| s.lp as f64).sum::<f64>() / n;
        let work: f64 = steps.iter().map(|s| s.work as f64).sum::<f64>() / n;
        let uniq: f64 = steps.iter().map(|s| s.unique_elems as f64).sum::<f64>() / n;
        table.row(vec![
            name.into(),
            format!("{lp:.1}"),
            format!("{work:.1}"),
            format!("{uniq:.1}"),
        ]);
    }
    table.print();
    println!(
        "\npaper (full scale): nd24k 329.7/587.5/14.0, Flan 43.8/64.8/10.2, \
         nlpkkt240 80.5/542.8/56.3"
    );
    println!("expected shape: |∪E_v| ≪ |L_p| (contention) and Σ|E_v| ≈ O(|L_p|) (little work).");
}
