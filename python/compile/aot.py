"""AOT compile path: lower the Layer-2 graphs to HLO *text* artifacts.

HLO text — never `.serialize()` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that the xla_extension
0.5.1 backing the `xla` crate rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts are f64 (`jax_enable_x64`): the Rust sparse solver is f64 and
the dense trailing block must not dominate its residual. A real-TPU build
would emit bf16/f32 kernels and recover precision with iterative
refinement (DESIGN.md §3).

Usage: python -m compile.aot --out ../artifacts/model.hlo.txt
The `--out` path names the *primary* artifact; every sized variant plus a
manifest is written next to it.
"""

import argparse
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402

# Dense-tail tile sizes the Rust runtime may request (padded upward).
SIZES = (32, 64, 128, 256)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_factor(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n, n), jnp.float64)
    return to_hlo_text(jax.jit(model.cholesky_factor).lower(spec))


def lower_solve(n: int) -> str:
    a = jax.ShapeDtypeStruct((n, n), jnp.float64)
    b = jax.ShapeDtypeStruct((n,), jnp.float64)
    return to_hlo_text(jax.jit(model.cholesky_solve).lower(a, b))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt")
    ap.add_argument("--sizes", type=int, nargs="*", default=list(SIZES))
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    art_dir = out.parent
    art_dir.mkdir(parents=True, exist_ok=True)

    manifest = []
    for n in args.sizes:
        for kind, lower in (("chol", lower_factor), ("solve", lower_solve)):
            path = art_dir / f"{kind}_{n}.hlo.txt"
            text = lower(n)
            path.write_text(text)
            manifest.append(f"{kind} {n} {path.name}")
            print(f"wrote {path} ({len(text)} chars)")
    (art_dir / "manifest.txt").write_text("\n".join(manifest) + "\n")
    # The primary artifact doubles as the make-target sentinel.
    out.write_text((art_dir / f"chol_{max(args.sizes)}.hlo.txt").read_text())
    print(f"wrote {out} (sentinel, chol_{max(args.sizes)})")


if __name__ == "__main__":
    main()
